"""Fault-tolerant checkpointing.

Design (DESIGN.md §6):
  * atomic: write to a tmp dir, fsync, then os.rename — a crash mid-write
    never corrupts the latest valid checkpoint;
  * self-describing: pytree structure stored as a path->array npz plus a
    JSON manifest (step, timestamp, aux state such as the data-iterator
    cursor);
  * keep-N garbage collection;
  * async: an optional background thread does the serialization so the
    train loop is not blocked (device->host copy happens synchronously,
    which is the correctness boundary);
  * elastic: arrays are saved unsharded (host RAM), so a restore may apply
    ANY NamedSharding — resuming on a different mesh shape re-shards for
    free (world-size changes after node failure).
  * restore scans newest->oldest and skips corrupt/partial checkpoints.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _unflatten(template, flat: Dict[str, np.ndarray]):
    leaves_p, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in leaves_p:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = flat[key]
        if hasattr(leaf, "shape") and tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(
                f"shape mismatch for {key}: ckpt {arr.shape} vs template "
                f"{leaf.shape}")
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3,
                 async_write: bool = False):
        self.dir = directory
        self.keep = keep
        self.async_write = async_write
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:010d}")

    def all_steps(self):
        steps = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    steps.append(int(name.split("_")[1]))
                except ValueError:
                    continue
        return sorted(steps)

    # ------------------------------------------------------------------
    def save(self, step: int, state, extra: Optional[Dict[str, Any]] = None,
             block: bool = True):
        """Persist `state` (any pytree) + small JSON-able `extra` dict."""
        state = jax.tree.map(lambda x: np.asarray(x), state)  # host copy
        self.wait()  # never two concurrent writers (same-step race)
        if self.async_write and not block:
            # non-daemon on purpose: if the train loop dies (induced fault,
            # uncaught exception) the interpreter still joins this thread at
            # shutdown, so an in-flight checkpoint finishes its atomic
            # tmp->rename instead of being torn down mid-write — crash one
            # step after a save kick-off must not lose the checkpoint.
            self._thread = threading.Thread(
                target=self._write, args=(step, state, extra), daemon=False)
            self._thread.start()
        else:
            self._write(step, state, extra)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, state, extra):
        final = self._step_dir(step)
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        flat = _flatten(state)
        np.savez(os.path.join(tmp, "arrays.npz"), **flat)
        manifest = {"step": step, "time": time.time(),
                    "extra": extra or {}, "n_arrays": len(flat)}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep > 0 else []:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # ------------------------------------------------------------------
    def restore_latest(self, template, sharding=None):
        """Restore the newest *valid* checkpoint into `template`'s
        structure.  Returns (state, step, extra) or (None, -1, {}).

        `sharding`: optional pytree (or single sharding) applied via
        jax.device_put — this is the elastic re-shard path.
        """
        for step in reversed(self.all_steps()):
            try:
                return (*self._load(step, template, sharding), )
            except Exception:
                continue
        return None, -1, {}

    def _load(self, step: int, template, sharding):
        d = self._step_dir(step)
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        with np.load(os.path.join(d, "arrays.npz")) as z:
            flat = {k: z[k] for k in z.files}
        if len(flat) != manifest["n_arrays"]:
            raise IOError("truncated checkpoint")
        state = _unflatten(template, flat)
        if sharding is not None:
            if jax.tree_util.treedef_is_leaf(
                    jax.tree_util.tree_structure(sharding)):
                state = jax.device_put(state, sharding)
            else:
                state = jax.tree.map(
                    lambda x, s: jax.device_put(x, s), state, sharding)
        return state, step, manifest.get("extra", {})
