"""checkpoint substrate."""
