"""qwen3-4b [dense]: qk_norm, GQA.  36L d_model=2560 32H (GQA kv=8,
head_dim=128) d_ff=9728 vocab=151936.  [hf:Qwen/Qwen3-8B; hf]

Largest vocab of the pool — the most paper-representative cell: the
Bloom IO layer removes ~78% of the 151,936-row embedding + head.
"""
import dataclasses

from repro.configs.base import BloomConfig, ModelConfig

ARCH = "qwen3-4b"


def config(bloom: bool = True) -> ModelConfig:
    return ModelConfig(
        name=ARCH,
        family="dense",
        num_layers=36,
        d_model=2560,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        d_ff=9728,
        vocab=151936,
        qk_norm=True,
        rope_theta=1_000_000.0,
        bloom=BloomConfig(enabled=bloom, m_ratio=0.2, k=4),
    )


def smoke() -> ModelConfig:
    return dataclasses.replace(
        config(),
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, vocab=512, dtype="float32", attn_chunk_q=16,
        attn_chunk_k=16,
        bloom=BloomConfig(enabled=True, m_ratio=0.25, k=3),
    )
