"""Web-scale Bloom retrieval scenario configs (DESIGN.md §11).

The retrieval scenario is NOT a ModelConfig architecture: there is no
token LM, no KV cache, no autoregressive loop.  A request carries a
padded item-id set, prefill Bloom-encodes it (core.bloom.encode, Eq. 1)
and runs a small FF tower (models/recommender.py) to an m-dim output,
and the single recover step streams the Eq. 3 top-k over the d-item
catalog — so the scenario gets its own frozen config describing exactly
those pieces.

Scale notes that drive the presets:
  * ``on_the_fly=True`` always: at d=10M a precomputed (d, k) int32 hash
    matrix is ~80 MB per k=2 spec (160 MB at k=4) and
    ``core.bloom.cached_hash_matrix`` retains up to 8 of them
    (lru_cache) — the double-hash recomputes indices in-graph instead,
    which is exactly what the streaming decode wants.
  * the streaming decode's working set is (B, m) + one (chunk, k) index
    block; the dense-table oracle it replaces needs the full (d, m)
    table plus a (B, d) score matrix — the modeled-bytes gap
    bench_serving.py gates on (retrieval.* rows).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

from repro.core.bloom import BloomSpec


@dataclasses.dataclass(frozen=True)
class RetrievalConfig:
    """Static description of one retrieval serving scenario."""

    name: str = "retrieval"
    d: int = 1_000_000        # item-catalog size
    m: int = 4096             # Bloom-compressed output dimensionality
    k: int = 2                # hash projections (paper: 2..4 best)
    c_max: int = 8            # input items per request (padded, -1)
    hidden: Tuple[int, ...] = (64, 64)   # FF tower widths
    topk: int = 10            # retrieved items per request
    seed: int = 0             # hash seed AND tower-init seed
    impl: str = "auto"        # "auto" | "xla" | "pallas" decode path
    chunk: int = 65536        # streaming-oracle vocab chunk (xla path)
    b_tile: int = 8           # kernel row-block (pallas path + bytes model)
    table_dtype: str = "auto" # pool-logits storage dtype for the decode
                              # (DESIGN.md §13): auto (legacy f32) |
                              # float32 | bfloat16 | int8 | fp8_e4m3; the
                              # quantized pallas path also re-derives hash
                              # indices in-kernel (no (d, k) stream), and
                              # the xla path fake-quantizes so both impls
                              # rank through identical dequantized scores

    def __post_init__(self):
        if not (0 < self.m <= self.d):
            raise ValueError(f"need 0 < m <= d, got m={self.m} d={self.d}")
        if not (1 <= self.topk <= self.d):
            raise ValueError(f"need 1 <= topk <= d, got topk={self.topk}")
        if self.c_max < 1:
            raise ValueError(f"need c_max >= 1, got {self.c_max}")
        if self.impl not in ("auto", "xla", "pallas"):
            raise ValueError(f"unknown decode impl {self.impl!r}")
        from repro.core import quant
        quant.resolve_table_dtype(self.table_dtype, allow_auto=True)

    def spec(self) -> BloomSpec:
        """The Bloom IO spec; on_the_fly on purpose (see module doc)."""
        return BloomSpec(d=self.d, m=self.m, k=self.k, seed=self.seed,
                         on_the_fly=True)

    @property
    def resolved_impl(self) -> str:
        """``auto`` resolves per backend: the fused Pallas kernel on TPU,
        the jitted streaming oracle (core.bloom.decode_topk) elsewhere —
        interpret-mode Pallas at a 10M-item grid is CI-infeasible, and
        the two paths share the tie-break contract (DESIGN.md §11) so
        the recovered ids are identical."""
        if self.impl != "auto":
            return self.impl
        import jax
        return "pallas" if jax.default_backend() == "tpu" else "xla"


# Presets: web1m fits CI wall-clock comfortably; web10m is the "dense
# table cannot fit" acceptance scale (d*m*4 = 320 GB dense vs an 8 MB
# streaming working set); smoke keeps full-score eval affordable.
RETRIEVAL_CONFIGS: Dict[str, RetrievalConfig] = {
    "web1m": RetrievalConfig(name="web1m", d=1_000_000, m=4096, k=2),
    "web10m": RetrievalConfig(name="web10m", d=10_000_000, m=8192, k=2),
    "smoke": RetrievalConfig(name="smoke", d=50_000, m=256, k=2,
                             hidden=(32,), topk=8, chunk=8192),
    # training/eval scale (train/retrieval_trainer.py): small enough
    # that the full-score (B, d) ranking eval and a CPU training drill
    # fit CI wall-clock, big enough that an untrained tower's MAP is
    # ~1/d-noise — the compression sweep replaces m per point
    # (m = d/ratio for ratio in {1, 2, 5, 10})
    "eval2k": RetrievalConfig(name="eval2k", d=2_000, m=400, k=2,
                              hidden=(32,), topk=10, chunk=2048),
}


def get_retrieval_config(name: str, **overrides) -> RetrievalConfig:
    if name not in RETRIEVAL_CONFIGS:
        raise KeyError(f"unknown retrieval config {name!r}; known: "
                       f"{tuple(RETRIEVAL_CONFIGS)}")
    cfg = RETRIEVAL_CONFIGS[name]
    return dataclasses.replace(cfg, **overrides) if overrides else cfg
