"""qwen1.5-0.5b [dense]: QKV bias, tied embeddings.  24L d_model=1024 16H
(MHA kv=16) d_ff=2816 vocab=151936.  [hf:Qwen/Qwen1.5-0.5B; hf]

Vocab-dominated model: the 151,936 x 1024 embedding is ~34% of all
parameters — the paper's '99.9%' regime scaled to 2024; Bloom IO at
m/d=0.2 removes ~27% of the entire model.
"""
import dataclasses

from repro.configs.base import BloomConfig, ModelConfig

ARCH = "qwen1.5-0.5b"


def config(bloom: bool = True) -> ModelConfig:
    return ModelConfig(
        name=ARCH,
        family="dense",
        num_layers=24,
        d_model=1024,
        num_heads=16,
        num_kv_heads=16,
        head_dim=64,
        d_ff=2816,
        vocab=151936,
        qkv_bias=True,
        tie_embeddings=True,
        rope_theta=1_000_000.0,
        bloom=BloomConfig(enabled=bloom, m_ratio=0.2, k=4),
    )


def smoke() -> ModelConfig:
    return dataclasses.replace(
        config(),
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
        d_ff=128, vocab=512, dtype="float32", attn_chunk_q=16,
        attn_chunk_k=16,
        bloom=BloomConfig(enabled=True, m_ratio=0.25, k=3),
    )
