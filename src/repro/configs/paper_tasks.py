"""The paper's own 7 experimental setups (Sec. 4.2, Tables 1 & 2) as
configs over the synthetic generators.

Statistics (d, median c, architecture, optimizer, measure) follow the
paper; n is scaled down so each task trains in seconds on CPU while
keeping density c/d and the latent co-occurrence structure in range.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence, Tuple

from repro.configs.base import TrainConfig


@dataclasses.dataclass(frozen=True)
class PaperTask:
    name: str
    kind: str                  # recsys | classify | session
    d: int                     # item/vocab dimensionality
    n: int                     # instances (scaled from the paper)
    mean_items: int            # median nonzero components c (Table 1)
    arch_hidden: Tuple[int, ...]
    cell: str = ""             # gru | lstm for sequence tasks
    measure: str = "map"       # map | rr | acc
    optimizer: str = "adam"
    learning_rate: float = 1e-3
    momentum: float = 0.0
    grad_clip: float = 0.0
    epochs: int = 12
    batch: int = 128
    n_classes: int = 0

    def train_config(self, steps: int) -> TrainConfig:
        return TrainConfig(
            learning_rate=self.learning_rate,
            optimizer=self.optimizer,
            momentum=self.momentum,
            grad_clip_norm=self.grad_clip,
            steps=steps,
            warmup_steps=0,
            checkpoint_every=0,
        )


# paper Table 2: architecture + optimizer per task.
PAPER_TASKS = {
    # ML: 3-layer FF + Adam, MAP; d=15,405 c=18 (densest: c/d 1.2e-3)
    "ML": PaperTask("ML", "recsys", d=1600, n=4000, mean_items=18,
                    arch_hidden=(150, 150), measure="map"),
    # MSD: 3-layer FF + Adam, MAP; c=5
    "MSD": PaperTask("MSD", "recsys", d=2400, n=5000, mean_items=5,
                     arch_hidden=(300, 300), measure="map"),
    # AMZ: 4-layer FF + Adam, MAP; c=1-2
    "AMZ": PaperTask("AMZ", "recsys", d=2000, n=5000, mean_items=3,
                     arch_hidden=(300, 300, 300), measure="map"),
    # BC: like MSD with 250 units; c=2
    "BC": PaperTask("BC", "recsys", d=2400, n=2500, mean_items=3,
                    arch_hidden=(250, 250), measure="map"),
    # YC: GRU(100) + Adagrad lr=0.01, RR
    "YC": PaperTask("YC", "session", d=2000, n=5000, mean_items=6,
                    arch_hidden=(100,), cell="gru", measure="rr",
                    optimizer="adagrad", learning_rate=0.01),
    # PTB: LSTM(250) + SGD lr=0.25 momentum=0.99 clip=1, RR
    "PTB": PaperTask("PTB", "session", d=2000, n=6000, mean_items=10,
                     arch_hidden=(250,), cell="lstm", measure="rr",
                     optimizer="sgd", learning_rate=0.25, momentum=0.99,
                     grad_clip=1.0),
    # CADE: 4-layer FF(400,200,100)+RMSprop lr=2e-4, Acc, 12 classes,
    # input-embedding only
    "CADE": PaperTask("CADE", "classify", d=4000, n=3000, mean_items=17,
                      arch_hidden=(400, 200, 100), measure="acc",
                      optimizer="rmsprop", learning_rate=2e-4,
                      n_classes=12),
}
