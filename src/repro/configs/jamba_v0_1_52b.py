"""jamba-v0.1-52b [hybrid]: Mamba+attention 1:7 interleave, MoE 16e top-2
every other layer.  32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536.
[arXiv:2403.19887; hf]

Layout: period-8 super-blocks with attention at offset 4 (1 attn : 7
mamba), MoE FFN on odd layers.  Jamba's attention uses no positional
encoding (use_rope=False); the SSM follows our Mamba-2 SSD block with
Jamba's d_state=16 (DESIGN.md §4 notes the Mamba-1 -> SSD substitution).
"""
import dataclasses

from repro.configs.base import (BloomConfig, MambaConfig, MoEConfig,
                                ModelConfig)

ARCH = "jamba-v0.1-52b"


def config(bloom: bool = True) -> ModelConfig:
    return ModelConfig(
        name=ARCH,
        family="hybrid",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab=65536,
        use_rope=False,
        attn_layer_period=8,
        attn_layer_offset=4,
        moe=MoEConfig(num_experts=16, top_k=2, num_shared=0,
                      d_ff_expert=14336),
        moe_layer_period=2,
        mamba=MambaConfig(d_state=16, d_conv=4, expand=2, head_dim=64,
                          chunk=256),
        moe_impl="ep",
        bloom=BloomConfig(enabled=bloom, m_ratio=0.2, k=4),
    )


def smoke() -> ModelConfig:
    return dataclasses.replace(
        config(),
        num_layers=8, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, vocab=512, dtype="float32", attn_chunk_q=16,
        attn_chunk_k=16, moe_impl="dense",
        moe=MoEConfig(num_experts=4, top_k=2, num_shared=0, d_ff_expert=64,
                      capacity_factor=8.0),
        mamba=MambaConfig(d_state=8, d_conv=4, expand=2, head_dim=16,
                          chunk=8),
        bloom=BloomConfig(enabled=True, m_ratio=0.25, k=3),
    )
