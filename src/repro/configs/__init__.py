"""Config registry: the 10 assigned architectures (+ reduced smoke
variants), the 4 input-shape cells, the paper's 7 tasks, and the
input_specs() stand-ins used by the multi-pod dry-run.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import (
    deepseek_moe_16b,
    granite_8b,
    jamba_v0_1_52b,
    mamba2_1_3b,
    olmoe_1b_7b,
    paper_tasks,
    phi3_mini_3_8b,
    pixtral_12b,
    qwen1_5_0_5b,
    qwen3_4b,
    whisper_small,
)
from repro.configs.retrieval import (  # noqa: F401
    RETRIEVAL_CONFIGS,
    RetrievalConfig,
    get_retrieval_config,
)
from repro.configs.base import (  # noqa: F401
    BloomConfig,
    MambaConfig,
    MeshConfig,
    MoEConfig,
    ModelConfig,
    SHAPES,
    SHAPE_BY_NAME,
    ShapeConfig,
    TrainConfig,
)

_MODULES = (
    pixtral_12b,
    phi3_mini_3_8b,
    granite_8b,
    qwen3_4b,
    qwen1_5_0_5b,
    whisper_small,
    deepseek_moe_16b,
    olmoe_1b_7b,
    jamba_v0_1_52b,
    mamba2_1_3b,
)

ARCH_MODULES: Dict[str, object] = {m.ARCH: m for m in _MODULES}
ARCH_NAMES = tuple(ARCH_MODULES)
PAPER_TASKS = paper_tasks.PAPER_TASKS


def get_config(arch: str, bloom: bool = True, **overrides) -> ModelConfig:
    if arch not in ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_NAMES}")
    cfg = ARCH_MODULES[arch].config(bloom=bloom)
    return dataclasses.replace(cfg, **overrides) if overrides else cfg


def get_smoke_config(arch: str, **overrides) -> ModelConfig:
    cfg = ARCH_MODULES[arch].smoke()
    return dataclasses.replace(cfg, **overrides) if overrides else cfg


# --------------------------------------------------------------------------
# Cell grid: which (arch x shape) pairs run (DESIGN.md §5)
# --------------------------------------------------------------------------

def cell_is_runnable(cfg: ModelConfig, shape: ShapeConfig
                     ) -> tuple[bool, str]:
    """long_500k needs sub-quadratic context cost; only ssm/hybrid run it."""
    if shape.name == "long_500k" and cfg.has_full_attention:
        return False, ("skip: full quadratic attention at 524k context "
                       "(documented in DESIGN.md §5)")
    return True, ""


def all_cells():
    """Yield (arch, shape, runnable, reason) for the 40-cell grid."""
    for arch in ARCH_NAMES:
        cfg = get_config(arch)
        for shape in SHAPES:
            ok, reason = cell_is_runnable(cfg, shape)
            yield arch, shape.name, ok, reason


# --------------------------------------------------------------------------
# input_specs: ShapeDtypeStruct stand-ins (no allocation) per cell
# --------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, object]:
    """Model inputs for one cell as ShapeDtypeStructs.

    train/prefill: token (and stub-embedding) sequences.
    decode: one new token; caches are produced by cache_specs() below.

    Frontend conventions (DESIGN.md §5): vlm reserves frontend_frac of the
    sequence for patch embeddings; audio uses seq_len encoder frames and
    seq_len//4 decoder tokens.
    """
    B, S = shape.global_batch, shape.seq_len
    i32, f32 = jnp.int32, jnp.bfloat16
    if shape.kind == "decode":
        return {"tokens": _sds((B, 1), i32)}
    if cfg.family == "vlm":
        s_img = int(S * cfg.frontend_frac)
        return {"tokens": _sds((B, S - s_img), i32),
                "embeds": _sds((B, s_img, cfg.d_model), f32)}
    if cfg.family == "audio":
        return {"tokens": _sds((B, max(S // 4, 16)), i32),
                "embeds": _sds((B, S, cfg.d_model), f32)}
    return {"tokens": _sds((B, S), i32)}


def cache_specs(cfg: ModelConfig, shape: ShapeConfig):
    """Decode-cache ShapeDtypeStructs via eval_shape (no allocation)."""
    from repro.models import encdec as encdec_lib
    from repro.models import transformer as tf
    B, S = shape.global_batch, shape.seq_len
    if cfg.family == "audio":
        enc_len = 1500  # whisper's 30 s of frames
        return jax.eval_shape(
            lambda: encdec_lib.init_encdec_cache(cfg, B, S, enc_len))
    return jax.eval_shape(lambda: tf.init_lm_cache(cfg, B, S))
