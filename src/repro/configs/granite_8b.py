"""granite-8b [dense]: llama-arch code model.  36L d_model=4096 32H
(GQA kv=8) d_ff=14336 vocab=49152.  [arXiv:2405.04324; hf]
"""
import dataclasses

from repro.configs.base import BloomConfig, ModelConfig

ARCH = "granite-8b"


def config(bloom: bool = True) -> ModelConfig:
    return ModelConfig(
        name=ARCH,
        family="dense",
        num_layers=36,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab=49152,
        rope_theta=10_000.0,
        bloom=BloomConfig(enabled=bloom, m_ratio=0.2, k=4),
    )


def smoke() -> ModelConfig:
    return dataclasses.replace(
        config(),
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, vocab=512, dtype="float32", attn_chunk_q=16,
        attn_chunk_k=16,
        bloom=BloomConfig(enabled=True, m_ratio=0.25, k=3),
    )
