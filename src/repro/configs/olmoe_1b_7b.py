"""olmoe-1b-7b [moe]: 64 experts top-8, no shared.  16L d_model=2048 16H
(MHA kv=16) d_ff(expert)=1024 vocab=50304.  [arXiv:2409.02060; hf]
"""
import dataclasses

from repro.configs.base import BloomConfig, MoEConfig, ModelConfig

ARCH = "olmoe-1b-7b"


def config(bloom: bool = True) -> ModelConfig:
    return ModelConfig(
        name=ARCH,
        family="moe",
        num_layers=16,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,
        head_dim=128,
        d_ff=1024,
        vocab=50304,
        moe=MoEConfig(num_experts=64, top_k=8, num_shared=0,
                      d_ff_expert=1024),
        moe_layer_period=1,
        rope_theta=10_000.0,
        moe_impl="ep",
        bloom=BloomConfig(enabled=bloom, m_ratio=0.2, k=4),
    )


def smoke() -> ModelConfig:
    return dataclasses.replace(
        config(),
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
        d_ff=32, vocab=512, dtype="float32", attn_chunk_q=16,
        attn_chunk_k=16, moe_impl="dense",
        moe=MoEConfig(num_experts=8, top_k=2, num_shared=0, d_ff_expert=32,
                      capacity_factor=8.0),
        bloom=BloomConfig(enabled=True, m_ratio=0.25, k=3),
    )
