"""Config dataclasses for the framework.

A ModelConfig fully determines a model; arch files under repro/configs
instantiate the 10 assigned architectures (plus reduced smoke variants and
the paper's 7 recommender/NLP tasks).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class BloomConfig:
    """The paper's technique as a first-class IO-compression feature."""

    enabled: bool = False
    m_ratio: float = 0.2      # m/d compression (paper's sweet spot)
    k: int = 4                # hash projections (paper: 2 <= k <= 4 best)
    seed: int = 0
    on_the_fly: bool = True   # double-hash in-graph (no H matrix in HBM)

    def m_of(self, d: int) -> int:
        m = int(round(self.m_ratio * d))
        if m >= 512:
            # align to 256 (TPU lane multiples + tensor-parallel
            # divisibility over a 16-way model axis)
            m = (m // 256) * 256
        return max(self.k, min(m, d))


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0          # routed experts (0 = dense FFN)
    top_k: int = 2
    num_shared: int = 0           # always-active shared experts
    d_ff_expert: int = 0          # per-expert hidden dim
    capacity_factor: float = 1.25
    router_jitter: float = 0.0
    aux_loss_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 256              # SSD chunk length


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"         # dense|moe|ssm|hybrid|vlm|audio
    num_layers: int = 2
    d_model: int = 128
    num_heads: int = 4
    num_kv_heads: int = 4
    d_ff: int = 512
    vocab: int = 1024
    head_dim: int = 0             # 0 => d_model // num_heads
    qk_norm: bool = False         # qwen3-style per-head RMSNorm on q,k
    qkv_bias: bool = False        # qwen1.5-style bias on QKV projections
    rope_theta: float = 10_000.0
    use_rope: bool = True
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    dtype: str = "bfloat16"       # activation/compute dtype
    param_dtype: str = "float32"
    # --- mixture of experts ---
    moe: Optional[MoEConfig] = None
    moe_layer_period: int = 1     # MoE FFN every Nth layer (jamba: 2)
    # --- SSM / hybrid ---
    mamba: Optional[MambaConfig] = None
    attn_layer_period: int = 0    # hybrid: 1 attn layer per N (jamba: 8)
    attn_layer_offset: int = 4    # index of the attn layer inside a period
    # --- encoder-decoder (whisper) ---
    encoder_layers: int = 0       # >0 => enc-dec; decoder uses num_layers
    # --- modality frontend stubs ---
    frontend: str = "none"        # none|vision_stub|audio_stub
    frontend_frac: float = 0.25   # fraction of seq occupied by stub embeds
    # --- paper technique ---
    bloom: BloomConfig = dataclasses.field(default_factory=BloomConfig)
    # --- execution knobs (perf-iteration surface) ---
    scan_layers: bool = True      # lax.scan over depth (O(1) HLO size)
    remat: str = "full"           # none|full|dots (checkpoint policy)
    attn_chunk_q: int = 2048      # chunked-attention block sizes
    attn_chunk_k: int = 1024
    attn_impl: str = "chunked"    # chunked|naive (oracle)
    causal_skip: bool = False     # triangular kv-chunk skipping (perf opt)
    attn_bf16_scores: bool = False  # bf16 score/prob chain (f32 softmax
                                    # stats kept) — flash2-style trade-off
    moe_impl: str = "dense"       # dense (1-device oracle)|ep (shard_map)
    io_impl: str = "xla"          # xla | pallas (bloom embed/CE kernels)
    bwd_impl: str = "csr"         # pallas-path backward: csr (CSR-binned
                                  # scatter-add, stream-once) | dense
                                  # (m-tile sweep, oracle-adjacent)
    table_dtype: str = "auto"     # Bloom table storage dtype (DESIGN.md
                                  # §13): auto (legacy: cast to `dtype`) |
                                  # float32 | bfloat16 | int8 (per-row
                                  # symmetric scales, in-kernel dequant) |
                                  # fp8_e4m3 — core.quant is the source
                                  # of truth; grads are straight-through
    # Dry-run analysis mode: unroll inner lax.scans (attention kv chunks,
    # top-k vocab chunks) so XLA cost_analysis counts every iteration —
    # cost_analysis counts a while-loop body exactly once (verified
    # empirically), so roofline FLOPs need static unrolling.
    unroll_for_analysis: bool = False

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def has_full_attention(self) -> bool:
        """True when context cost is quadratic => long_500k must be skipped."""
        return self.family not in ("ssm", "hybrid")

    @property
    def m_vocab(self) -> int:
        """Output/input IO dimensionality after (optional) Bloom compression."""
        return self.bloom.m_of(self.vocab) if self.bloom.enabled else self.vocab

    def param_count(self) -> int:
        """Analytic parameter count (embedding + backbone + head)."""
        D, F, V = self.d_model, self.d_ff, self.m_vocab
        hd = self.resolved_head_dim
        H, KV = self.num_heads, self.num_kv_heads
        attn = D * (H * hd) + 2 * D * (KV * hd) + (H * hd) * D
        dense_ffn = 3 * D * F  # SwiGLU
        n = V * D  # embedding
        if not self.tie_embeddings:
            n += D * V
        for li in range(self.num_layers):
            is_attn = self._layer_is_attention(li)
            if is_attn:
                n += attn
            elif self.mamba is not None:
                mc = self.mamba
                d_in = mc.expand * self.d_model
                nh = d_in // mc.head_dim
                # in_proj (z,x,B,C,dt) + conv + A,D + norm + out_proj
                n += D * (2 * d_in + 2 * mc.n_groups * mc.d_state + nh)
                n += (d_in + 2 * mc.n_groups * mc.d_state) * mc.d_conv
                n += 2 * nh + d_in
                n += d_in * D
            if self._layer_is_moe(li):
                mo = self.moe
                n += D * mo.num_experts  # router
                n += mo.num_experts * 3 * D * mo.d_ff_expert
                n += mo.num_shared * 3 * D * mo.d_ff_expert
            elif not (self.family == "ssm"):
                n += dense_ffn
            n += 2 * D  # two pre-norms
        n += D  # final norm
        if self.encoder_layers:
            n += self.encoder_layers * (attn + dense_ffn + 2 * D) + D
        return n

    def _layer_is_attention(self, li: int) -> bool:
        if self.family == "ssm":
            return False
        if self.attn_layer_period > 0:  # hybrid
            return li % self.attn_layer_period == self.attn_layer_offset
        return True

    def _layer_is_moe(self, li: int) -> bool:
        return self.moe is not None and li % self.moe_layer_period == (
            self.moe_layer_period - 1)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell (assigned per-arch)."""

    name: str
    seq_len: int
    global_batch: int
    kind: str                     # train|prefill|decode

    @property
    def is_train(self) -> bool:
        return self.kind == "train"


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4_096, 256, "train"),
    ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    ShapeConfig("decode_32k", 32_768, 128, "decode"),
    ShapeConfig("long_500k", 524_288, 1, "decode"),
)

SHAPE_BY_NAME = {s.name: s for s in SHAPES}


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 1e-3
    optimizer: str = "adam"       # adam|adamw|adagrad|rmsprop|sgd
    beta1: float = 0.9
    beta2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    momentum: float = 0.0
    grad_clip_norm: float = 1.0
    grad_compression: str = "none"  # none|bf16 (DP all-reduce compression)
    microbatch: int = 0           # >0 => grad-accumulation chunks
    steps: int = 100
    warmup_steps: int = 10
    checkpoint_every: int = 50
    keep_checkpoints: int = 3
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class MeshConfig:
    multi_pod: bool = False

    @property
    def shape(self):
        return (2, 16, 16) if self.multi_pod else (16, 16)

    @property
    def axes(self):
        return ("pod", "data", "model") if self.multi_pod \
            else ("data", "model")
