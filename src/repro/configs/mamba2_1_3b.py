"""mamba2-1.3b [ssm]: SSD (state-space duality), attention-free.
48L d_model=2048 d_ff=0 vocab=50280 ssm_state=128.  [arXiv:2405.21060]

expand=2 => d_inner=4096; head_dim=64 => 64 heads.  No FFN (mixer-only
blocks, as in the Mamba-2 reference).  Decode state is O(1) in context —
this arch runs the long_500k cell.
"""
import dataclasses

from repro.configs.base import BloomConfig, MambaConfig, ModelConfig

ARCH = "mamba2-1.3b"


def config(bloom: bool = True) -> ModelConfig:
    return ModelConfig(
        name=ARCH,
        family="ssm",
        num_layers=48,
        d_model=2048,
        num_heads=1,            # unused (attention-free)
        num_kv_heads=1,
        d_ff=0,
        vocab=50280,
        mamba=MambaConfig(d_state=128, d_conv=4, expand=2, head_dim=64,
                          chunk=256),
        bloom=BloomConfig(enabled=bloom, m_ratio=0.2, k=4),
    )


def smoke() -> ModelConfig:
    return dataclasses.replace(
        config(),
        num_layers=2, d_model=64, vocab=512, dtype="float32",
        mamba=MambaConfig(d_state=8, d_conv=4, expand=2, head_dim=16,
                          chunk=8),
        bloom=BloomConfig(enabled=True, m_ratio=0.25, k=3),
    )
