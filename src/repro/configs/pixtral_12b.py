"""pixtral-12b [vlm]: Pixtral-ViT frontend (stub) + Mistral-Nemo-style
decoder.  40L d_model=5120 32H (GQA kv=8, head_dim=128) d_ff=14336
vocab=131072.  [hf:mistralai/Pixtral-12B-2409; unverified]
"""
import dataclasses

from repro.configs.base import BloomConfig, MambaConfig, ModelConfig

ARCH = "pixtral-12b"


def config(bloom: bool = True) -> ModelConfig:
    return ModelConfig(
        name=ARCH,
        family="vlm",
        num_layers=40,
        d_model=5120,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab=131072,
        rope_theta=1_000_000.0,
        frontend="vision_stub",
        frontend_frac=0.25,
        bloom=BloomConfig(enabled=bloom, m_ratio=0.2, k=4),
    )


def smoke() -> ModelConfig:
    return dataclasses.replace(
        config(),
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, vocab=512, dtype="float32", attn_chunk_q=16,
        attn_chunk_k=16,
        bloom=BloomConfig(enabled=True, m_ratio=0.25, k=3),
    )
