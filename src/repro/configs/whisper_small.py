"""whisper-small [audio]: encoder-decoder, conv frontend STUBBED
(input_specs supplies precomputed frame embeddings).  12L enc + 12L dec,
d_model=768 12H (MHA kv=12) d_ff=3072 vocab=51865.  [arXiv:2212.04356]

Deviation notes (DESIGN.md §4): RoPE replaces whisper's sinusoidal/learned
positions (frontend is a stub anyway); norms are RMSNorm like the rest of
the zoo.  Bloom IO applies to the decoder vocabulary.
"""
import dataclasses

from repro.configs.base import BloomConfig, ModelConfig

ARCH = "whisper-small"


def config(bloom: bool = True) -> ModelConfig:
    return ModelConfig(
        name=ARCH,
        family="audio",
        num_layers=12,          # decoder
        encoder_layers=12,
        d_model=768,
        num_heads=12,
        num_kv_heads=12,
        head_dim=64,
        d_ff=3072,
        vocab=51865,
        frontend="audio_stub",
        bloom=BloomConfig(enabled=bloom, m_ratio=0.2, k=4),
    )


def smoke() -> ModelConfig:
    return dataclasses.replace(
        config(),
        num_layers=2, encoder_layers=2, d_model=64, num_heads=4,
        num_kv_heads=4, head_dim=16, d_ff=128, vocab=512, dtype="float32",
        attn_chunk_q=16, attn_chunk_k=16,
        bloom=BloomConfig(enabled=True, m_ratio=0.25, k=3),
    )
