"""Shared helpers for the per-arch config files."""
from __future__ import annotations

import dataclasses

from repro.configs.base import BloomConfig, ModelConfig


def with_bloom(cfg: ModelConfig, enabled: bool = True, m_ratio: float = 0.2,
               k: int = 4) -> ModelConfig:
    """Toggle the paper's IO compression on an arch config."""
    return dataclasses.replace(
        cfg, bloom=BloomConfig(enabled=enabled, m_ratio=m_ratio, k=k))


def reduce_for_smoke(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Structural-preserving reduction used by per-arch smoke tests."""
    return dataclasses.replace(cfg, **overrides)
