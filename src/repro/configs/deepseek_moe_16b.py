"""deepseek-moe-16b [moe]: fine-grained experts — 2 shared + 64 routed
top-6.  28L d_model=2048 16H (MHA kv=16) d_ff(expert)=1408 vocab=102400.
[arXiv:2401.06066; hf]

Simplification note (DESIGN.md §5): the HF model's dense first layer is
made MoE like the rest so the layer stack stays scan-homogeneous; expert
dims follow the assignment.
"""
import dataclasses

from repro.configs.base import BloomConfig, MoEConfig, ModelConfig

ARCH = "deepseek-moe-16b"


def config(bloom: bool = True) -> ModelConfig:
    return ModelConfig(
        name=ARCH,
        family="moe",
        num_layers=28,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,
        head_dim=128,
        d_ff=1408,
        vocab=102400,
        moe=MoEConfig(num_experts=64, top_k=6, num_shared=2,
                      d_ff_expert=1408),
        moe_layer_period=1,
        rope_theta=10_000.0,
        moe_impl="ep",
        bloom=BloomConfig(enabled=bloom, m_ratio=0.2, k=4),
    )


def smoke() -> ModelConfig:
    return dataclasses.replace(
        config(),
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
        d_ff=32, vocab=512, dtype="float32", attn_chunk_q=16,
        attn_chunk_k=16, moe_impl="dense",
        moe=MoEConfig(num_experts=8, top_k=2, num_shared=1, d_ff_expert=32,
                      capacity_factor=8.0),
        bloom=BloomConfig(enabled=True, m_ratio=0.25, k=3),
    )
