"""phi3-mini-3.8b [dense]: RoPE SwiGLU GQA.  32L d_model=3072 32H
(GQA kv=32, i.e. MHA) d_ff=8192 vocab=32064.  [arXiv:2404.14219; unverified]
"""
import dataclasses

from repro.configs.base import BloomConfig, ModelConfig

ARCH = "phi3-mini-3.8b"


def config(bloom: bool = True) -> ModelConfig:
    return ModelConfig(
        name=ARCH,
        family="dense",
        num_layers=32,
        d_model=3072,
        num_heads=32,
        num_kv_heads=32,
        head_dim=96,
        d_ff=8192,
        vocab=32064,
        rope_theta=10_000.0,
        bloom=BloomConfig(enabled=bloom, m_ratio=0.2, k=4),
    )


def smoke() -> ModelConfig:
    return dataclasses.replace(
        config(),
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
        d_ff=128, vocab=512, dtype="float32", attn_chunk_q=16,
        attn_chunk_k=16,
        bloom=BloomConfig(enabled=True, m_ratio=0.25, k=3),
    )
