"""Deterministic seeded load generator for the serving engine.

Arrivals are Poisson in *decode-step time* (exponential inter-arrival
gaps at `rate` requests/step, floored onto the integer step clock) with a
categorical prompt/generation length mix — the mixed-length workload that
makes static batching burn slot-steps on drained requests (DLRM-style
serving traffic, cf. Naumov et al., 2019).  Everything is a pure function
of `seed`, so the simulation tests and the committed BENCH_serving.json
baseline replay the exact same trace on every CI run.  Under sharding the
same contract holds per host: ``host_stream`` is a pure function of
``(seed, host_id)``, so the multi-host schedule replays exactly no matter
which hosts draw first (DESIGN.md §8).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence, Tuple

import numpy as np

from repro.serving.scheduler import Request


@dataclasses.dataclass(frozen=True)
class LoadSpec:
    n_requests: int = 16
    vocab: int = 1024
    rate: float = 0.5                    # mean arrivals per decode step
    prompt_lens: Tuple[int, ...] = (8, 16, 24)
    gen_lens: Tuple[int, ...] = (4, 8, 24)
    gen_weights: Tuple[float, ...] = ()  # uniform when empty
    seed: int = 0


def _draw_stream(rng: np.random.Generator, spec: LoadSpec,
                 rid_of, home: int) -> list[Request]:
    """One seeded arrival stream — the single sampling implementation
    behind make_workload AND host_stream, so the mixes can never diverge
    (merge_workloads must replay the identical traffic through the
    single-host engine).  Draw order (gaps, prompt lens, gen lens,
    prompts) is part of the committed-bench contract — do not reorder."""
    gaps = rng.exponential(1.0 / spec.rate, size=spec.n_requests)
    arrivals = np.floor(np.cumsum(gaps)).astype(np.int64)
    p_lens = rng.choice(spec.prompt_lens, size=spec.n_requests)
    w = (np.asarray(spec.gen_weights, np.float64)
         if spec.gen_weights else None)
    if w is not None:
        w = w / w.sum()
    g_lens = rng.choice(spec.gen_lens, size=spec.n_requests, p=w)
    reqs = []
    for i in range(spec.n_requests):
        prompt = rng.integers(0, spec.vocab, size=int(p_lens[i]),
                              dtype=np.int32)
        reqs.append(Request(rid=rid_of(i), prompt=prompt,
                            max_gen=int(g_lens[i]),
                            arrival_step=int(arrivals[i]), home=home))
    return reqs


def make_workload(spec: LoadSpec) -> list[Request]:
    """spec -> arrival-ordered [Request] (prompts drawn uniform over vocab)."""
    return _draw_stream(np.random.default_rng(spec.seed), spec,
                        rid_of=lambda i: i, home=0)


def host_stream(spec: LoadSpec, host: int, n_hosts: int) -> list[Request]:
    """One host's arrival stream for the sharded engine: a pure function
    of ``(spec.seed, host)`` and NOTHING else — in particular not of how
    many streams were drawn before it, so any subset of hosts replays
    bit-identically and the multi-host schedule is exactly reproducible
    (DESIGN.md §8; regression-tested in tests/test_serving_multihost.py).

    ``np.random.default_rng([seed, host])`` seeds the underlying
    SeedSequence with the (seed, host) entropy pair — independent per-host
    streams without any shared-counter coupling.  rids are globally unique
    and host-tagged: ``rid = i * n_hosts + host``.
    """
    return _draw_stream(np.random.default_rng([spec.seed, host]), spec,
                        rid_of=lambda i: i * n_hosts + host, home=host)


def sharded_workload(spec: LoadSpec, n_hosts: int) -> list[list[Request]]:
    """Per-host arrival streams (``spec.n_requests`` requests EACH);
    ``[h]`` is host h's stream.  See host_stream for the determinism
    contract."""
    return [host_stream(spec, h, n_hosts) for h in range(n_hosts)]


def merge_workloads(per_host: list[list[Request]]) -> list[Request]:
    """Flatten per-host streams into one global arrival-ordered workload
    (ties broken by (home, rid) — the same order the gossiped queue uses),
    for replaying the identical traffic through a single-host engine."""
    return sorted((r for reqs in per_host for r in reqs),
                  key=lambda r: (r.arrival_step, r.home, r.rid))


def burst_workload(spec: LoadSpec, step: int = 0) -> list[Request]:
    """A whole workload arriving at the SAME step — the prefill-pool
    stress shape (DESIGN.md §9): one prefill worker serializes the burst
    and head-of-line blocks admission; a pool of N drains it ~N-times
    faster in prefill-time while the step-clock schedule (and every
    recovered token) is unchanged.  Prompt/generation mixes draw exactly
    like ``make_workload`` (same seeded stream), only the arrival steps
    are collapsed onto ``step``."""
    reqs = make_workload(spec)
    for r in reqs:
        r.arrival_step = step
    return reqs


def mixed_length_workload(vocab: int, n_requests: int = 12,
                          seed: int = 0) -> list[Request]:
    """The canonical bench/test workload: bursty arrivals, bimodal
    generation lengths (many short, few long) — the shape where
    continuous batching beats static by the largest factor."""
    return make_workload(LoadSpec(
        n_requests=n_requests, vocab=vocab, rate=2.0,
        prompt_lens=(6, 10, 14), gen_lens=(3, 6, 20),
        gen_weights=(0.5, 0.3, 0.2), seed=seed))


def arrival_span(per_host: list[list[Request]]) -> tuple[int, int]:
    """(first, last) arrival step across per-host streams.  The chaos
    paths (sim_multihost, bench_serving) use it to place a host kill
    mid-traffic — strictly after the first arrival, before the last —
    so the kill is guaranteed to find in-flight work for ANY seed."""
    arrivals = [r.arrival_step for reqs in per_host for r in reqs]
    if not arrivals:
        return (0, 0)
    return (min(arrivals), max(arrivals))
