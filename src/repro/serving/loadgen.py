"""Deterministic seeded load generator for the serving engine.

Arrivals are Poisson in *decode-step time* (exponential inter-arrival
gaps at `rate` requests/step, floored onto the integer step clock) with a
categorical prompt/generation length mix — the mixed-length workload that
makes static batching burn slot-steps on drained requests (DLRM-style
serving traffic, cf. Naumov et al., 2019).  Everything is a pure function
of `seed`, so the simulation tests and the committed BENCH_serving.json
baseline replay the exact same trace on every CI run.  Under sharding the
same contract holds per host: ``host_stream`` is a pure function of
``(seed, host_id)``, so the multi-host schedule replays exactly no matter
which hosts draw first (DESIGN.md §8).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence, Tuple

import numpy as np

from repro.serving.scheduler import Request


@dataclasses.dataclass(frozen=True)
class LoadSpec:
    n_requests: int = 16
    vocab: int = 1024
    rate: float = 0.5                    # mean arrivals per decode step
    prompt_lens: Tuple[int, ...] = (8, 16, 24)
    gen_lens: Tuple[int, ...] = (4, 8, 24)
    gen_weights: Tuple[float, ...] = ()  # uniform when empty
    seed: int = 0

    def __post_init__(self):
        # rate=0 used to surface as a ZeroDivisionError deep inside
        # _draw_stream's exponential draw; a weights/lens length mismatch
        # as an opaque numpy error inside rng.choice — validate both at
        # construction with messages that name the fields
        if not self.rate > 0:
            raise ValueError(
                f"LoadSpec.rate must be > 0 arrivals/step (got "
                f"{self.rate}); the arrival process draws exponential "
                "gaps at 1/rate")
        if self.gen_weights and len(self.gen_weights) != len(self.gen_lens):
            raise ValueError(
                f"LoadSpec.gen_weights has {len(self.gen_weights)} "
                f"entries for {len(self.gen_lens)} gen_lens; the "
                "categorical mix needs one weight per length (or an "
                "empty tuple for uniform)")


def _draw_stream(rng: np.random.Generator, spec: LoadSpec,
                 rid_of, home: int) -> list[Request]:
    """One seeded arrival stream — the single sampling implementation
    behind make_workload AND host_stream, so the mixes can never diverge
    (merge_workloads must replay the identical traffic through the
    single-host engine).  Draw order (gaps, prompt lens, gen lens,
    prompts) is part of the committed-bench contract — do not reorder."""
    gaps = rng.exponential(1.0 / spec.rate, size=spec.n_requests)
    arrivals = np.floor(np.cumsum(gaps)).astype(np.int64)
    p_lens = rng.choice(spec.prompt_lens, size=spec.n_requests)
    w = (np.asarray(spec.gen_weights, np.float64)
         if spec.gen_weights else None)
    if w is not None:
        w = w / w.sum()
    g_lens = rng.choice(spec.gen_lens, size=spec.n_requests, p=w)
    reqs = []
    for i in range(spec.n_requests):
        prompt = rng.integers(0, spec.vocab, size=int(p_lens[i]),
                              dtype=np.int32)
        reqs.append(Request(rid=rid_of(i), prompt=prompt,
                            max_gen=int(g_lens[i]),
                            arrival_step=int(arrivals[i]), home=home))
    return reqs


def make_workload(spec: LoadSpec) -> list[Request]:
    """spec -> arrival-ordered [Request] (prompts drawn uniform over vocab)."""
    return _draw_stream(np.random.default_rng(spec.seed), spec,
                        rid_of=lambda i: i, home=0)


def host_stream(spec: LoadSpec, host: int, n_hosts: int) -> list[Request]:
    """One host's arrival stream for the sharded engine: a pure function
    of ``(spec.seed, host)`` and NOTHING else — in particular not of how
    many streams were drawn before it, so any subset of hosts replays
    bit-identically and the multi-host schedule is exactly reproducible
    (DESIGN.md §8; regression-tested in tests/test_serving_multihost.py).

    ``np.random.default_rng([seed, host])`` seeds the underlying
    SeedSequence with the (seed, host) entropy pair — independent per-host
    streams without any shared-counter coupling.  rids are globally unique
    and host-tagged: ``rid = i * n_hosts + host``.
    """
    return _draw_stream(np.random.default_rng([spec.seed, host]), spec,
                        rid_of=lambda i: i * n_hosts + host, home=host)


def sharded_workload(spec: LoadSpec, n_hosts: int) -> list[list[Request]]:
    """Per-host arrival streams (``spec.n_requests`` requests EACH);
    ``[h]`` is host h's stream.  See host_stream for the determinism
    contract."""
    return [host_stream(spec, h, n_hosts) for h in range(n_hosts)]


def merge_workloads(per_host: list[list[Request]]) -> list[Request]:
    """Flatten per-host streams into one global arrival-ordered workload
    (ties broken by (home, rid) — the same order the gossiped queue uses),
    for replaying the identical traffic through a single-host engine."""
    return sorted((r for reqs in per_host for r in reqs),
                  key=lambda r: (r.arrival_step, r.home, r.rid))


def burst_workload(spec: LoadSpec, step: int = 0) -> list[Request]:
    """A whole workload arriving at the SAME step — the prefill-pool
    stress shape (DESIGN.md §9): one prefill worker serializes the burst
    and head-of-line blocks admission; a pool of N drains it ~N-times
    faster in prefill-time while the step-clock schedule (and every
    recovered token) is unchanged.  Prompt/generation mixes draw exactly
    like ``make_workload`` (same seeded stream), only the arrival steps
    are collapsed onto ``step``.

    Fresh instances on purpose: the old in-place ``r.arrival_step =
    step`` mutated the very Requests make_workload returned, and Request
    also carries engine-filled bookkeeping (tokens, admitted_step, ...)
    that must start virgin — replaying one workload list through two
    engines would silently leak the first run's state into the second
    (fresh_copy resets nothing because there is nothing to reset)."""
    return [r.fresh_copy(arrival_step=step) for r in make_workload(spec)]


def assert_fresh_instances(*workloads) -> None:
    """Guard for A/B drivers: workload lists replayed through different
    engines must not share Request instances (engine-filled bookkeeping
    would leak between runs) and every request must still be virgin — no
    tokens, no admission — i.e. built by loadgen / ``fresh_copy``, not
    recycled from a previous run."""
    seen: set = set()
    for wl in workloads:
        for r in wl:
            if id(r) in seen:
                raise AssertionError(
                    f"request rid={r.rid} is the SAME instance in two "
                    "workload replays — engine-filled state would leak "
                    "between runs; build each replay via fresh_copy()")
            seen.add(id(r))
            if r.tokens or r.topk_ids or r.admitted_step >= 0 \
                    or r.finish_step >= 0 or r.slot >= 0:
                raise AssertionError(
                    f"request rid={r.rid} carries engine-filled state "
                    "(already served?) — replay fresh_copy()s, not the "
                    "previous run's objects")


def overload_workload(spec: LoadSpec, n_hosts: int, *, surge_start: int,
                      surge_factor: int,
                      deadline_slack: int | None = None
                      ) -> list[list[Request]]:
    """Open-loop overload traffic (DESIGN.md §14): each host's seeded
    Poisson stream (``host_stream`` — still pure in (seed, host)), with
    arrivals at or after ``surge_start`` compressed toward it by
    ``surge_factor`` (``a -> start + (a - start) // factor`` — the SAME
    transform ``FailPlan`` ``surge:R@S`` applies at injection time, here
    baked into ``arrival_step`` itself) and, with ``deadline_slack``
    set, an SLO deadline of ``arrival_step + deadline_slack`` per
    request.  Benches and drills use this instead of hand-rolling surge
    schedules; a failpoint surge composes on top (it re-compresses the
    already-compressed steps).

    Validated like ``LoadSpec``: a bad knob fails loudly at the call,
    not as a silent never-shedding or always-shedding run."""
    if surge_start < 0:
        raise ValueError(
            f"surge_start must be >= 0 (got {surge_start}); it is the "
            "first compressed arrival step")
    if surge_factor < 2:
        raise ValueError(
            f"surge_factor must be >= 2 (got {surge_factor}); factor 1 "
            "would be a no-op surge — drop the parameter instead")
    if deadline_slack is not None and deadline_slack < 1:
        raise ValueError(
            f"deadline_slack must be >= 1 step (got {deadline_slack}); "
            "a zero slack sheds every request that misses same-step "
            "admission")
    out = []
    for h in range(n_hosts):
        reqs = host_stream(spec, h, n_hosts)
        for r in reqs:
            if r.arrival_step >= surge_start:
                r.arrival_step = (surge_start
                                  + (r.arrival_step - surge_start)
                                  // surge_factor)
            if deadline_slack is not None:
                r.deadline_step = r.arrival_step + deadline_slack
        out.append(reqs)
    return out


def mixed_length_workload(vocab: int, n_requests: int = 12,
                          seed: int = 0) -> list[Request]:
    """The canonical bench/test workload: bursty arrivals, bimodal
    generation lengths (many short, few long) — the shape where
    continuous batching beats static by the largest factor."""
    return make_workload(LoadSpec(
        n_requests=n_requests, vocab=vocab, rate=2.0,
        prompt_lens=(6, 10, 14), gen_lens=(3, 6, 20),
        gen_weights=(0.5, 0.3, 0.2), seed=seed))


# ---------------------------------------------------------------------------
# Retrieval traffic (DESIGN.md §11): Zipf-skewed one-shot item lookups
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RetrievalLoadSpec:
    """Web-scale retrieval traffic over a d-item catalog: each request
    carries a padded set of input item ids (the user's history, Bloom-
    encoded on admit) plus held-out target items for offline ranking
    eval.  Item popularity is Zipf(1)-skewed — the DLRM traffic shape
    (Naumov et al., 2019): a few head items dominate, the tail is huge."""

    n_requests: int = 16
    catalog: int = 1 << 20               # d — item-catalog size
    c_max: int = 8                       # input items per request
    n_targets: int = 2                   # held-out eval items per request
    rate: float = 2.0                    # mean arrivals per decode step
    seed: int = 0

    def __post_init__(self):
        if not self.rate > 0:
            raise ValueError(
                f"RetrievalLoadSpec.rate must be > 0 (got {self.rate})")
        if self.c_max < 1 or self.n_targets < 0:
            raise ValueError(
                f"need c_max >= 1 and n_targets >= 0, got c_max="
                f"{self.c_max} n_targets={self.n_targets}")
        if self.catalog < 4 * (self.c_max + self.n_targets):
            raise ValueError(
                f"catalog {self.catalog} too small to draw "
                f"{self.c_max + self.n_targets} distinct items per "
                "request with a skewed popularity law")


def _zipf_items(rng: np.random.Generator, catalog: int,
                size: int) -> np.ndarray:
    """Zipf(s=1)-skewed item draws over [0, catalog), head at id 0.

    Inverse-CDF of the log-uniform density (pdf ∝ 1/(x+1)): item i draws
    with probability ∝ ln((i+2)/(i+1)) ≈ 1/(i+1) — the bounded Zipf(1)
    law — in O(size) numpy work with NO d-length probability vector, so
    the generator stays cheap at 10M-item catalogs."""
    u = rng.random(size)
    return np.floor(np.exp(u * np.log(float(catalog) + 1.0))
                    ).astype(np.int64) - 1


def retrieval_workload(spec: RetrievalLoadSpec, host: int = 0,
                       n_hosts: int = 1) -> list[Request]:
    """One host's Zipf-skewed retrieval stream — the same pure-function-
    of ``(seed, host)`` contract as ``host_stream`` (DESIGN.md §8/§11):
    independent per-host rngs via the (seed, host) entropy pair, rids
    globally unique and host-tagged (``i * n_hosts + host``), so any
    subset of hosts replays bit-identically.

    Every request is ``kind="oneshot"``: prompt = ``c_max`` distinct
    item ids (popularity-skewed, deduped in first-draw order), max_gen=1
    (prefill -> one recover step -> retire), targets = ``n_targets``
    further distinct held-out items for offline MAP/RR eval.  Draw order
    (gaps, then per-request item sets) is part of the committed-bench
    contract — do not reorder."""
    rng = np.random.default_rng([spec.seed, host])
    n, want = spec.n_requests, spec.c_max + spec.n_targets
    gaps = rng.exponential(1.0 / spec.rate, size=n)
    arrivals = np.floor(np.cumsum(gaps)).astype(np.int64)
    reqs = []
    for i in range(n):
        draw = _zipf_items(rng, spec.catalog, size=4 * want + 16)
        items = list(dict.fromkeys(draw.tolist()))[:want]
        while len(items) < want:          # head-heavy small catalogs can
            extra = rng.integers(0, spec.catalog, size=want)  # collide out
            items.extend(v for v in dict.fromkeys(extra.tolist())
                         if v not in set(items))
            items = items[:want]
        items_arr = np.asarray(items, np.int32)
        reqs.append(Request(
            rid=i * n_hosts + host,
            prompt=items_arr[:spec.c_max],
            max_gen=1, arrival_step=int(arrivals[i]), home=host,
            kind="oneshot", targets=items_arr[spec.c_max:]))
    return reqs


def arrival_span(per_host: list[list[Request]]) -> tuple[int, int]:
    """(first, last) arrival step across per-host streams.  The chaos
    paths (sim_multihost, bench_serving) use it to place a host kill
    mid-traffic — strictly after the first arrival, before the last —
    so the kill is guaranteed to find in-flight work for ANY seed."""
    arrivals = [r.arrival_step for reqs in per_host for r in reqs]
    if not arrivals:
        return (0, 0)
    return (min(arrivals), max(arrivals))
