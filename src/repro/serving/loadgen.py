"""Deterministic seeded load generator for the serving engine.

Arrivals are Poisson in *decode-step time* (exponential inter-arrival
gaps at `rate` requests/step, floored onto the integer step clock) with a
categorical prompt/generation length mix — the mixed-length workload that
makes static batching burn slot-steps on drained requests (DLRM-style
serving traffic, cf. Naumov et al., 2019).  Everything is a pure function
of `seed`, so the simulation tests and the committed BENCH_serving.json
baseline replay the exact same trace on every CI run.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence, Tuple

import numpy as np

from repro.serving.scheduler import Request


@dataclasses.dataclass(frozen=True)
class LoadSpec:
    n_requests: int = 16
    vocab: int = 1024
    rate: float = 0.5                    # mean arrivals per decode step
    prompt_lens: Tuple[int, ...] = (8, 16, 24)
    gen_lens: Tuple[int, ...] = (4, 8, 24)
    gen_weights: Tuple[float, ...] = ()  # uniform when empty
    seed: int = 0


def make_workload(spec: LoadSpec) -> list[Request]:
    """spec -> arrival-ordered [Request] (prompts drawn uniform over vocab)."""
    rng = np.random.default_rng(spec.seed)
    gaps = rng.exponential(1.0 / spec.rate, size=spec.n_requests)
    arrivals = np.floor(np.cumsum(gaps)).astype(np.int64)
    p_lens = rng.choice(spec.prompt_lens, size=spec.n_requests)
    w = (np.asarray(spec.gen_weights, np.float64)
         if spec.gen_weights else None)
    if w is not None:
        w = w / w.sum()
    g_lens = rng.choice(spec.gen_lens, size=spec.n_requests, p=w)
    reqs = []
    for i in range(spec.n_requests):
        prompt = rng.integers(0, spec.vocab, size=int(p_lens[i]),
                              dtype=np.int32)
        reqs.append(Request(rid=i, prompt=prompt, max_gen=int(g_lens[i]),
                            arrival_step=int(arrivals[i])))
    return reqs


def mixed_length_workload(vocab: int, n_requests: int = 12,
                          seed: int = 0) -> list[Request]:
    """The canonical bench/test workload: bursty arrivals, bimodal
    generation lengths (many short, few long) — the shape where
    continuous batching beats static by the largest factor."""
    return make_workload(LoadSpec(
        n_requests=n_requests, vocab=vocab, rate=2.0,
        prompt_lens=(6, 10, 14), gen_lens=(3, 6, 20),
        gen_weights=(0.5, 0.3, 0.2), seed=seed))
