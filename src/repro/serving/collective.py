"""Device collective for ``control.CollectiveTransport`` (DESIGN.md §9).

The transport's protocol logic (padding, rounds, visibility) is JAX-free
in serving/control.py; this module supplies only the physical exchange:
each host's fixed-size delta buffer lives on its ``data`` shard and one
``jax.lax.all_gather`` moves the stack, so every host receives the
identical merged view.  On the forced 8-device CPU topology this is a
real device collective — the single-process multi-controller stand-in the
multi-host sim proves — and the same shard_map runs unchanged under
jax.distributed with one process per host.

The buffer shape is static ((capacity + 1) x DELTA_FIELDS — the extra
row carries each host's replicated-state digest, DESIGN.md §10), so the
gather compiles exactly once per transport: fault injection never
changes the collective's shape, only the row contents.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.sharding import shard_map_nocheck


def make_device_gather(mesh, data_axis: str = "data"):
    """mesh -> gather fn for ``CollectiveTransport(gather=...)``.

    The returned callable maps the stacked outbox buffer
    ``(n_hosts, C, F) int32`` — row h committed to data shard h — to
    every host's received view ``(n_hosts, n_hosts, C, F)``; view[h] is
    what host h's all_gather returned, kept per-shard so the transport's
    replica-agreement assert checks the actual collective output."""
    n_hosts = int(mesh.shape[data_axis])
    row_sharding = NamedSharding(mesh, P(data_axis))

    def _exchange(local):                     # (1, C, F) per data shard
        gathered = jax.lax.all_gather(local, data_axis, axis=0,
                                      tiled=True)      # (n_hosts, C, F)
        return gathered[None]                 # (1, n_hosts, C, F)

    exchange = jax.jit(shard_map_nocheck(
        _exchange, mesh, in_specs=P(data_axis), out_specs=P(data_axis)))

    def gather(buf: np.ndarray) -> np.ndarray:
        assert buf.shape[0] == n_hosts, (buf.shape, n_hosts)
        committed = jax.device_put(jnp.asarray(buf, jnp.int32),
                                   row_sharding)
        return np.asarray(exchange(committed))

    return gather
