"""Continuous-batching serving engine (DESIGN.md §7–§9).

control.py      — control plane: pure replicated state machine
                  (apply_deltas/compute_admissions), membership + epochs
                  (HOST_DOWN reclaim/re-queue), compaction planning,
                  the shared EventLog + replay helper, and the Transport
                  implementations (SimTransport, CollectiveTransport)
                  with per-round digest checks + deadlines
failpoints.py   — seeded deterministic fault injection (FailPlan): one
                  spec string replays the identical failure schedule in
                  the engine, the model-free sim, the bench and CI
collective.py   — the device all_gather behind CollectiveTransport
admission.py    — overload policy (DESIGN.md §14): AdmissionPolicy,
                  deadline/bounded-queue shedding (compute_sheds), the
                  windowed pressure signal and the degrade ladder
                  (plan_stage/stage_topk) — pure functions of replicated
                  state, JAX-free like control.py
scheduler.py    — JAX-free RequestQueue/Scheduler (slot admission policy),
                  ShardedScheduler (transported multi-host admission),
                  and run_schedule — the ONE serve loop shared by the
                  sharded engine and the model-free simulation
loadgen.py      — deterministic Poisson arrival + length-mix workloads,
                  per-host streams pure in (seed, host_id)
engine.py       — the slot-pool engine, the disaggregated PrefillPool
                  (FIFO over N mesh-slice workers), the SlotProgram
                  per-slot program protocol, and the static-batching
                  A/B baseline
sharded_pool.py — data plane: data-axis-sharded slot pool, ShardedEngine,
                  slot compaction
retrieval.py    — web-scale one-shot Bloom retrieval over the same slot
                  pool: Zipf item lookups, streaming Eq. 3 top-k over a
                  10M+-item catalog, modeled-bytes audit vs the
                  dense-table oracle (DESIGN.md §11)
"""
from repro.serving.admission import (MAX_STAGE, SHED_DEADLINE,
                                     SHED_QUEUE_FULL, STAGE_MIN,
                                     STAGE_NARROW, STAGE_NORMAL,
                                     AdmissionPolicy, compute_sheds,
                                     plan_stage, pressure, slo_attainment,
                                     stage_topk)
from repro.serving.control import (CollectiveTransport, ControlState,
                                   Delta, EventLog, SimTransport,
                                   Transport, apply_deltas,
                                   compute_admissions, plan_compaction,
                                   replay_slot_log)
from repro.serving.control import (HOST_DOWN, ReplicaDivergence,
                                   TransportTimeout, control_digest)
from repro.serving.engine import Engine, LMSlotProgram, PrefillFault, \
    PrefillPool, PrefillWorker, ServeStats, SlotProgram, mean_latency
from repro.serving.failpoints import (FailPlan, Failpoint,
                                      PREFILL_MAX_ATTEMPTS)
from repro.serving.loadgen import (LoadSpec, RetrievalLoadSpec,
                                   assert_fresh_instances, burst_workload,
                                   host_stream, make_workload,
                                   merge_workloads, mixed_length_workload,
                                   overload_workload, retrieval_workload,
                                   sharded_workload)
from repro.serving.retrieval import (RetrievalEngine, RetrievalProgram,
                                     evaluate_retrieval,
                                     init_retrieval_params)
from repro.serving.scheduler import Request, RequestQueue, ScheduleClient, \
    Scheduler, ShardedScheduler, run_schedule, simulate_sharded_schedule
from repro.serving.sharded_pool import ShardedEngine

__all__ = ["Engine", "PrefillPool", "PrefillWorker", "ServeStats",
           "SlotProgram", "LMSlotProgram", "mean_latency", "LoadSpec",
           "burst_workload", "host_stream", "assert_fresh_instances",
           "make_workload", "merge_workloads", "mixed_length_workload",
           "sharded_workload", "RetrievalLoadSpec", "retrieval_workload",
           "RetrievalEngine", "RetrievalProgram", "evaluate_retrieval",
           "init_retrieval_params",
           "Request", "RequestQueue", "ScheduleClient",
           "Scheduler", "ShardedEngine", "ShardedScheduler",
           "run_schedule", "simulate_sharded_schedule",
           "CollectiveTransport", "ControlState", "Delta", "EventLog",
           "SimTransport", "Transport", "apply_deltas",
           "compute_admissions", "plan_compaction", "replay_slot_log",
           "FailPlan", "Failpoint", "PREFILL_MAX_ATTEMPTS",
           "PrefillFault", "HOST_DOWN", "ReplicaDivergence",
           "TransportTimeout", "control_digest",
           "AdmissionPolicy", "compute_sheds", "plan_stage", "pressure",
           "slo_attainment", "stage_topk", "overload_workload",
           "MAX_STAGE", "SHED_DEADLINE", "SHED_QUEUE_FULL",
           "STAGE_NORMAL", "STAGE_NARROW", "STAGE_MIN"]
