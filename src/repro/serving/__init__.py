"""Continuous-batching serving engine (DESIGN.md §7–§8).

scheduler.py    — JAX-free RequestQueue/Scheduler (slot admission policy)
                  + ShardedScheduler (gossiped multi-host admission)
loadgen.py      — deterministic Poisson arrival + length-mix workloads,
                  per-host streams pure in (seed, host_id)
engine.py       — the slot-pool engine, disaggregated PrefillWorker, and
                  the static-batching A/B baseline
sharded_pool.py — data-axis-sharded slot pool + ShardedEngine
"""
from repro.serving.engine import Engine, PrefillWorker, ServeStats, \
    mean_latency
from repro.serving.loadgen import LoadSpec, host_stream, make_workload, \
    merge_workloads, mixed_length_workload, sharded_workload
from repro.serving.scheduler import Request, RequestQueue, Scheduler, \
    ShardedScheduler, simulate_sharded_schedule
from repro.serving.sharded_pool import ShardedEngine

__all__ = ["Engine", "PrefillWorker", "ServeStats", "mean_latency",
           "LoadSpec", "host_stream", "make_workload", "merge_workloads",
           "mixed_length_workload", "sharded_workload", "Request",
           "RequestQueue", "Scheduler", "ShardedEngine",
           "ShardedScheduler", "simulate_sharded_schedule"]
