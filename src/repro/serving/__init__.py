"""Continuous-batching serving engine (DESIGN.md §7).

scheduler.py — JAX-free RequestQueue/Scheduler (slot admission policy)
loadgen.py   — deterministic Poisson arrival + length-mix workloads
engine.py    — the slot-pool engine + static-batching A/B baseline
"""
from repro.serving.engine import Engine, ServeStats, mean_latency
from repro.serving.loadgen import LoadSpec, make_workload, \
    mixed_length_workload
from repro.serving.scheduler import Request, RequestQueue, Scheduler

__all__ = ["Engine", "ServeStats", "mean_latency", "LoadSpec",
           "make_workload", "mixed_length_workload", "Request",
           "RequestQueue", "Scheduler"]
