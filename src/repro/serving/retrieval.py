"""Web-scale Bloom retrieval serving (DESIGN.md §11).

The paper is a recommender-systems paper; this module is the serving
scenario that makes its "millions of users" claim concrete: top-k item
retrieval over a Bloom-compressed catalog of d >= 10M items, served
through the SAME slot-pool machinery as the LM engine — Scheduler /
RequestQueue / ServeStats / PrefillPool are reused verbatim, only the
per-slot program differs (engine.SlotProgram):

  * prefill (``RetrievalProgram``): the request's padded item-id set is
    Bloom-encoded (core.bloom.encode, Eq. 1) and pushed through a small
    FF tower (models/recommender.py) to an m-dim logits row — that row
    IS the slot payload (no KV cache, no first token);
  * decode (``steps.make_retrieval_decode_step``): ONE occupancy-aware
    streaming Eq. 3 top-k over the whole catalog
    (io.recover_topk_spec), after which every served slot retires —
    the ``oneshot`` request kind: prefill -> single recover step ->
    retire, no autoregressive loop.

Never materialized: the (n_slots, d) score matrix and the (d, m) dense
item table.  At d=10M, m=8192 the dense table alone is 320 GB — the
catalog regime where only the streaming path serves at all; the
modeled-bytes gap vs that dense-table oracle is what
benchmarks/bench_serving.py commits and CI gates (retrieval.* rows).

Everything is deterministic: the Zipf workload is a pure function of
(seed, host) (loadgen.retrieval_workload), the schedule is a pure
function of (workload, n_slots), and the decode tie-break contract
(lowest item id wins on equal Eq. 3 scores) pins the recovered ids
bit-identically across replays and decode impls — asserted by the CLI
below and by tests/test_retrieval.py.

``python -m repro.serving.retrieval`` runs the acceptance drill: a
seeded Zipf run at d >= 10M through the slot pool, twice, hard-asserting
bit-identical top-k ids, a sound slot log, and tie-aware untrained
MAP/RR << 1 at eval scale, then prints the ``retrieval: verified``
marker the CI job greps for.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.retrieval import RetrievalConfig, get_retrieval_config
from repro.core import bloom as bloom_lib
from repro.core import quant
from repro.kernels.bloom_decode_topk import modeled_hbm_bytes
from repro.launch import steps as steps_lib
from repro.models import recommender as rec_lib
from repro.serving import admission as admission_lib
from repro.serving import engine as engine_lib
from repro.serving.admission import AdmissionPolicy
from repro.serving.engine import PrefillPool, SlotProgram, run_slot_loop
from repro.serving.failpoints import FailPlan
from repro.serving.loadgen import (RetrievalLoadSpec, assert_fresh_instances,
                                   retrieval_workload)
from repro.serving.scheduler import Request, ServeStats
from repro.train import metrics as metrics_lib

# full-score eval materializes (B, d) — fine for the smoke/web1m specs,
# a 40 GB allocation at web10m; the serving path never does this
EVAL_MAX_CATALOG = 2_000_000


def init_retrieval_params(rcfg: RetrievalConfig, key=None):
    """FF tower params: m-dim Bloom code in, m-dim logits out."""
    if key is None:
        key = jax.random.PRNGKey(rcfg.seed)
    return rec_lib.ff_init(key, rcfg.m, rcfg.hidden, rcfg.m)


@dataclasses.dataclass
class _RetrievalState:
    """Retrieval slot-pool state: the device-resident (n_slots, m)
    logits pool, a host mirror of the occupancy mask (the decode step's
    ``active`` input AND the bytes model's occupancy argument), and the
    run's accumulated modeled streaming bytes."""
    pool: object
    live: np.ndarray
    streaming_bytes: int = 0


class RetrievalProgram(SlotProgram):
    """The one-shot retrieval slot program (see module doc): prefill
    emits ``(logits_row, None)`` — there is no first token, the slot's
    whole output comes from the single recover step.  The decode half
    (constructed with ``n_slots``) owns the (n_slots, m) logits pool and
    the one occupancy-aware streaming Eq. 3 top-k step over the catalog,
    after which every served slot retires (``oneshot``)."""

    kind = "oneshot"
    oneshot = True
    engine_label = "the retrieval engine"

    def __init__(self, rcfg: RetrievalConfig,
                 n_slots: Optional[int] = None,
                 admission_policy=None):
        self.rcfg = rcfg
        self.n_slots = n_slots
        self._prefill = jax.jit(steps_lib.make_retrieval_prefill_step(rcfg))
        if n_slots is None:
            return                      # prefill-only program
        self._decode = jax.jit(steps_lib.make_retrieval_decode_step(rcfg))
        self._insert = jax.jit(
            lambda pool, row, slot: pool.at[slot].set(row),
            donate_argnums=(0,))
        # degrade ladder (DESIGN.md §14): "stage 2 shrinks retrieval
        # top-k" — each stage's narrower streaming decode is pre-built;
        # under the pinned lowest-id tie-break a degraded request's ids
        # are a bit-identical PREFIX of the full-width result
        self._stage = admission_lib.STAGE_NORMAL
        self._stage_topk = {
            st: admission_lib.stage_topk(rcfg.topk, st, admission_policy)
            for st in range(1, admission_policy.max_stage + 1)
        } if admission_policy is not None else {}
        self._stage_topk[admission_lib.STAGE_NORMAL] = rcfg.topk
        self._stage_decodes = engine_lib.build_stage_decodes(
            self._decode, rcfg.topk, admission_policy,
            lambda k: jax.jit(steps_lib.make_retrieval_decode_step(
                dataclasses.replace(rcfg, topk=k))))

    # -- prefill half --------------------------------------------------
    def prefill(self, params, req: Request, device=None):
        items = np.full((1, self.rcfg.c_max), -1, np.int32)
        items[0, :req.prompt_len] = np.asarray(req.prompt, np.int32)
        x = jnp.asarray(items)
        if device is not None:
            x = jax.device_put(x, device)
        return self._prefill(params, x)[0], None

    # -- decode half ---------------------------------------------------
    def check_admit(self, req: Request) -> None:
        assert req.prompt_len <= self.rcfg.c_max, (
            f"request {req.rid}: {req.prompt_len} input items exceeds "
            f"c_max {self.rcfg.c_max}")

    def init_state(self, n_slots: int) -> _RetrievalState:
        assert n_slots == self.n_slots
        return _RetrievalState(
            pool=jnp.zeros((n_slots, self.rcfg.m), jnp.float32),
            live=np.zeros((n_slots,), bool))

    def reset_slots(self, state: _RetrievalState) -> None:
        state.live[:] = False

    def insert(self, state: _RetrievalState, req: Request, payload,
               stats: ServeStats) -> bool:
        row, first = payload
        assert first is None, "oneshot prefill emits no token"
        state.pool = self._insert(state.pool, row, jnp.int32(req.slot))
        state.live[req.slot] = True
        return True

    def set_stage(self, stage: int) -> None:
        if stage not in self._stage_decodes:
            raise RuntimeError(
                f"{self.engine_label}: degrade stage {stage} was not "
                "pre-built — construct the program with the run's "
                "admission_policy (DESIGN.md §14)")
        self._stage = stage

    def step(self, params, state: _RetrievalState):
        active = jnp.asarray(state.live)
        scores, ids = self._stage_decodes[self._stage](state.pool, active)
        # bytes model follows the table_dtype knob (DESIGN.md §13): a
        # quantized decode stores the logp rows narrow, rehashes
        # in-kernel (no (d, k) stream) and — int8 only — reads one f32
        # scale per live row; "auto" keeps the legacy exact model.
        # The top-k term follows the degrade stage's served width.
        td = self.rcfg.table_dtype
        td = None if td == "auto" else td
        state.streaming_bytes += modeled_hbm_bytes(
            state.live, self.rcfg.b_tile, m=self.rcfg.m, d=self.rcfg.d,
            k=self.rcfg.k, topk=self._stage_topk[self._stage],
            logp_itemsize=quant.table_itemsize(td),
            inkernel_hash=td is not None,
            row_scales=td == "int8")
        return np.asarray(ids), np.asarray(scores)

    def emit(self, state: _RetrievalState, req: Request, slot: int, out,
             stats: ServeStats) -> bool:
        # one-shot: every slot that decoded retires with its top-k
        ids_np, scores_np = out
        req.topk_ids = [int(i) for i in ids_np[slot]]
        req.topk_scores = [float(s) for s in scores_np[slot]]
        req.tokens.append(int(ids_np[slot, 0]))
        stats.tokens_out += 1
        state.live[slot] = False
        return True


class RetrievalEngine:
    """Continuous-batching engine for ``oneshot`` retrieval requests.

    Admission, rejection, event logging and stats are the LM engine's
    (Scheduler / PrefillPool); the slot pool is a device-resident
    (n_slots, m) logits buffer + active mask instead of a KV-cache tree,
    and every live slot retires right after the step that recovers its
    top-k — so the schedule batches same-step admissions through one
    streaming decode over the catalog.

    After ``run`` the modeled decode bytes of the run are on
    ``self.modeled_bytes``: per-step streaming bytes from the kernel
    bytes model evaluated at the step's actual occupancy mask (the
    single source, kernels/bloom_decode_topk.modeled_hbm_bytes) and the
    dense-table oracle twin — all deterministic integers.
    """

    def __init__(self, rcfg: RetrievalConfig, params, *, n_slots: int,
                 prefill_workers: int = 1,
                 failpoints: Optional[FailPlan] = None,
                 admission_policy: Optional[AdmissionPolicy] = None):
        assert n_slots >= 1
        self.rcfg = rcfg
        self.params = params
        self.n_slots = n_slots
        self.failpoints = failpoints if failpoints else None
        self.policy = admission_policy
        self.program = RetrievalProgram(rcfg, n_slots=n_slots,
                                        admission_policy=admission_policy)
        self.prefill_pool = PrefillPool(
            None, params, topk=rcfg.topk, n_workers=prefill_workers,
            failpoints=self.failpoints, program=self.program)
        self.modeled_bytes: Dict[str, int] = {}

    def _dense_oracle_step_bytes(self) -> int:
        """HBM bytes of ONE dense-table decode step over the full pool:
        read the (d, m) f32 item table and the (B, m) logp rows, write
        AND re-read the (B, d) f32 score matrix (materialize, then
        top-k), flush the (B, topk) f32+i32 outputs.  The oracle the
        streaming path is gated against — at web10m the table term alone
        is 320 GB/step."""
        r, B = self.rcfg, self.n_slots
        return (r.d * r.m * 4 + B * r.m * 4 + 2 * B * r.d * 4
                + B * r.topk * 8)

    def run(self, requests: List[Request]
            ) -> Tuple[Dict[int, Request], ServeStats]:
        """Serve ``oneshot`` requests through the generic slot loop
        (engine.run_slot_loop — the SAME function the LM engine runs);
        mutates and returns them with ``topk_ids`` / ``topk_scores``
        filled (and ``tokens`` holding the top-1 item, so shared
        latency/throughput accounting works unchanged)."""
        results, stats, sched, state = run_slot_loop(
            self.program, self.params, self.prefill_pool, requests,
            self.n_slots, failpoints=self.failpoints,
            admission_policy=self.policy)
        self._sched = sched          # exposed for the simulation tests
        self.modeled_bytes = {
            "streaming_bytes": int(state.streaming_bytes),
            "dense_oracle_bytes": int(self._dense_oracle_step_bytes()
                                      * stats.decode_steps),
            "dense_oracle_step_bytes": self._dense_oracle_step_bytes(),
        }
        return results, stats


def evaluate_retrieval(rcfg: RetrievalConfig, params,
                       requests: List[Request],
                       table_dtype: Optional[str] = None
                       ) -> Dict[str, float]:
    """Offline ranking eval of served requests against their held-out
    targets, with the user's input items excluded from the ranking.

    Materializes the full (B, d) Eq. 3 score matrix (core.bloom.
    decode_scores — chunked, but still (B, d) at the end), so it is
    capped at eval-scale catalogs; the SERVING path never does this.
    Metrics are the tie-aware train/metrics.py: mid-rank RR and
    stable-sort MAP, so an untrained tower scores << 1 instead of the
    optimistic-tie 1.0 the old rank computation produced.

    ``table_dtype`` (DESIGN.md §13) fake-quantizes the (B, m) pool
    logits per row before Eq. 3 — the exact values a quantized Pallas
    decode ranks through — so the metrics measure what a quantized
    store would actually serve (the sweep's int8 dual-eval retention).
    """
    assert rcfg.d <= EVAL_MAX_CATALOG, (
        f"full-score eval at d={rcfg.d} would materialize a "
        f"(B, {rcfg.d}) matrix; eval on the smoke/web1m specs")
    served = [r for r in requests
              if r.done and not r.rejected and not r.shed
              and r.targets is not None and len(r.targets)]
    if not served:
        return {"map": 0.0, "rr": 0.0, "accuracy": 0.0, "n_evaluated": 0}
    B = len(served)
    prompts = np.full((B, rcfg.c_max), -1, np.int32)
    n_t = max(len(r.targets) for r in served)
    targets = np.full((B, n_t), -1, np.int32)
    for i, r in enumerate(served):
        prompts[i, :r.prompt_len] = np.asarray(r.prompt, np.int32)
        targets[i, :len(r.targets)] = np.asarray(r.targets, np.int32)
    logits = jax.jit(steps_lib.make_retrieval_prefill_step(rcfg))(
        params, jnp.asarray(prompts))
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    td = quant.resolve_table_dtype(table_dtype)
    if td is not None:
        q, s = quant.quantize_table(logp, td)
        logp = quant.dequantize_table(q, s)
    scores = np.asarray(bloom_lib.decode_scores(rcfg.spec(), logp,
                                                chunk=rcfg.chunk))
    # RR / accuracy score the FIRST held-out target (the single-correct-
    # item measures of Sec. 4.1); MAP scores the full held-out set
    return {
        "map": metrics_lib.mean_average_precision(scores, targets,
                                                  excludes=prompts),
        "rr": metrics_lib.reciprocal_rank(scores, targets[:, 0],
                                          exclude=prompts),
        "accuracy": metrics_lib.accuracy(scores, targets[:, 0],
                                         exclude=prompts),
        "n_evaluated": B,
    }


# ---------------------------------------------------------------------------
# CLI acceptance drill (the CI retrieval job greps "retrieval: verified")
# ---------------------------------------------------------------------------

def _drill(rcfg: RetrievalConfig, n_requests: int, n_slots: int,
           seed: int) -> Dict[str, object]:
    """Run the seeded Zipf workload through the slot pool TWICE from
    fresh request copies and hard-assert the acceptance criteria."""
    load = RetrievalLoadSpec(n_requests=n_requests, catalog=rcfg.d,
                             c_max=rcfg.c_max, rate=2.0, seed=seed)
    wl = retrieval_workload(load)
    params = init_retrieval_params(rcfg)
    engine = RetrievalEngine(rcfg, params, n_slots=n_slots)

    wl_a = [r.fresh_copy() for r in wl]
    wl_b = [r.fresh_copy() for r in wl]
    assert_fresh_instances(wl_a, wl_b)
    res_a, st_a = engine.run(wl_a)
    res_b, st_b = engine.run(wl_b)

    assert all(r.done and not r.rejected for r in res_a.values())
    for rid, ra in res_a.items():
        rb = res_b[rid]
        assert len(ra.topk_ids) == rcfg.topk
        assert all(0 <= i < rcfg.d for i in ra.topk_ids)
        assert ra.topk_ids == rb.topk_ids, (
            f"rid {rid}: top-k ids drifted across replays — the decode "
            "path is not deterministic")
        assert ra.topk_scores == rb.topk_scores
    assert st_a.decode_steps == st_b.decode_steps
    from repro.serving.control import replay_slot_log
    replay_slot_log(engine._sched.admissions, engine._sched.releases,
                    [], n_slots, rejects=engine._sched.rejects)
    mb = engine.modeled_bytes
    return {
        "config": rcfg.name, "d": rcfg.d, "m": rcfg.m, "k": rcfg.k,
        "impl": rcfg.resolved_impl, "n_requests": n_requests,
        "n_slots": n_slots, "decode_steps": st_a.decode_steps,
        "utilization": round(st_a.utilization, 4),
        "streaming_bytes": mb["streaming_bytes"],
        "dense_oracle_bytes": mb["dense_oracle_bytes"],
        "bytes_ratio": round(mb["dense_oracle_bytes"]
                             / max(mb["streaming_bytes"], 1), 1),
        "wall_s": round(st_a.wall_s, 3),
    }


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--config", default="web10m",
                    help="retrieval config preset (default: web10m — the "
                         "d >= 10M acceptance scale)")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--impl", default=None,
                    help="override the decode impl (auto|xla|pallas)")
    ap.add_argument("--out", default=None, help="write the report JSON")
    args = ap.parse_args()

    over = {"impl": args.impl} if args.impl else {}
    rcfg = get_retrieval_config(args.config, **over)
    report = _drill(rcfg, args.requests, args.slots, args.seed)

    # untrained-model ranking sanity at eval scale: with the tie-aware
    # metrics a random tower must score << 1 (the old optimistic-tie RR
    # reported ~1.0 on ties regardless of model quality)
    smoke = get_retrieval_config("smoke")
    load = RetrievalLoadSpec(n_requests=8, catalog=smoke.d,
                             c_max=smoke.c_max, rate=2.0, seed=args.seed)
    sparams = init_retrieval_params(smoke)
    sengine = RetrievalEngine(smoke, sparams, n_slots=4)
    sres, _ = sengine.run([r.fresh_copy() for r in retrieval_workload(load)])
    ev = evaluate_retrieval(smoke, sparams, list(sres.values()))
    assert ev["n_evaluated"] > 0
    assert ev["rr"] < 0.1 and ev["map"] < 0.1, (
        f"untrained tower ranks suspiciously well (rr={ev['rr']:.4f}, "
        f"map={ev['map']:.4f}) — tie handling regressed?")
    report["eval_smoke"] = {k: round(v, 6) if isinstance(v, float) else v
                           for k, v in ev.items()}
    report["verified"] = True

    print(json.dumps(report, indent=1, sort_keys=True))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(report, f, indent=1, sort_keys=True)
    print(f"retrieval: verified ({rcfg.name}: d={rcfg.d}, "
          f"{report['decode_steps']} decode steps, bytes ratio "
          f"{report['bytes_ratio']}x vs dense oracle)")


if __name__ == "__main__":
    main()
