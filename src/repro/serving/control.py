"""Serving control plane: the replicated admission state machine and the
transports that carry its deltas (DESIGN.md §9).

PR 3's ``ShardedScheduler`` *was* the simulated gossip: one authoritative
in-process queue playing every host at once.  This module splits that into
the three pieces a real multi-controller deployment needs:

  * **A pure state-machine core** — ``ControlState`` plus
    ``apply_deltas(state, deltas) -> state``: the replicated admission
    state every host maintains, advanced ONLY by applying scheduling
    deltas (request arrivals, slot releases).  ``compute_admissions`` is
    the deterministic admission function over that state (visible-ready
    requests ordered by (arrival, home, rid) -> visible-free slots in
    global slot order).  Because every host applies the same delta
    sequence and evaluates the same pure functions, all replicas agree
    without any further coordination.
  * **A pluggable ``Transport``** — the only component that knows how
    deltas move between hosts.  ``SimTransport`` is PR 3's in-process
    gossip reduced to just a transport (one global delay queue);
    ``CollectiveTransport`` carries per-host deltas over a fixed-size
    padded all_gather each step — the jax.distributed-ready protocol
    (the device collective itself is injected from serving/collective.py;
    the default numpy loopback computes the identical merged view, so the
    protocol logic is testable without devices).
  * **Compaction planning** — ``plan_compaction`` turns a fragmented
    visible occupancy into a host-local slot permutation.  It is a pure
    function of replicated state, so every host computes the identical
    remap at the identical step WITHOUT gossiping it; the ``COMPACT``
    event is recorded in the log for exact replay, never transported.

Release deltas are resolved **by rid**, not by slot id: a COMPACT remap
may land between a release's production and its visibility, so the slot
number in the delta can be stale — the rid's current slot never is.

**Membership + failure (DESIGN.md §10)**: ``ControlState`` carries a
live-host set and an epoch counter.  A ``HOST_DOWN`` delta (reported by
the lowest surviving host, carrying the dead host's id in its rid field)
travels the same transport as everything else; applying it reclaims the
dead host's slot range and re-queues its in-flight requests under their
ORIGINAL (arrival_step, home) keys, so every replica computes the
identical FIFO-order-preserving recovery.  Both transports carry a
per-round replicated-state digest and raise ``ReplicaDivergence`` the
round any host's digest disagrees — the "replicas must crash, not
desynchronize" invariant, enforced rather than commented — plus a
per-round deadline that turns an injected hang into ``TransportTimeout``.

Everything here is deliberately JAX-free (numpy only) so the hypothesis
suite can drive thousands of random topologies/delays/traffic patterns
against the protocol in microseconds.
"""
from __future__ import annotations

import dataclasses
import zlib
from collections import deque
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

# Delta kinds.  COMPACT is intentionally NOT a delta kind: compaction is a
# synchronous pure function of replicated state (see module docstring).
ARRIVE = 0
RELEASE = 1
HOST_DOWN = 2        # membership: rid field carries the DEAD host's id
_PAD = -1            # kind value of padding rows in the collective buffer
_DIGEST = -2         # transport-internal row kind: replicated-state digest
DELTA_FIELDS = 5     # (kind, step, home, rid, slot)

# Rounds whose injected hang exceeds this many virtual time units raise
# TransportTimeout instead of stalling the pool forever.  Inert without a
# FailPlan (real rounds have no virtual duration).
DEFAULT_ROUND_DEADLINE = 16


class ReplicaDivergence(RuntimeError):
    """A replica's state digest disagreed with its peers — the control
    plane is no longer replicated and MUST crash, not desynchronize."""


class TransportTimeout(RuntimeError):
    """An exchange round exceeded the transport's per-round deadline."""


@dataclasses.dataclass(frozen=True)
class Delta:
    """One scheduling event in flight.

    ``step`` is the event's logical production step — the arrival step for
    ARRIVE, the release step for RELEASE, the death-report step for
    HOST_DOWN; visibility is always ``step + delay`` regardless of when
    the transport physically moves the bytes (a fast-forwarded engine may
    exchange late; the schedule must not depend on that).

    For HOST_DOWN, ``home`` is the REPORTING host (lowest survivor) and
    ``rid`` carries the dead host's id — the victim cannot report its own
    death.
    """

    kind: int
    step: int
    home: int        # producing host (the slot's owner for RELEASE)
    rid: int
    slot: int = -1   # RELEASE: global slot id at production time;
                     # ARRIVE: the request's deadline_step (-1 = none)

    def encode(self) -> Tuple[int, int, int, int, int]:
        return (self.kind, self.step, self.home, self.rid, self.slot)

    @staticmethod
    def decode(row: Sequence[int]) -> "Delta":
        kind, step, home, rid, slot = (int(x) for x in row)
        if kind not in (ARRIVE, RELEASE, HOST_DOWN):
            raise ValueError(f"undecodable delta kind {kind}")
        return Delta(kind, step, home, rid, slot)


def _delta_order(d: Delta):
    # apply order is semantically irrelevant (arrivals join a sorted set,
    # releases resolve by rid) but a fixed sort keeps replicas literally
    # identical, transcript for transcript.  Kind is the second key on
    # purpose: a RELEASE and a HOST_DOWN delivered in one poll apply
    # release-first, so a request finishing at the death step is retired,
    # never re-queued (DESIGN.md §10 on the release/death race).
    return (d.step, d.kind, d.home, d.rid, d.slot)


# ---------------------------------------------------------------------------
# Pure replicated state machine
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ControlState:
    """The replicated admission state: what every host agrees on.

    ``pending`` holds only *visible* arrivals (the transport withholds a
    delta until ``step + delay``); ``occupant`` marks a slot free only
    once the release delta has applied — so "free in state" IS
    "visible-free" and no separate visibility bookkeeping exists here.

    ``admitted`` retains each occupant's original (arrival_step, home)
    admission key: HOST_DOWN re-queues a dead host's requests under that
    key, which is what makes recovery FIFO-order-preserving.  ``live``
    and ``epoch`` are the membership view; dead hosts' slots are never
    admission targets and ``epoch`` bumps once per death (the data plane
    keys its shrink on it).
    """

    slots_per_host: int
    pending: Dict[int, Tuple[int, int]]      # rid -> (arrival_step, home)
    occupant: List[int]                      # global slot -> rid, -1 free
    live: List[bool] = None                  # host -> alive (None: all)
    epoch: int = 0                           # bumps on every HOST_DOWN
    admitted: Dict[int, Tuple[int, int]] = dataclasses.field(
        default_factory=dict)                # rid -> its admission key
    deadlines: Dict[int, int] = dataclasses.field(
        default_factory=dict)                # rid -> deadline_step (if any)

    def __post_init__(self):
        if self.live is None:
            self.live = [True] * self.n_hosts

    @classmethod
    def fresh(cls, n_hosts: int, slots_per_host: int) -> "ControlState":
        return cls(slots_per_host=slots_per_host, pending={},
                   occupant=[-1] * (n_hosts * slots_per_host))

    @property
    def n_slots(self) -> int:
        return len(self.occupant)

    @property
    def n_hosts(self) -> int:
        return self.n_slots // self.slots_per_host

    def copy(self) -> "ControlState":
        return ControlState(self.slots_per_host, dict(self.pending),
                            list(self.occupant), list(self.live),
                            self.epoch, dict(self.admitted),
                            dict(self.deadlines))


def control_digest(state: ControlState) -> int:
    """A 31-bit digest of the full replicated state, stable across
    processes and platforms (crc32 of a canonical repr).  Every host
    reports it each transport round; a mismatch means the state machines
    diverged and the round raises ``ReplicaDivergence``."""
    canon = (state.slots_per_host,
             tuple(sorted(state.pending.items())),
             tuple(state.occupant),
             tuple(state.live),
             state.epoch,
             tuple(sorted(state.admitted.items())),
             tuple(sorted(state.deadlines.items())))
    return zlib.crc32(repr(canon).encode()) & 0x7FFFFFFF


def apply_deltas(state: ControlState,
                 deltas: Sequence[Delta]) -> ControlState:
    """THE replicated transition function: pure — returns a new state.

    Raises on protocol violations (double arrival, release of an
    unoccupied rid): a transport that delivers such a sequence is broken,
    and the hypothesis suite asserts these can't happen under any
    topology/delay/traffic.
    """
    out = state.copy()
    for d in sorted(deltas, key=_delta_order):
        if d.kind == ARRIVE:
            if d.rid in out.pending or d.rid in out.admitted:
                raise RuntimeError(f"request {d.rid} arrived twice")
            out.pending[d.rid] = (d.step, d.home)
            # ARRIVE reuses the otherwise-unused slot lane to replicate
            # the request's deadline_step (-1 = none): the shed decision
            # is a pure function of replicated state, so the deadline
            # must BE replicated state (DESIGN.md §14)
            if d.slot >= 0:
                out.deadlines[d.rid] = d.slot
        elif d.kind == RELEASE:
            # resolve by rid, NOT by the delta's slot field: a COMPACT
            # between production and visibility remaps slots, but the rid
            # still occupies exactly one
            try:
                slot = out.occupant.index(d.rid)
            except ValueError:
                raise RuntimeError(
                    f"release of rid {d.rid} which occupies no slot")
            out.occupant[slot] = -1
            out.admitted.pop(d.rid, None)
            out.deadlines.pop(d.rid, None)
        elif d.kind == HOST_DOWN:
            dead = d.rid
            if not (0 <= dead < out.n_hosts):
                raise RuntimeError(f"HOST_DOWN for unknown host {dead}")
            if not out.live[dead]:
                raise RuntimeError(f"host {dead} reported down twice")
            out.live[dead] = False
            out.epoch += 1
            # reclaim the dead range; re-queue its occupants under their
            # ORIGINAL admission keys so survivors recover them in FIFO
            # order relative to everything still pending
            lo = dead * out.slots_per_host
            for slot in range(lo, lo + out.slots_per_host):
                rid = out.occupant[slot]
                if rid == -1:
                    continue
                out.occupant[slot] = -1
                if rid not in out.admitted:  # pragma: no cover
                    raise RuntimeError(
                        f"rid {rid} occupies slot {slot} with no "
                        "admission record")
                out.pending[rid] = out.admitted.pop(rid)
        else:  # pragma: no cover
            raise RuntimeError(f"unknown delta kind {d.kind}")
    return out


def compute_admissions(state: ControlState) -> List[Tuple[int, int]]:
    """The deterministic admission function: visible-ready requests
    (ordered by (arrival_step, home, rid)) zipped onto visible-free slots
    (global slot order).  Pure — commit with ``commit_admission``."""
    ready = sorted(state.pending.items(),
                   key=lambda kv: (kv[1][0], kv[1][1], kv[0]))
    free = [s for s, r in enumerate(state.occupant)
            if r == -1 and state.live[s // state.slots_per_host]]
    return [(slot, rid) for slot, (rid, _) in zip(free, ready)]


def commit_admission(state: ControlState, slot: int, rid: int) -> None:
    """Synchronous transition: admissions are computed identically by
    every replica at the same step, so they need no delta.  The admission
    key moves from ``pending`` to ``admitted`` so a later HOST_DOWN can
    re-queue the rid under its original FIFO position."""
    if state.occupant[slot] != -1:  # pragma: no cover
        raise RuntimeError(f"slot {slot} double-assigned")
    state.occupant[slot] = rid
    # the deadline entry (if any) survives admission on purpose: a later
    # HOST_DOWN re-queues the rid, and its deadline did not die with the
    # host — the next shed pass judges it again (DESIGN.md §14)
    state.admitted[rid] = state.pending.pop(rid)


def commit_sheds(state: ControlState, rids: Sequence[int]) -> None:
    """Synchronous transition twin of ``commit_admission``: sheds are
    computed identically by every replica (admission.compute_sheds over
    replicated state), so they need no delta — each host just drops the
    rids from its queue mirror.  Raises (never asserts — queue integrity
    must survive ``python -O``) if a shed rid is not actually queued."""
    for rid in rids:
        if rid not in state.pending:
            raise RuntimeError(
                f"shed of rid {rid} which is not queued")
        state.pending.pop(rid)
        state.deadlines.pop(rid, None)


# ---------------------------------------------------------------------------
# Compaction planning (control plane of the data-plane remap)
# ---------------------------------------------------------------------------

def fragmentation(occupant: Sequence[int], slots_per_host: int,
                  host: int) -> float:
    """Dead-slot fraction below the host's highest live slot, normalized
    by the shard size — 0.0 for an empty or perfectly packed shard."""
    lo = host * slots_per_host
    live = [s for s in range(lo, lo + slots_per_host)
            if occupant[s] != -1]
    if not live:
        return 0.0
    holes = (live[-1] - lo + 1) - len(live)
    return holes / slots_per_host


def plan_compaction(occupant: Sequence[int], slots_per_host: int,
                    threshold: float) -> Optional[List[int]]:
    """Visible occupancy -> host-local remap permutation, or None.

    For every host whose ``fragmentation`` strictly exceeds ``threshold``,
    live slots are packed (order-preserving) into the dense prefix of the
    host's contiguous range, dead slots into the tail.  Returns
    ``perm`` with ``perm[new_slot] = old_slot`` (gather convention — the
    data plane applies it as ``pool[:, perm]``), always a permutation of
    ``range(n_slots)`` that never crosses a host boundary; None when no
    host crosses the threshold or packing would change nothing.

    Pure function of replicated state: every host computes the identical
    plan at the identical step, so the remap needs no transport — only a
    COMPACT log event so replay stays exact.
    """
    n_slots = len(occupant)
    perm = list(range(n_slots))
    changed = False
    for host in range(n_slots // slots_per_host):
        if fragmentation(occupant, slots_per_host, host) <= threshold:
            continue
        lo = host * slots_per_host
        hi = lo + slots_per_host
        live = [s for s in range(lo, hi) if occupant[s] != -1]
        dead = [s for s in range(lo, hi) if occupant[s] == -1]
        packed = live + dead
        if packed != perm[lo:hi]:
            perm[lo:hi] = packed
            changed = True
    return perm if changed else None


def invert_perm(perm: Sequence[int]) -> List[int]:
    """inv[old_slot] = new_slot for a gather-convention permutation."""
    inv = [0] * len(perm)
    for new, old in enumerate(perm):
        inv[old] = new
    return inv


# ---------------------------------------------------------------------------
# Event log (the ONE implementation shared by Scheduler, ShardedScheduler
# and the model-free replay — satellite dedupe)
# ---------------------------------------------------------------------------

class HostShard:
    """One host's slice of the global slot pool: the contiguous global
    slot range [host * slots_per_host, (host+1) * slots_per_host) plus the
    host-local event log.  Events carry GLOBAL slot ids and the global
    event seq, so the merged log is reconstructible from the per-host logs
    (linearization — tested in tests/test_property.py)."""

    def __init__(self, host: int, slots_per_host: int):
        self.host = host
        self.slots_per_host = slots_per_host
        self.lo = host * slots_per_host
        self.hi = (host + 1) * slots_per_host
        self.admissions: List[Tuple[int, int, int, int]] = []
        self.releases: List[Tuple[int, int, int, int]] = []
        # (step, local perm tuple over the host's GLOBAL slot ids, seq) —
        # recorded only when this host's range actually moved
        self.compactions: List[Tuple[int, Tuple[int, ...], int]] = []
        # failure-path events (same (step, slot, rid, seq) shape):
        # rejects free a slot whose prefill permanently failed; reclaims
        # free a dead host's slot when its HOST_DOWN applies
        self.rejects: List[Tuple[int, int, int, int]] = []
        self.reclaims: List[Tuple[int, int, int, int]] = []
        # (step, rid, reason, seq) — sheds vacate no slot (the rid was
        # still queued), so they are attributed to the request's HOME
        # host rather than a slot owner
        self.sheds: List[Tuple[int, int, int, int]] = []

    def owns(self, gslot: int) -> bool:
        return self.lo <= gslot < self.hi


class EventLog:
    """Monotonic scheduling event log: (step, slot, rid, seq) admission /
    release tuples plus (step, perm, seq) compactions, with optional
    per-host mirrors.  ``seq`` is the single global monotonic counter —
    several events can share one clock step (release + re-admit at the
    same tick), and every soundness check orders by seq."""

    def __init__(self, n_hosts: int = 0, slots_per_host: int = 0):
        self.admissions: List[Tuple[int, int, int, int]] = []
        self.releases: List[Tuple[int, int, int, int]] = []
        self.compactions: List[Tuple[int, Tuple[int, ...], int]] = []
        self.rejects: List[Tuple[int, int, int, int]] = []
        self.reclaims: List[Tuple[int, int, int, int]] = []
        # (step, rid, reason, seq) — overload sheds, merged + per-home
        self.sheds: List[Tuple[int, int, int, int]] = []
        # (step, dead host, epoch, seq) — merged only (not slot-owned)
        self.host_downs: List[Tuple[int, int, int, int]] = []
        # (step, from_stage, to_stage, seq) — degrade-ladder moves,
        # merged only: the stage is global replicated state, every host
        # executes the identical transition (DESIGN.md §14)
        self.degrades: List[Tuple[int, int, int, int]] = []
        self.hosts = [HostShard(h, slots_per_host)
                      for h in range(n_hosts)] if slots_per_host else []
        self._seq = 0

    def _host(self, gslot: int) -> Optional[HostShard]:
        if not self.hosts:
            return None
        return self.hosts[gslot // self.hosts[0].slots_per_host]

    def admission(self, step: int, slot: int, rid: int):
        ev = (step, slot, rid, self._seq)
        self._seq += 1
        self.admissions.append(ev)
        shard = self._host(slot)
        if shard is not None:
            shard.admissions.append(ev)
        return ev

    def release(self, step: int, slot: int, rid: int):
        ev = (step, slot, rid, self._seq)
        self._seq += 1
        self.releases.append(ev)
        shard = self._host(slot)
        if shard is not None:
            shard.releases.append(ev)
        return ev

    def reject(self, step: int, slot: int, rid: int):
        ev = (step, slot, rid, self._seq)
        self._seq += 1
        self.rejects.append(ev)
        shard = self._host(slot)
        if shard is not None:
            shard.rejects.append(ev)
        return ev

    def reclaim(self, step: int, slot: int, rid: int):
        ev = (step, slot, rid, self._seq)
        self._seq += 1
        self.reclaims.append(ev)
        shard = self._host(slot)
        if shard is not None:
            shard.reclaims.append(ev)
        return ev

    def shed(self, step: int, rid: int, reason: int, home: int = 0):
        ev = (step, rid, reason, self._seq)
        self._seq += 1
        self.sheds.append(ev)
        if self.hosts:
            self.hosts[home].sheds.append(ev)
        return ev

    def degrade(self, step: int, old: int, new: int):
        ev = (step, old, new, self._seq)
        self._seq += 1
        self.degrades.append(ev)
        return ev

    def host_down(self, step: int, host: int, epoch: int):
        ev = (step, host, epoch, self._seq)
        self._seq += 1
        self.host_downs.append(ev)
        return ev

    def compaction(self, step: int, perm: Sequence[int]):
        ev = (step, tuple(int(p) for p in perm), self._seq)
        self._seq += 1
        self.compactions.append(ev)
        for shard in self.hosts:
            local = ev[1][shard.lo:shard.hi]
            if local != tuple(range(shard.lo, shard.hi)):
                shard.compactions.append((step, local, ev[2]))
        return ev


def replay_slot_log(admissions, releases, compactions, n_slots: int,
                    rejects=(), reclaims=()):
    """THE shared event-log replay (satellite dedupe): reconstruct slot
    occupancy from a merged log, asserting soundness at every event —
    no slot double-assigned, every release matches the occupying rid
    (through any COMPACT remaps), no live request silently dropped by a
    remap (COMPACT perms are exact permutations).  Returns the final
    occupancy (rid or None per slot).

    ``rejects`` (prefill permanently failed) and ``reclaims`` (slot freed
    by a HOST_DOWN) vacate a slot exactly like releases — the replay
    checks the same occupant-match invariant for them, which is what lets
    a reclaimed rid be re-admitted later without tripping the
    double-assignment check.

    Used by tests/conftest.assert_slot_log_sound, the multi-host sim
    verdicts, and the hypothesis compaction/chaos properties.
    """
    events = (
        [(seq, 0, slot, rid) for step, slot, rid, seq in admissions]
        + [(seq, 1, slot, rid) for step, slot, rid, seq in
           list(releases) + list(rejects) + list(reclaims)]
        + [(seq, 2, perm, None) for step, perm, seq in compactions])
    occ: List[Optional[int]] = [None] * n_slots
    for ev in sorted(events, key=lambda e: e[0]):
        _, kind, a, b = ev
        if kind == 0:
            assert occ[a] is None, f"slot {a} double-assigned (rid {b})"
            occ[a] = b
        elif kind == 1:
            assert occ[a] == b, (
                f"slot {a} released with rid {b} but occupied by {occ[a]}")
            occ[a] = None
        else:
            perm = list(a)
            assert sorted(perm) == list(range(n_slots)), (
                "COMPACT event is not a permutation — live slots dropped")
            occ = [occ[p] for p in perm]
    return occ


# ---------------------------------------------------------------------------
# Transports
# ---------------------------------------------------------------------------

class Transport:
    """Delta movement contract (DESIGN.md §9/§10).

    ``send`` accepts a delta produced by its home host.  ``poll(now)``
    returns every delta whose visibility step (``delta.step + delay``) is
    <= now, exactly once, in any order (``apply_deltas`` sorts).
    ``pending_release_vis`` lists visibility steps of RELEASE deltas still
    in flight — the scheduler's fast-forward clock needs them;
    ``pending_recovery_vis`` does the same for HOST_DOWN deltas (the run
    loop must keep ticking until a death's reclaims apply).  Transports
    never interpret deltas beyond the kind/step fields.

    Failure-model hooks (inert when ``failpoints`` is None, which the
    scheduler wires): ARRIVE visibility is ``arrive_visibility(step)`` so
    an injected arrival delay stretches only arrivals — RELEASE and
    HOST_DOWN always travel at the base delay (DESIGN.md §10 explains why
    that asymmetry is load-bearing).  ``poll(now, digest=...)`` carries
    every host's reported state digest through the round and raises
    ``ReplicaDivergence`` on any mismatch; a round whose injected hang
    exceeds ``deadline`` raises ``TransportTimeout``.
    """

    delay: int
    failpoints = None                 # Optional[FailPlan]; scheduler wires
    deadline: Optional[int] = DEFAULT_ROUND_DEADLINE
    n_hosts: Optional[int] = None     # needed for per-host digest reports

    def send(self, delta: Delta) -> None:
        raise NotImplementedError

    def poll(self, now: int, digest: Optional[int] = None) -> List[Delta]:
        raise NotImplementedError

    def pending_release_vis(self) -> List[int]:
        raise NotImplementedError

    def pending_recovery_vis(self) -> List[int]:
        raise NotImplementedError

    # -- shared failure-model helpers ----------------------------------
    def arrive_visibility(self, step: int) -> int:
        """Visibility step of an ARRIVE delta produced at ``step``."""
        extra = (self.failpoints.arrive_extra_delay(step)
                 if self.failpoints is not None else 0)
        return step + self.delay + extra

    def _visibility(self, d: Delta) -> int:
        return (self.arrive_visibility(d.step) if d.kind == ARRIVE
                else d.step + self.delay)

    def _round_guard(self, now: int) -> None:
        if self.failpoints is None or self.deadline is None:
            return
        hang = self.failpoints.round_hang(now)
        if hang > self.deadline:
            raise TransportTimeout(
                f"exchange round at step {now} hung for {hang} units "
                f"(deadline {self.deadline})")

    def _reported_digests(self, now: int, digest: int) -> List[int]:
        """What each replica reports this round: the replicated digest,
        XOR any injected corruption (a stand-in for genuine divergence —
        in a real deployment each host computes its own digest)."""
        n = self.n_hosts if self.n_hosts else 1
        if self.failpoints is None:
            return [digest] * n
        return [digest ^ self.failpoints.digest_mask(h, now)
                for h in range(n)]

    @staticmethod
    def _check_digests(now: int, reported: Sequence[int]) -> None:
        if len(set(reported)) > 1:
            bad = [h for h, v in enumerate(reported) if v != reported[0]]
            raise ReplicaDivergence(
                f"state digest mismatch at step {now}: hosts {bad} "
                f"disagree ({reported})")


class SimTransport(Transport):
    """PR 3's in-process gossip, reduced to *just a transport*: one global
    delay queue.  A delta sent at logical step t is delivered by the first
    poll with ``now >= t + delay`` — including to the producing host
    (uniform visibility is what makes the admission function replicable).
    """

    def __init__(self, delay: int = 1, *, failpoints=None,
                 deadline: Optional[int] = DEFAULT_ROUND_DEADLINE,
                 n_hosts: Optional[int] = None):
        assert delay >= 0
        self.delay = delay
        self.failpoints = failpoints
        self.deadline = deadline
        self.n_hosts = n_hosts
        self._flight: List[Tuple[int, int, Delta]] = []
        self._n = 0

    def send(self, delta: Delta) -> None:
        self._flight.append((self._visibility(delta), self._n, delta))
        self._n += 1

    def poll(self, now: int, digest: Optional[int] = None) -> List[Delta]:
        self._round_guard(now)
        if digest is not None:
            self._check_digests(now, self._reported_digests(now, digest))
        due = sorted(e for e in self._flight if e[0] <= now)
        self._flight = [e for e in self._flight if e[0] > now]
        return [d for _, _, d in due]

    def pending_release_vis(self) -> List[int]:
        return [v for v, _, d in self._flight if d.kind == RELEASE]

    def pending_recovery_vis(self) -> List[int]:
        return [v for v, _, d in self._flight if d.kind == HOST_DOWN]


class CollectiveTransport(Transport):
    """Delta exchange over a fixed-size padded all_gather — the
    jax.distributed-ready protocol (ROADMAP follow-up a).

    Every poll runs >= 1 exchange round; a round stacks each host's
    outbox into its row of a ``(n_hosts, capacity, DELTA_FIELDS)`` int32
    buffer (padding rows carry kind=-1) and gathers the stack so every
    host receives the identical ``(n_hosts, capacity, F)`` merged view.
    The buffer is FIXED-SIZE on purpose: the collective's shape never
    depends on traffic, so the gather compiles exactly once and a real
    multi-controller deployment never negotiates lengths; a burst that
    overflows ``capacity`` simply runs extra rounds of the same
    executable (outboxes drain FIFO, so visibility order is preserved —
    and visibility is computed from the PRODUCTION step, so late physical
    delivery can never reorder the schedule).

    ``gather`` maps the stacked buffer ``(n_hosts, C+1, F)`` to every
    host's received view ``(n_hosts, n_hosts, C+1, F)``; the default
    numpy loopback computes exactly what all_gather computes, which is
    how the hypothesis equivalence sweep drives the protocol without
    devices.  Serving injects the device collective
    (serving/collective.py) — per host's row lives on its data shard and
    jax.lax.all_gather moves it.  The per-host views are asserted
    identical every round, and the last row of each host's buffer slice
    carries that host's replicated-state digest: a digest mismatch in the
    gathered view raises ``ReplicaDivergence`` within the round — a
    transport whose replicas diverge must crash, not desynchronize the
    pool.
    """

    def __init__(self, n_hosts: int, delay: int = 1, capacity: int = 8,
                 gather: Optional[Callable[[np.ndarray], np.ndarray]]
                 = None, *, failpoints=None,
                 deadline: Optional[int] = DEFAULT_ROUND_DEADLINE):
        assert n_hosts >= 1 and delay >= 0 and capacity >= 1
        self.n_hosts = n_hosts
        self.delay = delay
        self.capacity = capacity
        self.failpoints = failpoints
        self.deadline = deadline
        self._gather = gather if gather is not None else self._loopback
        self._outbox = [deque() for _ in range(n_hosts)]
        self._inbox: List[Tuple[int, int, Delta]] = []
        self._n = 0
        self.rounds = 0          # exchange rounds run (tests/bench)
        self.polls = 0

    @staticmethod
    def _loopback(buf: np.ndarray) -> np.ndarray:
        # broadcast == all_gather: every host receives the full stack
        return np.broadcast_to(buf[None], (buf.shape[0],) + buf.shape)

    def send(self, delta: Delta) -> None:
        assert 0 <= delta.home < self.n_hosts
        self._outbox[delta.home].append(delta)

    def _exchange_round(self, now: int,
                        digest: Optional[int] = None) -> None:
        self._round_guard(now)
        # capacity delta rows + 1 digest row per host: the buffer stays
        # FIXED-SIZE (shape never depends on traffic or failures), so the
        # gather still compiles exactly once
        buf = np.full((self.n_hosts, self.capacity + 1, DELTA_FIELDS),
                      _PAD, np.int32)
        for h, box in enumerate(self._outbox):
            for i in range(min(self.capacity, len(box))):
                buf[h, i] = box.popleft().encode()
        if digest is not None:
            for h, rep in enumerate(self._reported_digests(now, digest)):
                buf[h, self.capacity] = (_DIGEST, now, h, rep, -1)
        views = np.asarray(self._gather(buf))
        assert views.shape == (self.n_hosts,) + buf.shape, views.shape
        for h in range(1, self.n_hosts):
            assert (views[h] == views[0]).all(), (
                "collective replicas diverged — hosts received different "
                "merged delta buffers")
        if digest is not None:
            self._check_digests(
                now, [int(views[0][h, self.capacity, 3])
                      for h in range(self.n_hosts)])
        for row in views[0].reshape(-1, DELTA_FIELDS):
            if row[0] in (_PAD, _DIGEST):
                continue
            d = Delta.decode(row)
            self._inbox.append((self._visibility(d), self._n, d))
            self._n += 1
        self.rounds += 1

    def poll(self, now: int, digest: Optional[int] = None) -> List[Delta]:
        self.polls += 1
        self._exchange_round(now, digest)      # the per-step heartbeat
        while any(self._outbox):               # fixed-size overflow rounds
            self._exchange_round(now, digest)
        due = sorted(e for e in self._inbox if e[0] <= now)
        self._inbox = [e for e in self._inbox if e[0] > now]
        return [d for _, _, d in due]

    def pending_release_vis(self) -> List[int]:
        out = [d.step + self.delay for box in self._outbox for d in box
               if d.kind == RELEASE]
        out += [v for v, _, d in self._inbox if d.kind == RELEASE]
        return out

    def pending_recovery_vis(self) -> List[int]:
        out = [d.step + self.delay for box in self._outbox for d in box
               if d.kind == HOST_DOWN]
        out += [v for v, _, d in self._inbox if d.kind == HOST_DOWN]
        return out
