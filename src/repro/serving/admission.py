"""Deadline-aware admission control, backpressure and graceful
degradation (DESIGN.md §14).

Overload is the failure mode PR 6 left unmodeled: the replicated queue
was unbounded, requests had no deadlines, and a sustained arrival rate
above pool throughput just grew ``pending`` forever.  This module is the
policy layer that closes that hole, built on the same discipline as
compaction planning (control.plan_compaction): every decision here is a
**pure function of replicated state** — queue contents, deadlines,
occupancy, the clock — so every host computes the identical shed set and
the identical degrade stage at the identical step WITHOUT transporting
either.  SHED and DEGRADE/RESTORE are logged for exact replay, never
gossiped; only arrivals/releases/host-downs ever travel.

Three mechanisms, in the order the scheduler applies them each step:

  * **Deadline shedding** — a queued request whose ``deadline_step`` has
    passed (now > deadline) can no longer meet its SLO, so it is shed
    rather than admitted late.  Admitted requests are never shed: work
    already holding a slot always runs to completion (a reclaimed rid
    re-queued by HOST_DOWN becomes sheddable again, deliberately — its
    deadline did not die with the host).
  * **Bounded queues (backpressure)** — with ``max_queue_depth`` set,
    each home keeps only the FIFO-first ``max_queue_depth`` of its
    visible queued requests; the excess (latest arrivals first) is shed.
    This is load shedding at the door: the replicated queue can no
    longer grow without bound under a surge.
  * **Graceful degradation** — ``pressure`` (visible queue depth over
    live slot capacity) is averaged over a sliding window; the windowed
    signal drives a staged ladder executed identically by every replica:
    stage 1 halves the served top-k width, stage 2 shrinks it to
    ``degraded_topk`` (see the stage constants below for why the ladder
    narrows top-k rather than swapping to int8 tables).  Stages move one
    step per
    clock tick (DEGRADE up, RESTORE down, with hysteresis so the ladder
    cannot flap), and every stage's decode callable is pre-built at
    engine construction — a transition swaps jits, it NEVER compiles
    (the compaction zero-recompile trick, asserted in the drills).

Like control.py, this module is deliberately JAX-free (pure python) so
the hypothesis suite can sweep thousands of random (topology, surge,
deadline) combinations against the policy in microseconds, and the
signatures take plain mappings rather than ``ControlState`` so the
single-host engine loop (engine.run_slot_loop) and the sharded control
plane share one implementation.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

# Shed reasons (logged in the event's reason field)
SHED_DEADLINE = 0       # deadline passed while queued
SHED_QUEUE_FULL = 1     # per-host queue bound exceeded (backpressure)

# Degrade ladder stages.  Both degraded stages narrow the SERVED top-k
# width (pre-built decode jits at smaller k): the fused decode-topk's
# k-selection work and the per-step d2h payload shrink, while the
# emitted results stay a bit-identical prefix of the unloaded run's
# (the pinned lowest-id tie-break makes top-k at k' < k a prefix of
# top-k at k; the LM's next token is the top-1 id, so it is invariant).
# The int8 ``table_dtype`` path was measured and REJECTED as a ladder
# stage: per-row fake-quant flips the greedy argmax (8/48 top-1 flips
# on the smoke model), which would break the serving contract that a
# completed request is bit-identical to its unloaded twin — int8 stays
# a construction-time choice (DESIGN.md §13), not a mid-run swap.
STAGE_NORMAL = 0        # full top-k
STAGE_NARROW = 1        # served top-k halved
STAGE_MIN = 2           # served top-k shrunk to policy.degraded_topk
MAX_STAGE = STAGE_MIN


@dataclasses.dataclass(frozen=True)
class AdmissionPolicy:
    """The overload policy knobs — immutable pure data, validated like
    LoadSpec so a bad config fails at construction, not mid-drill.

    ``max_queue_depth`` bounds each home's *visible* queued requests
    (None = unbounded, the pre-PR-10 behaviour).  The pressure ladder
    degrades at windowed-average pressure >= ``degrade_lo`` (stage 1)
    / ``degrade_hi`` (stage 2) and restores a stage only once the
    average falls to ``restore_below`` — the hysteresis gap keeps a
    near-threshold signal from flapping the jit swap every step.
    ``max_stage`` caps the ladder (0 disables degradation entirely;
    shedding still applies)."""

    max_queue_depth: Optional[int] = None
    pressure_window: int = 4
    degrade_lo: float = 1.0
    degrade_hi: float = 2.0
    restore_below: float = 0.5
    max_stage: int = MAX_STAGE
    degraded_topk: int = 1     # served top-k width at STAGE_MIN

    def __post_init__(self):
        if self.max_queue_depth is not None and self.max_queue_depth < 1:
            raise ValueError(
                f"max_queue_depth must be >= 1, got {self.max_queue_depth}")
        if self.pressure_window < 1:
            raise ValueError(
                f"pressure_window must be >= 1, got {self.pressure_window}")
        if not (0.0 < self.degrade_lo <= self.degrade_hi):
            raise ValueError(
                "need 0 < degrade_lo <= degrade_hi, got "
                f"{self.degrade_lo} / {self.degrade_hi}")
        if not (0.0 <= self.restore_below <= self.degrade_lo):
            raise ValueError(
                "need 0 <= restore_below <= degrade_lo, got "
                f"{self.restore_below}")
        if not (0 <= self.max_stage <= MAX_STAGE):
            raise ValueError(f"max_stage must be in [0, {MAX_STAGE}], "
                             f"got {self.max_stage}")
        if self.degraded_topk < 1:
            raise ValueError(
                f"degraded_topk must be >= 1, got {self.degraded_topk}")


def compute_sheds(pending: Mapping[int, Tuple[int, int]],
                  deadlines: Mapping[int, int], now: int,
                  policy: AdmissionPolicy) -> List[Tuple[int, int]]:
    """The deterministic shed function: which queued rids drop this step,
    and why.  Pure in (pending, deadlines, now, policy) — every replica
    evaluates it on identical replicated state, so the shed set needs no
    transport (module docstring).

    ``pending`` maps rid -> (arrival_step, home) (the control plane's
    visible queue); ``deadlines`` maps rid -> deadline_step for rids
    that have one.  Returns ``[(rid, reason), ...]`` sorted by rid.
    Deadline sheds are decided first; the queue bound then applies to
    the survivors (FIFO-first ``max_queue_depth`` kept per home, excess
    shed — latest (arrival_step, rid) first)."""
    sheds: Dict[int, int] = {}
    for rid in pending:
        dl = deadlines.get(rid, -1)
        if dl >= 0 and now > dl:
            sheds[rid] = SHED_DEADLINE
    if policy.max_queue_depth is not None:
        by_home: Dict[int, List[Tuple[int, int]]] = {}
        for rid, (arrival, home) in pending.items():
            if rid not in sheds:
                by_home.setdefault(home, []).append((arrival, rid))
        for home, queued in by_home.items():
            queued.sort()
            for _, rid in queued[policy.max_queue_depth:]:
                sheds[rid] = SHED_QUEUE_FULL
    return sorted(sheds.items())


def stage_topk(topk: int, stage: int, policy: AdmissionPolicy) -> int:
    """Served top-k width at a degrade stage — THE width contract the
    engines pre-build their per-stage decode jits against (one
    definition, so the LM pool, the sharded pool and the retrieval
    program can never disagree on what a stage serves).  Narrowing is
    emission-preserving under the pinned lowest-id tie-break: the
    stage-s result is a bit-identical prefix of the stage-0 result."""
    if stage == STAGE_NORMAL:
        return topk
    if stage == STAGE_NARROW:
        return max(topk // 2, 1)
    if stage == STAGE_MIN:
        return min(policy.degraded_topk, topk)
    raise ValueError(f"unknown degrade stage {stage}")


def pressure(n_queued: int, n_live_slots: int) -> float:
    """The instantaneous pressure signal: visible queue depth over live
    slot capacity.  1.0 means a full pool's worth of work is waiting;
    a healthy pool with an empty queue reads 0.0 regardless of
    occupancy (occupied slots are work in progress, not backlog)."""
    return n_queued / max(n_live_slots, 1)


def plan_stage(window: Sequence[float], policy: AdmissionPolicy,
               stage: int) -> int:
    """Windowed pressure -> next degrade stage.  Pure: every replica
    appends the identical per-step pressure to its local window mirror
    (derived state, like the compaction plan — never transported) and
    steps the ladder identically.

    The ladder moves at most ONE stage per tick: escalation when the
    window average crosses the stage's threshold, restoration only once
    it falls to ``restore_below`` (hysteresis).  The window must be full
    before the first escalation so a single-arrival blip can't degrade
    the pool."""
    if policy.max_stage == 0:
        return 0
    if len(window) < policy.pressure_window:
        return stage
    recent = list(window)[-policy.pressure_window:]
    avg = sum(recent) / len(recent)
    if avg >= policy.degrade_hi:
        target = 2
    elif avg >= policy.degrade_lo:
        target = 1
    else:
        target = 0
    target = min(target, policy.max_stage)
    if target > stage:
        return stage + 1
    if target < stage and avg <= policy.restore_below:
        return stage - 1
    return stage


def slo_attainment(n_completed: int, n_total: int) -> float:
    """Fraction of offered requests that completed (the rest were shed
    or rejected).  With deterministic scheduling this is a pure function
    of (seed, topology, failplan) — the drills pin it."""
    return n_completed / max(n_total, 1)
