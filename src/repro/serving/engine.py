"""Slot-based continuous-batching serving engine.

The PR-1 kernel work made one decode step cheap (fused Bloom decode-topk,
no (B, d) score matrix in HBM); this module makes a *system* out of it:

  * a preallocated per-slot cache pool (``init_lm_cache`` at ``n_slots`` x
    ``max_len``), with prefill caches written into a freed slot via
    ``steps.insert_cache_slot`` (lax.dynamic_update_slice — the
    generalization of the old serve.py ``pad_caches_to``);
  * ONE jitted decode step for the whole pool: a per-slot position vector
    lets every slot sit at its own sequence offset, so admitting a request
    mid-flight never recompiles (models/attention.decode_self_attention
    handles scalar and (B,) pos);
  * host-side admission/retirement per step (serving/scheduler.py): freed
    slots are refilled from the queue every decode step, per-slot stop
    conditions (max_gen / EOS id) retire them;
  * device-resident slot state: (tokens, pos, active) stay on device for
    the whole run and advance from the decode step's own outputs; the
    host writes them only on admit/retire events instead of re-uploading
    all three every decode step (the one d2h transfer left in the
    steady-state loop is the new-token download the scheduler needs);
  * per-row math is *bit-identical* to the static path — a request served
    through the pool produces exactly the tokens it produces alone
    (asserted by tests/test_serving.py), because every decode op is
    row-independent and the masked slot cache write stores the same values
    as the static dynamic-slice write.

``Engine.run_static`` is the A/B baseline: classic static batching over
the same jitted steps — groups of n_slots start together and drain until
the longest request finishes, burning slot-steps on retired slots.  The
decode-step/slot-utilization gap between the two is what
benchmarks/bench_serving.py commits to BENCH_serving.json.

Time is counted in decode steps (deterministic on CPU CI); wall-clock is
recorded but never asserted on.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.launch import steps as steps_lib
from repro.models import io as io_lib
from repro.models import transformer as tf
from repro.serving import admission as admission_lib
from repro.serving.admission import AdmissionPolicy
from repro.serving.failpoints import FailPlan, PREFILL_MAX_ATTEMPTS
from repro.serving.scheduler import (Request, RequestQueue, Scheduler,
                                     ServeStats)


class PrefillFault(RuntimeError):
    """Injected prefill failure (FailPlan ``fail_prefill``) — raised at
    the same point a real worker crash would surface."""


def assert_request_fits(req: Request, max_len: int) -> None:
    """The one pool-capacity precondition, shared by every admission path
    (continuous, static, sharded)."""
    assert req.prompt_len + req.max_gen <= max_len, (
        f"request {req.rid}: prompt {req.prompt_len} + max_gen "
        f"{req.max_gen} exceeds pool max_len {max_len}")


def assert_kind(requests, kind: str, engine: str) -> None:
    """Engines serve exactly one request kind; a mixed workload is a
    routing bug upstream, not something to half-serve."""
    for r in requests:
        if r.kind != kind:
            raise NotImplementedError(
                f"request {r.rid}: kind={r.kind!r} — {engine} serves "
                f"kind={kind!r} only; oneshot retrieval requests go "
                "through serving/retrieval.RetrievalEngine and LM "
                "requests through serving/engine.Engine (DESIGN.md §11)")


class SlotProgram:
    """Arch-agnostic per-slot program: WHAT one slot computes, decoupled
    from WHEN the engine/scheduler runs it (the ROADMAP "continuous
    batching for every architecture" refactor; DESIGN.md §11–12).

    The protocol has two halves:

      * **prefill half** — ``prefill`` turns a request into the payload
        its slot will hold: (caches, first_token) for the autoregressive
        LM program below, a (m,) logits row (and no first token) for the
        one-shot retrieval program in serving/retrieval.py.  This is the
        half ``PrefillWorker``/``PrefillPool`` run, possibly on their own
        mesh slice — a prefill-only program never builds decode state.
      * **decode half** — the program OWNS its slot-pool state and the
        jitted callables that advance it.  ``init_state`` allocates the
        device-resident pool; ``insert`` consumes a prefill payload into
        a slot (returning whether the slot went live); ``step`` runs ONE
        jitted decode over the whole pool and returns host-side outputs;
        ``emit`` writes one slot's outputs into its request (returning
        whether the slot retires).  ``run_slot_loop`` below drives any
        program through the Scheduler/RequestQueue machinery — the LM
        engine and the retrieval engine are the same loop with a
        different program plugged in.

    ``kind`` names the Request.kind the program serves; ``oneshot``
    programs take exactly one recover step after prefill and retire.
    """

    kind = "lm"
    oneshot = False
    engine_label = "a slot-program engine"

    # -- prefill half --------------------------------------------------
    def prefill(self, params, req: Request, device=None):
        raise NotImplementedError

    # -- decode half ---------------------------------------------------
    def check_admit(self, req: Request) -> None:
        """Per-request capacity precondition, asserted at admission."""
        raise NotImplementedError

    def init_state(self, n_slots: int):
        """Allocate the program's device-resident slot-pool state."""
        raise NotImplementedError

    def reset_slots(self, state) -> None:
        """Reset per-slot occupancy for a fresh static group (persistent
        pool buffers survive; only the who-is-live state clears)."""
        raise NotImplementedError

    def insert(self, state, req: Request, payload, stats: ServeStats
               ) -> bool:
        """Consume ``payload`` (what ``prefill`` emitted) into
        ``req.slot``; record any prefill-time output on the request.
        Returns True if the slot is now live (needs decode steps),
        False if the request finished at prefill time."""
        raise NotImplementedError

    def step(self, params, state):
        """ONE jitted decode step over the whole pool; advances
        ``state`` in place and returns host-side outputs for ``emit``."""
        raise NotImplementedError

    def emit(self, state, req: Request, slot: int, out,
             stats: ServeStats) -> bool:
        """Write slot ``slot``'s share of ``out`` into ``req``.
        Returns True if the slot retires (the loop releases it)."""
        raise NotImplementedError

    def set_stage(self, stage: int) -> None:
        """Degrade-ladder hook (DESIGN.md §14): swap to ``stage``'s
        PRE-BUILT decode callable — a jit swap, never a compile.
        Programs built without an ``admission_policy`` serve stage 0
        only; asking them to degrade is a wiring bug, not a fallback."""
        if stage != admission_lib.STAGE_NORMAL:
            raise RuntimeError(
                f"{self.engine_label} was built without an "
                f"admission_policy — degrade stage {stage} has no "
                "pre-built decode callable (DESIGN.md §14: stage jits "
                "are constructed up front so a transition never "
                "compiles)")


def build_stage_decodes(stage0, topk: int,
                        policy: Optional[AdmissionPolicy], make):
    """stage -> PRE-BUILT jitted decode callable, shared by the LM,
    sharded and retrieval programs (DESIGN.md §14).

    ``stage0`` is the already-built full-width jit; ``make(k)`` builds
    (but does not compile — jax.jit is lazy) the width-``k`` variant.
    Stages whose ``admission.stage_topk`` width equals an already-built
    stage share its jit object, so cache-size accounting stays exact:
    every distinct executable in the ladder compiles at most once, and a
    DEGRADE/RESTORE transition is a dict lookup."""
    stages = {admission_lib.STAGE_NORMAL: stage0}
    if policy is None:
        return stages
    by_width = {topk: stage0}
    for st in range(1, policy.max_stage + 1):
        k = admission_lib.stage_topk(topk, st, policy)
        if k not in by_width:
            by_width[k] = make(k)
        stages[st] = by_width[k]
    return stages


@dataclasses.dataclass
class _LMState:
    """Device-resident LM slot-pool state: the KV-cache pool plus the
    (tokens, pos, active) slot vectors that stay on device for the whole
    run (host writes only on admit/retire events — see module doc)."""
    caches: object
    tokens: object
    pos: object
    active: object


class LMSlotProgram(SlotProgram):
    """The autoregressive token-LM program: jitted prefill + first-token
    Eq. 3 recovery, and (when constructed with ``max_len``) the decode
    half — slot KV-cache pool, one jitted pool-decode step, device-side
    (tokens, pos, active) advance.  Prefill is always B=1 at the exact
    prompt length — bit-identical to serving the request alone.

    A prefill-only instance (``PrefillWorker``'s default; the sharded
    engine's disaggregated prefill slice) omits ``max_len`` and never
    builds the decode-side jits or the pool template."""

    kind = "lm"
    oneshot = False
    engine_label = "the token-LM engine"

    def __init__(self, cfg: ModelConfig, *, topk: int, dist=None,
                 n_slots: Optional[int] = None,
                 max_len: Optional[int] = None,
                 eos_id: Optional[int] = None,
                 admission_policy: Optional[AdmissionPolicy] = None):
        self.cfg = cfg
        self.topk = topk
        self.n_slots = n_slots
        self.max_len = max_len
        self.eos_id = eos_id
        self._prefill = jax.jit(steps_lib.make_prefill_step(cfg, dist))
        self._recover = jax.jit(
            lambda logits: io_lib.recover_topk(cfg, logits, topk=topk))
        if max_len is None:
            return                      # prefill-only program
        assert n_slots is not None and n_slots >= 1 and max_len >= 2
        # the pool is donated through every decode/insert: the loop
        # never reuses the previous tree, so XLA (where supported)
        # updates the multi-GB cache in place instead of allocating a
        # second pool and copying per step
        self._decode = jax.jit(steps_lib.make_slot_decode_step(
            cfg, topk=topk, dist=dist), donate_argnums=(2,))
        # degrade ladder (DESIGN.md §14): one pre-built decode jit per
        # stage width; a DEGRADE/RESTORE swaps the dict entry in use.
        # Narrowing the served top-k never changes the emitted token —
        # the next token is the top-1 id, invariant under k.
        self._stage = admission_lib.STAGE_NORMAL
        self._stage_decodes = build_stage_decodes(
            self._decode, topk, admission_policy,
            lambda k: jax.jit(steps_lib.make_slot_decode_step(
                cfg, topk=k, dist=dist), donate_argnums=(2,)))
        self._insert = jax.jit(steps_lib.insert_cache_slot,
                               donate_argnums=(0,))
        self._pool_template = tf.init_lm_cache(
            cfg, n_slots, max_len, dtype=jnp.dtype(cfg.dtype))
        # (tokens, pos, active) live ON DEVICE for the whole run: the
        # old loop rebuilt them host-side and re-uploaded all three
        # every decode step (3 h2d transfers per token).  Steady-state
        # decode advances them from the step's own outputs (_advance —
        # next token and pos+1 for every slot that decoded, exactly
        # what the host wrote back); the host touches them only on
        # admit (_set_slot) and retire (_drop_slot) events.  Values are
        # bit-identical to the host-side bookkeeping, so tokens are too.
        self._advance = jax.jit(
            lambda ids, tokens, pos, active: (
                jnp.where(active[:, None], ids[:, :1], tokens),
                pos + active.astype(pos.dtype)),
            donate_argnums=(1, 2))
        self._set_slot = jax.jit(
            lambda tokens, pos, active, slot, tok, p: (
                tokens.at[slot, 0].set(tok), pos.at[slot].set(p),
                active.at[slot].set(True)),
            donate_argnums=(0, 1, 2))
        self._drop_slot = jax.jit(lambda active, slot:
                                  active.at[slot].set(False),
                                  donate_argnums=(0,))

    # -- prefill half --------------------------------------------------
    def prefill(self, params, req: Request, device=None):
        """req -> (caches at prompt length, greedy first token id)."""
        prompt = jnp.asarray(req.prompt, jnp.int32)[None, :]
        if device is not None:
            prompt = jax.device_put(prompt, device)
        pre = self._prefill(params, {"tokens": prompt})
        _, ids = self._recover(pre["last_logits"])
        return pre["caches"], int(np.asarray(ids)[0, 0])

    # -- decode half ---------------------------------------------------
    def check_admit(self, req: Request) -> None:
        assert_request_fits(req, self.max_len)

    def stopped(self, req: Request, tok: int) -> bool:
        if self.eos_id is not None and tok == self.eos_id:
            return True
        return len(req.tokens) >= req.max_gen

    def init_state(self, n_slots: int) -> _LMState:
        assert n_slots == self.n_slots
        # copy, not alias: the first donated insert/decode consumes its
        # input buffers, and the template must survive across runs
        return _LMState(
            caches=jax.tree.map(jnp.copy, self._pool_template),
            tokens=jnp.zeros((self.n_slots, 1), jnp.int32),
            pos=jnp.zeros((self.n_slots,), jnp.int32),
            active=jnp.zeros((self.n_slots,), bool))

    def reset_slots(self, state: _LMState) -> None:
        state.tokens = jnp.zeros((self.n_slots, 1), jnp.int32)
        state.pos = jnp.zeros((self.n_slots,), jnp.int32)
        state.active = jnp.zeros((self.n_slots,), bool)

    def insert(self, state: _LMState, req: Request, payload,
               stats: ServeStats) -> bool:
        small, first = payload
        state.caches = self._insert(state.caches, small,
                                    jnp.int32(req.slot))
        req.tokens.append(first)
        stats.tokens_out += 1
        if self.stopped(req, first):
            return False
        # admit event: the only h2d update of the slot state
        state.tokens, state.pos, state.active = self._set_slot(
            state.tokens, state.pos, state.active, jnp.int32(req.slot),
            jnp.int32(first), jnp.int32(req.prompt_len))
        return True

    def set_stage(self, stage: int) -> None:
        if stage not in self._stage_decodes:
            raise RuntimeError(
                f"{self.engine_label}: degrade stage {stage} was not "
                "pre-built — construct the program with the run's "
                "admission_policy (DESIGN.md §14)")
        self._stage = stage

    def step(self, params, state: _LMState):
        out = self._stage_decodes[self._stage](
            params, state.tokens, state.caches, state.pos, state.active)
        state.caches = out["caches"]
        # steady-state decode: tokens/pos advance on device from the
        # step's own outputs — no host round-trip re-upload.  The d2h
        # token download below is irreducible (the scheduler decides
        # retirement host-side).  The [:, :1] slice happens OUTSIDE
        # _advance so a degraded stage's narrower top-k never re-traces
        # it (the jit always sees a (B, 1) operand).
        state.tokens, state.pos = self._advance(
            out["topk_ids"][:, :1], state.tokens, state.pos, state.active)
        return np.asarray(out["topk_ids"][:, 0])

    def emit(self, state: _LMState, req: Request, slot: int, out,
             stats: ServeStats) -> bool:
        tok = int(out[slot])
        req.tokens.append(tok)
        stats.tokens_out += 1
        if self.stopped(req, tok):
            state.active = self._drop_slot(state.active, jnp.int32(slot))
            return True
        return False


class PrefillWorker:
    """Disaggregated prefill: owns a ``SlotProgram``'s jitted callables,
    optionally pinned to a dedicated device (a 1-device mesh slice of
    the serving topology — DESIGN.md §8).

    The worker emits whatever its program's prefill emits — (caches,
    first_token) for the LM program (default), (logits_row, None) for
    the one-shot retrieval program; the caller inserts the payload into
    its decode pool (for the sharded pool that insert is the
    device-to-device transfer out of the prefill slice).  Splitting
    prefill out of the engine is what lets the sharded engine place it
    on its own slice while the decode pool spans the data axis; the
    single-host engines use the same worker unpinned, so both paths run
    the very same jitted callables.
    """

    def __init__(self, cfg: Optional[ModelConfig], params, *, topk: int,
                 dist=None, device=None,
                 program: Optional[SlotProgram] = None):
        self.device = device
        if device is not None:
            params = jax.device_put(params, device)
        self.params = params
        self.program = (program if program is not None
                        else LMSlotProgram(cfg, topk=topk, dist=dist))

    def prefill(self, req: Request):
        """req -> the program's slot payload (see class doc)."""
        return self.program.prefill(self.params, req, device=self.device)


class PrefillPool:
    """Prefill *pool*: a FIFO scheduler over N single-slice
    ``PrefillWorker``s (DESIGN.md §9, ROADMAP follow-up b).

    A burst of same-step arrivals used to serialize on the single prefill
    worker — the whole burst head-of-line blocked admission for the
    duration of N prefills.  The pool dispatches queued jobs FIFO to the
    earliest-available worker (a deterministic virtual-time model: each
    worker's clock advances by the job's prompt length), so with W
    workers a burst drains ~W-times faster in prefill-time while the
    step-clock schedule — and therefore every committed bench row and
    every recovered token — is unchanged for ANY W (prefill is B=1
    exact-length on identical replicated weights on every worker; the
    dispatch order is the admission order).

    In this single-process simulation jobs still *execute* sequentially;
    ``stats`` records the dispatch the pool would overlap — per-worker
    job counts, max queue depth, and the summed virtual queue wait
    (``wait_units``, in prompt-length units) that tests assert shrinks as
    workers are added.  A real deployment runs each worker's jitted
    callables on its own mesh slice asynchronously.

    A worker raising mid-prefill no longer loses the request (it used to
    escape the pool and strand the slot): the job retries on the next
    worker, up to ``PREFILL_MAX_ATTEMPTS`` attempts, then surfaces as a
    ``None`` result — the scheduler turns that into a REJECT event
    instead of hanging.  Injected faults (``FailPlan.fail_prefill``)
    raise at the same point a real crash would.
    """

    def __init__(self, cfg: Optional[ModelConfig], params, *, topk: int,
                 n_workers: int = 1, devices=None, dist=None,
                 failpoints: Optional[FailPlan] = None,
                 program: Optional[SlotProgram] = None):
        assert n_workers >= 1
        if devices is None:
            devices = [None]
        # one PrefillWorker (and thus one set of jitted callables) per
        # DISTINCT device: pool slots landing on the same device share
        # it, so a same-device pool never re-traces the prefill step.
        # A shared `program` (the retrieval path) keeps one set of jitted
        # callables for the whole pool — jit re-specializes per device
        # placement on its own.
        by_device = {}
        self.workers = []
        for i in range(n_workers):
            dev = devices[i % len(devices)]
            if dev not in by_device:
                by_device[dev] = PrefillWorker(cfg, params, topk=topk,
                                               dist=dist, device=dev,
                                               program=program)
            self.workers.append(by_device[dev])
        self.n_workers = n_workers
        self.failpoints = failpoints if failpoints else None
        self._fifo: List[Request] = []
        self._busy = [0.0] * n_workers     # virtual per-worker clock
        self.stats = {"jobs": 0, "max_queue_depth": 0, "wait_units": 0.0,
                      "per_worker": [0] * n_workers, "retries": 0,
                      "rejects": 0}

    def submit(self, req: Request) -> None:
        self._fifo.append(req)
        self.stats["max_queue_depth"] = max(self.stats["max_queue_depth"],
                                            len(self._fifo))

    def _attempt(self, req: Request, w0: int,
                 base: float) -> Optional[Tuple[object, int]]:
        """Run ``req``'s prefill with retry-on-another-worker: attempt k
        lands on worker (w0 + k) % n_workers, so a crashed worker's jobs
        migrate off it.  Accounting (virtual clocks, per-worker counts)
        records only the attempt that completed — the failure-free path
        is step-for-step identical to the pre-retry pool.  Returns None
        once the attempt cap is exhausted (the REJECT path)."""
        for attempt in range(PREFILL_MAX_ATTEMPTS):
            w = (w0 + attempt) % self.n_workers
            try:
                if (self.failpoints is not None
                        and self.failpoints.prefill_attempt_fails(
                            req.rid, attempt)):
                    raise PrefillFault(
                        f"injected prefill fault: rid {req.rid} "
                        f"attempt {attempt} on worker {w}")
                res = self.workers[w].prefill(req)
            except Exception:
                self.stats["retries"] += 1
                continue
            self.stats["wait_units"] += self._busy[w] - base
            self._busy[w] += float(req.prompt_len)
            self.stats["per_worker"][w] += 1
            self.stats["jobs"] += 1
            return res
        self.stats["rejects"] += 1
        return None

    def drain(self) -> List[Optional[Tuple[object, int]]]:
        """Dispatch every queued job FIFO to the earliest-available
        worker; returns (caches, first_token) per job in submit order —
        None for a job whose every attempt failed."""
        out = []
        base = max(self._busy) if self._fifo else 0.0
        # a fresh burst starts all workers at the same origin: only the
        # waits created by THIS burst count
        self._busy = [base] * self.n_workers
        for req in self._fifo:
            w = min(range(self.n_workers), key=lambda i: (self._busy[i], i))
            out.append(self._attempt(req, w, base))
        self._fifo = []
        return out

    def prefill_all(self, reqs: List[Request]
                    ) -> List[Optional[Tuple[object, int]]]:
        for r in reqs:
            self.submit(r)
        return self.drain()


def run_slot_loop(program: SlotProgram, params, prefill_pool: PrefillPool,
                  requests: List[Request], n_slots: int,
                  state=None, failpoints: Optional[FailPlan] = None,
                  admission_policy: Optional[AdmissionPolicy] = None,
                  ) -> Tuple[Dict[int, Request], ServeStats,
                             Scheduler, object]:
    """THE continuous-batching serve loop, generic over a SlotProgram.

    Admission, prefill dispatch, rejection, per-step stats, clock
    fast-forward and retirement are identical for every program; what a
    slot holds (KV caches vs a logits row), what a decode step computes,
    and what retires a slot (stop condition vs oneshot) live in the
    program.  The LM engine's ``run`` and the retrieval engine's ``run``
    are both thin wrappers over this function — tokens and top-k ids are
    bit-identical to the pre-refactor per-engine loops (asserted by
    tests/test_serving.py + tests/test_retrieval.py and the
    BENCH_serving.json --check gate).

    ``failpoints`` injects overload (DESIGN.md §14) exactly as the
    sharded path does: ``surge:R@S`` compresses the queue's arrival
    clock, ``slow_decode:N@S`` makes each decode step cost N clock
    ticks.  ``admission_policy`` enables the overload pass — shed
    expired / over-bound queued requests, then step the degrade ladder
    — evaluated once per clock tick BEFORE admission, identical in shape
    to ``ShardedScheduler._apply_policy``.  Because this loop serves any
    SlotProgram, the policy lands on the LM and retrieval engines at
    once.

    Mutates and returns the requests; also returns the Scheduler (slot
    event log) and the program state (e.g. the retrieval program's
    accumulated modeled bytes).
    """
    assert_kind(requests, program.kind, program.engine_label)
    fp = failpoints if failpoints else None
    queue = RequestQueue(
        requests,
        arrival_key=(None if fp is None else
                     (lambda r: fp.effective_arrival(r.arrival_step))))
    sched = Scheduler(n_slots)
    stats = ServeStats()
    policy = admission_policy
    window = (deque(maxlen=policy.pressure_window)
              if policy is not None else None)
    stage = admission_lib.STAGE_NORMAL
    policy_stepped = -1
    if state is None:
        state = program.init_state(n_slots)
    now = 0
    t0 = time.perf_counter()

    while len(queue) or sched.n_active:
        if policy is not None and policy_stepped != now:
            # the overload pass, once per clock tick: sheds first, so
            # the pressure sample reflects the bounded queue
            policy_stepped = now
            visible = queue.visible(now)
            sheds = admission_lib.compute_sheds(
                {r.rid: (queue.arrival_of(r), r.home) for r in visible},
                {r.rid: r.deadline_step for r in visible}, now, policy)
            if sheds:
                reasons = dict(sheds)
                for req in queue.remove([rid for rid, _ in sheds]):
                    req.shed = True
                    req.finish_step = now
                    sched.log.shed(now, req.rid, reasons[req.rid],
                                   req.home)
                    stats.sheds += 1
            window.append(admission_lib.pressure(
                len(queue.visible(now)), n_slots))
            new = admission_lib.plan_stage(window, policy, stage)
            if new != stage:
                sched.log.degrade(now, stage, new)
                stats.degrades += 1
                program.set_stage(new)
                stage = new
        admitted = sched.admit(queue, now)
        for req in admitted:
            program.check_admit(req)
        # the whole admission burst goes through the prefill pool at
        # once: FIFO dispatch over the workers, results in admission
        # order (token- and schedule-identical for any worker count)
        prefilled = (prefill_pool.prefill_all(admitted)
                     if admitted else [])
        for req, res in zip(admitted, prefilled):
            if res is None:
                # every prefill attempt failed: REJECT — free the slot
                # instead of hanging the pool on a request that can
                # never start
                stats.rejects += 1
                sched.reject(req.slot, now)
                continue
            stats.prefills += 1
            if not program.insert(state, req, res, stats):
                # prefill-time retirement (max_gen==1 / first-token EOS)
                sched.release(req.slot, now)

        if not sched.n_active:
            nxt = queue.next_arrival()
            if nxt is None:
                break
            if nxt <= now:
                # a slot was freed at `now` (prefill-time retirement or
                # reject) while a request is already ready: re-admit
                # NOW, no clock tick
                continue
            # empty pool: fast-forward the clock to the next arrival
            stats.idle_steps += nxt - now
            now = nxt
            continue

        out = program.step(params, state)
        stats.decode_steps += 1
        stats.slot_steps_total += n_slots
        stats.slot_steps_active += sched.n_active
        # an injected slow_decode makes each decode step cost N clock
        # ticks — arrivals pile up, driving the pressure signal
        now += fp.decode_cost(now) if fp is not None else 1
        for slot, req in list(sched.active.items()):
            if program.emit(state, req, slot, out, stats):
                sched.release(slot, now)

    if stage != admission_lib.STAGE_NORMAL:
        # post-run data-plane reset (like reset_slots): the program is
        # reused across runs and must start the next one undegraded
        program.set_stage(admission_lib.STAGE_NORMAL)
    stats.wall_s = time.perf_counter() - t0
    return {r.rid: r for r in requests}, stats, sched, state


class Engine:
    """Continuous-batching engine over a fixed slot pool.

    One Engine owns ONE ``LMSlotProgram`` — the jitted prefill /
    slot-decode / cache-insert callables and the preallocated pool
    template; ``run`` (continuous, via ``run_slot_loop``) and
    ``run_static`` (A/B baseline) share them, so any numeric difference
    between the two paths would be a scheduling bug, not a compile
    difference.
    """

    @staticmethod
    def supports(cfg: ModelConfig) -> bool:
        """Continuous batching serves decoder-only token LMs; enc-dec
        (audio) and frontend-stub (vlm) archs carry non-token prefill
        inputs the engine does not schedule — they serve via the static
        launch/serve.py path.  Single source for the eligibility rule
        (the CLI checks it before paying for param init)."""
        return cfg.family != "audio" and cfg.frontend == "none"

    def __init__(self, cfg: ModelConfig, params, *, n_slots: int,
                 max_len: int, topk: int = 8,
                 eos_id: Optional[int] = None, dist=None,
                 prefill_workers: int = 1,
                 failpoints: Optional[FailPlan] = None,
                 admission_policy: Optional[AdmissionPolicy] = None):
        if not Engine.supports(cfg):
            raise NotImplementedError(
                f"{cfg.name}: continuous batching serves decoder-only "
                "token LMs (see Engine.supports); use the static "
                "launch/serve.py path")
        assert n_slots >= 1 and max_len >= 2
        self.cfg = cfg
        self.params = params
        self.n_slots = n_slots
        self.max_len = max_len
        self.topk = topk
        self.eos_id = eos_id
        self.failpoints = failpoints if failpoints else None
        self.policy = admission_policy
        self.program = LMSlotProgram(cfg, topk=topk, dist=dist,
                                     n_slots=n_slots, max_len=max_len,
                                     eos_id=eos_id,
                                     admission_policy=admission_policy)
        # the pool shares the engine's program: one set of jitted
        # prefill callables for prefill AND admission (jit
        # re-specializes per device placement on its own)
        self.prefill_pool = PrefillPool(cfg, params, topk=topk, dist=dist,
                                        n_workers=prefill_workers,
                                        failpoints=self.failpoints,
                                        program=self.program)

    def _stopped(self, req: Request, tok: int) -> bool:
        return self.program.stopped(req, tok)

    # ------------------------------------------------------------------
    def run(self, requests: List[Request]
            ) -> Tuple[Dict[int, Request], ServeStats]:
        """Continuous batching: admit into freed slots every step, retire
        on per-slot stop conditions.  Mutates and returns the requests."""
        results, stats, sched, _ = run_slot_loop(
            self.program, self.params, self.prefill_pool, requests,
            self.n_slots, failpoints=self.failpoints,
            admission_policy=self.policy)
        self._sched = sched          # exposed for the simulation tests
        return results, stats

    # ------------------------------------------------------------------
    def run_static(self, requests: List[Request]
                   ) -> Tuple[Dict[int, Request], ServeStats]:
        """Static-batching A/B baseline over the SAME jitted steps.

        Requests are grouped n_slots at a time in arrival order; a group
        starts only when its last member has arrived and drains until its
        longest request stops — retired slots keep burning decode steps,
        which is exactly the utilization gap continuous batching closes.
        """
        assert_kind(requests, "lm", "the token-LM engine")
        prog = self.program
        stats = ServeStats()
        reqs = sorted(requests, key=lambda r: (r.arrival_step, r.rid))
        state = prog.init_state(self.n_slots)
        now = 0
        t0 = time.perf_counter()

        for g in range(0, len(reqs), self.n_slots):
            group = reqs[g:g + self.n_slots]
            start = max([now] + [r.arrival_step for r in group])
            stats.idle_steps += start - now
            now = start

            prog.reset_slots(state)
            # host-side mirror of the active mask — scheduling decisions
            # (group drained? which slots still collect?) stay host-side;
            # the device mask is only written on admit/retire events
            collecting = np.zeros((self.n_slots,), bool)
            for slot, req in enumerate(group):
                req.slot = slot
                req.admitted_step = now
                prog.check_admit(req)
                res, = self.prefill_pool.prefill_all([req])
                assert res is not None, (
                    f"request {req.rid}: prefill permanently failed on "
                    "the static path (no REJECT protocol there — serve "
                    "it via the continuous engine)")
                stats.prefills += 1
                if prog.insert(state, req, res, stats):
                    collecting[slot] = True
                else:
                    req.finish_step = now

            while collecting.any():
                out = prog.step(self.params, state)
                stats.decode_steps += 1
                # static batching burns every slot of the pool per step
                stats.slot_steps_total += self.n_slots
                stats.slot_steps_active += int(collecting.sum())
                now += 1
                for slot, req in enumerate(group):
                    if not collecting[slot]:
                        continue
                    if prog.emit(state, req, slot, out, stats):
                        req.finish_step = now
                        collecting[slot] = False

        stats.wall_s = time.perf_counter() - t0
        return {r.rid: r for r in requests}, stats


def mean_latency(results: Dict[int, Request]) -> float:
    """Mean (finish - arrival) in decode steps across completed requests.
    Shed requests are terminal but never served — no latency to count."""
    done = [r for r in results.values() if r.done and not r.shed]
    if not done:
        return 0.0
    return float(np.mean([r.finish_step - r.arrival_step for r in done]))
