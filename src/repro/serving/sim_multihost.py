"""Multi-host serving simulation driver (runs in its OWN process).

Forces an 8-device CPU topology via XLA_FLAGS *before* jax initializes —
that is why this module must run as ``__main__`` in a fresh process (the
test suite's parent process must keep seeing 1 CPU device, see
tests/conftest.py) — then serves the same seeded per-host workload
through the full control/data-plane matrix (DESIGN.md §9) and dumps
everything a verdict needs as JSON:

  * ``runs`` — ONE ShardedEngine (single jitted decode step, prefill pool
    of 2 mesh-slice workers) driven through
    {sim, collective} transports x {no-compaction, compaction}: the
    collective runs exchange deltas over a REAL device all_gather on the
    8-device topology, and the compaction runs remap the sharded cache
    pytree mid-flight;
  * ``single`` — the PR-2 single-host Engine over the merged workload;
  * ``solo``   — each request alone through static serving (the paper's
    Fig. 3 serving path, the ground truth everything must match
    BIT-identically);
  * ``sims``   — the model-free ``simulate_sharded_schedule`` replays
    (per compaction setting): the engine logs must equal them
    integer-for-integer, COMPACT events included.

Also recorded: per-host event logs (linearization), the decode-step
compile count across the WHOLE matrix (the single-compiled-step
invariant must survive transports and compaction), and the prefill
pool's dispatch stats.

Usage:  python -m repro.serving.sim_multihost --out report.json
"""
from __future__ import annotations

import os

os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=8")
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import argparse
import json

import jax

from repro import configs
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_serving_mesh
from repro.serving import (Engine, LoadSpec, ShardedEngine,
                           merge_workloads, sharded_workload,
                           simulate_sharded_schedule)

ARCH = "qwen1.5-0.5b"
N_HOSTS = 8
SLOTS_PER_HOST = 2        # >= 2 so per-host fragmentation can occur
MAX_LEN = 40
TOPK = 4
GOSSIP_DELAY = 1
PREFILL_WORKERS = 2
COMPACT_THRESHOLD = 0.25  # frag 0.5 (1 hole of 2 slots) crosses it


def _log_of(sched) -> dict:
    return {
        "admissions": sched.admissions,
        "releases": sched.releases,
        "compactions": [(step, list(perm), seq)
                        for step, perm, seq in sched.compactions],
        "per_host": [{"admissions": h.admissions,
                      "releases": h.releases,
                      "compactions": [(s, list(p), q)
                                      for s, p, q in h.compactions]}
                     for h in sched.hosts],
    }


def run(seed: int = 0) -> dict:
    cfg = configs.get_smoke_config(ARCH)
    params = steps_lib.cast_params_for_compute(
        steps_lib.init_fn_for(cfg)(jax.random.PRNGKey(0)), cfg)
    # two requests per host per stream keeps the sim fast on CPU CI while
    # still exercising cross-host admission, mid-flight churn, and enough
    # slot fragmentation for the compaction runs to actually compact
    spec = LoadSpec(n_requests=2, vocab=cfg.vocab, rate=1.0,
                    prompt_lens=(6, 10), gen_lens=(3, 6, 12), seed=seed)

    mesh = make_serving_mesh()
    engine = ShardedEngine(cfg, params, mesh=mesh,
                           slots_per_host=SLOTS_PER_HOST, max_len=MAX_LEN,
                           topk=TOPK, gossip_delay=GOSSIP_DELAY,
                           prefill_workers=PREFILL_WORKERS)

    runs = {}
    for tname in ("sim", "collective"):
        for cname, thresh in (("plain", None),
                              ("compact", COMPACT_THRESHOLD)):
            res, stats = engine.run(sharded_workload(spec, N_HOSTS),
                                    transport=tname,
                                    compact_threshold=thresh)
            runs[f"{tname}_{cname}"] = {
                "tokens": {r.rid: r.tokens for r in res.values()},
                "done": {rid: r.done for rid, r in res.items()},
                "stats": stats.as_row(),
                "log": _log_of(engine._sched),
            }

    sims = {}
    for cname, thresh in (("plain", None), ("compact", COMPACT_THRESHOLD)):
        sim_sched, sim_stats = simulate_sharded_schedule(
            sharded_workload(spec, N_HOSTS), SLOTS_PER_HOST, GOSSIP_DELAY,
            compact_threshold=thresh)
        sims[cname] = {"stats": sim_stats.as_row(),
                       "log": _log_of(sim_sched)}

    single = Engine(cfg, params, n_slots=N_HOSTS * SLOTS_PER_HOST,
                    max_len=MAX_LEN, topk=TOPK)
    single_res, single_stats = single.run(
        merge_workloads(sharded_workload(spec, N_HOSTS)))

    solo = Engine(cfg, params, n_slots=1, max_len=MAX_LEN, topk=TOPK)
    solo_tokens = {}
    for reqs in sharded_workload(spec, N_HOSTS):
        for req in reqs:
            req.arrival_step = 0
            r, _ = solo.run_static([req])
            solo_tokens[req.rid] = r[req.rid].tokens

    return {
        "n_devices": jax.device_count(),
        "n_hosts": N_HOSTS,
        "slots_per_host": SLOTS_PER_HOST,
        "gossip_delay": GOSSIP_DELAY,
        "compact_threshold": COMPACT_THRESHOLD,
        "prefill_workers": PREFILL_WORKERS,
        # compile count across the ENTIRE matrix: 4 engine runs through
        # both transports, with and without mid-flight cache remaps
        "decode_compiles": engine._decode._cache_size(),
        "prefill_stats": engine.prefill_pool.stats,
        "runs": runs,
        "sims": sims,
        "single": {"tokens": {r.rid: r.tokens
                              for r in single_res.values()},
                   "stats": single_stats.as_row()},
        "solo": solo_tokens,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", required=True, help="JSON report path")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    report = run(seed=args.seed)
    with open(args.out, "w") as f:
        json.dump(report, f)
    print("wrote", args.out)


if __name__ == "__main__":
    main()
