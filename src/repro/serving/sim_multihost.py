"""Multi-host serving simulation driver (runs in its OWN process).

Forces an 8-device CPU topology via XLA_FLAGS *before* jax initializes —
that is why this module must run as ``__main__`` in a fresh process (the
test suite's parent process must keep seeing 1 CPU device, see
tests/conftest.py) — then serves the same seeded per-host workload three
ways and dumps everything a verdict needs as JSON:

  * ``sharded``  — ShardedEngine: data-axis-sharded slot pool, gossiped
    admission, disaggregated prefill (DESIGN.md §8);
  * ``single``   — the PR-2 single-host Engine over the merged workload;
  * ``solo``     — each request alone through static serving (the paper's
    Fig. 3 serving path, the ground truth the other two must match
    BIT-identically).

Also recorded: the sharded scheduler's merged + per-host event logs, the
model-free ``simulate_sharded_schedule`` replay of the same workload (the
engine log must equal it integer-for-integer), and the decode-step
compile count (the single-compiled-step invariant must survive sharding).

Usage:  python -m repro.serving.sim_multihost --out report.json
"""
from __future__ import annotations

import os

os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=8")
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import argparse
import json

import jax

from repro import configs
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_serving_mesh
from repro.serving import (Engine, LoadSpec, ShardedEngine,
                           merge_workloads, sharded_workload,
                           simulate_sharded_schedule)

ARCH = "qwen1.5-0.5b"
N_HOSTS = 8
SLOTS_PER_HOST = 1
MAX_LEN = 40
TOPK = 4
GOSSIP_DELAY = 1


def run(seed: int = 0) -> dict:
    cfg = configs.get_smoke_config(ARCH)
    params = steps_lib.cast_params_for_compute(
        steps_lib.init_fn_for(cfg)(jax.random.PRNGKey(0)), cfg)
    # one request per host per stream keeps the sim < ~1 min on CPU CI
    # while still exercising cross-host admission and mid-flight churn
    spec = LoadSpec(n_requests=1, vocab=cfg.vocab, rate=1.0,
                    prompt_lens=(6, 10), gen_lens=(3, 6, 12), seed=seed)

    mesh = make_serving_mesh()
    engine = ShardedEngine(cfg, params, mesh=mesh,
                           slots_per_host=SLOTS_PER_HOST, max_len=MAX_LEN,
                           topk=TOPK, gossip_delay=GOSSIP_DELAY)
    sharded_res, sharded_stats = engine.run(sharded_workload(spec, N_HOSTS))

    single = Engine(cfg, params, n_slots=N_HOSTS * SLOTS_PER_HOST,
                    max_len=MAX_LEN, topk=TOPK)
    single_res, single_stats = single.run(
        merge_workloads(sharded_workload(spec, N_HOSTS)))

    solo = Engine(cfg, params, n_slots=1, max_len=MAX_LEN, topk=TOPK)
    solo_tokens = {}
    for reqs in sharded_workload(spec, N_HOSTS):
        for req in reqs:
            req.arrival_step = 0
            r, _ = solo.run_static([req])
            solo_tokens[req.rid] = r[req.rid].tokens

    sim_sched, sim_stats = simulate_sharded_schedule(
        sharded_workload(spec, N_HOSTS), SLOTS_PER_HOST, GOSSIP_DELAY)

    sched = engine._sched
    return {
        "n_devices": jax.device_count(),
        "n_hosts": N_HOSTS,
        "slots_per_host": SLOTS_PER_HOST,
        "gossip_delay": GOSSIP_DELAY,
        "decode_compiles": engine._decode._cache_size(),
        "tokens": {
            "sharded": {r.rid: r.tokens for r in sharded_res.values()},
            "single": {r.rid: r.tokens for r in single_res.values()},
            "solo": solo_tokens,
        },
        "done": {rid: r.done for rid, r in sharded_res.items()},
        "stats": {"sharded": sharded_stats.as_row(),
                  "single": single_stats.as_row(),
                  "sim": sim_stats},
        "log": {
            "admissions": sched.admissions,
            "releases": sched.releases,
            "per_host": [{"admissions": h.admissions,
                          "releases": h.releases} for h in sched.hosts],
        },
        "sim_log": {"admissions": sim_sched.admissions,
                    "releases": sim_sched.releases},
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", required=True, help="JSON report path")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    report = run(seed=args.seed)
    with open(args.out, "w") as f:
        json.dump(report, f)
    print("wrote", args.out)


if __name__ == "__main__":
    main()
