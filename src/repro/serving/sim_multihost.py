"""Multi-host serving simulation driver (runs in its OWN process).

Forces an 8-device CPU topology via XLA_FLAGS *before* jax initializes —
that is why this module must run as ``__main__`` in a fresh process (the
test suite's parent process must keep seeing 1 CPU device, see
tests/conftest.py) — then serves the same seeded per-host workload
through the full control/data-plane matrix (DESIGN.md §9) and dumps
everything a verdict needs as JSON:

  * ``runs`` — ONE ShardedEngine (single jitted decode step, prefill pool
    of 2 mesh-slice workers) driven through
    {sim, collective} transports x {no-compaction, compaction}: the
    collective runs exchange deltas over a REAL device all_gather on the
    8-device topology, and the compaction runs remap the sharded cache
    pytree mid-flight;
  * ``single`` — the PR-2 single-host Engine over the merged workload;
  * ``solo``   — each request alone through static serving (the paper's
    Fig. 3 serving path, the ground truth everything must match
    BIT-identically);
  * ``sims``   — the model-free ``simulate_sharded_schedule`` replays
    (per compaction setting): the engine logs must equal them
    integer-for-integer, COMPACT events included.

Also recorded: per-host event logs (linearization), the decode-step
compile count across the WHOLE matrix (the single-compiled-step
invariant must survive transports and compaction), and the prefill
pool's dispatch stats.

``chaos`` — the host-failure recovery drill (DESIGN.md §10): a 4-host
mesh on the same 8-device topology serves the seeded workload while a
committed ``FailPlan`` kills one host mid-traffic.  Engine runs through
BOTH transports plus the model-free sim replay of the same plan, and
``_verify_chaos`` asserts *in this process* (so the CI chaos job fails
loudly, not just the pytest wrapper): every request completes, recovered
tokens are BIT-identical to the fault-free twin, re-admissions preserve
FIFO order, the engine log equals the sim log integer-for-integer
(RECLAIM / HOST_DOWN events included), the slot log replays soundly, the
drill actually requeued work (non-vacuous), and decode still compiled
exactly once across the fault-free + kill runs (the dead range is an
active-mask change, not a new executable).  ``--failpoints`` overrides
the committed schedule.

Usage:  python -m repro.serving.sim_multihost --out report.json
"""
from __future__ import annotations

import os

os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=8")
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import argparse
import json

import jax

from repro import configs
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_serving_mesh
from repro.serving import (AdmissionPolicy, Engine, FailPlan, LoadSpec,
                           ShardedEngine, merge_workloads,
                           overload_workload, sharded_workload,
                           simulate_sharded_schedule, slo_attainment)
from repro.serving.control import replay_slot_log
from repro.serving.loadgen import arrival_span

ARCH = "qwen1.5-0.5b"
N_HOSTS = 8
SLOTS_PER_HOST = 2        # >= 2 so per-host fragmentation can occur
MAX_LEN = 40
TOPK = 4
GOSSIP_DELAY = 1
PREFILL_WORKERS = 2
COMPACT_THRESHOLD = 0.25  # frag 0.5 (1 hole of 2 slots) crosses it

# -- chaos drill (committed schedule; --failpoints overrides) -----------
CHAOS_N_HOSTS = 4         # 4 of the 8 forced devices; kill 1 of 4 hosts
CHAOS_KILL_HOST = 1
CHAOS_KILL_STEP = 3       # inside arrival_span at seed 0: reclaims 2
CHAOS_FAILPOINTS = f"kill_host:{CHAOS_KILL_HOST}@{CHAOS_KILL_STEP}"

# -- overload drill (DESIGN.md §14; --overload-failpoints overrides) ----
# arrivals triple-compressed from step 1 on + every decode step costing
# 3 clock ticks from step 2 on: sustained arrival rate far above pool
# throughput, so the deadline/bounded-queue policy MUST shed and the
# pressure ladder MUST degrade and restore for the drill to pass
OVERLOAD_FAILPOINTS = "surge:3@1,slow_decode:3@2"
OVERLOAD_DEADLINE_SLACK = 8     # SLO: admitted within 8 clock ticks
OVERLOAD_SURGE_START = 1        # workload-level ramp (overload_workload)
OVERLOAD_SURGE_FACTOR = 2
# thresholds sized to the bounded queue: max_queue_depth=2 over 4 homes
# caps post-shed pending at 8 = the pool's 8 slots, so pressure tops out
# near 1.0 — the ladder trips at 2 queued (0.25) / 4 queued (0.5) and
# restores only once the queue is empty for a full window
OVERLOAD_POLICY = AdmissionPolicy(max_queue_depth=2, pressure_window=2,
                                  degrade_lo=0.25, degrade_hi=0.5,
                                  restore_below=0.1)


def _log_of(sched) -> dict:
    return {
        "admissions": sched.admissions,
        "releases": sched.releases,
        "compactions": [(step, list(perm), seq)
                        for step, perm, seq in sched.compactions],
        "rejects": sched.rejects,
        "reclaims": sched.reclaims,
        "host_downs": sched.host_downs,
        "sheds": sched.sheds,
        "degrades": sched.degrades,
        "per_host": [{"admissions": h.admissions,
                      "releases": h.releases,
                      "compactions": [(s, list(p), q)
                                      for s, p, q in h.compactions],
                      "rejects": h.rejects,
                      "reclaims": h.reclaims,
                      "sheds": h.sheds}
                     for h in sched.hosts],
    }


def run(seed: int = 0) -> dict:
    cfg = configs.get_smoke_config(ARCH)
    params = steps_lib.cast_params_for_compute(
        steps_lib.init_fn_for(cfg)(jax.random.PRNGKey(0)), cfg)
    # two requests per host per stream keeps the sim fast on CPU CI while
    # still exercising cross-host admission, mid-flight churn, and enough
    # slot fragmentation for the compaction runs to actually compact
    spec = LoadSpec(n_requests=2, vocab=cfg.vocab, rate=1.0,
                    prompt_lens=(6, 10), gen_lens=(3, 6, 12), seed=seed)

    mesh = make_serving_mesh()
    engine = ShardedEngine(cfg, params, mesh=mesh,
                           slots_per_host=SLOTS_PER_HOST, max_len=MAX_LEN,
                           topk=TOPK, gossip_delay=GOSSIP_DELAY,
                           prefill_workers=PREFILL_WORKERS)

    runs = {}
    for tname in ("sim", "collective"):
        for cname, thresh in (("plain", None),
                              ("compact", COMPACT_THRESHOLD)):
            res, stats = engine.run(sharded_workload(spec, N_HOSTS),
                                    transport=tname,
                                    compact_threshold=thresh)
            runs[f"{tname}_{cname}"] = {
                "tokens": {r.rid: r.tokens for r in res.values()},
                "done": {rid: r.done for rid, r in res.items()},
                "stats": stats.as_row(),
                "log": _log_of(engine._sched),
            }

    sims = {}
    for cname, thresh in (("plain", None), ("compact", COMPACT_THRESHOLD)):
        sim_sched, sim_stats = simulate_sharded_schedule(
            sharded_workload(spec, N_HOSTS), SLOTS_PER_HOST, GOSSIP_DELAY,
            compact_threshold=thresh)
        sims[cname] = {"stats": sim_stats.as_row(),
                       "log": _log_of(sim_sched)}

    single = Engine(cfg, params, n_slots=N_HOSTS * SLOTS_PER_HOST,
                    max_len=MAX_LEN, topk=TOPK)
    single_res, single_stats = single.run(
        merge_workloads(sharded_workload(spec, N_HOSTS)))

    solo = Engine(cfg, params, n_slots=1, max_len=MAX_LEN, topk=TOPK)
    solo_tokens = {}
    for reqs in sharded_workload(spec, N_HOSTS):
        for req in reqs:
            req.arrival_step = 0
            r, _ = solo.run_static([req])
            solo_tokens[req.rid] = r[req.rid].tokens

    return {
        "n_devices": jax.device_count(),
        "n_hosts": N_HOSTS,
        "slots_per_host": SLOTS_PER_HOST,
        "gossip_delay": GOSSIP_DELAY,
        "compact_threshold": COMPACT_THRESHOLD,
        "prefill_workers": PREFILL_WORKERS,
        # compile count across the ENTIRE matrix: 4 engine runs through
        # both transports, with and without mid-flight cache remaps
        "decode_compiles": engine._decode._cache_size(),
        "prefill_stats": engine.prefill_pool.stats,
        "runs": runs,
        "sims": sims,
        "single": {"tokens": {r.rid: r.tokens
                              for r in single_res.values()},
                   "stats": single_stats.as_row()},
        "solo": solo_tokens,
    }


def _verify_chaos(chaos: dict, arrival_key: dict) -> None:
    """Hard asserts on the recovery drill — run in THIS process so the
    CI chaos job fails on its own, without the pytest wrapper.
    `arrival_key[rid]` is the original (arrival_step, home, rid) FIFO
    key of each request."""
    base = chaos["base"]
    assert all(base["done"].values()), "fault-free twin did not finish"
    for tname in ("sim", "collective"):
        kr = chaos["kill_runs"][tname]
        # 1. no request lost or rejected under a pure kill plan
        assert all(kr["done"].values()), f"{tname}: lost requests"
        assert kr["stats"]["rejects"] == 0, f"{tname}: spurious rejects"
        # 2. the drill is non-vacuous: the kill reclaimed live work
        assert kr["stats"]["host_downs"] == 1, f"{tname}: no HOST_DOWN"
        assert kr["stats"]["requeued"] >= 1, (
            f"{tname}: kill at step {chaos['kill_step']} reclaimed "
            "nothing — move it inside the arrival span")
        # 3. recovered tokens are BIT-identical to the fault-free twin
        #    (greedy decode is pure in the prompt, so a re-prefilled
        #    request regenerates its exact stream)
        assert kr["tokens"] == base["tokens"], f"{tname}: token drift"
        # 4. re-admissions preserve FIFO order among requeued requests:
        #    each reclaimed rid's LAST admission is its re-admission;
        #    within one HOST_DOWN wave the re-admissions must follow the
        #    original (arrival_step, home, rid) keys (custom --failpoints
        #    plans may kill several hosts at different steps — no global
        #    order exists across waves)
        last_adm = {}
        wave = {}                      # rid -> its LAST reclaim step
        for step, _, rid, _ in kr["log"]["reclaims"]:
            wave[rid] = step
        for _, _, rid, seq in kr["log"]["admissions"]:
            if rid in wave:
                last_adm[rid] = seq
        assert set(last_adm) == set(wave), (
            f"{tname}: reclaimed request never re-admitted")
        for w in set(wave.values()):
            order = sorted((rid for rid, s in wave.items() if s == w),
                           key=last_adm.get)
            keys = [arrival_key[rid] for rid in order]
            assert keys == sorted(keys), (
                f"{tname}: re-admissions out of FIFO order: {order}")
        # 5. slot log replays soundly with RECLAIM events
        replay_slot_log(kr["log"]["admissions"], kr["log"]["releases"],
                        [(s, list(p), q) for s, p, q
                         in kr["log"]["compactions"]],
                        chaos["n_hosts"] * chaos["slots_per_host"],
                        rejects=kr["log"]["rejects"],
                        reclaims=kr["log"]["reclaims"])
    # 6. engine log == model-free sim log, integer-for-integer
    assert chaos["kill_runs"]["sim"]["log"] == chaos["kill_sim"]["log"], \
        "engine/sim log divergence under kill"
    assert (chaos["kill_runs"]["collective"]["log"]
            == chaos["kill_sim"]["log"]), \
        "collective transport log divergence under kill"
    # 7. ONE compiled decode step across fault-free + both kill runs:
    #    host death is an active-mask change, never a new executable
    assert chaos["decode_compiles"] == 1, (
        f"decode recompiled under host death: "
        f"{chaos['decode_compiles']} executables")


def run_chaos(seed: int = 0, failpoints: str | None = None) -> dict:
    spec_str = CHAOS_FAILPOINTS if failpoints is None else failpoints
    plan = FailPlan.parse(spec_str)
    cfg = configs.get_smoke_config(ARCH)
    params = steps_lib.cast_params_for_compute(
        steps_lib.init_fn_for(cfg)(jax.random.PRNGKey(0)), cfg)
    spec = LoadSpec(n_requests=2, vocab=cfg.vocab, rate=1.0,
                    prompt_lens=(6, 10), gen_lens=(3, 6, 12), seed=seed)

    def wl():
        return sharded_workload(spec, CHAOS_N_HOSTS)

    first, last = arrival_span(wl())
    arrival_key = {r.rid: (r.arrival_step, r.home, r.rid)
                   for reqs in wl() for r in reqs}

    mesh = make_serving_mesh(n_hosts=CHAOS_N_HOSTS)
    engine = ShardedEngine(cfg, params, mesh=mesh,
                           slots_per_host=SLOTS_PER_HOST, max_len=MAX_LEN,
                           topk=TOPK, gossip_delay=GOSSIP_DELAY,
                           prefill_workers=PREFILL_WORKERS)

    def pack(res, stats, sched) -> dict:
        return {
            "tokens": {r.rid: r.tokens for r in res.values()},
            "done": {rid: r.done for rid, r in res.items()},
            "stats": {**stats.as_row(), "host_downs": stats.host_downs,
                      "requeued": stats.requeued,
                      "rejects": stats.rejects},
            "log": _log_of(sched),
        }

    base_res, base_stats = engine.run(wl(), transport="sim",
                                      failpoints=None)
    base = pack(base_res, base_stats, engine._sched)

    kill_runs = {}
    for tname in ("sim", "collective"):
        res, stats = engine.run(wl(), transport=tname, failpoints=plan)
        kill_runs[tname] = pack(res, stats, engine._sched)

    kill_sim_sched, kill_sim_stats = simulate_sharded_schedule(
        wl(), SLOTS_PER_HOST, GOSSIP_DELAY, failpoints=plan)
    kill_sim = {"stats": {**kill_sim_stats.as_row(),
                          "host_downs": kill_sim_stats.host_downs,
                          "requeued": kill_sim_stats.requeued,
                          "rejects": kill_sim_stats.rejects},
                "log": _log_of(kill_sim_sched)}

    chaos = {
        "failpoints": spec_str,
        "kill_step": plan.kill_steps()[0] if plan.kill_steps() else None,
        "arrival_span": [first, last],
        "n_hosts": CHAOS_N_HOSTS,
        "slots_per_host": SLOTS_PER_HOST,
        "gossip_delay": GOSSIP_DELAY,
        "decode_compiles": engine._decode._cache_size(),
        "base": base,
        "kill_runs": kill_runs,
        "kill_sim": kill_sim,
    }
    if plan.kill_steps():          # custom plans may inject other faults
        _verify_chaos(chaos, arrival_key)
        chaos["verified"] = True
    return chaos


def _verify_overload(ov: dict) -> None:
    """Hard asserts on the overload drill (DESIGN.md §14), in THIS
    process so the CI chaos job fails loudly on its own: under the
    injected surge every request either completes BIT-identically to
    the unloaded twin or is shed deterministically — never both — the
    shed set is identical across SimTransport / CollectiveTransport /
    the model-free sim, the degrade ladder escalated AND restored with
    zero recompiles, and SLO attainment is the pure arithmetic of the
    shed count."""
    base = ov["base"]
    assert all(base["done"].values()), "unloaded twin did not finish"
    assert base["stats"]["sheds"] == 0, "unloaded twin shed requests"
    n_total = len(base["done"])
    shed_sets = {}
    for tname in ("sim", "collective"):
        sr = ov["surge_runs"][tname]
        shed = set(sr["shed_rids"])
        served = {rid for rid, d in sr["done"].items() if d} - shed
        shed_sets[tname] = shed
        # 1. the drill is non-vacuous and clean: sheds happened, no
        #    rejects (the plan injects no prefill faults), every request
        #    reached a terminal state
        assert sr["stats"]["sheds"] > 0, f"{tname}: surge shed nothing"
        assert sr["stats"]["rejects"] == 0, f"{tname}: spurious rejects"
        assert served | shed == set(sr["done"]), (
            f"{tname}: request neither served nor shed")
        # 2. no request is both served and shed
        assert not (served & shed), (
            f"{tname}: shed AND completed: {sorted(served & shed)}")
        # 3. every served request's tokens are BIT-identical to the
        #    unloaded twin's (degradation narrows the served top-k; the
        #    next token is the top-1 id, invariant under the width)
        for rid in served:
            assert sr["tokens"][rid] == base["tokens"][rid], (
                f"{tname}: rid {rid} token drift under overload")
        # 4. the ladder moved both ways: at least one DEGRADE escalation
        #    and one RESTORE once the shed+drained queue released the
        #    pressure (hysteresis means this is a real recovery, not a
        #    flap)
        degr = sr["log"]["degrades"]
        assert any(new > old for _, old, new, _ in degr), (
            f"{tname}: pressure never degraded the pool")
        assert any(new < old for _, old, new, _ in degr), (
            f"{tname}: pool never restored after the surge drained")
        # 5. SLO attainment is the pure arithmetic of the shed count —
        #    tie the result-marked shed flags to run_schedule's
        #    independently drained counter
        assert sr["stats"]["sheds"] == len(shed), (
            f"{tname}: stats.sheds != marked shed requests")
        assert sr["slo_attainment"] == slo_attainment(
            n_total - sr["stats"]["sheds"], n_total)
        # 6. the slot log replays soundly (sheds vacate no slot, so the
        #    replay contract is unchanged)
        replay_slot_log(sr["log"]["admissions"], sr["log"]["releases"],
                        [(s, list(p), q) for s, p, q
                         in sr["log"]["compactions"]],
                        ov["n_hosts"] * ov["slots_per_host"],
                        rejects=sr["log"]["rejects"],
                        reclaims=sr["log"]["reclaims"])
    # 7. shed decisions are deterministic and transport-invariant
    assert shed_sets["sim"] == shed_sets["collective"], (
        "shed set differs between transports")
    assert shed_sets["sim"] == set(ov["surge_sim"]["shed_rids"]), (
        "engine shed set differs from the model-free sim")
    # 8. engine log == model-free sim log, SHED / DEGRADE included
    assert ov["surge_runs"]["sim"]["log"] == ov["surge_sim"]["log"], \
        "engine/sim log divergence under overload"
    assert (ov["surge_runs"]["collective"]["log"]
            == ov["surge_sim"]["log"]), \
        "collective transport log divergence under overload"
    # 9. zero recompiles through every DEGRADE/RESTORE: each pre-built
    #    stage executable compiled at most once across twin + both surge
    #    runs, and every stage the ladder entered compiled exactly once
    entered = {0} | {new for _, _, new, _
                     in ov["surge_runs"]["sim"]["log"]["degrades"]}
    for st, n in ov["stage_decode_compiles"].items():
        assert n <= 1, (
            f"stage {st} decode recompiled: {n} executables")
        if int(st) in entered:
            assert n == 1, f"stage {st} entered but never compiled?"


def run_overload(seed: int = 0, failpoints: str | None = None) -> dict:
    """The overload chaos drill: the seeded per-host workload (ramped,
    deadline-tagged — loadgen.overload_workload) served on a 4-host mesh
    under an injected arrival surge + decode slowdown, with the
    committed AdmissionPolicy shedding and degrading; the unloaded twin
    serves the identical workload with no injection and no policy."""
    spec_str = OVERLOAD_FAILPOINTS if failpoints is None else failpoints
    plan = FailPlan.parse(spec_str)
    cfg = configs.get_smoke_config(ARCH)
    params = steps_lib.cast_params_for_compute(
        steps_lib.init_fn_for(cfg)(jax.random.PRNGKey(0)), cfg)
    spec = LoadSpec(n_requests=3, vocab=cfg.vocab, rate=1.0,
                    prompt_lens=(6, 10), gen_lens=(3, 6, 12), seed=seed)

    def wl():
        return overload_workload(
            spec, CHAOS_N_HOSTS, surge_start=OVERLOAD_SURGE_START,
            surge_factor=OVERLOAD_SURGE_FACTOR,
            deadline_slack=OVERLOAD_DEADLINE_SLACK)

    mesh = make_serving_mesh(n_hosts=CHAOS_N_HOSTS)
    engine = ShardedEngine(cfg, params, mesh=mesh,
                           slots_per_host=SLOTS_PER_HOST, max_len=MAX_LEN,
                           topk=TOPK, gossip_delay=GOSSIP_DELAY,
                           prefill_workers=PREFILL_WORKERS,
                           admission_policy=OVERLOAD_POLICY)
    n_total = CHAOS_N_HOSTS * spec.n_requests

    def pack(res, stats, sched) -> dict:
        shed = sorted(r.rid for r in res.values() if r.shed)
        return {
            "tokens": {r.rid: r.tokens for r in res.values()},
            "done": {rid: r.done for rid, r in res.items()},
            "shed_rids": shed,
            "slo_attainment": slo_attainment(n_total - len(shed),
                                             n_total),
            "stats": {**stats.as_row(), "sheds": stats.sheds,
                      "degrades": stats.degrades,
                      "rejects": stats.rejects},
            "log": _log_of(sched),
        }

    # the unloaded twin: same workload, no injection, no policy — every
    # request serves to completion at full width
    base_res, base_stats = engine.run(wl(), transport="sim",
                                      failpoints=None,
                                      admission_policy=None)
    base = pack(base_res, base_stats, engine._sched)

    surge_runs = {}
    for tname in ("sim", "collective"):
        res, stats = engine.run(wl(), transport=tname, failpoints=plan)
        surge_runs[tname] = pack(res, stats, engine._sched)

    sim_sched, sim_stats = simulate_sharded_schedule(
        wl(), SLOTS_PER_HOST, GOSSIP_DELAY, failpoints=plan,
        admission_policy=OVERLOAD_POLICY)
    shed_sim = sorted(rid for _, rid, _, _ in sim_sched.log.sheds)
    surge_sim = {"shed_rids": shed_sim,
                 "stats": {**sim_stats.as_row(),
                           "sheds": sim_stats.sheds,
                           "degrades": sim_stats.degrades,
                           "rejects": sim_stats.rejects},
                 "log": _log_of(sim_sched)}

    # stage -> compile count (stages sharing one width share one jit; a
    # shared jit reports the same count for each of its stages)
    stage_compiles = {st: jit._cache_size()
                      for st, jit in engine._stage_decodes.items()}

    overload = {
        "failpoints": spec_str,
        "overload_steps": plan.overload_steps(),
        "policy": {"max_queue_depth": OVERLOAD_POLICY.max_queue_depth,
                   "pressure_window": OVERLOAD_POLICY.pressure_window,
                   "degrade_lo": OVERLOAD_POLICY.degrade_lo,
                   "degrade_hi": OVERLOAD_POLICY.degrade_hi,
                   "restore_below": OVERLOAD_POLICY.restore_below,
                   "degraded_topk": OVERLOAD_POLICY.degraded_topk},
        "deadline_slack": OVERLOAD_DEADLINE_SLACK,
        "n_hosts": CHAOS_N_HOSTS,
        "slots_per_host": SLOTS_PER_HOST,
        "gossip_delay": GOSSIP_DELAY,
        "n_requests": n_total,
        "stage_decode_compiles": stage_compiles,
        "base": base,
        "surge_runs": surge_runs,
        "surge_sim": surge_sim,
    }
    if plan.overload_steps():      # custom plans may not inject overload
        _verify_overload(overload)
        overload["verified"] = True
    return overload


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", required=True, help="JSON report path")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--failpoints", default=None,
                    help="chaos failpoint spec (default: "
                         f"{CHAOS_FAILPOINTS!r})")
    ap.add_argument("--overload-failpoints", default=None,
                    help="overload drill spec (default: "
                         f"{OVERLOAD_FAILPOINTS!r})")
    args = ap.parse_args()
    report = run(seed=args.seed)
    report["chaos"] = run_chaos(seed=args.seed,
                                failpoints=args.failpoints)
    report["overload"] = run_overload(seed=args.seed,
                                      failpoints=args.overload_failpoints)
    with open(args.out, "w") as f:
        json.dump(report, f)
    print("wrote", args.out)
    print("chaos: verified" if report["chaos"].get("verified")
          else "chaos: ran (no kill in plan — checks skipped)")
    print("overload: verified" if report["overload"].get("verified")
          else "overload: ran (no surge in plan — checks skipped)")


if __name__ == "__main__":
    main()
