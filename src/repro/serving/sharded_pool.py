"""Data-axis-sharded serving: GSPMD slot pool + disaggregated prefill
(DESIGN.md §8).

The PR-2 engine is single-host: its slot pool lives on the local mesh and
admission is host-side Python.  This module shards exactly that boundary,
the way production recommenders do (DLRM, Naumov et al. 2019):

  * **Sharded slot pool** — the cache tree is one GSPMD pytree whose slot
    axis shards over the ``data`` mesh axis (`launch/sharding.
    slot_pool_pspecs`): each data shard owns a contiguous slot range, so
    decode reads are all-local and a cache insert touches one shard.
  * **Per-host admission + gossiped queue** — scheduling is the
    deterministic replicated state machine of ``scheduler.
    ShardedScheduler``: arrivals and releases gossip into global
    visibility after ``gossip_delay`` steps, every host computes the same
    admission assignment, and each host executes only admissions landing
    in its own slot range — no slot or request is ever claimed twice.
  * **Disaggregated prefill** — prefill runs on a dedicated 1-device mesh
    slice (``engine.PrefillWorker``); the emitted caches are inserted into
    the decode pool by ``steps.make_sharded_insert``, a shard_map whose
    replicated-operand broadcast IS the device-to-device transfer.
  * **ONE compiled decode step survives sharding** — the decode-pool step
    is the same ``steps.make_slot_decode_step`` per-slot-position jitted
    callable, now traced once over the sharded pool; tokens/pos/active
    are committed with explicit NamedShardings every step so the input
    layout (and therefore the executable) never changes mid-run.  The
    multi-host sim test asserts ``_decode._cache_size() == 1`` after a
    full run.

Per-request tokens are BIT-identical to the single-host engine and to
solo static serving: prefill is B=1 at exact prompt length either way,
and every decode op is row-independent — batch sharding partitions rows
across devices without touching per-row math (asserted by
tests/test_serving_multihost.py on a simulated 8-device topology).
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.launch import sharding as sharding_lib
from repro.launch import steps as steps_lib
from repro.models import transformer as tf
from repro.serving.engine import Engine, PrefillWorker, ServeStats
from repro.serving.scheduler import Request, ShardedScheduler


class ShardedEngine:
    """Continuous batching over a data-axis-sharded slot pool.

    ``mesh`` must carry a ``data`` axis; one simulated host per data
    shard, ``slots_per_host`` slots each (global pool = n_hosts *
    slots_per_host slots).  ``run`` consumes per-host workloads
    (``loadgen.sharded_workload``) through the gossiped admission
    protocol.  Eligibility mirrors ``Engine.supports``.
    """

    def __init__(self, cfg: ModelConfig, params, *, mesh,
                 slots_per_host: int, max_len: int, topk: int = 8,
                 eos_id: Optional[int] = None, gossip_delay: int = 1,
                 prefill_device=None):
        if not Engine.supports(cfg):
            raise NotImplementedError(
                f"{cfg.name}: sharded serving covers the same decoder-only "
                "token LMs as Engine (see Engine.supports)")
        assert slots_per_host >= 1 and max_len >= 2
        self.cfg = cfg
        self.mesh = mesh
        self.dist = sharding_lib.DistContext(mesh)
        self.n_hosts = int(self.dist.n_batch)
        self.slots_per_host = slots_per_host
        self.n_slots = self.n_hosts * slots_per_host
        self.max_len = max_len
        self.topk = topk
        self.eos_id = eos_id
        self.gossip_delay = gossip_delay

        # decode-pool weights: explicitly replicated across the mesh so
        # every per-step input is committed and the step compiles once
        self.params = jax.device_put(
            params, jax.tree.map(lambda _: NamedSharding(mesh, P()),
                                 params))

        # Disaggregated prefill: the worker owns its OWN weight copy on
        # its own device (prefill/decode disaggregation — prefill
        # capacity scales independently of the pool).  In this
        # single-process simulation the default device doubles as data
        # shard 0, so that device carries two param copies; a real
        # deployment passes a device OUTSIDE the decode mesh.  B=1
        # prefill cannot shard, so the slice needs no DistContext.
        self.prefill_worker = PrefillWorker(
            cfg, params, topk=topk,
            device=(mesh.devices.flat[0] if prefill_device is None
                    else prefill_device))

        # the sharded pool: slot axis over `data`
        template = tf.init_lm_cache(cfg, self.n_slots, max_len,
                                    dtype=jnp.dtype(cfg.dtype))
        self._pool_specs = sharding_lib.slot_pool_pspecs(
            cfg, template, self.dist, self.n_slots)
        self._pool_shardings = jax.tree.map(
            lambda spec: NamedSharding(mesh, spec), self._pool_specs,
            is_leaf=lambda x: isinstance(x, P))
        self._pool_template = jax.device_put(template, self._pool_shardings)

        # per-step host->device commits: slot-aligned over `data`
        self._row_sharding = NamedSharding(mesh, P(self.dist.batch_axes))
        self._tok_sharding = NamedSharding(
            mesh, P(self.dist.batch_axes, None))
        # out_shardings pin the cache layout to the pool specs so the
        # donated output of step t is a valid input of step t+1 with the
        # SAME layout — otherwise GSPMD may pick a different output
        # sharding and the second step recompiles (single-compiled-step
        # invariant; the sim test asserts _decode._cache_size() == 1)
        self._decode = jax.jit(
            steps_lib.make_slot_decode_step(cfg, topk=topk, dist=self.dist),
            donate_argnums=(2,),
            out_shardings={"caches": self._pool_shardings,
                           "topk_scores": self._tok_sharding,
                           "topk_ids": self._tok_sharding})
        self._insert = steps_lib.make_sharded_insert(
            self._pool_specs, self.dist, slots_per_host)

    def _fresh_pool(self):
        # copy, not alias: donation consumes the buffers (engine.py)
        return jax.tree.map(jnp.copy, self._pool_template)

    def _stopped(self, req: Request, tok: int) -> bool:
        if self.eos_id is not None and tok == self.eos_id:
            return True
        return len(req.tokens) >= req.max_gen

    def _admit_one(self, req: Request, caches):
        assert req.prompt_len + req.max_gen <= self.max_len, (
            f"request {req.rid}: prompt {req.prompt_len} + max_gen "
            f"{req.max_gen} exceeds pool max_len {self.max_len}")
        small, first = self.prefill_worker.prefill(req)
        caches = self._insert(caches, small, jnp.int32(req.slot))
        return caches, first

    # ------------------------------------------------------------------
    def run(self, per_host_requests: List[List[Request]]
            ) -> Tuple[Dict[int, Request], ServeStats]:
        """Serve per-host arrival streams through the gossiped pool.

        The loop order is EXACTLY ``scheduler.simulate_sharded_schedule``
        (admit -> fast-forward-if-empty -> decode -> retire), so with
        ``eos_id=None`` the engine's event log reproduces the model-free
        simulation's log integer-for-integer.
        """
        sched = ShardedScheduler(self.n_hosts, self.slots_per_host,
                                 self.gossip_delay)
        sched.push_workloads(per_host_requests)
        stats = ServeStats()

        tokens = np.zeros((self.n_slots, 1), np.int32)
        pos = np.zeros((self.n_slots,), np.int32)
        active = np.zeros((self.n_slots,), bool)
        caches = self._fresh_pool()
        now = 0
        t0 = time.perf_counter()

        while sched.n_pending or sched.n_active:
            for req in sched.admit(now):
                caches, first = self._admit_one(req, caches)
                req.tokens.append(first)
                stats.prefills += 1
                stats.tokens_out += 1
                if self._stopped(req, first):
                    sched.release(req.slot, now)
                else:
                    tokens[req.slot, 0] = first
                    pos[req.slot] = req.prompt_len
                    active[req.slot] = True

            if not sched.n_active:
                nxt = sched.next_event_time(now)
                if nxt is None:
                    break
                stats.idle_steps += nxt - now
                now = nxt
                continue

            out = self._decode(
                self.params,
                jax.device_put(jnp.asarray(tokens), self._tok_sharding),
                caches,
                jax.device_put(jnp.asarray(pos), self._row_sharding),
                jax.device_put(jnp.asarray(active), self._row_sharding))
            caches = out["caches"]
            ids = np.asarray(out["topk_ids"][:, 0])
            stats.decode_steps += 1
            stats.slot_steps_total += self.n_slots
            stats.slot_steps_active += int(active.sum())
            now += 1
            for gslot, req in list(sched.active.items()):
                tok = int(ids[gslot])
                req.tokens.append(tok)
                stats.tokens_out += 1
                tokens[gslot, 0] = tok
                pos[gslot] += 1
                if self._stopped(req, tok):
                    sched.release(gslot, now)
                    active[gslot] = False

        stats.wall_s = time.perf_counter() - t0
        self._sched = sched          # exposed for the simulation tests
        results = {r.rid: r for reqs in per_host_requests for r in reqs}
        return results, stats
