"""Data-axis-sharded serving: the *data plane* of the control/data-plane
split (DESIGN.md §8/§9): GSPMD slot pool + disaggregated prefill pool +
slot compaction.

The PR-2 engine is single-host: its slot pool lives on the local mesh and
admission is host-side Python.  This module shards exactly that boundary,
the way production recommenders do (DLRM, Naumov et al. 2019):

  * **Sharded slot pool** — the cache tree is one GSPMD pytree whose slot
    axis shards over the ``data`` mesh axis (`launch/sharding.
    slot_pool_pspecs`): each data shard owns a contiguous slot range, so
    decode reads are all-local and a cache insert touches one shard.
  * **Transported admission** — scheduling is the replicated state
    machine of ``serving/control.py`` orchestrated by
    ``scheduler.ShardedScheduler``: arrival/release deltas travel a
    pluggable ``Transport`` (``"sim"`` — PR 3's in-process gossip,
    log-identical; ``"collective"`` — fixed-size padded all_gather over
    the mesh's data axis, the jax.distributed-ready protocol), every host
    computes the same admission assignment, and each host executes only
    its own slot range — no slot or request is ever claimed twice.
  * **Disaggregated prefill pool** — prefill runs on
    ``engine.PrefillPool``: a FIFO scheduler over N single-device mesh
    slices, so a burst of arrivals no longer head-of-line blocks
    admission behind one worker; the emitted caches are inserted into
    the decode pool by ``steps.make_sharded_insert``, a shard_map whose
    replicated-operand broadcast IS the device-to-device transfer.
  * **Slot compaction** — with ``compact_threshold`` set, the control
    plane densifies fragmented host shards (``control.plan_compaction``)
    and this engine applies the remap to the cache pytree via
    ``steps.make_compact_pool`` (shard-local gather, donated in-place
    update) and to the host-side token/pos/active arrays.  The densified
    occupancy feeds ``bloom_decode_topk``'s prefetched row-skipping grid,
    so a scattered pool recovers the dense pool's HBM bytes
    (bench_kernels.py ``.decode_topk.scatter*`` rows, gated in CI).
  * **ONE compiled decode step survives sharding AND compaction** — the
    decode-pool step is the same ``steps.make_slot_decode_step``
    per-slot-position jitted callable; out_shardings pin the donated
    cache layout, and the compaction remap preserves it (out_specs ==
    pool specs), so the executable never changes mid-run.  The multi-host
    sim test asserts ``_decode._cache_size() == 1`` after a full
    transport x compaction run matrix.

Per-request tokens are BIT-identical to the single-host engine and to
solo static serving — across both transports and with compaction on or
off: prefill is B=1 at exact prompt length everywhere, every decode op is
row-independent, and a compaction merely permutes rows (asserted by
tests/test_serving_multihost.py on a simulated 8-device topology).
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.launch import sharding as sharding_lib
from repro.launch import steps as steps_lib
from repro.models import transformer as tf
from repro.serving.admission import AdmissionPolicy
from repro.serving.control import (CollectiveTransport, SimTransport,
                                   Transport)
from repro.serving import engine as engine_lib
from repro.serving.engine import Engine, PrefillPool, ServeStats
from repro.serving.scheduler import (Request, ScheduleClient,
                                     ShardedScheduler, run_schedule)


class _PoolClient(ScheduleClient):
    """The real data plane behind ``run_schedule``: prefill-pool dispatch,
    sharded cache inserts, the jitted pool decode step, and the
    compaction remap.  The model-free ``_SimClient`` fills the same hooks
    with placeholders — sharing the loop is what makes the engine's event
    log equal the simulation's integer-for-integer."""

    def __init__(self, engine: "ShardedEngine"):
        self.e = engine
        self.stage = 0        # current degrade stage (DESIGN.md §14)
        self.tokens = np.zeros((engine.n_slots, 1), np.int32)
        self.pos = np.zeros((engine.n_slots,), np.int32)
        self.active = np.zeros((engine.n_slots,), bool)
        self.caches = engine._fresh_pool()

    def prefill(self, reqs: List[Request]) -> List[Optional[int]]:
        for req in reqs:
            engine_lib.assert_request_fits(req, self.e.max_len)
        firsts = []
        for req, res in zip(reqs,
                            self.e.prefill_pool.prefill_all(reqs)):
            if res is None:
                # attempt cap exhausted: no caches to insert — the loop
                # REJECTs the slot, which stays inactive
                firsts.append(None)
                continue
            small, first = res
            self.caches = self.e._insert(self.caches, small,
                                         jnp.int32(req.slot))
            firsts.append(first)
        return firsts

    def stopped(self, req: Request, tok: int) -> bool:
        return self.e._stopped(req, tok)

    def start_slot(self, req: Request, first: int) -> None:
        self.tokens[req.slot, 0] = first
        self.pos[req.slot] = req.prompt_len
        self.active[req.slot] = True

    def decode(self, active_map: Dict[int, Request]) -> Dict[int, int]:
        e = self.e
        out = e._stage_decodes[self.stage](
            e.params,
            jax.device_put(jnp.asarray(self.tokens), e._tok_sharding),
            self.caches,
            jax.device_put(jnp.asarray(self.pos), e._row_sharding),
            jax.device_put(jnp.asarray(self.active), e._row_sharding))
        self.caches = out["caches"]
        ids = np.asarray(out["topk_ids"][:, 0])
        return {gslot: int(ids[gslot]) for gslot in active_map}

    def set_stage(self, stage: int) -> None:
        if stage not in self.e._stage_decodes:
            raise RuntimeError(
                f"sharded pool: degrade stage {stage} was not pre-built "
                "— construct the ShardedEngine with the run's "
                "admission_policy (DESIGN.md §14)")
        self.stage = stage

    def advance_slot(self, gslot: int, req: Request, tok: int) -> None:
        self.tokens[gslot, 0] = tok
        self.pos[gslot] += 1

    def stop_slot(self, gslot: int) -> None:
        self.active[gslot] = False

    def compact(self, perm: List[int]) -> None:
        p = np.asarray(perm, np.int32)
        self.caches = self.e._compact(self.caches, p)
        self.tokens = self.tokens[p]
        self.pos = self.pos[p]
        self.active = self.active[p]

    def host_killed(self, host: int) -> None:
        # the dead range stops decoding THIS step: clearing the active
        # mask is the data plane's entire epoch change — decode is the
        # occupancy-prefetched row-skipping grid, so surviving rows
        # neither recompile (same shapes) nor change values (row
        # independence); ≤1 recompile per epoch is trivially met at 0
        lo = host * self.e.slots_per_host
        self.active[lo:lo + self.e.slots_per_host] = False

    def host_down(self, host: int, reqs: List[Request]) -> None:
        # death is visible cluster-wide: scrub the dead range's host-side
        # state so the next occupant starts from the same zeros a fresh
        # pool would (cache rows are overwritten by insert at admission)
        lo = host * self.e.slots_per_host
        hi = lo + self.e.slots_per_host
        self.tokens[lo:hi] = 0
        self.pos[lo:hi] = 0
        self.active[lo:hi] = False


class ShardedEngine:
    """Continuous batching over a data-axis-sharded slot pool.

    ``mesh`` must carry a ``data`` axis; one simulated host per data
    shard, ``slots_per_host`` slots each (global pool = n_hosts *
    slots_per_host slots).  ``run`` consumes per-host workloads
    (``loadgen.sharded_workload``) through the transported admission
    protocol.  Eligibility mirrors ``Engine.supports``.

    ``transport`` / ``compact_threshold`` / ``failpoints`` set the run
    defaults (all overridable per ``run`` call): ``"sim"`` + ``None`` is
    exactly PR 3's behavior; ``"collective"`` exchanges the same deltas
    over a real device all_gather; a float threshold enables slot
    compaction; a ``FailPlan`` replays a deterministic failure schedule
    (host kills, prefill faults, transport hangs, digest corruption)
    against the run — recovery is part of the replicated schedule, so
    the engine's event log still equals the model-free sim's.
    ``prefill_workers`` sizes the prefill pool over single-device slices
    of the mesh (worker i on device i mod n_devices) — the recovered
    tokens are identical for any worker count.
    """

    def __init__(self, cfg: ModelConfig, params, *, mesh,
                 slots_per_host: int, max_len: int, topk: int = 8,
                 eos_id: Optional[int] = None, gossip_delay: int = 1,
                 prefill_device=None, prefill_workers: int = 1,
                 transport: Union[str, Transport] = "sim",
                 compact_threshold: Optional[float] = None,
                 collective_capacity: int = 8,
                 failpoints=None,
                 admission_policy: Optional[AdmissionPolicy] = None):
        if not Engine.supports(cfg):
            raise NotImplementedError(
                f"{cfg.name}: sharded serving covers the same decoder-only "
                "token LMs as Engine (see Engine.supports)")
        assert slots_per_host >= 1 and max_len >= 2
        self.cfg = cfg
        self.mesh = mesh
        self.dist = sharding_lib.DistContext(mesh)
        self.n_hosts = int(self.dist.n_batch)
        self.slots_per_host = slots_per_host
        self.n_slots = self.n_hosts * slots_per_host
        self.max_len = max_len
        self.topk = topk
        self.eos_id = eos_id
        self.gossip_delay = gossip_delay
        self.transport = transport
        self.compact_threshold = compact_threshold
        self.collective_capacity = collective_capacity
        self.failpoints = failpoints if failpoints else None
        self.admission_policy = admission_policy

        # decode-pool weights: explicitly replicated across the mesh so
        # every per-step input is committed and the step compiles once
        self.params = jax.device_put(
            params, jax.tree.map(lambda _: NamedSharding(mesh, P()),
                                 params))

        # Disaggregated prefill pool: each worker owns its OWN weight
        # copy on its own 1-device mesh slice (prefill/decode
        # disaggregation — prefill capacity scales independently of the
        # pool).  In this single-process simulation the slices double as
        # data shards, so those devices carry two param copies; a real
        # deployment passes devices OUTSIDE the decode mesh.  B=1
        # prefill cannot shard, so the slices need no DistContext.
        devices = ([mesh.devices.flat[i % mesh.devices.size]
                    for i in range(prefill_workers)]
                   if prefill_device is None else [prefill_device])
        self.prefill_pool = PrefillPool(cfg, params, topk=topk,
                                        n_workers=prefill_workers,
                                        devices=devices)

        # the sharded pool: slot axis over `data`
        template = tf.init_lm_cache(cfg, self.n_slots, max_len,
                                    dtype=jnp.dtype(cfg.dtype))
        self._pool_specs = sharding_lib.slot_pool_pspecs(
            cfg, template, self.dist, self.n_slots)
        self._pool_shardings = jax.tree.map(
            lambda spec: NamedSharding(mesh, spec), self._pool_specs,
            is_leaf=lambda x: isinstance(x, P))
        self._pool_template = jax.device_put(template, self._pool_shardings)

        # per-step host->device commits: slot-aligned over `data`
        self._row_sharding = NamedSharding(mesh, P(self.dist.batch_axes))
        self._tok_sharding = NamedSharding(
            mesh, P(self.dist.batch_axes, None))
        # out_shardings pin the cache layout to the pool specs so the
        # donated output of step t is a valid input of step t+1 with the
        # SAME layout — otherwise GSPMD may pick a different output
        # sharding and the second step recompiles (single-compiled-step
        # invariant; the sim test asserts _decode._cache_size() == 1)
        self._decode = jax.jit(
            steps_lib.make_slot_decode_step(cfg, topk=topk, dist=self.dist),
            donate_argnums=(2,),
            out_shardings={"caches": self._pool_shardings,
                           "topk_scores": self._tok_sharding,
                           "topk_ids": self._tok_sharding})
        # degrade ladder (DESIGN.md §14): pre-built narrower-top-k decode
        # jits, same donation and sharding pins as the stage-0 step so a
        # DEGRADE/RESTORE is a dict lookup — never a compile, never a
        # layout change
        self._stage_decodes = engine_lib.build_stage_decodes(
            self._decode, topk, admission_policy,
            lambda k: jax.jit(
                steps_lib.make_slot_decode_step(cfg, topk=k,
                                                dist=self.dist),
                donate_argnums=(2,),
                out_shardings={"caches": self._pool_shardings,
                               "topk_scores": self._tok_sharding,
                               "topk_ids": self._tok_sharding}))
        self._insert = steps_lib.make_sharded_insert(
            self._pool_specs, self.dist, slots_per_host)
        self._compact = steps_lib.make_compact_pool(
            self._pool_specs, self.dist, slots_per_host)

    def _fresh_pool(self):
        # copy, not alias: donation consumes the buffers (engine.py)
        return jax.tree.map(jnp.copy, self._pool_template)

    def _stopped(self, req: Request, tok: int) -> bool:
        if self.eos_id is not None and tok == self.eos_id:
            return True
        return len(req.tokens) >= req.max_gen

    def _make_transport(self,
                        transport: Union[str, Transport]) -> Transport:
        if isinstance(transport, Transport):
            return transport
        if transport == "sim":
            return SimTransport(self.gossip_delay)
        if transport == "collective":
            from repro.serving.collective import make_device_gather
            return CollectiveTransport(
                self.n_hosts, self.gossip_delay,
                capacity=self.collective_capacity,
                gather=make_device_gather(self.mesh))
        raise ValueError(f"unknown transport {transport!r}")

    # ------------------------------------------------------------------
    def run(self, per_host_requests: List[List[Request]], *,
            transport: Union[str, Transport, None] = None,
            compact_threshold: Union[float, None, str] = "default",
            failpoints="default",
            admission_policy="default",
            ) -> Tuple[Dict[int, Request], ServeStats]:
        """Serve per-host arrival streams through the transported pool.

        The loop is LITERALLY ``scheduler.run_schedule`` — the same
        driver the model-free ``simulate_sharded_schedule`` runs — so
        with ``eos_id=None`` the engine's event log reproduces the
        simulation's log integer-for-integer, COMPACT / reclaim / reject
        events included: a ``FailPlan`` injects the same kills and
        prefill faults into both.
        """
        fp = self.failpoints if failpoints == "default" else (
            failpoints if failpoints else None)
        pol = (self.admission_policy if admission_policy == "default"
               else admission_policy)
        if pol is not None and pol.max_stage > 0 \
                and self.admission_policy is None:
            raise RuntimeError(
                "run() got an admission_policy with degrade stages but "
                "the engine was built without one — stage decode jits "
                "are PRE-BUILT at construction (DESIGN.md §14); pass "
                "admission_policy to ShardedEngine(...)")
        # the prefill pool consults the run's plan (it is engine-owned,
        # so re-point it per run; None restores fault-free behavior)
        self.prefill_pool.failpoints = fp
        sched = ShardedScheduler(
            self.n_hosts, self.slots_per_host, self.gossip_delay,
            transport=self._make_transport(
                self.transport if transport is None else transport),
            compact_threshold=(self.compact_threshold
                               if compact_threshold == "default"
                               else compact_threshold),
            failpoints=fp,
            admission_policy=pol)
        sched.push_workloads(per_host_requests)
        client = _PoolClient(self)
        t0 = time.perf_counter()
        stats = run_schedule(sched, client)
        stats.wall_s = time.perf_counter() - t0
        self._sched = sched          # exposed for the simulation tests
        results = {r.rid: r for reqs in per_host_requests for r in reqs}
        return results, stats
