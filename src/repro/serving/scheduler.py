"""Request queues + slot schedulers for the continuous-batching engine.

Deliberately JAX-free: admission policy is host-side control flow over a
fixed pool of cache slots (the device-side pool lives in engine.py /
sharded_pool.py), so the invariants — slot conservation, FIFO admission
among ready requests, no starvation, and (sharded) no cross-host slot
double-claim — are testable with hypothesis in microseconds.

Time is measured in *decode steps*: the engine advances the clock once
per jitted decode step, and a request with ``arrival_step = t`` becomes
admissible the first time the clock reaches t.  That makes every schedule
a deterministic function of (workload, n_slots) — the property CI runs on
CPU without ever touching the model.

Two schedulers live here:

  * ``Scheduler`` — the single-host FIFO slot pool from PR 2.
  * ``ShardedScheduler`` — the multi-host admission protocol (DESIGN.md
    §8/§9), now an orchestrator over the *control plane* in
    serving/control.py: the replicated state machine advances only via
    ``control.apply_deltas`` over deltas carried by a pluggable
    ``Transport`` (in-process simulated gossip, or the fixed-size padded
    all_gather collective), and admission is the pure
    ``control.compute_admissions`` every host evaluates identically.
    A host then *executes* only the admissions that land in its own slot
    range; no two hosts can ever claim the same slot or the same request.
    With ``compact_threshold`` set, the control plane additionally plans
    host-local slot compactions (``control.plan_compaction``) and records
    them as COMPACT log events so replay stays integer-exact.

``run_schedule`` is the ONE admit -> fast-forward -> decode -> retire
loop shared by the real ``ShardedEngine.run`` and the model-free
``simulate_sharded_schedule`` — the engine's event log equals the
simulation's by construction, compaction decisions included.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.serving import admission as admission_lib
from repro.serving import control as control_lib
from repro.serving.admission import AdmissionPolicy
from repro.serving.control import (ARRIVE, HOST_DOWN, RELEASE,
                                   ControlState, Delta, EventLog,
                                   HostShard, SimTransport, Transport)
from repro.serving.failpoints import FailPlan, PREFILL_MAX_ATTEMPTS


@dataclasses.dataclass
class Request:
    """One serving request, plus the bookkeeping the engine fills in."""

    rid: int
    prompt: np.ndarray                 # (S,) int32 token / item ids
    max_gen: int                       # generation budget (incl. 1st token)
    arrival_step: int = 0              # decode-step clock of arrival
    home: int = 0                      # host shard the request arrived at
    # request kind (DESIGN.md §11): "lm" loops the autoregressive decode
    # step until a stop condition; "oneshot" takes exactly one recover
    # step after prefill and retires (the retrieval scenario's shape)
    kind: str = "lm"
    # held-out relevant item ids for offline ranking eval (-1-padded);
    # never read by the engines — carried so the eval path needs no side
    # table keyed by rid
    targets: Optional[np.ndarray] = None
    # SLO deadline (DESIGN.md §14): the last decode-step clock tick at
    # which admission still meets the request's latency budget; -1 means
    # no deadline (the pre-PR-10 behaviour — never shed on time).  A
    # queued request with ``now > deadline_step`` is shed by the
    # admission policy instead of admitted late.
    deadline_step: int = -1

    # engine-filled results
    tokens: List[int] = dataclasses.field(default_factory=list)
    topk_ids: List[int] = dataclasses.field(default_factory=list)
    topk_scores: List[float] = dataclasses.field(default_factory=list)
    admitted_step: int = -1
    finish_step: int = -1
    slot: int = -1
    rejected: bool = False             # prefill permanently failed
    requeues: int = 0                  # times reclaimed by a HOST_DOWN
    shed: bool = False                 # dropped by the admission policy

    @property
    def prompt_len(self) -> int:
        return int(len(self.prompt))

    @property
    def done(self) -> bool:
        return self.finish_step >= 0

    def fresh_copy(self, *, arrival_step: Optional[int] = None) -> "Request":
        """A new Request carrying ONLY the workload-defined fields.

        The engine-filled bookkeeping (tokens, admitted_step, slot, ...)
        is an *output* of one engine run, not an input; replaying a
        workload list through two engines (every A/B driver) must not
        share instances or the second run starts from the first run's
        state.  burst_workload and the A/B benches build their replays
        from fresh copies (see loadgen.assert_fresh_instances)."""
        return Request(
            rid=self.rid, prompt=np.array(self.prompt, copy=True),
            max_gen=self.max_gen,
            arrival_step=(self.arrival_step if arrival_step is None
                          else arrival_step),
            home=self.home, kind=self.kind,
            targets=(None if self.targets is None
                     else np.array(self.targets, copy=True)),
            deadline_step=self.deadline_step)


@dataclasses.dataclass
class ServeStats:
    """Deterministic schedule counters (+ wall-clock, never asserted on).
    Lives here, JAX-free, so the model-free simulation and the engines
    fill the identical structure."""

    decode_steps: int = 0
    idle_steps: int = 0              # clock ticks with an empty pool
    slot_steps_total: int = 0        # n_slots * decode_steps
    slot_steps_active: int = 0       # slot-steps spent on a live request
    prefills: int = 0
    tokens_out: int = 0
    compactions: int = 0             # COMPACT events executed
    # failure path (all zero on a fault-free run; as_row() omits them on
    # purpose — the committed bench baselines only carry them on rows
    # that exercise the failure model)
    host_downs: int = 0              # HOST_DOWN deltas applied
    requeued: int = 0                # in-flight requests reclaimed
    rejects: int = 0                 # prefill-exhausted REJECTs
    # overload path (DESIGN.md §14; zero on an unloaded run, omitted
    # from as_row() like the failure counters)
    sheds: int = 0                   # requests dropped by the policy
    degrades: int = 0                # degrade-ladder transitions executed
    wall_s: float = 0.0

    @property
    def utilization(self) -> float:
        if not self.slot_steps_total:
            return 1.0
        return self.slot_steps_active / self.slot_steps_total

    def as_row(self) -> Dict[str, float]:
        return {"decode_steps": self.decode_steps,
                "idle_steps": self.idle_steps,
                "slot_steps_total": self.slot_steps_total,
                "slot_steps_active": self.slot_steps_active,
                "utilization": round(self.utilization, 4),
                "prefills": self.prefills,
                "tokens_out": self.tokens_out,
                "compactions": self.compactions}


class RequestQueue:
    """Arrival-ordered queue; FIFO among requests whose arrival_step has
    passed.  push() order breaks arrival-step ties (stable).

    ``arrival_key`` customizes the arrival clock per request (default:
    ``r.arrival_step``) — the single-host engine passes the failpoint
    surge compression here so injected overload reshapes the FIFO key
    itself, exactly as the sharded ARRIVE deltas do."""

    def __init__(self, requests=(), *, arrival_key=None):
        self._key = (arrival_key if arrival_key is not None
                     else (lambda r: r.arrival_step))
        self._pending: Deque[Request] = deque(
            sorted(requests, key=self._key))

    def __len__(self) -> int:
        return len(self._pending)

    def push(self, req: Request) -> None:
        # maintain arrival order under online pushes
        self._pending.append(req)
        if (len(self._pending) > 1 and self._key(self._pending[-2])
                > self._key(req)):
            self._pending = deque(
                sorted(self._pending, key=self._key))

    def peek_ready(self, now: int) -> Optional[Request]:
        if self._pending and self._key(self._pending[0]) <= now:
            return self._pending[0]
        return None

    def pop_ready(self, now: int) -> Optional[Request]:
        if self.peek_ready(now) is None:
            return None
        return self._pending.popleft()

    def next_arrival(self) -> Optional[int]:
        return self._key(self._pending[0]) if self._pending else None

    def arrival_of(self, req: Request) -> int:
        """The queue's (possibly surge-compressed) arrival clock for
        ``req`` — what the admission policy sheds against."""
        return self._key(req)

    def visible(self, now: int) -> List[Request]:
        """Requests that have arrived (arrival_step <= now) but are
        still queued — the single-host analogue of the replicated
        visible-pending set the admission policy sheds from."""
        return [r for r in self._pending if self._key(r) <= now]

    def remove(self, rids) -> List[Request]:
        """Drop (and return) the given rids from the queue — the shed
        path.  Raises (never asserts) if any rid is not queued: queue
        integrity must survive ``python -O``."""
        rids = set(rids)
        out = [r for r in self._pending if r.rid in rids]
        if len(out) != len(rids):
            missing = rids - {r.rid for r in out}
            raise RuntimeError(
                f"shed of rids {sorted(missing)} which are not queued")
        self._pending = deque(r for r in self._pending
                              if r.rid not in rids)
        return out


class Scheduler:
    """Fixed pool of `n_slots` cache slots; admits FIFO into free slots.

    Raises on any invariant violation (double-assign, double-release) —
    the engine relies on these being impossible, and the hypothesis suite
    drives random admit/release sequences against them.  Event logging is
    the shared ``control.EventLog`` (same format as the sharded log, so
    one replay helper checks both).
    """

    def __init__(self, n_slots: int):
        assert n_slots >= 1
        self.n_slots = n_slots
        self._occupant: List[Optional[Request]] = [None] * n_slots
        self.log = EventLog()

    @property
    def admissions(self):
        return self.log.admissions

    @property
    def releases(self):
        return self.log.releases

    @property
    def compactions(self):
        return self.log.compactions

    @property
    def rejects(self):
        return self.log.rejects

    @property
    def sheds(self):
        return self.log.sheds

    @property
    def degrades(self):
        return self.log.degrades

    # ------------------------------------------------------------------
    @property
    def free_slots(self) -> List[int]:
        return [s for s, r in enumerate(self._occupant) if r is None]

    @property
    def active(self) -> Dict[int, Request]:
        return {s: r for s, r in enumerate(self._occupant) if r is not None}

    @property
    def n_active(self) -> int:
        return self.n_slots - len(self.free_slots)

    # ------------------------------------------------------------------
    def admit(self, queue: RequestQueue, now: int) -> List[Request]:
        """Admit ready requests (FIFO) into free slots; returns them with
        .slot/.admitted_step filled."""
        admitted = []
        for slot in self.free_slots:
            req = queue.pop_ready(now)
            if req is None:
                break
            if self._occupant[slot] is not None:  # pragma: no cover
                raise RuntimeError(f"slot {slot} double-assigned")
            req.slot = slot
            req.admitted_step = now
            self._occupant[slot] = req
            self.log.admission(now, slot, req.rid)
            admitted.append(req)
        return admitted

    def release(self, slot: int, now: int) -> Request:
        req = self._occupant[slot]
        if req is None:
            raise RuntimeError(f"slot {slot} released while free")
        req.finish_step = now
        self._occupant[slot] = None
        self.log.release(now, slot, req.rid)
        return req

    def reject(self, slot: int, now: int) -> Request:
        """Free a slot whose prefill permanently failed (REJECT event):
        the request finishes unserved instead of hanging the pool."""
        req = self._occupant[slot]
        if req is None:
            raise RuntimeError(f"slot {slot} rejected while free")
        req.finish_step = now
        req.rejected = True
        self._occupant[slot] = None
        self.log.reject(now, slot, req.rid)
        return req


# ---------------------------------------------------------------------------
# Sharded (multi-host) admission: transport-carried replicated state machine
# ---------------------------------------------------------------------------

class ShardedScheduler:
    """Deterministic transported admission over per-host slot shards.

    Protocol (DESIGN.md §8/§9): all scheduling inputs — request arrivals
    (at their home host) and slot releases — become deltas on a
    ``Transport`` and reach *every* host (including the producer)
    ``gossip_delay`` steps after their production step.  The replicated
    ``ControlState`` advances only by ``control.apply_deltas`` over the
    delivered deltas, and admission at step ``now`` is the pure
    ``control.compute_admissions`` over that state.  Because every host
    applies the same deltas and evaluates the same function, the
    assignment is identical everywhere; each host executes only the
    admissions inside its own slot range, so a slot (or a request) can
    never be claimed twice.  ``gossip_delay=0`` degenerates to a single
    synchronous pool — the single-host ``Scheduler`` order.

    This class is the per-host orchestrator (every replica would run this
    same code); the default ``SimTransport`` reproduces PR 3's simulated
    gossip log integer-for-integer, and ``CollectiveTransport`` carries
    the identical deltas over a fixed-size padded all_gather.
    """

    def __init__(self, n_hosts: int, slots_per_host: int,
                 gossip_delay: int = 1, *,
                 transport: Optional[Transport] = None,
                 compact_threshold: Optional[float] = None,
                 failpoints: Optional[FailPlan] = None,
                 admission_policy: Optional[AdmissionPolicy] = None):
        assert n_hosts >= 1 and slots_per_host >= 1 and gossip_delay >= 0
        self.n_hosts = n_hosts
        self.slots_per_host = slots_per_host
        self.n_slots = n_hosts * slots_per_host
        self.transport = (SimTransport(gossip_delay) if transport is None
                          else transport)
        self.gossip_delay = self.transport.delay
        assert self.gossip_delay == gossip_delay, (
            "transport delay must match gossip_delay")
        self.compact_threshold = compact_threshold
        self.failpoints = failpoints if failpoints else None
        # one plan drives scheduler AND transport (kills here; arrival
        # delays / round hangs / digest corruption in the transport) so a
        # single spec replays the identical failure schedule everywhere
        if (self.failpoints is not None
                and getattr(self.transport, "failpoints", None) is None):
            self.transport.failpoints = self.failpoints
        if getattr(self.transport, "n_hosts", None) is None:
            self.transport.n_hosts = n_hosts
        self.state = ControlState.fresh(n_hosts, slots_per_host)
        self.log = EventLog(n_hosts, slots_per_host)
        self._occupant: List[Optional[Request]] = [None] * self.n_slots
        self._requests: Dict[int, Request] = {}   # pushed, not admitted
        self._unsent: Dict[int, Request] = {}     # ARRIVE delta not sent
        self._stepped_at = -1
        # overload policy (DESIGN.md §14): sheds + the degrade ladder are
        # synchronous pure functions of replicated state, evaluated in
        # begin_step exactly once per clock tick
        self.policy = admission_policy
        self.degrade_stage = admission_lib.STAGE_NORMAL
        self._pressure: Deque[float] = deque(
            maxlen=(admission_policy.pressure_window
                    if admission_policy is not None else 1))
        self._policy_stepped = -1
        self._new_sheds: List[Request] = []
        self._new_stages: List[Tuple[int, int]] = []
        # membership: physically-dead hosts (local knowledge, applied the
        # instant the kill lands) vs the replicated live view mirrored at
        # the last apply (reclaims run when the two diverge)
        self._dead_local: set = set()
        self._applied_live = [True] * n_hosts
        self._new_kills: List[int] = []
        self._new_host_downs: List[Tuple[int, List[Request]]] = []

    # ------------------------------------------------------------------
    @property
    def admissions(self):
        return self.log.admissions

    @property
    def releases(self):
        return self.log.releases

    @property
    def compactions(self):
        return self.log.compactions

    @property
    def rejects(self):
        return self.log.rejects

    @property
    def reclaims(self):
        return self.log.reclaims

    @property
    def sheds(self):
        return self.log.sheds

    @property
    def degrades(self):
        return self.log.degrades

    @property
    def host_downs(self):
        return self.log.host_downs

    @property
    def hosts(self) -> List[HostShard]:
        return self.log.hosts

    # ------------------------------------------------------------------
    def push(self, req: Request, host: Optional[int] = None) -> None:
        """Local arrival at its home host (its ARRIVE delta enters the
        transport once the clock reaches arrival_step; visible
        cluster-wide at arrival_step + gossip_delay).

        Queue-integrity violations raise real exceptions (never bare
        asserts, which ``python -O`` strips): a duplicate rid would
        corrupt the replicated pending map and every downstream FIFO
        property."""
        if host is not None:
            req.home = host
        if not 0 <= req.home < self.n_hosts:
            raise ValueError(
                f"rid {req.rid}: home {req.home} outside "
                f"[0, {self.n_hosts})")
        if req.rid in self._requests:
            raise ValueError(f"rid {req.rid} pushed twice")
        if any(r is not None and r.rid == req.rid
               for r in self._occupant):
            raise ValueError(
                f"rid {req.rid} pushed while already admitted")
        self._requests[req.rid] = req
        self._unsent[req.rid] = req

    def push_workloads(self, per_host: List[List[Request]]) -> None:
        assert len(per_host) == self.n_hosts
        for h, reqs in enumerate(per_host):
            for r in reqs:
                self.push(r, host=h)

    # ------------------------------------------------------------------
    @property
    def n_active(self) -> int:
        return len(self.active)

    @property
    def n_pending(self) -> int:
        return len(self._requests)

    @property
    def active(self) -> Dict[int, Request]:
        """Slots actually decoding: a physically-dead host's slots drop
        out the moment the kill lands (the hardware is gone), even though
        the replicated state reclaims them only at HOST_DOWN visibility."""
        return {s: r for s, r in enumerate(self._occupant)
                if r is not None
                and self.host_of(s) not in self._dead_local}

    @property
    def recovery_pending(self) -> bool:
        """True while a HOST_DOWN delta is still in flight — the run loop
        must keep ticking so the reclaim (and re-admission) can land."""
        return bool(self.transport.pending_recovery_vis())

    def host_of(self, gslot: int) -> int:
        return gslot // self.slots_per_host

    def is_dead_slot(self, gslot: int) -> bool:
        """True when the slot's host died physically — its assignments
        are zombies until the HOST_DOWN reclaim re-queues them."""
        return self.host_of(gslot) in self._dead_local

    @property
    def live_hosts(self) -> List[int]:
        return [h for h in range(self.n_hosts)
                if h not in self._dead_local]

    # ------------------------------------------------------------------
    def _eff_arrival(self, req: Request) -> int:
        """Arrival step after any injected surge compression — the step
        the ARRIVE delta carries, so the compressed traffic is the FIFO
        key everywhere (engine, sim, both transports)."""
        if self.failpoints is None:
            return req.arrival_step
        return self.failpoints.effective_arrival(req.arrival_step)

    def _flush_arrivals(self, now: int) -> None:
        due = [r for r in self._unsent.values()
               if self._eff_arrival(r) <= now]
        for r in due:
            if r.home in self._dead_local:
                # the front door never routes new arrivals to a dead
                # host: reroute deterministically to the lowest survivor
                r.home = self.live_hosts[0]
        for r in sorted(due, key=lambda r: (self._eff_arrival(r), r.home,
                                            r.rid)):
            # the slot lane of an ARRIVE delta replicates the deadline
            # (-1 = none) — see control.apply_deltas
            self.transport.send(Delta(ARRIVE, self._eff_arrival(r),
                                      r.home, r.rid, r.deadline_step))
            del self._unsent[r.rid]

    def kill_host(self, host: int, now: int) -> None:
        """Host ``host`` dies physically at ``now``: its slots stop
        decoding immediately (``active`` excludes them from this step
        on), and the lowest surviving host reports a HOST_DOWN delta —
        every replica reclaims the dead range identically when the delta
        becomes visible.  The victim cannot report its own death."""
        assert host not in self._dead_local, f"host {host} killed twice"
        survivors = [h for h in self.live_hosts if h != host]
        if not survivors:
            raise RuntimeError("cannot kill the last live host")
        self._dead_local.add(host)
        self._new_kills.append(host)
        self.transport.send(Delta(HOST_DOWN, now, survivors[0], host))

    def begin_step(self, now: int) -> Optional[List[int]]:
        """Advance the replicated state to ``now``: execute any planned
        host kills, flush due arrivals into the transport, run the
        digest-checked exchange, apply every delta that has become
        visible (reconciling membership — reclaims + re-queues — when a
        HOST_DOWN lands), then (with compaction enabled) evaluate the
        compaction plan.  Returns the remap permutation when this step
        compacts — the data plane must apply it BEFORE this step's
        admissions/decode.  Safe to call more than once per step (kills
        are once-only, polling is idempotent, a second compaction check
        sees the already-packed state)."""
        if self.failpoints is not None:
            for h in self.failpoints.kills_at(now):
                if h not in self._dead_local:
                    self.kill_host(h, now)
        self._flush_arrivals(now)
        # digest of the pre-exchange state: every replica reports it into
        # the round, so divergence crashes before it can schedule anything
        digest = control_lib.control_digest(self.state)
        delivered = self.transport.poll(now, digest=digest)
        if delivered:
            self.state = control_lib.apply_deltas(self.state, delivered)
            self._reconcile_membership(now)
        if self.policy is not None and self._policy_stepped != now:
            # once per clock tick (begin_step is re-entrant): sheds
            # first, then the pressure sample reflects the bounded queue
            self._policy_stepped = now
            self._apply_policy(now)
        self._stepped_at = now
        if self.compact_threshold is None:
            return None
        perm = control_lib.plan_compaction(
            self.state.occupant, self.slots_per_host,
            self.compact_threshold)
        if perm is None:
            return None
        self._execute_compaction(now, perm)
        return perm

    def _reconcile_membership(self, now: int) -> None:
        """Replicated deaths became visible: mirror the reclaim that
        ``apply_deltas`` already performed on ``state`` into the
        authoritative request map — log one reclaim per seized slot,
        reset each seized request's generation (its partial tokens died
        with the host; the decode contract regenerates them bit-identical
        on re-admission) and return it to the pending pool under its
        original arrival key."""
        for h in range(self.n_hosts):
            if not self._applied_live[h] or self.state.live[h]:
                continue
            self._applied_live[h] = False
            self._dead_local.add(h)   # remote-reported death (no-op here)
            reclaimed: List[Request] = []
            for gslot in range(h * self.slots_per_host,
                               (h + 1) * self.slots_per_host):
                req = self._occupant[gslot]
                if req is None:
                    continue
                self._occupant[gslot] = None
                self.log.reclaim(now, gslot, req.rid)
                req.slot = -1
                req.admitted_step = -1
                req.tokens = []
                req.requeues += 1
                assert req.rid not in self._requests
                self._requests[req.rid] = req
                reclaimed.append(req)
            self.log.host_down(now, h, self.state.epoch)
            self._new_host_downs.append((h, reclaimed))

    def _apply_policy(self, now: int) -> None:
        """The overload pass (DESIGN.md §14): shed expired / over-bound
        queued requests, then step the degrade ladder on the windowed
        pressure signal.  Every decision is a pure function of
        (replicated state, now, policy) — replicas compute identical
        sheds and identical stage moves with nothing transported, the
        same argument as plan_compaction."""
        sheds = admission_lib.compute_sheds(
            self.state.pending, self.state.deadlines, now, self.policy)
        if sheds:
            homes = {rid: self.state.pending[rid][1]
                     for rid, _ in sheds}
            control_lib.commit_sheds(self.state,
                                     [rid for rid, _ in sheds])
            for rid, reason in sheds:
                req = self._requests.pop(rid, None)
                if req is None:
                    raise RuntimeError(
                        f"shed rid {rid} unknown to the orchestrator")
                req.shed = True
                req.finish_step = now
                self.log.shed(now, rid, reason, homes[rid])
                self._new_sheds.append(req)
        live_slots = self.slots_per_host * sum(self.state.live)
        self._pressure.append(admission_lib.pressure(
            len(self.state.pending), live_slots))
        new = admission_lib.plan_stage(self._pressure, self.policy,
                                       self.degrade_stage)
        if new != self.degrade_stage:
            self.log.degrade(now, self.degrade_stage, new)
            self._new_stages.append((self.degrade_stage, new))
            self.degrade_stage = new

    def drain_sheds(self) -> List[Request]:
        out, self._new_sheds = self._new_sheds, []
        return out

    def drain_stage_changes(self) -> List[Tuple[int, int]]:
        out, self._new_stages = self._new_stages, []
        return out

    def drain_kills(self) -> List[int]:
        out, self._new_kills = self._new_kills, []
        return out

    def drain_host_downs(self) -> List[Tuple[int, List[Request]]]:
        out, self._new_host_downs = self._new_host_downs, []
        return out

    def _execute_compaction(self, now: int, perm: List[int]) -> None:
        # replicated state and the authoritative occupant map remap with
        # the same permutation; live requests learn their new slot id
        self.state.occupant = [self.state.occupant[p] for p in perm]
        self._occupant = [self._occupant[p] for p in perm]
        for new_slot, req in enumerate(self._occupant):
            if req is not None:
                req.slot = new_slot
        self.log.compaction(now, perm)

    # ------------------------------------------------------------------
    def admit(self, now: int) -> List[Request]:
        """Execute the replicated admission function at ``now``.  Returns
        admitted requests with .slot (GLOBAL id) / .admitted_step filled;
        the owning HostShard records the event."""
        if self._stepped_at != now:
            # direct callers (no data plane) may skip begin_step; with
            # compaction or an admission policy enabled the caller MUST
            # begin_step first, or the data plane would miss the remap /
            # the shed+degrade pass (a real exception — queue integrity
            # must survive ``python -O``)
            if (self.compact_threshold is not None
                    or self.policy is not None):
                raise RuntimeError(
                    "begin_step(now) must run before admit(now) when "
                    "compaction or an admission policy is enabled")
            self.begin_step(now)
        admitted = []
        for gslot, rid in control_lib.compute_admissions(self.state):
            control_lib.commit_admission(self.state, gslot, rid)
            req = self._requests.pop(rid)
            req.slot = gslot
            req.admitted_step = now
            self._occupant[gslot] = req
            self.log.admission(now, gslot, rid)
            admitted.append(req)
        return admitted

    def release(self, gslot: int, now: int) -> Request:
        req = self._occupant[gslot]
        if req is None:
            raise RuntimeError(f"slot {gslot} released while free")
        req.finish_step = now
        self._occupant[gslot] = None
        self.log.release(now, gslot, req.rid)
        # the freed slot re-enters the replicated pool only once its
        # RELEASE delta has travelled the transport (by rid — a COMPACT
        # may remap slot ids while the delta is in flight)
        self.transport.send(Delta(RELEASE, now, self.host_of(gslot),
                                  req.rid, gslot))
        return req

    def reject(self, gslot: int, now: int) -> Request:
        """Free a slot whose prefill permanently failed: a REJECT event
        locally, a plain RELEASE delta to the replicated pool (the slot
        is free either way — only the local log knows the request ended
        unserved instead of retired)."""
        req = self._occupant[gslot]
        if req is None:
            raise RuntimeError(f"slot {gslot} rejected while free")
        req.finish_step = now
        req.rejected = True
        self._occupant[gslot] = None
        self.log.reject(now, gslot, req.rid)
        self.transport.send(Delta(RELEASE, now, self.host_of(gslot),
                                  req.rid, gslot))
        return req

    # ------------------------------------------------------------------
    def next_event_time(self, now: int) -> Optional[int]:
        """Earliest step >= now at which an admission could become
        possible (a pending request gossips into visibility, an in-flight
        release frees a slot, or an in-flight HOST_DOWN re-queues its
        victims) — the engine fast-forwards the clock here when the pool
        is empty.  Returns ``now`` itself when a slot freed during this
        step's admissions is already visible (gossip_delay=0) while a
        visible-ready request waits: the driver re-admits without a clock
        tick instead of dropping the request."""
        evs = (self.transport.pending_release_vis()
               + self.transport.pending_recovery_vis())
        if not self._requests:
            # nothing queued, but an in-flight HOST_DOWN will re-queue
            # its victims at visibility — the clock must reach it
            cands = [c for c in evs if c > now]
            return min(cands) if cands else None
        ready_at = min(self.transport.arrive_visibility(
            self._eff_arrival(r)) for r in self._requests.values())
        if ready_at <= now and any(v <= now for v in evs):
            return now
        cands = [c for c in [ready_at] + evs if c > now]
        return min(cands) if cands else None


# ---------------------------------------------------------------------------
# The shared serve loop (engine AND model-free simulation)
# ---------------------------------------------------------------------------

class ScheduleClient:
    """Data-plane hooks for ``run_schedule``.  The engine implements the
    real pool (prefill pool, jitted decode, cache compaction); the
    model-free simulation implements integer placeholders.  Sharing the
    loop is what makes the engine's event log equal the simulation's by
    construction — compaction decisions included."""

    def prefill(self, reqs: List[Request]) -> List[Optional[int]]:
        """Admitted requests (in admission order) -> first token ids.
        ``None`` for a request whose prefill permanently failed (every
        retry exhausted): the loop REJECTs it instead of hanging."""
        raise NotImplementedError

    def stopped(self, req: Request, tok: int) -> bool:
        """Called after ``tok`` was appended to req.tokens."""
        return len(req.tokens) >= req.max_gen

    def start_slot(self, req: Request, first: int) -> None:
        """A non-stopped admission begins decoding in req.slot."""

    def decode(self, active: Dict[int, Request]) -> Dict[int, int]:
        """One pool decode step -> token id per live slot."""
        raise NotImplementedError

    def advance_slot(self, gslot: int, req: Request, tok: int) -> None:
        """Per live slot after a decode step (token already appended)."""

    def stop_slot(self, gslot: int) -> None:
        """A live slot retired (release already recorded)."""

    def compact(self, perm: List[int]) -> None:
        """Apply the COMPACT remap to the data plane (perm[new]=old)."""

    def host_killed(self, host: int) -> None:
        """``host`` died physically this step: its slot range must stop
        decoding NOW (before HOST_DOWN visibility)."""

    def host_down(self, host: int, reqs: List[Request]) -> None:
        """``host``'s death became visible; ``reqs`` were reclaimed and
        re-queued.  The data plane may scrub the dead range."""

    def set_stage(self, stage: int) -> None:
        """The degrade ladder moved to ``stage`` (DESIGN.md §14): the
        data plane swaps to that stage's PRE-BUILT decode callable —
        a jit swap, never a compile (the model-free sim ignores it;
        degradation is schedule-invariant by design)."""


def run_schedule(sched: ShardedScheduler, client: ScheduleClient,
                 stats: Optional[ServeStats] = None) -> ServeStats:
    """THE admit -> fast-forward -> decode -> retire loop (DESIGN.md §9),
    shared by ``ShardedEngine.run`` and ``simulate_sharded_schedule``.
    One clock tick per pool decode step; requests admitted this step emit
    their first (prefill) token before the step's decode."""
    stats = stats or ServeStats()
    stalls = 0
    now = 0
    while sched.n_pending or sched.n_active or sched.recovery_pending:
        perm = sched.begin_step(now)
        for host in sched.drain_kills():
            client.host_killed(host)
        for host, reqs in sched.drain_host_downs():
            stats.host_downs += 1
            stats.requeued += len(reqs)
            client.host_down(host, reqs)
        stats.sheds += len(sched.drain_sheds())
        for _, stage in sched.drain_stage_changes():
            stats.degrades += 1
            client.set_stage(stage)
        if perm is not None:
            stats.compactions += 1
            client.compact(perm)
        admitted = sched.admit(now)
        # an admission may land on a host that died physically while its
        # HOST_DOWN is still in flight — the replicated assignment cannot
        # know yet, and a dead host can neither prefill nor release.  The
        # slot sits as a zombie (excluded from `active`) until the
        # HOST_DOWN reclaim re-queues the request under its original key.
        live_admits = [r for r in admitted
                       if not sched.is_dead_slot(r.slot)]
        firsts = client.prefill(live_admits) if live_admits else []
        for req, first in zip(live_admits, firsts):
            if first is None:
                stats.rejects += 1
                sched.reject(req.slot, now)
                continue
            req.tokens.append(first)
            stats.prefills += 1
            stats.tokens_out += 1
            if client.stopped(req, first):
                sched.release(req.slot, now)
            else:
                client.start_slot(req, first)
        if not sched.n_active:
            nxt = sched.next_event_time(now)
            if nxt is None:
                break
            if nxt < now:  # pragma: no cover
                raise RuntimeError("scheduler clock went backwards")
            if nxt == now:
                # a slot freed during this step's admissions is already
                # visible (delay 0): re-admit at the same clock tick
                stalls += 1
                if not admitted and stalls > 2:  # pragma: no cover
                    raise RuntimeError("scheduler made no progress")
                continue
            stalls = 0
            stats.idle_steps += nxt - now
            now = nxt
            continue
        stalls = 0
        toks = client.decode(sched.active)
        stats.decode_steps += 1
        stats.slot_steps_total += sched.n_slots
        stats.slot_steps_active += sched.n_active
        # an injected slow_decode makes each decode step cost N clock
        # ticks: arrivals pile up during the slow steps, which is what
        # drives the pressure signal in the overload drills
        now += (sched.failpoints.decode_cost(now)
                if sched.failpoints is not None else 1)
        for gslot, req in list(sched.active.items()):
            tok = toks[gslot]
            req.tokens.append(tok)
            stats.tokens_out += 1
            client.advance_slot(gslot, req, tok)
            if client.stopped(req, tok):
                sched.release(gslot, now)
                client.stop_slot(gslot)
    return stats


class _SimClient(ScheduleClient):
    """Model-free placeholders: every request occupies its slot for
    exactly ``max_gen`` emitted tokens (1 at prefill/admission +
    max_gen - 1 decode steps; no EOS).  Token i of request rid is the
    pure function ``rid * _TOKEN_BASE + i`` — the same shape of contract
    the real engine's greedy row-independent decode satisfies — so a
    request reclaimed by a HOST_DOWN regenerates the bit-identical
    stream on re-admission and the chaos properties can assert token
    equality on the model-free sim too.  With a ``FailPlan``, prefill
    mirrors the pool's retry loop via the shared pure predicate
    ``FailPlan.prefill_rejects``."""

    _TOKEN_BASE = 100_000

    def __init__(self, failpoints: Optional[FailPlan] = None):
        self.failpoints = failpoints if failpoints else None

    def _tok(self, req):
        return req.rid * self._TOKEN_BASE + len(req.tokens)

    def prefill(self, reqs):
        out = []
        for r in reqs:
            if (self.failpoints is not None
                    and self.failpoints.prefill_rejects(
                        r.rid, PREFILL_MAX_ATTEMPTS)):
                out.append(None)
            else:
                out.append(self._tok(r))
        return out

    def decode(self, active):
        return {gslot: self._tok(req) for gslot, req in active.items()}


def simulate_sharded_schedule(per_host: List[List[Request]],
                              slots_per_host: int, gossip_delay: int = 1,
                              *, transport: Optional[Transport] = None,
                              compact_threshold: Optional[float] = None,
                              failpoints: Optional[FailPlan] = None,
                              admission_policy: Optional[AdmissionPolicy]
                              = None,
                              ) -> Tuple[ShardedScheduler, ServeStats]:
    """Model-free replay of the sharded engine's schedule — the SAME
    ``run_schedule`` loop over placeholder tokens, so the engine's event
    log must match this one exactly, COMPACT / reclaim / reject events
    included (asserted by tests/test_serving_multihost.py).
    Deterministic integers only: bench_serving.py commits its outputs as
    a CI baseline.  ``failpoints`` replays a failure schedule against
    the placeholders — same kills, same requeues, same rejects as the
    engine run with the same plan."""
    sched = ShardedScheduler(len(per_host), slots_per_host, gossip_delay,
                             transport=transport,
                             compact_threshold=compact_threshold,
                             failpoints=failpoints,
                             admission_policy=admission_policy)
    sched.push_workloads(per_host)
    stats = run_schedule(sched, _SimClient(failpoints))
    return sched, stats
