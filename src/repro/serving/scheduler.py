"""Request queue + slot scheduler for the continuous-batching engine.

Deliberately JAX-free: admission policy is host-side control flow over a
fixed pool of cache slots (the device-side pool lives in engine.py), so
the invariants — slot conservation, FIFO admission among ready requests,
no starvation — are testable with hypothesis in microseconds.

Time is measured in *decode steps*: the engine advances the clock once
per jitted decode step, and a request with ``arrival_step = t`` becomes
admissible the first time the clock reaches t.  That makes every schedule
a deterministic function of (workload, n_slots) — the property CI runs on
CPU without ever touching the model.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class Request:
    """One serving request, plus the bookkeeping the engine fills in."""

    rid: int
    prompt: np.ndarray                 # (S,) int32 token ids
    max_gen: int                       # generation budget (incl. 1st token)
    arrival_step: int = 0              # decode-step clock of arrival

    # engine-filled results
    tokens: List[int] = dataclasses.field(default_factory=list)
    admitted_step: int = -1
    finish_step: int = -1
    slot: int = -1

    @property
    def prompt_len(self) -> int:
        return int(len(self.prompt))

    @property
    def done(self) -> bool:
        return self.finish_step >= 0


class RequestQueue:
    """Arrival-ordered queue; FIFO among requests whose arrival_step has
    passed.  push() order breaks arrival-step ties (stable)."""

    def __init__(self, requests=()):
        self._pending: Deque[Request] = deque(
            sorted(requests, key=lambda r: r.arrival_step))

    def __len__(self) -> int:
        return len(self._pending)

    def push(self, req: Request) -> None:
        # maintain arrival order under online pushes
        self._pending.append(req)
        if (len(self._pending) > 1 and self._pending[-2].arrival_step
                > req.arrival_step):
            self._pending = deque(
                sorted(self._pending, key=lambda r: r.arrival_step))

    def peek_ready(self, now: int) -> Optional[Request]:
        if self._pending and self._pending[0].arrival_step <= now:
            return self._pending[0]
        return None

    def pop_ready(self, now: int) -> Optional[Request]:
        if self.peek_ready(now) is None:
            return None
        return self._pending.popleft()

    def next_arrival(self) -> Optional[int]:
        return self._pending[0].arrival_step if self._pending else None


class Scheduler:
    """Fixed pool of `n_slots` cache slots; admits FIFO into free slots.

    Raises on any invariant violation (double-assign, double-release) —
    the engine relies on these being impossible, and the hypothesis suite
    drives random admit/release sequences against them.
    """

    def __init__(self, n_slots: int):
        assert n_slots >= 1
        self.n_slots = n_slots
        self._occupant: List[Optional[Request]] = [None] * n_slots
        # event log: (step, slot, rid, seq) — the deterministic sim test
        # reconstructs occupancy from this to prove no double-assignment;
        # `seq` is a global monotonic counter because several events can
        # share one step (release + re-admit at the same clock tick)
        self.admissions: List[Tuple[int, int, int, int]] = []
        self.releases: List[Tuple[int, int, int, int]] = []
        self._seq = 0

    # ------------------------------------------------------------------
    @property
    def free_slots(self) -> List[int]:
        return [s for s, r in enumerate(self._occupant) if r is None]

    @property
    def active(self) -> Dict[int, Request]:
        return {s: r for s, r in enumerate(self._occupant) if r is not None}

    @property
    def n_active(self) -> int:
        return self.n_slots - len(self.free_slots)

    # ------------------------------------------------------------------
    def admit(self, queue: RequestQueue, now: int) -> List[Request]:
        """Admit ready requests (FIFO) into free slots; returns them with
        .slot/.admitted_step filled."""
        admitted = []
        for slot in self.free_slots:
            req = queue.pop_ready(now)
            if req is None:
                break
            if self._occupant[slot] is not None:  # pragma: no cover
                raise RuntimeError(f"slot {slot} double-assigned")
            req.slot = slot
            req.admitted_step = now
            self._occupant[slot] = req
            self.admissions.append((now, slot, req.rid, self._seq))
            self._seq += 1
            admitted.append(req)
        return admitted

    def release(self, slot: int, now: int) -> Request:
        req = self._occupant[slot]
        if req is None:
            raise RuntimeError(f"slot {slot} released while free")
        req.finish_step = now
        self._occupant[slot] = None
        self.releases.append((now, slot, req.rid, self._seq))
        self._seq += 1
        return req
