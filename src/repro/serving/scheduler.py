"""Request queues + slot schedulers for the continuous-batching engine.

Deliberately JAX-free: admission policy is host-side control flow over a
fixed pool of cache slots (the device-side pool lives in engine.py /
sharded_pool.py), so the invariants — slot conservation, FIFO admission
among ready requests, no starvation, and (sharded) no cross-host slot
double-claim — are testable with hypothesis in microseconds.

Time is measured in *decode steps*: the engine advances the clock once
per jitted decode step, and a request with ``arrival_step = t`` becomes
admissible the first time the clock reaches t.  That makes every schedule
a deterministic function of (workload, n_slots) — the property CI runs on
CPU without ever touching the model.

Two schedulers live here:

  * ``Scheduler`` — the single-host FIFO slot pool from PR 2.
  * ``ShardedScheduler`` — the multi-host admission protocol (DESIGN.md
    §8): the global slot pool is partitioned into per-host shards, and
    admission runs as a *deterministic replicated state machine* over a
    gossiped event log.  Every scheduling event (request arrival at its
    home host, slot release) becomes globally visible ``gossip_delay``
    steps after it happens — including to the host that produced it, so
    every host replays the identical merged event prefix and computes the
    identical admission assignment.  A host then *executes* only the
    admissions that land in its own slot range; no two hosts can ever
    claim the same slot or the same request.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class Request:
    """One serving request, plus the bookkeeping the engine fills in."""

    rid: int
    prompt: np.ndarray                 # (S,) int32 token ids
    max_gen: int                       # generation budget (incl. 1st token)
    arrival_step: int = 0              # decode-step clock of arrival
    home: int = 0                      # host shard the request arrived at

    # engine-filled results
    tokens: List[int] = dataclasses.field(default_factory=list)
    admitted_step: int = -1
    finish_step: int = -1
    slot: int = -1

    @property
    def prompt_len(self) -> int:
        return int(len(self.prompt))

    @property
    def done(self) -> bool:
        return self.finish_step >= 0


class RequestQueue:
    """Arrival-ordered queue; FIFO among requests whose arrival_step has
    passed.  push() order breaks arrival-step ties (stable)."""

    def __init__(self, requests=()):
        self._pending: Deque[Request] = deque(
            sorted(requests, key=lambda r: r.arrival_step))

    def __len__(self) -> int:
        return len(self._pending)

    def push(self, req: Request) -> None:
        # maintain arrival order under online pushes
        self._pending.append(req)
        if (len(self._pending) > 1 and self._pending[-2].arrival_step
                > req.arrival_step):
            self._pending = deque(
                sorted(self._pending, key=lambda r: r.arrival_step))

    def peek_ready(self, now: int) -> Optional[Request]:
        if self._pending and self._pending[0].arrival_step <= now:
            return self._pending[0]
        return None

    def pop_ready(self, now: int) -> Optional[Request]:
        if self.peek_ready(now) is None:
            return None
        return self._pending.popleft()

    def next_arrival(self) -> Optional[int]:
        return self._pending[0].arrival_step if self._pending else None


class Scheduler:
    """Fixed pool of `n_slots` cache slots; admits FIFO into free slots.

    Raises on any invariant violation (double-assign, double-release) —
    the engine relies on these being impossible, and the hypothesis suite
    drives random admit/release sequences against them.
    """

    def __init__(self, n_slots: int):
        assert n_slots >= 1
        self.n_slots = n_slots
        self._occupant: List[Optional[Request]] = [None] * n_slots
        # event log: (step, slot, rid, seq) — the deterministic sim test
        # reconstructs occupancy from this to prove no double-assignment;
        # `seq` is a global monotonic counter because several events can
        # share one step (release + re-admit at the same clock tick)
        self.admissions: List[Tuple[int, int, int, int]] = []
        self.releases: List[Tuple[int, int, int, int]] = []
        self._seq = 0

    # ------------------------------------------------------------------
    @property
    def free_slots(self) -> List[int]:
        return [s for s, r in enumerate(self._occupant) if r is None]

    @property
    def active(self) -> Dict[int, Request]:
        return {s: r for s, r in enumerate(self._occupant) if r is not None}

    @property
    def n_active(self) -> int:
        return self.n_slots - len(self.free_slots)

    # ------------------------------------------------------------------
    def admit(self, queue: RequestQueue, now: int) -> List[Request]:
        """Admit ready requests (FIFO) into free slots; returns them with
        .slot/.admitted_step filled."""
        admitted = []
        for slot in self.free_slots:
            req = queue.pop_ready(now)
            if req is None:
                break
            if self._occupant[slot] is not None:  # pragma: no cover
                raise RuntimeError(f"slot {slot} double-assigned")
            req.slot = slot
            req.admitted_step = now
            self._occupant[slot] = req
            self.admissions.append((now, slot, req.rid, self._seq))
            self._seq += 1
            admitted.append(req)
        return admitted

    def release(self, slot: int, now: int) -> Request:
        req = self._occupant[slot]
        if req is None:
            raise RuntimeError(f"slot {slot} released while free")
        req.finish_step = now
        self._occupant[slot] = None
        self.releases.append((now, slot, req.rid, self._seq))
        self._seq += 1
        return req


# ---------------------------------------------------------------------------
# Sharded (multi-host) admission: gossiped replicated-state-machine queue
# ---------------------------------------------------------------------------

class HostShard:
    """One host's slice of the global slot pool: the contiguous global
    slot range [host * slots_per_host, (host+1) * slots_per_host) plus the
    host-local event log.  Events carry GLOBAL slot ids and the global
    event seq, so the merged log is reconstructible from the per-host logs
    (linearization — tested in tests/test_property.py)."""

    def __init__(self, host: int, slots_per_host: int):
        self.host = host
        self.slots_per_host = slots_per_host
        self.lo = host * slots_per_host
        self.hi = (host + 1) * slots_per_host
        self.admissions: List[Tuple[int, int, int, int]] = []
        self.releases: List[Tuple[int, int, int, int]] = []

    def owns(self, gslot: int) -> bool:
        return self.lo <= gslot < self.hi


class ShardedScheduler:
    """Deterministic gossiped admission over per-host slot shards.

    Protocol (DESIGN.md §8): all scheduling inputs — request arrivals
    (pushed at their home host) and slot releases — enter a logically
    replicated event log and become *globally visible* ``gossip_delay``
    decode steps after they happen, uniformly, including to the host that
    produced them.  Admission at step ``now`` is then a pure function of
    the visible prefix: the visible-ready requests, ordered by
    (arrival_step, home, rid), are assigned to the visible-free slots in
    global slot order.  Because every host evaluates the same function on
    the same prefix, the assignment is identical everywhere; each host
    executes only the admissions inside its own slot range, so a slot (or
    a request) can never be claimed twice.  ``gossip_delay=0`` degenerates
    to a single synchronous pool — the single-host ``Scheduler`` order.

    This class *is* the simulation of that protocol: one authoritative
    merged state, with per-host logs recorded on the owning ``HostShard``.
    Determinism (two replicas replaying identical logs) is asserted by
    tests/test_serving_multihost.py; the hypothesis suite drives random
    traffic against the invariants.
    """

    def __init__(self, n_hosts: int, slots_per_host: int,
                 gossip_delay: int = 1):
        assert n_hosts >= 1 and slots_per_host >= 1 and gossip_delay >= 0
        self.n_hosts = n_hosts
        self.slots_per_host = slots_per_host
        self.n_slots = n_hosts * slots_per_host
        self.gossip_delay = gossip_delay
        self.hosts = [HostShard(h, slots_per_host) for h in range(n_hosts)]
        self._pending: List[Request] = []
        self._occupant: List[Optional[Request]] = [None] * self.n_slots
        # step at which the slot's free status is globally visible
        self._free_vis: List[int] = [0] * self.n_slots
        self.admissions: List[Tuple[int, int, int, int]] = []
        self.releases: List[Tuple[int, int, int, int]] = []
        self._seq = 0

    # ------------------------------------------------------------------
    def push(self, req: Request, host: Optional[int] = None) -> None:
        """Local arrival at its home host (visible cluster-wide at
        arrival_step + gossip_delay)."""
        if host is not None:
            req.home = host
        assert 0 <= req.home < self.n_hosts
        self._pending.append(req)

    def push_workloads(self, per_host: List[List[Request]]) -> None:
        assert len(per_host) == self.n_hosts
        for h, reqs in enumerate(per_host):
            for r in reqs:
                self.push(r, host=h)

    # ------------------------------------------------------------------
    @property
    def n_active(self) -> int:
        return sum(r is not None for r in self._occupant)

    @property
    def n_pending(self) -> int:
        return len(self._pending)

    @property
    def active(self) -> Dict[int, Request]:
        return {s: r for s, r in enumerate(self._occupant) if r is not None}

    def host_of(self, gslot: int) -> int:
        return gslot // self.slots_per_host

    def _visible_ready(self, now: int) -> List[Request]:
        return sorted(
            (r for r in self._pending
             if r.arrival_step + self.gossip_delay <= now),
            key=lambda r: (r.arrival_step, r.home, r.rid))

    def _visible_free(self, now: int) -> List[int]:
        return [s for s in range(self.n_slots)
                if self._occupant[s] is None and self._free_vis[s] <= now]

    # ------------------------------------------------------------------
    def admit(self, now: int) -> List[Request]:
        """The replicated admission function: visible-ready requests ->
        visible-free slots, both in deterministic global order.  Returns
        admitted requests with .slot (GLOBAL id) / .admitted_step filled;
        the owning HostShard records the event."""
        admitted = []
        for gslot, req in zip(self._visible_free(now),
                              self._visible_ready(now)):
            if self._occupant[gslot] is not None:  # pragma: no cover
                raise RuntimeError(f"slot {gslot} double-assigned")
            req.slot = gslot
            req.admitted_step = now
            self._occupant[gslot] = req
            ev = (now, gslot, req.rid, self._seq)
            self.admissions.append(ev)
            self.hosts[self.host_of(gslot)].admissions.append(ev)
            self._seq += 1
            admitted.append(req)
        if admitted:
            taken = {id(r) for r in admitted}
            self._pending = [r for r in self._pending
                             if id(r) not in taken]
        return admitted

    def release(self, gslot: int, now: int) -> Request:
        req = self._occupant[gslot]
        if req is None:
            raise RuntimeError(f"slot {gslot} released while free")
        req.finish_step = now
        self._occupant[gslot] = None
        # the freed slot re-enters the pool only once gossip has spread it
        self._free_vis[gslot] = now + self.gossip_delay
        ev = (now, gslot, req.rid, self._seq)
        self.releases.append(ev)
        self.hosts[self.host_of(gslot)].releases.append(ev)
        self._seq += 1
        return req

    # ------------------------------------------------------------------
    def next_event_time(self, now: int) -> Optional[int]:
        """Earliest step > now at which an admission could become possible
        (a pending request or a freed slot gossips into visibility) — the
        engine fast-forwards the clock here when the pool is empty."""
        cands = []
        if self._pending:
            cands.append(min(r.arrival_step for r in self._pending)
                         + self.gossip_delay)
            cands.extend(v for s, v in enumerate(self._free_vis)
                         if self._occupant[s] is None and v > now)
        cands = [c for c in cands if c > now]
        return min(cands) if cands else None


def simulate_sharded_schedule(per_host: List[List[Request]],
                              slots_per_host: int, gossip_delay: int = 1
                              ) -> Tuple[ShardedScheduler, Dict[str, int]]:
    """Model-free replay of the sharded engine's schedule: every request
    occupies its slot for exactly ``max_gen`` emitted tokens (1 at
    prefill/admission + max_gen-1 decode steps; no EOS), one clock tick
    per pool decode step — the same loop order as ShardedEngine.run, so
    the engine's event log must match this one exactly (asserted by
    tests/test_serving_multihost.py).  Deterministic integers only:
    bench_serving.py commits its outputs as a CI baseline.
    """
    sched = ShardedScheduler(len(per_host), slots_per_host, gossip_delay)
    sched.push_workloads(per_host)
    remaining: Dict[int, int] = {}
    stats = {"decode_steps": 0, "idle_steps": 0, "slot_steps_total": 0,
             "slot_steps_active": 0, "tokens_out": 0}
    now = 0
    while sched.n_pending or sched.n_active:
        for req in sched.admit(now):
            req.tokens.append(-1)          # placeholder first token
            stats["tokens_out"] += 1
            if req.max_gen <= 1:
                sched.release(req.slot, now)
            else:
                remaining[req.rid] = req.max_gen - 1
        if not sched.n_active:
            nxt = sched.next_event_time(now)
            if nxt is None:
                break
            if nxt <= now:                 # pragma: no cover
                raise RuntimeError("scheduler clock did not advance")
            stats["idle_steps"] += nxt - now
            now = nxt
            continue
        stats["decode_steps"] += 1
        stats["slot_steps_total"] += sched.n_slots
        stats["slot_steps_active"] += sched.n_active
        now += 1
        for gslot, req in list(sched.active.items()):
            req.tokens.append(-1)
            stats["tokens_out"] += 1
            remaining[req.rid] -= 1
            if remaining[req.rid] <= 0:
                sched.release(gslot, now)
    return sched, stats
