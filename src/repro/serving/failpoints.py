"""Seeded, deterministic fault injection for the serving + training stack.

One registry (`FailPlan`) describes every fault a run will experience, as
pure data: which host dies at which step, which transport round hangs,
which prefill attempts fail, which replica reports a corrupted state
digest, at which train step the driver raises.  The plan is consulted by
the scheduler (host kills), both transports (round hangs, digest
corruption, arrival delays), the prefill pool (per-attempt worker
failures), and the train driver (induced crash) — so the engine run, the
model-free simulation, the bench row, and the CI chaos job all replay the
IDENTICAL failure schedule from one committed spec string.

The module is dependency-free (no jax, no numpy at import time) so the
train driver can import it without touching the serving stack.

Spec grammar — comma-separated failpoints, order irrelevant:

    kill_host:H@S        host H dies physically at step S (its slots stop
                         decoding at S; a HOST_DOWN delta gossips out and
                         every replica reclaims the range at visibility)
    delay_arrivals:D@S   ARRIVE deltas produced at step S become visible
                         D steps later than the transport's base delay
    hang_round:D@S       the transport round at step S takes D virtual
                         time units; rounds past the transport deadline
                         raise TransportTimeout instead of blocking
    fail_prefill:R:N     request R's first N prefill attempts raise; the
                         pool retries on other workers and REJECTs after
                         PREFILL_MAX_ATTEMPTS
    corrupt_digest:H@S   host H's replica reports a flipped state digest
                         in the round at step S (models silent divergence;
                         both transports must raise ReplicaDivergence)
    train_fault@S        the training loop raises at step S (the crash
                         the checkpoint/resume path must survive)
    surge:R@S            arrival-rate multiplier: arrivals scheduled at or
                         after step S are compressed toward S by factor R
                         (eff = S + (a - S) // R) — the open-loop traffic
                         spike that overwhelms the pool (DESIGN.md §14)
    slow_decode:N@S      from step S onward each decode step costs N clock
                         ticks instead of 1 (models a degraded accelerator
                         or noisy neighbour; arrivals pile up during the
                         slow steps, driving the pressure signal)

Delays apply to ARRIVE deltas only: a RELEASE or HOST_DOWN delta always
travels at the transport's base delay.  This is load-bearing — see
DESIGN.md §10 for why selectively delaying completion reports past a
host death would need an acknowledged-completion protocol to stay safe.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

# Shared by the real PrefillPool and the model-free sim client so both
# compute the same succeeds/rejects outcome from one plan.
PREFILL_MAX_ATTEMPTS = 3

KILL_HOST = "kill_host"
DELAY_ARRIVALS = "delay_arrivals"
HANG_ROUND = "hang_round"
FAIL_PREFILL = "fail_prefill"
CORRUPT_DIGEST = "corrupt_digest"
TRAIN_FAULT = "train_fault"
SURGE = "surge"
SLOW_DECODE = "slow_decode"

_KINDS = (KILL_HOST, DELAY_ARRIVALS, HANG_ROUND, FAIL_PREFILL,
          CORRUPT_DIGEST, TRAIN_FAULT, SURGE, SLOW_DECODE)


@dataclasses.dataclass(frozen=True)
class Failpoint:
    """One injected fault.  Field meaning depends on `kind`:

    kill_host:       host=victim,   step=death step
    delay_arrivals:  delay=extra,   step=production step it applies to
    hang_round:      delay=virtual round duration, step=the hung round
    fail_prefill:    rid=victim,    count=number of failing attempts
    corrupt_digest:  host=replica,  step=the corrupted round
    train_fault:     step=train step at which the driver raises
    surge:           count=rate multiplier, step=first compressed step
    slow_decode:     delay=ticks per decode step, step=first slow step
    """
    kind: str
    step: int = -1
    host: int = -1
    rid: int = -1
    count: int = 1
    delay: int = 0

    def spec(self) -> str:
        if self.kind == KILL_HOST:
            return f"{KILL_HOST}:{self.host}@{self.step}"
        if self.kind == DELAY_ARRIVALS:
            return f"{DELAY_ARRIVALS}:{self.delay}@{self.step}"
        if self.kind == HANG_ROUND:
            return f"{HANG_ROUND}:{self.delay}@{self.step}"
        if self.kind == FAIL_PREFILL:
            return f"{FAIL_PREFILL}:{self.rid}:{self.count}"
        if self.kind == CORRUPT_DIGEST:
            return f"{CORRUPT_DIGEST}:{self.host}@{self.step}"
        if self.kind == TRAIN_FAULT:
            return f"{TRAIN_FAULT}@{self.step}"
        if self.kind == SURGE:
            return f"{SURGE}:{self.count}@{self.step}"
        if self.kind == SLOW_DECODE:
            return f"{SLOW_DECODE}:{self.delay}@{self.step}"
        raise ValueError(f"unknown failpoint kind {self.kind!r}")


def _parse_one(tok: str) -> Failpoint:
    tok = tok.strip()
    if not tok:
        raise ValueError("empty failpoint token")
    head, _, tail = tok.partition(":")
    if head.partition("@")[0] == TRAIN_FAULT:
        # train_fault@S has no ':' segment
        head, _, at = tok.partition("@")
        if not at:
            raise ValueError(f"bad failpoint {tok!r}")
        return Failpoint(TRAIN_FAULT, step=int(at))
    if head not in _KINDS:
        raise ValueError(f"unknown failpoint kind {head!r} in {tok!r}")
    if head == FAIL_PREFILL:
        rid_s, _, n_s = tail.partition(":")
        return Failpoint(FAIL_PREFILL, rid=int(rid_s),
                         count=int(n_s) if n_s else 1)
    val_s, _, at_s = tail.partition("@")
    if not at_s:
        raise ValueError(f"failpoint {tok!r} needs an @step")
    val, step = int(val_s), int(at_s)
    if head == KILL_HOST:
        return Failpoint(KILL_HOST, step=step, host=val)
    if head == DELAY_ARRIVALS:
        return Failpoint(DELAY_ARRIVALS, step=step, delay=val)
    if head == HANG_ROUND:
        return Failpoint(HANG_ROUND, step=step, delay=val)
    if head == SURGE:
        if val < 2:
            raise ValueError(
                f"surge factor must be >= 2, got {val} in {tok!r}")
        return Failpoint(SURGE, step=step, count=val)
    if head == SLOW_DECODE:
        if val < 2:
            raise ValueError(
                f"slow_decode ticks must be >= 2, got {val} in {tok!r}")
        return Failpoint(SLOW_DECODE, step=step, delay=val)
    return Failpoint(CORRUPT_DIGEST, step=step, host=val)


@dataclasses.dataclass(frozen=True)
class FailPlan:
    """An immutable failure schedule; query methods are pure functions of
    (plan, step/rid/attempt), so any component consulting the same plan
    at the same point computes the same fault — the determinism the chaos
    tests lean on."""
    points: Tuple[Failpoint, ...] = ()

    # -- construction --------------------------------------------------
    @classmethod
    def parse(cls, spec: Optional[str]) -> "FailPlan":
        """Parse a comma-separated spec string; '' / None -> empty plan."""
        if not spec:
            return cls(())
        return cls(tuple(_parse_one(t) for t in spec.split(",") if
                         t.strip()))

    @classmethod
    def single_kill(cls, host: int, step: int) -> "FailPlan":
        return cls((Failpoint(KILL_HOST, step=step, host=host),))

    def merge(self, other: "FailPlan") -> "FailPlan":
        """Union of two plans (duplicates kept — every query sums or
        any()s over points, so repeats are harmless)."""
        return FailPlan(self.points + other.points)

    def spec(self) -> str:
        return ",".join(p.spec() for p in self.points)

    def __str__(self) -> str:
        return self.spec()

    def __bool__(self) -> bool:
        return bool(self.points)

    # -- queries -------------------------------------------------------
    def kills_at(self, step: int) -> List[int]:
        """Hosts that die at exactly `step`, in deterministic order."""
        return sorted(p.host for p in self.points
                      if p.kind == KILL_HOST and p.step == step)

    def kill_steps(self) -> List[int]:
        return sorted(p.step for p in self.points if p.kind == KILL_HOST)

    def arrive_extra_delay(self, step: int) -> int:
        """Extra visibility delay for ARRIVE deltas produced at `step`."""
        return sum(p.delay for p in self.points
                   if p.kind == DELAY_ARRIVALS and p.step == step)

    def round_hang(self, step: int) -> int:
        """Virtual duration of the transport round at `step` (0 = fast)."""
        return sum(p.delay for p in self.points
                   if p.kind == HANG_ROUND and p.step == step)

    def prefill_attempt_fails(self, rid: int, attempt: int) -> bool:
        """Does request `rid`'s `attempt`-th prefill attempt raise?"""
        return any(p.kind == FAIL_PREFILL and p.rid == rid
                   and attempt < p.count for p in self.points)

    def prefill_rejects(self, rid: int,
                        max_attempts: int = PREFILL_MAX_ATTEMPTS) -> bool:
        """Pure predicate: will `rid` exhaust every attempt and be
        REJECTed?  The model-free sim uses this to mirror the pool's
        retry loop without running it."""
        return all(self.prefill_attempt_fails(rid, a)
                   for a in range(max_attempts))

    def digest_mask(self, host: int, step: int) -> int:
        """XOR mask applied to `host`'s reported state digest in the
        round at `step`; 0 means the replica reports honestly."""
        hit = any(p.kind == CORRUPT_DIGEST and p.host == host
                  and p.step == step for p in self.points)
        return 0x5A5A5A5A if hit else 0

    def effective_arrival(self, step: int) -> int:
        """Arrival step after every surge compression has been applied.

        Each ``surge:R@S`` pulls arrivals scheduled at or after S toward
        S: ``a -> S + (a - S) // R``.  Surges apply in ascending-S order
        so stacked surges compose deterministically; steps before every
        surge are untouched.  Pure in (plan, step) — the scheduler AND
        the model-free sim both route arrivals through this, so the
        compressed traffic is identical everywhere."""
        for p in sorted(((p.step, p.count) for p in self.points
                         if p.kind == SURGE)):
            s, factor = p
            if step >= s:
                step = s + (step - s) // factor
        return step

    def surge_steps(self) -> List[int]:
        return sorted(p.step for p in self.points if p.kind == SURGE)

    def decode_cost(self, step: int) -> int:
        """Clock ticks one decode step costs at `step` (1 = healthy).
        The largest active ``slow_decode`` wins; slowdowns are permanent
        from their onset step, like kills."""
        costs = [p.delay for p in self.points
                 if p.kind == SLOW_DECODE and step >= p.step]
        return max(costs, default=1)

    def overload_steps(self) -> List[int]:
        """Onset steps of every overload failpoint (surge + slow_decode);
        empty means the plan injects no overload — drills gate their
        verified markers on this, like kill_steps()."""
        return sorted(p.step for p in self.points
                      if p.kind in (SURGE, SLOW_DECODE))

    def train_hook(self) -> Optional[Callable[[int], None]]:
        """A Trainer/driver `fault_hook` raising at the planned step, or
        None if the plan injects no train fault.  The message is part of
        the crash-and-resume contract (tests grep for it)."""
        steps = sorted(p.step for p in self.points
                       if p.kind == TRAIN_FAULT)
        if not steps:
            return None

        def hook(step: int) -> None:
            if step in steps:
                raise RuntimeError(f"induced fault at step {step}")

        return hook

    # -- generation ----------------------------------------------------
    @classmethod
    def sample_kills(cls, seed: int, n_hosts: int, lo: int, hi: int,
                     n_kills: int = 1) -> "FailPlan":
        """Seeded random kill schedule: `n_kills` distinct hosts (always
        leaving at least one survivor) die at steps drawn from [lo, hi).
        Pure python LCG so the plan is identical on every platform."""
        assert 0 < n_kills < n_hosts
        state = (seed * 2654435761 + 97531) & 0xFFFFFFFF
        hosts = list(range(n_hosts))
        points = []
        for _ in range(n_kills):
            state = (state * 1103515245 + 12345) & 0x7FFFFFFF
            h = hosts.pop(state % len(hosts))
            state = (state * 1103515245 + 12345) & 0x7FFFFFFF
            s = lo + state % max(1, hi - lo)
            points.append(Failpoint(KILL_HOST, step=s, host=h))
        return cls(tuple(sorted(points, key=lambda p: (p.step, p.host))))
