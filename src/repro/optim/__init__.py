"""optim substrate."""
