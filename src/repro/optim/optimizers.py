"""Self-built optimizer substrate (no optax dependency).

Gradient-transformation chain in the optax style: each transform is an
(init, update) pair; `chain` composes; `apply_updates` adds.  Covers every
optimizer the paper uses (Adam, Adagrad, RMSprop, SGD+momentum) plus AdamW,
global-norm clipping, LR schedules, and bf16 gradient compression for
accumulation/all-reduce traffic (DESIGN.md §6).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp


class Transform(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], tuple]   # (grads, state, params)


def chain(*transforms: Transform) -> Transform:
    def init(params):
        return tuple(t.init(params) for t in transforms)

    def update(grads, state, params):
        new_state = []
        for t, s in zip(transforms, state):
            grads, s = t.update(grads, s, params)
            new_state.append(s)
        return grads, tuple(new_state)

    return Transform(init, update)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u).astype(p.dtype), params,
                        updates)


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


# --------------------------------------------------------------------------
# Basic transforms
# --------------------------------------------------------------------------

def clip_by_global_norm(max_norm: float) -> Transform:
    def init(params):
        return ()

    def update(grads, state, params):
        norm = global_norm(grads)
        scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
        return jax.tree.map(lambda g: g * scale, grads), state

    return Transform(init, update)


def scale(factor: float) -> Transform:
    return Transform(lambda p: (),
                     lambda g, s, p: (jax.tree.map(lambda x: x * factor, g),
                                      s))


def scale_by_schedule(schedule: Callable[[jnp.ndarray], jnp.ndarray]
                      ) -> Transform:
    def init(params):
        return jnp.zeros((), jnp.int32)

    def update(grads, count, params):
        lr = schedule(count)
        return jax.tree.map(lambda g: g * lr, grads), count + 1

    return Transform(init, update)


def add_decayed_weights(weight_decay: float) -> Transform:
    def update(grads, state, params):
        return jax.tree.map(lambda g, p: g + weight_decay
                            * p.astype(g.dtype), grads, params), state

    return Transform(lambda p: (), update)


def compress_gradients(mode: str = "bf16") -> Transform:
    """Gradient compression: cast to bf16 (half the all-reduce/accumulation
    bytes) and back. 'none' is a no-op."""
    def update(grads, state, params):
        if mode == "none":
            return grads, state
        return jax.tree.map(
            lambda g: g.astype(jnp.bfloat16).astype(jnp.float32),
            grads), state

    return Transform(lambda p: (), update)


# --------------------------------------------------------------------------
# Second-moment optimizers
# --------------------------------------------------------------------------

def scale_by_adam(b1: float = 0.9, b2: float = 0.999,
                  eps: float = 1e-8) -> Transform:
    def init(params):
        zeros = lambda: jax.tree.map(  # noqa: E731
            lambda p: jnp.zeros_like(p, jnp.float32), params)
        return {"mu": zeros(), "nu": zeros(),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        count = state["count"] + 1
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g,
                          state["mu"], grads)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * jnp.square(g),
                          state["nu"], grads)
        c1 = 1 - b1 ** count.astype(jnp.float32)
        c2 = 1 - b2 ** count.astype(jnp.float32)
        upd = jax.tree.map(
            lambda m, v: (m / c1) / (jnp.sqrt(v / c2) + eps), mu, nu)
        return upd, {"mu": mu, "nu": nu, "count": count}

    return Transform(init, update)


def scale_by_adafactor(b1: float = 0.9, decay: float = 0.999,
                       eps: float = 1e-30,
                       momentum_dtype=jnp.bfloat16) -> Transform:
    """Adafactor-style: factored second moment for >=2-D params (row/col
    running means instead of a full tensor) + bf16 first moment.

    Memory: O(rows+cols) instead of O(rows*cols) for nu, and half-size mu —
    the production choice (T5/PaLM) when optimizer state dominates HBM
    (measured 7.6 GiB/device for qwen3-4b at TP=4 with plain AdamW).
    """

    def init(params):
        def one(p):
            if p.ndim >= 2:
                vr = jnp.zeros(p.shape[:-1], jnp.float32)
                vc = jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
                nu = {"vr": vr, "vc": vc}
            else:
                nu = {"v": jnp.zeros_like(p, jnp.float32)}
            return {"mu": jnp.zeros_like(p, momentum_dtype), "nu": nu}
        return {"s": jax.tree.map(one, params,
                                  is_leaf=lambda x: hasattr(x, "ndim")),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        count = state["count"] + 1
        c2 = 1 - decay ** count.astype(jnp.float32)

        def one(g, st):
            g32 = g.astype(jnp.float32)
            g2 = jnp.square(g32) + eps
            if g.ndim >= 2:
                vr = decay * st["nu"]["vr"] + (1 - decay) * g2.mean(-1)
                vc = decay * st["nu"]["vc"] + (1 - decay) * g2.mean(-2)
                denom_sq = (vr[..., None] * vc[..., None, :]
                            / jnp.clip(vr.mean(-1)[..., None, None],
                                       1e-30, None)) / c2
                nu = {"vr": vr, "vc": vc}
            else:
                v = decay * st["nu"]["v"] + (1 - decay) * g2
                denom_sq = v / c2
                nu = {"v": v}
            upd = g32 / (jnp.sqrt(denom_sq) + 1e-8)
            mu = b1 * st["mu"].astype(jnp.float32) + (1 - b1) * upd
            return mu, {"mu": mu.astype(momentum_dtype), "nu": nu}

        flat_g, treedef = jax.tree_util.tree_flatten(grads)
        flat_s = treedef.flatten_up_to(state["s"])
        outs = [one(g, s) for g, s in zip(flat_g, flat_s)]
        upd = jax.tree_util.tree_unflatten(treedef, [o[0] for o in outs])
        new_s = jax.tree_util.tree_unflatten(treedef,
                                             [o[1] for o in outs])
        return upd, {"s": new_s, "count": count}

    return Transform(init, update)


def scale_by_adagrad(eps: float = 1e-8) -> Transform:
    def init(params):
        return jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32),
                            params)

    def update(grads, acc, params):
        acc = jax.tree.map(lambda a, g: a + jnp.square(g), acc, grads)
        upd = jax.tree.map(lambda g, a: g / (jnp.sqrt(a) + eps), grads, acc)
        return upd, acc

    return Transform(init, update)


def scale_by_rmsprop(decay: float = 0.9, eps: float = 1e-8) -> Transform:
    def init(params):
        return jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32),
                            params)

    def update(grads, nu, params):
        nu = jax.tree.map(lambda v, g: decay * v + (1 - decay)
                          * jnp.square(g), nu, grads)
        upd = jax.tree.map(lambda g, v: g / (jnp.sqrt(v) + eps), grads, nu)
        return upd, nu

    return Transform(init, update)


def trace_momentum(momentum: float) -> Transform:
    def init(params):
        return jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32),
                            params)

    def update(grads, tr, params):
        tr = jax.tree.map(lambda t, g: momentum * t + g, tr, grads)
        return tr, tr

    return Transform(init, update)


# --------------------------------------------------------------------------
# Schedules + named constructors
# --------------------------------------------------------------------------

def warmup_cosine(base_lr: float, warmup_steps: int, total_steps: int,
                  final_frac: float = 0.1):
    def schedule(count):
        c = count.astype(jnp.float32)
        warm = c / jnp.maximum(warmup_steps, 1)
        prog = jnp.clip((c - warmup_steps)
                        / jnp.maximum(total_steps - warmup_steps, 1), 0, 1)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(
            jnp.pi * prog))
        return base_lr * jnp.where(c < warmup_steps, warm, cos)

    return schedule


def constant(lr: float):
    return lambda count: jnp.asarray(lr, jnp.float32)


def make_optimizer(name: str, lr, *, b1=0.9, b2=0.999, eps=1e-8,
                   momentum=0.0, weight_decay=0.0, grad_clip_norm=0.0,
                   compression: str = "none") -> Transform:
    """Named constructor used by TrainConfig.

    lr: float or schedule callable.  Returned updates are ready for
    apply_updates (they already include the negative sign).
    """
    parts = []
    if grad_clip_norm and grad_clip_norm > 0:
        parts.append(clip_by_global_norm(grad_clip_norm))
    if compression != "none":
        parts.append(compress_gradients(compression))
    if name in ("adam", "adamw"):
        parts.append(scale_by_adam(b1, b2, eps))
        if name == "adamw" and weight_decay:
            parts.append(add_decayed_weights(weight_decay))
    elif name == "adafactor":
        parts.append(scale_by_adafactor(b1, b2, eps))
        if weight_decay:
            parts.append(add_decayed_weights(weight_decay))
    elif name == "adagrad":
        parts.append(scale_by_adagrad(eps))
    elif name == "rmsprop":
        parts.append(scale_by_rmsprop(decay=0.9, eps=eps))
    elif name == "sgd":
        if momentum:
            parts.append(trace_momentum(momentum))
    else:
        raise ValueError(f"unknown optimizer {name!r}")
    sched = lr if callable(lr) else constant(lr)
    parts.append(scale_by_schedule(lambda c: -sched(c)))
    return chain(*parts)
