"""Bitwidth compression for Bloom tables (DESIGN.md §13).

The paper's win is k/m compression of the one-hot I/O layers; bitwidth
compression composes multiplicatively with it (PAPERS.md: Embedding
Compression in Recommender Systems survey).  This module is the single
source of truth for the ``table_dtype`` knob threaded through the kernel
layer, the configs and the bytes models:

* ``"float32"`` / ``"bfloat16"`` — plain casts, no scales.
* ``"int8"``     — symmetric per-row quantization: one positive float32
  scale per table row, ``scale[r] = max|row_r| / 127``, values rounded to
  [-127, 127].  Per-ROW (not per-tensor) because both Bloom kernels fetch
  whole rows: the embed forward DMAs ``idx[t, j]`` rows, the Eq. 3 decode
  reads whole ``logp[b, :]`` rows — so the scale rides the row fetch and
  dequantization is a single multiply on the VMEM tile.
* ``"fp8_e4m3"`` — scale-free cast to ``jnp.float8_e4m3fn`` (dynamic
  range ±448 covers activations/embeddings at init and after training;
  no scale tensor, dequant is the ``astype(f32)`` the kernels already do).

Quantization error is bounded elementwise by ``scale/2`` for int8 (see
tests/test_property.py for the hypothesis-checked bound) and the MXU
matmuls always accumulate in float32 — the knob changes HBM traffic, not
accumulation precision.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp

# Canonical knob values.  "auto" is the config-layer default meaning
# "legacy behavior": cast the table to the activation dtype, no
# quantization and no scales — byte-identical to the pre-quant code path.
TABLE_DTYPES = ("float32", "bfloat16", "int8", "fp8_e4m3")

_ALIASES = {"fp32": "float32", "bf16": "bfloat16", "fp8": "fp8_e4m3"}

_ITEMSIZE = {"float32": 4, "bfloat16": 2, "int8": 1, "fp8_e4m3": 1}

_STORAGE = {
    "float32": jnp.float32,
    "bfloat16": jnp.bfloat16,
    "int8": jnp.int8,
    "fp8_e4m3": jnp.float8_e4m3fn,
}


def resolve_table_dtype(table_dtype: Optional[str],
                        allow_auto: bool = False) -> Optional[str]:
    """Normalize/validate a ``table_dtype`` knob value.

    Returns the canonical name from TABLE_DTYPES; passes ``None`` through
    (kernel-layer "no quantization requested").  ``allow_auto=True`` also
    accepts the config-layer default ``"auto"``.  Mirrors
    kernels.common.resolve_bwd_impl: unknown values raise with the full
    menu so CLI typos fail fast.
    """
    if table_dtype is None:
        return None
    if allow_auto and table_dtype == "auto":
        return "auto"
    td = _ALIASES.get(table_dtype, table_dtype)
    if td not in TABLE_DTYPES:
        extra = ("auto", ) if allow_auto else ()
        raise ValueError(
            f"table_dtype must be one of {tuple(extra) + TABLE_DTYPES} "
            f"(aliases: {sorted(_ALIASES)}), got {table_dtype!r}")
    return td


def table_itemsize(table_dtype: Optional[str]) -> int:
    """Bytes per stored table element — the bytes models' single source."""
    if table_dtype is None:
        return 4
    return _ITEMSIZE[resolve_table_dtype(table_dtype)]


def storage_dtype(table_dtype: str) -> jnp.dtype:
    """The jnp dtype a table with this knob is stored (and DMA'd) in."""
    return jnp.dtype(_STORAGE[resolve_table_dtype(table_dtype)])


def quantize_table(table: jnp.ndarray, table_dtype: str
                   ) -> Tuple[jnp.ndarray, Optional[jnp.ndarray]]:
    """(m, D) float table -> (stored table, per-row float32 scales | None).

    int8 returns ``(q, scales)`` with ``q[r] = round(row_r / scales[r])``
    clipped to [-127, 127] and ``scales[r] = max|row_r| / 127`` (clamped
    to a tiny positive value so all-zero rows stay exactly zero instead
    of dividing by zero).  Every other dtype is a plain cast with
    ``scales=None``.  jit-safe: runs in-graph during training (the
    straight-through estimator path) and eagerly at serve time (see
    core.bloom.cached_quantized_table).
    """
    td = resolve_table_dtype(table_dtype)
    if td != "int8":
        return table.astype(_STORAGE[td]), None
    x = table.astype(jnp.float32)
    amax = jnp.max(jnp.abs(x), axis=-1)                       # (m,)
    scales = jnp.maximum(amax / 127.0, jnp.float32(1e-12))
    q = jnp.clip(jnp.round(x / scales[:, None]), -127, 127).astype(jnp.int8)
    return q, scales


def dequantize_table(qtable: jnp.ndarray,
                     scales: Optional[jnp.ndarray]) -> jnp.ndarray:
    """The XLA oracle the kernels' in-VMEM dequant is tested against."""
    x = qtable.astype(jnp.float32)
    if scales is not None:
        x = x * scales[:, None].astype(jnp.float32)
    return x
