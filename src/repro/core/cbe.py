"""Co-occurrence-based Bloom embeddings (paper Sec. 6, Algorithm 1).

CBE 're-directs' the collisions that must happen anyway (m < d) so that the
most co-occurring item pairs share a bit.  Training/serving cost is
unchanged — CBE only produces a different precomputed hash matrix H'.

This is host-side preprocessing (the paper stores H in RAM, not GPU memory),
so it is written in NumPy/SciPy over the sparse instance matrix X.
"""
from __future__ import annotations

from typing import Optional

import numpy as np
import scipy.sparse as sp


def cooccurrence_stats(X: sp.spmatrix):
    """Co-occurrence statistics reported in paper Table 4.

    Returns (percent_cooccurring_pairs, mean_cooccurrence_ratio rho).
    """
    X = X.tocsr().astype(np.float64)
    n, d = X.shape
    C = (X.T @ X).tocoo()
    mask = C.row < C.col                      # strict lower/upper triangle
    vals = C.data[mask]
    vals = vals[vals > 0]
    total_pairs = d * (d - 1) / 2
    pct = 100.0 * vals.size / max(total_pairs, 1)
    rho = float(vals.mean() / n) if vals.size else 0.0
    return pct, rho


def cbe_hash_matrix(
    X: sp.spmatrix,
    H: np.ndarray,
    m: int,
    seed: int = 0,
    max_pairs: Optional[int] = None,
) -> np.ndarray:
    """Algorithm 1: co-occurrence-based hashing matrix H'.

    Args:
      X: (n, d) sparse binary instance matrix (inputs and/or outputs).
      H: (d, k) precomputed hash matrix (hashing.make_hash_matrix_np).
      m: embedding dimensionality (range of H entries).
      max_pairs: optional cap on processed pairs (largest co-occurrences are
        processed last and therefore always kept — the cap drops the
        *smallest* entries, which Algorithm 1 would have overwritten anyway).

    Returns a new (d, k) int32 matrix.
    """
    rng = np.random.default_rng(seed)
    H = np.array(H, dtype=np.int64, copy=True)
    d, k = H.shape
    X = X.tocsr().astype(np.float64)

    # line 1: C <- X^T X  (pairwise co-occurrence counts)
    C = (X.T @ X).tocsr()
    # line 2: C <- C ⊙ sgn(C - Avgfreq(X)); Avgfreq = mean item frequency.
    avg_freq = float(X.sum() / d)
    C = C.tocoo()
    data = C.data * np.sign(C.data - avg_freq)
    # line 3: lower triangle in coordinate format.
    tri = C.row > C.col
    vals, rows, cols = data[tri], C.row[tri], C.col[tri]
    keep = vals != 0
    vals, rows, cols = vals[keep], rows[keep], cols[keep]
    # line 4: increasing order => largest co-occurrence processed last, so
    # its collision assignment survives any earlier overwrite.
    order = np.argsort(vals, kind="stable")
    if max_pairs is not None and order.size > max_pairs:
        order = order[-max_pairs:]

    for i in order:
        a, b = int(rows[i]), int(cols[i])
        used = set(H[a]) | set(H[b])
        if len(used) >= m:       # degenerate tiny-m case: nothing to redirect
            continue
        # line 6: r <- URND(1, m, h_a ∪ h_b)
        while True:
            r = int(rng.integers(0, m))
            if r not in used:
                break
        # lines 7-9: pick projections and redirect both to bit r.
        ja = int(rng.integers(0, k))
        jb = int(rng.integers(0, k))
        H[a, ja] = r
        H[b, jb] = r
    return H.astype(np.int32)
