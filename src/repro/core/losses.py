"""Losses for Bloom-embedded (and baseline) outputs.

The paper trains every task with a softmax output + categorical
cross-entropy, where the target is the (normalized) Bloom encoding of the
ground-truth item set.  For an LM position (c = 1 item), the target is
exactly k-hot with mass 1/k per projection, so

    CE = logsumexp(z) - (1/k) * sum_j z[H_j(y)]

which needs only a k-gather — never a dense m-hot target.  That identity is
what the fused Pallas kernel (repro.kernels.bloom_ce) implements; the
functions here are the jnp oracles used everywhere on CPU.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.bloom import BloomSpec


def softmax_xent_dense(logits: jnp.ndarray, target: jnp.ndarray,
                       axis: int = -1) -> jnp.ndarray:
    """CE against a dense target distribution (rows may sum to 0 => masked)."""
    logz = jax.nn.logsumexp(logits, axis=axis)
    tmass = target.sum(axis=axis)
    return logz * tmass - (target * logits).sum(axis=axis)


def gather_last_axis(logits: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    """Shard-friendly gather over the last axis: logits (..., m),
    idx (..., k) -> (..., k) in float32.

    Implemented as k iota-compare masked sums instead of take_along_axis:
    every op is elementwise/reduce over the m axis, so GSPMD keeps m-dim
    (vocab/model-axis) sharding intact and lowers the reduction to one
    small all-reduce — a gather over a sharded dim would force XLA to
    replicate the whole logits tensor per device (measured: 16x memory).
    """
    m = logits.shape[-1]
    iota = jax.lax.broadcasted_iota(jnp.int32, (m,), 0)
    cols = []
    for j in range(idx.shape[-1]):
        mask = iota == idx[..., j:j + 1]                    # (..., m)
        cols.append(jnp.sum(jnp.where(mask, logits, 0)
                            .astype(jnp.float32), axis=-1))
    return jnp.stack(cols, axis=-1)


def _logsumexp_f32(logits: jnp.ndarray) -> jnp.ndarray:
    z = logits.astype(jnp.float32)
    zmax = jax.lax.stop_gradient(z.max(axis=-1, keepdims=True))
    return jnp.log(jnp.sum(jnp.exp(z - zmax), axis=-1)) + zmax[..., 0]


def softmax_xent_label(logits: jnp.ndarray, label: jnp.ndarray,
                       valid: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Standard CE with integer labels (..., ) over logits (..., n)."""
    logz = _logsumexp_f32(logits)
    picked = gather_last_axis(logits, label[..., None].astype(jnp.int32))
    loss = logz - picked[..., 0]
    if valid is not None:
        loss = loss * valid.astype(loss.dtype)
    return loss


def bloom_xent_label(spec: BloomSpec, logits: jnp.ndarray,
                     label: jnp.ndarray,
                     hash_matrix: Optional[jnp.ndarray] = None,
                     valid: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Bloom CE for single-item targets (the LM / next-click case).

    logits: (..., m); label: (...,) item ids in [0, d).
    loss = logsumexp(z) - (1/k) * sum_j z[H_j(label)].

    §Perf note: the k-gather is fused into ONE weighted pass over the m
    axis — w[i] = #{j : H_j(y) == i} built from k int compares (int8), so
    the f32 logits row is read once instead of k times (the k-pass variant
    measured ~4x the loss-block HBM traffic).
    """
    idx = spec.indices_for(jnp.maximum(label, 0), hash_matrix)   # (..., k)
    m = logits.shape[-1]
    iota = jax.lax.broadcasted_iota(jnp.int32, (m,), 0)
    w = jnp.zeros(logits.shape, jnp.int8)
    for j in range(spec.k):
        w = w + (iota == idx[..., j:j + 1]).astype(jnp.int8)
    picked_sum = jnp.sum(logits.astype(jnp.float32)
                         * w.astype(jnp.float32), axis=-1)
    logz = _logsumexp_f32(logits)
    loss = logz - picked_sum / spec.k
    if valid is not None:
        loss = loss * valid.astype(loss.dtype)
    return loss


def bloom_xent_multilabel(spec: BloomSpec, logits: jnp.ndarray,
                          targets: jnp.ndarray,
                          hash_matrix: Optional[jnp.ndarray] = None
                          ) -> jnp.ndarray:
    """Bloom CE for item *sets* (recommender outputs).

    targets: (..., c_max) padded item ids (-1 = pad).  The target
    distribution is the Bloom encoding u of the set, normalized to sum 1
    (ties collapse under `max`, as in Eq. 1: u is binary).
    """
    from repro.core.bloom import encode
    u = encode(spec, targets, hash_matrix)                 # (..., m) binary
    mass = jnp.clip(u.sum(-1, keepdims=True), 1e-9, None)
    return softmax_xent_dense(logits, u / mass)


def cosine_proximity_loss(pred: jnp.ndarray, target: jnp.ndarray,
                          eps: float = 1e-8) -> jnp.ndarray:
    """Cosine loss used by the PMI / CCA alternatives (Chollet 2016)."""
    p = pred / (jnp.linalg.norm(pred, axis=-1, keepdims=True) + eps)
    t = target / (jnp.linalg.norm(target, axis=-1, keepdims=True) + eps)
    return 1.0 - (p * t).sum(-1)
