"""Counting Bloom embeddings — the paper's own 'future work' (Sec. 7):

  "one could enhance the proposed approach by considering further
   extensions of Bloom filters such as counting Bloom filters. In theory,
   those extensions could provide a more compact representation by
   breaking the binary nature of the embedding."

Implementation (beyond-paper extension): the encoding counts how many
(item, projection) pairs land on each bit instead of saturating at 1 —
u[i] = #{(p, j) : H_j(p) = i} — and the training target becomes the
normalized count distribution.  Recovery stays Eq. 3 (the count encoding
only changes the *target*; the model's softmax output is unchanged), so
serving code is identical — exactly the property the paper asks for.

When does it help?  With binary encoding, two items colliding on a bit
contribute the same mass as one item; the count target keeps the lost
multiplicity, so the gradient 'knows' a bit is doubly loaded.  For the
LM case (single-label), counts matter when k-hash self-collisions occur
(rare) — counting is primarily a multi-label recommender feature.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import losses
from repro.core.bloom import BloomSpec, decode_scores


def encode_counting(spec: BloomSpec, p: jnp.ndarray,
                    hash_matrix: Optional[jnp.ndarray] = None,
                    dtype=jnp.float32) -> jnp.ndarray:
    """Count-valued Bloom encoding: u[i] = multiplicity of bit i.

    p: (..., c_max) padded item ids (-1 = pad) -> (..., m) counts.
    """
    valid = p >= 0
    idx = spec.indices_for(jnp.where(valid, p, 0), hash_matrix)
    flat = idx.reshape(*p.shape[:-1], -1)
    mask = jnp.repeat(valid, spec.k, axis=-1).reshape(flat.shape)

    def one(f_row, m_row):
        return jnp.zeros((spec.m,), dtype).at[f_row].add(
            m_row.astype(dtype))

    fn = one
    for _ in range(flat.ndim - 1):
        fn = jax.vmap(fn)
    return fn(flat, mask)


def counting_xent_multilabel(spec: BloomSpec, logits: jnp.ndarray,
                             targets: jnp.ndarray,
                             hash_matrix: Optional[jnp.ndarray] = None
                             ) -> jnp.ndarray:
    """CE against the normalized COUNT distribution (vs the paper's
    binary multi-hot): collisions keep their multiplicity."""
    u = encode_counting(spec, targets, hash_matrix)
    mass = jnp.clip(u.sum(-1, keepdims=True), 1e-9, None)
    return losses.softmax_xent_dense(logits, u / mass)


class CountingBloomIO:
    """IOEmbedding-compatible counting variant (drop-in for BloomIO)."""

    def __init__(self, d: int, m: int, k: int = 4, seed: int = 0):
        self.name = "CBE-count"
        self.d, self.m_in, self.m_out = d, m, m
        self.spec_in = BloomSpec(d=d, m=m, k=k, seed=seed)
        self.spec_out = BloomSpec(d=d, m=m, k=k, seed=seed + 1)

    def encode_input(self, p):
        # counting inputs carry multiplicity into the first layer too
        return encode_counting(self.spec_in, p)

    def loss(self, pred, q):
        return counting_xent_multilabel(self.spec_out, pred, q)

    def decode(self, pred):
        logp = jax.nn.log_softmax(pred, axis=-1)
        return decode_scores(self.spec_out, logp)
