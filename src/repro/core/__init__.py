"""Core: the paper's contribution — Bloom embeddings for sparse binary IO.

Public API:
  hashing          — double hashing + precomputed hash matrices
  BloomSpec        — static spec of one Bloom-compressed IO boundary
  encode / decode_scores / decode_topk / recover_probabilities
  losses           — bloom softmax-CE (label / multilabel), cosine
  cbe              — co-occurrence-based hash matrices (Alg. 1)
  alternatives     — HT / ECOC / PMI / CCA baselines + IOEmbedding interface
"""
from repro.core import hashing, cbe, losses, alternatives  # noqa: F401
from repro.core.bloom import (  # noqa: F401
    BloomSpec,
    identity_spec,
    encode,
    encode_dense,
    decode_scores,
    decode_topk,
    recover_probabilities,
)
