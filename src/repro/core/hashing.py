"""Hash-function substrate for Bloom embeddings.

The paper (Sec. 3.1/3.2) requires k independent hash functions H = {H_j},
each mapping item ids [0, d) -> [0, m).  Two interchangeable realizations:

1. **On-the-fly enhanced double hashing** (Dillinger & Manolios 2004, cited
   by the paper):  ``h_j(x) = (a(x) + j*b(x) + (j^3 - j)/6) mod m`` with
   ``a, b`` derived from a strong integer mixer.  O(1) space, O(k) time,
   jit-compatible — this is the paper's "no disk or memory space" mode.

2. **Precomputed hash matrix** ``H`` of shape (d, k) — the paper's
   "pre-generate all projections for all d items ... d x k matrix of
   integers between 1 and m" mode.  We add a vectorized within-row
   de-duplication pass (the paper draws without replacement); any residual
   duplicate after the repair rounds is a benign Bloom collision.

All arithmetic is uint32 with wraparound, so everything runs identically
under jit on CPU/TPU without x64.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

_GOLDEN = np.uint32(0x9E3779B9)
_MIX1 = np.uint32(0x85EBCA6B)
_MIX2 = np.uint32(0xC2B2AE35)


def splitmix32(x: jnp.ndarray) -> jnp.ndarray:
    """SplitMix finalizer — a high-quality 32-bit integer mixer.

    Accepts any integer dtype; returns uint32 uniformly mixed bits.
    """
    z = x.astype(jnp.uint32) + _GOLDEN
    z = (z ^ (z >> 16)) * _MIX1
    z = (z ^ (z >> 13)) * _MIX2
    z = z ^ (z >> 16)
    return z


def _salted(ids: jnp.ndarray, salt: int | jnp.ndarray) -> jnp.ndarray:
    """Mix item ids with a salt; different salts give independent streams."""
    s = jnp.asarray(salt, dtype=jnp.uint32)
    return splitmix32(ids.astype(jnp.uint32) ^ splitmix32(s))


def double_hash_salts(seed: int) -> tuple[int, int]:
    """Host-side ``(splitmix32(2*seed), splitmix32(2*seed+1))`` as ints.

    The two mixed salt constants double_hash folds into every id.  Kernels
    that rehash ids IN-GRAPH (the quantized decode-topk's on-the-fly mode,
    kernels/bloom_decode_topk.py) bake these in as static scalars so the
    in-kernel hash is bit-identical to double_hash / cached_hash_matrix
    without ever streaming the (d, k) matrix from HBM.  Pure-int mirror of
    splitmix32 (masked 32-bit arithmetic) so it needs no device round-trip.
    """
    mask = 0xFFFFFFFF

    def mix(x: int) -> int:
        z = (x + 0x9E3779B9) & mask
        z = ((z ^ (z >> 16)) * 0x85EBCA6B) & mask
        z = ((z ^ (z >> 13)) * 0xC2B2AE35) & mask
        return z ^ (z >> 16)

    return mix(2 * seed & mask), mix((2 * seed + 1) & mask)


def double_hash(
    ids: jnp.ndarray,
    k: int,
    m: int,
    seed: int = 0,
) -> jnp.ndarray:
    """Enhanced double hashing: k indices in [0, m) per id.

    h_j = (h1 + j*h2 + (j^3 - j)/6) mod m, with h2 forced odd/nonzero so the
    probe sequence cycles through residues.  Returns shape ids.shape + (k,)
    int32.  Negative ids (padding) hash like their bit pattern — callers
    mask them out themselves.
    """
    h1 = _salted(ids, 2 * seed) % np.uint32(m)
    h2 = _salted(ids, 2 * seed + 1) % np.uint32(max(m - 1, 1)) + np.uint32(1)
    j = jnp.arange(k, dtype=jnp.uint32)
    # (j^3 - j)/6 is integral for all j; precompute host-side.
    tri = jnp.asarray([(int(v) ** 3 - int(v)) // 6 % m for v in range(k)],
                      dtype=jnp.uint32)
    h = (h1[..., None] + j * h2[..., None] + tri) % np.uint32(m)
    return h.astype(jnp.int32)


@functools.partial(jax.jit, static_argnums=(0, 1, 2, 3, 4))
def _hash_matrix_impl(d: int, k: int, m: int, seed: int, repair_rounds: int):
    ids = jnp.arange(d, dtype=jnp.uint32)
    h = double_hash(ids, k, m, seed)  # (d, k)

    for r in range(repair_rounds):  # static unroll — repair_rounds is tiny
        # dup[j] = True iff h[j] equals some h[i], i < j (within the row).
        eq = h[:, :, None] == h[:, None, :]              # (d, k, k)
        lower = jnp.tril(jnp.ones((k, k), bool), k=-1)   # i < j
        dup = jnp.any(eq & lower[None, :, :].transpose(0, 2, 1), axis=-1)
        fresh = double_hash(ids + np.uint32((r + 1) * 0x1000_0003), k, m,
                            seed + 7919 * (r + 1))
        h = jnp.where(dup, fresh, h)
    return h.astype(jnp.int32)


def make_hash_matrix(
    d: int,
    k: int,
    m: int,
    seed: int = 0,
    repair_rounds: int = 4,
) -> jnp.ndarray:
    """Precompute the paper's (d, k) hash matrix H of indices in [0, m).

    Rows are de-duplicated with `repair_rounds` vectorized redraw passes;
    residual within-row duplicates have probability ~(k^2/2m)^rounds and are
    benign (they only weaken one item's Bloom code slightly).
    """
    if m <= 0 or d <= 0 or k <= 0:
        raise ValueError(f"d, k, m must be positive; got {d=} {k=} {m=}")
    if k > m:
        raise ValueError(f"k ({k}) cannot exceed m ({m})")
    return _hash_matrix_impl(d, k, m, seed, repair_rounds)


def make_hash_matrix_np(d: int, k: int, m: int, seed: int = 0,
                        strict: bool = True) -> np.ndarray:
    """NumPy hash matrix with *guaranteed* distinct entries per row.

    Used by CBE (host-side preprocessing) and by tests as an oracle.  Loops
    only over residual collisions, so it is fast for realistic (d, k, m).
    """
    if k > m:
        raise ValueError(f"k ({k}) cannot exceed m ({m})")
    rng = np.random.default_rng(seed)
    h = rng.integers(0, m, size=(d, k), dtype=np.int64)
    if strict:
        for _ in range(64):
            srt = np.sort(h, axis=1)
            bad_rows = np.nonzero((srt[:, 1:] == srt[:, :-1]).any(axis=1))[0]
            if bad_rows.size == 0:
                break
            h[bad_rows] = rng.integers(0, m, size=(bad_rows.size, k))
        else:  # pragma: no cover - probabilistically unreachable
            for r in np.nonzero(
                (np.sort(h, 1)[:, 1:] == np.sort(h, 1)[:, :-1]).any(1))[0]:
                h[r] = rng.choice(m, size=k, replace=False)
    return h.astype(np.int32)


def hash_indices(
    ids: jnp.ndarray,
    *,
    k: int,
    m: int,
    seed: int = 0,
    hash_matrix: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """Unified lookup: per-id k hash indices, from H if given else on-the-fly.

    ids: int array, any shape; returns ids.shape + (k,) int32 in [0, m).
    Negative ids are clamped to 0 for the matrix path — callers must mask.
    """
    if hash_matrix is not None:
        safe = jnp.clip(ids, 0, hash_matrix.shape[0] - 1)
        return jnp.take(hash_matrix, safe, axis=0)
    return double_hash(ids, k, m, seed)
