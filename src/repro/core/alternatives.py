"""The 4 alternative IO-embedding methods the paper compares against
(Sec. 4.3): HT, ECOC, PMI, CCA — plus the shared interface they and Bloom
embeddings implement, so the trainer/benchmarks can swap them freely.

All fitting happens host-side in NumPy/SciPy (these are preprocessing
artifacts, like the paper's hash matrix); the encode/loss/decode hot paths
are jnp and jit-compatible.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
import scipy.sparse as sp
import scipy.sparse.linalg as spla

from repro.core import hashing, losses
from repro.core.bloom import BloomSpec, encode as bloom_encode


# --------------------------------------------------------------------------
# Shared interface
# --------------------------------------------------------------------------

@dataclasses.dataclass
class IOEmbedding:
    """Input encoder + output target + loss + decoder for one method."""

    name: str
    d: int
    m_in: int
    m_out: int

    def encode_input(self, p: jnp.ndarray) -> jnp.ndarray:
        """(B, c_max) padded ids -> (B, m_in) dense network input."""
        raise NotImplementedError

    def loss(self, pred: jnp.ndarray, q: jnp.ndarray) -> jnp.ndarray:
        """(B, m_out) net output (pre-activation logits) + (B, c) targets."""
        raise NotImplementedError

    def decode(self, pred: jnp.ndarray) -> jnp.ndarray:
        """(B, m_out) net output -> (B, d) ranking scores (higher=better)."""
        raise NotImplementedError


# --------------------------------------------------------------------------
# Bloom embeddings / hashing trick (HT == BE with k=1, paper Sec. 4.3)
# --------------------------------------------------------------------------

@dataclasses.dataclass
class BloomIO(IOEmbedding):
    spec_in: BloomSpec = None
    spec_out: BloomSpec = None
    H_in: Optional[jnp.ndarray] = None     # optional CBE-adjusted matrices
    H_out: Optional[jnp.ndarray] = None

    @classmethod
    def build(cls, d: int, m: int, k: int = 4, seed: int = 0,
              H_in=None, H_out=None, name: str = "BE"):
        on_fly = H_in is None
        spec_i = BloomSpec(d=d, m=m, k=k, seed=seed, on_the_fly=on_fly)
        spec_o = BloomSpec(d=d, m=m, k=k, seed=seed + 1,
                           on_the_fly=H_out is None)
        return cls(name=name, d=d, m_in=m, m_out=m, spec_in=spec_i,
                   spec_out=spec_o,
                   H_in=None if H_in is None else jnp.asarray(H_in),
                   H_out=None if H_out is None else jnp.asarray(H_out))

    def encode_input(self, p):
        return bloom_encode(self.spec_in, p, self.H_in)

    def loss(self, pred, q):
        return losses.bloom_xent_multilabel(self.spec_out, pred, q,
                                            self.H_out)

    def decode(self, pred):
        from repro.core.bloom import decode_scores
        logp = jax.nn.log_softmax(pred, axis=-1)
        return decode_scores(self.spec_out, logp, self.H_out)


def hashing_trick(d: int, m: int, seed: int = 0) -> BloomIO:
    """HT baseline = BE special case with k = 1 (Ganchev & Dredze recovery)."""
    return BloomIO.build(d=d, m=m, k=1, seed=seed, name="HT")


# --------------------------------------------------------------------------
# ECOC (Dietterich & Bakiri randomized hill-climbing codes)
# --------------------------------------------------------------------------

def _ecoc_code_matrix(d: int, m: int, seed: int, iters: int = 200,
                      sample: int = 256) -> np.ndarray:
    """Randomized hill-climbing on min pairwise Hamming distance.

    Exact all-pairs hill-climbing is O(d^2 m); we hill-climb on sampled row
    pairs, which recovers the published construction's behaviour for the
    d >> m regime (random codes are already near-optimal there).
    """
    rng = np.random.default_rng(seed)
    C = (rng.random((d, m)) < 0.5).astype(np.int8)
    for _ in range(iters):
        rows = rng.integers(0, d, size=sample)
        sub = C[rows]
        # pair with the nearest sampled row, then flip the bit that helps.
        dist = (sub[:, None, :] ^ sub[None, :, :]).sum(-1)
        np.fill_diagonal(dist, m + 1)
        nearest = dist.argmin(1)
        for i, j in enumerate(nearest):
            if dist[i, j] > m // 2:
                continue
            agree = np.nonzero(sub[i] == sub[j])[0]
            if agree.size:
                b = rng.choice(agree)
                C[rows[i], b] ^= 1
    return C


@dataclasses.dataclass
class ECOCIO(IOEmbedding):
    code: jnp.ndarray = None          # (d, m) binary codes

    @classmethod
    def build(cls, d: int, m: int, seed: int = 0, iters: int = 200):
        C = _ecoc_code_matrix(d, m, seed, iters)
        return cls(name="ECOC", d=d, m_in=m, m_out=m,
                   code=jnp.asarray(C, jnp.float32))

    def _encode(self, p):
        valid = (p >= 0)[..., None].astype(jnp.float32)
        rows = jnp.take(self.code, jnp.maximum(p, 0), axis=0)   # (B, c, m)
        return jnp.minimum((rows * valid).sum(-2), 1.0)

    def encode_input(self, p):
        return self._encode(p)

    def loss(self, pred, q):
        # Paper Sec. 4.3: Hamming loss underperformed; use CE on normalized
        # code-union target, same as BE's multilabel CE.
        u = self._encode(q)
        mass = jnp.clip(u.sum(-1, keepdims=True), 1e-9, None)
        return losses.softmax_xent_dense(pred, u / mass)

    def decode(self, pred):
        logp = jax.nn.log_softmax(pred, axis=-1)
        w = self.code / jnp.clip(self.code.sum(-1, keepdims=True), 1.0, None)
        return logp @ w.T                                   # (B, d)


# --------------------------------------------------------------------------
# PMI (Chollet 2016: SVD of the pointwise-mutual-information matrix + KNN)
# --------------------------------------------------------------------------

def _pmi_vectors(X: sp.spmatrix, r: int, seed: int = 0) -> np.ndarray:
    X = X.tocsr().astype(np.float64)
    n, d = X.shape
    C = (X.T @ X).toarray()
    freq = np.asarray(X.sum(0)).ravel() + 1e-9
    pmi = np.log((C * n + 1e-9) / np.outer(freq, freq))
    pmi = np.maximum(pmi, 0.0)       # positive PMI, standard practice
    r = min(r, d - 1)
    u, s, _ = spla.svds(sp.csr_matrix(pmi), k=r,
                        random_state=np.random.default_rng(seed))
    order = np.argsort(-s)
    return (u[:, order] * np.sqrt(s[order])).astype(np.float32)


@dataclasses.dataclass
class PMIIO(IOEmbedding):
    vecs: jnp.ndarray = None          # (d, r) item vectors

    @classmethod
    def build(cls, X: sp.spmatrix, m: int, seed: int = 0):
        d = X.shape[1]
        V = _pmi_vectors(X, m, seed)
        return cls(name="PMI", d=d, m_in=V.shape[1], m_out=V.shape[1],
                   vecs=jnp.asarray(V))

    def _embed(self, p):
        valid = (p >= 0)[..., None].astype(jnp.float32)
        rows = jnp.take(self.vecs, jnp.maximum(p, 0), axis=0)
        return (rows * valid).sum(-2)

    def encode_input(self, p):
        return self._embed(p)

    def loss(self, pred, q):
        return losses.cosine_proximity_loss(pred, self._embed(q))

    def decode(self, pred):
        vn = self.vecs / (jnp.linalg.norm(self.vecs, axis=-1,
                                          keepdims=True) + 1e-8)
        pn = pred / (jnp.linalg.norm(pred, axis=-1, keepdims=True) + 1e-8)
        return pn @ vn.T


# --------------------------------------------------------------------------
# CCA (Hotelling; SVD of the input/output cross-correlation + KNN)
# --------------------------------------------------------------------------

@dataclasses.dataclass
class CCAIO(IOEmbedding):
    U: jnp.ndarray = None             # (d, r) input projections
    V: jnp.ndarray = None             # (d, r) output projections

    @classmethod
    def build(cls, X_in: sp.spmatrix, X_out: sp.spmatrix, m: int,
              seed: int = 0):
        Xi = X_in.tocsr().astype(np.float64)
        Xo = X_out.tocsr().astype(np.float64)
        d = Xi.shape[1]
        # whitened cross-correlation (spectral CCA, Hsu et al. 2012 style)
        fi = np.asarray(Xi.sum(0)).ravel() + 1.0
        fo = np.asarray(Xo.sum(0)).ravel() + 1.0
        Cxy = (Xi.T @ Xo).toarray() / np.sqrt(np.outer(fi, fo))
        r = min(m, d - 1)
        u, s, vt = spla.svds(sp.csr_matrix(Cxy), k=r,
                             random_state=np.random.default_rng(seed))
        order = np.argsort(-s)
        U = (u[:, order] * np.sqrt(s[order])).astype(np.float32)
        V = (vt[order].T * np.sqrt(s[order])).astype(np.float32)
        return cls(name="CCA", d=d, m_in=r, m_out=r,
                   U=jnp.asarray(U), V=jnp.asarray(V))

    def _embed(self, p, mat):
        valid = (p >= 0)[..., None].astype(jnp.float32)
        rows = jnp.take(mat, jnp.maximum(p, 0), axis=0)
        return (rows * valid).sum(-2)

    def encode_input(self, p):
        return self._embed(p, self.U)

    def loss(self, pred, q):
        return losses.cosine_proximity_loss(pred, self._embed(q, self.V))

    def decode(self, pred):
        vn = self.V / (jnp.linalg.norm(self.V, axis=-1, keepdims=True) + 1e-8)
        pn = pred / (jnp.linalg.norm(pred, axis=-1, keepdims=True) + 1e-8)
        return pn @ vn.T
