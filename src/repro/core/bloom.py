"""Bloom embeddings (paper Sec. 3.2): encode, recover, and module helpers.

Terminology follows the paper:
  d  — original (vocab / item-catalogue) dimensionality,
  m  — embedding dimensionality, m < d,
  k  — number of hash projections,
  p  — the set of active positions of a sparse instance x (padded, mask -1),
  u  — the Bloom-encoded binary vector, u[H_j(p_i)] = 1        (Eq. 1),
  v̂  — the model's m-dim softmax output,
  L(q_i) = prod_j v̂[H_j(q_i)]   (Eq. 2)  /  -sum_j log v̂[..]   (Eq. 3).

Everything here is pure jnp (the oracle path).  The Pallas fast path lives
in repro.kernels and is numerically checked against these functions.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hashing


@dataclasses.dataclass(frozen=True)
class BloomSpec:
    """Static description of one Bloom-embedded IO boundary."""

    d: int                    # original dimensionality (vocab size)
    m: int                    # compressed dimensionality
    k: int = 4                # number of hash projections (paper: 2..4 best)
    seed: int = 0
    on_the_fly: bool = True   # double-hash in-graph vs precomputed H matrix

    def __post_init__(self):
        if not (0 < self.m <= self.d):
            raise ValueError(f"need 0 < m <= d, got m={self.m} d={self.d}")
        if not (1 <= self.k <= self.m):
            raise ValueError(f"need 1 <= k <= m, got k={self.k} m={self.m}")

    @property
    def compression(self) -> float:
        return self.m / self.d

    def hash_matrix(self) -> jnp.ndarray:
        """(d, k) int32 hash matrix (paper's RAM-cached mode)."""
        return hashing.make_hash_matrix(self.d, self.k, self.m, self.seed)

    def indices_for(self, ids: jnp.ndarray,
                    hash_matrix: Optional[jnp.ndarray] = None) -> jnp.ndarray:
        """ids (...,) -> (..., k) hash indices in [0, m)."""
        if self.m == self.d and self.k == 1 and hash_matrix is None:
            # no-compression spec: the identity map (the paper's Baseline)
            return ids[..., None].astype(jnp.int32)
        if hash_matrix is None and not self.on_the_fly:
            hash_matrix = self.hash_matrix()
        return hashing.hash_indices(ids, k=self.k, m=self.m, seed=self.seed,
                                    hash_matrix=hash_matrix)


def identity_spec(d: int) -> BloomSpec:
    """No-compression spec (m == d, k == 1) — the paper's Baseline."""
    return BloomSpec(d=d, m=d, k=1)


@functools.lru_cache(maxsize=8)
def cached_hash_matrix(spec: BloomSpec) -> jnp.ndarray:
    """(d, k) int32 whole-vocab hash matrix for `spec`, cached per spec.

    Serving decodes the same spec every step; recomputing
    ``spec.indices_for(arange(d))`` per decode (or per retrace) rehashes the
    entire vocab each time and embeds a fresh d x k constant into every
    compiled step.  BloomSpec is frozen/hashable, so one device array per
    spec is built on first use and shared by every caller (kernels.ops, the
    serving loop, benchmarks).  Respects `on_the_fly`: the cached matrix is
    exactly what indices_for would return for every id.

    Forced eager (ensure_compile_time_eval): the first call may come from
    inside someone else's jit trace (an ops.* call in a user-jitted loss,
    or the lazy decode-bins thunk resolving at vjp-trace time) — without
    the guard the lru_cache would capture that trace's tracers and poison
    every later caller.
    """
    with jax.ensure_compile_time_eval():
        return spec.indices_for(jnp.arange(spec.d))


@functools.lru_cache(maxsize=8)
def cached_decode_bins(spec: BloomSpec, m_tile: int, e_tile: int):
    """CSR bins of the whole-vocab hash matrix, cached per (spec, tiling).

    The bwd_impl="csr" decode backward (DESIGN.md §4) scatter-adds the
    (B, d) cotangent through per-m-tile segments of H.  H is a pure
    function of the spec, so the binning pass (argsort of d*k entries —
    kernels.bloom_csr.bin_csr) runs ONCE per spec here, next to the
    cached hash matrix it bins, and every caller that DIFFERENTIATES the
    Eq. 3 decode (ranking losses / grad sweeps through ops.bloom_decode)
    reuses the device arrays; per-step binned-backward traffic is just
    the segment row DMAs.  Built lazily on the first csr decode backward
    — the LM training loss (embed + CE) never reads it.  (Embed bins
    depend on the batch's token indices and are rebuilt in-graph each
    step instead — see bloom_embed_pallas.)
    """
    from repro.kernels.bloom_csr import bin_csr   # deferred: keeps the
    # core -> kernels edge lazy so the oracle layer stays importable
    # without Pallas
    # The first call may come from INSIDE a backward trace (kernels.ops
    # resolves the bins thunk lazily at vjp-trace time); force eager
    # evaluation so the lru_cache always holds concrete device arrays —
    # never tracers of whatever jit happened to trigger the build.
    with jax.ensure_compile_time_eval():
        return bin_csr(cached_hash_matrix(spec), spec.m, m_tile=m_tile,
                       e_tile=e_tile)


_QUANT_CACHE: dict = {}


def cached_quantized_table(spec: BloomSpec, table: jnp.ndarray,
                           table_dtype: str):
    """Quantized ``table`` for a frozen-params caller, cached per spec.

    The serve-time sibling of cached_hash_matrix: eager callers (benches,
    eval sweeps, anything that calls kernels.ops with concrete params)
    would otherwise re-run quantize_table per call on a table that never
    changes.  Keyed on (spec, table_dtype) with an identity check on the
    table object — params swapped under the same spec (a training step,
    a checkpoint reload) miss and requantize, so the cache can never
    serve stale values; the straight-through TRAINING path never lands
    here at all (tracers quantize in-graph, see kernels.ops).
    """
    from repro.core import quant
    td = quant.resolve_table_dtype(table_dtype)
    key = (spec, td)
    hit = _QUANT_CACHE.get(key)
    if hit is not None and hit[0] is table:
        return hit[1]
    with jax.ensure_compile_time_eval():
        q = quant.quantize_table(table, td)
    _QUANT_CACHE[key] = (table, q)
    return q


# --------------------------------------------------------------------------
# Encoding (Eq. 1)
# --------------------------------------------------------------------------

def encode(spec: BloomSpec, p: jnp.ndarray,
           hash_matrix: Optional[jnp.ndarray] = None,
           dtype=jnp.float32) -> jnp.ndarray:
    """Bloom-encode padded index sets into multi-hot vectors.

    p: (..., c_max) int32, padding = -1.  Returns (..., m) in `dtype` with
    u[H_j(p_i)] = 1 for every valid p_i and projection j.  Binary (set, not
    add) semantics, exactly Eq. 1.
    """
    valid = p >= 0
    idx = spec.indices_for(jnp.where(valid, p, 0), hash_matrix)  # (..., c, k)
    flat = idx.reshape(*p.shape[:-1], -1)
    mask = jnp.repeat(valid, spec.k, axis=-1).reshape(flat.shape)
    u = jnp.zeros((*p.shape[:-1], spec.m), dtype=dtype)
    # scatter 1s; `max` keeps binary semantics under collisions.
    return u.at[..., flat].max(mask.astype(dtype)) if p.ndim == 1 else \
        _batched_scatter(u, flat, mask, dtype)


def _batched_scatter(u, flat, mask, dtype):
    def one(u_row, f_row, m_row):
        return u_row.at[f_row].max(m_row.astype(dtype))
    for _ in range(flat.ndim - 2):
        one = jax.vmap(one)
    return jax.vmap(one)(u, flat, mask)


def encode_dense(spec: BloomSpec, x: jnp.ndarray,
                 hash_matrix: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Encode dense binary instances (..., d) -> (..., m).

    Oracle-only path (materializes d); production uses `encode` on index
    sets.  u_i = max over original positions hashing to i.
    """
    if hash_matrix is None:
        hash_matrix = spec.hash_matrix() if not spec.on_the_fly else \
            spec.indices_for(jnp.arange(spec.d))
    hm = hash_matrix  # (d, k)
    onehot = jax.nn.one_hot(hm, spec.m, dtype=x.dtype)      # (d, k, m)
    proj = jnp.einsum("...d,dkm->...m", x, onehot)
    return jnp.minimum(proj, 1.0)


# --------------------------------------------------------------------------
# Recovery (Eqs. 2 & 3)
# --------------------------------------------------------------------------

def decode_scores(spec: BloomSpec, log_v: jnp.ndarray,
                  hash_matrix: Optional[jnp.ndarray] = None,
                  item_ids: Optional[jnp.ndarray] = None,
                  chunk: int = 8192) -> jnp.ndarray:
    """Eq. 3 ranking scores over original items.

    log_v: (..., m) log-probabilities (e.g. log_softmax of model logits).
    Returns (..., d) scores where scores[i] = sum_j log_v[H_j(i)] — larger is
    better; identical ranking to the Eq. 2 product likelihood.

    Memory-safe: chunks the item axis so we never materialize (..., d, k)
    for huge d.  `item_ids` restricts scoring to a subset (e.g. candidates).
    """
    if item_ids is not None:
        idx = spec.indices_for(item_ids, hash_matrix)         # (n, k)
        return jnp.take(log_v, idx, axis=-1).sum(-1)

    d = spec.d
    n_chunks = -(-d // chunk)
    pad_d = n_chunks * chunk
    ids = jnp.arange(pad_d, dtype=jnp.int32).reshape(n_chunks, chunk)

    def body(carry, ids_c):
        idx = spec.indices_for(jnp.minimum(ids_c, d - 1), hash_matrix)
        return carry, jnp.take(log_v, idx, axis=-1).sum(-1)

    _, out = jax.lax.scan(body, None, ids)                    # (nc, ..., chunk)
    out = jnp.moveaxis(out, 0, -2).reshape(*log_v.shape[:-1], pad_d)
    return out[..., :d]


def decode_topk(spec: BloomSpec, log_v: jnp.ndarray, topk: int,
                hash_matrix: Optional[jnp.ndarray] = None,
                chunk: int = 8192, unroll: bool = False):
    """Top-k item recovery without materializing all d scores at once.

    Streaming top-k merge over vocab chunks; returns (values, indices) of
    shape (..., topk).
    """
    d = spec.d
    n_chunks = -(-d // chunk)
    pad_d = n_chunks * chunk
    ids = jnp.arange(pad_d, dtype=jnp.int32).reshape(n_chunks, chunk)
    neg = jnp.asarray(-jnp.inf, log_v.dtype)

    init_v = jnp.full((*log_v.shape[:-1], topk), neg, log_v.dtype)
    init_i = jnp.full((*log_v.shape[:-1], topk), -1, jnp.int32)

    def body(carry, ids_c):
        best_v, best_i = carry
        idx = spec.indices_for(jnp.minimum(ids_c, d - 1), hash_matrix)
        s = jnp.take(log_v, idx, axis=-1).sum(-1)            # (..., chunk)
        s = jnp.where(ids_c < d, s, neg)
        cat_v = jnp.concatenate([best_v, s], axis=-1)
        cat_i = jnp.concatenate(
            [best_i, jnp.broadcast_to(ids_c, s.shape).astype(jnp.int32)], -1)
        v, sel = jax.lax.top_k(cat_v, topk)
        i = jnp.take_along_axis(cat_i, sel, axis=-1)
        return (v, i), None

    if unroll:
        carry = (init_v, init_i)
        for c in range(n_chunks):
            carry, _ = body(carry, ids[c])
        return carry
    (v, i), _ = jax.lax.scan(body, (init_v, init_i), ids)
    return v, i


def recover_probabilities(spec: BloomSpec, v_hat: jnp.ndarray,
                          hash_matrix: Optional[jnp.ndarray] = None,
                          eps: float = 1e-30) -> jnp.ndarray:
    """Eq. 2 likelihoods, renormalized to a distribution over d items.

    The paper skips renormalization (ranking tasks); provided for users that
    need calibrated probabilities.  Oracle path — materializes (..., d).
    """
    log_v = jnp.log(jnp.clip(v_hat, eps, 1.0))
    scores = decode_scores(spec, log_v, hash_matrix)
    return jax.nn.softmax(scores, axis=-1)
