"""data substrate."""
