"""Resumable, shard-aware batch pipeline.

Deterministic iteration whose full state (epoch, cursor, shuffle seed) is a
small dict stored inside every checkpoint — resuming after preemption
replays from the exact batch boundary (fault-tolerance requirement,
DESIGN.md §6).  Host-sharding: each host takes a strided slice
(host_id::host_count) so multi-host data-parallel feeding needs no
coordination.
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence

import numpy as np


class BatchIterator:
    """Shuffled, epoch-aware iterator over aligned numpy arrays."""

    def __init__(self, arrays: Sequence[np.ndarray], batch_size: int,
                 seed: int = 0, host_id: int = 0, host_count: int = 1,
                 drop_remainder: bool = True):
        n = arrays[0].shape[0]
        assert all(a.shape[0] == n for a in arrays)
        self.arrays = [a[host_id::host_count] for a in arrays]
        self.n = self.arrays[0].shape[0]
        self.batch_size = batch_size
        self.seed = seed
        self.drop_remainder = drop_remainder
        self.epoch = 0
        self.cursor = 0
        self._perm = self._make_perm()

    def _make_perm(self):
        rng = np.random.default_rng(self.seed + self.epoch)
        return rng.permutation(self.n)

    # ---- checkpointable state ------------------------------------------
    def state(self) -> Dict[str, int]:
        return {"epoch": self.epoch, "cursor": self.cursor,
                "seed": self.seed}

    def restore(self, state: Dict[str, int]):
        self.seed = int(state["seed"])
        self.epoch = int(state["epoch"])
        self.cursor = int(state["cursor"])
        self._perm = self._make_perm()

    # ---- iteration ------------------------------------------------------
    def __next__(self):
        if self.cursor + self.batch_size > self.n:
            if self.drop_remainder or self.cursor >= self.n:
                self.epoch += 1
                self.cursor = 0
                self._perm = self._make_perm()
        idx = self._perm[self.cursor:self.cursor + self.batch_size]
        self.cursor += self.batch_size
        return tuple(a[idx] for a in self.arrays)

    def __iter__(self):
        return self

    def batches_per_epoch(self) -> int:
        return self.n // self.batch_size


def lm_batches(stream: np.ndarray, batch: int, seq_len: int):
    """Chop a token stream into (batch, seq_len+1) windows (inputs+shifted
    labels come from the same window)."""
    per = seq_len + 1
    n_windows = len(stream) // per
    windows = stream[:n_windows * per].reshape(n_windows, per)
    return windows
