"""Synthetic data generators matched to the paper's dataset statistics.

The paper's 7 datasets are public but unavailable offline; we generate
latent-factor interaction data whose *statistics* (dimensionality d, median
set size c, density c/d, co-occurrence structure — paper Tables 1 & 4) are
dialed to match each task, so the qualitative claims (Figs. 1-3, Tables
3-5) can be validated end-to-end on CPU.

Generator: users/items live in a low-rank latent space with Zipf-distributed
item popularity; a user's profile is sampled from popularity x affinity and
split at a random point into input/output halves — the paper's
'split user profiles at a timestamp chosen uniformly at random'.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np
import scipy.sparse as sp


@dataclasses.dataclass
class RecsysData:
    """Padded index-set views (+ sparse matrices) of a generated dataset."""

    d: int
    p_in: np.ndarray          # (n, c_max) int32, -1 padded — input item sets
    q_out: np.ndarray         # (n, c_max) int32, -1 padded — target sets
    X_in: sp.csr_matrix       # (n, d) binary
    X_out: sp.csr_matrix
    n_train: int

    @property
    def n(self) -> int:
        return self.p_in.shape[0]

    def train(self):
        return self.p_in[:self.n_train], self.q_out[:self.n_train]

    def test(self):
        return self.p_in[self.n_train:], self.q_out[self.n_train:]


def _pad_sets(sets, c_max: int) -> np.ndarray:
    out = np.full((len(sets), c_max), -1, np.int32)
    for i, s in enumerate(sets):
        s = np.asarray(s[:c_max], np.int32)
        out[i, :len(s)] = s
    return out


def _to_sparse(sets, n: int, d: int) -> sp.csr_matrix:
    rows, cols = [], []
    for i, s in enumerate(sets):
        rows.extend([i] * len(s))
        cols.extend(s)
    data = np.ones(len(rows), np.float64)
    return sp.csr_matrix((data, (rows, cols)), shape=(n, d))


def make_recsys(
    n: int = 4000,
    d: int = 2000,
    rank: int = 16,
    mean_items: int = 12,
    zipf_a: float = 1.2,
    test_frac: float = 0.2,
    seed: int = 0,
) -> RecsysData:
    """Latent-factor interaction data with Zipf popularity."""
    rng = np.random.default_rng(seed)
    users = rng.normal(size=(n, rank)) / np.sqrt(rank)
    items = rng.normal(size=(d, rank)) / np.sqrt(rank)
    pop = 1.0 / np.power(np.arange(1, d + 1), zipf_a)
    pop = pop[rng.permutation(d)]
    pop /= pop.sum()

    p_in_sets, q_out_sets = [], []
    logits_scale = 4.0
    for u in range(n):
        c = max(2, int(rng.poisson(mean_items)))
        aff = users[u] @ items.T
        w = pop * np.exp(logits_scale * aff)
        w /= w.sum()
        profile = rng.choice(d, size=min(c, d), replace=False, p=w)
        split = rng.integers(1, len(profile)) if len(profile) > 1 else 1
        p_in_sets.append(profile[:split])
        q_out_sets.append(profile[split:] if split < len(profile)
                          else profile[-1:])

    c_max = max(max(len(s) for s in p_in_sets),
                max(len(s) for s in q_out_sets))
    n_train = int(n * (1 - test_frac))
    return RecsysData(
        d=d,
        p_in=_pad_sets(p_in_sets, c_max),
        q_out=_pad_sets(q_out_sets, c_max),
        X_in=_to_sparse(p_in_sets, n, d),
        X_out=_to_sparse(q_out_sets, n, d),
        n_train=n_train,
    )


def make_classification(
    n: int = 3000,
    d: int = 5000,
    n_classes: int = 12,
    mean_items: int = 17,
    seed: int = 0,
    test_frac: float = 0.25,
):
    """CADE-style: sparse binary documents -> one of n_classes labels.

    Class-conditional Zipf vocabularies with overlap, mirroring text
    categorization.  Returns (p_in (n,c_max), labels (n,), n_train).
    """
    rng = np.random.default_rng(seed)
    class_centers = rng.dirichlet(np.full(d, 0.05), size=n_classes)
    labels = rng.integers(0, n_classes, size=n)
    sets = []
    for i in range(n):
        c = max(3, int(rng.poisson(mean_items)))
        sets.append(rng.choice(d, size=min(c, d), replace=False,
                               p=class_centers[labels[i]]))
    c_max = max(len(s) for s in sets)
    n_train = int(n * (1 - test_frac))
    return (_pad_sets(sets, c_max), labels.astype(np.int32), n_train,
            _to_sparse(sets, n, d))


def make_sessions(
    n_sessions: int = 6000,
    d: int = 3000,
    mean_len: int = 6,
    rank: int = 12,
    seed: int = 0,
    test_frac: float = 0.2,
):
    """YC/PTB-style next-item sequences from a latent Markov process.

    Returns (seqs (n, T_max) int32 -1-padded, n_train).  Targets are the
    next element at every position.
    """
    rng = np.random.default_rng(seed)
    items = rng.normal(size=(d, rank)) / np.sqrt(rank)
    pop = 1.0 / np.power(np.arange(1, d + 1), 1.1)
    pop = pop[rng.permutation(d)] / pop.sum()
    seqs = []
    for s in range(n_sessions):
        T = max(2, int(rng.poisson(mean_len)))
        cur = rng.choice(d, p=pop)
        seq = [cur]
        for _ in range(T - 1):
            aff = items[cur] @ items.T
            w = pop * np.exp(5.0 * aff)
            w /= w.sum()
            cur = rng.choice(d, p=w)
            seq.append(cur)
        seqs.append(seq)
    t_max = max(len(s) for s in seqs)
    padded = _pad_sets(seqs, t_max)
    return padded, int(n_sessions * (1 - test_frac))


def make_token_stream(n_tokens: int, vocab: int, seed: int = 0,
                      zipf_a: float = 1.1) -> np.ndarray:
    """Zipf token stream for LM smoke training (qwen-style cells)."""
    rng = np.random.default_rng(seed)
    p = 1.0 / np.power(np.arange(1, vocab + 1), zipf_a)
    p /= p.sum()
    return rng.choice(vocab, size=n_tokens, p=p).astype(np.int32)
