"""Pure-jnp oracles for the Pallas kernels — the ground truth every kernel
sweep in tests/test_kernels_*.py asserts against."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def bloom_embed_ref(table: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    """table (m, D); idx (T, k) hash indices -> (T, D) k-way gather-sum."""
    rows = jnp.take(table, idx, axis=0)            # (T, k, D)
    return rows.sum(axis=1)


def bloom_decode_ref(logp: jnp.ndarray, H: jnp.ndarray) -> jnp.ndarray:
    """logp (B, m); H (d, k) -> scores (B, d): scores[b,i]=sum_j logp[b,H[i,j]]."""
    g = jnp.take(logp, H, axis=-1)                 # (B, d, k)
    return g.sum(-1)


def bloom_ce_ref(logits: jnp.ndarray, h_idx: jnp.ndarray) -> jnp.ndarray:
    """logits (T, m); h_idx (T, k) hashed labels ->
    loss (T,) = logsumexp(z) - mean_j z[h_j]."""
    z = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(z, axis=-1)
    picked = jnp.take_along_axis(z, h_idx, axis=-1)   # (T, k)
    return lse - picked.mean(-1)
