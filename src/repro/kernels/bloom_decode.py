"""Pallas TPU kernel: Bloom vocabulary recovery (paper Eq. 3).

scores[b, i] = sum_{j<k} logp[b, H[i, j]]

TPU mapping: the m-dim log-prob row is small (m = d/5 of a 152k vocab is
~30k fp32 = 120 KB) and is kept WHOLE in VMEM per batch tile, so the
per-item k-gather runs at VMEM bandwidth while the vocab axis streams
through the grid.  This inverts the GPU formulation (random HBM access)
into sequential-HBM + random-VMEM — the memory-hierarchy adaptation of
DESIGN.md §4.

  grid = (nB, nV)
  logp — block (Bt, m)  at (b, 0)  (revisited across the vocab axis; Pallas
         keeps it resident in VMEM between consecutive grid steps)
  H    — block (Vt, k)  at (v, 0)
  out  — block (Bt, Vt) at (b, v)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(logp_ref, h_ref, out_ref):
    logp = logp_ref[...].astype(jnp.float32)       # (Bt, m)
    h = h_ref[...]                                 # (Vt, k)
    k = h.shape[1]
    acc = jnp.take(logp, h[:, 0], axis=1)          # (Bt, Vt)
    for j in range(1, k):
        acc = acc + jnp.take(logp, h[:, j], axis=1)
    out_ref[...] = acc.astype(out_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("b_tile", "v_tile", "interpret"))
def bloom_decode_pallas(logp: jnp.ndarray, H: jnp.ndarray,
                        b_tile: int = 8, v_tile: int = 2048,
                        interpret: bool = True) -> jnp.ndarray:
    """logp (B, m) float; H (d, k) int32 -> scores (B, d) float32."""
    B, m = logp.shape
    d, k = H.shape
    b_tile = min(b_tile, B)
    v_tile = min(v_tile, d)
    pad_b = (-B) % b_tile
    pad_v = (-d) % v_tile
    if pad_b:
        logp = jnp.pad(logp, ((0, pad_b), (0, 0)))
    if pad_v:
        H = jnp.pad(H, ((0, pad_v), (0, 0)))
    Bp, dp = B + pad_b, d + pad_v

    out = pl.pallas_call(
        _kernel,
        grid=(Bp // b_tile, dp // v_tile),
        in_specs=[
            pl.BlockSpec((b_tile, m), lambda b, v: (b, 0)),
            pl.BlockSpec((v_tile, k), lambda b, v: (v, 0)),
        ],
        out_specs=pl.BlockSpec((b_tile, v_tile), lambda b, v: (b, v)),
        out_shape=jax.ShapeDtypeStruct((Bp, dp), jnp.float32),
        interpret=interpret,
    )(logp, H)
    return out[:B, :d]
