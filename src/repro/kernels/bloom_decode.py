"""Pallas TPU kernels: Bloom vocabulary recovery (paper Eq. 3), forward and
backward.

Forward:   scores[b, i] = sum_{j<k} logp[b, H[i, j]]
Backward:  dlogp[b, c]  = sum_{i, j : H[i, j] == c} g[b, i]   (scatter-add)

TPU mapping: the m-dim log-prob row is small (m = d/5 of a 152k vocab is
~30k fp32 = 120 KB) and is kept WHOLE in VMEM per batch tile, so the
per-item k-gather runs at VMEM bandwidth while the vocab axis streams
through the grid.  This inverts the GPU formulation (random HBM access)
into sequential-HBM + random-VMEM — the memory-hierarchy adaptation of
DESIGN.md §4.

  grid = (nB, nV)
  logp — block (Bt, m)  at (b, 0)  (revisited across the vocab axis; Pallas
         keeps it resident in VMEM between consecutive grid steps)
  H    — block (Vt, k)  at (v, 0)
  out  — block (Bt, Vt) at (b, v)

The DENSE backward inverts the stream: grid (nM, nV) with the vocab axis
innermost; each step builds the (v_tile, m_tile) one-hot count matrix
w[i, c] = #{j : H[i, j] == c} from k iota-compares in VMEM and accumulates
``g_tile @ w`` into the revisited (B, m_tile) output block on the MXU —
race-free, and no (B, d, k) or (d, m) one-hot ever reaches HBM, but the
m-tile sweep re-reads the (B, d) cotangent and H nM times.
``bwd_impl="csr"`` (the training default) routes the VJP through the
CSR-binned backward (kernels/bloom_csr.py) on the transposed cotangent
with per-spec cached bins of H — one read of g plus ~k row fetches; the
dense kernel remains the oracle-adjacent fallback.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import quant
from repro.kernels.common import (BWD_M_TILE, onehot_count, pad_axis,
                                  resolve_bwd_impl, resolve_interpret)


# --------------------------------------------------------------------------
# Forward
# --------------------------------------------------------------------------

def _fwd_kernel(logp_ref, h_ref, out_ref):
    logp = logp_ref[...].astype(jnp.float32)       # (Bt, m)
    h = h_ref[...]                                 # (Vt, k)
    k = h.shape[1]
    acc = jnp.take(logp, h[:, 0], axis=1)          # (Bt, Vt)
    for j in range(1, k):
        acc = acc + jnp.take(logp, h[:, j], axis=1)
    out_ref[...] = acc.astype(out_ref.dtype)


def _fwd_kernel_scaled(logp_ref, s_ref, h_ref, out_ref):
    """int8-logp variant (DESIGN.md §13): every gathered element of a
    batch row shares that row's scale, so the k-gather accumulates in the
    integer domain's f32 image and dequantizes ONCE on the (Bt, Vt)
    output tile — one multiply per output, not per gather."""
    logp = logp_ref[...].astype(jnp.float32)       # (Bt, m) int8 -> f32
    h = h_ref[...]                                 # (Vt, k)
    k = h.shape[1]
    acc = jnp.take(logp, h[:, 0], axis=1)          # (Bt, Vt)
    for j in range(1, k):
        acc = acc + jnp.take(logp, h[:, j], axis=1)
    out_ref[...] = (acc * s_ref[...]).astype(out_ref.dtype)   # s (Bt, 1)


def _decode_fwd(logp, H, b_tile, v_tile, interpret, scales=None):
    B, m = logp.shape
    d, k = H.shape
    logp = pad_axis(logp, 0, b_tile)
    H = pad_axis(H, 0, v_tile)
    Bp, dp = logp.shape[0], H.shape[0]

    in_specs = [
        pl.BlockSpec((b_tile, m), lambda b, v: (b, 0)),
        pl.BlockSpec((v_tile, k), lambda b, v: (v, 0)),
    ]
    operands = (logp, H)
    kernel = _fwd_kernel
    if scales is not None:
        sg = pad_axis(scales.astype(jnp.float32)[:, None], 0, b_tile)
        in_specs.insert(1, pl.BlockSpec((b_tile, 1), lambda b, v: (b, 0)))
        operands = (logp, sg, H)
        kernel = _fwd_kernel_scaled

    out = pl.pallas_call(
        kernel,
        grid=(Bp // b_tile, dp // v_tile),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((b_tile, v_tile), lambda b, v: (b, v)),
        out_shape=jax.ShapeDtypeStruct((Bp, dp), jnp.float32),
        interpret=interpret,
    )(*operands)
    return out[:B, :d]


def _decode_fwd_quant(logp, H, b_tile, v_tile, interpret, table_dtype):
    if table_dtype is None:
        return _decode_fwd(logp, H, b_tile, v_tile, interpret)
    qlogp, scales = quant.quantize_table(logp, table_dtype)
    return _decode_fwd(qlogp, H, b_tile, v_tile, interpret, scales=scales)


# --------------------------------------------------------------------------
# Backward (dlogp)
# --------------------------------------------------------------------------

def _bwd_kernel(h_ref, g_ref, out_ref, *, m_tile):
    iv = pl.program_id(1)

    @pl.when(iv == 0)
    def _():
        out_ref[...] = jnp.zeros_like(out_ref)

    base = pl.program_id(0) * m_tile
    w = onehot_count(h_ref[...], m_tile, base)           # (v_tile, m_tile)
    g = g_ref[...].astype(jnp.float32)                   # (B, v_tile)
    out_ref[...] += jnp.dot(g, w, preferred_element_type=jnp.float32)


@functools.partial(jax.jit,
                   static_argnames=("m", "m_tile", "v_tile", "interpret"))
def bloom_decode_bwd_pallas(g: jnp.ndarray, H: jnp.ndarray, m: int,
                            m_tile: int = BWD_M_TILE, v_tile: int = 2048,
                            interpret: bool | None = None) -> jnp.ndarray:
    """g (B, d) cotangent; H (d, k) -> dlogp (B, m) float32 scatter-add."""
    interpret = resolve_interpret(interpret)
    B, d = g.shape
    k = H.shape[1]
    m_tile = min(m_tile, m)
    v_tile = min(v_tile, d)
    g = pad_axis(g, 1, v_tile)
    H = pad_axis(H, 0, v_tile, value=-1)       # -1 never matches the iota
    mp = m + ((-m) % m_tile)
    dp = H.shape[0]
    grid = (mp // m_tile, dp // v_tile)

    out = pl.pallas_call(
        functools.partial(_bwd_kernel, m_tile=m_tile),
        grid=grid,
        in_specs=[
            pl.BlockSpec((v_tile, k), lambda im, iv: (iv, 0)),
            pl.BlockSpec((B, v_tile), lambda im, iv: (0, iv)),
        ],
        out_specs=pl.BlockSpec((B, m_tile), lambda im, iv: (0, im)),
        out_shape=jax.ShapeDtypeStruct((B, mp), jnp.float32),
        interpret=interpret,
    )(H, g)
    return out[:, :m]


# --------------------------------------------------------------------------
# custom_vjp glue + public entry point
# --------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4, 5, 6, 7, 8, 9))
def _bloom_decode(logp, H, bins_fn, b_tile, v_tile, interpret, bwd_impl,
                  m_tile, e_tile, table_dtype):
    return _decode_fwd_quant(logp, H, b_tile, v_tile, interpret, table_dtype)


def _bloom_decode_vjp_fwd(logp, H, bins_fn, b_tile, v_tile, interpret,
                          bwd_impl, m_tile, e_tile, table_dtype):
    return (_decode_fwd_quant(logp, H, b_tile, v_tile, interpret,
                              table_dtype), (logp, H))


def _bloom_decode_vjp_bwd(bins_fn, b_tile, v_tile, interpret, bwd_impl,
                          m_tile, e_tile, table_dtype, res, g):
    logp, H = res
    if bwd_impl == "csr":
        from repro.kernels.bloom_csr import bloom_decode_bwd_csr_pallas
        # bins_fn resolves HERE, at backward-trace time — forward-only
        # callers never pay the binning sort (the cached device arrays
        # are picked up as constants, like cached_hash_matrix elsewhere)
        bins = bins_fn() if bins_fn is not None else None
        dlogp = bloom_decode_bwd_csr_pallas(
            g, H, logp.shape[1], m_tile=m_tile, e_tile=e_tile,
            interpret=interpret, bins=bins)
    else:
        # all tiling knobs forwarded (m_tile was previously dropped)
        dlogp = bloom_decode_bwd_pallas(g, H, logp.shape[1],
                                        m_tile=m_tile, v_tile=v_tile,
                                        interpret=interpret)
    # table_dtype != None trains straight-through: the scatter-add is the
    # exact gradient of the unquantized linear map (the backward kernels
    # never read logp, so their math is untouched — DESIGN.md §13).
    return dlogp.astype(logp.dtype), None


_bloom_decode.defvjp(_bloom_decode_vjp_fwd, _bloom_decode_vjp_bwd)


@functools.partial(jax.jit,
                   static_argnames=("b_tile", "v_tile", "interpret",
                                    "bwd_impl", "m_tile", "e_tile",
                                    "bins_fn", "table_dtype"))
def bloom_decode_pallas(logp: jnp.ndarray, H: jnp.ndarray,
                        b_tile: int = 8, v_tile: int = 2048,
                        interpret: bool | None = None,
                        bwd_impl: str = "dense",
                        m_tile: int = BWD_M_TILE,
                        e_tile: int | None = None,
                        bins_fn=None,
                        table_dtype: str | None = None) -> jnp.ndarray:
    """logp (B, m) float; H (d, k) int32 -> scores (B, d) float32.

    Differentiable: jax.grad w.r.t. `logp` runs the scatter-add backward
    selected by ``bwd_impl`` — "dense" (the blocked m-tile sweep,
    oracle-adjacent fallback) or "csr" (the CSR-binned backward of
    kernels.bloom_csr, which reads the (B, d) cotangent once instead of
    once per m-tile).  ``bins_fn`` is an optional HASHABLE zero-arg
    callable returning precomputed bin_csr output for H; it is invoked
    only when the backward is traced, so forward-only calls never pay
    the binning pass (kernels.ops wires the per-spec
    core.bloom.cached_decode_bins thunk here — H is fixed per BloomSpec,
    so the sort amortizes to zero).  None on the csr path re-bins
    in-graph inside the backward.  All backward tiling knobs
    (``m_tile``, ``e_tile``) are threaded through the custom VJP.

    ``table_dtype`` (DESIGN.md §13) stores the resident (B, m) log-prob
    block in a narrower dtype: "int8" quantizes per-batch-row symmetric
    and dequantizes once per output tile in VMEM; "bfloat16"/"fp8_e4m3"
    cast (the kernel's astype(f32) is the dequant); None is the legacy
    exact path.  Gradients are straight-through against the f32 logp.
    """
    bwd_impl, e_tile = resolve_bwd_impl(bwd_impl, e_tile)
    b_tile = min(b_tile, logp.shape[0])
    v_tile = min(v_tile, H.shape[0])
    return _bloom_decode(logp, H, bins_fn, b_tile, v_tile,
                         resolve_interpret(interpret), bwd_impl, m_tile,
                         e_tile, quant.resolve_table_dtype(table_dtype))
