"""Pallas TPU kernel: fused Bloom vocabulary recovery + streaming top-k
(the serving hot path — paper Fig. 3 right, DESIGN.md §4/§5).

The unfused serving decode writes the full (B, d) recovered-score matrix to
HBM and reads it back for jax.lax.top_k — 2 * B * d * 4 bytes that dominate
decode cost at LLM vocab scale (qwen3-4b: d = 151 936).  This kernel never
materializes the score matrix: it streams (v_tile, k) hash-matrix tiles
through the grid, recovers each (Bt, Vt) score tile in VMEM from the
resident (Bt, m) log-prob row, and folds it into a running per-batch top-k
held in VMEM scratch.  HBM traffic drops to

    B*m*4 (logp) + d*k*4 (H) + B*topk*8 (out)        [>= 3.8x fewer bytes
                                                      than decode-then-topk
                                                      at qwen3-4b shapes]

  grid = (nB, nV)          — vocab axis innermost
  logp — block (Bt, m)  at (b, 0)   (VMEM-resident across the vocab sweep)
  H    — block (Vt, k)  at (v, 0)
  outs — values (Bt, topk) f32 and ids (Bt, topk) i32 at (b, 0), written
         once at the last vocab step
  scratch — running (Bt, topk) best values/ids, reset at v == 0

The merge concatenates the running best with the fresh score tile and takes
``jax.lax.top_k`` over topk + Vt lanes; each vocab id enters the stream
exactly once, so no dedup pass is needed.

**Row-skipping grid (serving slot pools, DESIGN.md §8).**  A continuous-
batching pool at partial occupancy decodes dead slot rows; the dense grid
still streams every (logp row-block, H vocab tile) pair for them.  With
``active`` given, a slot-occupancy-prefetched grid
(``pltpu.PrefetchScalarGridSpec``) skips the HBM traffic of fully-inactive
row blocks: the prefetched per-block occupancy drives *data-dependent
index maps* that pin an inactive block's logp/H block indices to the
previously-resident blocks, so the Pallas pipeline issues NO new copies
for them (a revisited block index is never re-fetched); the kernel body
skips the fold under ``pl.when`` and emits (-inf, 0) for skipped rows —
exactly the post-hoc masking ``io.recover_topk`` applies anyway.  Modeled
HBM bytes drop from ``nB*(Bt*m*4 + d*k*4)`` to ``nA*(Bt*m*4 + d*k*4)``
(+ the B*topk*8 output either way) where nA = #row-blocks containing at
least one live slot — bytes scale with occupancy instead of pool size
(bench_kernels.py commits the occupancy sweep; CI gates >=1.5x fewer
bytes at <=50% occupancy).

**Quantized logp + in-kernel hashing (DESIGN.md §13).**  ``table_dtype``
stores the resident (Bt, m) block in bf16/int8/fp8 — the VMEM gather runs
on the narrow tile and int8 dequantizes with ONE per-batch-row scale
multiply on the score tile.  That alone cannot beat the fp32 row by the
gated 3x: at serving batch sizes the ``d*k*4`` H stream dominates (2.4 MB
vs 0.24 MB of logp at qwen3-4b/B=8).  So the quantized path also drops H
entirely: ``hash_spec=(d, k, seed)`` re-derives every vocab tile's hash
indices IN-KERNEL from the tile's id iota via enhanced double hashing —
bit-identical to core.hashing.double_hash (and therefore to the cached
(d, k) matrix for any on-the-fly spec), at zero HBM bytes.  Identity
specs (m == d, k == 1) keep the explicit-H path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import hashing, quant
from repro.kernels.common import pad_axis, resolve_interpret


def modeled_hbm_bytes(active, b_tile: int, *, m: int, d: int, k: int,
                      topk: int, logp_itemsize: int = 4,
                      inkernel_hash: bool = False,
                      row_scales: bool = False) -> int:
    """Analytic HBM bytes of one row-skipping decode-topk call for a
    given slot-occupancy mask — the SINGLE source for the occupancy rows
    in benchmarks/bench_kernels.py and the serving byte audits, so the
    bytes model can never drift from the grid it describes.

    Per VISITED row block the grid streams the (b_tile, m) logp block at
    ``logp_itemsize`` bytes/element (4 = legacy f32; the table_dtype knob
    sets 2/1/1 for bf16/int8/fp8) plus one full (d, k) i32 sweep of H
    (vocab axis innermost => H is re-streamed per block) — unless
    ``inkernel_hash``, where the hash indices are re-derived from the
    tile iota at zero HBM cost.  ``row_scales`` adds the (b_tile,) f32
    int8 dequant scales per visited block.  Blocks with no live slot are
    pinned to resident blocks and fetch nothing.  The (B, topk) f32+i32
    outputs are flushed for every block, live or dead.  A dense (no
    ``active``) grid is the all-ones mask.
    """
    act = np.asarray(active, bool).ravel()
    B = act.shape[0]
    pad = (-B) % b_tile
    if pad:
        act = np.concatenate([act, np.zeros(pad, bool)])
    n_visited = int(act.reshape(-1, b_tile).any(axis=1).sum())
    per_block = b_tile * m * logp_itemsize
    if not inkernel_hash:
        per_block += d * k * 4
    if row_scales:
        per_block += b_tile * 4
    return int(n_visited * per_block + B * topk * 8)


def _tile_scores(logp, h_ref, iv, v_tile, hash_spec):
    """(Bt, Vt) raw score tile: k-gather from the resident logp block,
    indices either streamed from H or re-derived in-kernel."""
    if hash_spec is None:
        h = h_ref[...]                              # (Vt, k)
        k = h.shape[1]
        scores = jnp.take(logp, h[:, 0], axis=1)    # (Bt, Vt)
        for j in range(1, k):
            scores = scores + jnp.take(logp, h[:, j], axis=1)
        return scores
    # Enhanced double hashing on the tile's id iota — the exact
    # arithmetic of core.hashing.double_hash, with the two mixed salts
    # baked in as static scalars (hashing.double_hash_salts).
    m, k, c1, c2 = hash_spec
    vid = (jax.lax.broadcasted_iota(jnp.int32, (1, v_tile), 1)
           + iv * v_tile).astype(jnp.uint32)
    h1 = hashing.splitmix32(vid ^ np.uint32(c1)) % np.uint32(m)
    h2 = hashing.splitmix32(vid ^ np.uint32(c2)) \
        % np.uint32(max(m - 1, 1)) + np.uint32(1)
    scores = None
    for j in range(k):
        tri = (j ** 3 - j) // 6 % m
        hj = (h1 + np.uint32(j) * h2 + np.uint32(tri)) % np.uint32(m)
        hj = hj.astype(jnp.int32).reshape(v_tile)
        sj = jnp.take(logp, hj, axis=1)
        scores = sj if scores is None else scores + sj
    return scores


def _fold_tile(logp_ref, h_ref, s_ref, vals_ref, ids_ref, best_v, best_i, *,
               iv, topk, v_tile, d, hash_spec):
    """One (row-block, vocab-tile) fold of the streaming top-k — shared
    by the dense and the row-skipping grids."""
    logp = logp_ref[...].astype(jnp.float32)        # (Bt, m)
    if s_ref is not None:
        # int8 dequant happens HERE, on the VMEM-resident (Bt, m) block:
        # one per-batch-row scale multiply before the k-gather, so the
        # gathered f32 values (and thus tie patterns) are bit-identical
        # to the XLA dequantize-then-decode oracle.
        logp = logp * s_ref[...]                    # s (Bt, 1)
    scores = _tile_scores(logp, h_ref, iv, v_tile, hash_spec)

    b_tile = scores.shape[0]
    gid = jax.lax.broadcasted_iota(jnp.int32, (b_tile, v_tile), 1) \
        + iv * v_tile
    scores = jnp.where(gid < d, scores, -jnp.inf)   # mask vocab padding

    # Seed the running best from the first tile (requires topk <= v_tile)
    # rather than -inf/-1 sentinels: with fully -inf rows (masked vocabs)
    # a sentinel would win the top_k tie-break and leak id -1.  Seeding
    # also reproduces jax.lax.top_k's lowest-index tie ordering exactly —
    # best entries (earlier vocab ids) sit first in the concat, and
    # -inf-masked pad ids can never displace them.
    @pl.when(iv == 0)
    def _():
        top_v, sel = jax.lax.top_k(scores, topk)
        best_v[...] = top_v
        best_i[...] = jnp.take_along_axis(gid, sel, axis=-1)

    @pl.when(iv > 0)
    def _():
        cat_v = jnp.concatenate([best_v[...], scores], axis=-1)
        cat_i = jnp.concatenate([best_i[...], gid], axis=-1)
        top_v, sel = jax.lax.top_k(cat_v, topk)
        best_v[...] = top_v
        best_i[...] = jnp.take_along_axis(cat_i, sel, axis=-1)

    @pl.when(iv == pl.num_programs(1) - 1)
    def _():
        vals_ref[...] = best_v[...]
        ids_ref[...] = best_i[...]


def _split_refs(refs, has_scales, hash_spec):
    """(logp[, s][, h], vals, ids, best_v, best_i) positional unpack for
    the dense/skip kernels' variable operand lists."""
    refs = list(refs)
    logp_ref = refs.pop(0)
    s_ref = refs.pop(0) if has_scales else None
    h_ref = refs.pop(0) if hash_spec is None else None
    vals_ref, ids_ref, best_v, best_i = refs
    return logp_ref, s_ref, h_ref, vals_ref, ids_ref, best_v, best_i


def _kernel(*refs, topk, v_tile, d, has_scales, hash_spec):
    logp_ref, s_ref, h_ref, vals_ref, ids_ref, best_v, best_i = \
        _split_refs(refs, has_scales, hash_spec)
    _fold_tile(logp_ref, h_ref, s_ref, vals_ref, ids_ref, best_v, best_i,
               iv=pl.program_id(1), topk=topk, v_tile=v_tile, d=d,
               hash_spec=hash_spec)


def _kernel_skip(occ_ref, pin_ref, *refs, topk, v_tile, d, has_scales,
                 hash_spec):
    """Row-skipping variant: ``occ_ref``/``pin_ref`` are the scalar-
    prefetched per-block occupancy / logp-block pin arrays (also consumed
    by the data-dependent index maps).  Inactive blocks never touch HBM:
    their logp/H block indices revisit resident blocks (no copy), the fold
    is skipped, and the output block — which IS flushed for every b — is
    written as (-inf, 0), matching recover_topk's dead-row masking."""
    logp_ref, s_ref, h_ref, vals_ref, ids_ref, best_v, best_i = \
        _split_refs(refs, has_scales, hash_spec)
    ib = pl.program_id(0)
    iv = pl.program_id(1)
    act = occ_ref[ib] > 0

    @pl.when(act)
    def _():
        _fold_tile(logp_ref, h_ref, s_ref, vals_ref, ids_ref, best_v,
                   best_i, iv=iv, topk=topk, v_tile=v_tile, d=d,
                   hash_spec=hash_spec)

    @pl.when(jnp.logical_not(act) & (iv == pl.num_programs(1) - 1))
    def _():
        vals_ref[...] = jnp.full(vals_ref.shape, -jnp.inf,
                                 vals_ref.dtype)
        ids_ref[...] = jnp.zeros(ids_ref.shape, ids_ref.dtype)


def block_occupancy(active: jnp.ndarray, b_tile: int):
    """active (B,) bool -> (occ, pin), the scalar-prefetch operands of the
    row-skipping grid, for B padded to a multiple of b_tile.

    occ (nB,) int32 — 1 iff the row block holds >=1 live slot.
    pin (nB,) int32 — logp block to map block b's fetch to: b itself when
    active, else the nearest active block at-or-before b (still resident
    when the pipeline reaches b — revisit, no copy), else the FIRST
    active block (leading dead blocks prefetch the block the first live
    sweep needs anyway, so even a drained low-slot prefix issues no dead
    logp fetch).  All-dead pools pin to 0 (one unavoidable fetch; the
    engine never decodes an empty pool).
    """
    act = pad_axis(active.astype(jnp.int32), 0, b_tile)
    blk = act.reshape(-1, b_tile).max(axis=1)
    idx = jnp.arange(blk.shape[0], dtype=jnp.int32)
    cand = jnp.where(blk > 0, idx, -1)
    before = jax.lax.cummax(cand, axis=0)
    first_active = jnp.argmax(blk > 0).astype(jnp.int32)  # 0 if none
    pin = jnp.where(before >= 0, before, first_active).astype(jnp.int32)
    return blk.astype(jnp.int32), pin


@functools.partial(jax.jit,
                   static_argnames=("topk", "b_tile", "v_tile", "interpret",
                                    "table_dtype", "hash_spec"))
def bloom_decode_topk_pallas(logp: jnp.ndarray, H: jnp.ndarray | None,
                             topk: int,
                             b_tile: int = 8, v_tile: int = 2048,
                             interpret: bool | None = None,
                             active: jnp.ndarray | None = None,
                             table_dtype: str | None = None,
                             hash_spec: tuple[int, int, int] | None = None):
    """logp (B, m) float; H (d, k) int32 -> (values, ids), each (B, topk).

    values[b] are the topk largest Eq. 3 scores over the original vocab,
    descending; ids[b] the corresponding item/token ids.  The (B, d) score
    matrix is never written to HBM.

    ``active`` (B,) bool selects the row-skipping occupancy grid: rows in
    a fully-inactive b_tile block are skipped at the HBM level (no logp /
    H tile fetches — see module docstring) and return (-inf, 0); rows
    sharing a block with a live slot are computed normally, identical to
    the dense grid (the caller masks dead rows regardless —
    io.recover_topk).

    ``table_dtype`` (DESIGN.md §13) stores the resident logp block in a
    narrower dtype (int8: per-row symmetric scales, dequantized on the
    score tile).  ``hash_spec=(d, k, seed)`` drops the H operand and
    re-derives hash indices in-kernel (bit-identical to
    core.hashing.double_hash for on-the-fly specs); H may then be None.
    """
    interpret = resolve_interpret(interpret)
    B, m = logp.shape
    if hash_spec is not None:
        d, k, seed = hash_spec
        c1, c2 = hashing.double_hash_salts(seed)
        kern_hash = (m, k, c1, c2)
        H = None
    else:
        d, k = H.shape
        kern_hash = None
    if not (0 < topk <= d):
        raise ValueError(f"need 0 < topk <= d, got topk={topk} d={d}")
    b_tile = min(b_tile, B)
    v_tile = max(min(v_tile, d), topk)   # first tile seeds the running best

    table_dtype = quant.resolve_table_dtype(table_dtype)
    scales = None
    if table_dtype is not None:
        logp, scales = quant.quantize_table(logp, table_dtype)

    logp = pad_axis(logp, 0, b_tile)
    Bp = logp.shape[0]
    if H is not None:
        H = pad_axis(H, 0, v_tile)             # padded ids masked via d
        dp = H.shape[0]
    else:
        dp = d + ((-d) % v_tile)               # iota ids masked via d
    grid = (Bp // b_tile, dp // v_tile)
    has_scales = scales is not None

    out_shape = [
        jax.ShapeDtypeStruct((Bp, topk), jnp.float32),
        jax.ShapeDtypeStruct((Bp, topk), jnp.int32),
    ]
    scratch_shapes = [
        pltpu.VMEM((b_tile, topk), jnp.float32),
        pltpu.VMEM((b_tile, topk), jnp.int32),
    ]
    kwargs = dict(topk=topk, v_tile=v_tile, d=d, has_scales=has_scales,
                  hash_spec=kern_hash)

    if active is None:
        in_specs = [pl.BlockSpec((b_tile, m), lambda b, v: (b, 0))]
        operands = [logp]
        if has_scales:
            in_specs.append(pl.BlockSpec((b_tile, 1), lambda b, v: (b, 0)))
            operands.append(pad_axis(scales.astype(jnp.float32)[:, None],
                                     0, b_tile))
        if H is not None:
            in_specs.append(pl.BlockSpec((v_tile, k), lambda b, v: (v, 0)))
            operands.append(H)
        vals, ids = pl.pallas_call(
            functools.partial(_kernel, **kwargs),
            grid=grid,
            in_specs=in_specs,
            out_specs=[
                pl.BlockSpec((b_tile, topk), lambda b, v: (b, 0)),
                pl.BlockSpec((b_tile, topk), lambda b, v: (b, 0)),
            ],
            out_shape=out_shape,
            scratch_shapes=scratch_shapes,
            interpret=interpret,
        )(*operands)
        return vals[:B], ids[:B]

    occ, pin = block_occupancy(active, b_tile)
    nv_last = grid[1] - 1
    in_specs = [
        # inactive block: revisit the pinned logp block and the H tile
        # left resident by the previous sweep (nv_last) — a revisited
        # block index issues no copy in the Pallas pipeline.  Leading
        # dead blocks (pin points FORWARD to the first active block)
        # instead prefetch tile 0, the tile that first live sweep starts
        # with, so they too fetch nothing the live sweeps would not
        # fetch anyway.
        pl.BlockSpec((b_tile, m), lambda b, v, occ, pin: (pin[b], 0)),
    ]
    operands = [logp]
    if has_scales:
        in_specs.append(pl.BlockSpec((b_tile, 1),
                                     lambda b, v, occ, pin: (pin[b], 0)))
        operands.append(pad_axis(scales.astype(jnp.float32)[:, None],
                                 0, b_tile))
    if H is not None:
        in_specs.append(pl.BlockSpec(
            (v_tile, k),
            lambda b, v, occ, pin:
            (jnp.where(occ[b] > 0, v,
                       jnp.where(pin[b] > b, 0, nv_last)),
             0)))
        operands.append(H)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((b_tile, topk), lambda b, v, occ, pin: (b, 0)),
            pl.BlockSpec((b_tile, topk), lambda b, v, occ, pin: (b, 0)),
        ],
        scratch_shapes=scratch_shapes,
    )
    vals, ids = pl.pallas_call(
        functools.partial(_kernel_skip, **kwargs),
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=interpret,
    )(occ, pin, logp, *operands[1:])
    return vals[:B], ids[:B]
