"""Pallas TPU kernel: fused Bloom vocabulary recovery + streaming top-k
(the serving hot path — paper Fig. 3 right, DESIGN.md §4/§5).

The unfused serving decode writes the full (B, d) recovered-score matrix to
HBM and reads it back for jax.lax.top_k — 2 * B * d * 4 bytes that dominate
decode cost at LLM vocab scale (qwen3-4b: d = 151 936).  This kernel never
materializes the score matrix: it streams (v_tile, k) hash-matrix tiles
through the grid, recovers each (Bt, Vt) score tile in VMEM from the
resident (Bt, m) log-prob row, and folds it into a running per-batch top-k
held in VMEM scratch.  HBM traffic drops to

    B*m*4 (logp) + d*k*4 (H) + B*topk*8 (out)        [>= 3.8x fewer bytes
                                                      than decode-then-topk
                                                      at qwen3-4b shapes]

  grid = (nB, nV)          — vocab axis innermost
  logp — block (Bt, m)  at (b, 0)   (VMEM-resident across the vocab sweep)
  H    — block (Vt, k)  at (v, 0)
  outs — values (Bt, topk) f32 and ids (Bt, topk) i32 at (b, 0), written
         once at the last vocab step
  scratch — running (Bt, topk) best values/ids, reset at v == 0

The merge concatenates the running best with the fresh score tile and takes
``jax.lax.top_k`` over topk + Vt lanes; each vocab id enters the stream
exactly once, so no dedup pass is needed.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import pad_axis, resolve_interpret


def _kernel(logp_ref, h_ref, vals_ref, ids_ref, best_v, best_i, *,
            topk, v_tile, d):
    iv = pl.program_id(1)

    logp = logp_ref[...].astype(jnp.float32)        # (Bt, m)
    h = h_ref[...]                                  # (Vt, k)
    k = h.shape[1]
    scores = jnp.take(logp, h[:, 0], axis=1)        # (Bt, Vt)
    for j in range(1, k):
        scores = scores + jnp.take(logp, h[:, j], axis=1)

    b_tile = scores.shape[0]
    gid = jax.lax.broadcasted_iota(jnp.int32, (b_tile, v_tile), 1) \
        + iv * v_tile
    scores = jnp.where(gid < d, scores, -jnp.inf)   # mask vocab padding

    # Seed the running best from the first tile (requires topk <= v_tile)
    # rather than -inf/-1 sentinels: with fully -inf rows (masked vocabs)
    # a sentinel would win the top_k tie-break and leak id -1.  Seeding
    # also reproduces jax.lax.top_k's lowest-index tie ordering exactly —
    # best entries (earlier vocab ids) sit first in the concat, and
    # -inf-masked pad ids can never displace them.
    @pl.when(iv == 0)
    def _():
        top_v, sel = jax.lax.top_k(scores, topk)
        best_v[...] = top_v
        best_i[...] = jnp.take_along_axis(gid, sel, axis=-1)

    @pl.when(iv > 0)
    def _():
        cat_v = jnp.concatenate([best_v[...], scores], axis=-1)
        cat_i = jnp.concatenate([best_i[...], gid], axis=-1)
        top_v, sel = jax.lax.top_k(cat_v, topk)
        best_v[...] = top_v
        best_i[...] = jnp.take_along_axis(cat_i, sel, axis=-1)

    @pl.when(iv == pl.num_programs(1) - 1)
    def _():
        vals_ref[...] = best_v[...]
        ids_ref[...] = best_i[...]


@functools.partial(jax.jit,
                   static_argnames=("topk", "b_tile", "v_tile", "interpret"))
def bloom_decode_topk_pallas(logp: jnp.ndarray, H: jnp.ndarray, topk: int,
                             b_tile: int = 8, v_tile: int = 2048,
                             interpret: bool | None = None):
    """logp (B, m) float; H (d, k) int32 -> (values, ids), each (B, topk).

    values[b] are the topk largest Eq. 3 scores over the original vocab,
    descending; ids[b] the corresponding item/token ids.  The (B, d) score
    matrix is never written to HBM.
    """
    interpret = resolve_interpret(interpret)
    B, m = logp.shape
    d, k = H.shape
    if not (0 < topk <= d):
        raise ValueError(f"need 0 < topk <= d, got topk={topk} d={d}")
    b_tile = min(b_tile, B)
    v_tile = max(min(v_tile, d), topk)   # first tile seeds the running best
    logp = pad_axis(logp, 0, b_tile)
    H = pad_axis(H, 0, v_tile)                 # padded ids masked via d
    Bp, dp = logp.shape[0], H.shape[0]
    grid = (Bp // b_tile, dp // v_tile)

    vals, ids = pl.pallas_call(
        functools.partial(_kernel, topk=topk, v_tile=v_tile, d=d),
        grid=grid,
        in_specs=[
            pl.BlockSpec((b_tile, m), lambda b, v: (b, 0)),
            pl.BlockSpec((v_tile, k), lambda b, v: (v, 0)),
        ],
        out_specs=[
            pl.BlockSpec((b_tile, topk), lambda b, v: (b, 0)),
            pl.BlockSpec((b_tile, topk), lambda b, v: (b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bp, topk), jnp.float32),
            jax.ShapeDtypeStruct((Bp, topk), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((b_tile, topk), jnp.float32),
            pltpu.VMEM((b_tile, topk), jnp.int32),
        ],
        interpret=interpret,
    )(logp, H)
    return vals[:B], ids[:B]
