"""Jitted public wrappers around the Pallas kernels.

These adapt model-layer shapes ((B, S, ...) activations, BloomSpec hash
generation) to the flat kernel interfaces.  The kernels auto-select
interpret mode off-TPU (kernels.common.resolve_interpret), so the same
call sites run everywhere; all of them are differentiable via the
custom-VJP backward kernels in their defining modules.

Vocab-sized hash matrices come from ``core.bloom.cached_hash_matrix`` — one
(d, k) device array per BloomSpec, shared across decode calls so the
serving loop never rehashes the vocabulary per step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import quant
from repro.core.bloom import (BloomSpec, cached_decode_bins,
                              cached_hash_matrix, cached_quantized_table)
from repro.kernels.common import BWD_M_TILE
from repro.kernels.bloom_csr import CSR_E_TILE
from repro.kernels.bloom_embed import (bloom_embed_fwd_quantized,
                                       bloom_embed_pallas)
from repro.kernels.bloom_decode import bloom_decode_pallas
from repro.kernels.bloom_decode_topk import bloom_decode_topk_pallas
from repro.kernels.bloom_ce import bloom_ce_pallas


def bloom_embed(table: jnp.ndarray, tokens: jnp.ndarray,
                spec: BloomSpec, bwd_impl: str = "csr",
                table_dtype: str | None = None,
                out_dtype=None) -> jnp.ndarray:
    """table (m, D); tokens (B, S) -> (B, S, D).

    ``bwd_impl`` selects the scatter-add backward under jax.grad: "csr"
    (CSR-binned, reads the cotangent ~k times total) or "dense" (m-tile
    sweep fallback) — threaded from ModelConfig.bwd_impl by models/io.py.

    ``table_dtype`` (DESIGN.md §13) sets the table's storage dtype on the
    HBM side of the kernel's row DMAs, threaded from
    ModelConfig.table_dtype.  Traced tables (training/serving steps)
    quantize in-graph — the straight-through path, so jax.grad flows f32
    into the master table; a CONCRETE table (eager eval sweeps, benches)
    is quantized once through core.bloom.cached_quantized_table and the
    forward-only kernel entry runs on the cached arrays.
    """
    B, S = tokens.shape
    idx = spec.indices_for(tokens.reshape(-1))        # (T, k)
    td = quant.resolve_table_dtype(table_dtype)
    if td is not None and not isinstance(table, jax.core.Tracer):
        qtable, scales = cached_quantized_table(spec, table, td)
        out = bloom_embed_fwd_quantized(
            qtable, scales, idx,
            out_dtype=out_dtype if out_dtype is not None else jnp.float32)
    else:
        out = bloom_embed_pallas(table, idx, bwd_impl=bwd_impl,
                                 table_dtype=td, out_dtype=out_dtype)
    return out.reshape(B, S, -1)


def bloom_ce(logits: jnp.ndarray, labels: jnp.ndarray,
             spec: BloomSpec) -> jnp.ndarray:
    """logits (..., m); labels (...,) -> per-position loss (...,)."""
    shape = labels.shape
    z = logits.reshape(-1, logits.shape[-1])
    h = spec.indices_for(jnp.maximum(labels.reshape(-1), 0))
    loss = bloom_ce_pallas(z, h)
    return loss.reshape(shape)


@functools.lru_cache(maxsize=8)
def _decode_bins_thunk(spec: BloomSpec, m_tile: int, e_tile: int):
    """One stable (hashable, identity-cached) zero-arg thunk per
    (spec, tiling): bloom_decode_pallas takes it as a STATIC arg and the
    csr backward calls it at trace time — so the binning sort runs only
    if the decode is actually differentiated, and a stable thunk object
    never forces a retrace."""
    return functools.partial(cached_decode_bins, spec, m_tile, e_tile)


def bloom_decode(logp: jnp.ndarray, spec: BloomSpec,
                 hash_matrix: jnp.ndarray | None = None,
                 bwd_impl: str = "csr",
                 table_dtype: str | None = None) -> jnp.ndarray:
    """logp (..., m) -> Eq. 3 scores (..., d) over the original vocab.

    With bwd_impl="csr" and the spec-cached hash matrix, the per-spec CSR
    bins thunk (core.bloom.cached_decode_bins) rides into the custom VJP
    so the binned backward never re-sorts H — and forward-only callers
    never build the bins at all; a caller-supplied hash_matrix falls back
    to in-graph binning inside the backward.  ``table_dtype`` stores the
    resident logp block narrow (DESIGN.md §13; gradients straight-through).
    """
    lead = logp.shape[:-1]
    flat = logp.reshape(-1, logp.shape[-1])
    bins_fn = None
    if hash_matrix is None:
        H = cached_hash_matrix(spec)
        if bwd_impl == "csr":
            bins_fn = _decode_bins_thunk(spec, BWD_M_TILE, CSR_E_TILE)
    else:
        H = hash_matrix
    scores = bloom_decode_pallas(flat, H, bwd_impl=bwd_impl,
                                 bins_fn=bins_fn,
                                 table_dtype=quant.resolve_table_dtype(
                                     table_dtype))
    return scores.reshape(*lead, spec.d)


def bloom_decode_topk(logp: jnp.ndarray, spec: BloomSpec, topk: int,
                      hash_matrix: jnp.ndarray | None = None,
                      active: jnp.ndarray | None = None,
                      table_dtype: str | None = None):
    """logp (..., m) -> fused Eq. 3 + top-k: (values, ids), each (..., topk).

    Never materializes the (..., d) recovered-score matrix — the serving
    fast path (see kernels.bloom_decode_topk for the bytes model).
    ``active`` (...,) bool enables the row-skipping occupancy grid for
    slot pools at partial occupancy (skipped rows return (-inf, 0)).

    ``table_dtype`` (DESIGN.md §13) narrows the resident logp block AND —
    for on-the-fly non-identity specs with no caller H — drops the (d, k)
    hash stream entirely: the kernel re-derives the indices in-graph
    (hash_spec), bit-identical to the cached matrix.  The legacy
    table_dtype=None path is untouched, so existing bytes-model rows and
    serving schedules cannot drift.
    """
    lead = logp.shape[:-1]
    flat = logp.reshape(-1, logp.shape[-1])
    act = None if active is None else active.reshape(-1)
    td = quant.resolve_table_dtype(table_dtype)
    inkernel = (td is not None and hash_matrix is None and spec.on_the_fly
                and not (spec.m == spec.d and spec.k == 1))
    if inkernel:
        vals, ids = bloom_decode_topk_pallas(
            flat, None, topk, active=act, table_dtype=td,
            hash_spec=(spec.d, spec.k, spec.seed))
    else:
        H = (hash_matrix if hash_matrix is not None
             else cached_hash_matrix(spec))
        vals, ids = bloom_decode_topk_pallas(flat, H, topk, active=act,
                                             table_dtype=td)
    return vals.reshape(*lead, topk), ids.reshape(*lead, topk)
