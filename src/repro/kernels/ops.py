"""Jitted public wrappers around the Pallas kernels.

These adapt model-layer shapes ((B, S, ...) activations, BloomSpec hash
generation) to the flat kernel interfaces, and select interpret mode
automatically off-TPU so the same call sites run everywhere.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.bloom import BloomSpec
from repro.kernels.bloom_embed import bloom_embed_pallas
from repro.kernels.bloom_decode import bloom_decode_pallas
from repro.kernels.bloom_ce import bloom_ce_pallas


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def bloom_embed(table: jnp.ndarray, tokens: jnp.ndarray,
                spec: BloomSpec) -> jnp.ndarray:
    """table (m, D); tokens (B, S) -> (B, S, D)."""
    B, S = tokens.shape
    idx = spec.indices_for(tokens.reshape(-1))        # (T, k)
    out = bloom_embed_pallas(table, idx, interpret=_interpret())
    return out.reshape(B, S, -1)


def bloom_ce(logits: jnp.ndarray, labels: jnp.ndarray,
             spec: BloomSpec) -> jnp.ndarray:
    """logits (..., m); labels (...,) -> per-position loss (...,)."""
    shape = labels.shape
    z = logits.reshape(-1, logits.shape[-1])
    h = spec.indices_for(jnp.maximum(labels.reshape(-1), 0))
    loss = bloom_ce_pallas(z, h, interpret=_interpret())
    return loss.reshape(shape)


def bloom_decode(logp: jnp.ndarray, spec: BloomSpec,
                 hash_matrix: jnp.ndarray | None = None) -> jnp.ndarray:
    """logp (..., m) -> Eq. 3 scores (..., d) over the original vocab."""
    lead = logp.shape[:-1]
    flat = logp.reshape(-1, logp.shape[-1])
    H = hash_matrix if hash_matrix is not None else \
        spec.indices_for(jnp.arange(spec.d))
    scores = bloom_decode_pallas(flat, H, interpret=_interpret())
    return scores.reshape(*lead, spec.d)
