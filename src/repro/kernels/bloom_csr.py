"""CSR-binned scatter-add backward for the Bloom kernels (DESIGN.md §4).

Both Bloom backwards are the same op: a k-way scatter-add of cotangent
rows into an (m, ·) gradient table,

    out[r, :] = sum_{entries e : val[e] == r} g[row[e], :].

The dense formulation (bloom_embed_bwd_pallas / bloom_decode_bwd_pallas)
makes that race-free by brute force: a grid over EVERY (m_tile, ·) block
with the entry axis innermost, re-reading the full cotangent once per
m-tile sweep — `nM` reads of `g` where the op needs ~k.  At production
shapes that is the one place the bytes-first rule is still violated
(qwen3-4b embed.bwd models 4.25x the single-pass floor, decode.bwd 53x).

This module restores the stream-once shape by *sorting instead of
sweeping*:

  1. ``bin_csr`` — a jitted binning pass.  The flat hash indices are
     argsorted by owning m-tile (stable, so same-tile entries keep token
     order) and laid out into fixed-size entry tiles of ``e_tile`` slots,
     each tile owned by exactly ONE m-tile (segments are padded up to the
     tile boundary; every m-tile owns >= 1 tile so every output block
     gets zero-initialized).  All shapes are static: with E entries and
     nM m-tiles the layout has ``NT = E // e_tile + nM`` tiles, the worst
     case of per-segment padding.  Per tile the pass emits the source-row
     list (``tok``), the in-tile m values (``val``, -1 pad), the owning
     m-block (``tile_mb``, ascending), a first-tile-of-block flag
     (``tile_first``) and the live-entry count (``tile_len``).

  2. ``csr_scatter_add_pallas`` — the binned backward kernel.  Grid
     ``(nD, NT)`` with entry tiles innermost; ``tok``/``tile_*`` ride in
     as scalar prefetch.  Each step DMAs EXACTLY the segment's live
     cotangent rows from HBM into VMEM scratch (mirroring the forward's
     row-DMA layout; pad slots are gated off with ``pl.when``), builds
     the (e_tile, m_tile) one-hot of the in-tile m values and accumulates
     ``w.T @ rows`` on the MXU into the output block selected by the
     *data-dependent* index map ``tile_mb[ie]``.  Because tiles arrive
     sorted, each (m_tile, d_tile) block is revisited only by one
     consecutive run of grid steps — race-free like the dense sweep, but
     `g` is read ~k times total (once per entry) instead of nM times, and
     an empty m-tile is one pad tile that fetches nothing (pinned
     resident like the decode-topk row-skipping grid) and writes zeros.

``modeled_embed_bwd_csr_bytes`` / ``modeled_decode_bwd_csr_bytes`` are the
single bytes-model source for the ``*.bwd.csr`` rows in
benchmarks/bench_kernels.py.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import (BWD_M_TILE, onehot_count, pad_axis,
                                  resolve_interpret)

# Default entry-tile size of the binned backward: one MXU-friendly
# contraction depth per grid step, and the unit segments are padded to.
CSR_E_TILE = 128


@functools.partial(jax.tree_util.register_dataclass,
                   data_fields=("tok", "val", "tile_mb", "tile_first",
                                "tile_len"),
                   meta_fields=("m", "m_tile"))
@dataclasses.dataclass(frozen=True)
class CSRBins:
    """Static-shaped CSR layout of one entry set, produced by bin_csr.

    NT = E // e_tile + nM tiles of e_tile slots (E = number of entries).
    ``m``/``m_tile`` ride along as STATIC pytree metadata (the clamped
    values the bins were built for), so the kernel entry can enforce the
    bins-match-tiling contract instead of trusting the caller.
    """

    tok: jnp.ndarray         # (NT*e_tile,) i32 source row per slot (pad 0;
    #                          pad DMAs are gated off via tile_len)
    val: jnp.ndarray         # (NT*e_tile, 1) i32 global m index, -1 pad
    tile_mb: jnp.ndarray     # (NT,) i32 owning m-block per tile, ascending
    tile_first: jnp.ndarray  # (NT,) i32 1 iff first tile of its m-block
    tile_len: jnp.ndarray    # (NT,) i32 live entries in tile, in [0, e_tile]
    m: int                   # output rows the bins cover
    m_tile: int              # CLAMPED m-tile the entries were binned by

    @property
    def e_tile(self) -> int:
        return self.tok.shape[0] // self.tile_mb.shape[0]

    @property
    def n_tiles(self) -> int:
        return self.tile_mb.shape[0]


def csr_tile_counts(m: int, n_entries: int, m_tile: int = BWD_M_TILE,
                    e_tile: int = CSR_E_TILE):
    """(nM, NT, e_tile) static tile geometry shared by bin_csr, the kernel
    entry point and the bytes models."""
    m_tile = min(m_tile, m)
    e_tile = min(e_tile, max(n_entries, 1))
    nM = -(-m // m_tile)
    NT = n_entries // e_tile + nM
    return nM, NT, e_tile


@functools.partial(jax.jit, static_argnames=("m", "m_tile", "e_tile"))
def bin_csr(idx: jnp.ndarray, m: int, m_tile: int = BWD_M_TILE,
            e_tile: int = CSR_E_TILE) -> CSRBins:
    """Bin flat hash indices into the per-m-tile segment layout.

    idx (T, k) int32 in [0, m) — rows are source rows of the cotangent
    (tokens for embed.bwd, vocab ids for decode.bwd on the transposed
    cotangent).  Fully jitted and static-shaped, so for embed it fuses
    into the training step (per-batch), and for decode it is computed
    once per BloomSpec and cached (core.bloom.cached_decode_bins).
    """
    T, k = idx.shape
    E = T * k
    nM, NT, e_tile = csr_tile_counts(m, E, m_tile, e_tile)
    m_tile = min(m_tile, m)

    flat = idx.reshape(-1).astype(jnp.int32)
    src_row = jnp.arange(E, dtype=jnp.int32) // k
    blk = flat // m_tile                                   # owning m-block
    order = jnp.argsort(blk, stable=True)
    sval, stok, sblk = flat[order], src_row[order], blk[order]

    counts = jnp.zeros((nM,), jnp.int32).at[blk].add(1)
    tiles_per = jnp.maximum(1, -(-counts // e_tile))       # >= 1 per block
    tile_off = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(tiles_per)[:-1]])
    seg_start = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(counts)[:-1]])

    # destination slot of sorted entry j: its block's first tile plus its
    # position within the segment
    pos = jnp.arange(E, dtype=jnp.int32) - seg_start[sblk]
    dst = tile_off[sblk] * e_tile + pos
    tok = jnp.zeros((NT * e_tile,), jnp.int32).at[dst].set(stok)
    val = jnp.full((NT * e_tile,), -1, jnp.int32).at[dst].set(sval)

    # per-tile metadata; tiles past the last used one degrade to no-op
    # revisits of the final block (tile_len 0, tile_first 0)
    tile_mb = jnp.cumsum(
        jnp.zeros((NT,), jnp.int32).at[tile_off[1:]].add(1))
    tile_first = jnp.zeros((NT,), jnp.int32).at[tile_off].set(1)
    local_tile = jnp.arange(NT, dtype=jnp.int32) - tile_off[tile_mb]
    tile_len = jnp.clip(counts[tile_mb] - local_tile * e_tile, 0, e_tile)
    return CSRBins(tok=tok, val=val.reshape(-1, 1),
                   tile_mb=tile_mb.astype(jnp.int32),
                   tile_first=tile_first, tile_len=tile_len,
                   m=m, m_tile=m_tile)


def _csr_kernel(tok_ref, tmb_ref, tfirst_ref, tlen_ref, val_ref, g_ref,
                out_ref, rows, sems, *, e_tile, d_tile, m_tile):
    ie = pl.program_id(1)
    d0 = pl.program_id(0) * d_tile
    e0 = ie * e_tile
    n = tlen_ref[ie]

    # zero the output block exactly once, at the head of its tile run
    @pl.when(tfirst_ref[ie] == 1)
    def _():
        out_ref[...] = jnp.zeros_like(out_ref)

    # DMA exactly the live cotangent rows of this segment tile (pad slots
    # are skipped — an empty tile touches no HBM at all)
    copies = []
    for s in range(e_tile):
        c = pltpu.make_async_copy(
            g_ref.at[pl.ds(tok_ref[e0 + s], 1), pl.ds(d0, d_tile)],
            rows.at[pl.ds(s, 1), :],
            sems.at[s],
        )
        copies.append(c)

        @pl.when(s < n)
        def _(c=c):
            c.start()
    for s, c in enumerate(copies):
        @pl.when(s < n)
        def _(c=c):
            c.wait()

    @pl.when(n > 0)
    def _():
        base = tmb_ref[ie] * m_tile
        valid = val_ref[...] >= 0                        # (e_tile, 1)
        w = onehot_count(val_ref[...], m_tile, base)     # (e_tile, m_tile)
        g_rows = rows[...].astype(jnp.float32)           # (e_tile, d_tile)
        # pad slots carry stale scratch; select them to 0 so the matmul
        # can never multiply garbage (0 * NaN would poison the block)
        g_rows = jnp.where(valid, g_rows, 0.0)
        out_ref[...] += jnp.dot(w.T, g_rows,
                                preferred_element_type=jnp.float32)


@functools.partial(jax.jit,
                   static_argnames=("m", "m_tile", "d_tile", "interpret"))
def csr_scatter_add_pallas(g: jnp.ndarray, bins: CSRBins, m: int,
                           m_tile: int = BWD_M_TILE, d_tile: int = 512,
                           interpret: bool | None = None) -> jnp.ndarray:
    """g (T, D) cotangent rows + bins over (T, k) indices -> (m, D) f32.

    out[r, :] = sum over binned entries with val == r of g[tok, :].
    `bins` must come from bin_csr with the same (m, m_tile) — enforced
    against the bins' static metadata; e_tile is recovered from the
    bins' static shapes.
    """
    interpret = resolve_interpret(interpret)
    T, D = g.shape
    m_tile = min(m_tile, m)
    d_tile = min(d_tile, D)
    e_tile = bins.e_tile
    if (bins.m, bins.m_tile) != (m, m_tile):
        raise ValueError(
            f"bins were built for (m={bins.m}, m_tile={bins.m_tile}) but "
            f"the kernel was called with (m={m}, m_tile={m_tile}) — "
            "mismatched bins would scatter into the wrong output blocks")
    g = pad_axis(g, 1, d_tile)
    mp = m + ((-m) % m_tile)
    Dp = g.shape[1]
    NT = bins.n_tiles
    grid = (Dp // d_tile, NT)                     # entry tiles innermost

    out = pl.pallas_call(
        functools.partial(_csr_kernel, e_tile=e_tile, d_tile=d_tile,
                          m_tile=m_tile),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=4,                # tok, tile_mb/first/len
            grid=grid,
            in_specs=[
                pl.BlockSpec((e_tile, 1),
                             lambda id_, ie, tok, tmb, tf, tl: (ie, 0)),
                pl.BlockSpec(memory_space=pltpu.ANY),   # g stays in HBM
            ],
            out_specs=pl.BlockSpec(
                (m_tile, d_tile),
                # data-dependent: the output block this tile's segment
                # owns; sorted tiles revisit it in one consecutive run
                lambda id_, ie, tok, tmb, tf, tl: (tmb[ie], id_)),
            scratch_shapes=[
                pltpu.VMEM((e_tile, d_tile), g.dtype),
                pltpu.SemaphoreType.DMA((e_tile,)),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((mp, Dp), jnp.float32),
        interpret=interpret,
    )(bins.tok, bins.tile_mb, bins.tile_first, bins.tile_len, bins.val, g)
    return out[:m, :D]


# --------------------------------------------------------------------------
# Backward entry points (the bwd_impl="csr" paths of the custom VJPs)
# --------------------------------------------------------------------------

@functools.partial(jax.jit,
                   static_argnames=("m", "m_tile", "e_tile", "d_tile",
                                    "interpret"))
def bloom_embed_bwd_csr_pallas(g: jnp.ndarray, idx: jnp.ndarray, m: int,
                               m_tile: int = BWD_M_TILE,
                               e_tile: int = CSR_E_TILE, d_tile: int = 512,
                               interpret: bool | None = None,
                               bins: CSRBins | None = None) -> jnp.ndarray:
    """g (T, D) cotangent; idx (T, k) -> dtable (m, D) f32 scatter-add.

    Drop-in for bloom_embed_bwd_pallas; the binning pass runs in-graph
    (per batch) unless precomputed `bins` are passed.
    """
    if bins is None:
        bins = bin_csr(idx, m, m_tile=m_tile, e_tile=e_tile)
    return csr_scatter_add_pallas(g, bins, m, m_tile=m_tile,
                                  d_tile=d_tile, interpret=interpret)


@functools.partial(jax.jit,
                   static_argnames=("m", "m_tile", "e_tile", "interpret"))
def bloom_decode_bwd_csr_pallas(g: jnp.ndarray, H: jnp.ndarray, m: int,
                                m_tile: int = BWD_M_TILE,
                                e_tile: int = CSR_E_TILE,
                                interpret: bool | None = None,
                                bins: CSRBins | None = None) -> jnp.ndarray:
    """g (B, d) cotangent; H (d, k) -> dlogp (B, m) f32 scatter-add.

    The decode backward IS the embed backward on the transposed
    cotangent: dlogp.T[c, b] = sum_{i,j : H[i,j] == c} g.T[i, b] — so it
    reuses csr_scatter_add_pallas on g.T with H's bins (fixed per
    BloomSpec, cached by core.bloom.cached_decode_bins) and transposes
    back.  The two (B·d + B·m)-sized XLA transposes are counted in the
    bytes model and are noise next to the nM-fold dense re-reads.
    """
    if bins is None:
        bins = bin_csr(H, m, m_tile=m_tile, e_tile=e_tile)
    B = g.shape[0]
    out = csr_scatter_add_pallas(g.T, bins, m, m_tile=m_tile,
                                 d_tile=min(512, B),
                                 interpret=interpret)          # (m, B)
    return out.T


# --------------------------------------------------------------------------
# Bytes models (single source for benchmarks/bench_kernels.py .csr rows)
# --------------------------------------------------------------------------

# Modeled HBM passes of the in-graph radix/merge sort in bin_csr: read +
# write of the key/payload streams over a small constant number of
# passes.  Deliberately generous — at E = T*k ~ 16k int32 entries the
# whole binning pass is < 1% of the row traffic it saves.
SORT_PASSES = 4


def _bin_bytes(E: int, nM: int, NT: int, e_tile: int) -> int:
    """Bytes of one bin_csr run: the sort over (E,) keys+payloads plus
    the scattered tile-layout writes and per-tile metadata."""
    sort = SORT_PASSES * 2 * E * 4
    layout = 2 * (NT * e_tile) * 4          # tok + val writes
    meta = 3 * NT * 4 + 3 * nM * 4          # tile_mb/first/len, counts etc.
    return sort + layout + meta


def modeled_embed_bwd_csr_bytes(T: int, k: int, D: int, m: int,
                                m_tile: int = BWD_M_TILE,
                                e_tile: int = CSR_E_TILE,
                                d_tile: int = 512,
                                include_binning: bool = True) -> int:
    """Analytic HBM bytes of the CSR embed backward at a production
    shape.  Per d-block sweep the kernel fetches exactly the E = T*k live
    cotangent rows (sum of tile_len; pad slots are DMA-gated), streams
    the (NT*e_tile, 1) val tiles, and writes each output block once; the
    per-batch binning pass is included by default."""
    E = T * k
    nM, NT, e_tile = csr_tile_counts(m, E, m_tile, e_tile)
    d_tile = min(d_tile, D)
    nD = -(-D // d_tile)
    rows = E * d_tile * 4 * nD              # ~= E * D * 4: g read ~k times
    vals = nD * NT * e_tile * 4             # val stream, re-read per sweep
    prefetch = (NT * e_tile + 3 * NT) * 4   # tok + tile metadata (SMEM)
    out = m * D * 4                         # dtable written exactly once
    total = rows + vals + prefetch + out
    if include_binning:
        total += _bin_bytes(E, nM, NT, e_tile)
    return int(total)


def modeled_decode_bwd_csr_bytes(B: int, d: int, k: int, m: int,
                                 m_tile: int = BWD_M_TILE,
                                 e_tile: int = CSR_E_TILE) -> int:
    """Analytic HBM bytes of the CSR decode backward.  The cotangent is
    transposed to (d, B) around the shared row-scatter kernel (read +
    write each way); bins over H are per-BloomSpec and cached, so the
    binning pass is NOT in the per-step model (cached_decode_bins)."""
    E = d * k
    nM, NT, e_tile = csr_tile_counts(m, E, m_tile, e_tile)
    d_tile = min(512, B)                    # as bloom_decode_bwd_csr_pallas
    nD = -(-B // d_tile)                    # 1 whenever B <= 512
    transpose_in = 2 * B * d * 4            # g -> gT
    rows = nD * E * d_tile * 4              # ~= E * B * 4: one row/entry
    vals = nD * NT * e_tile * 4             # val stream, re-read per sweep
    prefetch = (NT * e_tile + 3 * NT) * 4
    out = m * B * 4 + 2 * B * m * 4         # write + transpose back
    return int(transpose_in + rows + vals + prefetch + out)
