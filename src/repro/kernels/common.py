"""Shared plumbing for the Bloom Pallas kernel suite (DESIGN.md §4).

Every public ``*_pallas`` entry point takes ``interpret=None`` and resolves
it here: interpret mode off-TPU (CPU CI, tests, this box), compiled Mosaic
on TPU.  Passing an explicit bool still forces either mode — tests pin
``interpret=True`` so sweeps stay deterministic regardless of backend.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# Default m-tile of the blocked backward kernels (bloom_embed_bwd_pallas,
# bloom_decode_bwd_pallas).  benchmarks/bench_kernels.py imports this to
# keep the committed *.bwd bytes models in lock-step with the kernels.
BWD_M_TILE = 512


def resolve_interpret(interpret: bool | None) -> bool:
    """None -> auto (interpret everywhere except real TPU)."""
    if interpret is None:
        return jax.default_backend() != "tpu"
    return bool(interpret)


def resolve_bwd_impl(bwd_impl: str, e_tile: int | None) -> tuple[str, int]:
    """Validate a differentiable entry point's ``bwd_impl`` knob and
    resolve the csr entry-tile default — shared by bloom_embed_pallas
    and bloom_decode_pallas so the two public APIs cannot drift."""
    if bwd_impl not in ("dense", "csr"):
        raise ValueError(f"bwd_impl must be 'dense' or 'csr', "
                         f"got {bwd_impl!r}")
    if e_tile is None:
        from repro.kernels.bloom_csr import CSR_E_TILE
        e_tile = CSR_E_TILE
    return bwd_impl, e_tile


def pad_axis(x: jnp.ndarray, axis: int, multiple: int,
             value=0) -> jnp.ndarray:
    """Right-pad `axis` of x to a multiple of `multiple` with `value`."""
    pad = (-x.shape[axis]) % multiple
    if not pad:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def onehot_count(ids: jnp.ndarray, n: int, base=0) -> jnp.ndarray:
    """counts[r, c] = #{j : ids[r, j] == base + c} as float32.

    The shared building block of every backward kernel's scatter-add:
    built from k iota-compares over a (rows, n) tile in VMEM/registers —
    the dense one-hot never exists in HBM.  Out-of-range ids (e.g. the -1
    padding sentinel) simply never match.  `base` offsets the class axis
    for m-tiled grids.
    """
    rows, k = ids.shape
    iota = jax.lax.broadcasted_iota(jnp.int32, (rows, n), 1) + base
    w = (iota == ids[:, 0][:, None]).astype(jnp.float32)
    for j in range(1, k):
        w = w + (iota == ids[:, j][:, None]).astype(jnp.float32)
    return w
