"""Pallas TPU kernels: Bloom k-way gather-sum embedding lookup, forward and
backward (differentiable via jax.custom_vjp).

Forward:   out[t, :] = sum_{j<k} table[idx[t, j], :]
Backward:  dtable[r, :] = sum_{t, j : idx[t, j] == r} g[t, :]   (scatter-add)

TPU mapping (DESIGN.md §4):

* Forward — token-blocked grid ``(nT, nD)``.  The table is passed ONCE in
  ``pltpu.ANY`` (it stays in HBM); the kernel issues ``t_tile * k`` async row
  DMAs per step into a VMEM scratch and reduces over k in-register.  This
  replaces the seed kernel's one-token-per-grid-step layout with
  ``[table] * k`` duplicated operands: operand count drops k+1 -> 2 and grid
  steps drop ``t_tile``x, while the scalar-prefetched index array still lets
  the DMA engine run ahead of compute (the TPU analogue of the paper's
  'pre-computed hash matrix in RAM' fast path).

* Backward — the k-way scatter-add.  A data-dependent-output scatter races
  under the Pallas output pipeline (and interpret mode's block write-back),
  so this module's DENSE backward is formulated race-free as a blocked
  one-hot contraction: grid ``(nM, nD, nT)`` with tokens innermost; each
  step builds the ``(t_tile, m_tile)`` one-hot count matrix
  w[t, i] = #{j : idx[t, j] == i} (kernels.common.onehot_count) IN VMEM
  ONLY and accumulates ``w.T @ g`` into the revisited ``(m_tile, d_tile)``
  output block on the MXU.  The dense ``(T, m)`` one-hot gradient of the
  XLA fallback never exists in HBM — but the m-tile sweep re-reads ``g``
  nM times.  ``bwd_impl="csr"`` (the training default) instead routes the
  VJP through the CSR-binned backward of kernels/bloom_csr.py, which
  sorts entries by m-tile and reads ``g`` ~k times total; the dense
  kernel remains the oracle-adjacent fallback.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import quant
from repro.kernels.common import (BWD_M_TILE, onehot_count, pad_axis,
                                  resolve_bwd_impl, resolve_interpret)


# --------------------------------------------------------------------------
# Forward
# --------------------------------------------------------------------------

def _fwd_kernel(idx_ref, table_ref, out_ref, rows, sems, *, t_tile, k,
                d_tile):
    t0 = pl.program_id(0) * t_tile
    d0 = pl.program_id(1) * d_tile
    copies = []
    for tt in range(t_tile):
        for j in range(k):
            row = idx_ref[t0 + tt, j]
            c = pltpu.make_async_copy(
                table_ref.at[pl.ds(row, 1), pl.ds(d0, d_tile)],
                rows.at[pl.ds(tt * k + j, 1), :],
                sems.at[tt * k + j],
            )
            c.start()
            copies.append(c)
    for c in copies:
        c.wait()
    r = rows[...].astype(jnp.float32).reshape(t_tile, k, d_tile)
    out_ref[...] = r.sum(axis=1).astype(out_ref.dtype)


def _fwd_kernel_scaled(idx_ref, s_ref, table_ref, out_ref, rows, sems, *,
                       t_tile, k, d_tile):
    """int8-table variant: same row DMAs, plus an in-VMEM dequant.

    The fetched rows stay in their 1-byte storage dtype through the DMA;
    dequantization is one multiply by the per-row scale on the VMEM tile
    (DESIGN.md §13).  Scales ride the scalar-prefetch path next to the
    indices — (T, k) float32 pre-gathered per fetched row, so the kernel
    reads t_tile*k SMEM scalars, never the (m,) scale vector.
    """
    t0 = pl.program_id(0) * t_tile
    d0 = pl.program_id(1) * d_tile
    copies = []
    for tt in range(t_tile):
        for j in range(k):
            row = idx_ref[t0 + tt, j]
            c = pltpu.make_async_copy(
                table_ref.at[pl.ds(row, 1), pl.ds(d0, d_tile)],
                rows.at[pl.ds(tt * k + j, 1), :],
                sems.at[tt * k + j],
            )
            c.start()
            copies.append(c)
    for c in copies:
        c.wait()
    s = jnp.stack([jnp.stack([s_ref[t0 + tt, j] for j in range(k)])
                   for tt in range(t_tile)])             # (t_tile, k) f32
    r = rows[...].astype(jnp.float32).reshape(t_tile, k, d_tile)
    out_ref[...] = (r * s[:, :, None]).sum(axis=1).astype(out_ref.dtype)


def _embed_fwd(table, idx, t_tile, d_tile, interpret, scales=None,
               out_dtype=None):
    m, D = table.shape
    T, k = idx.shape
    t_tile = min(t_tile, T)
    d_tile = min(d_tile, D)
    out_dtype = table.dtype if out_dtype is None else jnp.dtype(out_dtype)
    table = pad_axis(table, 1, d_tile)
    idx = pad_axis(idx, 0, t_tile)             # pad rows gather row 0: sliced
    Tp, Dp = idx.shape[0], table.shape[1]
    grid = (Tp // t_tile, Dp // d_tile)

    if scales is None:
        kernel = functools.partial(_fwd_kernel, t_tile=t_tile, k=k,
                                   d_tile=d_tile)
        n_prefetch, operands = 1, (idx, table)
        out_index = lambda t, d, idx_ref: (t, d)
    else:
        # Per-fetched-row scales, gathered OUTSIDE the kernel (a (T, k)
        # float32 gather of the (m,) vector — tiny next to the row DMAs)
        # so they prefetch alongside the indices.
        sg = jnp.take(scales.astype(jnp.float32), idx, axis=0)   # (Tp, k)
        kernel = functools.partial(_fwd_kernel_scaled, t_tile=t_tile, k=k,
                                   d_tile=d_tile)
        n_prefetch, operands = 2, (idx, sg, table)
        out_index = lambda t, d, idx_ref, s_ref: (t, d)

    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=n_prefetch,
            grid=grid,
            in_specs=[pl.BlockSpec(memory_space=pltpu.ANY)],
            out_specs=pl.BlockSpec((t_tile, d_tile), out_index),
            scratch_shapes=[
                pltpu.VMEM((t_tile * k, d_tile), table.dtype),
                pltpu.SemaphoreType.DMA((t_tile * k,)),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((Tp, Dp), out_dtype),
        interpret=interpret,
    )(*operands)
    return out[:T, :D]


def _default_out_dtype(table_dtype, table):
    """out dtype when the caller leaves it implicit: float storage keeps
    its own dtype (legacy behavior); sub-byte storage widens to f32."""
    if table_dtype is None:
        return table.dtype
    st = quant.storage_dtype(table_dtype)
    return st if st in (jnp.dtype(jnp.float32), jnp.dtype(jnp.bfloat16)) \
        else jnp.dtype(jnp.float32)


def _embed_fwd_quant(table, idx, t_tile, d_tile, interpret, table_dtype,
                     out_dtype):
    if table_dtype is None:
        return _embed_fwd(table, idx, t_tile, d_tile, interpret,
                          out_dtype=out_dtype)
    if out_dtype is None:
        out_dtype = _default_out_dtype(table_dtype, table)
    qtable, scales = quant.quantize_table(table, table_dtype)
    return _embed_fwd(qtable, idx, t_tile, d_tile, interpret, scales=scales,
                      out_dtype=out_dtype)


# --------------------------------------------------------------------------
# Backward (dtable)
# --------------------------------------------------------------------------

def _bwd_kernel(idx_ref, g_ref, out_ref, *, m_tile):
    it = pl.program_id(2)

    @pl.when(it == 0)
    def _():
        out_ref[...] = jnp.zeros_like(out_ref)

    base = pl.program_id(0) * m_tile
    w = onehot_count(idx_ref[...], m_tile, base)         # (t_tile, m_tile)
    g = g_ref[...].astype(jnp.float32)                   # (t_tile, d_tile)
    out_ref[...] += jnp.dot(w.T, g, preferred_element_type=jnp.float32)


@functools.partial(jax.jit,
                   static_argnames=("m", "m_tile", "d_tile", "t_tile",
                                    "interpret"))
def bloom_embed_bwd_pallas(g: jnp.ndarray, idx: jnp.ndarray, m: int,
                           m_tile: int = BWD_M_TILE, d_tile: int = 512,
                           t_tile: int = 128,
                           interpret: bool | None = None) -> jnp.ndarray:
    """g (T, D) cotangent; idx (T, k) -> dtable (m, D) float32 scatter-add."""
    interpret = resolve_interpret(interpret)
    T, D = g.shape
    k = idx.shape[1]
    m_tile = min(m_tile, m)
    d_tile = min(d_tile, D)
    t_tile = min(t_tile, T)
    g = pad_axis(pad_axis(g, 0, t_tile), 1, d_tile)
    idx = pad_axis(idx, 0, t_tile, value=-1)   # -1 never matches the iota
    mp = m + ((-m) % m_tile)
    Tp, Dp = g.shape
    grid = (mp // m_tile, Dp // d_tile, Tp // t_tile)

    out = pl.pallas_call(
        functools.partial(_bwd_kernel, m_tile=m_tile),
        grid=grid,
        in_specs=[
            pl.BlockSpec((t_tile, k), lambda im, id_, it: (it, 0)),
            pl.BlockSpec((t_tile, d_tile), lambda im, id_, it: (it, id_)),
        ],
        out_specs=pl.BlockSpec((m_tile, d_tile),
                               lambda im, id_, it: (im, id_)),
        out_shape=jax.ShapeDtypeStruct((mp, Dp), jnp.float32),
        interpret=interpret,
    )(idx, g)
    return out[:m, :D]


# --------------------------------------------------------------------------
# custom_vjp glue + public entry point
# --------------------------------------------------------------------------

@functools.partial(jax.custom_vjp,
                   nondiff_argnums=(2, 3, 4, 5, 6, 7, 8, 9, 10))
def _bloom_embed(table, idx, t_tile, d_tile, interpret, bwd_impl,
                 m_tile, bwd_t_tile, e_tile, table_dtype, out_dtype):
    return _embed_fwd_quant(table, idx, t_tile, d_tile, interpret,
                            table_dtype, out_dtype)


def _bloom_embed_vjp_fwd(table, idx, t_tile, d_tile, interpret, bwd_impl,
                         m_tile, bwd_t_tile, e_tile, table_dtype, out_dtype):
    out = _embed_fwd_quant(table, idx, t_tile, d_tile, interpret,
                           table_dtype, out_dtype)
    # `table` rides along for shape/dtype only — it is a live param anyway.
    return out, (idx, table)


def _bloom_embed_vjp_bwd(t_tile, d_tile, interpret, bwd_impl, m_tile,
                         bwd_t_tile, e_tile, table_dtype, out_dtype, res, g):
    idx, table = res
    if bwd_impl == "csr":
        from repro.kernels.bloom_csr import bloom_embed_bwd_csr_pallas
        dtable = bloom_embed_bwd_csr_pallas(
            g, idx, table.shape[0], m_tile=m_tile, e_tile=e_tile,
            d_tile=d_tile, interpret=interpret)
    else:
        # every caller tiling knob is forwarded (bwd_t_tile defaults to
        # the dense backward's own token tile, NOT the forward t_tile:
        # the fwd default of 8 would shrink the bwd grid 16x)
        dtable = bloom_embed_bwd_pallas(
            g, idx, table.shape[0], m_tile=m_tile, d_tile=d_tile,
            t_tile=bwd_t_tile, interpret=interpret)
    # Quantized tables (table_dtype != None) train straight-through: the
    # forward ran on quantize(table) but the scatter-add above is the
    # exact gradient of the UNquantized linear map, accumulated in f32
    # against the master table — round() has zero gradient, so STE is the
    # standard estimator (DESIGN.md §13).  The CSR/dense kernels are
    # unchanged in math; only the forward's fetched-row dtype differs.
    return dtable.astype(table.dtype), None


_bloom_embed.defvjp(_bloom_embed_vjp_fwd, _bloom_embed_vjp_bwd)


@functools.partial(jax.jit,
                   static_argnames=("t_tile", "d_tile", "interpret",
                                    "out_dtype"))
def bloom_embed_fwd_quantized(qtable: jnp.ndarray,
                              scales: jnp.ndarray | None,
                              idx: jnp.ndarray,
                              t_tile: int = 8, d_tile: int = 512,
                              interpret: bool | None = None,
                              out_dtype=jnp.float32) -> jnp.ndarray:
    """Forward-only gather-sum on a PRE-quantized table.

    The serve-path sibling of bloom_embed_pallas: callers with frozen
    params pay the quantize cost once (core.bloom.cached_quantized_table)
    and pass ``(qtable, scales)`` straight to the kernel — no per-call
    quantize in the graph, no VJP.  ``scales=None`` for the scale-free
    dtypes (f32/bf16/fp8); (m,) float32 per-row scales for int8.
    """
    return _embed_fwd(qtable, idx, t_tile, d_tile,
                      resolve_interpret(interpret), scales=scales,
                      out_dtype=out_dtype)


@functools.partial(jax.jit,
                   static_argnames=("t_tile", "d_tile", "interpret",
                                    "bwd_impl", "m_tile", "bwd_t_tile",
                                    "e_tile", "table_dtype", "out_dtype"))
def bloom_embed_pallas(table: jnp.ndarray, idx: jnp.ndarray,
                       t_tile: int = 8, d_tile: int = 512,
                       interpret: bool | None = None,
                       bwd_impl: str = "dense",
                       m_tile: int = BWD_M_TILE,
                       bwd_t_tile: int = 128,
                       e_tile: int | None = None,
                       table_dtype: str | None = None,
                       out_dtype=None) -> jnp.ndarray:
    """table (m, D), idx (T, k) int32 -> (T, D) = k-way gather-sum.

    Differentiable: jax.grad w.r.t. `table` runs the scatter-add backward
    selected by ``bwd_impl`` (validated vs the XLA oracle in
    tests/test_kernels.py):

      "dense" — the blocked one-hot-contraction sweep over every m-tile
                (oracle-adjacent fallback; re-reads g once per m-tile);
      "csr"   — the CSR-binned backward (kernels.bloom_csr): a jitted
                per-batch binning pass + segment row-DMA kernel that
                reads g ~k times total.

    All backward tiling knobs are threaded through the custom VJP:
    ``m_tile`` (both impls), ``bwd_t_tile`` (dense token tile) and
    ``e_tile`` (csr entry tile; None = kernels.bloom_csr.CSR_E_TILE).

    ``table_dtype`` (DESIGN.md §13) selects the table's storage dtype on
    the HBM side of the row DMAs: None leaves the table untouched (legacy
    path, bit-identical to before the knob existed); "float32"/"bfloat16"
    cast; "int8" quantizes per-row symmetric in-graph and dequantizes on
    the VMEM tile; "fp8_e4m3" casts scale-free.  Gradients are
    straight-through against the master table.  ``out_dtype`` overrides
    the output dtype (default: the float storage dtype, or float32 for
    the sub-byte dtypes).
    """
    bwd_impl, e_tile = resolve_bwd_impl(bwd_impl, e_tile)
    table_dtype = quant.resolve_table_dtype(table_dtype)
    return _bloom_embed(table, idx, t_tile, d_tile,
                        resolve_interpret(interpret), bwd_impl, m_tile,
                        bwd_t_tile, e_tile, table_dtype, out_dtype)
