"""Pallas TPU kernel: Bloom k-way gather-sum embedding lookup.

out[t, :] = sum_{j<k} table[idx[t, j], :]

TPU mapping (DESIGN.md §4): the op is HBM-bandwidth-bound (k rows of D
floats per token, no MXU work), so the kernel streams one token's k rows
per grid step through VMEM, tiled over d_model lanes:

  grid  = (T, nD)            — token-major so each row tile is copied
                               HBM->VMEM exactly once per (token, j)
  table — k BlockSpecs (one per hash projection, k is small and static),
          each selecting row idx[t, j] via the scalar-prefetched index
          array: block (1, Dt) at (idx_ref[t, j], dt).
  out   — block (1, Dt) at (t, dt); the k VMEM blocks are summed in-register.

The scalar prefetch (PrefetchScalarGridSpec) lets the DMA engine issue the
k row fetches ahead of the compute step — this is the TPU analogue of the
paper's 'pre-computed hash matrix in RAM' fast path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(idx_ref, *refs):
    table_blks, out_ref = refs[:-1], refs[-1]
    acc = table_blks[0][...].astype(jnp.float32)
    for blk in table_blks[1:]:
        acc = acc + blk[...].astype(jnp.float32)
    out_ref[...] = acc.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("d_tile", "interpret"))
def bloom_embed_pallas(table: jnp.ndarray, idx: jnp.ndarray,
                       d_tile: int = 512, interpret: bool = True
                       ) -> jnp.ndarray:
    """table (m, D), idx (T, k) int32 -> (T, D) = k-way gather-sum."""
    m, D = table.shape
    T, k = idx.shape
    d_tile = min(d_tile, D)
    pad_d = (-D) % d_tile
    if pad_d:
        table = jnp.pad(table, ((0, 0), (0, pad_d)))
    Dp = D + pad_d
    grid = (T, Dp // d_tile)

    in_specs = [
        pl.BlockSpec((1, d_tile),
                     functools.partial(
                         lambda t, dt, idx_ref, j: (idx_ref[t, j], dt), j=j))
        for j in range(k)
    ]
    out_spec = pl.BlockSpec((1, d_tile), lambda t, dt, idx_ref: (t, dt))

    out = pl.pallas_call(
        _kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=in_specs,
            out_specs=out_spec,
        ),
        out_shape=jax.ShapeDtypeStruct((T, Dp), table.dtype),
        interpret=interpret,
    )(idx, *([table] * k))
    return out[:, :D]
