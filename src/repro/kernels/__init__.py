"""Pallas TPU kernels for the paper's IO hot spots (+ ops/ref).

  bloom_embed       — k-way gather-sum embedding lookup (HBM-bandwidth
                      bound); custom-VJP scatter-add backward
  bloom_ce          — fused m-softmax CE against the k-hot Bloom target;
                      lse-residual backward (one read of the logits row)
  bloom_decode      — Eq. 3 vocabulary recovery gather-reduce; blocked
                      scatter-add backward
  bloom_decode_topk — fused Eq. 3 + streaming top-k (serving path; the
                      (B, d) score matrix never reaches HBM)
  bloom_csr         — CSR-binned scatter-add backward shared by
                      bloom_embed/bloom_decode (bwd_impl="csr": sort by
                      m-tile, DMA exactly the live cotangent rows — the
                      stream-once training backward; DESIGN.md §4)

All four are differentiable where it makes sense (jax.custom_vjp with
dedicated backward Pallas kernels) and validated in interpret mode against
ref.py / core oracles (tests/test_kernels.py).
"""
from repro.kernels import ops, ref  # noqa: F401
