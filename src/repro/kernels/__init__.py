"""Pallas TPU kernels for the paper's IO hot spots (+ ops/ref).

  bloom_embed  — k-way gather-sum embedding lookup (HBM-bandwidth bound)
  bloom_ce     — fused m-softmax CE against the k-hot Bloom target
  bloom_decode — Eq. 3 vocabulary recovery gather-reduce

Validated in interpret mode against ref.py oracles (tests/test_kernels*).
"""
from repro.kernels import ops, ref  # noqa: F401
