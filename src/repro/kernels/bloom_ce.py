"""Pallas TPU kernels: fused Bloom softmax cross-entropy (paper's training
loss in the compressed m-space), forward and backward.

Forward:   loss[t] = logsumexp(z[t, :]) - (1/k) * sum_{j<k} z[t, h[t, j]]
Backward:  dz[t, :] = g[t] * (softmax(z[t, :]) - onehot_count(h[t, :]) / k)

Fusing the logsumexp with the k-gather means the m-dim logits row is read
from HBM exactly once (the unfused path reads it three times: max, exp-sum,
gather).  The forward additionally emits the per-token ``lse`` as a VJP
residual, so the backward rebuilds softmax(z) = exp(z - lse) from ONE read
of the logits row instead of re-running the max/exp-sum reduction — the
(T, m) row is touched once in each direction (DESIGN.md §4).

  grid = (nT,)
  z    — block (Tt, m) at (t, 0)
  h    — block (Tt, k) at (t, 0)
  loss/lse — blocks (Tt,) at (t,);  bwd adds g (Tt,) in, dz (Tt, m) out.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import onehot_count, pad_axis, resolve_interpret


# --------------------------------------------------------------------------
# Forward (loss + lse residual)
# --------------------------------------------------------------------------

def _fwd_kernel(z_ref, h_ref, loss_ref, lse_ref):
    z = z_ref[...].astype(jnp.float32)             # (Tt, m)
    h = h_ref[...]                                 # (Tt, k)
    zmax = z.max(axis=-1, keepdims=True)
    lse = jnp.log(jnp.sum(jnp.exp(z - zmax), axis=-1)) + zmax[:, 0]
    picked = jnp.take_along_axis(z, h, axis=-1)    # (Tt, k)
    loss_ref[...] = lse - picked.mean(-1)
    lse_ref[...] = lse


def _ce_fwd(logits, h_idx, t_tile, interpret):
    T, m = logits.shape
    k = h_idx.shape[1]
    t_tile = min(t_tile, T)
    logits = pad_axis(logits, 0, t_tile)
    h_idx = pad_axis(h_idx, 0, t_tile)
    Tp = logits.shape[0]

    loss, lse = pl.pallas_call(
        _fwd_kernel,
        grid=(Tp // t_tile,),
        in_specs=[
            pl.BlockSpec((t_tile, m), lambda t: (t, 0)),
            pl.BlockSpec((t_tile, k), lambda t: (t, 0)),
        ],
        out_specs=[
            pl.BlockSpec((t_tile,), lambda t: (t,)),
            pl.BlockSpec((t_tile,), lambda t: (t,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Tp,), jnp.float32),
            jax.ShapeDtypeStruct((Tp,), jnp.float32),
        ],
        interpret=interpret,
    )(logits, h_idx)
    return loss[:T], lse[:T]


# --------------------------------------------------------------------------
# Backward (dz from the lse residual)
# --------------------------------------------------------------------------

def _bwd_kernel(z_ref, h_ref, lse_ref, g_ref, dz_ref, *, k):
    z = z_ref[...].astype(jnp.float32)             # (Tt, m)
    h = h_ref[...]                                 # (Tt, k)
    lse = lse_ref[...]                             # (Tt,)
    g = g_ref[...]                                 # (Tt,)
    p = jnp.exp(z - lse[:, None])                  # softmax via residual
    w = onehot_count(h, z.shape[1])                # (Tt, m)
    dz_ref[...] = g[:, None] * (p - w / k)


@functools.partial(jax.jit, static_argnames=("t_tile", "interpret"))
def bloom_ce_bwd_pallas(g: jnp.ndarray, logits: jnp.ndarray,
                        h_idx: jnp.ndarray, lse: jnp.ndarray,
                        t_tile: int = 8,
                        interpret: bool | None = None) -> jnp.ndarray:
    """g (T,) cotangent; logits (T, m); h_idx (T, k); lse (T,) residual
    -> dlogits (T, m) float32, one pass over the m row."""
    interpret = resolve_interpret(interpret)
    T, m = logits.shape
    k = h_idx.shape[1]
    t_tile = min(t_tile, T)
    logits = pad_axis(logits, 0, t_tile)
    h_idx = pad_axis(h_idx, 0, t_tile)
    lse = pad_axis(lse, 0, t_tile)
    g = pad_axis(g, 0, t_tile)                  # 0-cotangent pad rows -> dz 0
    Tp = logits.shape[0]

    dz = pl.pallas_call(
        functools.partial(_bwd_kernel, k=k),
        grid=(Tp // t_tile,),
        in_specs=[
            pl.BlockSpec((t_tile, m), lambda t: (t, 0)),
            pl.BlockSpec((t_tile, k), lambda t: (t, 0)),
            pl.BlockSpec((t_tile,), lambda t: (t,)),
            pl.BlockSpec((t_tile,), lambda t: (t,)),
        ],
        out_specs=pl.BlockSpec((t_tile, m), lambda t: (t, 0)),
        out_shape=jax.ShapeDtypeStruct((Tp, m), jnp.float32),
        interpret=interpret,
    )(logits, h_idx, lse, g)
    return dz[:T]


# --------------------------------------------------------------------------
# custom_vjp glue + public entry point
# --------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def _bloom_ce(logits, h_idx, t_tile, interpret):
    loss, _ = _ce_fwd(logits, h_idx, t_tile, interpret)
    return loss


def _bloom_ce_vjp_fwd(logits, h_idx, t_tile, interpret):
    loss, lse = _ce_fwd(logits, h_idx, t_tile, interpret)
    return loss, (logits, h_idx, lse)


def _bloom_ce_vjp_bwd(t_tile, interpret, res, g):
    logits, h_idx, lse = res
    dz = bloom_ce_bwd_pallas(g, logits, h_idx, lse, t_tile=t_tile,
                             interpret=interpret)
    return dz.astype(logits.dtype), None


_bloom_ce.defvjp(_bloom_ce_vjp_fwd, _bloom_ce_vjp_bwd)


@functools.partial(jax.jit, static_argnames=("t_tile", "interpret"))
def bloom_ce_pallas(logits: jnp.ndarray, h_idx: jnp.ndarray,
                    t_tile: int = 8,
                    interpret: bool | None = None) -> jnp.ndarray:
    """logits (T, m); h_idx (T, k) int32 -> per-token loss (T,) float32.

    Differentiable: jax.grad w.r.t. `logits` runs the fused lse-residual
    backward kernel (one HBM read of the row, no re-softmax).
    """
    return _bloom_ce(logits, h_idx, min(t_tile, max(logits.shape[0], 1)),
                     resolve_interpret(interpret))
