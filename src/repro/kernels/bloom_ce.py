"""Pallas TPU kernel: fused Bloom softmax cross-entropy (paper's training
loss in the compressed m-space).

loss[t] = logsumexp(z[t, :]) - (1/k) * sum_{j<k} z[t, h[t, j]]

Fusing the logsumexp with the k-gather means the m-dim logits row is read
from HBM exactly once (the unfused path reads it three times: max, exp-sum,
gather).  The row fits VMEM for every assigned config (m <= ~38k fp32).

  grid = (nT,)
  z    — block (Tt, m) at (t, 0)
  h    — block (Tt, k) at (t, 0)
  out  — block (Tt,)   at (t,)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(z_ref, h_ref, out_ref):
    z = z_ref[...].astype(jnp.float32)             # (Tt, m)
    h = h_ref[...]                                 # (Tt, k)
    zmax = z.max(axis=-1, keepdims=True)
    lse = jnp.log(jnp.sum(jnp.exp(z - zmax), axis=-1)) + zmax[:, 0]
    picked = jnp.take_along_axis(z, h, axis=-1)    # (Tt, k)
    out_ref[...] = lse - picked.mean(-1)


@functools.partial(jax.jit, static_argnames=("t_tile", "interpret"))
def bloom_ce_pallas(logits: jnp.ndarray, h_idx: jnp.ndarray,
                    t_tile: int = 8, interpret: bool = True) -> jnp.ndarray:
    """logits (T, m); h_idx (T, k) int32 -> per-token loss (T,) float32."""
    T, m = logits.shape
    k = h_idx.shape[1]
    t_tile = min(t_tile, T)
    pad_t = (-T) % t_tile
    if pad_t:
        logits = jnp.pad(logits, ((0, pad_t), (0, 0)))
        h_idx = jnp.pad(h_idx, ((0, pad_t), (0, 0)))
    Tp = T + pad_t

    out = pl.pallas_call(
        _kernel,
        grid=(Tp // t_tile,),
        in_specs=[
            pl.BlockSpec((t_tile, m), lambda t: (t, 0)),
            pl.BlockSpec((t_tile, k), lambda t: (t, 0)),
        ],
        out_specs=pl.BlockSpec((t_tile,), lambda t: (t,)),
        out_shape=jax.ShapeDtypeStruct((Tp,), jnp.float32),
        interpret=interpret,
    )(logits, h_idx)
    return out[:T]
