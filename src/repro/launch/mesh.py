"""Production meshes (DESIGN.md §6).

A *function*, not a module-level constant, so importing this module never
touches jax device state — critical because smoke tests must see 1 CPU
device while the dry-run forces 512 placeholder devices via XLA_FLAGS.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 two pods (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(axes=("data", "model")):
    """Whatever devices exist, as a (1, ..., n_devices) mesh — used by
    tests and the CPU train/serve drivers."""
    n = jax.device_count()
    shape = (1,) * (len(axes) - 1) + (n,)
    return jax.make_mesh(shape, axes)


def make_serving_mesh(n_hosts: int | None = None, model_parallel: int = 1):
    """Serving-pool mesh: one `data` shard per (simulated) host, `model`
    fixed at `model_parallel`.  With
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` this simulates
    an N-way multi-host serving topology on one CPU process (the
    multi-host sim tests and the `--sharded` serve CLI use exactly that).
    """
    total = jax.device_count()
    if n_hosts is None:
        assert total % model_parallel == 0
        n_hosts = total // model_parallel
    assert n_hosts * model_parallel <= total, (
        f"need {n_hosts * model_parallel} devices, have {total}")
    return jax.make_mesh((n_hosts, model_parallel), ("data", "model"),
                         devices=jax.devices()[:n_hosts * model_parallel])


def make_elastic_mesh(n_devices: int, axes=("data", "model"),
                      model_parallel: int = 1):
    """Rebuild a mesh after a world-size change (node failure / elastic
    scale): keeps `model_parallel` fixed and gives the rest to data."""
    assert n_devices % model_parallel == 0
    shape = (n_devices // model_parallel, model_parallel)
    return jax.make_mesh(shape, axes[-2:])
