"""Retrieval-tower training driver (DESIGN.md §12).

Trains the FF tower on the Zipf stream with the serving-consistent Bloom
loss (train/retrieval_trainer.py), serves the TRAINED params through
``RetrievalEngine`` (the generic slot loop) on a fresh eval-seed
workload, and hard-asserts the paper's margin — trained MAP ≫ untrained
MAP — before printing the ``retrieval-train: verified`` marker the CI
train-retrieval job greps.

Fault-tolerant like launch/train.py: ``--ckpt`` checkpoints every N
steps and auto-resumes on rerun; ``--fault-at S`` / ``--failpoints`` go
through the same seeded registry as serving chaos (``train_fault@S``
kills the loop at step S — rerun the identical command to resume).

Examples:
  # one point at the config's m (eval2k default = 1/5 compression)
  PYTHONPATH=src python -m repro.launch.train_retrieval --steps 300

  # the paper's compression/accuracy curve, m/d in {1/1, 1/2, 1/5, 1/10}
  PYTHONPATH=src python -m repro.launch.train_retrieval --sweep

  # chaos drill: crash at step 120, resume from the last checkpoint
  PYTHONPATH=src python -m repro.launch.train_retrieval \
      --ckpt /tmp/rt_ckpt --fault-at 120 ; \
  PYTHONPATH=src python -m repro.launch.train_retrieval \
      --ckpt /tmp/rt_ckpt
"""
from __future__ import annotations

import argparse
import json

from repro.configs.retrieval import get_retrieval_config
from repro.serving.failpoints import FailPlan
from repro.train import retrieval_trainer as rt


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--config", default="eval2k",
                    help="retrieval config preset (default: eval2k — "
                         "the full-score-eval training scale)")
    ap.add_argument("--m", type=int, default=None,
                    help="override the Bloom output dim (single-point "
                         "mode only; the sweep sets m per ratio)")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--pairs", type=int, default=512,
                    help="training pairs drawn from the Zipf stream")
    ap.add_argument("--eval-requests", type=int, default=64)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--microbatch", type=int, default=0,
                    help="grad-accumulation chunks (0 = off)")
    ap.add_argument("--lr", type=float, default=3e-2)
    ap.add_argument("--seed", type=int, default=0,
                    help="training-data seed (eval always uses seed+1)")
    ap.add_argument("--ckpt", default=None,
                    help="checkpoint dir (enables resume-on-rerun)")
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--fault-at", type=int, default=-1,
                    help="induce a crash at this train step (sugar for "
                         "--failpoints train_fault@S)")
    ap.add_argument("--failpoints", default=None,
                    help="failpoint spec (serving/failpoints.py grammar)")
    ap.add_argument("--table-dtype", default=None,
                    choices=["auto", "float32", "bfloat16", "int8",
                             "fp8_e4m3"],
                    help="pool-logits storage dtype for the recover "
                         "decode (DESIGN.md §13; auto = legacy f32). "
                         "The eval decodes through this knob; the sweep "
                         "additionally reports int8 dual-eval retention "
                         "regardless")
    ap.add_argument("--sweep", action="store_true",
                    help="run the m/d in {1/1, 1/2, 1/5, 1/10} "
                         "compression sweep instead of a single point")
    ap.add_argument("--min-margin", type=float, default=3.0,
                    help="required trained/untrained MAP ratio at 1/5 "
                         "compression (the ISSUE-8 acceptance bar)")
    ap.add_argument("--out", default=None, help="write the report JSON")
    args = ap.parse_args()

    over = {"m": args.m} if args.m else {}
    if args.table_dtype is not None:
        over["table_dtype"] = args.table_dtype
    base = get_retrieval_config(args.config, **over)
    tc = rt.default_train_config(
        steps=args.steps, microbatch=args.microbatch,
        checkpoint_every=(args.checkpoint_every if args.ckpt else 0),
        learning_rate=args.lr)
    plan = FailPlan.parse(args.failpoints)
    if args.fault_at >= 0:
        plan = plan.merge(FailPlan.parse(f"train_fault@{args.fault_at}"))
    failpoints = plan if (args.failpoints or args.fault_at >= 0) else None

    if args.sweep:
        rows = rt.compression_sweep(
            base, tc, n_pairs=args.pairs, batch_size=args.batch,
            n_eval=args.eval_requests, n_slots=args.slots,
            data_seed=args.seed, eval_seed=args.seed + 1)
        rt.assert_trained_margin(rows, min_ratio_at_5=args.min_margin)
        report = {"sweep": rows}
        head = rows[0]
    else:
        row = rt.train_and_eval_point(
            base, tc, n_pairs=args.pairs, batch_size=args.batch,
            n_eval=args.eval_requests, n_slots=args.slots,
            data_seed=args.seed, eval_seed=args.seed + 1,
            checkpoint_dir=args.ckpt, failpoints=failpoints)
        assert row["map"] > row["untrained_map"], (
            f"trained MAP {row['map']:.4f} <= untrained "
            f"{row['untrained_map']:.4f} — training is not helping")
        report = {"point": row}
        head = row

    report["verified"] = True
    print(json.dumps(report, indent=1, sort_keys=True))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(report, f, indent=1, sort_keys=True)
    print(f"retrieval-train: verified ({head['config']}: d={head['d']}, "
          f"{head['steps']} steps, trained map {head['map']:.4f} vs "
          f"untrained {head['untrained_map']:.4f})")


if __name__ == "__main__":
    main()
