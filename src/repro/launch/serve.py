"""Batched serving driver: prefill + decode with Bloom vocab recovery.

Serves a (smoke-config) model end to end: a batch of token prompts is
prefilled into KV/SSM caches, then decoded autoregressively; every decode
step runs the paper's Eq. 3 top-k recovery from the m-dim Bloom softmax
back to real vocabulary ids — the path the paper benchmarks in Fig. 3
(right).

With io_impl="pallas" the recovery runs the fused decode-topk kernel
(kernels.bloom_decode_topk): the (B, d) recovered-score matrix never
touches HBM, and the whole-vocab (d, k) hash matrix is built once per
BloomSpec (core.bloom.cached_hash_matrix) instead of being rehashed every
decode step.

Example:
  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-1.3b \
      --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_local_mesh
from repro.launch.sharding import DistContext
from repro.models import encdec as encdec_lib
from repro.models import io as io_lib
from repro.models import transformer as tf


def pad_caches_to(caches_small, caches_template):
    """Place prefill caches (length S_p) into preallocated max-length
    buffers (the serving cache pool)."""
    def put(buf, small):
        if buf.shape == small.shape:
            return small.astype(buf.dtype)
        idx = (slice(None),) * buf.ndim
        slices = tuple(slice(0, s) for s in small.shape)
        return buf.at[slices].set(small.astype(buf.dtype))

    return jax.tree.map(put, caches_template, caches_small)


def run(arch: str, batch: int = 4, prompt_len: int = 32, gen: int = 16,
        topk: int = 8, seed: int = 0, full: bool = False,
        io_impl: str | None = None):
    cfg = (configs.get_config(arch) if full
           else configs.get_smoke_config(arch))
    if io_impl is not None:
        import dataclasses
        cfg = dataclasses.replace(cfg, io_impl=io_impl)
    mesh = make_local_mesh()
    dist = DistContext(mesh) if mesh.size > 1 else None
    max_len = prompt_len + gen

    rng = np.random.default_rng(seed)
    prompts = rng.integers(0, cfg.vocab, size=(batch, prompt_len),
                           dtype=np.int32)
    batch_in = {"tokens": jnp.asarray(prompts)}
    if cfg.family in ("vlm", "audio"):
        batch_in["embeds"] = jnp.zeros((batch, max(4, prompt_len // 4),
                                        cfg.d_model), jnp.dtype(cfg.dtype))

    init = steps_lib.init_fn_for(cfg)
    params = init(jax.random.PRNGKey(seed))
    # one-time cast to the serving dtype (bf16 serving checkpoint)
    params = steps_lib.cast_params_for_compute(params, cfg)

    prefill = jax.jit(steps_lib.make_prefill_step(cfg, dist))
    decode = jax.jit(steps_lib.make_decode_step(cfg, topk=topk, dist=dist))

    t0 = time.perf_counter()
    pre = prefill(params, batch_in)
    if cfg.family == "audio":
        template = encdec_lib.init_encdec_cache(
            cfg, batch, max_len, batch_in["embeds"].shape[1])
    else:
        template = tf.init_lm_cache(cfg, batch, max_len)
    caches = pad_caches_to(pre["caches"], template)
    t_prefill = time.perf_counter() - t0

    # greedy decode in recovered-vocab space (hash matrix already cached by
    # make_decode_step — no per-step vocab rehash)
    last = pre["last_logits"]
    _, ids = io_lib.recover_topk(cfg, last, topk=topk)
    token = ids[:, :1].astype(jnp.int32)

    n_prefix = prompt_len
    generated = [np.asarray(token)]
    t0 = time.perf_counter()
    for t in range(gen - 1):
        out = decode(params, token, caches, jnp.int32(n_prefix + t))
        caches = out["caches"]
        token = out["topk_ids"][:, :1].astype(jnp.int32)
        generated.append(np.asarray(token))
    t_decode = time.perf_counter() - t0
    gen_tokens = np.concatenate(generated, axis=1)

    print(f"prefill {prompt_len} toks x{batch}: {t_prefill*1e3:.0f} ms")
    print(f"decode  {gen-1} steps: {t_decode*1e3:.0f} ms "
          f"({(gen-1)*batch/max(t_decode,1e-9):.0f} tok/s)")
    print("generated ids (first seq):", gen_tokens[0].tolist())
    return gen_tokens


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True,
                    choices=list(configs.ARCH_NAMES))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--topk", type=int, default=8)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--io-impl", choices=("xla", "pallas"), default=None,
                    help="override cfg.io_impl (pallas = fused Bloom "
                         "kernels incl. streaming decode-topk)")
    args = ap.parse_args()
    run(args.arch, batch=args.batch, prompt_len=args.prompt_len,
        gen=args.gen, topk=args.topk, full=args.full,
        io_impl=args.io_impl)


if __name__ == "__main__":
    main()
