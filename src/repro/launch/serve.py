"""Serving driver: thin CLI over the continuous-batching engine.

Default mode builds a seeded Poisson workload (serving/loadgen.py) and
runs it through repro.serving.Engine — requests are admitted into freed
cache slots every decode step and retired on per-slot stop conditions,
so a drained slot never burns decode FLOPs while traffic waits.  Every
decode step still runs the paper's Eq. 3 top-k recovery from the m-dim
Bloom softmax back to real vocabulary ids (Fig. 3 right); with
io_impl="pallas" that recovery is the fused decode-topk kernel.

``--static`` keeps the old whole-batch path for A/B: one batch of
identical-length prompts, prefilled together, decoded until the longest
request drains.  That path (run()) also remains the only one serving
enc-dec / frontend-stub archs (whisper, pixtral), whose prefill carries
non-token inputs the engine does not schedule.

``--mode retrieval`` serves one-shot Bloom top-k retrieval requests
(Zipf item lookups over a configs/retrieval.py catalog preset) through
RetrievalEngine — the identical slot loop, so ``--failpoints`` and the
overload flags (``--deadline-slack`` / ``--max-queue-depth``,
DESIGN.md §14) apply there too.

Examples:
  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-1.3b \
      --slots 4 --requests 16 --gen 16
  PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b --static \
      --batch 4 --prompt-len 32 --gen 16
  PYTHONPATH=src python -m repro.launch.serve --mode retrieval \
      --retrieval-config smoke --slots 4 --requests 16 \
      --failpoints 'surge:3@1' --deadline-slack 8
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_local_mesh, make_serving_mesh
from repro.launch.sharding import DistContext
from repro.models import encdec as encdec_lib
from repro.models import io as io_lib
from repro.models import transformer as tf
from repro.serving import retrieval as retrieval_lib
from repro.serving import (AdmissionPolicy, Engine, FailPlan, LoadSpec,
                           RetrievalEngine, RetrievalLoadSpec,
                           ShardedEngine, evaluate_retrieval,
                           init_retrieval_params, make_workload,
                           mean_latency, retrieval_workload,
                           sharded_workload)


def pad_caches_to(caches_small, caches_template):
    """Place prefill caches (length S_p) into preallocated max-length
    buffers — the whole-batch special case (slot 0, full batch) of the
    engine's slot-indexed steps.insert_cache_slot."""
    return steps_lib.insert_cache_slot(caches_template, caches_small, 0)


def _config(arch: str, full: bool, io_impl, table_dtype=None):
    cfg = (configs.get_config(arch) if full
           else configs.get_smoke_config(arch))
    import dataclasses
    if io_impl is not None:
        cfg = dataclasses.replace(cfg, io_impl=io_impl)
    if table_dtype is not None:
        cfg = dataclasses.replace(cfg, table_dtype=table_dtype)
    return cfg


def _setup(cfg, seed: int):
    mesh = make_local_mesh()
    dist = DistContext(mesh) if mesh.size > 1 else None
    init = steps_lib.init_fn_for(cfg)
    params = init(jax.random.PRNGKey(seed))
    # one-time cast to the serving dtype (bf16 serving checkpoint)
    params = steps_lib.cast_params_for_compute(params, cfg)
    return params, dist


def _overload_policy(deadline_slack, max_queue_depth):
    """CLI knobs -> optional AdmissionPolicy (DESIGN.md §14): either
    flag alone activates the policy (deadline shedding needs workload
    deadlines; the ladder runs with its default thresholds)."""
    if deadline_slack is None and max_queue_depth is None:
        return None
    return AdmissionPolicy(max_queue_depth=max_queue_depth)


def _tag_deadlines(requests, deadline_slack):
    if deadline_slack is not None:
        for r in requests:
            r.deadline_step = r.arrival_step + deadline_slack
    return requests


def run(arch: str, batch: int = 4, prompt_len: int = 32, gen: int = 16,
        topk: int = 8, seed: int = 0, full: bool = False,
        io_impl: str | None = None, table_dtype: str | None = None):
    """Static whole-batch serving (the --static / A-B baseline path)."""
    cfg = _config(arch, full, io_impl, table_dtype)
    params, dist = _setup(cfg, seed)
    max_len = prompt_len + gen

    rng = np.random.default_rng(seed)
    prompts = rng.integers(0, cfg.vocab, size=(batch, prompt_len),
                           dtype=np.int32)
    batch_in = {"tokens": jnp.asarray(prompts)}
    if cfg.family in ("vlm", "audio"):
        batch_in["embeds"] = jnp.zeros((batch, max(4, prompt_len // 4),
                                        cfg.d_model), jnp.dtype(cfg.dtype))

    prefill = jax.jit(steps_lib.make_prefill_step(cfg, dist))
    decode = jax.jit(steps_lib.make_decode_step(cfg, topk=topk, dist=dist))

    t0 = time.perf_counter()
    pre = prefill(params, batch_in)
    if cfg.family == "audio":
        template = encdec_lib.init_encdec_cache(
            cfg, batch, max_len, batch_in["embeds"].shape[1])
    else:
        template = tf.init_lm_cache(cfg, batch, max_len)
    caches = pad_caches_to(pre["caches"], template)
    t_prefill = time.perf_counter() - t0

    # greedy decode in recovered-vocab space (hash matrix already cached by
    # make_decode_step — no per-step vocab rehash)
    last = pre["last_logits"]
    _, ids = io_lib.recover_topk(cfg, last, topk=topk)
    token = ids[:, :1].astype(jnp.int32)

    n_prefix = prompt_len
    generated = [np.asarray(token)]
    t0 = time.perf_counter()
    for t in range(gen - 1):
        out = decode(params, token, caches, jnp.int32(n_prefix + t))
        caches = out["caches"]
        token = out["topk_ids"][:, :1].astype(jnp.int32)
        generated.append(np.asarray(token))
    t_decode = time.perf_counter() - t0
    gen_tokens = np.concatenate(generated, axis=1)

    print(f"prefill {prompt_len} toks x{batch}: {t_prefill*1e3:.0f} ms")
    print(f"decode  {gen-1} steps: {t_decode*1e3:.0f} ms "
          f"({(gen-1)*batch/max(t_decode,1e-9):.0f} tok/s)")
    print("generated ids (first seq):", gen_tokens[0].tolist())
    return gen_tokens


def run_continuous(arch: str, slots: int = 4, requests: int = 16,
                   rate: float = 1.0, prompt_len: int = 32, gen: int = 16,
                   topk: int = 8, seed: int = 0, full: bool = False,
                   io_impl: str | None = None, eos_id: int | None = None,
                   prefill_workers: int = 1,
                   table_dtype: str | None = None,
                   failpoints: str | None = None,
                   deadline_slack: int | None = None,
                   max_queue_depth: int | None = None):
    """Continuous batching over a seeded Poisson workload."""
    cfg = _config(arch, full, io_impl, table_dtype)
    if not Engine.supports(cfg):       # before paying for param init
        raise SystemExit(
            f"{arch}: enc-dec / frontend-stub archs serve via --static")
    params, dist = _setup(cfg, seed)
    spec = LoadSpec(
        n_requests=requests, vocab=cfg.vocab, rate=rate,
        prompt_lens=(max(prompt_len // 2, 2), prompt_len),
        gen_lens=(max(gen // 4, 1), gen // 2 or 1, gen), seed=seed)
    workload = _tag_deadlines(make_workload(spec), deadline_slack)
    max_len = max(r.prompt_len + r.max_gen for r in workload)

    engine = Engine(cfg, params, n_slots=slots, max_len=max_len,
                    topk=topk, eos_id=eos_id, dist=dist,
                    prefill_workers=prefill_workers,
                    failpoints=FailPlan.parse(failpoints),
                    admission_policy=_overload_policy(deadline_slack,
                                                      max_queue_depth))
    results, stats = engine.run(workload)
    if stats.rejects:
        print(f"rejected {stats.rejects} requests "
              f"(prefill attempts exhausted)")
    if stats.sheds or stats.degrades:
        print(f"overload policy: {stats.sheds} shed, "
              f"{stats.degrades} degrade transitions")

    row = stats.as_row()
    print(f"served {len(results)} requests on {slots} slots: "
          f"{row['decode_steps']} decode steps, "
          f"utilization {row['utilization']:.2f}, "
          f"mean latency {mean_latency(results):.1f} steps")
    print(f"wall {stats.wall_s*1e3:.0f} ms "
          f"({stats.tokens_out/max(stats.wall_s, 1e-9):.0f} tok/s)")
    for r in list(results.values())[:4]:
        print(f"  req {r.rid}: arrive {r.arrival_step} admit "
              f"{r.admitted_step} finish {r.finish_step} "
              f"tokens {r.tokens[:8]}{'...' if len(r.tokens) > 8 else ''}")
    return results, stats


def run_sharded(arch: str, slots_per_host: int = 1, requests: int = 8,
                rate: float = 1.0, prompt_len: int = 32, gen: int = 16,
                topk: int = 8, seed: int = 0, full: bool = False,
                io_impl: str | None = None, eos_id: int | None = None,
                gossip_delay: int = 1, transport: str = "sim",
                prefill_workers: int = 1,
                compact_threshold: float | None = None,
                table_dtype: str | None = None,
                failpoints: str | None = None,
                deadline_slack: int | None = None,
                max_queue_depth: int | None = None):
    """Data-axis-sharded serving over per-host arrival streams.

    One simulated host per `data` shard — run under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` to simulate an
    8-host topology on CPU (DESIGN.md §8/§9).  `requests` is PER HOST.
    Defaults (sim transport, one prefill worker, no compaction) are
    exactly PR 3's behavior.  ``failpoints`` replays a deterministic
    failure schedule (serving/failpoints.py grammar) against the run,
    e.g. ``kill_host:1@3`` — survivors reclaim the dead host's slots and
    finish every request.
    """
    cfg = _config(arch, full, io_impl, table_dtype)
    if not Engine.supports(cfg):       # before paying for param init
        raise SystemExit(
            f"{arch}: enc-dec / frontend-stub archs serve via --static")
    mesh = make_serving_mesh()
    n_hosts = mesh.shape["data"]
    init = steps_lib.init_fn_for(cfg)
    params = steps_lib.cast_params_for_compute(
        init(jax.random.PRNGKey(seed)), cfg)
    spec = LoadSpec(
        n_requests=requests, vocab=cfg.vocab, rate=rate,
        prompt_lens=(max(prompt_len // 2, 2), prompt_len),
        gen_lens=(max(gen // 4, 1), gen // 2 or 1, gen), seed=seed)
    per_host = sharded_workload(spec, n_hosts)
    for reqs in per_host:
        _tag_deadlines(reqs, deadline_slack)
    max_len = max(r.prompt_len + r.max_gen
                  for reqs in per_host for r in reqs)

    engine = ShardedEngine(cfg, params, mesh=mesh,
                           slots_per_host=slots_per_host, max_len=max_len,
                           topk=topk, eos_id=eos_id,
                           gossip_delay=gossip_delay, transport=transport,
                           prefill_workers=prefill_workers,
                           compact_threshold=compact_threshold,
                           failpoints=FailPlan.parse(failpoints),
                           admission_policy=_overload_policy(
                               deadline_slack, max_queue_depth))
    results, stats = engine.run(per_host)

    row = stats.as_row()
    print(f"served {len(results)} requests on {n_hosts} hosts x "
          f"{slots_per_host} slots (gossip_delay={gossip_delay}, "
          f"transport={transport}, prefill_workers={prefill_workers}, "
          f"compact={compact_threshold}): "
          f"{row['decode_steps']} decode steps, "
          f"{row['compactions']} compactions, "
          f"utilization {row['utilization']:.2f}, "
          f"mean latency {mean_latency(results):.1f} steps")
    if failpoints:
        print(f"failpoints {failpoints!r}: {stats.host_downs} host_downs, "
              f"{stats.requeued} requeued, {stats.rejects} rejects")
    if stats.sheds or stats.degrades:
        print(f"overload policy: {stats.sheds} shed, "
              f"{stats.degrades} degrade transitions")
    print(f"wall {stats.wall_s*1e3:.0f} ms "
          f"({stats.tokens_out/max(stats.wall_s, 1e-9):.0f} tok/s)")
    return results, stats




def run_retrieval(preset: str = "smoke", slots: int = 4,
                  requests: int = 16, rate: float = 2.0, seed: int = 0,
                  prefill_workers: int = 1,
                  failpoints: str | None = None,
                  deadline_slack: int | None = None,
                  max_queue_depth: int | None = None):
    """One-shot Bloom retrieval serving (--mode retrieval): Zipf item
    lookups from ``loadgen.retrieval_workload`` through RetrievalEngine
    — the same ``run_slot_loop`` the LM engine drives, so
    ``--failpoints`` (prefill faults, surge, slow_decode) and the
    overload policy flags work unchanged.  The pool is single-host
    (sharding it is the remaining ROADMAP item), so there is no
    ``--transport`` here."""
    rcfg = configs.get_retrieval_config(preset)
    spec = RetrievalLoadSpec(n_requests=requests, catalog=rcfg.d,
                             c_max=rcfg.c_max, rate=rate, seed=seed)
    workload = _tag_deadlines(retrieval_workload(spec), deadline_slack)
    params = init_retrieval_params(rcfg)
    engine = RetrievalEngine(rcfg, params, n_slots=slots,
                             prefill_workers=prefill_workers,
                             failpoints=FailPlan.parse(failpoints),
                             admission_policy=_overload_policy(
                                 deadline_slack, max_queue_depth))
    results, stats = engine.run(workload)

    row = stats.as_row()
    served = [r for r in results.values() if r.done and not r.shed]
    print(f"served {len(served)}/{len(results)} retrieval requests on "
          f"{slots} slots over a d={rcfg.d:,} catalog ({preset}): "
          f"{row['decode_steps']} decode steps, "
          f"utilization {row['utilization']:.2f}, "
          f"mean latency {mean_latency(results):.1f} steps")
    mb = engine.modeled_bytes
    if mb["streaming_bytes"]:
        print(f"modeled decode HBM bytes: streaming "
              f"{mb['streaming_bytes']:,} vs dense-table oracle "
              f"{mb['dense_oracle_bytes']:,} "
              f"({mb['dense_oracle_bytes']/mb['streaming_bytes']:.1f}x)")
    if stats.rejects:
        print(f"rejected {stats.rejects} requests "
              f"(prefill attempts exhausted)")
    if stats.sheds or stats.degrades:
        print(f"overload policy: {stats.sheds} shed, "
              f"{stats.degrades} degrade transitions")
    if rcfg.d <= retrieval_lib.EVAL_MAX_CATALOG and served:
        metrics = evaluate_retrieval(rcfg, params, served)
        print(f"offline ranking vs held-out targets: "
              f"map {metrics['map']:.4f}, rr {metrics['rr']:.4f} "
              f"over {metrics['n_evaluated']} requests")
    else:
        print("offline ranking eval skipped "
              f"(d={rcfg.d:,} > {retrieval_lib.EVAL_MAX_CATALOG:,}"
              f"{'' if served else ' or nothing served'})")
    return results, stats


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=("lm", "retrieval"), default="lm",
                    help="'lm' = token generation (default); 'retrieval' "
                         "= one-shot Bloom top-k over an item catalog "
                         "(DESIGN.md §11; --retrieval-config picks the "
                         "catalog preset, --arch is ignored)")
    ap.add_argument("--retrieval-config",
                    choices=sorted(configs.RETRIEVAL_CONFIGS),
                    default="smoke",
                    help="configs/retrieval.py preset (--mode retrieval)")
    ap.add_argument("--arch", default=None,
                    choices=list(configs.ARCH_NAMES))
    ap.add_argument("--static", action="store_true",
                    help="old whole-batch path (A/B baseline; required "
                         "for enc-dec / frontend archs)")
    ap.add_argument("--sharded", action="store_true",
                    help="data-axis-sharded pool: one simulated host per "
                         "data shard (set XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N)")
    ap.add_argument("--slots-per-host", type=int, default=1,
                    help="cache-pool slots per host shard (--sharded)")
    ap.add_argument("--gossip-delay", type=int, default=1,
                    help="steps before arrivals/releases become globally "
                         "visible (--sharded)")
    ap.add_argument("--transport", choices=("sim", "collective"),
                    default="sim",
                    help="control-plane delta transport (--sharded): "
                         "'sim' = PR-3 in-process gossip (default), "
                         "'collective' = fixed-size padded all_gather "
                         "over the mesh data axis (jax.distributed-ready)")
    ap.add_argument("--prefill-workers", type=int, default=1,
                    help="prefill-pool size: FIFO over N single-device "
                         "mesh slices (default 1 = PR-3 behavior)")
    ap.add_argument("--compact-threshold", type=float, default=None,
                    help="per-host fragmentation (dead-slot fraction "
                         "below the highest live slot) above which the "
                         "slot pool compacts; default off = PR-3 "
                         "behavior (--sharded)")
    ap.add_argument("--batch", type=int, default=4,
                    help="batch size (--static path)")
    ap.add_argument("--slots", type=int, default=4,
                    help="cache-pool slots (continuous path)")
    ap.add_argument("--requests", type=int, default=16,
                    help="workload size (continuous path)")
    ap.add_argument("--rate", type=float, default=1.0,
                    help="Poisson arrivals per decode step")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--topk", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--eos-id", type=int, default=None,
                    help="stop a slot early on this token id")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--io-impl", choices=("xla", "pallas"), default=None,
                    help="override cfg.io_impl (pallas = fused Bloom "
                         "kernels incl. streaming decode-topk)")
    ap.add_argument("--table-dtype", default=None,
                    choices=("auto", "float32", "bfloat16", "int8",
                             "fp8_e4m3"),
                    help="Bloom table/logp storage dtype (DESIGN.md §13); "
                         "auto = legacy cast-to-activation-dtype; the "
                         "serve path quantizes the embedding table once "
                         "and decodes through narrow logp rows")
    ap.add_argument("--failpoints", default=None,
                    help="deterministic fault schedule "
                         "(serving/failpoints.py grammar), e.g. "
                         "'kill_host:1@3,fail_prefill:2:3,surge:3@1'; "
                         "host kills need --sharded")
    ap.add_argument("--deadline-slack", type=int, default=None,
                    help="tag every request with deadline = arrival + "
                         "SLACK and enable the admission policy: queued "
                         "requests past their deadline are SHED "
                         "deterministically (DESIGN.md §14)")
    ap.add_argument("--max-queue-depth", type=int, default=None,
                    help="bound the visible queue per home host; excess "
                         "arrivals are shed FIFO-last (enables the "
                         "admission policy, DESIGN.md §14)")
    args = ap.parse_args()
    if args.mode == "retrieval":
        if args.static or args.sharded:
            raise SystemExit("--mode retrieval is its own serve path: "
                             "drop --static/--sharded (sharding the "
                             "retrieval pool is a ROADMAP item)")
        if args.transport != "sim":
            raise SystemExit("--mode retrieval has no control-plane "
                             "transport: the pool is single-host "
                             "(DESIGN.md §11)")
        run_retrieval(args.retrieval_config, slots=args.slots,
                      requests=args.requests, rate=args.rate,
                      seed=args.seed,
                      prefill_workers=args.prefill_workers,
                      failpoints=args.failpoints,
                      deadline_slack=args.deadline_slack,
                      max_queue_depth=args.max_queue_depth)
        return
    if args.arch is None:
        ap.error("--arch is required with --mode lm")
    if args.static:
        run(args.arch, batch=args.batch, prompt_len=args.prompt_len,
            gen=args.gen, topk=args.topk, seed=args.seed, full=args.full,
            io_impl=args.io_impl, table_dtype=args.table_dtype)
    elif args.sharded:
        run_sharded(args.arch, slots_per_host=args.slots_per_host,
                    requests=args.requests, rate=args.rate,
                    prompt_len=args.prompt_len, gen=args.gen,
                    topk=args.topk, seed=args.seed, full=args.full,
                    io_impl=args.io_impl, eos_id=args.eos_id,
                    gossip_delay=args.gossip_delay,
                    transport=args.transport,
                    prefill_workers=args.prefill_workers,
                    compact_threshold=args.compact_threshold,
                    table_dtype=args.table_dtype,
                    failpoints=args.failpoints,
                    deadline_slack=args.deadline_slack,
                    max_queue_depth=args.max_queue_depth)
    else:
        run_continuous(args.arch, slots=args.slots, requests=args.requests,
                       rate=args.rate, prompt_len=args.prompt_len,
                       gen=args.gen, topk=args.topk, seed=args.seed,
                       full=args.full, io_impl=args.io_impl,
                       eos_id=args.eos_id,
                       prefill_workers=args.prefill_workers,
                       table_dtype=args.table_dtype,
                       failpoints=args.failpoints,
                       deadline_slack=args.deadline_slack,
                       max_queue_depth=args.max_queue_depth)


if __name__ == "__main__":
    main()
