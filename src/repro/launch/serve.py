"""Serving driver: thin CLI over the continuous-batching engine.

Default mode builds a seeded Poisson workload (serving/loadgen.py) and
runs it through repro.serving.Engine — requests are admitted into freed
cache slots every decode step and retired on per-slot stop conditions,
so a drained slot never burns decode FLOPs while traffic waits.  Every
decode step still runs the paper's Eq. 3 top-k recovery from the m-dim
Bloom softmax back to real vocabulary ids (Fig. 3 right); with
io_impl="pallas" that recovery is the fused decode-topk kernel.

``--static`` keeps the old whole-batch path for A/B: one batch of
identical-length prompts, prefilled together, decoded until the longest
request drains.  That path (run()) also remains the only one serving
enc-dec / frontend-stub archs (whisper, pixtral), whose prefill carries
non-token inputs the engine does not schedule.

Examples:
  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-1.3b \
      --slots 4 --requests 16 --gen 16
  PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b --static \
      --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_local_mesh, make_serving_mesh
from repro.launch.sharding import DistContext
from repro.models import encdec as encdec_lib
from repro.models import io as io_lib
from repro.models import transformer as tf
from repro.serving import (Engine, FailPlan, LoadSpec, ShardedEngine,
                           make_workload, mean_latency, sharded_workload)


def pad_caches_to(caches_small, caches_template):
    """Place prefill caches (length S_p) into preallocated max-length
    buffers — the whole-batch special case (slot 0, full batch) of the
    engine's slot-indexed steps.insert_cache_slot."""
    return steps_lib.insert_cache_slot(caches_template, caches_small, 0)


def _config(arch: str, full: bool, io_impl, table_dtype=None):
    cfg = (configs.get_config(arch) if full
           else configs.get_smoke_config(arch))
    import dataclasses
    if io_impl is not None:
        cfg = dataclasses.replace(cfg, io_impl=io_impl)
    if table_dtype is not None:
        cfg = dataclasses.replace(cfg, table_dtype=table_dtype)
    return cfg


def _setup(cfg, seed: int):
    mesh = make_local_mesh()
    dist = DistContext(mesh) if mesh.size > 1 else None
    init = steps_lib.init_fn_for(cfg)
    params = init(jax.random.PRNGKey(seed))
    # one-time cast to the serving dtype (bf16 serving checkpoint)
    params = steps_lib.cast_params_for_compute(params, cfg)
    return params, dist


def run(arch: str, batch: int = 4, prompt_len: int = 32, gen: int = 16,
        topk: int = 8, seed: int = 0, full: bool = False,
        io_impl: str | None = None, table_dtype: str | None = None):
    """Static whole-batch serving (the --static / A-B baseline path)."""
    cfg = _config(arch, full, io_impl, table_dtype)
    params, dist = _setup(cfg, seed)
    max_len = prompt_len + gen

    rng = np.random.default_rng(seed)
    prompts = rng.integers(0, cfg.vocab, size=(batch, prompt_len),
                           dtype=np.int32)
    batch_in = {"tokens": jnp.asarray(prompts)}
    if cfg.family in ("vlm", "audio"):
        batch_in["embeds"] = jnp.zeros((batch, max(4, prompt_len // 4),
                                        cfg.d_model), jnp.dtype(cfg.dtype))

    prefill = jax.jit(steps_lib.make_prefill_step(cfg, dist))
    decode = jax.jit(steps_lib.make_decode_step(cfg, topk=topk, dist=dist))

    t0 = time.perf_counter()
    pre = prefill(params, batch_in)
    if cfg.family == "audio":
        template = encdec_lib.init_encdec_cache(
            cfg, batch, max_len, batch_in["embeds"].shape[1])
    else:
        template = tf.init_lm_cache(cfg, batch, max_len)
    caches = pad_caches_to(pre["caches"], template)
    t_prefill = time.perf_counter() - t0

    # greedy decode in recovered-vocab space (hash matrix already cached by
    # make_decode_step — no per-step vocab rehash)
    last = pre["last_logits"]
    _, ids = io_lib.recover_topk(cfg, last, topk=topk)
    token = ids[:, :1].astype(jnp.int32)

    n_prefix = prompt_len
    generated = [np.asarray(token)]
    t0 = time.perf_counter()
    for t in range(gen - 1):
        out = decode(params, token, caches, jnp.int32(n_prefix + t))
        caches = out["caches"]
        token = out["topk_ids"][:, :1].astype(jnp.int32)
        generated.append(np.asarray(token))
    t_decode = time.perf_counter() - t0
    gen_tokens = np.concatenate(generated, axis=1)

    print(f"prefill {prompt_len} toks x{batch}: {t_prefill*1e3:.0f} ms")
    print(f"decode  {gen-1} steps: {t_decode*1e3:.0f} ms "
          f"({(gen-1)*batch/max(t_decode,1e-9):.0f} tok/s)")
    print("generated ids (first seq):", gen_tokens[0].tolist())
    return gen_tokens


def run_continuous(arch: str, slots: int = 4, requests: int = 16,
                   rate: float = 1.0, prompt_len: int = 32, gen: int = 16,
                   topk: int = 8, seed: int = 0, full: bool = False,
                   io_impl: str | None = None, eos_id: int | None = None,
                   prefill_workers: int = 1,
                   table_dtype: str | None = None,
                   failpoints: str | None = None):
    """Continuous batching over a seeded Poisson workload."""
    cfg = _config(arch, full, io_impl, table_dtype)
    if not Engine.supports(cfg):       # before paying for param init
        raise SystemExit(
            f"{arch}: enc-dec / frontend-stub archs serve via --static")
    params, dist = _setup(cfg, seed)
    spec = LoadSpec(
        n_requests=requests, vocab=cfg.vocab, rate=rate,
        prompt_lens=(max(prompt_len // 2, 2), prompt_len),
        gen_lens=(max(gen // 4, 1), gen // 2 or 1, gen), seed=seed)
    workload = make_workload(spec)
    max_len = max(r.prompt_len + r.max_gen for r in workload)

    engine = Engine(cfg, params, n_slots=slots, max_len=max_len,
                    topk=topk, eos_id=eos_id, dist=dist,
                    prefill_workers=prefill_workers,
                    failpoints=FailPlan.parse(failpoints))
    results, stats = engine.run(workload)
    if stats.rejects:
        print(f"rejected {stats.rejects} requests "
              f"(prefill attempts exhausted)")

    row = stats.as_row()
    print(f"served {len(results)} requests on {slots} slots: "
          f"{row['decode_steps']} decode steps, "
          f"utilization {row['utilization']:.2f}, "
          f"mean latency {mean_latency(results):.1f} steps")
    print(f"wall {stats.wall_s*1e3:.0f} ms "
          f"({stats.tokens_out/max(stats.wall_s, 1e-9):.0f} tok/s)")
    for r in list(results.values())[:4]:
        print(f"  req {r.rid}: arrive {r.arrival_step} admit "
              f"{r.admitted_step} finish {r.finish_step} "
              f"tokens {r.tokens[:8]}{'...' if len(r.tokens) > 8 else ''}")
    return results, stats


def run_sharded(arch: str, slots_per_host: int = 1, requests: int = 8,
                rate: float = 1.0, prompt_len: int = 32, gen: int = 16,
                topk: int = 8, seed: int = 0, full: bool = False,
                io_impl: str | None = None, eos_id: int | None = None,
                gossip_delay: int = 1, transport: str = "sim",
                prefill_workers: int = 1,
                compact_threshold: float | None = None,
                table_dtype: str | None = None,
                failpoints: str | None = None):
    """Data-axis-sharded serving over per-host arrival streams.

    One simulated host per `data` shard — run under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` to simulate an
    8-host topology on CPU (DESIGN.md §8/§9).  `requests` is PER HOST.
    Defaults (sim transport, one prefill worker, no compaction) are
    exactly PR 3's behavior.  ``failpoints`` replays a deterministic
    failure schedule (serving/failpoints.py grammar) against the run,
    e.g. ``kill_host:1@3`` — survivors reclaim the dead host's slots and
    finish every request.
    """
    cfg = _config(arch, full, io_impl, table_dtype)
    if not Engine.supports(cfg):       # before paying for param init
        raise SystemExit(
            f"{arch}: enc-dec / frontend-stub archs serve via --static")
    mesh = make_serving_mesh()
    n_hosts = mesh.shape["data"]
    init = steps_lib.init_fn_for(cfg)
    params = steps_lib.cast_params_for_compute(
        init(jax.random.PRNGKey(seed)), cfg)
    spec = LoadSpec(
        n_requests=requests, vocab=cfg.vocab, rate=rate,
        prompt_lens=(max(prompt_len // 2, 2), prompt_len),
        gen_lens=(max(gen // 4, 1), gen // 2 or 1, gen), seed=seed)
    per_host = sharded_workload(spec, n_hosts)
    max_len = max(r.prompt_len + r.max_gen
                  for reqs in per_host for r in reqs)

    engine = ShardedEngine(cfg, params, mesh=mesh,
                           slots_per_host=slots_per_host, max_len=max_len,
                           topk=topk, eos_id=eos_id,
                           gossip_delay=gossip_delay, transport=transport,
                           prefill_workers=prefill_workers,
                           compact_threshold=compact_threshold,
                           failpoints=FailPlan.parse(failpoints))
    results, stats = engine.run(per_host)

    row = stats.as_row()
    print(f"served {len(results)} requests on {n_hosts} hosts x "
          f"{slots_per_host} slots (gossip_delay={gossip_delay}, "
          f"transport={transport}, prefill_workers={prefill_workers}, "
          f"compact={compact_threshold}): "
          f"{row['decode_steps']} decode steps, "
          f"{row['compactions']} compactions, "
          f"utilization {row['utilization']:.2f}, "
          f"mean latency {mean_latency(results):.1f} steps")
    if failpoints:
        print(f"failpoints {failpoints!r}: {stats.host_downs} host_downs, "
              f"{stats.requeued} requeued, {stats.rejects} rejects")
    print(f"wall {stats.wall_s*1e3:.0f} ms "
          f"({stats.tokens_out/max(stats.wall_s, 1e-9):.0f} tok/s)")
    return results, stats


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True,
                    choices=list(configs.ARCH_NAMES))
    ap.add_argument("--static", action="store_true",
                    help="old whole-batch path (A/B baseline; required "
                         "for enc-dec / frontend archs)")
    ap.add_argument("--sharded", action="store_true",
                    help="data-axis-sharded pool: one simulated host per "
                         "data shard (set XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N)")
    ap.add_argument("--slots-per-host", type=int, default=1,
                    help="cache-pool slots per host shard (--sharded)")
    ap.add_argument("--gossip-delay", type=int, default=1,
                    help="steps before arrivals/releases become globally "
                         "visible (--sharded)")
    ap.add_argument("--transport", choices=("sim", "collective"),
                    default="sim",
                    help="control-plane delta transport (--sharded): "
                         "'sim' = PR-3 in-process gossip (default), "
                         "'collective' = fixed-size padded all_gather "
                         "over the mesh data axis (jax.distributed-ready)")
    ap.add_argument("--prefill-workers", type=int, default=1,
                    help="prefill-pool size: FIFO over N single-device "
                         "mesh slices (default 1 = PR-3 behavior)")
    ap.add_argument("--compact-threshold", type=float, default=None,
                    help="per-host fragmentation (dead-slot fraction "
                         "below the highest live slot) above which the "
                         "slot pool compacts; default off = PR-3 "
                         "behavior (--sharded)")
    ap.add_argument("--batch", type=int, default=4,
                    help="batch size (--static path)")
    ap.add_argument("--slots", type=int, default=4,
                    help="cache-pool slots (continuous path)")
    ap.add_argument("--requests", type=int, default=16,
                    help="workload size (continuous path)")
    ap.add_argument("--rate", type=float, default=1.0,
                    help="Poisson arrivals per decode step")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--topk", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--eos-id", type=int, default=None,
                    help="stop a slot early on this token id")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--io-impl", choices=("xla", "pallas"), default=None,
                    help="override cfg.io_impl (pallas = fused Bloom "
                         "kernels incl. streaming decode-topk)")
    ap.add_argument("--table-dtype", default=None,
                    choices=("auto", "float32", "bfloat16", "int8",
                             "fp8_e4m3"),
                    help="Bloom table/logp storage dtype (DESIGN.md §13); "
                         "auto = legacy cast-to-activation-dtype; the "
                         "serve path quantizes the embedding table once "
                         "and decodes through narrow logp rows")
    ap.add_argument("--failpoints", default=None,
                    help="deterministic fault schedule "
                         "(serving/failpoints.py grammar), e.g. "
                         "'kill_host:1@3,fail_prefill:2:3'; host kills "
                         "need --sharded")
    args = ap.parse_args()
    if args.static:
        run(args.arch, batch=args.batch, prompt_len=args.prompt_len,
            gen=args.gen, topk=args.topk, seed=args.seed, full=args.full,
            io_impl=args.io_impl, table_dtype=args.table_dtype)
    elif args.sharded:
        run_sharded(args.arch, slots_per_host=args.slots_per_host,
                    requests=args.requests, rate=args.rate,
                    prompt_len=args.prompt_len, gen=args.gen,
                    topk=args.topk, seed=args.seed, full=args.full,
                    io_impl=args.io_impl, eos_id=args.eos_id,
                    gossip_delay=args.gossip_delay,
                    transport=args.transport,
                    prefill_workers=args.prefill_workers,
                    compact_threshold=args.compact_threshold,
                    table_dtype=args.table_dtype,
                    failpoints=args.failpoints)
    else:
        run_continuous(args.arch, slots=args.slots, requests=args.requests,
                       rate=args.rate, prompt_len=args.prompt_len,
                       gen=args.gen, topk=args.topk, seed=args.seed,
                       full=args.full, io_impl=args.io_impl,
                       eos_id=args.eos_id,
                       prefill_workers=args.prefill_workers,
                       table_dtype=args.table_dtype,
                       failpoints=args.failpoints)


if __name__ == "__main__":
    main()
