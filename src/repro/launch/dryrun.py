import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e): prove the distribution config is
coherent without hardware.

For every (arch x shape) cell this driver lowers + compiles the real step
function (train step incl. optimizer update / prefill / decode incl. Eq. 3
top-k recovery) against ShapeDtypeStruct stand-ins on the production mesh
(16x16 single pod, 2x16x16 multi-pod) and records:

  * memory_analysis()            — proves the step fits per-device HBM;
  * cost_analysis() FLOPs/bytes  — roofline compute & memory terms;
  * HLO collective parse         — roofline collective term.

Roofline numbers come from two reduced-depth *unrolled* variants (L and 2L
layers; XLA cost analysis counts while-bodies once — see launch/roofline),
extrapolated linearly to full depth; the full-depth scanned model is also
compiled as the fits-and-compiles proof.

Usage:
  python -m repro.launch.dryrun --arch qwen3-4b --shape train_4k
  python -m repro.launch.dryrun --all                 # 32-cell single-pod
  python -m repro.launch.dryrun --all --multi-pod     # 512-chip proof
"""
import argparse
import dataclasses
import functools
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.configs.base import SHAPE_BY_NAME, TrainConfig
from repro.launch import roofline, steps
from repro.launch.mesh import make_production_mesh
from repro.launch.sharding import (DistContext, batch_pspecs, cache_pspecs,
                                   opt_state_pspecs, param_pspecs)
from repro.models import transformer as tf
from repro.train import trainer as trainer_lib

KEY_SDS = jax.ShapeDtypeStruct((2,), jnp.uint32)


def _params_sds(cfg, serving: bool = False):
    init = steps.init_fn_for(cfg)
    sds = jax.eval_shape(init, KEY_SDS)
    if serving:  # bf16 serving checkpoint: no fp32 master at inference
        sds = jax.eval_shape(
            lambda p: steps.cast_params_for_compute(p, cfg), sds)
    return sds


def _shardings(dist, specs):
    return jax.tree.map(lambda s: dist.sharding(s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def compile_variant(cfg, shape, dist, tc: TrainConfig, zero: bool = False):
    """Lower + compile one step function; return (compiled, lowered).

    zero=True shards optimizer moments over the data axes (ZeRO-1)."""
    mesh = dist.mesh
    params = _params_sds(cfg, serving=shape.kind != "train")
    pspecs = param_pspecs(cfg, params, dist)
    p_sh = _shardings(dist, pspecs)

    if shape.kind == "train":
        step, optimizer = steps.make_train_step(cfg, tc, dist)
        opt_sds = jax.eval_shape(optimizer.init, params)
        opt_specs = opt_state_pspecs(opt_sds, pspecs,
                                     zero_dist=dist if zero else None,
                                     params_shapes=params)
        opt_sh = _shardings(dist, opt_specs)
        batch = configs.input_specs(cfg, shape)
        b_sh = _shardings(dist, batch_pspecs(cfg, batch, dist))
        with mesh:
            jitted = jax.jit(step, in_shardings=(p_sh, opt_sh, b_sh),
                             out_shardings=(p_sh, opt_sh, None))
            lowered = jitted.lower(params, opt_sds, batch)
            compiled = lowered.compile()
    elif shape.kind == "prefill":
        step = steps.make_prefill_step(cfg, dist)
        batch = configs.input_specs(cfg, shape)
        b_sh = _shardings(dist, batch_pspecs(cfg, batch, dist))
        with mesh:
            jitted = jax.jit(step, in_shardings=(p_sh, b_sh))
            lowered = jitted.lower(params, batch)
            compiled = lowered.compile()
    else:  # decode
        step = steps.make_decode_step(cfg, topk=16, dist=dist)
        token = configs.input_specs(cfg, shape)["tokens"]
        caches = configs.cache_specs(cfg, shape)
        c_specs = cache_pspecs(cfg, caches, dist, shape.global_batch)
        c_sh = _shardings(dist, c_specs)
        tok_ax = dist.batch_spec_axes(shape.global_batch)
        t_sh = dist.sharding(P(tok_ax, None))
        pos = jax.ShapeDtypeStruct((), jnp.int32)
        with mesh:
            jitted = jax.jit(step,
                             in_shardings=(p_sh, t_sh, c_sh, None))
            lowered = jitted.lower(params, token, caches, pos)
            compiled = lowered.compile()
    return compiled, lowered


def _collect(compiled, n_devices):
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        # older jax (<=0.4.x) returns a one-element list of the cost dict
        cost = cost[0] if cost else {}
    mem = compiled.memory_analysis()
    colls = roofline.parse_collectives(compiled.as_text(), n_devices)
    return {
        "flops_dev": float(cost.get("flops", 0.0)),
        "bytes_dev": float(cost.get("bytes accessed", 0.0)),
        "collectives": colls,
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "code_bytes": mem.generated_code_size_in_bytes,
        },
    }


def _reduced(cfg, n_layers):
    """Depth-reduced, unrolled variant for exact per-layer cost counting."""
    kw = dict(num_layers=n_layers, scan_layers=False,
              unroll_for_analysis=True)
    if cfg.encoder_layers:
        kw["encoder_layers"] = n_layers
    return dataclasses.replace(cfg, **kw)


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             bloom: bool = True, roofline_pass: bool = True,
             overrides=None, out_dir: str = "experiments/dryrun",
             mesh_shape=None, tag: str = "", zero: bool = False,
             optimizer: str = "adamw"):
    """mesh_shape: optional (data, model) override, e.g. (32, 8) for a
    TP=8 hillclimb variant (256 chips either way)."""
    cfg = configs.get_config(arch, bloom=bloom, **(overrides or {}))
    shape = SHAPE_BY_NAME[shape_name]
    ok, reason = configs.cell_is_runnable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "skipped": reason}

    if mesh_shape is not None:
        mesh = jax.make_mesh(tuple(mesh_shape),
                             ("data", "model")[-len(mesh_shape):]
                             if len(mesh_shape) == 2
                             else ("pod", "data", "model"))
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
    dist = DistContext(mesh)
    n_dev = mesh.size
    tc = TrainConfig(optimizer=optimizer, grad_clip_norm=1.0,
                     warmup_steps=0)
    result = {"arch": arch, "shape": shape_name,
              "mesh": "x".join(map(str, mesh.devices.shape)),
              "bloom": bloom, "n_devices": n_dev,
              "param_count": cfg.param_count(),
              "model_flops_global": roofline.model_flops(cfg, shape)}

    # 1. full-depth scanned compile: the fits-and-compiles proof + memory
    t0 = time.perf_counter()
    compiled, _ = compile_variant(cfg, shape, dist, tc, zero=zero)
    result["full"] = _collect(compiled, n_dev)
    result["full"]["compile_s"] = time.perf_counter() - t0
    del compiled

    # 2. roofline terms via reduced unrolled L/2L extrapolation (single-pod)
    if roofline_pass:
        period = tf.period_of(cfg)
        L1, L2 = period, 2 * period
        ext = {}
        for name, L in (("L1", L1), ("L2", L2)):
            t0 = time.perf_counter()
            c, _ = compile_variant(_reduced(cfg, L), shape, dist, tc,
                                   zero=zero)
            ext[name] = _collect(c, n_dev)
            ext[name]["compile_s"] = time.perf_counter() - t0
            ext[name]["layers"] = L
            del c
        Lf = cfg.num_layers
        def extrap(f):
            a, b = f(ext["L1"]), f(ext["L2"])
            per = (b - a) / (L2 - L1)
            return max(a + per * (Lf - L1), 0.0)
        flops = extrap(lambda e: e["flops_dev"])
        bytes_ = extrap(lambda e: e["bytes_dev"])
        coll = extrap(lambda e: e["collectives"]["total_bytes"])
        result["reduced"] = ext
        result["roofline"] = roofline.roofline_terms(flops, bytes_, coll)
        result["roofline"]["flops_dev"] = flops
        result["roofline"]["bytes_dev"] = bytes_
        result["roofline"]["coll_bytes_dev"] = coll
        mf = result["model_flops_global"] / n_dev
        result["roofline"]["model_flops_ratio"] = (
            mf / flops if flops > 0 else 0.0)

    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        base_tag = tag or ("multipod" if multi_pod else "singlepod")
        suffix = "" if bloom else "__dense"
        path = os.path.join(out_dir,
                            f"{arch}__{shape_name}__{base_tag}{suffix}.json")
        with open(path, "w") as f:
            json.dump(result, f, indent=1)
        result["artifact"] = path
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--no-bloom", action="store_true")
    ap.add_argument("--no-roofline", action="store_true",
                    help="full compile proof only (used for multi-pod)")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    cells = []
    if args.all:
        for arch, shape, ok, _ in configs.all_cells():
            if ok:
                cells.append((arch, shape))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all required"
        cells.append((args.arch, args.shape))

    failures = 0
    for arch, shape in cells:
        t0 = time.perf_counter()
        try:
            res = run_cell(arch, shape, multi_pod=args.multi_pod,
                           bloom=not args.no_bloom,
                           roofline_pass=not args.no_roofline,
                           out_dir=args.out)
            if "roofline" in res:
                r = res["roofline"]
                print(f"OK  {arch:18s} {shape:12s} "
                      f"compute={r['compute_s']:.4f}s "
                      f"memory={r['memory_s']:.4f}s "
                      f"coll={r['collective_s']:.4f}s "
                      f"dom={r['dominant']} "
                      f"[{time.perf_counter()-t0:.0f}s]", flush=True)
            else:
                mem = res.get("full", {}).get("memory", {})
                print(f"OK  {arch:18s} {shape:12s} "
                      f"temp={mem.get('temp_bytes', 0)/2**30:.2f}GiB "
                      f"args={mem.get('argument_bytes', 0)/2**30:.2f}GiB "
                      f"[{time.perf_counter()-t0:.0f}s]", flush=True)
        except Exception as e:  # noqa
            failures += 1
            print(f"FAIL {arch} {shape}: {e}", flush=True)
            traceback.print_exc()
    print(f"done: {len(cells) - failures}/{len(cells)} cells passed",
          flush=True)
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
