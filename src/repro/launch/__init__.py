"""Launch: mesh, sharding, dry-run, train/serve drivers."""
