"""Roofline analysis (deliverable g).

Per (arch x shape x mesh) we derive three terms from the compiled dry-run
artifact (TPU v5e constants):

  compute    = HLO_FLOPs_per_device / 197e12            [s]
  memory     = HLO_bytes_per_device / 819e9             [s]
  collective = collective_bytes_per_device / 50e9       [s]

`cost_analysis()` of the SPMD-partitioned executable reports *per-device*
FLOPs/bytes (local shapes).  Collective bytes are not in cost_analysis —
we parse the post-optimization HLO text and apply ring-algorithm byte
models per collective kind.

IMPORTANT caveat handled upstream: XLA's cost analysis counts a while-loop
body exactly ONCE (empirically verified), so the dry-run lowers statically
unrolled reduced-depth variants (L, 2L layers) and extrapolates linearly —
every super-block is identical, so per-layer cost is exact.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

from repro.configs.base import ModelConfig, ShapeConfig

# TPU v5e, per chip
PEAK_FLOPS = 197e12        # bf16
HBM_BW = 819e9             # bytes/s
LINK_BW = 50e9             # bytes/s/link ICI

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(?P<result>\([^=]*?\)|\S+?)\s*"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?P<start>-start)?\(")
_SHAPE_RE = re.compile(r"(?P<dt>[a-z0-9]+)\[(?P<dims>[0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_IOTA_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")


def _shape_bytes(text: str) -> int:
    """Bytes of the first shape literal in `text`."""
    m = _SHAPE_RE.search(text)
    if not m:
        return 0
    dt = _DTYPE_BYTES.get(m.group("dt"), 4)
    dims = m.group("dims")
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * dt


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _IOTA_GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    return default


def parse_collectives(hlo_text: str, n_devices: int) -> Dict[str, Dict]:
    """Per-device collective byte model from post-SPMD HLO text.

    Ring models (bytes crossing links per device):
      all-reduce: 2 * size * (g-1)/g        (reduce-scatter + all-gather)
      all-gather: out_size * (g-1)/g
      reduce-scatter: in_size * (g-1)/g
      all-to-all: size * (g-1)/g
      collective-permute: size (one hop)
    """
    per_kind: Dict[str, Dict] = {}
    total = 0.0
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        op = m.group("op")
        result = m.group("result")
        # operand shapes are inside the call parens
        rest = line[m.end():]
        res_bytes = _shape_bytes(result)
        arg_bytes = _shape_bytes(rest)
        g = _group_size(line, n_devices)
        frac = (g - 1) / g if g > 1 else 0.0
        if op == "all-reduce":
            moved = 2 * res_bytes * frac
        elif op == "all-gather":
            moved = res_bytes * frac
        elif op == "reduce-scatter":
            moved = max(arg_bytes, res_bytes) * frac
        elif op == "all-to-all":
            moved = max(arg_bytes, res_bytes) * frac
        else:  # collective-permute
            moved = res_bytes
        k = per_kind.setdefault(op, {"count": 0, "bytes": 0.0})
        k["count"] += 1
        k["bytes"] += moved
        total += moved
    return {"per_kind": per_kind, "total_bytes": total}


def roofline_terms(flops_dev: float, bytes_dev: float,
                   coll_bytes_dev: float) -> Dict[str, float]:
    compute = flops_dev / PEAK_FLOPS
    memory = bytes_dev / HBM_BW
    collective = coll_bytes_dev / LINK_BW
    terms = {"compute_s": compute, "memory_s": memory,
             "collective_s": collective}
    dom = max(terms, key=terms.get)
    bound = max(compute, memory, collective)
    terms["dominant"] = dom
    terms["step_time_s"] = bound          # no-overlap upper bound
    terms["roofline_fraction"] = (compute / bound) if bound > 0 else 0.0
    return terms


# --------------------------------------------------------------------------
# Analytic MODEL_FLOPS (6·N·D dense / 6·N_active·D MoE)
# --------------------------------------------------------------------------

def active_param_count(cfg: ModelConfig) -> int:
    """Matmul-active params per token: excludes the input embedding table
    (gather, not matmul) and the non-selected experts."""
    n = cfg.param_count()
    # subtract embedding table (m_vocab x D); the LM head stays (matmul).
    n -= cfg.m_vocab * cfg.d_model
    if cfg.tie_embeddings:
        n += cfg.m_vocab * cfg.d_model  # tied: the head matmul is real
    if cfg.moe is not None:
        mo = cfg.moe
        n_moe_layers = sum(
            1 for li in range(cfg.num_layers) if cfg._layer_is_moe(li))
        per_expert = 3 * cfg.d_model * mo.d_ff_expert
        inactive = (mo.num_experts - mo.top_k) * per_expert
        n -= n_moe_layers * inactive
    return int(n)


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Useful-model FLOPs per step (global, all chips)."""
    n_active = active_param_count(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        if cfg.family == "audio":
            tokens = shape.global_batch * (shape.seq_len
                                           + max(shape.seq_len // 4, 16))
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        if cfg.family == "audio":
            tokens = shape.global_batch * (shape.seq_len
                                           + max(shape.seq_len // 4, 16))
        return 2.0 * n_active * tokens
    # decode: one token per sequence + attention KV-cache reads (flops side
    # of the cache dot-products)
    flops = 2.0 * n_active * shape.global_batch
    n_attn = sum(1 for li in range(cfg.num_layers)
                 if cfg._layer_is_attention(li))
    hd = cfg.resolved_head_dim
    kv_dot = (4.0 * shape.global_batch * shape.seq_len
              * cfg.num_heads * hd)
    return flops + n_attn * kv_dot
