"""End-to-end LM training driver.

Runs any `--arch` (reduced smoke config by default, full config with
--full) on the local mesh with the same step builders the dry-run lowers
for the production meshes.  Fault-tolerant by construction:

  * checkpoints (params + optimizer + data cursor) every N steps, atomic,
    keep-K, auto-resume on restart — kill the process mid-run and rerun
    the same command to continue;
  * elastic: a resume may use a different device count / mesh shape — the
    checkpointer stores unsharded arrays and re-shards on load
    (launch/mesh.make_elastic_mesh);
  * straggler mitigation on real multi-host pods is the runtime's
    responsibility (TPU SPMD is bulk-synchronous): we surface it by (a)
    per-step wall-clock logging for detection and (b) deterministic
    checkpoint-resume for the mitigation path (restart the sick host).

On real TPU pods, set these XLA flags for collective/compute overlap
(latency-hiding scheduler):
  --xla_tpu_enable_async_collective_fusion=true
  --xla_tpu_overlap_compute_collective_tc=true
  --xla_enable_async_all_gather=true

Example:
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b \
      --steps 200 --batch 8 --seq 128 --ckpt /tmp/ckpt_qwen3
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.configs.base import TrainConfig
from repro.data import synthetic
from repro.data.pipeline import BatchIterator, lm_batches
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_local_mesh
from repro.launch.sharding import DistContext, param_pspecs
from repro.checkpoint.checkpointer import Checkpointer
from repro.optim import optimizers as opt_lib
from repro.serving.failpoints import FailPlan


def run(arch: str, steps: int = 100, batch: int = 8, seq: int = 64,
        ckpt_dir: str | None = None, full: bool = False,
        bloom: bool = True, log_every: int = 10, microbatch: int = 0,
        grad_compression: str = "none", seed: int = 0,
        fault_at: int = -1, learning_rate: float = 3e-3,
        io_impl: str | None = None, bwd_impl: str | None = None,
        table_dtype: str | None = None,
        failpoints: str | None = None):
    cfg = (configs.get_config(arch, bloom=bloom) if full
           else configs.get_smoke_config(arch))
    import dataclasses
    if io_impl is not None:
        cfg = dataclasses.replace(cfg, io_impl=io_impl)
    if bwd_impl is not None:
        cfg = dataclasses.replace(cfg, bwd_impl=bwd_impl)
    if table_dtype is not None:
        cfg = dataclasses.replace(cfg, table_dtype=table_dtype)
    mesh = make_local_mesh()
    dist = DistContext(mesh) if mesh.size > 1 else None
    tc = TrainConfig(optimizer="adamw", learning_rate=learning_rate,
                     grad_clip_norm=1.0, steps=steps, warmup_steps=10,
                     checkpoint_every=max(steps // 4, 10),
                     microbatch=microbatch,
                     grad_compression=grad_compression)

    # data: synthetic Zipf token stream shaped like the cell's inputs
    stream = synthetic.make_token_stream(
        n_tokens=batch * (seq + 1) * max(steps, 64), vocab=cfg.vocab,
        seed=seed)
    windows = lm_batches(stream, batch, seq)
    it = BatchIterator([windows], batch, seed=seed)

    def make_batch(arrays):
        w = jnp.asarray(arrays[0])
        b = {"tokens": w[:, :]}
        if cfg.family in ("vlm", "audio"):
            n_emb = max(4, seq // 4)
            b["embeds"] = jnp.zeros((w.shape[0], n_emb, cfg.d_model),
                                    jnp.dtype(cfg.dtype))
        return b

    step_fn, optimizer = steps_lib.make_train_step(cfg, tc, dist)
    step_jit = jax.jit(step_fn, donate_argnums=(0, 1))

    init = steps_lib.init_fn_for(cfg)
    params = init(jax.random.PRNGKey(seed))
    opt_state = optimizer.init(params)
    start_step = 0

    ckpt = Checkpointer(ckpt_dir, keep=tc.keep_checkpoints,
                        async_write=True) if ckpt_dir else None
    if ckpt:
        restored, rstep, extra = ckpt.restore_latest(
            {"params": params, "opt_state": opt_state})
        if restored is not None:
            params = restored["params"]
            opt_state = restored["opt_state"]
            start_step = rstep
            if "data" in extra:
                it.restore(extra["data"])
            print(f"resumed from step {rstep}")

    # Fault injection goes through the same seeded registry the serving
    # stack uses (serving/failpoints.py); --fault-at is sugar for
    # `train_fault@S`, and both compose in one plan.
    plan = FailPlan.parse(failpoints)
    if fault_at >= 0:
        plan = plan.merge(FailPlan.parse(f"train_fault@{fault_at}"))
    fault_hook = plan.train_hook()

    history = []
    t_start = time.perf_counter()
    for s in range(start_step, steps):
        if fault_hook is not None:
            fault_hook(s)
        arrays = next(it)
        t0 = time.perf_counter()
        params, opt_state, metrics = step_jit(params, opt_state,
                                              make_batch(arrays))
        if log_every and (s + 1) % log_every == 0:
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            history.append({"step": s + 1, "loss": loss, "step_s": dt})
            print(f"step {s+1:5d}  loss {loss:.4f}  {dt*1e3:.0f} ms",
                  flush=True)
        if ckpt and (s + 1) % tc.checkpoint_every == 0:
            ckpt.save(s + 1, {"params": params, "opt_state": opt_state},
                      extra={"data": it.state()}, block=False)
    if ckpt:
        ckpt.save(steps, {"params": params, "opt_state": opt_state},
                  extra={"data": it.state()})
        ckpt.wait()
    wall = time.perf_counter() - t_start
    print(f"trained {steps - start_step} steps in {wall:.1f}s")
    return params, history


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True,
                    choices=list(configs.ARCH_NAMES))
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--no-bloom", action="store_true")
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--grad-compression", default="none",
                    choices=["none", "bf16"])
    ap.add_argument("--fault-at", type=int, default=-1,
                    help="raise at this step (fault-tolerance demo); "
                         "sugar for --failpoints train_fault@S")
    ap.add_argument("--failpoints", default=None,
                    help="failpoint spec (serving/failpoints.py grammar), "
                         "e.g. train_fault@7")
    ap.add_argument("--io-impl", default=None, choices=["xla", "pallas"],
                    help="override cfg.io_impl (pallas = fused Bloom "
                         "embed/CE kernels in the train step)")
    ap.add_argument("--bwd-impl", default=None, choices=["dense", "csr"],
                    help="pallas-path Bloom backward: csr (CSR-binned "
                         "scatter-add, stream-once) or dense (m-tile "
                         "sweep fallback)")
    ap.add_argument("--table-dtype", default=None,
                    choices=["auto", "float32", "bfloat16", "int8",
                             "fp8_e4m3"],
                    help="Bloom table storage dtype (DESIGN.md §13); "
                         "auto = legacy cast-to-activation-dtype; int8 "
                         "uses per-row scales with straight-through "
                         "gradients (quantization-aware training)")
    args = ap.parse_args()
    run(args.arch, steps=args.steps, batch=args.batch, seq=args.seq,
        ckpt_dir=args.ckpt, full=args.full, bloom=not args.no_bloom,
        microbatch=args.microbatch, grad_compression=args.grad_compression,
        fault_at=args.fault_at, io_impl=args.io_impl,
        bwd_impl=args.bwd_impl, table_dtype=args.table_dtype,
        failpoints=args.failpoints)


if __name__ == "__main__":
    main()
