"""Sharding rules: logical-axis mapping for every parameter/activation/
cache in the zoo (DESIGN.md §6).

Conventions:
  * batch axes  = every mesh axis except `model` (i.e. ("pod","data") on the
    multi-pod mesh) — pure data parallelism;
  * `model` axis = Megatron-style tensor parallelism (heads / d_ff / vocab
    m-dim / experts / mamba d_inner+heads);
  * GQA kv heads replicate when num_kv_heads < |model| (MaxText-style kv
    replication) — the weights are small;
  * decode caches shard batch over the batch axes when divisible, else the
    *sequence* dim shards over `data` (sequence-parallel KV for long_500k).

All rules are path-regex -> PartitionSpec, evaluated on the flattened
parameter tree; stacked scan weights (leading n_super dim under blocks/)
automatically get a leading None.
"""
from __future__ import annotations

import dataclasses
import math
import re
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig

try:  # jax>=0.6 stabilized shard_map
    from jax import shard_map as _shard_map
except ImportError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map as _shard_map

# The "skip replication check" kwarg was renamed check_rep -> check_vma
# across jax versions; resolve it from the actual signature so either
# jaxlib works (same dance as models/moe.py).
import inspect as _inspect

_CHECK_KW = ("check_vma" if "check_vma"
             in _inspect.signature(_shard_map).parameters else "check_rep")


def shard_map_nocheck(fn, mesh, in_specs, out_specs):
    """shard_map with the replication check disabled, version-portable."""
    return _shard_map(fn, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, **{_CHECK_KW: False})


def replicated_specs(tree):
    """Fully-replicated PartitionSpec pytree matching ``tree`` — the
    shard_map operand spec for host-broadcast inputs (prefill caches
    entering the sharded pool, compaction permutations)."""
    return jax.tree.map(lambda leaf: P(*([None] * leaf.ndim)), tree)


@dataclasses.dataclass
class DistContext:
    """Carries the mesh + axis conventions into model code."""

    mesh: Mesh
    model_axis: str = "model"

    @property
    def batch_axes(self) -> Tuple[str, ...]:
        return tuple(a for a in self.mesh.axis_names
                     if a != self.model_axis)

    @property
    def n_batch(self) -> int:
        return math.prod(self.mesh.shape[a] for a in self.batch_axes)

    @property
    def n_model(self) -> int:
        return self.mesh.shape[self.model_axis]

    def sharding(self, spec: P) -> NamedSharding:
        return NamedSharding(self.mesh, spec)

    def constrain(self, x, spec: P):
        return jax.lax.with_sharding_constraint(x, self.sharding(spec))

    def batch_spec_axes(self, b: int):
        """Batch-dim axes if `b` divides across them, else None (replicate)."""
        return self.batch_axes if b % self.n_batch == 0 else None

    def constrain_tokens(self, x):
        """(B, S, D) activations: DP over batch when divisible."""
        ax = self.batch_spec_axes(x.shape[0])
        spec = P(ax, *([None] * (x.ndim - 1)))
        return self.constrain(x, spec)

    def constrain_logits(self, x):
        """(..., m) logits: DP over batch + TP over the vocab/m dim.

        Without this constraint GSPMD replicates the full m-dim logits on
        every device once the loss touches them (measured 16x temp blowup).
        """
        ax = self.batch_spec_axes(x.shape[0])
        v_ax = "model" if x.shape[-1] % self.n_model == 0 else None
        spec = P(ax, *([None] * (x.ndim - 2)), v_ax)
        return self.constrain(x, spec)


# --------------------------------------------------------------------------
# Parameter partition specs
# --------------------------------------------------------------------------

def _param_rules(cfg: ModelConfig, n_model: int):
    """Ordered (regex, builder) table; builder(leaf_ndim) -> PartitionSpec."""
    kv_shardable = cfg.num_kv_heads % n_model == 0
    heads_shardable = cfg.num_heads % n_model == 0
    kv_ax = "model" if kv_shardable else None
    q_ax = "model" if heads_shardable else None
    mamba_ok = (cfg.mamba is not None
                and (cfg.mamba.expand * cfg.d_model
                     // cfg.mamba.head_dim) % n_model == 0)
    m_ax = "model" if mamba_ok else None
    vocab_ok = cfg.m_vocab % n_model == 0
    v_ax = "model" if vocab_ok else None
    moe_ok = cfg.moe is not None and cfg.moe.num_experts % n_model == 0
    e_ax = "model" if moe_ok else None
    ff_ok = cfg.d_ff % n_model == 0
    f_ax = "model" if ff_ok else None
    fe_ok = cfg.moe is not None and cfg.moe.d_ff_expert % n_model == 0
    fe_ax = "model" if fe_ok else None

    return [
        (r"io/embed$", lambda nd: P(v_ax, None)),
        (r"io/head$", lambda nd: P(None, v_ax)),
        (r"frontend_proj", lambda nd: P(*([None] * nd))),
        (r"attn/wq$", lambda nd: P(None, q_ax, None)),
        (r"(attn|self_attn|cross_attn)/w[kv]$",
         lambda nd: P(None, kv_ax, None)),
        (r"(self_attn|cross_attn)/wq$", lambda nd: P(None, q_ax, None)),
        (r"(attn|self_attn|cross_attn)/wo$",
         lambda nd: P(q_ax, None, None)),
        (r"attn/bq$|(self|cross)_attn/bq$", lambda nd: P(q_ax, None)),
        (r"b[kv]$", lambda nd: P(kv_ax, None)),
        (r"(q|k)_norm/scale$", lambda nd: P(None)),
        # FFN: 2D = dense SwiGLU (shard d_ff); 3D = expert-stacked MoE
        (r"ffn/router$", lambda nd: P(None, None)),
        (r"ffn/(w_gate|w_up)$", lambda nd: P(None, f_ax) if nd == 2
         else P(e_ax, None, None)),
        (r"ffn/w_down$", lambda nd: P(f_ax, None) if nd == 2
         else P(e_ax, None, None)),
        (r"shared/w_(gate|up)$", lambda nd: P(None, fe_ax)),
        (r"shared/w_down$", lambda nd: P(fe_ax, None)),
        # mamba
        (r"mamba/(z|x)_proj$", lambda nd: P(None, m_ax)),
        (r"mamba/dt_proj$", lambda nd: P(None, m_ax)),
        (r"mamba/(b|c)_proj$", lambda nd: P(None, None)),
        (r"mamba/conv_x/w$", lambda nd: P(None, m_ax)),
        (r"mamba/conv_x/b$", lambda nd: P(m_ax)),
        (r"mamba/conv_[bc]/", lambda nd: P(*([None] * nd))),
        (r"mamba/(A_log|D|dt_bias)$", lambda nd: P(m_ax)),
        (r"mamba/norm/scale$", lambda nd: P(m_ax)),
        (r"mamba/out_proj$", lambda nd: P(m_ax, None)),
        # rnn / recommender dense layers
        (r"cell/|in_proj|l\d+/", lambda nd: P(*([None] * nd))),
        # norms & everything residual-dim shaped
        (r"norm", lambda nd: P(*([None] * nd))),
        (r"", lambda nd: P(*([None] * nd))),   # fallback: replicate
    ]


def _path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                    for p in path)


def param_pspecs(cfg: ModelConfig, params, dist: DistContext):
    """Pytree of PartitionSpec matching `params` (shapes or arrays)."""
    rules = _param_rules(cfg, dist.n_model)

    def spec_for(path, leaf):
        s = _path_str(path)
        nd = len(leaf.shape)
        stacked = s.startswith("blocks/") or s.startswith("encoder/") \
            or s.startswith("decoder/")
        eff_nd = nd - 1 if stacked else nd
        for pat, builder in rules:
            if re.search(pat, s):
                spec = builder(eff_nd)
                break
        if stacked:
            spec = P(None, *spec)
        if len(spec) != nd:  # defensive: pad/truncate
            spec = P(*(list(spec) + [None] * nd)[:nd])
        return spec

    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    return jax.tree_util.tree_unflatten(
        treedef, [spec_for(p, l) for p, l in flat])


# --------------------------------------------------------------------------
# Input / cache partition specs
# --------------------------------------------------------------------------

def batch_pspecs(cfg: ModelConfig, batch, dist: DistContext):
    def spec_for(leaf):
        ax = dist.batch_spec_axes(leaf.shape[0])
        return P(ax, *([None] * (len(leaf.shape) - 1)))

    return jax.tree.map(spec_for, batch)


def cache_pspecs(cfg: ModelConfig, caches, dist: DistContext,
                 global_batch: int):
    """Decode-cache specs.

    Decode is KV-cache-read bound, so the cache must never replicate:
      * kv heads shard over `model` when divisible;
      * otherwise (GQA kv < n_model) the cache SEQUENCE dim shards over
        `model` — decode softmax stats cost one tiny all-reduce while the
        dominant cache reads drop n_model-fold (§Perf decode finding);
      * batch shards over the data axes when divisible, else (long_500k
        B=1) the sequence additionally shards over `data`.
    """
    bx = dist.batch_spec_axes(global_batch)
    kv_ax = "model" if cfg.num_kv_heads % dist.n_model == 0 else None
    mamba_ok = (cfg.mamba is not None
                and (cfg.mamba.expand * cfg.d_model
                     // cfg.mamba.head_dim) % dist.n_model == 0)
    m_ax = "model" if mamba_ok else None

    # seq-shard over `model` ONLY when no head dim can shard at all
    # (e.g. whisper's 12 heads on a 16-way axis).  For GQA archs the
    # right answer is a decode mesh with TP == num_kv_heads (measured:
    # TP=8 beats seq-sharding 15x for qwen3/granite/pixtral decode —
    # XLA's pre-Shardy partitioner reshards seq-sharded caches
    # pathologically around the masked update, see b/433785288).
    heads_shardable = cfg.num_heads % dist.n_model == 0
    allow_seq_model = kv_ax is None and not heads_shardable

    def seq_axes_for(seq_len: int):
        axes = []
        if bx is None and seq_len % dist.n_batch == 0:
            axes.extend(dist.batch_axes)
        if allow_seq_model:
            n = dist.n_model
            total = math.prod(dist.mesh.shape[a] for a in axes) * n
            if seq_len % total == 0:
                axes.append(dist.model_axis)
        if not axes:
            return None
        return tuple(axes) if len(axes) > 1 else axes[0]

    def spec_for(path, leaf):
        s = _path_str(path)
        nd = len(leaf.shape)
        # leading dim is the stacked layer dim (n_super)
        if "attn" in s or "cross" in s:   # (L, B, T, KV, hd)
            return P(None, bx, seq_axes_for(leaf.shape[2]), kv_ax, None)
        if "ssm" in s:                    # (L, B, H, N, P)
            return P(None, bx, m_ax, None, None)
        if "conv_x" in s:                 # (L, B, d_conv-1, d_in)
            return P(None, bx, None, m_ax)
        if "conv_" in s:                  # gn channels: replicated
            return P(None, bx, None, None)
        return P(*([None] * nd))

    flat, treedef = jax.tree_util.tree_flatten_with_path(caches)
    return jax.tree_util.tree_unflatten(
        treedef, [spec_for(p, l) for p, l in flat])


def slot_pool_pspecs(cfg: ModelConfig, pool, dist: DistContext,
                     n_slots: int):
    """Serving slot-pool specs (DESIGN.md §8).

    Unlike training-time ``cache_pspecs``, the pool's rules are fixed by
    the serving protocol, not by divisibility heuristics:

      * the SLOT axis (axis 1 of every stacked ``(L, n_slots, ...)`` leaf)
        shards over the data axes — each data shard owns the contiguous
        slot range its host admits into, so a cache insert touches exactly
        one shard and decode reads are all-local;
      * the sequence dim NEVER shards: ``insert_cache_slot`` writes a
        slot-local ``[0, S_p)`` block, and a seq-sharded pool would turn
        every insert into a ragged multi-shard write;
      * kv heads shard over ``model`` when divisible (same as
        cache_pspecs) — orthogonal to the slot axis.

    ``n_slots`` must divide across the data axes: the per-host admission
    shards (serving/scheduler.py ShardedScheduler) assume equal contiguous
    slot ranges.
    """
    if n_slots % dist.n_batch:
        raise ValueError(
            f"n_slots={n_slots} must divide the data axes "
            f"(|data|={dist.n_batch}) — per-host admission shards own "
            "equal contiguous slot ranges")
    bx = dist.batch_axes if dist.n_batch > 1 else None
    kv_ax = ("model" if dist.n_model > 1
             and cfg.num_kv_heads % dist.n_model == 0 else None)
    mamba_ok = (cfg.mamba is not None and dist.n_model > 1
                and (cfg.mamba.expand * cfg.d_model
                     // cfg.mamba.head_dim) % dist.n_model == 0)
    m_ax = "model" if mamba_ok else None

    def spec_for(path, leaf):
        s = _path_str(path)
        nd = len(leaf.shape)
        if "attn" in s or "cross" in s:   # (L, B, T, KV, hd)
            return P(None, bx, None, kv_ax, None)
        if "ssm" in s:                    # (L, B, H, N, P)
            return P(None, bx, m_ax, None, None)
        if "conv_x" in s:                 # (L, B, d_conv-1, d_in)
            return P(None, bx, None, m_ax)
        return P(None, bx, *([None] * (nd - 2)))

    flat, treedef = jax.tree_util.tree_flatten_with_path(pool)
    return jax.tree_util.tree_unflatten(
        treedef, [spec_for(p, l) for p, l in flat])


def opt_state_pspecs(opt_state, params_specs, zero_dist=None,
                     params_shapes=None):
    """Optimizer-state specs: subtrees that mirror the param tree reuse the
    param specs; scalars/counters replicate.

    ZeRO-1 (`zero_dist` = DistContext + `params_shapes` matching
    params_specs): second-moment/momentum tensors additionally shard over
    the *data* axes on their first still-unsharded divisible dim — the
    moments are only touched at update time, so data-replicating them
    wastes HBM (measured 7.6 GiB/device for qwen3-4b at TP=4).  The update
    all-gather this induces is params-bytes once per step (cheap).
    """
    params_treedef = jax.tree_util.tree_structure(params_specs)

    def zero_extend(spec, shape):
        if zero_dist is None:
            return spec
        n_data = zero_dist.n_batch
        axes = zero_dist.batch_axes
        parts = list(spec)
        for i, (dim, ax) in enumerate(zip(shape, parts)):
            if ax is None and dim % n_data == 0 and dim >= n_data:
                parts[i] = axes if len(axes) > 1 else axes[0]
                return P(*parts)
        return spec

    def _is_factored(x):
        return isinstance(x, dict) and set(x) == {"mu", "nu"}

    def factored_specs(spec):
        """Adafactor per-param state: mu mirrors the param; vr/vc drop the
        last / second-to-last dim of the param spec."""
        parts = tuple(spec)
        if len(parts) >= 2:
            nu = {"vr": P(*parts[:-1]),
                  "vc": P(*(parts[:-2] + parts[-1:]))}
        else:
            nu = {"v": spec}
        return {"mu": spec, "nu": nu}

    def map_state(st):
        if jax.tree_util.tree_structure(st) == params_treedef:
            if zero_dist is None or params_shapes is None:
                return params_specs
            return jax.tree.map(
                lambda spec, sds: zero_extend(spec, sds.shape),
                params_specs, params_shapes,
                is_leaf=lambda x: isinstance(x, P))
        if jax.tree_util.tree_structure(
                st, is_leaf=_is_factored) == params_treedef:
            return jax.tree.map(factored_specs, params_specs,
                                is_leaf=lambda x: isinstance(x, P))
        if isinstance(st, dict):
            return {k: map_state(v) for k, v in st.items()}
        if isinstance(st, tuple):
            return tuple(map_state(v) for v in st)
        # leaf (e.g. count scalar)
        return jax.tree.map(lambda l: P(), st)

    return map_state(opt_state)
