"""Step builders: train / prefill / decode step functions per architecture,
shared by the real drivers (train.py, serve.py) and the dry-run.

The lowered objects are exactly what runs on hardware: the train step
includes the optimizer update (realistic memory picture), the decode step
includes the paper's Eq. 3 top-k vocabulary recovery (the serving path the
paper times in Fig. 3 right).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig, TrainConfig
from repro.core import bloom as bloom_lib
from repro.models import encdec as encdec_lib
from repro.models import io as io_lib
from repro.models import transformer as tf
from repro.train import trainer as trainer_lib


def loss_fn_for(cfg: ModelConfig, dist=None):
    base = (encdec_lib.encdec_loss_fn if cfg.family == "audio"
            else tf.lm_loss_fn)
    return lambda params, batch: base(params, cfg, batch, dist=dist)


def init_fn_for(cfg: ModelConfig):
    base = encdec_lib.encdec_init if cfg.family == "audio" else tf.lm_init
    return lambda key: base(key, cfg)


def apply_fn_for(cfg: ModelConfig):
    if cfg.family == "audio":
        return encdec_lib.encdec_apply
    return tf.lm_apply


def cast_params_for_compute(params, cfg: ModelConfig):
    """One-shot fp32 -> compute-dtype cast of all matrix params.

    §Perf iteration (qwen3-4b train_4k): without this, every weight is
    read as fp32 and converted at every use site — and remat re-executes
    the converts in the backward pass.  Profiling the 1-layer unrolled HLO
    showed `convert` = 202 GB of 230 GB/device accessed.  Casting once at
    the step boundary (outside the remat scope) leaves exactly one
    convert per param per step.  1-D params (norm scales, biases, A_log)
    stay fp32 — their consumers want f32 math and they are tiny.
    """
    dt = jnp.dtype(cfg.dtype)

    def cast(p):
        if p.ndim >= 2 and jnp.issubdtype(p.dtype, jnp.floating):
            return p.astype(dt)
        return p

    return jax.tree.map(cast, params)


def make_train_step(cfg: ModelConfig, tc: TrainConfig, dist=None):
    """(params, opt_state, batch) -> (params, opt_state, metrics)."""
    loss_fn = loss_fn_for(cfg, dist)
    optimizer = trainer_lib.make_optimizer(tc)
    # pallas path: build the per-spec hash matrix before the first trace
    # (the LM loss never differentiates decode, so no decode bins here)
    trainer_lib.warm_bloom_caches(cfg)

    def step(params, opt_state, batch):
        def scalar_loss(p):
            loss, metrics = loss_fn(cast_params_for_compute(p, cfg), batch)
            return loss, metrics

        (loss, metrics), grads = jax.value_and_grad(
            scalar_loss, has_aux=True)(params)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = jax.tree.map(lambda p, u: (p + u).astype(p.dtype),
                              params, updates)
        out_metrics = {"loss": loss, **metrics}
        return params, opt_state, out_metrics

    return step, optimizer


def make_prefill_step(cfg: ModelConfig, dist=None):
    """(params, batch) -> {last_logits, caches} — inference prefill."""
    apply_fn = apply_fn_for(cfg)

    def step(params, batch):
        # serving params arrive already in compute dtype (bf16 serving
        # checkpoint — no fp32 master at inference); no in-step cast.
        out = apply_fn(params, cfg, batch, mode="prefill", dist=dist)
        return {"last_logits": out["logits"][:, -1],
                "caches": out["caches"]}

    return step


def make_decode_step(cfg: ModelConfig, topk: int = 16, dist=None):
    """(params, token, caches, pos) -> {logits, caches, topk ids/scores}.

    One new token against a seq_len KV cache; includes the Bloom Eq. 3
    vocabulary recovery so serving cost is end-to-end.
    """
    apply_fn = apply_fn_for(cfg)

    # Build the whole-vocab (d, k) hash matrix ONCE at step-construction
    # time: recover_topk then picks up the cached device array at trace
    # time instead of rehashing arange(d) inside every compiled step.
    spec = io_lib.vocab_spec(cfg)
    if spec is not None and cfg.io_impl == "pallas":
        bloom_lib.cached_hash_matrix(spec)

    def step(params, token, caches, pos):
        out = apply_fn(params, cfg, {"tokens": token}, mode="decode",
                       caches=caches, pos=pos, dist=dist)
        scores, ids = io_lib.recover_topk(cfg, out["logits"][:, 0],
                                          topk=topk)
        return {"logits": out["logits"], "caches": out["caches"],
                "topk_scores": scores, "topk_ids": ids}

    return step


def insert_cache_slot(pool, caches_small, slot):
    """Write one request's prefill caches into batch slot `slot` of a
    preallocated cache pool.

    Every cache leaf is stacked (n_layers, B, ...) — attention k/v carry a
    sequence dim at axis 2 that may be SHORTER in the prefill caches than
    in the pool (prompt_len < max_len); lax.dynamic_update_slice writes the
    small block at (0, slot, 0, ...) and leaves the tail untouched.  Stale
    tail entries from a previous occupant are never read: the kv validity
    mask only admits positions <= the slot's current offset, and decode
    overwrites each position before first attending to it.  SSM caches
    (conv/ssm state) have no sequence dim and are replaced wholesale.

    `slot` may be a traced int32 scalar, so one jitted insert per prompt
    length serves every slot index.
    """
    def put(buf, small):
        starts = (jnp.int32(0), jnp.asarray(slot, jnp.int32)) + \
            (jnp.int32(0),) * (buf.ndim - 2)
        return jax.lax.dynamic_update_slice(buf, small.astype(buf.dtype),
                                            starts)

    return jax.tree.map(put, pool, caches_small)


def make_sharded_insert(pool_specs, dist, slots_per_shard: int):
    """``insert_cache_slot`` lifted to a shard_map device-to-device cache
    insert (DESIGN.md §8).

    The pool's slot axis is sharded over ``data`` (sharding.
    slot_pool_pspecs); the prefill worker's caches arrive replicated —
    that broadcast IS the device-to-device transfer from the prefill mesh
    slice into the decode pool.  Inside the shard_map every data shard
    computes its local view of the global ``slot`` id and only the owning
    shard's dynamic_update_slice survives the ``where``; all other shards
    return their pool block untouched, so the insert writes exactly one
    shard and never gathers the pool.

    Returns a jitted (pool, caches_small, slot) -> pool callable that
    donates the pool (in-place semantics, same as the engine's single-host
    insert); semantically identical to ``insert_cache_slot`` on the
    unsharded tree (asserted by tests/test_serving_multihost.py).
    """
    from repro.launch.sharding import replicated_specs, shard_map_nocheck
    from jax.sharding import PartitionSpec as P

    data_axes = dist.batch_axes

    def _insert(pool_local, small, slot):
        ax = jax.lax.axis_index(data_axes[0]) if data_axes else 0
        local = jnp.asarray(slot, jnp.int32) - ax * slots_per_shard
        owns = (local >= 0) & (local < slots_per_shard)
        idx = jnp.clip(local, 0, slots_per_shard - 1)

        def put(buf, sm):
            starts = (jnp.int32(0), idx) + (jnp.int32(0),) * (buf.ndim - 2)
            upd = jax.lax.dynamic_update_slice(
                buf, sm.astype(buf.dtype), starts)
            return jnp.where(owns, upd, buf)

        return jax.tree.map(put, pool_local, small)

    def insert(pool, caches_small, slot):
        fn = shard_map_nocheck(
            _insert, dist.mesh,
            in_specs=(pool_specs, replicated_specs(caches_small), P()),
            out_specs=pool_specs)
        return fn(pool, caches_small, jnp.asarray(slot, jnp.int32))

    jitted = jax.jit(insert, donate_argnums=(0,))

    def insert_with_transfer(pool, caches_small, slot):
        # the prefill worker's caches are committed to its mesh slice;
        # broadcasting them onto the decode mesh is the explicit
        # device-to-device transfer (jit refuses mixed commitments)
        from jax.sharding import NamedSharding
        caches_small = jax.device_put(
            caches_small, jax.tree.map(
                lambda leaf: NamedSharding(dist.mesh,
                                           P(*([None] * leaf.ndim))),
                caches_small))
        return jitted(pool, caches_small, slot)

    return insert_with_transfer


def make_compact_pool(pool_specs, dist, slots_per_shard: int):
    """Slot-compaction remap of the sharded cache pool (DESIGN.md §9).

    ``perm`` is the control plane's (n_slots,) int32 gather permutation
    (perm[new_slot] = old_slot), guaranteed host-local by
    ``serving.control.plan_compaction`` — no entry crosses a shard
    boundary, so the remap is a pure within-shard move and NEVER gathers
    the pool across the data axis.  Inside the shard_map each data shard
    slices its own window of the replicated permutation, rebases it to
    local slot ids, and gathers its slot rows through it; the donated
    output is the in-place update of the pool (same layout as the input
    — ``out_specs = pool_specs`` — so the single-compiled-decode-step
    invariant survives compaction).

    Returns a jitted (pool, perm) -> pool callable; one executable serves
    every permutation (perm is a traced operand, never a compile-time
    constant).
    """
    from repro.launch.sharding import shard_map_nocheck
    from jax.sharding import NamedSharding, PartitionSpec as P

    data_axes = dist.batch_axes

    def _compact(pool_local, perm):
        ax = jax.lax.axis_index(data_axes[0]) if data_axes else 0
        local = jax.lax.dynamic_slice(
            perm, (ax * slots_per_shard,), (slots_per_shard,)) \
            - ax * slots_per_shard

        def take(buf):
            return jnp.take(buf, local, axis=1, mode="clip")

        return jax.tree.map(take, pool_local)

    def compact(pool, perm):
        fn = shard_map_nocheck(
            _compact, dist.mesh,
            in_specs=(pool_specs, P(None)), out_specs=pool_specs)
        return fn(pool, jnp.asarray(perm, jnp.int32))

    jitted = jax.jit(compact, donate_argnums=(0,))

    def compact_with_commit(pool, perm):
        # the host-built permutation must be committed replicated before
        # entering the jit (same dance as the sharded insert's broadcast)
        perm = jax.device_put(jnp.asarray(perm, jnp.int32),
                              NamedSharding(dist.mesh, P(None)))
        return jitted(pool, perm)

    return compact_with_commit


def make_slot_decode_step(cfg: ModelConfig, topk: int = 16, dist=None):
    """Continuous-batching decode step over a slot pool.

    (params, token (B, 1), caches, pos (B,), active (B,)) ->
        {caches, topk_scores, topk_ids}

    Unlike make_decode_step's scalar `pos`, every slot decodes at its own
    sequence offset — the per-slot position vector is what keeps ONE
    compiled step serving a pool whose requests were admitted at different
    times (no per-offset recompiles, no bucketing).  `active` masks the
    Eq. 3 vocabulary recovery so retired slots can never leak tokens.
    """
    apply_fn = apply_fn_for(cfg)

    spec = io_lib.vocab_spec(cfg)
    if spec is not None and cfg.io_impl == "pallas":
        bloom_lib.cached_hash_matrix(spec)

    def step(params, token, caches, pos, active):
        out = apply_fn(params, cfg, {"tokens": token}, mode="decode",
                       caches=caches, pos=pos, dist=dist)
        scores, ids = io_lib.recover_topk(cfg, out["logits"][:, 0],
                                          topk=topk, active=active)
        return {"caches": out["caches"], "topk_scores": scores,
                "topk_ids": ids}

    return step


def make_retrieval_prefill_step(rcfg):
    """One-shot retrieval prefill (DESIGN.md §11).

    (params, items (B, c_max) int32, -1-padded) -> (B, m) tower logits:
    Bloom-encode the item set (core.bloom.encode, Eq. 1 — on-the-fly
    hashing, no (d, k) matrix at 10M-item catalogs) and run the FF tower
    (models/recommender.ff_apply).  No caches, no first token — the
    payload a ``oneshot`` slot holds is this logits row.
    """
    from repro.models import recommender as rec_lib
    spec = rcfg.spec()

    def step(params, items):
        u = bloom_lib.encode(spec, items)            # (B, m) multi-hot
        return rec_lib.ff_apply(params, u)

    return step


def make_retrieval_decode_step(rcfg):
    """The single recover step of a ``oneshot`` slot pool.

    (pool (n_slots, m) logits, active (n_slots,)) -> (scores, ids) of
    shape (n_slots, topk): log_softmax then the occupancy-aware
    streaming Eq. 3 top-k over the d-item catalog
    (io.recover_topk_spec) — never materializing (n_slots, d) scores.
    ``active`` masks retired slots to scores=-inf / ids=0 and, on the
    pallas path, drives the kernel's row-skipping occupancy grid.
    ``rcfg.table_dtype`` rides through to recover_topk_spec: narrow
    pool-logit storage on the pallas path (with in-kernel rehashing — no
    (d, k) stream), fake-quantized ranking on the xla path (DESIGN.md
    §13).
    """
    spec = rcfg.spec()
    impl = rcfg.resolved_impl
    td = rcfg.table_dtype
    td = None if td == "auto" else td
    if impl == "pallas" and td is None:
        # quantized decode rehashes in-kernel; only legacy streams H
        bloom_lib.cached_hash_matrix(spec)

    def step(pool, active):
        return io_lib.recover_topk_spec(spec, pool, topk=rcfg.topk,
                                        impl=impl, chunk=rcfg.chunk,
                                        active=active, table_dtype=td)

    return step


def init_caches_for(cfg: ModelConfig, shape: ShapeConfig):
    if cfg.family == "audio":
        return functools.partial(encdec_lib.init_encdec_cache, cfg,
                                 shape.global_batch, shape.seq_len, 1500)
    return functools.partial(tf.init_lm_cache, cfg, shape.global_batch,
                             shape.seq_len)
