"""Model zoo: pure-functional pytree models.

  layers       — norms, RoPE, SwiGLU, dense
  attention    — GQA chunked/naive/decode attention, KV caches
  moe          — shared+routed top-k experts (dense & expert-parallel)
  mamba2       — SSD chunked scan + O(1) decode
  transformer  — decoder-only assembly (dense/moe/ssm/hybrid families)
  encdec       — whisper-style encoder-decoder
  rnn          — GRU/LSTM (paper session/LM tasks)
  recommender  — paper's feed-forward recommenders over IOEmbeddings
  io           — Bloom/dense token IO boundary (the paper's technique)
"""
from repro.models import (  # noqa: F401
    attention,
    encdec,
    io,
    layers,
    mamba2,
    moe,
    recommender,
    rnn,
    transformer,
)
