"""Encoder-decoder (whisper-style) transformer.

The audio conv frontend is a STUB per the assignment: `input_specs()`
supplies precomputed frame embeddings (B, S_enc, D); a linear projection
stands in for the conv stack.  The decoder vocabulary IO uses the Bloom
layer exactly like the decoder-only LMs.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention, io, layers
from repro.models.transformer import _remat


# --------------------------------------------------------------------------
# Init
# --------------------------------------------------------------------------

def _enc_block_init(key, cfg: ModelConfig):
    k1, k2 = jax.random.split(key)
    return {
        "norm1": layers.rms_norm_init(cfg.d_model),
        "attn": attention.attention_init(k1, cfg),
        "norm2": layers.rms_norm_init(cfg.d_model),
        "ffn": layers.swiglu_init(k2, cfg.d_model, cfg.d_ff),
    }


def _dec_block_init(key, cfg: ModelConfig):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "norm1": layers.rms_norm_init(cfg.d_model),
        "self_attn": attention.attention_init(k1, cfg),
        "norm_x": layers.rms_norm_init(cfg.d_model),
        "cross_attn": attention.attention_init(k2, cfg, cross=True),
        "norm2": layers.rms_norm_init(cfg.d_model),
        "ffn": layers.swiglu_init(k3, cfg.d_model, cfg.d_ff),
    }


def encdec_init(key, cfg: ModelConfig):
    k_io, k_enc, k_dec, k_front = jax.random.split(key, 4)
    enc_keys = jax.random.split(k_enc, cfg.encoder_layers)
    dec_keys = jax.random.split(k_dec, cfg.num_layers)
    return {
        "io": io.io_init(k_io, cfg),
        "frontend_proj": layers.dense_init(k_front, cfg.d_model,
                                           cfg.d_model, bias=False),
        "encoder": jax.vmap(lambda k: _enc_block_init(k, cfg))(enc_keys),
        "enc_norm": layers.rms_norm_init(cfg.d_model),
        "decoder": jax.vmap(lambda k: _dec_block_init(k, cfg))(dec_keys),
        "final_norm": layers.rms_norm_init(cfg.d_model),
    }


# --------------------------------------------------------------------------
# Apply
# --------------------------------------------------------------------------

def encode(params, cfg: ModelConfig, frames, dist=None):
    """frames (B, S_enc, D) stub embeddings -> encoder output (B, S_enc, D)."""
    x = layers.dense(params["frontend_proj"],
                     frames.astype(jnp.dtype(cfg.dtype)))
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    if dist is not None:
        x = dist.constrain_tokens(x)

    def block(bp, x):
        h = layers.rms_norm(bp["norm1"], x, cfg.norm_eps)
        x = x + attention.self_attention(bp["attn"], cfg, h, positions,
                                         causal=False)
        h = layers.rms_norm(bp["norm2"], x, cfg.norm_eps)
        x = x + layers.swiglu(bp["ffn"], h)
        if dist is not None:
            x = dist.constrain_tokens(x)
        return x

    blk = _remat(lambda bp, x: (block(bp, x), None), cfg)

    if cfg.scan_layers:
        def body(x, bp):
            x, _ = blk(bp, x)
            return x, None

        x, _ = jax.lax.scan(body, x, params["encoder"])
    else:
        for i in range(cfg.encoder_layers):
            bp = jax.tree.map(lambda a: a[i], params["encoder"])
            x, _ = blk(bp, x)
    return layers.rms_norm(params["enc_norm"], x, cfg.norm_eps)


def _dec_block(bp, cfg, x, positions, enc_out, mode, cache, pos, dist):
    new_cache = {}
    h = layers.rms_norm(bp["norm1"], x, cfg.norm_eps)
    if mode == "train":
        y = attention.self_attention(bp["self_attn"], cfg, h, positions)
    elif mode == "prefill":
        y, kv = attention.self_attention_with_cache(bp["self_attn"], cfg,
                                                    h, positions,
                                                    cache_dtype=h.dtype)
        new_cache["attn"] = kv
    else:
        y, kv = attention.decode_self_attention(bp["self_attn"], cfg, h,
                                                cache["attn"], pos,
                                                dist=dist)
        new_cache["attn"] = kv
    x = x + y

    h = layers.rms_norm(bp["norm_x"], x, cfg.norm_eps)
    if mode == "decode":
        # cross k/v were precomputed at prefill; reuse the cached ones.
        ck, cv = cache["cross"]["k"], cache["cross"]["v"]
        q = attention._project_qkv(bp["cross_attn"], cfg, h, h,
                                   None, None, rope=False)[0]
        qg, ck, cv = attention._expand_heads(
            q, ck.astype(h.dtype), cv.astype(h.dtype), cfg.num_heads)
        o = attention.naive_attention(qg, ck, cv, causal=False)
        B = h.shape[0]
        o = o.reshape(B, 1, cfg.num_heads, cfg.resolved_head_dim)
        y = jnp.einsum("bshk,hkd->bsd", o,
                       bp["cross_attn"]["wo"].astype(h.dtype))
        new_cache["cross"] = cache["cross"]
    else:
        y = attention.cross_attention(bp["cross_attn"], cfg, h, enc_out,
                                      positions)
        if mode == "prefill":
            _, k_enc, v_enc = attention._project_qkv(
                bp["cross_attn"], cfg, enc_out, enc_out, None, None,
                rope=False)
            new_cache["cross"] = {"k": k_enc.astype(h.dtype),
                                  "v": v_enc.astype(h.dtype)}
    x = x + y

    h = layers.rms_norm(bp["norm2"], x, cfg.norm_eps)
    x = x + layers.swiglu(bp["ffn"], h)
    if dist is not None:
        x = dist.constrain_tokens(x)
    return x, new_cache


def encdec_apply(params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray],
                 mode: str = "train", caches=None, pos=None, dist=None):
    """batch: {"embeds": (B,S_enc,D) frames, "tokens": (B,S_dec)}.

    decode mode runs only the decoder against caches (encoder output is
    folded into the cached cross k/v); like the decoder-only path, `pos`
    may be a scalar or a (B,) per-slot offset vector (the self-attention
    cache update handles both; cross k/v are position-free).
    """
    tokens = batch["tokens"]
    x = io.embed_tokens(params["io"], cfg, tokens)
    B, S = x.shape[:2]
    if mode == "decode":
        positions = None
        enc_out = None
    else:
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        enc_out = encode(params, cfg, batch["embeds"], dist)
    if dist is not None:
        x = dist.constrain_tokens(x)

    blk = (_remat(lambda bp, x, c: _dec_block(bp, cfg, x, positions,
                                              enc_out, mode, c, pos, dist),
                  cfg)
           if mode == "train" else
           lambda bp, x, c: _dec_block(bp, cfg, x, positions, enc_out,
                                       mode, c, pos, dist))

    if cfg.scan_layers:
        def body(carry, inp):
            bp, c = inp
            x, nc = blk(bp, carry, c)
            return x, nc

        x, new_caches = jax.lax.scan(body, x, (params["decoder"], caches))
    else:
        ncs = []
        for i in range(cfg.num_layers):
            bp = jax.tree.map(lambda a: a[i], params["decoder"])
            c = (None if caches is None
                 else jax.tree.map(lambda a: a[i], caches))
            x, nc = blk(bp, x, c)
            ncs.append(nc)
        new_caches = (jax.tree.map(lambda *a: jnp.stack(a), *ncs)
                      if ncs and ncs[0] else None)
    x = layers.rms_norm(params["final_norm"], x, cfg.norm_eps)
    logits = io.lm_logits(params["io"], cfg, x)
    if dist is not None:
        logits = dist.constrain_logits(logits)
    out = {"logits": logits, "aux": jnp.zeros((), jnp.float32)}
    if mode in ("prefill", "decode"):
        out["caches"] = new_caches
    return out


def init_encdec_cache(cfg: ModelConfig, batch: int, cache_len: int,
                      enc_len: int, dtype=jnp.bfloat16):
    hd = cfg.resolved_head_dim
    one = {
        "attn": attention.init_kv_cache(cfg, batch, cache_len, dtype),
        "cross": {
            "k": jnp.zeros((batch, enc_len, cfg.num_kv_heads, hd), dtype),
            "v": jnp.zeros((batch, enc_len, cfg.num_kv_heads, hd), dtype),
        },
    }
    L = cfg.num_layers
    return jax.tree.map(lambda a: jnp.zeros((L, *a.shape), a.dtype), one)


def encdec_loss_fn(params, cfg: ModelConfig, batch, dist=None):
    out = encdec_apply(params, cfg, batch, mode="train", dist=dist)
    logits = out["logits"][:, :-1]
    if dist is not None:
        logits = dist.constrain_logits(logits)
    labels = batch["tokens"][:, 1:]
    loss_tok = io.lm_loss(params["io"], cfg, logits, labels,
                          batch.get("loss_mask"))
    loss = loss_tok.mean()
    return loss, {"ce": loss, "aux": out["aux"]}
