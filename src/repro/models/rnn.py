"""GRU / LSTM sequence models (paper tasks: YC session GRU, PTB LSTM).

Mirrors Hidasi et al. (GRU4Rec) and Graves-style LSTM LMs: one-hot (or
Bloom-encoded) input -> recurrent core -> softmax over the (possibly
Bloom-compressed) output space.  lax.scan over time.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.models import layers


def gru_init(key, d_in: int, d_hidden: int):
    k1, k2 = jax.random.split(key)
    return {
        "wx": layers.truncated_normal_init(k1, (d_in, 3 * d_hidden), 1.0),
        "wh": layers.truncated_normal_init(k2, (d_hidden, 3 * d_hidden),
                                           1.0),
        "b": jnp.zeros((3 * d_hidden,), jnp.float32),
    }


def gru_cell(params, h, x):
    xg = x @ params["wx"] + params["b"]
    hg = h @ params["wh"]
    xr, xz, xn = jnp.split(xg, 3, axis=-1)
    hr, hz, hn = jnp.split(hg, 3, axis=-1)
    r = jax.nn.sigmoid(xr + hr)
    z = jax.nn.sigmoid(xz + hz)
    n = jnp.tanh(xn + r * hn)
    return (1 - z) * n + z * h


def lstm_init(key, d_in: int, d_hidden: int):
    k1, k2 = jax.random.split(key)
    return {
        "wx": layers.truncated_normal_init(k1, (d_in, 4 * d_hidden), 1.0),
        "wh": layers.truncated_normal_init(k2, (d_hidden, 4 * d_hidden),
                                           1.0),
        "b": jnp.zeros((4 * d_hidden,), jnp.float32),
    }


def lstm_cell(params, carry, x):
    h, c = carry
    gates = x @ params["wx"] + h @ params["wh"] + params["b"]
    i, f, g, o = jnp.split(gates, 4, axis=-1)
    i, f, o = jax.nn.sigmoid(i), jax.nn.sigmoid(f + 1.0), jax.nn.sigmoid(o)
    c = f * c + i * jnp.tanh(g)
    h = o * jnp.tanh(c)
    return (h, c)


def rnn_lm_init(key, cell: str, d_in: int, d_hidden: int, d_out: int):
    k1, k2, k3 = jax.random.split(key, 3)
    init = gru_init if cell == "gru" else lstm_init
    return {
        "in_proj": layers.dense_init(k1, d_in, d_hidden, bias=True),
        "cell": init(k2, d_hidden, d_hidden),
        "out": layers.dense_init(k3, d_hidden, d_out, bias=True),
    }


def rnn_lm_apply(params, cell: str, x_seq: jnp.ndarray) -> jnp.ndarray:
    """x_seq: (B, T, d_in) encoded inputs -> logits (B, T, d_out)."""
    B, T, _ = x_seq.shape
    x_seq = layers.dense(params["in_proj"], x_seq)
    d_h = x_seq.shape[-1]
    if cell == "gru":
        carry0 = jnp.zeros((B, d_h), x_seq.dtype)

        def step(h, x):
            h = gru_cell(params["cell"], h, x)
            return h, h
    else:
        carry0 = (jnp.zeros((B, d_h), x_seq.dtype),
                  jnp.zeros((B, d_h), x_seq.dtype))

        def step(c, x):
            c = lstm_cell(params["cell"], c, x)
            return c, c[0]

    _, hs = jax.lax.scan(step, carry0, x_seq.transpose(1, 0, 2))
    hs = hs.transpose(1, 0, 2)                       # (B, T, d_h)
    return layers.dense(params["out"], hs)


def rnn_lm_last_logits(params, cell: str, x_seq: jnp.ndarray) -> jnp.ndarray:
    return rnn_lm_apply(params, cell, x_seq)[:, -1]
