"""Decoder-only LM assembly: dense / MoE / SSM / hybrid families.

Layers are grouped into *super-blocks* of `period` sub-layers (period = 1
for homogeneous stacks, 8 for jamba's 1-attn:7-mamba interleave) and the
super-block stack is traversed with lax.scan over stacked weights —
HLO size and compile time are O(1) in depth (MaxText-style), and the remat
policy wraps exactly one super-block.

Modes:
  train    — full sequence, no caches (loss handled by the caller).
  prefill  — full sequence, emits decode caches + all-position logits.
  decode   — one token against caches at position `pos`.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention, io, layers, mamba2, moe


# --------------------------------------------------------------------------
# Layer-role layout
# --------------------------------------------------------------------------

def period_of(cfg: ModelConfig) -> int:
    p = cfg.attn_layer_period if cfg.attn_layer_period > 0 else 1
    q = cfg.moe_layer_period if cfg.moe is not None else 1
    return math.lcm(p, q)


def sublayer_roles(cfg: ModelConfig):
    """[(mixer, ffn)] for one period. mixer: attn|mamba; ffn: dense|moe|none."""
    roles = []
    for j in range(period_of(cfg)):
        mixer = "attn" if cfg._layer_is_attention(j) else "mamba"
        if cfg._layer_is_moe(j):
            ffn = "moe"
        elif cfg.d_ff > 0:
            ffn = "dense"
        else:
            ffn = "none"
        roles.append((mixer, ffn))
    return roles


def num_superblocks(cfg: ModelConfig) -> int:
    p = period_of(cfg)
    assert cfg.num_layers % p == 0, (
        f"num_layers {cfg.num_layers} must divide into period {p}")
    return cfg.num_layers // p


# --------------------------------------------------------------------------
# Init
# --------------------------------------------------------------------------

def _sublayer_init(key, cfg: ModelConfig, j: int):
    mixer, ffn = sublayer_roles(cfg)[j]
    ks = jax.random.split(key, 3)
    p: Dict[str, Any] = {"norm1": layers.rms_norm_init(cfg.d_model)}
    if mixer == "attn":
        p["attn"] = attention.attention_init(ks[0], cfg)
    else:
        p["mamba"] = mamba2.mamba_init(ks[0], cfg)
    if ffn != "none":
        p["norm2"] = layers.rms_norm_init(cfg.d_model)
        p["ffn"] = (moe.moe_init(ks[1], cfg) if ffn == "moe"
                    else layers.swiglu_init(ks[1], cfg.d_model, cfg.d_ff))
    return p


def superblock_init(key, cfg: ModelConfig):
    p = period_of(cfg)
    ks = jax.random.split(key, p)
    return {f"sub{j}": _sublayer_init(ks[j], cfg, j) for j in range(p)}


def lm_init(key, cfg: ModelConfig):
    k_io, k_blocks, k_front = jax.random.split(key, 3)
    n_super = num_superblocks(cfg)
    block_keys = jax.random.split(k_blocks, n_super)
    params = {
        "io": io.io_init(k_io, cfg),
        "blocks": jax.vmap(lambda k: superblock_init(k, cfg))(block_keys),
        "final_norm": layers.rms_norm_init(cfg.d_model),
    }
    if cfg.frontend != "none":
        params["frontend_proj"] = layers.dense_init(
            k_front, cfg.d_model, cfg.d_model, bias=False)
    return params


# --------------------------------------------------------------------------
# Apply
# --------------------------------------------------------------------------

def _sublayer_apply(p, cfg: ModelConfig, j: int, x, positions, mode,
                    cache, pos, dist):
    mixer, ffn = sublayer_roles(cfg)[j]
    aux = jnp.zeros((), jnp.float32)
    new_cache = {}
    h = layers.rms_norm(p["norm1"], x, cfg.norm_eps)
    if mixer == "attn":
        if mode == "train":
            y = attention.self_attention(p["attn"], cfg, h, positions)
        elif mode == "prefill":
            y, kv = attention.self_attention_with_cache(
                p["attn"], cfg, h, positions, cache_dtype=h.dtype)
            new_cache["attn"] = kv
        else:
            y, kv = attention.decode_self_attention(
                p["attn"], cfg, h, cache["attn"], pos, dist=dist)
            new_cache["attn"] = kv
    else:
        if mode == "train":
            y = mamba2.mamba_apply(p["mamba"], cfg, h)
        elif mode == "prefill":
            y, mc = mamba2.mamba_apply(p["mamba"], cfg, h,
                                       return_cache=True)
            new_cache["mamba"] = mc
        else:
            y, mc = mamba2.mamba_decode_step(p["mamba"], cfg, h,
                                             cache["mamba"])
            new_cache["mamba"] = mc
    x = x + y
    if dist is not None:
        x = dist.constrain_tokens(x)
    if ffn != "none":
        h = layers.rms_norm(p["norm2"], x, cfg.norm_eps)
        if ffn == "moe":
            y, aux = moe.moe_apply(p["ffn"], h, cfg, dist)
        else:
            y = layers.swiglu(p["ffn"], h)
        x = x + y
        if dist is not None:
            x = dist.constrain_tokens(x)
    return x, new_cache, aux


def _superblock_apply(bp, cfg: ModelConfig, x, positions, mode, cache,
                      pos, dist):
    auxes = jnp.zeros((), jnp.float32)
    new_caches = {}
    for j in range(period_of(cfg)):
        sub_c = cache.get(f"sub{j}") if cache is not None else None
        x, nc, aux = _sublayer_apply(bp[f"sub{j}"], cfg, j, x, positions,
                                     mode, sub_c, pos, dist)
        if nc:
            new_caches[f"sub{j}"] = nc
        auxes = auxes + aux
    return x, new_caches, auxes


def _remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    else:
        policy = jax.checkpoint_policies.nothing_saveable
    return jax.checkpoint(fn, policy=policy)


def init_lm_cache(cfg: ModelConfig, batch: int, cache_len: int,
                  dtype=jnp.bfloat16):
    """Zeroed decode caches, stacked (n_super, ...) to match scanned blocks."""
    sb = {}
    for j, (mixer, _) in enumerate(sublayer_roles(cfg)):
        if mixer == "attn":
            sb[f"sub{j}"] = {"attn": attention.init_kv_cache(
                cfg, batch, cache_len, dtype)}
        else:
            sb[f"sub{j}"] = {"mamba": mamba2.init_mamba_cache(
                cfg, batch, dtype)}
    n = num_superblocks(cfg)
    return jax.tree.map(lambda a: jnp.zeros((n, *a.shape), a.dtype), sb)


def _frontend_concat(params, cfg: ModelConfig, x_tokens, embeds):
    if embeds is None:
        return x_tokens
    pre = layers.dense(params["frontend_proj"],
                       embeds.astype(x_tokens.dtype))
    return jnp.concatenate([pre, x_tokens], axis=1)


def lm_apply(params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray],
             mode: str = "train", caches=None, pos=None, dist=None):
    """Run the LM.

    batch: {"tokens": (B, S)} plus optional {"embeds": (B, S_emb, D)} for
    vlm/audio stub frontends.  Returns a dict:
      train   -> {logits (B, S_tot, m_vocab), aux}
      prefill -> {logits, aux, caches}
      decode  -> {logits (B, 1, m_vocab), aux, caches}   (needs caches+pos)

    decode `pos` is a scalar (static batch) or a (B,) vector of per-slot
    sequence offsets (continuous-batching slot pool — one compiled step
    serves slots at different positions; SSM caches are offset-free so
    only the attention cache write/mask depends on it).
    """
    tokens = batch["tokens"]
    x = io.embed_tokens(params["io"], cfg, tokens)
    x = _frontend_concat(params, cfg, x, batch.get("embeds"))
    B, S_tot = x.shape[:2]
    if mode == "decode":
        assert caches is not None and pos is not None
        positions = None
    else:
        positions = jnp.broadcast_to(jnp.arange(S_tot)[None], (B, S_tot))
    if dist is not None:
        x = dist.constrain_tokens(x)

    block = _remat(
        lambda bp, x, c: _superblock_apply(bp, cfg, x, positions, mode, c,
                                           pos, dist),
        cfg) if mode == "train" else (
        lambda bp, x, c: _superblock_apply(bp, cfg, x, positions, mode, c,
                                           pos, dist))

    if cfg.scan_layers:
        def body(carry, inp):
            x, aux = carry
            bp, c = inp
            x, nc, a = block(bp, x, c)
            return (x, aux + a), nc

        xs = (params["blocks"], caches)
        (x, aux), new_caches = jax.lax.scan(body, (x, 0.0), xs)
    else:
        n = num_superblocks(cfg)
        aux = jnp.zeros((), jnp.float32)
        ncs = []
        for i in range(n):
            bp = jax.tree.map(lambda a: a[i], params["blocks"])
            c = (None if caches is None
                 else jax.tree.map(lambda a: a[i], caches))
            x, nc, a = block(bp, x, c)
            aux = aux + a
            ncs.append(nc)
        new_caches = (jax.tree.map(lambda *a: jnp.stack(a), *ncs)
                      if ncs and ncs[0] else None)

    x = layers.rms_norm(params["final_norm"], x, cfg.norm_eps)
    logits = io.lm_logits(params["io"], cfg, x)
    if dist is not None:
        logits = dist.constrain_logits(logits)
    out = {"logits": logits, "aux": aux}
    if mode in ("prefill", "decode"):
        out["caches"] = new_caches
    return out


def lm_loss_fn(params, cfg: ModelConfig, batch, dist=None):
    """Next-token CE (+ MoE aux). batch: tokens (B,S), optional embeds,
    optional loss_mask (B, S-1)."""
    out = lm_apply(params, cfg, batch, mode="train", dist=dist)
    logits = out["logits"]
    tokens = batch["tokens"]
    n_front = logits.shape[1] - tokens.shape[1]
    logits = logits[:, n_front:]                    # text region only
    shift_logits = logits[:, :-1]
    if dist is not None:
        shift_logits = dist.constrain_logits(shift_logits)
    shift_labels = tokens[:, 1:]
    valid = batch.get("loss_mask")
    loss_tok = io.lm_loss(params["io"], cfg, shift_logits, shift_labels,
                          valid)
    denom = (valid.sum() if valid is not None
             else jnp.asarray(loss_tok.size, jnp.float32))
    loss = loss_tok.sum() / jnp.maximum(denom, 1.0)
    aux_w = cfg.moe.aux_loss_weight if cfg.moe is not None else 0.0
    total = loss + aux_w * out["aux"] / max(num_superblocks(cfg), 1)
    return total, {"ce": loss, "aux": out["aux"]}


def lm_prefill(params, cfg: ModelConfig, batch, dist=None):
    return lm_apply(params, cfg, batch, mode="prefill", dist=dist)


def lm_decode_step(params, cfg: ModelConfig, token, caches, pos, dist=None,
                   topk: int = 0):
    """token: (B, 1) -> next-token logits; optional vocab recovery.

    With topk > 0 also returns the paper's Eq. 3 top-k recovery over the
    original vocab (the serving path measured in Fig. 3 right).
    """
    out = lm_apply(params, cfg, {"tokens": token}, mode="decode",
                   caches=caches, pos=pos, dist=dist)
    if topk:
        scores, ids = io.recover_topk(cfg, out["logits"][:, 0], topk=topk)
        out["topk_scores"], out["topk_ids"] = scores, ids
    return out
