"""Shared neural building blocks (pure-functional, pytree params).

Every module is an (init, apply) pair.  Params are plain dicts of jnp
arrays; a parallel tree of jax.sharding.PartitionSpec is produced by
repro.launch.sharding.  Compute dtype is configurable (bf16 on TPU),
params stay fp32.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def truncated_normal_init(key, shape, scale: float, dtype=jnp.float32):
    """Fan-in-scaled truncated normal (stddev = scale / sqrt(fan_in))."""
    fan_in = shape[0] if len(shape) >= 1 else 1
    std = scale / np.sqrt(max(fan_in, 1))
    return std * jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype)


def embed_init(key, shape, dtype=jnp.float32):
    return jax.random.normal(key, shape, dtype) * 0.02


# --------------------------------------------------------------------------
# Norms
# --------------------------------------------------------------------------

def rms_norm_init(d: int):
    return {"scale": jnp.ones((d,), jnp.float32)}


def rms_norm(params, x, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * params["scale"]).astype(dt)


def layer_norm_init(d: int):
    return {"scale": jnp.ones((d,), jnp.float32),
            "bias": jnp.zeros((d,), jnp.float32)}


def layer_norm(params, x, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = x.mean(-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * params["scale"] + params["bias"]).astype(dt)


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponent)                   # (head_dim/2,)


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float) -> jnp.ndarray:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)                # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    sin = jnp.sin(angles)[..., None, :]                # (..., S, 1, hd/2)
    cos = jnp.cos(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# MLPs
# --------------------------------------------------------------------------

def swiglu_init(key, d_model: int, d_ff: int):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": truncated_normal_init(k1, (d_model, d_ff), 1.0),
        "w_up": truncated_normal_init(k2, (d_model, d_ff), 1.0),
        "w_down": truncated_normal_init(k3, (d_ff, d_model), 1.0),
    }


def swiglu(params, x, dtype=None):
    dt = dtype or x.dtype
    g = x @ params["w_gate"].astype(dt)
    u = x @ params["w_up"].astype(dt)
    return (jax.nn.silu(g) * u) @ params["w_down"].astype(dt)


def dense_init(key, d_in: int, d_out: int, bias: bool = True,
               scale: float = 1.0):
    p = {"w": truncated_normal_init(key, (d_in, d_out), scale)}
    if bias:
        p["b"] = jnp.zeros((d_out,), jnp.float32)
    return p


def dense(params, x, dtype=None):
    dt = dtype or x.dtype
    y = x @ params["w"].astype(dt)
    if "b" in params:
        y = y + params["b"].astype(dt)
    return y
