"""GQA attention: memory-efficient chunked (flash-style) training path,
cached decode path, cross-attention, and a naive oracle.

Adaptation notes (DESIGN.md §4): on TPU we never materialize the (S, T)
score matrix for long sequences — the chunked path scans kv-blocks with a
running (max, sum, acc) triple, giving O(S·chunk) live memory under remat.
`causal_skip=True` switches to a statically-unrolled q-chunk loop whose
kv extent grows triangularly, removing the ~2x masked-FLOP waste of the
rectangle+mask formulation (a §Perf hillclimb lever).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import layers

NEG_INF = -1e30


def attention_init(key, cfg: ModelConfig, cross: bool = False):
    D = cfg.d_model
    hd = cfg.resolved_head_dim
    H, KV = cfg.num_heads, cfg.num_kv_heads
    ks = jax.random.split(key, 4)
    p = {
        "wq": layers.truncated_normal_init(ks[0], (D, H * hd), 1.0)
        .reshape(D, H, hd),
        "wk": layers.truncated_normal_init(ks[1], (D, KV * hd), 1.0)
        .reshape(D, KV, hd),
        "wv": layers.truncated_normal_init(ks[2], (D, KV * hd), 1.0)
        .reshape(D, KV, hd),
        "wo": layers.truncated_normal_init(ks[3], (H * hd, D), 1.0)
        .reshape(H, hd, D),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H, hd), jnp.float32)
        p["bk"] = jnp.zeros((KV, hd), jnp.float32)
        p["bv"] = jnp.zeros((KV, hd), jnp.float32)
    if cfg.qk_norm:
        p["q_norm"] = layers.rms_norm_init(hd)
        p["k_norm"] = layers.rms_norm_init(hd)
    return p


def _project_qkv(params, cfg: ModelConfig, xq, xkv, q_pos, kv_pos,
                 rope: bool):
    dt = xq.dtype
    q = jnp.einsum("bsd,dhk->bshk", xq, params["wq"].astype(dt))
    k = jnp.einsum("btd,dhk->bthk", xkv, params["wk"].astype(dt))
    v = jnp.einsum("btd,dhk->bthk", xkv, params["wv"].astype(dt))
    if cfg.qkv_bias:
        q = q + params["bq"].astype(dt)
        k = k + params["bk"].astype(dt)
        v = v + params["bv"].astype(dt)
    if cfg.qk_norm:
        q = layers.rms_norm(params["q_norm"], q, cfg.norm_eps)
        k = layers.rms_norm(params["k_norm"], k, cfg.norm_eps)
    if rope and cfg.use_rope:
        q = layers.apply_rope(q, q_pos, cfg.rope_theta)
        k = layers.apply_rope(k, kv_pos, cfg.rope_theta)
    return q, k, v


def _gqa_split(q, num_kv: int):
    """(B, S, H, hd) -> (B, S, KV, G, hd) with G = H // KV."""
    B, S, H, hd = q.shape
    return q.reshape(B, S, num_kv, H // num_kv, hd)


def _expand_heads(q, k, v, num_heads: int):
    """GQA -> MHA layout that PRESERVES tensor-parallel head sharding.

    §Perf iteration (qwen3-4b train_4k): reshaping q (B,S,H,hd) ->
    (B,S,KV,G,hd) splits the sharded H dim into two dims (8,4) neither of
    which divides a 16-way model axis, so GSPMD replicated every attention
    inner tensor on all devices (measured: ~2x HLO FLOPs, dominant memory
    term).  Repeating k/v to the full H count keeps the flat, shardable H
    dim on every attention operand; the repeat itself is a cheap broadcast
    of the small kv tensors.

    Returns q (B,S,H,1,hd), k/v (B,T,H,hd).
    """
    B, S, H, hd = q.shape
    rep = num_heads // k.shape[2]
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    return q.reshape(B, S, H, 1, hd), k, v


def naive_attention(q, k, v, *, causal: bool, q_pos=None, kv_pos=None,
                    kv_valid=None):
    """Oracle: materializes full scores. q:(B,S,KV,G,hd), k/v:(B,T,KV,hd)."""
    hd = q.shape[-1]
    scores = jnp.einsum("bskgh,btkh->bskgt", q, k) / np.sqrt(hd)
    scores = scores.astype(jnp.float32)
    if causal:
        mask = q_pos[:, :, None, None, None] >= kv_pos[:, None, None, None, :]
        scores = jnp.where(mask, scores, NEG_INF)
    if kv_valid is not None:
        scores = jnp.where(kv_valid[:, None, None, None, :], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bskgt,btkh->bskgh", w, v)


def _chunk_accumulate(q, k_c, v_c, m, l, acc, mask_c,
                      bf16_scores: bool = False):
    """One flash-style accumulation step over a kv chunk.

    bf16_scores=True keeps the (S, Ck) score/probability chain in bf16
    (flash2-style: running max/sum/acc stats stay f32) — halves the
    dominant HBM traffic of score-bound cells (§Perf whisper prefill);
    validated to ~2e-2 vs the f32 oracle.
    """
    hd = q.shape[-1]
    s = jnp.einsum("bskgh,bckh->bskgc", q, k_c) / np.sqrt(hd)
    sdt = q.dtype if bf16_scores else jnp.float32
    neg = jnp.asarray(NEG_INF if sdt == jnp.float32 else -3e38, sdt)
    if mask_c is None:          # §Perf: non-causal unpadded fast path —
        s = s.astype(sdt)           # no (B,S,H,1,Ck) mask broadcast/select
    else:
        s = jnp.where(mask_c, s.astype(sdt), neg)
    m_new = jnp.maximum(m, s.max(-1).astype(jnp.float32))
    p = jnp.exp(s - m_new[..., None].astype(sdt))
    corr = jnp.exp(m - m_new)
    l_new = l * corr + p.sum(-1).astype(jnp.float32)
    acc_new = acc * corr[..., None] + jnp.einsum(
        "bskgc,bckh->bskgh", p.astype(q.dtype), v_c).astype(jnp.float32)
    return m_new, l_new, acc_new


def _blockify(k, v, kv_pos, kv_valid, chunk_k):
    """Pad + reshape kv tensors into (n_chunks, B, Ck, ...) blocks.

    kv_valid may be None (= everything valid); padding forces it back."""
    B, T, KV, hd = k.shape
    Ck = min(chunk_k, T)
    n_c = -(-T // Ck)
    pad = n_c * Ck - T
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        if kv_valid is None:
            kv_valid = jnp.ones(kv_pos.shape, bool)
        kv_pos = jnp.pad(kv_pos, ((0, 0), (0, pad)), constant_values=2**30)
        kv_valid = jnp.pad(kv_valid, ((0, 0), (0, pad)))
    kc = k.reshape(B, n_c, Ck, KV, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n_c, Ck, KV, hd).transpose(1, 0, 2, 3, 4)
    pc = kv_pos.reshape(B, n_c, Ck).transpose(1, 0, 2)
    valc = (kv_valid.reshape(B, n_c, Ck).transpose(1, 0, 2)
            if kv_valid is not None else None)
    return kc, vc, pc, valc, n_c, Ck, pad


def _mask_for(causal, q_pos, p_c, v_ok):
    if v_ok is None and not causal:
        return None
    ok = jnp.ones_like(p_c, bool) if v_ok is None else v_ok
    mask = ok[:, None, None, None, :]
    if causal:
        mask = mask & (q_pos[:, :, None, None, None]
                       >= p_c[:, None, None, None, :])
    return mask


def _flash_fwd_scan(q, kc, vc, pc, valc, q_pos, causal, unroll,
                    bf16_scores=False):
    B, S, KV, G, hd = q.shape
    m0 = jnp.full((B, S, KV, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros_like(m0)
    a0 = jnp.zeros((*m0.shape, hd), jnp.float32)

    def body(carry, blk):
        m, l, acc = carry
        k_c, v_c, p_c, v_ok = blk
        mask = _mask_for(causal, q_pos, p_c, v_ok)
        return _chunk_accumulate(q, k_c, v_c, m, l, acc, mask,
                                 bf16_scores), None

    blks = ((kc, vc, pc, valc) if valc is not None
            else (kc, vc, pc, None))
    if unroll:
        carry = (m0, l0, a0)
        for i in range(kc.shape[0]):
            carry, _ = body(carry, (kc[i], vc[i], pc[i],
                                    None if valc is None else valc[i]))
        m, l, acc = carry
    else:
        (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), blks)
    out = (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)
    lse = jnp.where(l > 0, m + jnp.log(jnp.maximum(l, 1e-30)), jnp.inf)
    return out, lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(6, 7, 8, 9))
def _flash_attention(q, k, v, q_pos, kv_pos, kv_valid, causal, chunk_k,
                     unroll, bf16_scores=False):
    """Memory-efficient attention with a flash-style *backward*.

    Plain autodiff of the forward scan makes XLA store every chunk's
    attention probabilities ((S, Ck) per step, all steps live at once in
    the scan-reverse) — measured 17 GiB/device at 4k and O(70 GiB) at 32k
    prefill.  The custom VJP recomputes p per chunk from the saved
    (out, lse), so live memory is O(S*(hd + Ck)).
    """
    out, _ = _flash_attention_fwd(q, k, v, q_pos, kv_pos, kv_valid, causal,
                                  chunk_k, unroll, bf16_scores)
    return out


def _flash_attention_fwd(q, k, v, q_pos, kv_pos, kv_valid, causal, chunk_k,
                         unroll, bf16_scores=False):
    kc, vc, pc, valc, *_ = _blockify(k, v, kv_pos, kv_valid, chunk_k)
    out, lse = _flash_fwd_scan(q, kc, vc, pc, valc, q_pos, causal, unroll,
                               bf16_scores)
    return out, (q, k, v, q_pos, kv_pos, kv_valid, out, lse)


def _flash_attention_bwd(causal, chunk_k, unroll, bf16_scores, res, do):
    q, k, v, q_pos, kv_pos, kv_valid, out, lse = res
    B, T, KV, hd = k.shape
    kc, vc, pc, valc, n_c, Ck, pad = _blockify(k, v, kv_pos, kv_valid,
                                               chunk_k)
    scale = 1.0 / np.sqrt(hd)
    do32 = do.astype(jnp.float32)
    delta = (do32 * out.astype(jnp.float32)).sum(-1)      # (B,S,KV,G)
    dq0 = jnp.zeros(q.shape, jnp.float32)

    def body(dq, blk):
        k_c, v_c, p_c, v_ok = blk
        mask = _mask_for(causal, q_pos, p_c, v_ok)
        sdt = q.dtype if bf16_scores else jnp.float32
        neg = NEG_INF if sdt == jnp.float32 else -3e38
        s = jnp.einsum("bskgh,bckh->bskgc", q, k_c) * scale
        if mask is None:
            s = s.astype(sdt)
        else:
            s = jnp.where(mask, s.astype(sdt), jnp.asarray(neg, sdt))
        p = jnp.exp((s - lse[..., None].astype(sdt)).astype(jnp.float32))
        pb = p.astype(q.dtype)
        dv_c = jnp.einsum("bskgc,bskgh->bckh", pb, do)
        dp = jnp.einsum("bskgh,bckh->bskgc", do, v_c).astype(jnp.float32)
        ds = (p * (dp - delta[..., None]) * scale).astype(q.dtype)
        dq = dq + jnp.einsum("bskgc,bckh->bskgh", ds,
                             k_c).astype(jnp.float32)
        dk_c = jnp.einsum("bskgc,bskgh->bckh", ds, q)
        return dq, (dk_c, dv_c)

    if unroll:
        dq, dks, dvs = dq0, [], []
        for i in range(n_c):
            dq, (dk_c, dv_c) = body(dq, (kc[i], vc[i], pc[i],
                                         None if valc is None
                                         else valc[i]))
            dks.append(dk_c)
            dvs.append(dv_c)
        dkc, dvc = jnp.stack(dks), jnp.stack(dvs)
    else:
        dq, (dkc, dvc) = jax.lax.scan(body, dq0, (kc, vc, pc, valc))
    dk = dkc.transpose(1, 0, 2, 3, 4).reshape(B, n_c * Ck, KV, hd)
    dv = dvc.transpose(1, 0, 2, 3, 4).reshape(B, n_c * Ck, KV, hd)
    if pad:
        dk, dv = dk[:, :T], dv[:, :T]
    return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype),
            None, None, None)


_flash_attention.defvjp(_flash_attention_fwd, _flash_attention_bwd)


def chunked_attention(q, k, v, *, causal: bool, chunk_k: int,
                      q_pos, kv_pos, kv_valid=None, unroll: bool = False,
                      bf16_scores: bool = False):
    """Flash-style attention: kv-chunk streaming softmax forward + flash
    backward (custom VJP — see _flash_attention).

    q: (B, S, KV, G, hd); k, v: (B, T, KV, hd).  Never materializes (S, T).
    unroll=True replaces lax.scan with a static loop (dry-run analysis
    mode: XLA cost_analysis counts while bodies once).
    """
    return _flash_attention(q, k, v, q_pos, kv_pos, kv_valid, causal,
                            chunk_k, unroll, bf16_scores)


def chunked_attention_causal_skip(q, k, v, *, chunk_q: int, chunk_k: int,
                                  q_pos, kv_pos, kv_valid=None,
                                  unroll: bool = False):
    """Triangular chunked attention: static q-chunk loop, each q-chunk only
    scans kv up to its own end — saving the ~2x masked-FLOP waste.

    Requires q and kv to be position-aligned (self-attention, q_pos ==
    kv_pos), the standard train/prefill case.
    """
    B, S = q.shape[:2]
    Cq = min(chunk_q, S)
    n_q = -(-S // Cq)
    assert n_q * Cq == S, "causal_skip path requires S % chunk_q == 0"
    outs = []
    for i in range(n_q):
        sl = slice(i * Cq, (i + 1) * Cq)
        kv_end = (i + 1) * Cq
        outs.append(chunked_attention(
            q[:, sl], k[:, :kv_end], v[:, :kv_end], causal=True,
            chunk_k=chunk_k, q_pos=q_pos[:, sl], kv_pos=kv_pos[:, :kv_end],
            kv_valid=None if kv_valid is None else kv_valid[:, :kv_end],
            unroll=unroll))
    return jnp.concatenate(outs, axis=1)


def self_attention(params, cfg: ModelConfig, x, positions,
                   valid: Optional[jnp.ndarray] = None,
                   causal: bool = True):
    """Full-sequence self-attention (train / prefill)."""
    q, k, v = _project_qkv(params, cfg, x, x, positions, positions,
                           rope=True)
    qg, k, v = _expand_heads(q, k, v, cfg.num_heads)
    if cfg.attn_impl == "naive":
        o = naive_attention(qg, k, v, causal=causal, q_pos=positions,
                            kv_pos=positions, kv_valid=valid)
    elif causal and cfg.causal_skip:
        o = chunked_attention_causal_skip(
            qg, k, v, chunk_q=cfg.attn_chunk_q, chunk_k=cfg.attn_chunk_k,
            q_pos=positions, kv_pos=positions, kv_valid=valid,
            unroll=cfg.unroll_for_analysis)
    else:
        o = chunked_attention(qg, k, v, causal=causal,
                              chunk_k=cfg.attn_chunk_k, q_pos=positions,
                              kv_pos=positions, kv_valid=valid,
                              unroll=cfg.unroll_for_analysis,
                              bf16_scores=cfg.attn_bf16_scores)
    B, S = x.shape[:2]
    o = o.reshape(B, S, cfg.num_heads, cfg.resolved_head_dim)
    return jnp.einsum("bshk,hkd->bsd", o, params["wo"].astype(x.dtype))


def self_attention_with_cache(params, cfg: ModelConfig, x, positions,
                              valid: Optional[jnp.ndarray] = None,
                              cache_dtype=jnp.bfloat16):
    """Prefill: full causal self-attention that also emits the KV cache."""
    q, k, v = _project_qkv(params, cfg, x, x, positions, positions,
                           rope=True)
    kv_k, kv_v = k, v                   # cache stores the compact GQA kv
    qg, k, v = _expand_heads(q, k, v, cfg.num_heads)
    if cfg.causal_skip:
        o = chunked_attention_causal_skip(
            qg, k, v, chunk_q=cfg.attn_chunk_q, chunk_k=cfg.attn_chunk_k,
            q_pos=positions, kv_pos=positions, kv_valid=valid,
            unroll=cfg.unroll_for_analysis)
    else:
        o = chunked_attention(qg, k, v, causal=True,
                              chunk_k=cfg.attn_chunk_k, q_pos=positions,
                              kv_pos=positions, kv_valid=valid,
                              unroll=cfg.unroll_for_analysis,
                              bf16_scores=cfg.attn_bf16_scores)
    B, S = x.shape[:2]
    o = o.reshape(B, S, cfg.num_heads, cfg.resolved_head_dim)
    out = jnp.einsum("bshk,hkd->bsd", o, params["wo"].astype(x.dtype))
    return out, {"k": kv_k.astype(cache_dtype),
                 "v": kv_v.astype(cache_dtype)}


def cross_attention(params, cfg: ModelConfig, x, kv_x, q_positions,
                    kv_valid: Optional[jnp.ndarray] = None):
    """Encoder-decoder cross attention (whisper). No RoPE, no causality."""
    B, T = kv_x.shape[:2]
    kv_pos = jnp.broadcast_to(jnp.arange(T)[None], (B, T))
    q, k, v = _project_qkv(params, cfg, x, kv_x, q_positions, kv_pos,
                           rope=False)
    qg, k, v = _expand_heads(q, k, v, cfg.num_heads)
    o = chunked_attention(qg, k, v, causal=False, chunk_k=cfg.attn_chunk_k,
                          q_pos=q_positions, kv_pos=kv_pos,
                          kv_valid=kv_valid,
                          unroll=cfg.unroll_for_analysis,
                          bf16_scores=cfg.attn_bf16_scores)
    S = x.shape[1]
    o = o.reshape(B, S, cfg.num_heads, cfg.resolved_head_dim)
    return jnp.einsum("bshk,hkd->bsd", o, params["wo"].astype(x.dtype))


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int,
                  dtype=jnp.bfloat16):
    hd = cfg.resolved_head_dim
    return {
        "k": jnp.zeros((batch, max_len, cfg.num_kv_heads, hd), dtype),
        "v": jnp.zeros((batch, max_len, cfg.num_kv_heads, hd), dtype),
    }


def decode_self_attention(params, cfg: ModelConfig, x, cache, pos,
                          dist=None):
    """Single-token decode against a KV cache.

    x: (B, 1, D); cache: {"k","v"} (B, T, KV, hd); pos: position of the
    new token (cache entries < pos are valid) — either a scalar int32
    (static batch: all rows at the same offset) or a (B,) int32 vector
    (continuous-batching slot pool: every slot decodes at its own
    sequence offset inside ONE compiled step).
    Returns (out (B, 1, D), new_cache).
    """
    B, _, D = x.shape
    T = cache["k"].shape[1]
    pos = jnp.asarray(pos)
    per_slot = pos.ndim == 1
    posb = (pos[:, None] if per_slot
            else jnp.broadcast_to(pos[None, None], (B, 1)))
    q, k_new, v_new = _project_qkv(params, cfg, x, x, posb, posb, rope=True)
    seq_sharded = (dist is not None
                   and cfg.num_kv_heads % dist.n_model != 0
                   and cfg.num_heads % dist.n_model != 0)
    if seq_sharded:
        # cache is SEQUENCE-sharded over `model` (no shardable head dim,
        # e.g. whisper); q must not carry head sharding on the same axis
        # or GSPMD moves the multi-GB cache.  Replicating the
        # single-token q costs one small wq gather — §Perf finding.
        from jax.sharding import PartitionSpec as P
        bx = dist.batch_spec_axes(B)
        rep = lambda a: dist.constrain(  # noqa: E731
            a, P(bx, *([None] * (a.ndim - 1))))
        q, k_new, v_new = rep(q), rep(k_new), rep(v_new)
    if seq_sharded or per_slot:
        # masked (iota == pos) write: fully elementwise.  Needed when the
        # cache is sequence-sharded (a positional dynamic write makes
        # GSPMD reshard the whole multi-GB cache) and when pos is a (B,)
        # slot vector (each row writes a different offset — there is no
        # single dynamic_update_slice for that).  Writes the exact same
        # values as the slice path, so slot decode stays bit-identical to
        # static decode per row.
        sel = (jnp.arange(T)[None, :, None, None]
               == posb.reshape(B, 1, 1, 1))
        cache = {
            "k": jnp.where(sel, k_new.astype(cache["k"].dtype),
                           cache["k"]),
            "v": jnp.where(sel, v_new.astype(cache["v"].dtype),
                           cache["v"]),
        }
    else:
        # unsharded/batch-sharded cache: write exactly one position.
        cache = {
            "k": jax.lax.dynamic_update_slice_in_dim(
                cache["k"], k_new.astype(cache["k"].dtype), pos, axis=1),
            "v": jax.lax.dynamic_update_slice_in_dim(
                cache["v"], v_new.astype(cache["v"].dtype), pos, axis=1),
        }
    kv_pos = jnp.broadcast_to(jnp.arange(T)[None], (B, T))
    kv_valid = kv_pos <= posb
    qg, k_all, v_all = _expand_heads(q, cache["k"].astype(x.dtype),
                                     cache["v"].astype(x.dtype),
                                     cfg.num_heads)
    # decode reads the whole cache once -> bandwidth-bound; use the naive
    # path (scores are (B, 1, H, T) — small) so XLA fuses mask+softmax.
    o = naive_attention(qg, k_all, v_all, causal=False, q_pos=posb,
                        kv_pos=kv_pos, kv_valid=kv_valid)
    o = o.reshape(B, 1, cfg.num_heads, cfg.resolved_head_dim)
    out = jnp.einsum("bshk,hkd->bsd", o, params["wo"].astype(x.dtype))
    return out, cache
