"""Mamba-2 (SSD — state-space duality, arXiv:2405.21060) block.

TPU adaptation (DESIGN.md §4): the CUDA SSD kernel is re-expressed as the
chunked matmul decomposition the paper derives — intra-chunk quadratic
(attention-like, MXU-friendly (Q x Q) tiles) plus an inter-chunk linear
recurrence carried by lax.scan.  Chunk length is a config knob
(MambaConfig.chunk).

Tensor-parallel layout: unlike the reference CUDA impl's single fused
in_proj, projections are kept separate (z/x/B/C/dt) so the d_inner and
n_heads dimensions shard over the `model` mesh axis without splitting a
sharded dim (head_dim * heads_per_shard stays contiguous).  B/C (d_state
per group, G=1) are small and stay replicated.

Decode is the O(1)-state recurrence: h' = exp(dt*A) h + dt * B ⊗ x.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import layers


def _dims(cfg: ModelConfig):
    mc = cfg.mamba
    d_in = mc.expand * cfg.d_model
    n_heads = d_in // mc.head_dim
    gn = mc.n_groups * mc.d_state
    return mc, d_in, n_heads, gn


def mamba_init(key, cfg: ModelConfig):
    mc, d_in, n_heads, gn = _dims(cfg)
    D = cfg.d_model
    ks = jax.random.split(key, 7)
    return {
        "z_proj": layers.truncated_normal_init(ks[0], (D, d_in), 1.0),
        "x_proj": layers.truncated_normal_init(ks[1], (D, d_in), 1.0),
        "b_proj": layers.truncated_normal_init(ks[2], (D, gn), 1.0),
        "c_proj": layers.truncated_normal_init(ks[3], (D, gn), 1.0),
        "dt_proj": layers.truncated_normal_init(ks[4], (D, n_heads), 1.0),
        "conv_x": {"w": layers.truncated_normal_init(
            ks[5], (mc.d_conv, d_in), 1.0),
            "b": jnp.zeros((d_in,), jnp.float32)},
        "conv_b": {"w": layers.truncated_normal_init(
            ks[6], (mc.d_conv, gn), 1.0),
            "b": jnp.zeros((gn,), jnp.float32)},
        "conv_c": {"w": layers.truncated_normal_init(
            jax.random.fold_in(ks[6], 1), (mc.d_conv, gn), 1.0),
            "b": jnp.zeros((gn,), jnp.float32)},
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, n_heads)),
        "D": jnp.ones((n_heads,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(jnp.exp(jax.random.uniform(
            jax.random.fold_in(ks[5], 1), (n_heads,),
            minval=np.log(1e-3), maxval=np.log(1e-1))))),
        "norm": layers.rms_norm_init(d_in),
        "out_proj": layers.truncated_normal_init(
            jax.random.fold_in(key, 99), (d_in, D), 1.0),
    }


def _causal_conv(conv, x, dtype):
    """Depthwise causal conv via shifted adds (d_conv is tiny)."""
    w, b = conv["w"], conv["b"]
    d_conv = w.shape[0]
    out = x * w[-1].astype(dtype)
    for i in range(1, d_conv):
        shifted = jnp.pad(x, ((0, 0), (i, 0), (0, 0)))[:, :-i]
        out = out + shifted * w[-1 - i].astype(dtype)
    return jax.nn.silu(out + b.astype(dtype))


def _ssd_chunked(x, dt, A, Bm, Cm, chunk: int, h0=None):
    """Chunked SSD scan.

    x:  (B, S, H, P)    head inputs
    dt: (B, S, H)       positive step sizes (softplus applied)
    A:  (H,)            negative decay rates
    Bm: (B, S, G, N)    input projections  (broadcast over H//G heads)
    Cm: (B, S, G, N)    output projections
    Returns (y (B,S,H,P), h_final (B,H,N,P)).
    """
    Bsz, S, H, Pd = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    Q = min(chunk, S)
    nc = -(-S // Q)
    assert nc * Q == S, f"seq {S} must be divisible by chunk {Q}"

    xc = x.reshape(Bsz, nc, Q, H, Pd)
    dtc = dt.reshape(Bsz, nc, Q, H)
    Bc = jnp.repeat(Bm.reshape(Bsz, nc, Q, G, N), rep, axis=3)
    Cc = jnp.repeat(Cm.reshape(Bsz, nc, Q, G, N), rep, axis=3)

    dA = dtc * A                                  # (B,nc,Q,H), negative
    cs = jnp.cumsum(dA, axis=2)                   # within-chunk cumulative
    total = cs[:, :, -1]                          # (B,nc,H)

    # intra-chunk quadratic: y_i += sum_{j<=i} (C_i.B_j) e^{cs_i-cs_j} dt_j x_j
    decay = cs[:, :, :, None, :] - cs[:, :, None, :, :]       # (B,nc,Qi,Qj,H)
    causal = jnp.tril(jnp.ones((Q, Q), bool))[None, None, :, :, None]
    L = jnp.where(causal, jnp.exp(decay), 0.0)
    scores = jnp.einsum("bcihn,bcjhn->bcijh", Cc, Bc) * L.astype(x.dtype)
    y = jnp.einsum("bcijh,bcjh,bcjhp->bcihp", scores,
                   dtc.astype(x.dtype), xc)

    # chunk summary state: S_c = sum_j e^{total - cs_j} dt_j B_j ⊗ x_j
    w = jnp.exp(total[:, :, None] - cs) * dtc                  # (B,nc,Q,H)
    chunk_states = jnp.einsum("bcjh,bcjhn,bcjhp->bchnp",
                              w.astype(x.dtype), Bc, xc)

    # inter-chunk recurrence over nc
    if h0 is None:
        h0 = jnp.zeros((Bsz, H, N, Pd), x.dtype)

    def step(h, inp):
        st, tot = inp                                    # (B,H,N,P), (B,H)
        h_prev = h
        h = h * jnp.exp(tot)[:, :, None, None].astype(x.dtype) + st
        return h, h_prev

    (h_final, h_prevs) = jax.lax.scan(
        step, h0, (chunk_states.transpose(1, 0, 2, 3, 4),
                   total.transpose(1, 0, 2)))
    h_prevs = h_prevs.transpose(1, 0, 2, 3, 4)           # (B,nc,H,N,P)

    # contribution of the carried state to each position in its chunk
    y_state = jnp.einsum("bcihn,bchnp->bcihp",
                         Cc * jnp.exp(cs)[..., None].astype(x.dtype),
                         h_prevs)
    y = (y + y_state).reshape(Bsz, S, H, Pd)
    return y, h_final


def _project(params, cfg: ModelConfig, x):
    """x (..., D) -> z, xs, Bm, Cm, dt_raw (pre-softplus)."""
    dt_ = x.dtype
    z = x @ params["z_proj"].astype(dt_)
    xs = x @ params["x_proj"].astype(dt_)
    Bm = x @ params["b_proj"].astype(dt_)
    Cm = x @ params["c_proj"].astype(dt_)
    dt_raw = x @ params["dt_proj"].astype(dt_)
    return z, xs, Bm, Cm, dt_raw


def mamba_apply(params, cfg: ModelConfig, x, return_cache: bool = False):
    """Full-sequence Mamba-2 mixer. x: (B, S, D) -> (B, S, D)."""
    mc, d_in, n_heads, gn = _dims(cfg)
    dt_ = x.dtype
    B, S, D = x.shape
    z, xs_raw, Bm_raw, Cm_raw, dt_raw = _project(params, cfg, x)
    xs = _causal_conv(params["conv_x"], xs_raw, dt_)
    Bm = _causal_conv(params["conv_b"], Bm_raw, dt_)
    Cm = _causal_conv(params["conv_c"], Cm_raw, dt_)
    xs_h = xs.reshape(B, S, n_heads, mc.head_dim)
    Bm = Bm.reshape(B, S, mc.n_groups, mc.d_state)
    Cm = Cm.reshape(B, S, mc.n_groups, mc.d_state)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])
    # pad S to a chunk multiple: zero dt/x/B/C => exp(0)=1 decay, zero
    # state contribution — padded tail is a mathematical no-op.
    Q = min(mc.chunk, S)
    pad = (-S) % Q
    if pad:
        zpad = lambda a: jnp.pad(  # noqa: E731
            a, [(0, 0), (0, pad)] + [(0, 0)] * (a.ndim - 2))
        xs_h, Bm, Cm, dt = zpad(xs_h), zpad(Bm), zpad(Cm), zpad(dt)
    y, h_final = _ssd_chunked(xs_h, dt, A, Bm, Cm, mc.chunk)
    if pad:
        y = y[:, :S]
    y = y + xs_h[:, :S] * params["D"].astype(dt_)[None, None, :, None]
    y = y.reshape(B, S, d_in)
    y = layers.rms_norm(params["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = y @ params["out_proj"].astype(dt_)
    if not return_cache:
        return out
    nc = mc.d_conv - 1
    cache = {"ssm": h_final.astype(dt_),
             "conv_x": xs_raw[:, S - nc:, :].astype(dt_),
             "conv_b": Bm_raw[:, S - nc:, :].astype(dt_),
             "conv_c": Cm_raw[:, S - nc:, :].astype(dt_)}
    return out, cache


def init_mamba_cache(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16):
    mc, d_in, n_heads, gn = _dims(cfg)
    return {
        "ssm": jnp.zeros((batch, n_heads, mc.d_state, mc.head_dim), dtype),
        "conv_x": jnp.zeros((batch, mc.d_conv - 1, d_in), dtype),
        "conv_b": jnp.zeros((batch, mc.d_conv - 1, gn), dtype),
        "conv_c": jnp.zeros((batch, mc.d_conv - 1, gn), dtype),
    }


def _conv_step(conv, hist_new, dtype):
    """hist_new: (B, d_conv, ch) — last d_conv raw inputs incl current."""
    w = conv["w"].astype(dtype)
    return jax.nn.silu((hist_new * w[None]).sum(1)
                       + conv["b"].astype(dtype))


def mamba_decode_step(params, cfg: ModelConfig, x, cache):
    """Single-token decode. x: (B, 1, D); O(1) state update."""
    mc, d_in, n_heads, gn = _dims(cfg)
    dt_ = x.dtype
    B = x.shape[0]
    z, xs_raw, Bm_raw, Cm_raw, dt_raw = _project(params, cfg, x[:, 0])
    hx = jnp.concatenate([cache["conv_x"].astype(dt_), xs_raw[:, None]], 1)
    hb = jnp.concatenate([cache["conv_b"].astype(dt_), Bm_raw[:, None]], 1)
    hc = jnp.concatenate([cache["conv_c"].astype(dt_), Cm_raw[:, None]], 1)
    xs = _conv_step(params["conv_x"], hx, dt_)
    Bm = _conv_step(params["conv_b"], hb, dt_)
    Cm = _conv_step(params["conv_c"], hc, dt_)
    xs = xs.reshape(B, n_heads, mc.head_dim)
    rep = n_heads // mc.n_groups
    Bm = jnp.repeat(Bm.reshape(B, mc.n_groups, mc.d_state), rep, axis=1)
    Cm = jnp.repeat(Cm.reshape(B, mc.n_groups, mc.d_state), rep, axis=1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])
    decay = jnp.exp(dt * A).astype(dt_)                   # (B,H)
    h = cache["ssm"].astype(dt_)                          # (B,H,N,P)
    dBx = (dt.astype(dt_)[..., None, None]
           * Bm[..., :, None] * xs[..., None, :])         # (B,H,N,P)
    h = h * decay[..., None, None] + dBx
    y = jnp.einsum("bhn,bhnp->bhp", Cm, h)                # (B,H,P)
    y = y + xs * params["D"].astype(dt_)[None, :, None]
    y = y.reshape(B, 1, d_in)
    y = layers.rms_norm(params["norm"], y * jax.nn.silu(z[:, None]),
                        cfg.norm_eps)
    out = y @ params["out_proj"].astype(dt_)
    new_cache = {"ssm": h.astype(cache["ssm"].dtype),
                 "conv_x": hx[:, 1:].astype(cache["conv_x"].dtype),
                 "conv_b": hb[:, 1:].astype(cache["conv_b"].dtype),
                 "conv_c": hc[:, 1:].astype(cache["conv_c"].dtype)}
    return out, new_cache


def mamba_reference(params, cfg: ModelConfig, x):
    """Sequential-scan oracle for testing the chunked SSD path."""
    B, S, D = x.shape
    cache = init_mamba_cache(cfg, B, dtype=jnp.float32)
    outs = []
    for t in range(S):
        y, cache = mamba_decode_step(params, cfg, x[:, t:t + 1], cache)
        outs.append(y)
    return jnp.concatenate(outs, axis=1)
