"""Feed-forward recommenders (paper Sec. 4.2 architectures).

A thin MLP over an IOEmbedding (Bloom / HT / ECOC / PMI / CCA / identity
baseline): encode(p) -> hidden ReLU layers -> m_out logits, trained with
the embedding's own loss and evaluated after decode() back to item space.
This is the exact shape of the paper's ML/MSD/AMZ/BC setups (3-4 layer
feed-forward + softmax CE) and of CADE (classifier).
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core.alternatives import IOEmbedding
from repro.models import layers


def ff_init(key, d_in: int, hidden: Sequence[int], d_out: int):
    dims = [d_in, *hidden, d_out]
    ks = jax.random.split(key, len(dims) - 1)
    return {f"l{i}": layers.dense_init(ks[i], dims[i], dims[i + 1])
            for i in range(len(dims) - 1)}


def ff_apply(params, x: jnp.ndarray) -> jnp.ndarray:
    n = len(params)
    for i in range(n):
        x = layers.dense(params[f"l{i}"], x)
        if i < n - 1:
            x = jax.nn.relu(x)
    return x


def recommender_init(key, emb: IOEmbedding, hidden: Sequence[int]):
    return ff_init(key, emb.m_in, hidden, emb.m_out)


def recommender_loss(params, emb: IOEmbedding, p_in: jnp.ndarray,
                     q_out: jnp.ndarray) -> jnp.ndarray:
    """p_in/q_out: padded item-id sets (B, c_max). Mean loss over batch."""
    x = emb.encode_input(p_in)
    pred = ff_apply(params, x)
    return emb.loss(pred, q_out).mean()


def recommender_scores(params, emb: IOEmbedding,
                       p_in: jnp.ndarray) -> jnp.ndarray:
    """(B, c_max) -> (B, d) item ranking scores via the embedding's decode."""
    x = emb.encode_input(p_in)
    pred = ff_apply(params, x)
    return emb.decode(pred)
