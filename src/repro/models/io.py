"""Token IO boundary: embedding + LM head, dense or Bloom-compressed.

This is where the paper's technique plugs into every architecture
(DESIGN.md §5): with bloom.enabled the embedding table and LM head operate
in the m-dim hashed space; the per-token loss and serving-time vocabulary
recovery use the k-way likelihood of Eqs. 2/3.

io_impl selects the execution path:
  "xla"    — pure jnp (gather/take); the oracle, and the dry-run path.
  "pallas" — fused TPU kernels from repro.kernels (validated vs this file).

On the pallas path, bwd_impl selects the training backward of the Bloom
scatter-adds: "csr" (default — CSR-binned segment kernel, reads the
cotangent ~k times total, DESIGN.md §4) or "dense" (the m-tile-sweep
fallback).  Both match the xla oracle's jax.grad to <= 1e-4.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import losses, quant
from repro.core.bloom import BloomSpec, decode_topk
from repro.models import layers


def resolved_table_dtype(cfg: ModelConfig) -> Optional[str]:
    """ModelConfig.table_dtype -> kernel-layer knob (DESIGN.md §13).

    The config default "auto" maps to ``None`` (legacy behavior: cast the
    table to the activation dtype, no quantization) so pre-quant configs
    stay bit-identical; anything else is canonicalized by core.quant.
    """
    td = quant.resolve_table_dtype(cfg.table_dtype, allow_auto=True)
    return None if td == "auto" else td


def _fake_quant_rows(x: jnp.ndarray, table_dtype: str) -> jnp.ndarray:
    """Quantize+dequantize (..., m) rows — the XLA oracle's storage model.

    The xla io_impl has no narrow HBM tables, but it must RANK through the
    same dequantized values the Pallas kernels see, or accuracy sweeps
    (bench_retrieval.py int8 retention) would silently compare a quantized
    kernel against an unquantized oracle.  Row axis = last axis, matching
    the per-row scales of core.quant.
    """
    flat = x.reshape(-1, x.shape[-1])
    q, s = quant.quantize_table(flat, table_dtype)
    return quant.dequantize_table(q, s).reshape(x.shape)


def vocab_spec(cfg: ModelConfig) -> Optional[BloomSpec]:
    if not cfg.bloom.enabled:
        return None
    return BloomSpec(d=cfg.vocab, m=cfg.m_vocab, k=cfg.bloom.k,
                     seed=cfg.bloom.seed, on_the_fly=cfg.bloom.on_the_fly)


def io_init(key, cfg: ModelConfig):
    V, D = cfg.m_vocab, cfg.d_model
    k1, k2 = jax.random.split(key)
    p = {"embed": layers.embed_init(k1, (V, D))}
    if not cfg.tie_embeddings:
        p["head"] = layers.truncated_normal_init(k2, (D, V), 1.0)
    return p


def embed_tokens(params, cfg: ModelConfig, tokens: jnp.ndarray,
                 ) -> jnp.ndarray:
    """tokens (B, S) int32 -> (B, S, D) activations.

    Bloom path: x = sum_j Table[H_j(tok)] — the dense-matrix product with
    the k-hot Bloom code of the paper, computed as a k-way gather-sum.
    """
    table = params["embed"]
    dt = jnp.dtype(cfg.dtype)
    spec = vocab_spec(cfg)
    if spec is None:
        return jnp.take(table, tokens, axis=0).astype(dt)
    td = resolved_table_dtype(cfg)
    if cfg.io_impl == "pallas":
        from repro.kernels import ops
        if td is None:
            return ops.bloom_embed(table.astype(dt), tokens, spec,
                                   bwd_impl=cfg.bwd_impl)
        # master-precision table in; the kernel stores/DMAs it narrow and
        # dequantizes on the VMEM tile (grads straight-through to master)
        return ops.bloom_embed(table, tokens, spec, bwd_impl=cfg.bwd_impl,
                               table_dtype=td, out_dtype=dt)
    if td is not None:
        table = _fake_quant_rows(table, td)
    idx = spec.indices_for(tokens)                     # (B, S, k)
    rows = jnp.take(table, idx, axis=0).astype(dt)     # (B, S, k, D)
    return rows.sum(axis=2)


def lm_logits(params, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    """x (B, S, D) -> logits (B, S, m_vocab) (m-dim when bloom enabled)."""
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    return x @ head.astype(x.dtype)


def lm_loss(params, cfg: ModelConfig, logits: jnp.ndarray,
            labels: jnp.ndarray, valid: Optional[jnp.ndarray] = None
            ) -> jnp.ndarray:
    """Per-token CE. Bloom: logsumexp(z) - (1/k) sum_j z[H_j(y)] (Eq. 3)."""
    spec = vocab_spec(cfg)
    logits = logits.astype(jnp.float32)
    if spec is None:
        return losses.softmax_xent_label(logits, labels, valid)
    if cfg.io_impl == "pallas":
        from repro.kernels import ops
        loss = ops.bloom_ce(logits, labels, spec)
        return loss if valid is None else loss * valid.astype(loss.dtype)
    return losses.bloom_xent_label(spec, logits, labels, valid=valid)


def recover_topk(cfg: ModelConfig, logits: jnp.ndarray, topk: int = 16,
                 chunk: int = 8192, active: Optional[jnp.ndarray] = None):
    """Serving-time vocabulary recovery (paper Sec. 3.2).

    logits (..., m_vocab) -> (scores, token_ids) (..., topk) over the
    original vocab.  Dense path: plain top-k.  Bloom path: Eq. 3 scores
    via the streaming k-gather reduction; with io_impl="pallas" the fused
    decode-topk kernel keeps the running top-k in VMEM and never writes
    the (..., d) recovered-score matrix to HBM.

    `active` (..., ) bool marks live slots in a continuous-batching pool:
    retired/idle slots get ids=0 and scores=-inf so engine bookkeeping
    can never mistake a stale row for output.  With io_impl="pallas" the
    mask additionally drives the kernel's row-skipping occupancy grid
    (DESIGN.md §8): fully-inactive row blocks are skipped at the HBM
    level, so a half-empty pool no longer pays full-pool bytes; the
    post-hoc where() below still masks dead rows inside partially-live
    blocks.
    """
    spec = vocab_spec(cfg)
    return recover_topk_spec(spec, logits, topk, impl=cfg.io_impl,
                             chunk=chunk, active=active,
                             unroll=cfg.unroll_for_analysis,
                             table_dtype=resolved_table_dtype(cfg))


def recover_topk_spec(spec: Optional[BloomSpec], logits: jnp.ndarray,
                      topk: int = 16, *, impl: str = "xla",
                      chunk: int = 8192,
                      active: Optional[jnp.ndarray] = None,
                      unroll: bool = False,
                      table_dtype: Optional[str] = None):
    """``recover_topk`` keyed by a BloomSpec instead of a ModelConfig —
    the shared recovery core for the LM head AND the retrieval scenario
    (serving/retrieval.py), which has no ModelConfig to hand.

    All three paths follow the SAME tie-break contract (DESIGN.md §11):
    equal Eq. 3 scores resolve to the lowest item id, exactly like
    ``jax.lax.top_k`` on a materialized score vector — the streaming
    oracle seeds each chunk merge with the running best (earlier = lower
    ids first in the concat), and the Pallas kernel folds tiles in
    ascending vocab order with strictly-greater replacement.

    ``table_dtype`` (DESIGN.md §13, None = legacy f32) narrows the
    resident logp rows: the Pallas kernel stores them narrow in HBM and
    dequantizes on the VMEM tile; the streaming oracle fake-quantizes the
    SAME per-row storage model before ranking, so a MAP measured on the
    xla path is an honest proxy for the quantized kernel.  (int8 ids may
    still differ by quantization-induced score ties — the scores agree
    to float rounding; see tests/test_kernels.py.)
    """
    if spec is None:
        scores, ids = jax.lax.top_k(logits, topk)
    else:
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        td = quant.resolve_table_dtype(table_dtype)
        if impl == "pallas":
            from repro.kernels import ops
            scores, ids = ops.bloom_decode_topk(logp, spec, topk,
                                                active=active,
                                                table_dtype=td)
        else:
            if td is not None:
                logp = _fake_quant_rows(logp, td)
            scores, ids = decode_topk(spec, logp, topk, chunk=chunk,
                                      unroll=unroll)
    if active is not None:
        live = active[..., None]
        scores = jnp.where(live, scores, -jnp.inf)
        ids = jnp.where(live, ids, 0)
    return scores, ids
