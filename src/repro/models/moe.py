"""Mixture-of-experts FFN: shared + routed experts (DeepSeekMoE / OLMoE /
Jamba style) with top-k routing and capacity buffers.

Two execution paths sharing one routing core:
  * ``dense``  — all experts local (CPU smoke tests, single device).
  * ``ep``     — expert-parallel: experts sharded over the `model` mesh axis
                 inside shard_map; activations arrive replicated over
                 `model` (Megatron TP convention), each rank computes its
                 local experts' capacity buffers, and one psum over `model`
                 combines.  No token all-to-all is needed because the
                 dispatch is resolved by the buffer gather (DESIGN.md §6).

The capacity-buffer trick keeps peak memory at O(E_local·C·d_model) by
scattering token *indices* (int32) rather than token vectors, then
gathering rows once into the (E_local, C, D) buffer.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers

try:  # jax>=0.6 stabilized shard_map
    from jax import shard_map as _shard_map
except ImportError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map as _shard_map

# The "skip replication check" kwarg was renamed check_rep -> check_vma
# across jax versions; resolve it from the actual signature so either
# jaxlib works (the seed pinned check_vma and broke on jax 0.4.x).
import inspect as _inspect

_CHECK_KW = ("check_vma" if "check_vma"
             in _inspect.signature(_shard_map).parameters else "check_rep")

from jax.sharding import PartitionSpec as P


def moe_init(key, cfg: ModelConfig):
    mo = cfg.moe
    D, Fe, E = cfg.d_model, mo.d_ff_expert, mo.num_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": layers.truncated_normal_init(ks[0], (D, E), 1.0),
        "w_gate": layers.truncated_normal_init(ks[1], (E * D, Fe), 1.0)
        .reshape(E, D, Fe),
        "w_up": layers.truncated_normal_init(ks[2], (E * D, Fe), 1.0)
        .reshape(E, D, Fe),
        "w_down": layers.truncated_normal_init(ks[3], (E * Fe, D), 1.0)
        .reshape(E, Fe, D),
    }
    if mo.num_shared:
        p["shared"] = layers.swiglu_init(ks[4], D, mo.num_shared * Fe)
    return p


def _capacity(tokens: int, cfg: ModelConfig) -> int:
    mo = cfg.moe
    return max(1, math.ceil(tokens * mo.top_k * mo.capacity_factor
                            / mo.num_experts))


def _route_local(params, x_flat, cfg: ModelConfig, expert_offset,
                 num_local: int, capacity: int):
    """Route x_flat (T, D) through `num_local` experts starting at
    `expert_offset` (a traced scalar under shard_map). Returns (out, aux)."""
    mo = cfg.moe
    T, D = x_flat.shape
    k, E, C = mo.top_k, mo.num_experts, capacity
    dt = x_flat.dtype

    logits = (x_flat @ params["router"].astype(dt)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                   # (T, E)
    gate, sel = jax.lax.top_k(probs, k)                       # (T, k)
    gate = gate / jnp.clip(gate.sum(-1, keepdims=True), 1e-9, None)

    # Load-balance aux loss (Switch/GShard): E * sum_e f_e * P_e.
    f = jnp.zeros((E,), jnp.float32).at[sel.reshape(-1)].add(1.0) / (T * k)
    aux = mo.num_experts * jnp.sum(f * probs.mean(0))

    flat_sel = sel.reshape(-1)                                # (T*k,)
    tok = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)
    le = flat_sel - expert_offset
    local = (le >= 0) & (le < num_local)
    le_safe = jnp.where(local, le, num_local)
    # position of each routed copy within its expert's queue
    oh = jax.nn.one_hot(le_safe, num_local, dtype=jnp.int32)  # (T*k, E_loc)
    pos = jnp.cumsum(oh, axis=0) - oh                         # exclusive
    pos_sel = (pos * oh).sum(-1)
    keep = local & (pos_sel < C)
    slot = jnp.where(keep, le_safe * C + pos_sel, num_local * C)

    # scatter token indices (not vectors) into the buffer, then gather once
    sentinel = T
    idx_buf = jnp.full((num_local * C + 1,), sentinel, jnp.int32)
    idx_buf = idx_buf.at[slot].set(tok, mode="drop")
    gate_buf = jnp.zeros((num_local * C + 1,), jnp.float32)
    gate_buf = gate_buf.at[slot].set(
        jnp.where(keep, gate.reshape(-1), 0.0), mode="drop")
    idx_buf, gate_buf = idx_buf[:-1], gate_buf[:-1]           # drop overflow

    x_pad = jnp.concatenate([x_flat, jnp.zeros((1, D), dt)], 0)
    x_buf = jnp.take(x_pad, idx_buf, axis=0)                  # (E_loc*C, D)
    x_buf = x_buf.reshape(num_local, C, D)

    g = jnp.einsum("ecd,edf->ecf", x_buf, params["w_gate"].astype(dt))
    u = jnp.einsum("ecd,edf->ecf", x_buf, params["w_up"].astype(dt))
    y_buf = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u,
                       params["w_down"].astype(dt))
    y_buf = (y_buf.reshape(num_local * C, D)
             * gate_buf[:, None].astype(dt))

    out = jnp.zeros((T + 1, D), dt).at[idx_buf].add(y_buf)[:-1]
    return out, aux


def _moe_core(params, x_flat, cfg: ModelConfig, expert_offset, num_local,
              capacity, axis: Optional[str]):
    out, aux = _route_local(params, x_flat, cfg, expert_offset, num_local,
                            capacity)
    if axis is not None:
        out = jax.lax.psum(out, axis)
        aux = jax.lax.pmean(aux, axis)
    if cfg.moe.num_shared:
        out = out + layers.swiglu(params["shared"], x_flat)
    return out, aux


def moe_apply(params, x, cfg: ModelConfig, dist=None):
    """MoE FFN. x: (B, S, D). Returns (y (B,S,D), aux scalar).

    dist: repro.launch.sharding.DistContext or None.  With a context and
    cfg.moe_impl == "ep", experts run expert-parallel over the `model` axis.
    """
    B, S, D = x.shape
    x_flat = x.reshape(B * S, D)
    mo = cfg.moe

    if dist is not None and cfg.moe_impl == "ep":
        mesh = dist.mesh
        model_ax = dist.model_axis
        n_model = mesh.shape[model_ax]
        assert mo.num_experts % n_model == 0, (
            f"experts {mo.num_experts} must divide model axis {n_model}")
        n_local = mo.num_experts // n_model
        # tokens shard over the batch axes when divisible (train/prefill);
        # tiny decode batches stay replicated (B=1 long-context decode).
        batch_axes = dist.batch_spec_axes(B * S) or ()
        n_batch = 1
        for a in batch_axes:
            n_batch *= mesh.shape[a]
        t_loc = max(1, (B * S) // n_batch)
        cap = _capacity(t_loc, cfg)

        def fn(xf, router, wg, wu, wd, shared):
            prm = {"router": router, "w_gate": wg, "w_up": wu, "w_down": wd}
            if shared is not None:
                prm["shared"] = shared
            off = jax.lax.axis_index(model_ax) * n_local
            out, aux = _moe_core(prm, xf, cfg, off, n_local, cap, model_ax)
            for a in batch_axes:
                aux = jax.lax.pmean(aux, a)
            return out, aux

        shared = params.get("shared")
        xs = P(batch_axes if batch_axes else None, None)
        wspec = P(model_ax, None, None)
        sspec = (None if shared is None
                 else jax.tree.map(lambda _: P(None, None), shared))
        out, aux = _shard_map(
            fn, mesh=mesh,
            in_specs=(xs, P(None, None), wspec, wspec, wspec, sspec),
            out_specs=(xs, P()),
            **{_CHECK_KW: False},
        )(x_flat, params["router"], params["w_gate"], params["w_up"],
          params["w_down"], shared)
        return out.reshape(B, S, D), aux

    cap = _capacity(B * S, cfg)
    out, aux = _moe_core(params, x_flat, cfg, 0, mo.num_experts, cap, None)
    return out.reshape(B, S, D), aux


def moe_apply_reference(params, x, cfg: ModelConfig):
    """Oracle: computes every expert densely for every token (O(E) FLOPs).

    Used only in tests to validate the capacity-buffer path (tokens that
    are not dropped must match exactly).
    """
    B, S, D = x.shape
    mo = cfg.moe
    x_flat = x.reshape(B * S, D)
    dt = x_flat.dtype
    logits = (x_flat @ params["router"].astype(dt)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, -1)
    gate, sel = jax.lax.top_k(probs, mo.top_k)
    gate = gate / jnp.clip(gate.sum(-1, keepdims=True), 1e-9, None)
    g = jnp.einsum("td,edf->tef", x_flat, params["w_gate"].astype(dt))
    u = jnp.einsum("td,edf->tef", x_flat, params["w_up"].astype(dt))
    y = jnp.einsum("tef,efd->ted", jax.nn.silu(g) * u,
                   params["w_down"].astype(dt))         # (T, E, D)
    mask = jax.nn.one_hot(sel, mo.num_experts, dtype=jnp.float32)  # (T,k,E)
    w = (mask * gate[..., None]).sum(1)                 # (T, E)
    out = jnp.einsum("ted,te->td", y, w.astype(dt))
    if mo.num_shared:
        out = out + layers.swiglu(params["shared"], x_flat)
    return out.reshape(B, S, D)
