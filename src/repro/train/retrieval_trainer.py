"""Train the retrieval FF tower on the Zipf stream (DESIGN.md §12).

PR 7 opened the web-scale retrieval serving scenario with an UNTRAINED
tower; this module closes the paper's accuracy loop: the same
pure-in-``(seed, host)`` Zipf(1) stream loadgen serves from becomes the
training distribution — each request's ``c_max`` history items are the
input set, its ``n_targets`` held-out items the prediction target — and
the tower is trained with the paper's Bloom multilabel cross-entropy
(``models/recommender.recommender_loss`` over a ``BloomIO`` whose input
AND output spec are the serving spec), through the fault-tolerant
``train.trainer.Trainer`` (checkpoint/resume, ``--failpoints`` chaos).

Spec discipline: serving Bloom-encodes the request with ``rcfg.spec()``
(launch/steps.make_retrieval_prefill_step) and recovers items through
the SAME spec (make_retrieval_decode_step), so training must too —
``BloomIO.build`` would derive a ``seed+1`` output spec and silently
train a tower whose served rankings decode through the wrong hashes.
``make_retrieval_loss`` constructs the BloomIO directly with
``spec_in = spec_out = rcfg.spec()``.

Evaluation is end-to-end THROUGH the serving stack: a fresh eval-seed
workload is served by ``RetrievalEngine`` with the trained params (the
slot pool, not an offline matmul), then ranked with the tie-aware
MAP/RR/accuracy of ``serving/retrieval.evaluate_retrieval``.
``compression_sweep`` repeats train+serve+eval at m/d ∈ {1/1, 1/2, 1/5,
1/10} — the paper's Fig. 2 trade-off at serving scale — and
benchmarks/bench_retrieval.py commits the curve to BENCH_retrieval.json
with a ``--check`` gate on the trained ≫ untrained margin.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import TrainConfig
from repro.configs.retrieval import RetrievalConfig
from repro.core import bloom as bloom_lib
from repro.core.alternatives import BloomIO
from repro.data.pipeline import BatchIterator
from repro.models import recommender as rec_lib
from repro.serving.loadgen import RetrievalLoadSpec, retrieval_workload
from repro.serving.retrieval import (RetrievalEngine, evaluate_retrieval,
                                     init_retrieval_params)
from repro.train.trainer import Trainer

# the sweep the paper's headline claim lives on: accuracy holds to ~1/5
# compression (ratio = d/m)
SWEEP_RATIOS = (1, 2, 5, 10)


def make_retrieval_emb(rcfg: RetrievalConfig) -> BloomIO:
    """The serving-consistent BloomIO: ONE spec (``rcfg.spec()``) for
    input encode, training loss and Eq. 3 decode — exactly the hashes
    the serving prefill/decode steps use (see module doc)."""
    spec = rcfg.spec()
    return BloomIO(name="BE", d=rcfg.d, m_in=rcfg.m, m_out=rcfg.m,
                   spec_in=spec, spec_out=spec)


def make_retrieval_dataset(rcfg: RetrievalConfig, n_pairs: int,
                           seed: int = 0, n_targets: int = 2,
                           host: int = 0, n_hosts: int = 1
                           ) -> Tuple[np.ndarray, np.ndarray]:
    """(history, held-out) training pairs from the SAME generator the
    serving workload draws from — ``loadgen.retrieval_workload``, a pure
    function of ``(seed, host)``.  Returns -1-padded int32 arrays:
    prompts (n_pairs, c_max) and targets (n_pairs, n_targets)."""
    load = RetrievalLoadSpec(n_requests=n_pairs, catalog=rcfg.d,
                             c_max=rcfg.c_max, n_targets=n_targets,
                             rate=2.0, seed=seed)
    wl = retrieval_workload(load, host=host, n_hosts=n_hosts)
    prompts = np.full((n_pairs, rcfg.c_max), -1, np.int32)
    targets = np.full((n_pairs, n_targets), -1, np.int32)
    for i, r in enumerate(wl):
        prompts[i, :r.prompt_len] = np.asarray(r.prompt, np.int32)
        targets[i, :len(r.targets)] = np.asarray(r.targets, np.int32)
    return prompts, targets


def make_retrieval_loss(rcfg: RetrievalConfig):
    """loss_fn(params, batch) -> (scalar, metrics) for Trainer.

    batch = {"p": (B, c_max), "q": (B, n_targets)} -1-padded int32.
    The aux metric ``target_mass`` is the mean softmax probability mass
    the tower puts on the target set's Bloom bits — a per-example mean,
    so the grad-accumulation path must AVERAGE it across microbatches to
    match the microbatch=1 twin (the trainer bug this PR fixed;
    regression-tested in tests/test_retrieval_train.py)."""
    emb = make_retrieval_emb(rcfg)
    spec = rcfg.spec()

    def loss_fn(params, batch):
        p, q = batch["p"], batch["q"]
        loss = rec_lib.recommender_loss(params, emb, p, q)
        logits = rec_lib.ff_apply(params, emb.encode_input(p))
        code = (bloom_lib.encode(spec, q) > 0).astype(jnp.float32)
        probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
        mass = (probs * code).sum(-1).mean()
        return loss, {"target_mass": mass}

    return loss_fn


def default_train_config(steps: int = 300, microbatch: int = 0,
                         checkpoint_every: int = 0,
                         learning_rate: float = 3e-2) -> TrainConfig:
    return TrainConfig(optimizer="adamw", learning_rate=learning_rate,
                       grad_clip_norm=1.0, steps=steps, warmup_steps=10,
                       checkpoint_every=checkpoint_every,
                       microbatch=microbatch)


def train_retrieval(rcfg: RetrievalConfig, tc: TrainConfig, *,
                    n_pairs: int = 512, batch_size: int = 64,
                    n_targets: int = 2, data_seed: int = 0,
                    checkpoint_dir: Optional[str] = None,
                    failpoints=None, log_every: int = 10):
    """Train the tower; returns (params, run_result).

    Fault tolerance comes for free from the Trainer: checkpoint/resume
    via ``checkpoint_dir`` and chaos via ``failpoints`` (the same
    grammar as serving — ``train_fault@S`` kills at step S; rerunning
    the same call resumes from the last checkpoint)."""
    prompts, targets = make_retrieval_dataset(
        rcfg, n_pairs, seed=data_seed, n_targets=n_targets)
    it = BatchIterator([prompts, targets], batch_size, seed=data_seed)

    def make_batch(arrays):
        p, q = arrays
        return {"p": jnp.asarray(p), "q": jnp.asarray(q)}

    trainer = Trainer(make_retrieval_loss(rcfg),
                      init_retrieval_params(rcfg), tc, it,
                      checkpoint_dir=checkpoint_dir,
                      make_batch=make_batch, failpoints=failpoints)
    result = trainer.run(log_every=log_every)
    return trainer.state.params, result


def serve_and_eval(rcfg: RetrievalConfig, params, *,
                   n_requests: int = 64, n_slots: int = 8,
                   eval_seed: int = 1) -> Dict[str, float]:
    """End-to-end eval THROUGH the serving stack: serve a fresh
    eval-seed Zipf workload with ``RetrievalEngine`` (the generic slot
    loop), then rank the served requests with the tie-aware metrics.
    The eval seed differs from the training seed — fresh users, same
    popularity law."""
    load = RetrievalLoadSpec(n_requests=n_requests, catalog=rcfg.d,
                             c_max=rcfg.c_max, rate=2.0, seed=eval_seed)
    wl = [r.fresh_copy() for r in retrieval_workload(load)]
    engine = RetrievalEngine(rcfg, params, n_slots=n_slots)
    results, stats = engine.run(wl)
    served = list(results.values())
    ev = evaluate_retrieval(rcfg, params, served)
    # int8 dual-eval (DESIGN.md §13): re-rank the SAME served requests
    # through per-row fake-quantized pool logits — the values a
    # quantized Pallas decode would rank through — so the sweep can
    # gate quantized MAP retention without retraining the tower
    ev["map_int8"] = evaluate_retrieval(rcfg, params, served,
                                        table_dtype="int8")["map"]
    ev["decode_steps"] = stats.decode_steps
    return ev


def train_and_eval_point(rcfg: RetrievalConfig, tc: TrainConfig, *,
                         n_pairs: int = 512, batch_size: int = 64,
                         n_eval: int = 64, n_slots: int = 8,
                         data_seed: int = 0, eval_seed: int = 1,
                         checkpoint_dir: Optional[str] = None,
                         failpoints=None) -> Dict[str, object]:
    """One sweep point: train, then serve+eval BOTH the trained and the
    untrained (init) tower on the identical eval workload."""
    params, result = train_retrieval(
        rcfg, tc, n_pairs=n_pairs, batch_size=batch_size,
        data_seed=data_seed, checkpoint_dir=checkpoint_dir,
        failpoints=failpoints)
    trained = serve_and_eval(rcfg, params, n_requests=n_eval,
                             n_slots=n_slots, eval_seed=eval_seed)
    untrained = serve_and_eval(rcfg, init_retrieval_params(rcfg),
                               n_requests=n_eval, n_slots=n_slots,
                               eval_seed=eval_seed)
    final_loss = (result["history"][-1]["loss"]
                  if result["history"] else float("nan"))
    return {
        "config": rcfg.name, "d": rcfg.d, "m": rcfg.m, "k": rcfg.k,
        "ratio": round(rcfg.d / rcfg.m, 2), "steps": result["steps"],
        "n_train_pairs": n_pairs, "n_eval_requests": n_eval,
        "n_evaluated": trained["n_evaluated"],
        "decode_steps": trained["decode_steps"],
        "final_loss": float(final_loss),
        "map": trained["map"], "rr": trained["rr"],
        "accuracy": trained["accuracy"],
        "untrained_map": untrained["map"], "untrained_rr": untrained["rr"],
        # quantized-store retention: MAP of the trained tower ranked
        # through int8 fake-quantized logits, relative to the fp32 MAP
        # (gated fresh-value in benchmarks/bench_retrieval.py)
        "map_int8": trained["map_int8"],
        "int8_retention": round(
            trained["map_int8"] / max(trained["map"], 1e-12), 6),
    }


def compression_sweep(base: RetrievalConfig, tc: TrainConfig, *,
                      ratios=SWEEP_RATIOS, n_pairs: int = 512,
                      batch_size: int = 64, n_eval: int = 64,
                      n_slots: int = 8, data_seed: int = 0,
                      eval_seed: int = 1) -> List[Dict[str, object]]:
    """The paper's compression/accuracy trade-off at serving scale:
    train+serve+eval at m = d/ratio for each ratio.  ``base.m`` is
    replaced per point; everything else (catalog, hashes count, tower
    widths, seeds) is held fixed."""
    rows = []
    for ratio in ratios:
        m = base.d // ratio
        rcfg = dataclasses.replace(base, m=m,
                                   name=f"{base.name}_r{ratio}")
        rows.append(train_and_eval_point(
            rcfg, tc, n_pairs=n_pairs, batch_size=batch_size,
            n_eval=n_eval, n_slots=n_slots, data_seed=data_seed,
            eval_seed=eval_seed))
    return rows


def assert_trained_margin(rows: List[Dict[str, object]],
                          min_ratio_at_5: float = 3.0) -> None:
    """The hard acceptance gate: the trained tower must beat the
    untrained one by ``min_ratio_at_5``x MAP at 1/5 compression (and
    strictly beat it at every point).  Float MAPs are compared on FRESH
    values only — never exact-matched against a committed file (platform
    float drift); the committed BENCH_retrieval.json exact-checks the
    deterministic integers instead."""
    for row in rows:
        assert row["map"] > row["untrained_map"], (
            f"{row['config']}: trained MAP {row['map']:.4f} <= untrained "
            f"{row['untrained_map']:.4f} — training is not helping")
    at5 = [r for r in rows if abs(r["ratio"] - 5.0) < 1e-6]
    assert at5, "sweep has no 1/5-compression point to gate on"
    r = at5[0]
    floor = min_ratio_at_5 * max(r["untrained_map"], 1e-12)
    assert r["map"] >= floor, (
        f"{r['config']}: trained MAP {r['map']:.4f} < {min_ratio_at_5}x "
        f"untrained {r['untrained_map']:.4f} at 1/5 compression — the "
        "paper's headline margin does not hold")
