"""train substrate."""
