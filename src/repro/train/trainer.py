"""Training loop substrate: TrainState, jitted steps, fault-tolerant loop.

The loop is deliberately restart-oriented: every `checkpoint_every` steps
the full state (params, optimizer, step counter, data cursor) is saved
atomically; `run()` always begins by attempting a restore, so any crash /
preemption / induced fault resumes exactly where it left off (tested in
tests/test_checkpoint.py by killing the loop mid-run).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig
from repro.checkpoint.checkpointer import Checkpointer
from repro.optim import optimizers as opt_lib


@dataclasses.dataclass
class TrainState:
    params: Any
    opt_state: Any
    step: int


def warm_bloom_caches(cfg, decode_grad: bool = False,
                      params: Optional[Any] = None) -> None:
    """Pre-build the per-spec Bloom device caches the hot path reads
    (ModelConfig-aware entry; no-op off the pallas path).

    The LM training loss touches only the (d, k) hash matrix (embed +
    CE; embed's bwd_impl="csr" bins are per-batch and fuse into the
    jitted step), so that is all the default warms.  Pass
    ``decode_grad=True`` from workloads that DIFFERENTIATE the Eq. 3
    decode (ranking losses through ops.bloom_decode) to also pre-build
    the per-spec CSR bins of the hash matrix
    (core.bloom.cached_decode_bins) — otherwise they are built lazily on
    the first csr decode backward.  Warming before the first jitted step
    keeps the one-time work out of the first step's wall time and out of
    any traced scope.

    With a quantized ``cfg.table_dtype`` (DESIGN.md §13) AND concrete
    ``params`` in hand (serve-time; training steps quantize in-graph),
    the quantized embedding table is also pre-built through
    core.bloom.cached_quantized_table, so the first forward never pays
    the eager quantize.
    """
    from repro.core import bloom as bloom_lib
    from repro.models import io as io_lib
    spec = io_lib.vocab_spec(cfg)
    td = io_lib.resolved_table_dtype(cfg)  # validates the knob eagerly
    if spec is None or cfg.io_impl != "pallas":
        return
    bloom_lib.cached_hash_matrix(spec)
    if td is not None and params is not None:
        bloom_lib.cached_quantized_table(spec, params["embed"], td)
    if decode_grad and cfg.bwd_impl == "csr":
        from repro.kernels.bloom_csr import CSR_E_TILE
        from repro.kernels.common import BWD_M_TILE
        bloom_lib.cached_decode_bins(spec, BWD_M_TILE, CSR_E_TILE)


def make_optimizer(tc: TrainConfig, total_steps: Optional[int] = None):
    sched = (opt_lib.warmup_cosine(tc.learning_rate, tc.warmup_steps,
                                   total_steps or tc.steps)
             if tc.warmup_steps else tc.learning_rate)
    return opt_lib.make_optimizer(
        tc.optimizer, sched, b1=tc.beta1, b2=tc.beta2, eps=tc.eps,
        momentum=tc.momentum, weight_decay=tc.weight_decay,
        grad_clip_norm=tc.grad_clip_norm, compression=tc.grad_compression)


def make_train_step(loss_fn: Callable, optimizer, microbatch: int = 0,
                    donate: bool = True):
    """loss_fn(params, batch) -> (scalar, metrics dict).

    With microbatch > 0, the batch's leading axis is split into chunks and
    gradients are accumulated (bf16-compressible) before one update —
    the grad-accumulation path for large global batches.
    """

    def grads_of(params, batch):
        return jax.value_and_grad(loss_fn, has_aux=True)(params, batch)

    def step(params, opt_state, batch):
        if microbatch and microbatch > 1:
            def split(x):
                b = x.shape[0]
                assert b % microbatch == 0
                return x.reshape(microbatch, b // microbatch, *x.shape[1:])

            micro = jax.tree.map(split, batch)
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def acc_body(carry, mb):
                g_acc, loss_acc = carry
                (loss, metrics), g = grads_of(params, mb)
                g_acc = jax.tree.map(lambda a, b: a + b, g_acc, g)
                return (g_acc, loss_acc + loss), metrics

            (g, loss), metrics = jax.lax.scan(
                acc_body, (zeros, jnp.zeros((), jnp.float32)), micro)
            g = jax.tree.map(lambda x: x / microbatch, g)
            loss = loss / microbatch
            # scan stacks each metric to (microbatch, ...); average them
            # like the loss — keeping only the LAST chunk's value made
            # logged accuracy/aux metrics silently diverge from the
            # microbatch=1 twin (equal-size chunks, so the mean of the
            # per-chunk means IS the full-batch mean)
            metrics = jax.tree.map(lambda m: jnp.mean(m, axis=0), metrics)
        else:
            (loss, metrics), g = grads_of(params, batch)
        updates, opt_state = optimizer.update(g, opt_state, params)
        params = opt_lib.apply_updates(params, updates)
        metrics = dict(metrics)
        metrics["loss"] = loss
        metrics["grad_norm"] = opt_lib.global_norm(g)
        return params, opt_state, metrics

    return jax.jit(step, donate_argnums=(0, 1) if donate else ())


class Trainer:
    """Fault-tolerant train loop over a resumable BatchIterator."""

    def __init__(self, loss_fn, init_params, tc: TrainConfig,
                 data_iter, checkpoint_dir: Optional[str] = None,
                 make_batch=None, eval_fn=None,
                 fault_hook: Optional[Callable[[int], None]] = None,
                 failpoints=None):
        self.tc = tc
        self.optimizer = make_optimizer(tc)
        self.loss_fn = loss_fn
        self.data_iter = data_iter
        self.make_batch = make_batch or (lambda arrays: arrays)
        self.eval_fn = eval_fn
        if fault_hook is None and failpoints is not None:
            # Train faults come from the same seeded registry the serving
            # stack injects from (serving/failpoints.py, FailPlan or spec
            # string) — one grammar for train and serve chaos.
            from repro.serving.failpoints import FailPlan
            plan = (failpoints if isinstance(failpoints, FailPlan)
                    else FailPlan.parse(failpoints))
            fault_hook = plan.train_hook()
        self.fault_hook = fault_hook
        self.step_fn = make_train_step(loss_fn, self.optimizer,
                                       tc.microbatch)
        self.state = TrainState(params=init_params,
                                opt_state=self.optimizer.init(init_params),
                                step=0)
        self.ckpt = (Checkpointer(checkpoint_dir, keep=tc.keep_checkpoints)
                     if checkpoint_dir else None)
        self.history = []

    # ------------------------------------------------------------------
    def try_restore(self) -> bool:
        if self.ckpt is None:
            return False
        template = {"params": self.state.params,
                    "opt_state": self.state.opt_state}
        restored, step, extra = self.ckpt.restore_latest(template)
        if restored is None:
            return False
        self.state = TrainState(params=restored["params"],
                                opt_state=restored["opt_state"],
                                step=step)
        if "data" in extra and hasattr(self.data_iter, "restore"):
            self.data_iter.restore(extra["data"])
        # history rides in `extra` (JSON-able floats): without it a
        # crash-resumed run() returned only the post-crash tail, so any
        # curve plotted from the result was silently truncated
        if "history" in extra:
            self.history = list(extra["history"])
        return True

    def save(self, block: bool = True):
        if self.ckpt is None:
            return
        extra = {"history": list(self.history)}
        if hasattr(self.data_iter, "state"):
            extra["data"] = self.data_iter.state()
        self.ckpt.save(self.state.step,
                       {"params": self.state.params,
                        "opt_state": self.state.opt_state},
                       extra=extra, block=block)

    # ------------------------------------------------------------------
    def run(self, steps: Optional[int] = None, log_every: int = 0):
        steps = steps or self.tc.steps
        self.try_restore()
        t0 = time.perf_counter()
        while self.state.step < steps:
            if self.fault_hook is not None:
                self.fault_hook(self.state.step)  # may raise (test harness)
            arrays = next(self.data_iter)
            batch = self.make_batch(arrays)
            params, opt_state, metrics = self.step_fn(
                self.state.params, self.state.opt_state, batch)
            self.state = TrainState(params, opt_state, self.state.step + 1)
            if log_every and self.state.step % log_every == 0:
                m = {k: float(v) for k, v in metrics.items()}
                self.history.append({"step": self.state.step, **m})
            if (self.tc.checkpoint_every
                    and self.state.step % self.tc.checkpoint_every == 0):
                self.save()
        self.save()
        wall = time.perf_counter() - t0
        return {"steps": self.state.step, "wall_time_s": wall,
                "history": self.history}
