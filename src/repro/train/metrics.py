"""Evaluation measures used by the paper (Sec. 4.1): MAP, RR, Accuracy."""
from __future__ import annotations

import numpy as np


def average_precision(scores: np.ndarray, relevant: np.ndarray,
                      exclude: np.ndarray | None = None) -> float:
    """AP of `relevant` item ids under `scores` (d,), optionally excluding
    `exclude` ids (e.g. the user's input items) from the ranking."""
    s = np.asarray(scores, np.float64).copy()
    rel = set(int(i) for i in relevant if i >= 0)
    if not rel:
        return np.nan
    if exclude is not None:
        ex = [int(i) for i in exclude if i >= 0 and int(i) not in rel]
        s[ex] = -np.inf
    # stable sort: ties rank in ascending item-id order — the SAME
    # tie-break every top-k decode path follows (DESIGN.md §11), and
    # deterministic (the default introsort permutes ties arbitrarily,
    # which made MAP on tied scores platform-dependent)
    order = np.argsort(-s, kind="stable")
    hits, ap = 0, 0.0
    for rank, item in enumerate(order, start=1):
        if int(item) in rel:
            hits += 1
            ap += hits / rank
            if hits == len(rel):
                break
    return ap / len(rel)


def mean_average_precision(scores: np.ndarray, relevants: np.ndarray,
                           excludes: np.ndarray | None = None) -> float:
    """MAP over a batch. scores (B, d); relevants (B, c) -1-padded."""
    aps = []
    for i in range(scores.shape[0]):
        ex = None if excludes is None else excludes[i]
        ap = average_precision(scores[i], relevants[i], ex)
        if not np.isnan(ap):
            aps.append(ap)
    return float(np.mean(aps)) if aps else 0.0


def reciprocal_rank(scores: np.ndarray, target: np.ndarray,
                    exclude: np.ndarray | None = None) -> float:
    """Mean RR of the single correct item. scores (B, d), target (B,).

    Tie handling is mid-rank: ``rank = greater + ties/2 + 1`` where
    ``ties`` counts the OTHER items scoring exactly scores[t].  The old
    ``greater + 1`` rank was optimistic — an untrained model emitting
    constant scores got RR = 1.0 for every target; mid-rank gives the
    honest expectation over random tie orders (RR ~ 2/d for d-way ties).

    ``exclude`` (B, c) -1-padded masks e.g. the user's input items from
    the ranking, mirroring average_precision.
    """
    scores = np.asarray(scores, np.float64)
    rrs = []
    for i in range(scores.shape[0]):
        t = int(target[i])
        if t < 0:
            continue
        s = scores[i]
        if exclude is not None:
            s = s.copy()
            ex = [int(j) for j in exclude[i] if j >= 0 and int(j) != t]
            s[ex] = -np.inf
        greater = int((s > s[t]).sum())
        ties = int((s == s[t]).sum()) - 1   # items tied with the target
        rrs.append(1.0 / (greater + ties / 2.0 + 1.0))
    return float(np.mean(rrs)) if rrs else 0.0


def accuracy(scores: np.ndarray, target: np.ndarray,
             exclude: np.ndarray | None = None) -> float:
    """Top-1 accuracy (%) of the single correct item. scores (B, d),
    target (B,) with -1 = skip the row.

    ``exclude`` (B, c) -1-padded masks e.g. the user's input items from
    the ranking before the argmax, mirroring average_precision /
    reciprocal_rank — the paper's Sec. 4.1 accuracy on retrieval evals
    must not rank items the user already has (the target itself is never
    masked).  Tied argmax resolves to the LOWEST item id (np.argmax
    returns the first maximum) — the same tie-break contract every
    top-k decode path follows (DESIGN.md §11).
    """
    scores = np.asarray(scores, np.float64)
    if exclude is not None:
        scores = scores.copy()
        for i in range(scores.shape[0]):
            t = int(target[i])
            ex = [int(j) for j in exclude[i] if j >= 0 and int(j) != t]
            scores[i, ex] = -np.inf
    pred = scores.argmax(-1)
    valid = target >= 0
    if valid.sum() == 0:
        return 0.0
    return float((pred[valid] == target[valid]).mean() * 100.0)
