"""Evaluation measures used by the paper (Sec. 4.1): MAP, RR, Accuracy."""
from __future__ import annotations

import numpy as np


def average_precision(scores: np.ndarray, relevant: np.ndarray,
                      exclude: np.ndarray | None = None) -> float:
    """AP of `relevant` item ids under `scores` (d,), optionally excluding
    `exclude` ids (e.g. the user's input items) from the ranking."""
    s = np.asarray(scores, np.float64).copy()
    rel = set(int(i) for i in relevant if i >= 0)
    if not rel:
        return np.nan
    if exclude is not None:
        ex = [int(i) for i in exclude if i >= 0 and int(i) not in rel]
        s[ex] = -np.inf
    order = np.argsort(-s)
    hits, ap = 0, 0.0
    for rank, item in enumerate(order, start=1):
        if int(item) in rel:
            hits += 1
            ap += hits / rank
            if hits == len(rel):
                break
    return ap / len(rel)


def mean_average_precision(scores: np.ndarray, relevants: np.ndarray,
                           excludes: np.ndarray | None = None) -> float:
    """MAP over a batch. scores (B, d); relevants (B, c) -1-padded."""
    aps = []
    for i in range(scores.shape[0]):
        ex = None if excludes is None else excludes[i]
        ap = average_precision(scores[i], relevants[i], ex)
        if not np.isnan(ap):
            aps.append(ap)
    return float(np.mean(aps)) if aps else 0.0


def reciprocal_rank(scores: np.ndarray, target: np.ndarray) -> float:
    """Mean RR of the single correct item. scores (B, d), target (B,)."""
    rrs = []
    for i in range(scores.shape[0]):
        t = int(target[i])
        if t < 0:
            continue
        rank = int((scores[i] > scores[i, t]).sum()) + 1
        rrs.append(1.0 / rank)
    return float(np.mean(rrs)) if rrs else 0.0


def accuracy(scores: np.ndarray, target: np.ndarray) -> float:
    pred = scores.argmax(-1)
    valid = target >= 0
    if valid.sum() == 0:
        return 0.0
    return float((pred[valid] == target[valid]).mean() * 100.0)
