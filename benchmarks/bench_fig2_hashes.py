"""Paper Fig. 2: score ratio S_i/S_0 as a function of k (m/d = 0.3).

Expected qualitative result: k = 1 (hashing trick) is clearly worse;
2 <= k <= 4 is the sweet spot; large k degrades again.
"""
from __future__ import annotations

from benchmarks.common import baseline_embedding, run_task
from repro.core.alternatives import BloomIO
from repro.configs.paper_tasks import PAPER_TASKS

KS = (1, 2, 4, 8, 16)


def run(tasks=("MSD",), m_over_d: float = 0.3, steps: int = 120,
        scale: float = 0.6, seeds=(0,)):
    rows = []
    for name in tasks:
        d = PAPER_TASKS[name].d
        s0 = run_task(name, baseline_embedding(d), steps=steps,
                      scale=scale)["score"]
        m = int(d * m_over_d)
        for k in KS:
            vals = [run_task(name, BloomIO.build(d=d, m=m, k=k, seed=s),
                             steps=steps, seed=s, scale=scale)["score"]
                    for s in seeds]
            si = sum(vals) / len(vals)
            rows.append({"bench": "fig2", "task": name, "k": k,
                         "m_over_d": m_over_d, "score": si,
                         "ratio": si / max(s0, 1e-9)})
    return rows


if __name__ == "__main__":
    for row in run():
        print(row)
