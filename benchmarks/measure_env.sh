#!/usr/bin/env bash
# Env-hygiene wrapper for the --measure modes of bench_kernels.py and
# bench_serving.py: wall-clock numbers are only comparable run-to-run
# when the allocator and thread pools are pinned.  Usage:
#
#   benchmarks/measure_env.sh python -m benchmarks.bench_kernels \
#       --quick --measure
#   benchmarks/measure_env.sh python -m benchmarks.bench_serving --measure
#
# measured_us / model_vs_measured are informational only — never gated,
# never committed (write_json strips them) — so this wrapper exists to
# make the numbers *stable*, not official.
set -euo pipefail

cd "$(dirname "$0")/.."
export PYTHONPATH="${PYTHONPATH:-src}"

# one deterministic CPU thread pool: XLA intra-op + BLAS/OpenMP.  The
# interpret-mode Pallas kernels are single-stream anyway; unpinned
# pools add run-to-run jitter without adding speed at bench shapes.
export XLA_FLAGS="${XLA_FLAGS:-} --xla_cpu_multi_thread_eigen=false"
export OMP_NUM_THREADS="${OMP_NUM_THREADS:-1}"
export OPENBLAS_NUM_THREADS="${OPENBLAS_NUM_THREADS:-1}"
export MKL_NUM_THREADS="${MKL_NUM_THREADS:-1}"

# keep XLA from autotuning differently run-to-run
export TF_CPP_MIN_LOG_LEVEL="${TF_CPP_MIN_LOG_LEVEL:-2}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-}"

# tcmalloc, when the image ships it, removes glibc-malloc arena noise
# from the large table/logit allocations; silently skipped otherwise
for lib in /usr/lib/x86_64-linux-gnu/libtcmalloc_minimal.so.4 \
           /usr/lib/x86_64-linux-gnu/libtcmalloc.so.4; do
    if [[ -e "$lib" ]]; then
        export LD_PRELOAD="${LD_PRELOAD:+$LD_PRELOAD:}$lib"
        break
    fi
done

exec "$@"
