"""Paper Tables 4/5: co-occurrence-based Bloom embeddings (CBE) vs BE.

Expected qualitative result: CBE gives moderate average gains over BE
(largest on co-occurrence-rich data), plus the Table 4 co-occurrence
statistics of each dataset.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import baseline_embedding, run_task, task_data
from benchmarks.bench_table3_alternatives import _input_matrix
from repro.configs.paper_tasks import PAPER_TASKS
from repro.core import hashing
from repro.core.alternatives import BloomIO
from repro.core.cbe import cbe_hash_matrix, cooccurrence_stats


def run(points=(("MSD", 0.1), ("MSD", 0.3)), k: int = 4,
        steps: int = 120, scale: float = 0.5, max_pairs: int = 20_000):
    rows = []
    for name, r in points:
        t = PAPER_TASKS[name]
        X_in, X_out = _input_matrix(name, scale)
        pct_in, rho_in = cooccurrence_stats(X_in)
        s0 = run_task(name, baseline_embedding(t.d), steps=steps,
                      scale=scale)["score"]
        m = max(16, int(t.d * r))

        be = BloomIO.build(d=t.d, m=m, k=k, seed=0)
        s_be = run_task(name, be, steps=steps, scale=scale)["score"]

        H_in = hashing.make_hash_matrix_np(t.d, k, m, seed=0)
        H_out = hashing.make_hash_matrix_np(t.d, k, m, seed=1)
        H_in2 = cbe_hash_matrix(X_in, H_in, m, seed=0,
                                max_pairs=max_pairs)
        H_out2 = cbe_hash_matrix(X_out, H_out, m, seed=1,
                                 max_pairs=max_pairs)
        cbe = BloomIO.build(d=t.d, m=m, k=k, seed=0, H_in=H_in2,
                            H_out=H_out2, name="CBE")
        s_cbe = run_task(name, cbe, steps=steps, scale=scale)["score"]

        rows.append({
            "bench": "table5", "task": name, "m_over_d": r, "k": k,
            "cooc_pct_in": pct_in, "cooc_rho_in": rho_in,
            "be_ratio": s_be / max(s0, 1e-9),
            "cbe_ratio": s_cbe / max(s0, 1e-9),
            "cbe_minus_be_pct": 100 * (s_cbe - s_be) / max(s0, 1e-9),
        })
    return rows


if __name__ == "__main__":
    for row in run():
        print(row)
