"""Serving-engine benchmark: continuous batching vs static batching on the
seeded mixed-length workload (serving/loadgen.py), per architecture, plus
model-free replays of the gossiped multi-host schedule
(``sched.sharded_*`` rows — scheduler.simulate_sharded_schedule over
per-host loadgen streams, DESIGN.md §8).  The ``sched.sharded_kill1``
row replays the h4x2_d1 workload under a committed mid-traffic host
kill (DESIGN.md §10) and pins the recovery overhead in decode steps;
the ``sched.sharded_surge`` row replays the same topology under the
DESIGN.md §14 overload drill (surge + slow_decode + admission policy)
and pins shed count, SLO attainment, degrade transitions and the
overhead vs an in-bench unloaded twin.

Every row is a *deterministic simulation*: decode-step counts, slot
utilization and mean latency are pure functions of (workload seed,
n_slots, gen-length mix) — no float in the loop — so the committed
``BENCH_serving.json`` is an exact CI baseline on any host.  Wall-clock
throughput is recorded for humans but never checked.

``python -m benchmarks.bench_serving`` regenerates the committed JSON;
``--check`` compares a fresh run against it and exits non-zero on any
drift of the deterministic fields or if the continuous/static decode-step
speedup falls below MIN_SPEEDUP (the ISSUE-2 acceptance bar).  (No
--quick mode: the whole sim IS the quick mode — one seeded workload per
arch, ~15 s on CPU.)

``--measure`` wall-clocks one warm full-occupancy retrieval decode step
per retrieval case (jit warmup, best of 3 around block_until_ready) into
``measured_us`` / ``model_vs_measured`` fields — the same informational,
never-gated, never-committed contract as bench_kernels (wall_s
precedent); run through ``benchmarks/measure_env.sh`` for a quiet
allocator/thread environment.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.kernels.bloom_decode_topk import modeled_hbm_bytes
from repro.launch import steps as steps_lib
from repro.serving import (AdmissionPolicy, Engine, FailPlan, LoadSpec,
                           RetrievalEngine, RetrievalLoadSpec,
                           assert_fresh_instances, init_retrieval_params,
                           mean_latency, mixed_length_workload,
                           overload_workload, retrieval_workload,
                           sharded_workload, simulate_sharded_schedule,
                           slo_attainment)

JSON_PATH = pathlib.Path(__file__).resolve().parent.parent / \
    "BENCH_serving.json"
MIN_SPEEDUP = 1.5
HBM_BW = 819e9     # TPU-v5e HBM bandwidth (matches bench_kernels)
# retrieval.* rows: the streaming decode must model at least this many
# times fewer HBM bytes than the dense-table oracle (ISSUE-7 acceptance
# bar at d=1M; the actual ratios are orders of magnitude above it)
MIN_RETRIEVAL_BYTES_RATIO = 3.0

# (arch, n_slots, n_requests, seed): one dense and one attention-free SSM
# arch — the slot pool covers KV caches and conv/ssm state alike.
CASES = [
    ("qwen1.5-0.5b", 3, 10, 0),
    ("mamba2-1.3b", 3, 10, 0),
]
TOPK = 4
MAX_LEN = 40

# (n_hosts, slots_per_host, n_requests PER HOST, gossip_delay, seed,
#  compact_threshold): model-free replays of the gossiped multi-host
# schedule (scheduler.simulate_sharded_schedule) — deterministic integers
# on any host, including the 1-device bench-check runner.  The delay
# sweep pins the gossip cost: the d2 schedule must stay within a few
# steps of d0.  The compaction pair (same topology with and without a
# threshold) pins the remap's schedule-invariance: identical step counts,
# only slot ids move (COMPACT events counted in the row).
SHARDED_CASES = [
    (4, 2, 4, 1, 0, None),
    (8, 1, 2, 1, 0, None),
    (4, 2, 4, 0, 0, None),
    (4, 2, 4, 2, 0, None),
    (4, 4, 6, 1, 0, None),
    (4, 4, 6, 1, 0, 0.25),
]

# The chaos row (failure-model satellite): replay the h4x2_d1 workload
# with host 1 killed mid-traffic — the same committed kill schedule the
# CI chaos job drives through sim_multihost.  Every request must still
# complete (the HOST_DOWN reclaim re-queues host 1's in-flight work),
# nothing is rejected, and the extra decode steps over the fault-free
# twin — the price of re-prefilling the reclaimed requests — are pinned
# as ``recovery_overhead_steps``.
SHARDED_KILL_CASES = [
    (4, 2, 4, 1, 0, None, "kill_host:1@3"),
]

# The surge row (overload satellite, DESIGN.md §14): the h4x2_d1
# topology under open-loop overload — ``overload_workload`` bakes a 2x
# arrival ramp with per-request SLO deadlines, then the failpoint surge
# re-compresses the tail and ``slow_decode`` triples the decode cost —
# with the admission policy shedding and walking the degrade ladder.
# The unloaded twin (the SAME compressed workload, no failpoints, no
# policy) is ephemeral: its workload differs from every committed row,
# so it is recomputed in-bench and only its decode steps are pinned
# inside the surge row, making the overload overhead a pure schedule
# diff.  The policy thresholds are sized to the bounded queue exactly
# like the CI chaos drill (sim_multihost.OVERLOAD_POLICY): pending
# tops out near max_queue_depth * n_hosts / n_slots, so the ladder
# must trip well below 1.0.
SHARDED_SURGE_CASES = [
    # (n_hosts, slots_per_host, n_requests PER HOST, gossip_delay, seed,
    #  failpoints, surge_start, surge_factor, deadline_slack)
    (4, 2, 4, 1, 0, "surge:3@1,slow_decode:3@2", 1, 2, 8),
]
SURGE_POLICY = dict(max_queue_depth=2, pressure_window=2,
                    degrade_lo=0.25, degrade_hi=0.5, restore_below=0.1)

# (retrieval config, n_slots, n_requests, seed): the web-scale one-shot
# retrieval scenario (DESIGN.md §11) — Zipf item lookups through the
# slot pool with the streaming Eq. 3 decode, at a CI-friendly 1M-item
# catalog and the dense-table-cannot-fit 10M acceptance scale.  Each
# case runs TWICE from fresh request copies and asserts bit-identical
# top-k ids; only analytic bytes + schedule integers are committed (the
# float id scores never touch the baseline).
RETRIEVAL_CASES = [
    ("web1m", 8, 12, 0),
    ("web10m", 8, 8, 0),
]


def _run_case(arch: str, n_slots: int, n_requests: int, seed: int):
    cfg = configs.get_smoke_config(arch)
    params = steps_lib.cast_params_for_compute(
        steps_lib.init_fn_for(cfg)(jax.random.PRNGKey(seed)), cfg)
    engine = Engine(cfg, params, n_slots=n_slots, max_len=MAX_LEN,
                    topk=TOPK)

    # one workload, two engines: the A/B replays must never share
    # Request instances (engine-filled bookkeeping would leak run to
    # run) — each path serves its own fresh copies
    wl = mixed_length_workload(cfg.vocab, n_requests, seed=seed)
    wl_c = [r.fresh_copy() for r in wl]
    wl_s = [r.fresh_copy() for r in wl]
    assert_fresh_instances(wl_c, wl_s)
    res_c, st_c = engine.run(wl_c)
    res_s, st_s = engine.run_static(wl_s)
    assert all(r.done for r in res_c.values())

    rows = []
    for mode, res, st in (("continuous", res_c, st_c),
                          ("static", res_s, st_s)):
        rows.append({
            "bench": "serving", "name": f"{arch}.{mode}",
            "n_slots": n_slots, "n_requests": n_requests, "seed": seed,
            "decode_steps": st.decode_steps,
            "slot_steps_total": st.slot_steps_total,
            "slot_steps_active": st.slot_steps_active,
            "utilization": round(st.utilization, 4),
            "tokens_out": st.tokens_out,
            "mean_latency_steps": round(mean_latency(res), 4),
            # informational only (CPU wall time — never checked)
            "wall_s": round(st.wall_s, 3),
            "tok_per_s_wall": round(st.tokens_out / max(st.wall_s, 1e-9)),
        })
    rows.append({
        "bench": "serving", "name": f"{arch}.speedup",
        "n_slots": n_slots, "n_requests": n_requests, "seed": seed,
        "decode_step_speedup": round(
            st_s.decode_steps / max(st_c.decode_steps, 1), 4),
        "utilization_gain": round(
            st_c.utilization - st_s.utilization, 4),
    })
    return rows


def _sharded_spec(n_requests: int, seed: int) -> LoadSpec:
    # the canonical mixed-length mix (loadgen.mixed_length_workload),
    # split into per-host streams
    return LoadSpec(n_requests=n_requests, vocab=1024, rate=2.0,
                    prompt_lens=(6, 10, 14), gen_lens=(3, 6, 20),
                    gen_weights=(0.5, 0.3, 0.2), seed=seed)


def _run_sharded_case(n_hosts: int, slots_per_host: int, n_requests: int,
                      gossip_delay: int, seed: int,
                      compact_threshold=None, failpoints=None):
    per_host = sharded_workload(_sharded_spec(n_requests, seed), n_hosts)
    sched, st = simulate_sharded_schedule(
        per_host, slots_per_host, gossip_delay,
        compact_threshold=compact_threshold,
        failpoints=FailPlan.parse(failpoints) if failpoints else None)
    results = {r.rid: r for reqs in per_host for r in reqs}
    assert all(r.done for r in results.values())
    name = f"sched.sharded_h{n_hosts}x{slots_per_host}_d{gossip_delay}"
    row = {
        "bench": "serving",
        "name": name,
        "n_hosts": n_hosts, "slots_per_host": slots_per_host,
        "n_requests": n_requests * n_hosts, "seed": seed,
        "gossip_delay": gossip_delay,
        "decode_steps": st.decode_steps,
        "slot_steps_total": st.slot_steps_total,
        "slot_steps_active": st.slot_steps_active,
        "utilization": round(st.utilization, 4),
        "tokens_out": st.tokens_out,
        "mean_latency_steps": round(mean_latency(results), 4),
    }
    if compact_threshold is not None:
        # compaction is schedule-invariant: the remap moves slot ids,
        # never admission/release steps — so all counters must equal the
        # no-compaction row's; only the COMPACT count is new
        row["name"] = f"{name}_c{int(compact_threshold * 100)}"
        row["compact_threshold"] = compact_threshold
        row["compactions"] = st.compactions
        assert st.compactions > 0, (
            f"{row['name']}: compaction case never compacted — the row "
            "would silently pin nothing")
    if failpoints is not None:
        # the kill row keeps the fault-free twin's workload so the
        # recovery overhead is a pure schedule diff, computed in run()
        row["name"] = "sched.sharded_kill1"
        row["fault_free_twin"] = name
        row["failpoints"] = failpoints
        row["host_downs"] = st.host_downs
        row["requeued"] = st.requeued
        row["rejects"] = st.rejects
        assert st.requeued > 0, (
            f"{row['name']}: the kill reclaimed nothing — the row would "
            "silently pin a fault-free schedule; move the kill step "
            "inside the arrival span")
        assert st.rejects == 0, (
            f"{row['name']}: recovery dropped {st.rejects} requests")
    return row


def _run_surge_case(n_hosts: int, slots_per_host: int, n_requests: int,
                    gossip_delay: int, seed: int, failpoints: str,
                    surge_start: int, surge_factor: int,
                    deadline_slack: int):
    spec = _sharded_spec(n_requests, seed)

    def wl():
        # fresh Request instances per replay (same no-sharing rule as
        # the A/B engine cases — loadgen rebuilds from the seed)
        return overload_workload(spec, n_hosts, surge_start=surge_start,
                                 surge_factor=surge_factor,
                                 deadline_slack=deadline_slack)

    per_host = wl()
    sched, st = simulate_sharded_schedule(
        per_host, slots_per_host, gossip_delay,
        failpoints=FailPlan.parse(failpoints),
        admission_policy=AdmissionPolicy(**SURGE_POLICY))
    results = {r.rid: r for reqs in per_host for r in reqs}
    shed = sorted(r.rid for r in results.values() if r.shed)
    served = [r for r in results.values()
              if r.done and not r.shed and not r.rejected]
    assert all(r.done for r in results.values()), (
        "sched.sharded_surge: a request is neither served nor shed — "
        "the overload run left non-terminal state")
    assert st.sheds == len(shed) and st.sheds > 0, (
        f"sched.sharded_surge: expected sheds under overload, got "
        f"{st.sheds} — the row would silently pin an unloaded schedule; "
        "tighten the policy or the surge")
    assert st.degrades > 0, (
        "sched.sharded_surge: the degrade ladder never moved — pressure "
        "never crossed degrade_lo; tighten the thresholds")
    assert st.rejects == 0, (
        f"sched.sharded_surge: overload must shed, never reject "
        f"(got {st.rejects} rejects)")

    # the unloaded twin: same compressed arrivals, no failpoints, no
    # policy — every request completes, and the decode-step delta is
    # what the slowdown cost net of the shed requests' freed capacity
    twin_wl = wl()
    _, twin_st = simulate_sharded_schedule(twin_wl, slots_per_host,
                                           gossip_delay)
    assert all(r.done and not r.shed and not r.rejected
               for reqs in twin_wl for r in reqs), (
        "sched.sharded_surge: the unloaded twin shed or dropped work — "
        "the overhead baseline is contaminated")

    return {
        "bench": "serving", "name": "sched.sharded_surge",
        "n_hosts": n_hosts, "slots_per_host": slots_per_host,
        "n_requests": n_requests * n_hosts, "seed": seed,
        "gossip_delay": gossip_delay,
        "failpoints": failpoints,
        "surge_start": surge_start, "surge_factor": surge_factor,
        "deadline_slack": deadline_slack,
        "decode_steps": st.decode_steps,
        "slot_steps_total": st.slot_steps_total,
        "slot_steps_active": st.slot_steps_active,
        "utilization": round(st.utilization, 4),
        "tokens_out": st.tokens_out,
        # arrival-relative; can dip under surge (the serving clock is
        # compressed past the original arrival steps) — deterministic
        # either way, so it stays checked
        "mean_latency_steps": round(mean_latency(results), 4),
        "sheds": st.sheds,
        "rejects": st.rejects,
        "degrade_transitions": st.degrades,
        "slo_attainment": round(slo_attainment(len(served),
                                               len(results)), 4),
        "unloaded_twin_decode_steps": twin_st.decode_steps,
        # negative is expected here (unlike the kill row's
        # recovery_overhead_steps): shedding 6 of 16 requests frees more
        # decode work than the slow_decode slowdown adds back
        "overhead_steps_vs_twin": st.decode_steps - twin_st.decode_steps,
    }


def _measure_us(fn, repeats: int = 3) -> float:
    """Best-of-N wall-clock of ``fn()`` in microseconds (one untimed
    warmup call first — jit compile + Bloom cache build)."""
    jax.block_until_ready(fn())
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return 1e6 * best


def _run_retrieval_case(name: str, n_slots: int, n_requests: int,
                        seed: int, measure: bool = False):
    rcfg = configs.get_retrieval_config(name)
    load = RetrievalLoadSpec(n_requests=n_requests, catalog=rcfg.d,
                             c_max=rcfg.c_max, rate=2.0, seed=seed)
    wl = retrieval_workload(load)
    engine = RetrievalEngine(rcfg, init_retrieval_params(rcfg),
                             n_slots=n_slots)
    wl_a = [r.fresh_copy() for r in wl]
    wl_b = [r.fresh_copy() for r in wl]
    assert_fresh_instances(wl_a, wl_b)
    res_a, st = engine.run(wl_a)
    res_b, _ = engine.run(wl_b)
    assert all(r.done and not r.rejected for r in res_a.values())
    for rid, ra in res_a.items():
        assert ra.topk_ids == res_b[rid].topk_ids, (
            f"retrieval.{name}: rid {rid} top-k ids drifted across "
            "replays — the streaming decode is not deterministic")
    mb = engine.modeled_bytes
    ratio = round(mb["dense_oracle_bytes"]
                  / max(mb["streaming_bytes"], 1), 1)
    measured = {}
    if measure:
        # one warm full-occupancy decode step: the modeled HBM time of
        # that step vs its wall clock (informational — on CPU the step
        # is the jitted XLA streaming oracle, on TPU the Pallas kernel)
        step = jax.jit(steps_lib.make_retrieval_decode_step(rcfg))
        pool = jax.nn.log_softmax(jax.random.normal(
            jax.random.PRNGKey(seed), (n_slots, rcfg.m)), axis=-1)
        active = jnp.ones((n_slots,), bool)
        us = _measure_us(lambda: step(pool, active))
        step_bytes = modeled_hbm_bytes(
            np.ones(n_slots, bool), rcfg.b_tile, m=rcfg.m, d=rcfg.d,
            k=rcfg.k, topk=rcfg.topk)
        model_us = 1e6 * step_bytes / HBM_BW
        measured = {"measured_us": round(us, 1),
                    "model_vs_measured": round(model_us / us, 6)}
    return {
        "bench": "serving", "name": f"retrieval.{name}",
        "d": rcfg.d, "m": rcfg.m, "k": rcfg.k, "topk": rcfg.topk,
        "impl": rcfg.resolved_impl,
        "n_slots": n_slots, "n_requests": n_requests, "seed": seed,
        "decode_steps": st.decode_steps,
        "slot_steps_total": st.slot_steps_total,
        "slot_steps_active": st.slot_steps_active,
        "utilization": round(st.utilization, 4),
        "tokens_out": st.tokens_out,
        "mean_latency_steps": round(mean_latency(res_a), 4),
        # analytic decode-bytes model (deterministic integers): the
        # streaming path at the run's actual per-step occupancy vs the
        # dense (d, m)-table oracle over the same steps
        "streaming_bytes": mb["streaming_bytes"],
        "dense_oracle_bytes": mb["dense_oracle_bytes"],
        "bytes_ratio": ratio,
        # informational only (CPU wall time — never checked)
        "wall_s": round(st.wall_s, 3),
        **measured,
    }


def run(measure: bool = False):
    rows = []
    for arch, n_slots, n_requests, seed in CASES:
        rows.extend(_run_case(arch, n_slots, n_requests, seed))
    for case in RETRIEVAL_CASES:
        rows.append(_run_retrieval_case(*case, measure=measure))
    for case in SHARDED_CASES:
        rows.append(_run_sharded_case(*case))
    for case in SHARDED_KILL_CASES:
        rows.append(_run_sharded_case(*case))
    for case in SHARDED_SURGE_CASES:
        rows.append(_run_surge_case(*case))
    # compaction schedule-invariance: every _c row must replay the exact
    # step counts of its no-compaction twin (slot ids move, steps don't)
    by_name = {r["name"]: r for r in rows}
    for r in rows:
        if "compact_threshold" not in r:
            continue
        twin = by_name.get(r["name"].rsplit("_c", 1)[0])
        assert twin is not None, (
            f"{r['name']}: compaction case needs its no-compaction twin "
            "in SHARDED_CASES (same topology with compact_threshold=None) "
            "for the schedule-invariance check")
        for f in ("decode_steps", "slot_steps_total", "slot_steps_active",
                  "tokens_out", "mean_latency_steps"):
            assert r[f] == twin[f], (
                f"{r['name']}.{f}: compaction changed the schedule "
                f"({twin[f]} -> {r[f]})")
    # recovery overhead: the kill row replays its fault-free twin's
    # workload, so the decode-step delta is exactly what the mid-traffic
    # host loss cost (re-prefill + re-decode of the reclaimed requests)
    for r in rows:
        twin_name = r.get("fault_free_twin")
        if twin_name is None:
            continue
        twin = by_name.get(twin_name)
        assert twin is not None, (
            f"{r['name']}: fault-free twin {twin_name} missing from "
            "SHARDED_CASES — the recovery overhead has no baseline")
        overhead = r["decode_steps"] - twin["decode_steps"]
        assert overhead >= 0, (
            f"{r['name']}: killing a host SHORTENED the schedule "
            f"({twin['decode_steps']} -> {r['decode_steps']})")
        r["recovery_overhead_steps"] = overhead
    return rows


# deterministic simulation outputs; wall-clock fields are excluded
CHECKED_FIELDS = ("decode_steps", "slot_steps_total", "slot_steps_active",
                  "utilization", "tokens_out", "mean_latency_steps",
                  "decode_step_speedup", "utilization_gain", "compactions",
                  "host_downs", "requeued", "rejects",
                  "recovery_overhead_steps", "sheds",
                  "degrade_transitions", "slo_attainment",
                  "unloaded_twin_decode_steps", "overhead_steps_vs_twin",
                  "streaming_bytes", "dense_oracle_bytes", "bytes_ratio")


def write_json(rows, path=JSON_PATH):
    # measured wall-clock is machine-dependent — never committed
    rows = [{k: v for k, v in r.items()
             if k not in ("measured_us", "model_vs_measured")}
            for r in rows]
    payload = {
        "generated_by": "PYTHONPATH=src python -m benchmarks.bench_serving",
        "min_speedup": MIN_SPEEDUP,
        # informational, like wall_s: never checked
        "notes": ("wall_s reflects the device-resident slot-state loop "
                  "(ISSUE 5 satellite): the engine no longer re-uploads "
                  "tokens/pos/active every decode step — they advance on "
                  "device and the host writes them only on admit/retire "
                  "events.  Warm-jit A/B on this workload's decode loop: "
                  "2.3 ms/step vs 4.5 ms/step before the hoist (~1.9x); "
                  "the committed wall_s of these tiny 10-request rows is "
                  "first-call-compile-dominated and includes the three "
                  "new one-time helper compiles.  Schedules and tokens "
                  "are bit-identical to the previous baseline (all "
                  "deterministic fields unchanged)."),
        "rows": rows,
    }
    path.write_text(json.dumps(payload, indent=1, sort_keys=True) + "\n")
    return path


def check_against(rows, path=JSON_PATH) -> list[str]:
    """Compare fresh rows against the committed baseline.

    Every mismatch is LOUD (collected here, nonzero exit in main):
    committed rows missing from the fresh run, fresh rows missing from
    the committed file, and — unlike the old `f in old` guard, which
    silently skipped a checked field absent on either side — any checked
    field present in one row but not the other.
    """
    committed = {r["name"]: r for r in
                 json.loads(path.read_text())["rows"]}
    failures = []
    fresh = {r["name"]: r for r in rows}
    for gone in sorted(set(committed) - set(fresh)):
        failures.append(f"{gone}: committed serving bench row missing "
                        "from the fresh run — a bench case was dropped "
                        "or renamed")
    for name, r in fresh.items():
        old = committed.get(name)
        if old is None:
            failures.append(f"{name}: expected row missing from "
                            f"{path.name} — regenerate the baseline")
            continue
        for f in CHECKED_FIELDS:
            if (f in old) != (f in r):
                side = "baseline" if f in r else "fresh run"
                failures.append(
                    f"{name}.{f}: checked field missing from the {side} "
                    "— schema drift; regenerate the baseline "
                    "deliberately")
            elif f in old and old[f] != r[f]:
                failures.append(
                    f"{name}.{f}: {old[f]} -> {r[f]} — the seeded "
                    "simulation is no longer reproducing the baseline "
                    "schedule")
        if name.endswith(".speedup") \
                and r.get("decode_step_speedup", 0.0) < MIN_SPEEDUP:
            failures.append(
                f"{name}: continuous/static decode-step speedup "
                f"{r['decode_step_speedup']:.2f} < {MIN_SPEEDUP} — "
                "continuous batching no longer pays on the mixed-length "
                "workload")
        if name.startswith("retrieval.") \
                and r.get("bytes_ratio", 0.0) < MIN_RETRIEVAL_BYTES_RATIO:
            failures.append(
                f"{name}: streaming-vs-dense modeled-bytes ratio "
                f"{r.get('bytes_ratio')} < {MIN_RETRIEVAL_BYTES_RATIO} — "
                "the streaming decode no longer pays over the "
                "dense-table oracle")
    return failures


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--check", action="store_true",
                    help="compare against committed BENCH_serving.json; "
                         "fail on schedule drift or speedup regression")
    ap.add_argument("--measure", action="store_true",
                    help="also wall-clock one warm full-occupancy "
                         "retrieval decode step per retrieval case "
                         "(informational; never gated, never committed "
                         "— run through benchmarks/measure_env.sh)")
    args = ap.parse_args()
    rows = run(measure=args.measure)
    for row in rows:
        print(row)
    if args.check:
        failures = check_against(rows)
        for f in failures:
            print("REGRESSION:", f, file=sys.stderr)
        if failures:
            sys.exit(1)
        print(f"check ok: {len(rows)} rows vs {JSON_PATH.name}")
    else:
        print("wrote", write_json(rows))


if __name__ == "__main__":
    main()
