"""Retrieval-training benchmark: the paper's compression/accuracy curve
at serving scale (DESIGN.md §12).

Runs the seeded train+serve+eval sweep of train/retrieval_trainer.py —
m/d in {1/1, 1/2, 1/5, 1/10} on the eval2k catalog, each point trained
on the Zipf stream and evaluated END-TO-END through RetrievalEngine's
generic slot loop with tie-aware MAP/RR/accuracy — and commits the curve
to ``BENCH_retrieval.json``.

Checking philosophy (same split as BENCH_kernels/BENCH_serving):

  * deterministic integers (catalog/compression config, train steps,
    pair counts, the served schedule's decode_steps, n_evaluated) are
    EXACT-checked against the committed file — any drift means the
    seeded pipeline no longer reproduces the baseline;
  * float ranking metrics (map, rr, accuracy, final_loss) are committed
    for humans but never exact-matched — cross-platform float drift
    would make that gate flaky.  Instead the ISSUE-8 margins are gated
    on the FRESH values every run: trained MAP >= MIN_MARGIN_AT_5 x
    untrained MAP at 1/5 compression, trained strictly above untrained
    at every point, MAP at 1/5 retaining >= MIN_RETENTION_AT_5 of
    the uncompressed (1/1) point — the paper's "accuracy holds to ~1/5"
    claim as a gate — and, per sweep point, the int8 dual-eval MAP
    (the same trained tower re-ranked through per-row fake-quantized
    pool logits, DESIGN.md §13) retaining >= MIN_INT8_RETENTION of the
    fp32 MAP — the ISSUE-9 quantized-store accuracy bar.

``python -m benchmarks.bench_retrieval`` regenerates the committed JSON;
``--check`` compares a fresh run against it and exits non-zero on drift
or a failed margin (~15 s on CPU).
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

from repro.configs.retrieval import get_retrieval_config
from repro.train import retrieval_trainer as rt

JSON_PATH = pathlib.Path(__file__).resolve().parent.parent / \
    "BENCH_retrieval.json"

# the ISSUE-8 acceptance bar: trained/untrained MAP ratio at 1/5
MIN_MARGIN_AT_5 = 3.0
# the paper's headline shape: 1/5-compressed MAP keeps at least this
# fraction of the uncompressed point (actual ~0.5; bar is deliberately
# loose — it guards the claim, not the exact float)
MIN_RETENTION_AT_5 = 0.2
# the ISSUE-9 quantized-store bar: int8 dual-eval MAP must keep at
# least this fraction of the fp32 MAP at EVERY sweep point (gated on
# fresh values — actual retention is ~1.0; int8 per-row scales are
# near-lossless on an m-dim log-softmax row)
MIN_INT8_RETENTION = 0.9

# sweep shape (seeded; CHANGING ANY OF THESE changes the committed rows)
CONFIG = "eval2k"
STEPS = 300
N_PAIRS = 512
BATCH = 64
N_EVAL = 64
N_SLOTS = 8
DATA_SEED = 0
EVAL_SEED = 1

CHECKED_FIELDS = ("d", "m", "k", "ratio", "steps", "n_train_pairs",
                  "n_eval_requests", "n_evaluated", "decode_steps")


def run_sweep() -> list[dict]:
    base = get_retrieval_config(CONFIG)
    tc = rt.default_train_config(steps=STEPS)
    rows = rt.compression_sweep(
        base, tc, n_pairs=N_PAIRS, batch_size=BATCH, n_eval=N_EVAL,
        n_slots=N_SLOTS, data_seed=DATA_SEED, eval_seed=EVAL_SEED)
    for row in rows:
        row["name"] = f"retrieval_train.{row.pop('config')}"
        for f in ("map", "rr", "accuracy", "final_loss",
                  "untrained_map", "untrained_rr",
                  "map_int8", "int8_retention"):
            row[f] = round(float(row[f]), 6)
    return rows


def gate_margins(rows: list[dict]) -> list[str]:
    """Fresh-value margin gates (see module doc) — returns failures."""
    failures = []
    try:
        rt.assert_trained_margin(
            [dict(r, config=r["name"]) for r in rows],
            min_ratio_at_5=MIN_MARGIN_AT_5)
    except AssertionError as e:
        failures.append(str(e))
    by_ratio = {r["ratio"]: r for r in rows}
    if 1.0 in by_ratio and 5.0 in by_ratio:
        full, fifth = by_ratio[1.0]["map"], by_ratio[5.0]["map"]
        if fifth < MIN_RETENTION_AT_5 * full:
            failures.append(
                f"map at 1/5 compression ({fifth:.4f}) retains < "
                f"{MIN_RETENTION_AT_5} of the uncompressed point "
                f"({full:.4f}) — the paper's compression claim broke")
    for r in rows:
        if r["map_int8"] < MIN_INT8_RETENTION * r["map"]:
            failures.append(
                f"{r['name']}: int8 dual-eval MAP {r['map_int8']:.4f} "
                f"retains < {MIN_INT8_RETENTION} of the fp32 MAP "
                f"({r['map']:.4f}) — quantized Bloom storage costs "
                "more accuracy than the ISSUE-9 bar allows")
    return failures


def write_json(rows, path=JSON_PATH):
    payload = {
        "generated_by":
            "PYTHONPATH=src python -m benchmarks.bench_retrieval",
        "min_margin_at_5": MIN_MARGIN_AT_5,
        "min_retention_at_5": MIN_RETENTION_AT_5,
        "min_int8_retention": MIN_INT8_RETENTION,
        "notes": ("Float metrics (map/rr/accuracy/final_loss) are "
                  "committed for humans; --check gates the margins on "
                  "fresh values and exact-matches only the "
                  "deterministic integer fields."),
        "rows": rows,
    }
    path.write_text(json.dumps(payload, indent=1, sort_keys=True) + "\n")
    return path


def check_against(rows, path=JSON_PATH) -> list[str]:
    committed = {r["name"]: r for r in
                 json.loads(path.read_text())["rows"]}
    failures = []
    fresh = {r["name"]: r for r in rows}
    for gone in sorted(set(committed) - set(fresh)):
        failures.append(f"{gone}: committed retrieval bench row missing "
                        "from the fresh run — a sweep point was dropped "
                        "or renamed")
    for name, r in fresh.items():
        old = committed.get(name)
        if old is None:
            failures.append(f"{name}: expected row missing from "
                            f"{path.name} — regenerate the baseline")
            continue
        for f in CHECKED_FIELDS:
            if (f in old) != (f in r):
                side = "baseline" if f in r else "fresh run"
                failures.append(
                    f"{name}.{f}: checked field missing from the {side} "
                    "— schema drift; regenerate the baseline "
                    "deliberately")
            elif f in old and old[f] != r[f]:
                failures.append(
                    f"{name}.{f}: {old[f]} -> {r[f]} — the seeded "
                    "train+serve pipeline no longer reproduces the "
                    "baseline")
    failures.extend(gate_margins(rows))
    return failures


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--check", action="store_true",
                    help="compare a fresh sweep against the committed "
                         "JSON instead of regenerating it")
    args = ap.parse_args()

    rows = run_sweep()
    for r in rows:
        print(r)

    if args.check:
        failures = check_against(rows)
        if failures:
            for f in failures:
                print(f"DRIFT: {f}", file=sys.stderr)
            sys.exit(1)
        print(f"check ok: {len(rows)} rows vs {JSON_PATH.name}")
    else:
        failures = gate_margins(rows)
        if failures:
            for f in failures:
                print(f"GATE: {f}", file=sys.stderr)
            sys.exit(1)
        path = write_json(rows)
        print(f"wrote {path}")


if __name__ == "__main__":
    main()
