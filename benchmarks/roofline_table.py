"""Aggregate the dry-run artifacts into the EXPERIMENTS.md roofline table."""
from __future__ import annotations

import glob
import json
import os


def load_cells(out_dir: str = "experiments/dryrun", tag: str = "singlepod",
               dense: bool = False):
    rows = []
    suffix = "__dense" if dense else ""
    for path in sorted(glob.glob(os.path.join(
            out_dir, f"*__{tag}{suffix}.json"))):
        if not dense and path.endswith("__dense.json"):
            continue
        with open(path) as f:
            d = json.load(f)
        rows.append(d)
    return rows


def fmt_table(rows, include_memory_analysis: bool = True):
    header = ("| arch | shape | compute s | memory s | collective s | "
              "dominant | step bound s | MODEL/HLO flops | temp GiB |")
    sep = "|" + "---|" * 9
    lines = [header, sep]
    for d in rows:
        if "roofline" not in d:
            continue
        r = d["roofline"]
        temp = d.get("full", {}).get("memory", {}).get("temp_bytes", 0)
        lines.append(
            f"| {d['arch']} | {d['shape']} | {r['compute_s']:.4f} | "
            f"{r['memory_s']:.4f} | {r['collective_s']:.4f} | "
            f"{r['dominant'].replace('_s','')} | {r['step_time_s']:.4f} | "
            f"{r['model_flops_ratio']:.3f} | {temp/2**30:.2f} |")
    return "\n".join(lines)


def fmt_multipod(rows):
    header = "| arch | shape | mesh | temp GiB | args GiB | compile s |"
    lines = [header, "|" + "---|" * 6]
    for d in rows:
        mem = d.get("full", {}).get("memory", {})
        lines.append(
            f"| {d['arch']} | {d['shape']} | {d['mesh']} | "
            f"{mem.get('temp_bytes', 0)/2**30:.2f} | "
            f"{mem.get('argument_bytes', 0)/2**30:.2f} | "
            f"{d.get('full', {}).get('compile_s', 0):.0f} |")
    return "\n".join(lines)


def run(quick: bool = True):
    rows = load_cells()
    out = []
    for d in rows:
        if "roofline" not in d:
            continue
        r = d["roofline"]
        out.append({"bench": "roofline", "arch": d["arch"],
                    "shape": d["shape"], "dominant": r["dominant"],
                    "compute_s": r["compute_s"], "memory_s": r["memory_s"],
                    "collective_s": r["collective_s"],
                    "model_flops_ratio": r["model_flops_ratio"]})
    return out


if __name__ == "__main__":
    print(fmt_table(load_cells()))
    print()
    print(fmt_multipod(load_cells(tag="multipod")))
