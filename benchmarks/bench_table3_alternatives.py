"""Paper Table 3: BE (k=3,4,5) vs HT / ECOC / PMI / CCA at fixed m/d.

Expected qualitative result: BE wins most (task, m/d) test points, by a
large margin over HT/ECOC; PMI/CCA are competitive only on their favorable
tasks (CADE-like input-only classification / AMZ-like co-occurrence-rich).
"""
from __future__ import annotations

import scipy.sparse as sp

from benchmarks.common import baseline_embedding, run_task, task_data
from repro.configs.paper_tasks import PAPER_TASKS
from repro.core.alternatives import (BloomIO, CCAIO, ECOCIO, PMIIO,
                                     hashing_trick)


def _input_matrix(name, scale):
    data = task_data(name, scale)
    t = PAPER_TASKS[name]
    if t.kind == "recsys":
        return data.X_in, data.X_out
    if t.kind == "classify":
        return data[3], data[3]
    # sessions: bag-of-items per session
    seqs, _ = data
    import numpy as np
    n, d = len(seqs), t.d
    rows, cols = [], []
    for i, s in enumerate(seqs):
        for it in s[s >= 0]:
            rows.append(i)
            cols.append(int(it))
    X = sp.csr_matrix((np.ones(len(rows)), (rows, cols)), shape=(n, d))
    X.data[:] = 1.0
    return X, X


def build_methods(name, m, scale, seed=0):
    t = PAPER_TASKS[name]
    X_in, X_out = _input_matrix(name, scale)
    return {
        "HT": hashing_trick(t.d, m, seed=seed),
        "ECOC": ECOCIO.build(t.d, m, seed=seed, iters=60),
        "PMI": PMIIO.build(X_in, min(m, 128), seed=seed),
        "CCA": CCAIO.build(X_in, X_out, min(m, 128), seed=seed),
        "BE k=3": BloomIO.build(d=t.d, m=m, k=3, seed=seed),
        "BE k=4": BloomIO.build(d=t.d, m=m, k=4, seed=seed),
        "BE k=5": BloomIO.build(d=t.d, m=m, k=5, seed=seed),
    }


def run(points=(("MSD", 0.1), ("MSD", 0.2), ("YC", 0.1)),
        steps: int = 120, scale: float = 0.5):
    rows = []
    for name, r in points:
        t = PAPER_TASKS[name]
        s0 = run_task(name, baseline_embedding(t.d), steps=steps,
                      scale=scale)["score"]
        m = max(16, int(t.d * r))
        for meth, emb in build_methods(name, m, scale).items():
            res = run_task(name, emb, steps=steps, scale=scale)
            rows.append({"bench": "table3", "task": name, "m_over_d": r,
                         "method": meth, "score": res["score"],
                         "ratio": res["score"] / max(s0, 1e-9)})
    return rows


if __name__ == "__main__":
    for row in run():
        print(row)
