"""Paper Fig. 3: training-time and evaluation-time ratios T_i/T_0 vs m/d.

Expected qualitative result: training time drops ~linearly with m/d
(~2x speedup at 2x compression); evaluation time (incl. Eq. 3 recovery)
stays below ~1.5x of baseline.
"""
from __future__ import annotations

from benchmarks.common import baseline_embedding, run_task
from repro.core.alternatives import BloomIO
from repro.configs.paper_tasks import PAPER_TASKS

RATIOS = (0.1, 0.2, 0.3, 0.5, 0.8)


def run(tasks=("MSD",), k: int = 4, steps: int = 150, scale: float = 0.6):
    rows = []
    for name in tasks:
        d = PAPER_TASKS[name].d
        base = run_task(name, baseline_embedding(d), steps=steps,
                        scale=scale)
        rows.append({"bench": "fig3", "task": name, "m_over_d": 1.0,
                     "train_ratio": 1.0, "eval_ratio": 1.0,
                     "train_time": base["train_time"],
                     "eval_time": base["eval_time"]})
        for r in RATIOS:
            m = max(8, int(d * r))
            res = run_task(name, BloomIO.build(d=d, m=m, k=min(k, m)),
                           steps=steps, scale=scale)
            rows.append({
                "bench": "fig3", "task": name, "m_over_d": r,
                "train_ratio": res["train_time"] / base["train_time"],
                "eval_ratio": res["eval_time"] / max(base["eval_time"],
                                                     1e-9),
                "train_time": res["train_time"],
                "eval_time": res["eval_time"]})
    return rows


if __name__ == "__main__":
    for row in run():
        print(row)
