"""Kernel microbenchmarks: the three Bloom Pallas kernels at production
shapes, with analytic TPU-v5e time models (this box is CPU — wall time of
interpret mode is meaningless; bytes-derived HBM time is the metric).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bloom import BloomSpec
from repro.kernels import ops, ref

HBM_BW = 819e9


def _cases():
    # (name, d, m, k, D, tokens)
    return [
        ("qwen3-4b.embed", 151_936, 30_464, 4, 2560, 4096),
        ("qwen1.5-0.5b.embed", 151_936, 30_464, 4, 1024, 4096),
        ("pixtral-12b.embed", 131_072, 26_112, 4, 5120, 2048),
    ]


def run(quick: bool = True):
    rows = []
    key = jax.random.PRNGKey(0)
    for name, d, m, k, D, T in _cases():
        # interpret-mode Pallas executes the grid in Python — keep the
        # measured token block small; the bytes model scales analytically.
        T = min(T, 64 if quick else 256)
        spec = BloomSpec(d=d, m=m, k=k)
        table = jax.random.normal(key, (m, D), jnp.bfloat16)
        tokens = jax.random.randint(key, (1, T), 0, d)
        idx = spec.indices_for(tokens.reshape(-1))

        # correctness vs oracle (always)
        got = ops.bloom_embed(table, tokens, spec)[0]
        want = ref.bloom_embed_ref(table, idx)
        err = float(jnp.abs(got.astype(jnp.float32)
                            - want.astype(jnp.float32)).max())

        # analytic TPU time: k rows of D bf16 per token + output write
        bytes_moved = T * (k * D * 2 + D * 2) + T * k * 4
        rows.append({"bench": "kernels", "name": name, "tokens": T,
                     "bytes": bytes_moved, "max_err": err,
                     "tpu_us_model": 1e6 * bytes_moved / HBM_BW})

        # fused CE kernel: one read of the (T, m) logits row
        logits = jax.random.normal(key, (T, m), jnp.float32)
        labels = jax.random.randint(key, (T,), 0, d)
        got = ops.bloom_ce(logits, labels, spec)
        from repro.core import losses
        want = losses.bloom_xent_label(spec, logits, labels)
        err = float(jnp.abs(got - want).max())
        bytes_moved = T * m * 4
        rows.append({"bench": "kernels", "name": name.replace(
            "embed", "ce"), "tokens": T, "bytes": bytes_moved,
            "max_err": err, "tpu_us_model": 1e6 * bytes_moved / HBM_BW})

        # decode kernel: read logp rows + d*k int32 hash matrix
        B = 8
        logp = jax.nn.log_softmax(jax.random.normal(key, (B, m)))
        got = ops.bloom_decode(logp, spec)
        H = spec.indices_for(jnp.arange(d))
        want = ref.bloom_decode_ref(logp, H)
        err = float(jnp.abs(got - want).max())
        bytes_moved = B * m * 4 + d * k * 4 + B * d * 4
        rows.append({"bench": "kernels", "name": name.replace(
            "embed", "decode"), "tokens": B, "bytes": bytes_moved,
            "max_err": err, "tpu_us_model": 1e6 * bytes_moved / HBM_BW})
    return rows


if __name__ == "__main__":
    for row in run():
        print(row)
