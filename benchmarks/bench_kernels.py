"""Kernel microbenchmarks: the Bloom Pallas kernel suite (fwd, bwd and the
fused decode-topk) at production shapes, with analytic TPU-v5e byte/time
models (this box is CPU — wall time of interpret mode is meaningless;
bytes-derived HBM time is the metric).

Every row couples the analytic bytes model of the kernel's HBM traffic at
the PRODUCTION shape with a numeric oracle check (kernel vs the pure-jnp
XLA reference) at a shape small enough for interpret mode; `check_*` fields
record the checked shape when it is scaled down.

``python -m benchmarks.bench_kernels --quick`` regenerates the committed
``BENCH_kernels.json``; ``--check`` instead compares fresh errors/ratios
against the committed file and exits non-zero on regression (wired into
CI).  The serving acceptance bar lives in the `decode_topk` rows:
``hbm_ratio`` = decode-then-top_k bytes / fused bytes must stay >= 3 at the
qwen3-4b shape.  The training acceptance bar lives in the ``*.bwd.csr``
rows (uniform + collision-heavy skew variants): the CSR-binned backward
must model >= MIN_EMBED_CSR_RATIO / MIN_DECODE_CSR_RATIO fewer bytes than
the dense-sweep rows it replaces; every ``*.bwd`` row carries
``bytes_ideal`` (the single-pass floor of the op AS A SCATTER-ADD —
embed's includes the grad table's read-modify-write) and
``bwd_bytes_ratio`` = bytes / bytes_ideal, which the embed CSR rows
legitimately push below 1.0 (sorting turns the RMW scatter into
write-once output runs — see the embed.bwd comment in run()).

The quantized-table acceptance bar (DESIGN.md §13) lives in the
``*.embed.fwd.{fp32,bf16,int8,fp8}`` and ``*.decode_topk.{bf16,int8,fp8}``
rows: the int8 rows must model >= MIN_INT8_VS_FP32 fewer total bytes than
their fp32 twin and >= MIN_INT8_VS_BF16 fewer than bf16 (table stream for
embed — the activations are bf16 either way; whole row for decode-topk,
whose quantized path also drops the (d, k) hash stream by re-deriving
indices in-kernel).  All byte widths are single-sourced from dtype
itemsize (core.quant.table_itemsize / ndarray.dtype.itemsize) — no bare
``* 2`` / ``* 4`` literals — so a storage-dtype change cannot silently
desync the models.

``--measure`` additionally wall-clocks each forward kernel numeric check
(jit warmup, then best-of-N around jax.block_until_ready) into
``measured_us`` / ``model_vs_measured`` fields.  On this CPU box the
kernels execute in interpret mode at the clamped check shapes, so the
numbers only bound sanity (the model is production-shape HBM time); on a
real TPU the same flag produces the backing measurement.  The fields are
informational — ``--check`` never gates on them — and the committed
baseline is generated WITHOUT ``--measure``.  Run through
``benchmarks/measure_env.sh`` for a quiet allocator/thread environment.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quant
from repro.core.bloom import BloomSpec
from repro.kernels import ops, ref
# M_TILE is single-sourced from the kernels so the bwd bytes models
# cannot drift from the m-tile the backward grids actually run with
from repro.kernels.common import BWD_M_TILE as M_TILE
from repro.kernels.bloom_ce import bloom_ce_pallas
from repro.kernels.bloom_csr import (modeled_decode_bwd_csr_bytes,
                                     modeled_embed_bwd_csr_bytes)
from repro.kernels.bloom_decode import bloom_decode_pallas
from repro.kernels.bloom_decode_topk import (bloom_decode_topk_pallas,
                                             modeled_hbm_bytes)
from repro.kernels.bloom_embed import bloom_embed_pallas
from repro.serving.control import plan_compaction

HBM_BW = 819e9
JSON_PATH = pathlib.Path(__file__).resolve().parent.parent / \
    "BENCH_kernels.json"
TOPK = 16
B_DECODE = 8
# serving-pool shape for the row-skipping occupancy sweep: 64 slots in
# b_tile=8 row blocks (8 blocks) — the scale where block skipping pays
B_POOL = 64
BT_POOL = 8
SPH_POOL = 16         # slots per host shard in the compaction row
MIN_OCC_RATIO = 1.5   # >= 1.5x fewer modeled bytes at <= 50% occupancy
# compaction acceptance (ISSUE 4): the densified scattered pool must
# model within 1.1x of the globally-dense pool's bytes
MAX_COMPACT_VS_DENSE = 1.1
# CSR-binned backward acceptance (ISSUE 5): the binned scatter-add must
# model >= these factors fewer HBM bytes than the dense m-tile sweep it
# replaces (both at the production shape)
MIN_EMBED_CSR_RATIO = 3.0
MIN_DECODE_CSR_RATIO = 10.0
# quantized-table acceptance (ISSUE 9, DESIGN.md §13): the int8 rows
# must model >= these factors fewer HBM bytes than their fp32 / bf16
# twins (embed compares the table stream against bf16 — activations are
# bf16 on both; decode-topk compares whole rows)
MIN_INT8_VS_FP32 = 3.0
MIN_INT8_VS_BF16 = 1.8
# itemsizes, single-sourced (satellite of ISSUE 9): every bytes model
# below derives widths from these or from the benched array's own dtype
IS_F32 = jnp.dtype(jnp.float32).itemsize
IS_I32 = jnp.dtype(jnp.int32).itemsize
# fused top-k emits (values f32, ids i32) per kept element
IS_TOPK_PAIR = IS_F32 + IS_I32
# quantized embed rows: sub-f32 storage emits bf16 activations (the
# serving compute dtype); fp32 storage emits fp32
QUANT_EMBED_SWEEP = (("float32", "fp32"), ("bfloat16", "bf16"),
                     ("int8", "int8"), ("fp8_e4m3", "fp8"))
QUANT_TOPK_SWEEP = (("bfloat16", "bf16"), ("int8", "int8"),
                    ("fp8_e4m3", "fp8"))


def _measure_us(fn, repeats: int = 3) -> float:
    """Best-of-N wall-clock of ``fn()`` in microseconds.

    One untimed call first (jit compile + Bloom cache warmup), then N
    timed calls around jax.block_until_ready — the informational
    ``--measure`` numbers (module docstring; never CI-gated).
    """
    jax.block_until_ready(fn())
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return 1e6 * best


def _measured(row: dict, fn) -> dict:
    """Attach measured_us / model_vs_measured to a bench row in place."""
    us = _measure_us(fn)
    row["measured_us"] = round(us, 1)
    row["model_vs_measured"] = round(row["tpu_us_model"] / us, 6)
    return row


def _cases():
    # (name, d, m, k, D, tokens)
    return [
        ("qwen3-4b", 151_936, 30_464, 4, 2560, 4096),
        ("qwen1.5-0.5b", 151_936, 30_464, 4, 1024, 4096),
        ("pixtral-12b", 131_072, 26_112, 4, 5120, 2048),
    ]


def _row(name, tokens, bytes_moved, err, **extra):
    return {"bench": "kernels", "name": name, "tokens": tokens,
            "bytes": bytes_moved, "max_err": err,
            "tpu_us_model": 1e6 * bytes_moved / HBM_BW, **extra}


def _max_err(a, b):
    return float(jnp.abs(jnp.asarray(a, jnp.float32)
                         - jnp.asarray(b, jnp.float32)).max())


def run(quick: bool = True, measure: bool = False):
    rows = []
    key = jax.random.PRNGKey(0)
    for name, d, m, k, D, T in _cases():
        # Bytes models are computed at the PRODUCTION shape (d, m, k, D, T
        # from _cases()).  Interpret-mode Pallas executes the grid in
        # Python, so the numeric oracle CHECK runs at a clamped token
        # count, recorded in check_* fields — never in the bytes model.
        Tc = min(T, 64 if quick else 256)
        spec = BloomSpec(d=d, m=m, k=k)
        table = jax.random.normal(key, (m, D), jnp.bfloat16)
        tokens = jax.random.randint(key, (1, Tc), 0, d)
        idx = spec.indices_for(tokens.reshape(-1))
        n_mtiles = -(-m // M_TILE)   # outer m-tile sweeps of the bwd grids
        its_tbl = table.dtype.itemsize   # serving table dtype (bf16)
        its_idx = idx.dtype.itemsize     # int32 hash/index streams

        # ---- embed fwd: k rows of D bf16 per token + output write --------
        got = ops.bloom_embed(table, tokens, spec)[0]
        want = ref.bloom_embed_ref(table, idx)
        bytes_fwd = T * (k * D * its_tbl + D * its_tbl) + T * k * its_idx
        row = _row(f"{name}.embed.fwd", T, bytes_fwd,
                   _max_err(got, want), check_tokens=Tc)
        if measure:
            _measured(row, lambda: ops.bloom_embed(table, tokens, spec))
        rows.append(row)

        # ---- embed fwd, quantized tables (DESIGN.md §13): the same
        # k-row gather with the table stored narrow in HBM.  int8 adds
        # the scale stream: one f32 per table row, gathered host-side
        # into the (T, k) scalar-prefetch operand (written once, read
        # once by the grid).  fp32/bf16 emit activations in their own
        # dtype; the sub-f32 dtypes emit bf16 (the serving compute
        # dtype).  ``table_bytes`` isolates the table+scale stream —
        # the int8 vs bf16 gate compares it (whole-row totals share the
        # bf16 activation term, diluting the table win below the bar).
        # Numeric check: kernel on the narrow table vs the XLA oracle on
        # the DEQUANTIZED table — identical values by construction (the
        # kernel dequantizes on the VMEM-resident tile, MXU accumulation
        # stays f32).
        tbl_master = table.astype(jnp.float32)
        qbytes = {}
        for td, alias in QUANT_EMBED_SWEEP:
            its = quant.table_itemsize(td)
            out_is = its if td in ("float32", "bfloat16") else \
                jnp.dtype(jnp.bfloat16).itemsize
            table_bytes = T * k * D * its
            if td == "int8":
                table_bytes += m * IS_F32 + 2 * T * k * IS_F32
            bytes_q = table_bytes + T * D * out_is + T * k * its_idx
            qbytes[alias] = (bytes_q, table_bytes)
            q, s = quant.quantize_table(tbl_master, td)
            got = bloom_embed_pallas(tbl_master, idx, table_dtype=td,
                                     out_dtype=jnp.float32)
            want = ref.bloom_embed_ref(quant.dequantize_table(q, s), idx)
            extra = {}
            if alias != "fp32":
                extra["vs_fp32_ratio"] = round(
                    qbytes["fp32"][0] / bytes_q, 4)
            if alias == "int8":
                extra["vs_bf16_ratio"] = round(
                    qbytes["bf16"][1] / table_bytes, 4)
            row = _row(f"{name}.embed.fwd.{alias}", T, bytes_q,
                       _max_err(got, want), check_tokens=Tc,
                       table_dtype=td, table_bytes=table_bytes, **extra)
            if measure:
                _measured(row, lambda td=td: bloom_embed_pallas(
                    tbl_master, idx, table_dtype=td,
                    out_dtype=jnp.float32))
            rows.append(row)

        # ---- embed bwd: blocked one-hot contraction.  The kernel sweeps
        # the m axis in M_TILE blocks and re-reads g/idx from HBM on every
        # sweep; the f32 grad table is written exactly once (blocks are
        # zero-initialized in VMEM).  `bytes_ideal` is the single-pass
        # SCATTER-ADD floor (one g read + the grad table's RMW read+write
        # — the 2*m*D*4 term — as a true data-dependent scatter pays);
        # `bwd_bytes_ratio` = bytes / that floor.  NOTE the CSR rows land
        # BELOW 1.0 on this ratio: binning sorts the scatter into
        # write-once output runs, so it never pays the RMW read — beating
        # the scatter formulation's floor is the point, not a modeling
        # error.  Numeric check runs jax.grad through the custom-VJP at a
        # reduced (tokens, d_model) shape.
        Tb = min(Tc, 16)
        idx_b = idx[:Tb]
        tbl32 = table[:, :min(D, 512)].astype(jnp.float32)
        cot = jax.random.normal(key, (Tb, tbl32.shape[1]))
        g_pal = jax.grad(lambda t: jnp.sum(
            bloom_embed_pallas(t, idx_b, interpret=True) * cot))(tbl32)
        g_ref = jax.grad(lambda t: jnp.sum(
            ref.bloom_embed_ref(t, idx_b) * cot))(tbl32)
        bytes_bwd = n_mtiles * (T * D * IS_F32 + T * k * its_idx) \
            + m * D * IS_F32
        bytes_bwd_ideal = T * D * IS_F32 + 2 * m * D * IS_F32 \
            + T * k * its_idx
        rows.append(_row(f"{name}.embed.bwd", T, bytes_bwd,
                         _max_err(g_pal, g_ref),
                         bytes_ideal=bytes_bwd_ideal,
                         bwd_bytes_ratio=round(bytes_bwd
                                               / bytes_bwd_ideal, 4),
                         check_tokens=Tb, check_dmodel=tbl32.shape[1]))

        # ---- embed bwd CSR: the binned scatter-add (bwd_impl="csr").
        # The production bytes model is distribution-INDEPENDENT: the
        # kernel DMAs exactly the E = T*k live cotangent rows whatever
        # the hash draw (pad slots are gated off), so the uniform and
        # collision-heavy rows commit the SAME bytes — the skew variant
        # exists to pin numeric correctness when every entry piles into
        # one m-tile (long multi-tile segment + all-empty pad tiles).
        # Numeric checks run jax.grad through the custom VJP at a scaled
        # (tokens, m, d_model) shape, recorded in check_* fields.
        bytes_bwd_csr = modeled_embed_bwd_csr_bytes(T, k, D, m)
        m_chk = 4096
        tblc = jax.random.normal(key, (m_chk, tbl32.shape[1]))
        for variant, hi in (("", m_chk), (".skew", min(M_TILE, m_chk))):
            idx_c = jax.random.randint(jax.random.fold_in(key, 11),
                                       (Tb, k), 0, hi)
            cot_c = jax.random.normal(jax.random.fold_in(key, 12),
                                      (Tb, tbl32.shape[1]))
            g_pal = jax.grad(lambda t: jnp.sum(
                bloom_embed_pallas(t, idx_c, interpret=True,
                                   bwd_impl="csr") * cot_c))(tblc)
            g_ref = jax.grad(lambda t: jnp.sum(
                ref.bloom_embed_ref(t, idx_c) * cot_c))(tblc)
            rows.append(_row(
                f"{name}.embed.bwd.csr{variant}", T, bytes_bwd_csr,
                _max_err(g_pal, g_ref),
                bytes_ideal=bytes_bwd_ideal,
                bwd_bytes_ratio=round(bytes_bwd_csr / bytes_bwd_ideal, 4),
                vs_dense_ratio=round(bytes_bwd / bytes_bwd_csr, 4),
                skew="collision_heavy" if variant else "uniform",
                check_tokens=Tb, check_m=m_chk,
                check_dmodel=tbl32.shape[1]))

        # ---- ce fwd: ONE read of the (T, m) f32 logits row + loss/lse ----
        logits = jax.random.normal(key, (Tc, m), jnp.float32)
        labels = jax.random.randint(key, (Tc,), 0, d)
        got = ops.bloom_ce(logits, labels, spec)
        from repro.core import losses
        want = losses.bloom_xent_label(spec, logits, labels)
        bytes_ce_fwd = T * m * IS_F32 + T * k * its_idx + 2 * T * IS_F32
        rows.append(_row(f"{name}.ce.fwd", T, bytes_ce_fwd,
                         _max_err(got, want), check_tokens=Tc))

        # ---- ce bwd: lse residual — read the row once, write dz once
        # (token-blocked grid, no m-tiling: the model IS the actual
        # kernel traffic here) ---------------------------------------------
        h = spec.indices_for(labels)
        cot = jax.random.normal(key, (Tc,))
        g_pal = jax.grad(lambda z: jnp.sum(
            bloom_ce_pallas(z, h, interpret=True) * cot))(logits)
        g_ref = jax.grad(lambda z: jnp.sum(
            ref.bloom_ce_ref(z, h) * cot))(logits)
        # ce.bwd IS the floor already (ISSUE 5 satellite: emit the ideal
        # + ratio for it too, so every *.bwd row carries the same audit
        # columns): one logits-row read + one dz write is irreducible
        bytes_ce_bwd = 2 * T * m * IS_F32 + T * (k + 2) * IS_F32
        rows.append(_row(f"{name}.ce.bwd", T, bytes_ce_bwd,
                         _max_err(g_pal, g_ref),
                         bytes_ideal=bytes_ce_bwd, bwd_bytes_ratio=1.0,
                         check_tokens=Tc))

        # ---- decode fwd: logp rows + (d, k) hash matrix + (B, d) scores --
        B = B_DECODE
        logp = jax.nn.log_softmax(jax.random.normal(key, (B, m)))
        scores = ops.bloom_decode(logp, spec)
        H = ops.cached_hash_matrix(spec)
        want_scores = ref.bloom_decode_ref(logp, H)
        bytes_dec = B * m * IS_F32 + d * k * its_idx + B * d * IS_F32
        rows.append(_row(f"{name}.decode", B, bytes_dec,
                         _max_err(scores, want_scores)))

        # ---- decode bwd: blocked scatter-add of the (B, d) cotangent;
        # like embed.bwd, the m-tile sweep re-reads g/H per M_TILE block
        # and writes dlogp once.  Full-shape MACs (B*d*m) are prohibitive
        # in interpret mode, so the numeric check runs a scaled vocab
        # slice; the bytes model is full-shape.
        d_chk, m_chk = 4096, 1024
        spec_chk = BloomSpec(d=d_chk, m=m_chk, k=k)
        H_chk = ops.cached_hash_matrix(spec_chk)
        logp_chk = jax.nn.log_softmax(jax.random.normal(key, (B, m_chk)))
        cot = jax.random.normal(key, (B, d_chk))
        g_pal = jax.grad(lambda lp: jnp.sum(
            bloom_decode_pallas(lp, H_chk, interpret=True) * cot))(logp_chk)
        g_ref = jax.grad(lambda lp: jnp.sum(
            ref.bloom_decode_ref(lp, H_chk) * cot))(logp_chk)
        bytes_dec_bwd = n_mtiles * (B * d * IS_F32 + d * k * its_idx) \
            + B * m * IS_F32
        bytes_dec_bwd_ideal = B * d * IS_F32 + d * k * its_idx \
            + B * m * IS_F32
        rows.append(_row(f"{name}.decode.bwd", B, bytes_dec_bwd,
                         _max_err(g_pal, g_ref),
                         bytes_ideal=bytes_dec_bwd_ideal,
                         bwd_bytes_ratio=round(bytes_dec_bwd
                                               / bytes_dec_bwd_ideal, 4),
                         check_d=d_chk, check_m=m_chk))

        # ---- decode bwd CSR: the shared row-scatter kernel on the
        # transposed cotangent, with H's bins cached per spec
        # (core.bloom.cached_decode_bins — binning amortizes to zero and
        # is NOT in the per-step model).  Same skew story as embed: the
        # bytes model is distribution-independent, the .skew row pins
        # numerics with the whole scaled vocab hashed into one m-tile.
        bytes_dec_bwd_csr = modeled_decode_bwd_csr_bytes(B, d, k, m)
        dc_chk, mc_chk = 2048, 1024     # nM=2 at check scale: the skew
        #                                 draw leaves m-tile 1 fully empty
        logp_c = jax.nn.log_softmax(
            jax.random.normal(jax.random.fold_in(key, 13), (B, mc_chk)))
        cot_c = jax.random.normal(jax.random.fold_in(key, 14), (B, dc_chk))
        for variant, hi in (("", mc_chk), (".skew", min(M_TILE, mc_chk))):
            H_c = jax.random.randint(jax.random.fold_in(key, 15),
                                     (dc_chk, k), 0, hi)
            g_pal = jax.grad(lambda lp: jnp.sum(
                bloom_decode_pallas(lp, H_c, interpret=True,
                                    bwd_impl="csr") * cot_c))(logp_c)
            g_ref = jax.grad(lambda lp: jnp.sum(
                ref.bloom_decode_ref(lp, H_c) * cot_c))(logp_c)
            rows.append(_row(
                f"{name}.decode.bwd.csr{variant}", B, bytes_dec_bwd_csr,
                _max_err(g_pal, g_ref),
                bytes_ideal=bytes_dec_bwd_ideal,
                bwd_bytes_ratio=round(bytes_dec_bwd_csr
                                      / bytes_dec_bwd_ideal, 4),
                vs_dense_ratio=round(bytes_dec_bwd
                                     / bytes_dec_bwd_csr, 4),
                skew="collision_heavy" if variant else "uniform",
                check_d=dc_chk, check_m=mc_chk))

        # ---- serving: decode-then-top_k vs fused decode_topk -------------
        # baseline writes the (B, d) score matrix to HBM and reads it back
        # for jax.lax.top_k
        want_v, _ = jax.lax.top_k(want_scores, TOPK)
        bytes_then = B * m * IS_F32 + d * k * its_idx \
            + 2 * B * d * IS_F32 + B * TOPK * IS_TOPK_PAIR
        base_v, _ = jax.lax.top_k(scores, TOPK)
        rows.append(_row(f"{name}.decode_then_topk", B, bytes_then,
                         _max_err(base_v, want_v), topk=TOPK))

        # fused kernel streams vocab tiles; running top-k stays in VMEM
        vals, ids = bloom_decode_topk_pallas(logp, H, TOPK)
        picked = jnp.take_along_axis(want_scores, ids, axis=-1)
        err = max(_max_err(vals, want_v), _max_err(picked, want_v))
        bytes_fused = B * m * IS_F32 + d * k * its_idx \
            + B * TOPK * IS_TOPK_PAIR
        row = _row(f"{name}.decode_topk", B, bytes_fused, err,
                   topk=TOPK, hbm_ratio=bytes_then / bytes_fused)
        if measure:
            _measured(row, lambda: bloom_decode_topk_pallas(logp, H, TOPK))
        rows.append(row)

        # ---- quantized fused decode-topk (DESIGN.md §13): the logp pool
        # is stored narrow AND the kernel re-derives the hash indices
        # in-graph (hash_spec, bit-identical to cached_hash_matrix) — the
        # (d, k) H stream, the dominant term at production d, disappears
        # entirely.  int8 adds one f32 scale per pool row, riding the
        # occupancy prefetch path.  Numeric check runs at the production
        # (B, m) like the legacy fused row, against the XLA oracle on the
        # FAKE-QUANTIZED logp (the models/io.py storage contract); int8
        # ids can legitimately flip on quantization-induced score ties
        # (XLA's FMA fusion differs per tile shape by 1 ulp), so the err
        # also scores the RETURNED ids through the oracle's score vector
        # (``picked``) — a flipped tie contributes 0 error, a wrong id
        # does not.
        for td, alias in QUANT_TOPK_SWEEP:
            q, s = quant.quantize_table(logp, td)
            want_q = ref.bloom_decode_ref(quant.dequantize_table(q, s), H)
            want_qv, _ = jax.lax.top_k(want_q, TOPK)
            vals_q, ids_q = bloom_decode_topk_pallas(
                logp, None, TOPK, table_dtype=td,
                hash_spec=(d, k, spec.seed))
            picked = jnp.take_along_axis(want_q, ids_q, axis=-1)
            err = max(_max_err(vals_q, want_qv), _max_err(picked, want_qv))
            bytes_q = modeled_hbm_bytes(
                np.ones(B, bool), B, m=m, d=d, k=k, topk=TOPK,
                logp_itemsize=quant.table_itemsize(td),
                inkernel_hash=True, row_scales=(td == "int8"))
            extra = {"vs_fp32_ratio": round(bytes_fused / bytes_q, 4)}
            if td == "int8":
                bytes_bf16 = modeled_hbm_bytes(
                    np.ones(B, bool), B, m=m, d=d, k=k, topk=TOPK,
                    logp_itemsize=quant.table_itemsize("bfloat16"),
                    inkernel_hash=True)
                extra["vs_bf16_ratio"] = round(bytes_bf16 / bytes_q, 4)
            row = _row(f"{name}.decode_topk.{alias}", B, bytes_q, err,
                       topk=TOPK, table_dtype=td, inkernel_hash=True,
                       **extra)
            if measure:
                _measured(row, lambda td=td: bloom_decode_topk_pallas(
                    logp, None, TOPK, table_dtype=td,
                    hash_spec=(d, k, spec.seed)))
            rows.append(row)

        # ---- serving pool: row-skipping decode-topk vs slot occupancy ----
        # At pool size (B_POOL slots, b_tile row blocks) the grid streams
        # (b_tile*m logp + d*k H) bytes per VISITED row block — H is
        # re-streamed once per block because the vocab axis is innermost.
        # The dense grid visits all nB blocks regardless of occupancy; the
        # occupancy-prefetched grid (DESIGN.md §8) visits only the nA
        # blocks holding a live slot, so modeled HBM bytes scale with
        # active slots.  CI gates hbm_ratio_vs_full >= MIN_OCC_RATIO at
        # <= 50% occupancy.  Numeric check runs the skip grid against the
        # dense grid at a clamped (d, m) — interpret mode executes the
        # grid in Python — recorded in check_* fields.
        nB = B_POOL // BT_POOL
        d_chk, m_chk = 4096, 512
        spec_occ = BloomSpec(d=d_chk, m=m_chk, k=k)
        H_occ = ops.cached_hash_matrix(spec_occ)
        logp_occ = jax.nn.log_softmax(
            jax.random.normal(key, (B_POOL, m_chk)))
        dense_v, dense_i = bloom_decode_topk_pallas(
            logp_occ, H_occ, TOPK, b_tile=BT_POOL, v_tile=512,
            interpret=True)
        # the bytes model is single-sourced from the kernel module so it
        # can never drift from the grid it describes
        bytes_full = modeled_hbm_bytes(np.ones(B_POOL, bool), BT_POOL,
                                       m=m, d=d, k=k, topk=TOPK)
        for occ_name, frac in (("occ100", 1.0), ("occ50", 0.5),
                               ("occ12", 0.125)):
            n_act = int(B_POOL * frac)
            active = np.arange(B_POOL) < n_act
            nA = -(-n_act // BT_POOL)       # blocks holding a live slot
            bytes_occ = modeled_hbm_bytes(active, BT_POOL, m=m, d=d, k=k,
                                          topk=TOPK)
            vals_s, ids_s = bloom_decode_topk_pallas(
                logp_occ, H_occ, TOPK, b_tile=BT_POOL, v_tile=512,
                interpret=True, active=jnp.asarray(active))
            live = np.repeat(active.reshape(nB, BT_POOL).any(axis=1),
                             BT_POOL)
            err = max(_max_err(vals_s[live], dense_v[live]),
                      float(jnp.abs(ids_s[live]
                                    - dense_i[live]).max()))
            if not live.all():
                dead_ok = bool((np.asarray(vals_s)[~live]
                                == -np.inf).all()
                               and (np.asarray(ids_s)[~live] == 0).all())
                if not dead_ok:      # skipped rows must read (-inf, 0)
                    err = float("inf")
            rows.append(_row(
                f"{name}.decode_topk.{occ_name}", B_POOL, bytes_occ, err,
                topk=TOPK, occupancy=frac, active_slots=n_act,
                visited_blocks=nA, total_blocks=nB,
                hbm_ratio_vs_full=round(bytes_full / bytes_occ, 4),
                check_d=d_chk, check_m=m_chk))

        # ---- serving pool compaction: scattered vs densified occupancy
        # 4 host shards x SPH_POOL slots, 8 live per host on even local
        # slots: EVERY b_tile row block holds a live slot, so the
        # row-skipping grid recovers nothing (the b_tile-bound loss).
        # plan_compaction — the SAME planner the serving control plane
        # runs — packs each host's live slots into its dense prefix;
        # visited blocks halve and the compacted model lands exactly on
        # the globally-dense model.  CI gates >= MIN_OCC_RATIO recovery
        # and <= MAX_COMPACT_VS_DENSE of dense (ISSUE 4 acceptance).
        scattered = np.zeros(B_POOL, bool)
        scattered[::2] = True                      # 50% live, all blocks
        occupant = [s if scattered[s] else -1 for s in range(B_POOL)]
        perm = np.asarray(
            plan_compaction(occupant, SPH_POOL, threshold=0.0), np.int32)
        compacted = scattered[perm]
        dense = np.arange(B_POOL) < int(scattered.sum())
        b_sc = modeled_hbm_bytes(scattered, BT_POOL, m=m, d=d, k=k,
                                 topk=TOPK)
        b_co = modeled_hbm_bytes(compacted, BT_POOL, m=m, d=d, k=k,
                                 topk=TOPK)
        b_de = modeled_hbm_bytes(dense, BT_POOL, m=m, d=d, k=k, topk=TOPK)
        # numeric: the permuted pool recovers the SAME top-k per live
        # slot — compaction is a pure row move
        v_sc, i_sc = bloom_decode_topk_pallas(
            logp_occ, H_occ, TOPK, b_tile=BT_POOL, v_tile=512,
            interpret=True, active=jnp.asarray(scattered))
        v_co, i_co = bloom_decode_topk_pallas(
            logp_occ[perm], H_occ, TOPK, b_tile=BT_POOL, v_tile=512,
            interpret=True, active=jnp.asarray(compacted))
        live_new = np.flatnonzero(compacted)
        err = max(_max_err(v_co[live_new], v_sc[perm[live_new]]),
                  float(jnp.abs(i_co[live_new]
                                - i_sc[perm[live_new]]).max()))
        rows.append(_row(
            f"{name}.decode_topk.scatter_compact", B_POOL, b_co, err,
            topk=TOPK, occupancy=0.5,
            active_slots=int(scattered.sum()),
            slots_per_host=SPH_POOL,
            bytes_scattered=b_sc, bytes_dense=b_de,
            hbm_ratio_vs_scattered=round(b_sc / b_co, 4),
            vs_dense_ratio=round(b_co / b_de, 4),
            check_d=d_chk, check_m=m_chk))
    return rows


def write_json(rows, path=JSON_PATH, quick=True):
    """Write the committed bytes-model snapshot.

    Only --quick rows are accepted as the CI baseline: bytes models are
    production-shape either way, but check_* shapes (and thus max_err)
    depend on quick, and CI runs --quick --check — a full-run baseline
    would compare mismatched check shapes.
    """
    if not quick:
        raise ValueError("the committed baseline is generated with --quick "
                         "only; rerun with quick=True")
    # measured wall-clock is machine-dependent — never committed
    rows = [{k: v for k, v in r.items()
             if k not in ("measured_us", "model_vs_measured")}
            for r in rows]
    payload = {
        "generated_by": "PYTHONPATH=src python -m benchmarks.bench_kernels"
                        " --quick",
        "hbm_bw_bytes_per_s": HBM_BW,
        "rows": rows,
    }
    path.write_text(json.dumps(payload, indent=1, sort_keys=True) + "\n")
    return path


def check_against(rows, path=JSON_PATH, err_slack=1e-3,
                  min_topk_ratio=3.0) -> list[str]:
    """Compare fresh rows to the committed JSON; return failure messages."""
    committed = {r["name"]: r for r in
                 json.loads(path.read_text())["rows"]}
    failures = []
    fresh_names = {r["name"] for r in rows}
    for gone in sorted(set(committed) - fresh_names):
        failures.append(f"{gone}: bench row disappeared from the fresh run "
                        "— a kernel bench was dropped or renamed")
    for r in rows:
        old = committed.get(r["name"])
        if old is None:
            failures.append(f"{r['name']}: missing from {path.name} — "
                            "regenerate with --quick")
            continue
        if r["max_err"] > old["max_err"] + err_slack:
            failures.append(
                f"{r['name']}: max_err regressed "
                f"{old['max_err']:.2e} -> {r['max_err']:.2e}")
        # the bytes model is deterministic given the production shapes —
        # any drift means kernel tiling and model went out of sync and
        # the baseline must be regenerated deliberately
        if r["bytes"] != old["bytes"]:
            failures.append(
                f"{r['name']}: bytes model changed "
                f"{old['bytes']} -> {r['bytes']}")
        if r["name"].endswith(".decode_topk") \
                and r.get("hbm_ratio", 0.0) < min_topk_ratio:
            failures.append(
                f"{r['name']}: fused top-k HBM ratio {r['hbm_ratio']:.2f} "
                f"< {min_topk_ratio} — serving fusion no longer pays")
        # quantized-table acceptance bars (ISSUE 9, DESIGN.md §13): the
        # int8 rows must model >= MIN_INT8_VS_FP32 fewer total bytes
        # than their fp32 twin (embed.fwd.fp32 / the legacy f32
        # decode_topk row) and >= MIN_INT8_VS_BF16 fewer than bf16
        # (table stream for embed, whole row for decode-topk); the fp8
        # rows ride the same drift check via bytes equality above
        if r["name"].endswith(".embed.fwd.int8") \
                or r["name"].endswith(".decode_topk.int8"):
            if r.get("vs_fp32_ratio", 0.0) < MIN_INT8_VS_FP32:
                failures.append(
                    f"{r['name']}: int8/fp32 bytes ratio "
                    f"{r.get('vs_fp32_ratio', 0.0):.2f} < "
                    f"{MIN_INT8_VS_FP32} — int8 storage no longer closes "
                    "the table-stream gap")
            if r.get("vs_bf16_ratio", 0.0) < MIN_INT8_VS_BF16:
                failures.append(
                    f"{r['name']}: int8/bf16 bytes ratio "
                    f"{r.get('vs_bf16_ratio', 0.0):.2f} < "
                    f"{MIN_INT8_VS_BF16} — int8 no longer beats plain "
                    "bf16 storage meaningfully")
        # CSR-binned backward acceptance bars (ISSUE 5): the binned
        # scatter-add must model >= MIN_*_CSR_RATIO fewer HBM bytes than
        # the dense m-tile sweep at the production shape, on the uniform
        # AND the collision-heavy (skew) rows alike — the model is
        # distribution-independent, so a diverging skew row means the
        # kernel/model went out of sync
        if ".embed.bwd.csr" in r["name"] \
                and r.get("vs_dense_ratio", 0.0) < MIN_EMBED_CSR_RATIO:
            failures.append(
                f"{r['name']}: CSR/dense bytes ratio "
                f"{r.get('vs_dense_ratio', 0.0):.2f} < "
                f"{MIN_EMBED_CSR_RATIO} — the binned embed backward no "
                "longer closes the backward bytes gap")
        if ".decode.bwd.csr" in r["name"] \
                and r.get("vs_dense_ratio", 0.0) < MIN_DECODE_CSR_RATIO:
            failures.append(
                f"{r['name']}: CSR/dense bytes ratio "
                f"{r.get('vs_dense_ratio', 0.0):.2f} < "
                f"{MIN_DECODE_CSR_RATIO} — the binned decode backward "
                "no longer closes the backward bytes gap")
        # row-skipping acceptance bar (ISSUE 3): at <= 50% slot occupancy
        # the occupancy grid must model >= MIN_OCC_RATIO fewer HBM bytes
        # than the full pool
        if (".decode_topk.occ" in r["name"]
                and not r["name"].endswith(".occ100")
                and r.get("occupancy", 1.0) <= 0.5
                and r.get("hbm_ratio_vs_full", 0.0) < MIN_OCC_RATIO):
            failures.append(
                f"{r['name']}: occupancy bytes ratio "
                f"{r.get('hbm_ratio_vs_full', 0.0):.2f} < {MIN_OCC_RATIO} "
                "— row skipping no longer pays at partial occupancy")
        # compaction acceptance bar (ISSUE 4): densifying a scattered
        # pool must recover >= MIN_OCC_RATIO of the modeled bytes AND
        # land within MAX_COMPACT_VS_DENSE of the globally-dense model
        if r["name"].endswith(".decode_topk.scatter_compact"):
            if r.get("hbm_ratio_vs_scattered", 0.0) < MIN_OCC_RATIO:
                failures.append(
                    f"{r['name']}: compaction bytes recovery "
                    f"{r.get('hbm_ratio_vs_scattered', 0.0):.2f} < "
                    f"{MIN_OCC_RATIO} — densifying scattered slots no "
                    "longer pays")
            if r.get("vs_dense_ratio", float("inf")) \
                    > MAX_COMPACT_VS_DENSE:
                failures.append(
                    f"{r['name']}: compacted bytes are "
                    f"{r.get('vs_dense_ratio', float('inf')):.2f}x the "
                    f"dense-occupancy model (> {MAX_COMPACT_VS_DENSE}) — "
                    "per-host packing is leaving b_tile tails behind")
    return failures


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--check", action="store_true",
                    help="compare against committed BENCH_kernels.json and "
                         "fail on max_err / hbm_ratio regressions")
    ap.add_argument("--measure", action="store_true",
                    help="wall-clock the forward kernels (warmup + "
                         "block_until_ready, best of 3) into measured_us "
                         "/ model_vs_measured fields — informational, "
                         "never gated, never committed; use "
                         "benchmarks/measure_env.sh for env hygiene")
    args = ap.parse_args()
    if args.check and not args.quick:
        # the committed baseline records --quick check shapes; comparing
        # full-run max_err against it would validate mismatched shapes
        ap.error("--check requires --quick (the baseline is "
                 "--quick-generated)")
    rows = run(quick=args.quick, measure=args.measure)
    for row in rows:
        print(row)
    if args.check:
        failures = check_against(rows)
        for f in failures:
            print("REGRESSION:", f, file=sys.stderr)
        if failures:
            sys.exit(1)
        print(f"check ok: {len(rows)} rows vs {JSON_PATH.name}")
    elif args.quick:
        print("wrote", write_json(rows, quick=True))
    else:
        print(f"not writing {JSON_PATH.name}: the committed baseline is "
              "--quick-generated; rerun with --quick")


if __name__ == "__main__":
    main()
