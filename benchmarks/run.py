"""Benchmark suite entry: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:
  * paper-reproduction benches report score ratios (derived = S_i/S_0) and
    train-time per step (us_per_call);
  * kernel benches report the analytic TPU HBM-time model (us_per_call)
    and max error vs the jnp oracle (derived);
  * roofline rows report the dominant-term seconds (us_per_call) and
    the MODEL_FLOPS/HLO_FLOPs ratio (derived).

Full-budget run: PYTHONPATH=src python -m benchmarks.run
Quick run:       PYTHONPATH=src python -m benchmarks.run --quick
"""
from __future__ import annotations

import argparse
import sys
import time


def _csv(name, us, derived):
    print(f"{name},{us:.2f},{derived:.4f}", flush=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset: fig1,fig2,fig3,table3,"
                         "table5,kernels,serving,roofline")
    args = ap.parse_args()
    quick = args.quick
    steps = 60 if quick else 150
    scale = 0.35 if quick else 0.6
    only = set(args.only.split(",")) if args.only else None

    def want(name):
        return only is None or name in only

    if want("fig1"):
        from benchmarks import bench_fig1_compression as f1
        tasks = ("MSD",) if quick else ("MSD", "ML", "AMZ")
        for row in f1.run(tasks=tasks, steps=steps, scale=scale):
            _csv(f"fig1.{row['task']}.m{row['m_over_d']:.2f}",
                 0.0, row["ratio"])

    if want("fig2"):
        from benchmarks import bench_fig2_hashes as f2
        for row in f2.run(steps=steps, scale=scale):
            _csv(f"fig2.{row['task']}.k{row['k']}", 0.0, row["ratio"])

    if want("fig3"):
        from benchmarks import bench_fig3_time as f3
        for row in f3.run(steps=steps, scale=scale):
            _csv(f"fig3.{row['task']}.m{row['m_over_d']:.2f}.train",
                 1e6 * row["train_time"] / max(steps, 1),
                 row["train_ratio"])
            _csv(f"fig3.{row['task']}.m{row['m_over_d']:.2f}.eval",
                 1e6 * row["eval_time"], row["eval_ratio"])

    if want("table3"):
        from benchmarks import bench_table3_alternatives as t3
        points = ((("MSD", 0.1),) if quick
                  else (("MSD", 0.1), ("MSD", 0.2), ("YC", 0.1)))
        for row in t3.run(points=points, steps=steps, scale=scale):
            _csv(f"table3.{row['task']}.m{row['m_over_d']:.2f}."
                 f"{row['method'].replace(' ', '')}", 0.0, row["ratio"])

    if want("table5"):
        from benchmarks import bench_table5_cbe as t5
        points = ((("MSD", 0.1),) if quick
                  else (("MSD", 0.1), ("MSD", 0.3), ("AMZ", 0.2)))
        for row in t5.run(points=points, steps=steps, scale=scale):
            _csv(f"table5.{row['task']}.m{row['m_over_d']:.2f}.BE",
                 0.0, row["be_ratio"])
            _csv(f"table5.{row['task']}.m{row['m_over_d']:.2f}.CBE",
                 0.0, row["cbe_ratio"])

    if want("kernels"):
        from benchmarks import bench_kernels as bk
        rows = bk.run(quick=quick)
        for row in rows:
            _csv(f"kernels.{row['name']}", row["tpu_us_model"],
                 row["max_err"])
        if quick:
            # Refresh the committed bytes-model snapshot — but never
            # launder a regression into the CI baseline: refuse to
            # overwrite when the fresh rows regress vs the committed file
            # (regenerate deliberately via bench_kernels --quick after
            # vetting the change).  No baseline yet => write the first one.
            if not bk.JSON_PATH.exists():
                bk.write_json(rows, quick=True)
            else:
                failures = bk.check_against(rows)
                if failures:
                    for f in failures:
                        print(f"kernels: NOT refreshing "
                              f"{bk.JSON_PATH.name}: {f}", file=sys.stderr)
                else:
                    bk.write_json(rows, quick=True)

    if want("serving"):
        from benchmarks import bench_serving as bs
        rows = bs.run()      # one seeded sim per arch — no quick/full split
        for row in rows:
            if row["name"].endswith(".speedup"):
                _csv(f"serving.{row['name']}", 0.0,
                     row["decode_step_speedup"])
            else:
                _csv(f"serving.{row['name']}", 1e6 * row["wall_s"],
                     row["utilization"])
        # same no-laundering policy as the kernel baseline: refresh only
        # when the fresh deterministic schedule matches the committed one
        if not bs.JSON_PATH.exists():
            bs.write_json(rows)
        else:
            failures = bs.check_against(rows)
            if failures:
                for f in failures:
                    print(f"serving: NOT refreshing {bs.JSON_PATH.name}: "
                          f"{f}", file=sys.stderr)
            else:
                bs.write_json(rows)

    if want("roofline"):
        from benchmarks import roofline_table as rt
        for row in rt.run():
            _csv(f"roofline.{row['arch']}.{row['shape']}",
                 1e6 * max(row["compute_s"], row["memory_s"],
                           row["collective_s"]),
                 row["model_flops_ratio"])


if __name__ == "__main__":
    main()
