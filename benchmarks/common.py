"""Shared benchmark engine: train one (task x IOEmbedding) combination and
report (score, train_time, eval_time) — the measurement behind every paper
figure/table reproduction.

Baseline (S_0) = identity encoding (m == d, k == 1 -> exact one-hot space),
matching the paper's plain-network baseline.  All scores are reported as
ratios S_i/S_0 like the paper.
"""
from __future__ import annotations

import functools
import time
from typing import Callable, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.paper_tasks import PAPER_TASKS, PaperTask
from repro.core.alternatives import BloomIO, IOEmbedding
from repro.data.pipeline import BatchIterator
from repro.data import synthetic
from repro.models import recommender as rec
from repro.models import rnn
from repro.optim import optimizers as opt_lib
from repro.train import metrics as M


@functools.lru_cache(maxsize=None)
def task_data(name: str, scale: float = 1.0):
    t = PAPER_TASKS[name]
    n = max(int(t.n * scale), 300)
    if t.kind == "recsys":
        return synthetic.make_recsys(n=n, d=t.d, mean_items=t.mean_items,
                                     seed=hash(name) % 2**31)
    if t.kind == "classify":
        return synthetic.make_classification(
            n=n, d=t.d, n_classes=t.n_classes, mean_items=t.mean_items,
            seed=hash(name) % 2**31)
    return synthetic.make_sessions(n_sessions=n, d=t.d,
                                   mean_len=t.mean_items,
                                   seed=hash(name) % 2**31)


def baseline_embedding(d: int) -> BloomIO:
    """Identity encoding: the paper's no-embedding Baseline."""
    return BloomIO.build(d=d, m=d, k=1, name="Baseline")


# --------------------------------------------------------------------------
# Feed-forward recommender tasks (ML / MSD / AMZ / BC)
# --------------------------------------------------------------------------

def run_recsys(task: PaperTask, emb: IOEmbedding, steps: int = 120,
               seed: int = 0, scale: float = 1.0) -> Dict[str, float]:
    data = task_data(task.name, scale)
    key = jax.random.PRNGKey(seed)
    params = rec.recommender_init(key, emb, list(task.arch_hidden))
    tx = opt_lib.make_optimizer(task.optimizer, task.learning_rate,
                                momentum=task.momentum,
                                grad_clip_norm=task.grad_clip)
    opt_state = tx.init(params)

    @jax.jit
    def step(params, opt_state, p, q):
        def loss(pr):
            return rec.recommender_loss(pr, emb, p, q)
        g = jax.grad(loss)(params)
        upd, opt_state2 = tx.update(g, opt_state, params)
        return opt_lib.apply_updates(params, upd), opt_state2

    it = BatchIterator(list(data.train()), task.batch, seed=seed)
    p0, q0 = next(it)
    params, opt_state = step(params, opt_state, jnp.asarray(p0),
                             jnp.asarray(q0))  # compile warmup
    t0 = time.perf_counter()
    for _ in range(steps):
        p, q = next(it)
        params, opt_state = step(params, opt_state, jnp.asarray(p),
                                 jnp.asarray(q))
    jax.block_until_ready(params)
    train_time = time.perf_counter() - t0

    p_te, q_te = data.test()
    score_fn = jax.jit(lambda pr, p: rec.recommender_scores(pr, emb, p))
    scores = np.asarray(score_fn(params, jnp.asarray(p_te)))  # warm
    t0 = time.perf_counter()
    scores = np.asarray(score_fn(params, jnp.asarray(p_te)))
    eval_time = time.perf_counter() - t0
    return {"score": M.mean_average_precision(scores, q_te, p_te),
            "train_time": train_time, "eval_time": eval_time}


# --------------------------------------------------------------------------
# Classification task (CADE): input embedding only
# --------------------------------------------------------------------------

def run_classify(task: PaperTask, emb: IOEmbedding, steps: int = 120,
                 seed: int = 0, scale: float = 1.0) -> Dict[str, float]:
    p_all, labels, n_train, _ = task_data(task.name, scale)
    key = jax.random.PRNGKey(seed)
    params = rec.ff_init(key, emb.m_in, list(task.arch_hidden),
                         task.n_classes)
    tx = opt_lib.make_optimizer(task.optimizer, task.learning_rate)
    opt_state = tx.init(params)

    @jax.jit
    def step(params, opt_state, p, y):
        def loss(pr):
            x = emb.encode_input(p)
            logits = rec.ff_apply(pr, x)
            from repro.core import losses
            return losses.softmax_xent_label(logits, y).mean()
        g = jax.grad(loss)(params)
        upd, opt_state2 = tx.update(g, opt_state, params)
        return opt_lib.apply_updates(params, upd), opt_state2

    it = BatchIterator([p_all[:n_train], labels[:n_train]], task.batch,
                       seed=seed)
    p0, y0 = next(it)
    params, opt_state = step(params, opt_state, jnp.asarray(p0),
                             jnp.asarray(y0))
    t0 = time.perf_counter()
    for _ in range(steps):
        p, y = next(it)
        params, opt_state = step(params, opt_state, jnp.asarray(p),
                                 jnp.asarray(y))
    jax.block_until_ready(params)
    train_time = time.perf_counter() - t0

    p_te, y_te = p_all[n_train:], labels[n_train:]
    score_fn = jax.jit(
        lambda pr, p: rec.ff_apply(pr, emb.encode_input(p)))
    logits = np.asarray(score_fn(params, jnp.asarray(p_te)))
    t0 = time.perf_counter()
    logits = np.asarray(score_fn(params, jnp.asarray(p_te)))
    eval_time = time.perf_counter() - t0
    return {"score": M.accuracy(logits, y_te),
            "train_time": train_time, "eval_time": eval_time}


# --------------------------------------------------------------------------
# Session tasks (YC GRU / PTB LSTM): next-item prediction
# --------------------------------------------------------------------------

def run_session(task: PaperTask, emb: IOEmbedding, steps: int = 120,
                seed: int = 0, scale: float = 1.0) -> Dict[str, float]:
    seqs, n_train = task_data(task.name, scale)
    key = jax.random.PRNGKey(seed)
    d_h = task.arch_hidden[0]
    params = rnn.rnn_lm_init(key, task.cell, emb.m_in, d_h, emb.m_out)
    tx = opt_lib.make_optimizer(task.optimizer, task.learning_rate,
                                momentum=task.momentum,
                                grad_clip_norm=task.grad_clip)
    opt_state = tx.init(params)

    def encode_seq(s):
        # (B, T) item ids -> (B, T, m_in); -1 padded positions are zeros
        return emb.encode_input(s[..., None])

    @jax.jit
    def step(params, opt_state, s):
        x_in, tgt = s[:, :-1], s[:, 1:]
        valid = (tgt >= 0) & (x_in >= 0)

        def loss(pr):
            x = encode_seq(x_in)
            logits = rnn.rnn_lm_apply(pr, task.cell, x)
            B, T, mo = logits.shape
            per = emb.loss(logits.reshape(B * T, mo),
                           tgt.reshape(B * T, 1))
            return (per * valid.reshape(-1)).sum() / jnp.maximum(
                valid.sum(), 1)

        g = jax.grad(loss)(params)
        upd, opt_state2 = tx.update(g, opt_state, params)
        return opt_lib.apply_updates(params, upd), opt_state2

    it = BatchIterator([seqs[:n_train]], task.batch, seed=seed)
    (s0,) = next(it)
    params, opt_state = step(params, opt_state, jnp.asarray(s0))
    t0 = time.perf_counter()
    for _ in range(steps):
        (s,) = next(it)
        params, opt_state = step(params, opt_state, jnp.asarray(s))
    jax.block_until_ready(params)
    train_time = time.perf_counter() - t0

    # eval: RR of the true next item after the penultimate position
    test = seqs[n_train:]
    lengths = (test >= 0).sum(1)
    keep = lengths >= 2
    test, lengths = test[keep], lengths[keep]
    ctx = test.copy()
    tgt = np.zeros(len(test), np.int64)
    for i, L in enumerate(lengths):
        tgt[i] = test[i, L - 1]
        ctx[i, L - 1:] = -1

    @jax.jit
    def score_last(params, s, idx):
        x = encode_seq(s)
        hs = rnn.rnn_lm_apply(params, task.cell, x)
        last = jnp.take_along_axis(
            hs, idx[:, None, None].astype(jnp.int32), axis=1)[:, 0]
        return emb.decode(last)

    idx = jnp.asarray(lengths - 2)
    scores = np.asarray(score_last(params, jnp.asarray(ctx), idx))
    t0 = time.perf_counter()
    scores = np.asarray(score_last(params, jnp.asarray(ctx), idx))
    eval_time = time.perf_counter() - t0
    return {"score": M.reciprocal_rank(scores, tgt),
            "train_time": train_time, "eval_time": eval_time}


RUNNERS: Dict[str, Callable] = {
    "recsys": run_recsys,
    "classify": run_classify,
    "session": run_session,
}


def run_task(name: str, emb: IOEmbedding, steps: int = 120, seed: int = 0,
             scale: float = 1.0) -> Dict[str, float]:
    task = PAPER_TASKS[name]
    return RUNNERS[task.kind](task, emb, steps=steps, seed=seed,
                              scale=scale)
