"""Paper Fig. 1: score ratio S_i/S_0 as a function of m/d at k = 4.

Expected qualitative result (paper Sec. 5.1): curves bend toward the
top-left — S_i/S_0 >= ~0.9 down to m/d = 0.2 for the sparse tasks; the
dense ML-like task degrades faster.
"""
from __future__ import annotations

from benchmarks.common import baseline_embedding, run_task
from repro.core.alternatives import BloomIO
from repro.configs.paper_tasks import PAPER_TASKS

RATIOS = (0.1, 0.2, 0.3, 0.5, 0.8)


def run(tasks=("MSD", "ML"), k: int = 4, steps: int = 120,
        scale: float = 0.6, seeds=(0,)):
    rows = []
    for name in tasks:
        d = PAPER_TASKS[name].d
        base = [run_task(name, baseline_embedding(d), steps=steps,
                         seed=s, scale=scale) for s in seeds]
        s0 = sum(b["score"] for b in base) / len(base)
        rows.append({"bench": "fig1", "task": name, "method": "Baseline",
                     "m_over_d": 1.0, "score": s0, "ratio": 1.0})
        for r in RATIOS:
            m = max(8, int(d * r))
            vals = [run_task(name, BloomIO.build(d=d, m=m, k=min(k, m),
                                                 seed=s),
                             steps=steps, seed=s, scale=scale)["score"]
                    for s in seeds]
            si = sum(vals) / len(vals)
            rows.append({"bench": "fig1", "task": name, "method": f"BE k={k}",
                         "m_over_d": r, "score": si,
                         "ratio": si / max(s0, 1e-9)})
    return rows


if __name__ == "__main__":
    for row in run():
        print(row)
