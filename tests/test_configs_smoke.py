"""Per-arch reduced smoke tests (deliverable f): instantiate each assigned
architecture's reduced config and run one forward + one train step on CPU,
asserting output shapes and no NaNs.  Full configs are exercised only via
the dry-run (ShapeDtypeStruct, no allocation)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs.base import SHAPE_BY_NAME, TrainConfig
from repro.launch import steps as steps_lib

KEY = jax.random.PRNGKey(0)


def _smoke_batch(cfg, B=2, S=16):
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    batch = {"tokens": toks}
    if cfg.family in ("vlm", "audio"):
        batch["embeds"] = jax.random.normal(
            KEY, (B, 8, cfg.d_model)).astype(jnp.dtype(cfg.dtype))
    return batch


@pytest.mark.parametrize("arch", list(configs.ARCH_NAMES))
def test_smoke_forward_and_train_step(arch):
    cfg = configs.get_smoke_config(arch)
    init = steps_lib.init_fn_for(cfg)
    params = init(KEY)
    batch = _smoke_batch(cfg)

    # forward
    loss_fn = steps_lib.loss_fn_for(cfg)
    loss, metrics = loss_fn(params, batch)
    assert np.isfinite(float(loss)), f"{arch}: non-finite loss"

    # one full train step (grads + optimizer update)
    tc = TrainConfig(optimizer="adamw", learning_rate=1e-3,
                     grad_clip_norm=1.0, warmup_steps=0)
    step, optimizer = steps_lib.make_train_step(cfg, tc)
    opt_state = optimizer.init(params)
    new_params, _, m2 = jax.jit(step)(params, opt_state, batch)
    assert np.isfinite(float(m2["loss"]))
    # params actually changed
    delta = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()),
                         params, new_params)
    assert max(jax.tree.leaves(delta)) > 0
    # no NaNs anywhere in updated params
    finite = jax.tree.map(lambda a: bool(jnp.isfinite(a).all()), new_params)
    assert all(jax.tree.leaves(finite)), f"{arch}: NaN in updated params"


@pytest.mark.parametrize("arch", list(configs.ARCH_NAMES))
def test_full_config_matches_assignment(arch):
    """Full configs carry the exact assigned hyperparameters."""
    spec = {
        "pixtral-12b": (40, 5120, 32, 8, 14336, 131072),
        "phi3-mini-3.8b": (32, 3072, 32, 32, 8192, 32064),
        "granite-8b": (36, 4096, 32, 8, 14336, 49152),
        "qwen3-4b": (36, 2560, 32, 8, 9728, 151936),
        "qwen1.5-0.5b": (24, 1024, 16, 16, 2816, 151936),
        "whisper-small": (12, 768, 12, 12, 3072, 51865),
        "deepseek-moe-16b": (28, 2048, 16, 16, 1408, 102400),
        "olmoe-1b-7b": (16, 2048, 16, 16, 1024, 50304),
        "jamba-v0.1-52b": (32, 4096, 32, 8, 14336, 65536),
        "mamba2-1.3b": (48, 2048, None, None, 0, 50280),
    }[arch]
    cfg = configs.get_config(arch)
    L, D, H, KV, FF, V = spec
    assert cfg.num_layers == L and cfg.d_model == D
    assert cfg.d_ff == FF and cfg.vocab == V
    if H is not None:
        assert cfg.num_heads == H and cfg.num_kv_heads == KV


def test_moe_configs():
    ds = configs.get_config("deepseek-moe-16b")
    assert ds.moe.num_experts == 64 and ds.moe.top_k == 6
    assert ds.moe.num_shared == 2
    ol = configs.get_config("olmoe-1b-7b")
    assert ol.moe.num_experts == 64 and ol.moe.top_k == 8
    jb = configs.get_config("jamba-v0.1-52b")
    assert jb.moe.num_experts == 16 and jb.moe.top_k == 2
    assert jb.attn_layer_period == 8
    mb = configs.get_config("mamba2-1.3b")
    assert mb.mamba.d_state == 128


def test_param_counts_match_published_sizes():
    """Analytic parameter counts near the published model sizes (dense IO)."""
    expect = {"pixtral-12b": 12.2e9, "phi3-mini-3.8b": 3.8e9,
              "granite-8b": 8.2e9, "qwen3-4b": 4.4e9,
              "qwen1.5-0.5b": 0.46e9, "deepseek-moe-16b": 16.9e9,
              "olmoe-1b-7b": 6.9e9, "jamba-v0.1-52b": 51.5e9,
              "mamba2-1.3b": 1.4e9}
    for arch, want in expect.items():
        got = configs.get_config(arch, bloom=False).param_count()
        assert abs(got - want) / want < 0.12, f"{arch}: {got/1e9:.2f}B"


def test_cell_grid_has_32_runnable_and_8_documented_skips():
    cells = list(configs.all_cells())
    assert len(cells) == 40
    runnable = [c for c in cells if c[2]]
    skipped = [c for c in cells if not c[2]]
    assert len(runnable) == 32 and len(skipped) == 8
    for arch, shape, _, reason in skipped:
        assert shape == "long_500k" and "quadratic" in reason


def test_input_specs_cover_all_runnable_cells():
    for arch, shape_name, ok, _ in configs.all_cells():
        if not ok:
            continue
        cfg = configs.get_config(arch)
        shape = SHAPE_BY_NAME[shape_name]
        spec = configs.input_specs(cfg, shape)
        assert "tokens" in spec
        if shape.kind == "decode":
            assert spec["tokens"].shape == (shape.global_batch, 1)
            caches = configs.cache_specs(cfg, shape)
            assert len(jax.tree.leaves(caches)) > 0
        if cfg.family in ("vlm", "audio") and shape.kind != "decode":
            assert "embeds" in spec


def test_bloom_m_alignment():
    for arch in configs.ARCH_NAMES:
        cfg = configs.get_config(arch)
        assert cfg.m_vocab % 256 == 0, arch  # TPU-lane / TP alignment
        assert cfg.m_vocab < cfg.vocab
