"""HT / ECOC / PMI / CCA baselines behind the IOEmbedding interface."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
import scipy.sparse as sp

from repro.core.alternatives import (BloomIO, CCAIO, ECOCIO, PMIIO,
                                     hashing_trick)


def _X(n=300, d=50, seed=0):
    X = sp.random(n, d, density=0.08, format="csr",
                  random_state=np.random.default_rng(seed))
    X.data[:] = 1.0
    return X


P_IN = jnp.array([[1, 5, 9, -1], [0, -1, -1, -1]])
Q_OUT = jnp.array([[2, 3, -1, -1], [7, 8, -1, -1]])


def _check_interface(emb, d=50):
    x = emb.encode_input(P_IN)
    assert x.shape == (2, emb.m_in)
    pred = jax.random.normal(jax.random.PRNGKey(0), (2, emb.m_out))
    loss = emb.loss(pred, Q_OUT)
    assert loss.shape == (2,) and np.isfinite(np.asarray(loss)).all()
    scores = emb.decode(pred)
    assert scores.shape == (2, d)
    assert np.isfinite(np.asarray(scores)).all()


def test_bloom_io_interface():
    _check_interface(BloomIO.build(d=50, m=20, k=3))


def test_hashing_trick_is_k1_bloom():
    ht = hashing_trick(50, 20)
    assert ht.spec_in.k == 1 and ht.name == "HT"
    _check_interface(ht)


def test_ecoc_interface_and_code_quality():
    emb = ECOCIO.build(50, 24, iters=50)
    _check_interface(emb)
    C = np.asarray(emb.code)
    assert set(np.unique(C)) <= {0.0, 1.0}
    # random-ish codes: pairwise Hamming distance concentrated near m/2
    dist = (C[:20, None, :] != C[None, :20, :]).sum(-1)
    np.fill_diagonal(dist, 12)
    assert dist.min() >= 2


def test_pmi_interface():
    emb = PMIIO.build(_X(), m=16)
    _check_interface(emb)


def test_cca_interface():
    X = _X()
    emb = CCAIO.build(X, X, m=16)
    _check_interface(emb)


def test_bloom_io_with_cbe_matrices():
    from repro.core import hashing
    from repro.core.cbe import cbe_hash_matrix
    X = _X()
    H_in = hashing.make_hash_matrix_np(50, 3, 20, seed=0)
    H_cbe = cbe_hash_matrix(X, H_in, 20, seed=0)
    emb = BloomIO.build(d=50, m=20, k=3, H_in=H_cbe, H_out=H_cbe,
                        name="CBE")
    _check_interface(emb)
