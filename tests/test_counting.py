"""Counting Bloom embeddings (paper Sec. 7 future-work extension)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.bloom import BloomSpec, encode
from repro.core.counting import (CountingBloomIO, counting_xent_multilabel,
                                 encode_counting)


def test_counting_encode_sums_multiplicities():
    spec = BloomSpec(d=50, m=20, k=3, seed=0)
    p = jnp.array([[1, 2, 3, -1]])
    u_bin = np.asarray(encode(spec, p))
    u_cnt = np.asarray(encode_counting(spec, p))
    # total mass = c * k exactly (no saturation)
    assert u_cnt.sum() == 3 * 3
    # counting >= binary everywhere; equal where no collisions
    assert (u_cnt >= u_bin).all()
    assert u_cnt.max() >= 1


def test_binary_is_saturated_counting():
    # the binary encoding is exactly min(counting, 1) — always
    spec = BloomSpec(d=600, m=48, k=3, seed=1)
    p = jnp.array([[4, 9, 100, 599, -1]])
    u_bin = np.asarray(encode(spec, p))
    u_cnt = np.asarray(encode_counting(spec, p))
    np.testing.assert_allclose(u_bin, np.minimum(u_cnt, 1.0))


def test_counting_io_interface_and_learning_signal():
    emb = CountingBloomIO(d=80, m=24, k=3)
    p = jnp.array([[1, 2, 5, -1], [7, -1, -1, -1]])
    x = emb.encode_input(p)
    assert x.shape == (2, 24)
    pred = jax.random.normal(jax.random.PRNGKey(0), (2, 24))
    loss = emb.loss(pred, p)
    assert np.isfinite(np.asarray(loss)).all()
    scores = emb.decode(pred)
    assert scores.shape == (2, 80)
    # gradient exists and is nonzero
    g = jax.grad(lambda z: emb.loss(z, p).sum())(pred)
    assert float(jnp.abs(g).sum()) > 0


def test_counting_recommender_learns():
    from repro.data.synthetic import make_recsys
    from repro.data.pipeline import BatchIterator
    from repro.models import recommender as rec
    from repro.optim import optimizers as opt
    from repro.train import metrics as M

    data = make_recsys(n=600, d=300, mean_items=8, seed=3)
    emb = CountingBloomIO(d=300, m=100, k=3)
    params = rec.recommender_init(jax.random.PRNGKey(0), emb, [64])
    tx = opt.make_optimizer("adam", 2e-3)
    state = tx.init(params)

    @jax.jit
    def step(params, state, p, q):
        g = jax.grad(lambda pr: rec.recommender_loss(pr, emb, p, q))(params)
        u, state = tx.update(g, state, params)
        return opt.apply_updates(params, u), state

    it = BatchIterator(list(data.train()), 64, seed=0)
    for _ in range(80):
        p, q = next(it)
        params, state = step(params, state, jnp.asarray(p), jnp.asarray(q))
    p_te, q_te = data.test()
    scores = np.asarray(rec.recommender_scores(params, emb,
                                               jnp.asarray(p_te)))
    mapv = M.mean_average_precision(scores, q_te, p_te)
    assert mapv > 0.02, mapv
