"""Optimizer substrate: convergence on quadratics, clipping, schedules."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import optimizers as opt


def _minimize(tx, steps=200, dim=4):
    target = jnp.arange(1.0, dim + 1)
    params = {"w": jnp.zeros(dim)}
    state = tx.init(params)

    def loss(p):
        return jnp.sum((p["w"] - target) ** 2)

    for _ in range(steps):
        g = jax.grad(loss)(params)
        upd, state = tx.update(g, state, params)
        params = opt.apply_updates(params, upd)
    return float(loss(params))


@pytest.mark.parametrize("name,lr", [
    ("adam", 0.1), ("adamw", 0.1), ("adagrad", 0.9), ("rmsprop", 0.05),
    ("sgd", 0.05),
])
def test_optimizers_converge(name, lr):
    tx = opt.make_optimizer(name, lr, momentum=0.9 if name == "sgd" else 0)
    assert _minimize(tx) < 1e-2


def test_clip_by_global_norm():
    tx = opt.clip_by_global_norm(1.0)
    g = {"a": jnp.full(4, 10.0)}
    clipped, _ = tx.update(g, tx.init(g), g)
    assert float(opt.global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)
    small = {"a": jnp.full(4, 0.01)}
    out, _ = tx.update(small, tx.init(small), small)
    np.testing.assert_allclose(np.asarray(out["a"]),
                               np.asarray(small["a"]))


def test_warmup_cosine_schedule():
    sched = opt.warmup_cosine(1.0, warmup_steps=10, total_steps=100)
    assert float(sched(jnp.asarray(0))) == 0.0
    assert float(sched(jnp.asarray(10))) == pytest.approx(1.0)
    assert float(sched(jnp.asarray(5))) == pytest.approx(0.5)
    assert float(sched(jnp.asarray(100))) == pytest.approx(0.1, rel=1e-3)


def test_gradient_compression_bf16_roundtrip():
    tx = opt.compress_gradients("bf16")
    g = {"w": jnp.asarray([1.0, 1e-3, 256.123])}
    out, _ = tx.update(g, tx.init(g), g)
    # values quantized to bf16 grid but close
    np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(g["w"]),
                               rtol=1e-2)
    assert out["w"].dtype == jnp.float32


def test_weight_decay_adds_param_term():
    tx = opt.add_decayed_weights(0.1)
    g = {"w": jnp.zeros(3)}
    p = {"w": jnp.asarray([1.0, 2.0, 3.0])}
    out, _ = tx.update(g, (), p)
    np.testing.assert_allclose(np.asarray(out["w"]), 0.1 * np.asarray(p["w"]))


def test_adam_bias_correction_first_step():
    tx = opt.scale_by_adam(0.9, 0.999)
    p = {"w": jnp.zeros(2)}
    st = tx.init(p)
    g = {"w": jnp.asarray([1.0, -2.0])}
    upd, st = tx.update(g, st, p)
    # first-step bias-corrected adam update is ~sign(g)
    np.testing.assert_allclose(np.asarray(upd["w"]), [1.0, -1.0],
                               rtol=1e-4)
