"""Evaluation measures (paper Sec. 4.1)."""
import numpy as np
import pytest

from repro.train import metrics as M


def test_average_precision_perfect_ranking():
    scores = np.array([0.9, 0.8, 0.1, 0.05])
    ap = M.average_precision(scores, np.array([0, 1]))
    assert ap == pytest.approx(1.0)


def test_average_precision_interleaved():
    # relevant at ranks 1 and 3 -> AP = (1/1 + 2/3)/2
    scores = np.array([0.9, 0.5, 0.4, 0.1])
    ap = M.average_precision(scores, np.array([0, 2]))
    assert ap == pytest.approx((1.0 + 2 / 3) / 2)


def test_average_precision_excludes_inputs():
    scores = np.array([0.9, 0.8, 0.7, 0.1])
    # item 0 excluded (was an input) -> relevant item 1 ranks first
    ap = M.average_precision(scores, np.array([1]), exclude=np.array([0]))
    assert ap == pytest.approx(1.0)


def test_map_ignores_empty_rows():
    scores = np.random.default_rng(0).normal(size=(3, 5))
    rel = np.array([[0, -1], [-1, -1], [1, -1]])
    m = M.mean_average_precision(scores, rel)
    assert 0.0 <= m <= 1.0


def test_reciprocal_rank():
    scores = np.array([[0.1, 0.9, 0.5], [0.9, 0.1, 0.5]])
    target = np.array([1, 2])
    rr = M.reciprocal_rank(scores, target)
    assert rr == pytest.approx((1.0 + 0.5) / 2)


def test_reciprocal_rank_midrank_ties():
    # constant scores over d=5: rank = 0 greater + 4/2 ties + 1 = 3 —
    # the old optimistic `greater + 1` reported RR = 1.0 here
    scores = np.ones((1, 5))
    assert M.reciprocal_rank(scores, np.array([2])) == pytest.approx(1 / 3)
    # partial tie: one item above, one tied -> rank 1 + 0.5 + 1 = 2.5
    scores = np.array([[0.9, 0.5, 0.5, 0.1]])
    assert M.reciprocal_rank(scores, np.array([2])) \
        == pytest.approx(1 / 2.5)


def test_reciprocal_rank_exclude_mirrors_average_precision():
    scores = np.array([[0.9, 0.8, 0.7]])
    # item 0 excluded (an input) -> target 1 ranks first
    assert M.reciprocal_rank(scores, np.array([1]),
                             exclude=np.array([[0, -1]])) == 1.0
    # the exclude mask never drops the target itself
    assert M.reciprocal_rank(scores, np.array([1]),
                             exclude=np.array([[1, -1]])) \
        == pytest.approx(0.5)


def test_average_precision_tied_scores_index_order():
    # MAP's tie-break is the stable sort's: ascending item id — the same
    # lowest-id-wins contract every top-k decode path follows
    # (DESIGN.md §11), and deterministic (the old unstable argsort
    # permuted ties arbitrarily per platform)
    scores = np.ones(6)
    assert M.average_precision(scores, np.array([0])) == pytest.approx(1.0)
    assert M.average_precision(scores, np.array([3])) \
        == pytest.approx(1 / 4)
    assert M.average_precision(scores, np.array([2, 4])) \
        == pytest.approx((1 / 3 + 2 / 5) / 2)


def test_accuracy():
    scores = np.array([[0.9, 0.1], [0.2, 0.8], [0.7, 0.3]])
    target = np.array([0, 1, 1])
    assert M.accuracy(scores, target) == pytest.approx(100 * 2 / 3)


def test_accuracy_exclude_masks_inputs_not_target():
    """`exclude` (-1-padded) removes the user's input items from the
    argmax ranking — but NEVER the target itself, even if listed."""
    scores = np.array([[3.0, 2.0, 1.0],
                       [3.0, 2.0, 1.0]])
    target = np.array([1, 1])
    # row 0: no exclude -> argmax = 0, miss.  row 1: item 0 excluded ->
    # argmax = 1, hit.
    exclude = np.array([[-1, -1], [0, -1]])
    assert M.accuracy(scores, target) == pytest.approx(0.0)
    assert M.accuracy(scores, target, exclude=exclude) == pytest.approx(50.0)
    # the target id in the exclude list is ignored (mirrors AP/RR)
    assert M.accuracy(np.array([[3.0, 2.0, 1.0]]), np.array([0]),
                      exclude=np.array([[0, -1]])) == pytest.approx(100.0)


def test_accuracy_tied_argmax_lowest_id():
    """Tied top scores resolve to the LOWEST item id (np.argmax picks
    the first maximum) — the pinned three-path tie-break contract."""
    scores = np.array([[1.0, 1.0, 1.0]])
    assert M.accuracy(scores, np.array([0])) == pytest.approx(100.0)
    assert M.accuracy(scores, np.array([2])) == pytest.approx(0.0)
    # -1 target rows are skipped entirely
    assert M.accuracy(scores, np.array([-1])) == pytest.approx(0.0)
