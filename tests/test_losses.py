"""Loss functions, incl. the shard-friendly iota-compare gather."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # dev-only dep (requirements-dev.txt)
from hypothesis import given, settings, strategies as st

from repro.core import losses
from repro.core.bloom import BloomSpec, encode


@given(st.integers(2, 64), st.integers(1, 6), st.integers(0, 100))
@settings(max_examples=25, deadline=None)
def test_gather_last_axis_matches_take_along_axis(m, k, seed):
    key = jax.random.PRNGKey(seed)
    logits = jax.random.normal(key, (3, m))
    idx = jax.random.randint(jax.random.fold_in(key, 1), (3, k), 0, m)
    got = np.asarray(losses.gather_last_axis(logits, idx))
    want = np.asarray(jnp.take_along_axis(logits, idx, axis=-1))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_bloom_xent_equals_dense_ce_with_khot_target():
    spec = BloomSpec(d=100, m=32, k=4, seed=0)
    key = jax.random.PRNGKey(0)
    logits = jax.random.normal(key, (5, 32))
    labels = jnp.array([3, 50, 99, 0, 42])
    got = np.asarray(losses.bloom_xent_label(spec, logits, labels))
    # manual: CE against 1/k mass on each hash position
    idx = np.asarray(spec.indices_for(labels))
    logp = np.asarray(jax.nn.log_softmax(logits))
    want = -np.stack([logp[i, idx[i]].mean() for i in range(5)])
    np.testing.assert_allclose(got, want, rtol=1e-5)


def test_bloom_xent_identity_spec_equals_standard_ce():
    spec = BloomSpec(d=32, m=32, k=1, seed=0, on_the_fly=False)
    H = jnp.arange(32)[:, None]  # identity hash
    logits = jax.random.normal(jax.random.PRNGKey(1), (4, 32))
    labels = jnp.array([0, 5, 31, 7])
    got = np.asarray(losses.bloom_xent_label(spec, logits, labels,
                                             hash_matrix=H))
    want = np.asarray(losses.softmax_xent_label(logits, labels))
    np.testing.assert_allclose(got, want, rtol=1e-5)


def test_multilabel_bloom_xent_finite_and_masked():
    spec = BloomSpec(d=64, m=24, k=3, seed=1)
    logits = jax.random.normal(jax.random.PRNGKey(2), (3, 24))
    targets = jnp.array([[1, 2, -1], [5, -1, -1], [-1, -1, -1]])
    loss = np.asarray(losses.bloom_xent_multilabel(spec, logits, targets))
    assert np.isfinite(loss[:2]).all()
    assert loss[2] == 0.0  # empty target set -> masked out


def test_valid_mask_zeroes_loss():
    logits = jax.random.normal(jax.random.PRNGKey(3), (4, 16))
    labels = jnp.array([1, 2, 3, 4])
    valid = jnp.array([1.0, 0.0, 1.0, 0.0])
    loss = np.asarray(losses.softmax_xent_label(logits, labels, valid))
    assert loss[1] == 0.0 and loss[3] == 0.0 and (loss[[0, 2]] > 0).all()


def test_cosine_loss_bounds():
    a = jax.random.normal(jax.random.PRNGKey(4), (10, 8))
    same = np.asarray(losses.cosine_proximity_loss(a, a))
    np.testing.assert_allclose(same, 0.0, atol=1e-5)
    opp = np.asarray(losses.cosine_proximity_loss(a, -a))
    np.testing.assert_allclose(opp, 2.0, atol=1e-5)


def test_softmax_xent_dense_masks_zero_rows():
    logits = jax.random.normal(jax.random.PRNGKey(5), (2, 8))
    target = jnp.stack([jnp.zeros(8), jax.nn.one_hot(3, 8)])
    loss = np.asarray(losses.softmax_xent_dense(logits, target))
    assert loss[0] == 0.0 and loss[1] > 0
