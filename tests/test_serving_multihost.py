"""Deterministic multi-host serving simulation tests (DESIGN.md §8/§9).

The heavyweight piece runs ``repro.serving.sim_multihost`` in a
subprocess with ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` —
the forced topology must be set before jax initializes, and this pytest
process must keep seeing 1 CPU device (tests/test_launch.py asserts it).
The driver serves the same seeded per-host workload through the FULL
control/data-plane matrix — {sim, collective} transports x
{no-compaction, compaction} on ONE sharded engine — plus the single-host
engine and solo static serving, and the assertions here prove:

  * per-request tokens are BIT-identical across ALL SIX paths — data-axis
    sharding, transported admission (including the real device all_gather
    of the collective transport), the prefill pool, and mid-flight slot
    compaction change the schedule but never a single recovered token;
  * each engine run's event log equals the model-free
    ``simulate_sharded_schedule`` replay integer-for-integer, COMPACT
    events included, and the sim/collective transports produce identical
    logs (transport equivalence on the device topology);
  * no slot is double-claimed (shared ``replay_slot_log`` through any
    COMPACT remaps) and the merged log is a linearization of per-host
    logs;
  * the single-compiled-step invariant survives the whole matrix (decode
    compiled exactly once across all four runs);
  * the compaction runs actually compact, and the prefill pool actually
    dispatches over both workers.

The JAX-free tests below the subprocess fixture pin the loadgen and
scheduler determinism contracts in-process — including deterministic
(no-hypothesis) versions of the transport-equivalence and compaction
invariants, so they run even where hypothesis is absent.
"""
import json
import subprocess
import sys

import numpy as np
import pytest

from conftest import subprocess_env

from repro.serving import (AdmissionPolicy, CollectiveTransport, FailPlan,
                           LoadSpec, ReplicaDivergence, Request,
                           TransportTimeout, host_stream, merge_workloads,
                           overload_workload, replay_slot_log,
                           sharded_workload, simulate_sharded_schedule,
                           slo_attainment)

N_HOSTS = 8
SLOTS_PER_HOST = 2
RUNS = ("sim_plain", "sim_compact", "collective_plain",
        "collective_compact")


@pytest.fixture(scope="module")
def report(tmp_path_factory):
    """One subprocess run of the 8-device sim, shared by the tests."""
    out = tmp_path_factory.mktemp("multihost") / "report.json"
    env = subprocess_env()
    # the driver appends the forced-topology flag itself; wiping any
    # inherited XLA_FLAGS keeps the 8-device count authoritative
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, "-m", "repro.serving.sim_multihost",
         "--out", str(out)],
        capture_output=True, text=True, env=env,
        cwd="/root/repo", timeout=540)
    assert r.returncode == 0, r.stdout + r.stderr
    with open(out) as f:
        return json.load(f)


def test_sim_ran_on_8_devices(report):
    assert report["n_devices"] == 8
    assert report["n_hosts"] == N_HOSTS
    assert report["slots_per_host"] == SLOTS_PER_HOST
    assert set(report["runs"]) == set(RUNS)


def test_tokens_bit_identical_across_all_paths(report):
    """{sim, collective} x {plain, compact} == single-host pool == solo
    static, token for token."""
    solo = report["solo"]
    assert solo, "solo run produced no results"
    single = report["single"]["tokens"]
    assert set(single) == set(solo)
    for rid in solo:
        assert single[rid] == solo[rid], (
            f"req {rid}: single {single[rid]} != solo {solo[rid]}")
    for name in RUNS:
        toks = report["runs"][name]["tokens"]
        assert set(toks) == set(solo)
        for rid in solo:
            assert toks[rid] == solo[rid], (
                f"req {rid}: {name} {toks[rid]} != solo {solo[rid]}")


def test_every_request_completes(report):
    for name in RUNS:
        done = report["runs"][name]["done"]
        assert done and all(done.values()), name


def test_single_compiled_decode_step_survives_the_matrix(report):
    """One executable across sim+collective transports AND mid-flight
    cache compactions (out_specs == pool specs pins the layout)."""
    assert report["decode_compiles"] == 1


def test_engine_logs_match_model_free_simulation(report):
    """Each engine run's transported schedule is exactly the JAX-free
    replay for its compaction setting — scheduling is decoupled from the
    model (the workload has no EOS) — and COMPACT events replay too."""
    as_tuples = lambda evs: [tuple(e) for e in evs]
    as_comp = lambda evs: [(s, tuple(p), q) for s, p, q in evs]
    for name in RUNS:
        sim = report["sims"][name.split("_")[1]]
        run_log, sim_log = report["runs"][name]["log"], sim["log"]
        assert as_tuples(run_log["admissions"]) == \
            as_tuples(sim_log["admissions"]), name
        assert as_tuples(run_log["releases"]) == \
            as_tuples(sim_log["releases"]), name
        assert as_comp(run_log["compactions"]) == \
            as_comp(sim_log["compactions"]), name
        assert report["runs"][name]["stats"]["decode_steps"] == \
            sim["stats"]["decode_steps"]


def test_transport_equivalence_on_device_topology(report):
    """The collective transport (REAL device all_gather on the 8-device
    mesh) reproduces the simulated gossip's log integer-for-integer."""
    for cname in ("plain", "compact"):
        a = report["runs"][f"sim_{cname}"]["log"]
        b = report["runs"][f"collective_{cname}"]["log"]
        assert a == b, f"sim vs collective diverged ({cname})"


def test_compaction_runs_compact_and_stay_schedule_invariant(report):
    """The compact runs execute COMPACT events; the remap moves slot ids
    but never admission/release steps or rids."""
    for t in ("sim", "collective"):
        plain = report["runs"][f"{t}_plain"]
        comp = report["runs"][f"{t}_compact"]
        assert comp["stats"]["compactions"] > 0
        assert len(comp["log"]["compactions"]) == \
            comp["stats"]["compactions"]
        assert plain["stats"]["compactions"] == 0
        key = lambda evs: [(e[0], e[2]) for e in evs]   # (step, rid)
        assert key(plain["log"]["admissions"]) == \
            key(comp["log"]["admissions"])
        # intra-step release order follows slot order, which the remap
        # permutes — per-step multiset comparison
        assert sorted(key(plain["log"]["releases"])) == \
            sorted(key(comp["log"]["releases"]))
        assert plain["stats"]["decode_steps"] == \
            comp["stats"]["decode_steps"]


def test_prefill_pool_dispatches_over_all_workers(report):
    """FIFO pool over 2 mesh-slice workers: every job dispatched, both
    workers used, totals consistent across the 4-run matrix."""
    st = report["prefill_stats"]
    total = sum(r["stats"]["prefills"] for r in report["runs"].values())
    assert st["jobs"] == total
    assert len(st["per_worker"]) == report["prefill_workers"] == 2
    assert sum(st["per_worker"]) == st["jobs"]
    assert all(c > 0 for c in st["per_worker"])


def test_no_slot_double_claim_and_linearization(report):
    """Merged-log soundness through COMPACT remaps (shared
    ``replay_slot_log``), every request admitted exactly once by exactly
    one host, and the merged log restricted to each host's slot range
    reproduces that host's local log exactly (linearization)."""
    n_slots = N_HOSTS * SLOTS_PER_HOST
    for name in RUNS:
        log = report["runs"][name]["log"]
        adm = [tuple(e) for e in log["admissions"]]
        rel = [tuple(e) for e in log["releases"]]
        comp = [(s, tuple(p), q) for s, p, q in log["compactions"]]
        final = replay_slot_log(adm, rel, comp, n_slots)
        assert all(o is None for o in final), f"{name}: slots left live"

        # every request admitted exactly once, by exactly one host —
        # "which host" is the admitting slot's owner at admission time
        rids = [rid for _, _, rid, _ in adm]
        assert len(rids) == len(set(rids))

        for h, hlog in enumerate(log["per_host"]):
            lo, hi = h * SLOTS_PER_HOST, (h + 1) * SLOTS_PER_HOST
            assert [tuple(e) for e in hlog["admissions"]] == \
                [e for e in adm if lo <= e[1] < hi]
            assert [tuple(e) for e in hlog["releases"]] == \
                [e for e in rel if lo <= e[1] < hi]
            assert [(s, tuple(p), q)
                    for s, p, q in hlog["compactions"]] == \
                [(s, p[lo:hi], q) for s, p, q in comp
                 if p[lo:hi] != tuple(range(lo, hi))]
        # seqs strictly increase within each host list (order preserved)
        # and never collide across a host's lists
        for hlog in log["per_host"]:
            for evs in (hlog["admissions"], hlog["releases"],
                        hlog["compactions"]):
                assert [e[-1] for e in evs] == \
                    sorted(e[-1] for e in evs)
            seqs = [e[-1] for e in hlog["admissions"] + hlog["releases"]
                    + hlog["compactions"]]
            assert len(seqs) == len(set(seqs))


# ---------------------------------------------------------------------------
# JAX-free determinism contracts (loadgen + scheduler) — run in-process
# ---------------------------------------------------------------------------

def test_host_stream_is_pure_in_seed_and_host():
    """satellite: arrivals are a pure function of (seed, host_id) — the
    stream does not depend on which hosts were drawn before it."""
    spec = LoadSpec(n_requests=6, vocab=256, rate=0.8, seed=11)
    alone = host_stream(spec, host=3, n_hosts=8)
    in_full_draw = sharded_workload(spec, 8)[3]
    assert [r.rid for r in alone] == [r.rid for r in in_full_draw]
    assert [r.arrival_step for r in alone] == \
        [r.arrival_step for r in in_full_draw]
    assert [r.max_gen for r in alone] == [r.max_gen for r in in_full_draw]
    assert all((x.prompt == y.prompt).all()
               for x, y in zip(alone, in_full_draw))
    # distinct hosts get distinct streams (same seed)
    other = host_stream(spec, host=4, n_hosts=8)
    assert [r.arrival_step for r in other] != \
        [r.arrival_step for r in alone] or \
        any((x.prompt != y.prompt).any() for x, y in zip(other, alone))
    # rids are globally unique and host-tagged
    all_rids = [r.rid for reqs in sharded_workload(spec, 8) for r in reqs]
    assert len(all_rids) == len(set(all_rids))
    assert all(r.home == h for h, reqs in
               enumerate(sharded_workload(spec, 8)) for r in reqs)


def test_two_sharded_runs_replay_identical_event_logs():
    """satellite: the multi-host schedule is exactly reproducible — two
    independent replays of the same (seed, topology) produce identical
    merged AND per-host event logs."""
    spec = LoadSpec(n_requests=5, vocab=128, rate=1.3, seed=7)
    logs = []
    for _ in range(2):
        sched, stats = simulate_sharded_schedule(
            sharded_workload(spec, 4), slots_per_host=2, gossip_delay=1)
        logs.append((sched.admissions, sched.releases,
                     [(h.admissions, h.releases) for h in sched.hosts],
                     stats))
    assert logs[0] == logs[1]


def test_gossip_delay_defers_visibility():
    """A request arriving at t is admitted no earlier than t + delay, and
    a freed slot is reused no earlier than release + delay."""
    for delay in (0, 1, 3):
        spec = LoadSpec(n_requests=4, vocab=64, rate=2.0, seed=5)
        wl = sharded_workload(spec, 2)
        arrival = {r.rid: r.arrival_step for reqs in wl for r in reqs}
        sched, _ = simulate_sharded_schedule(wl, slots_per_host=1,
                                             gossip_delay=delay)
        assert len(sched.admissions) == 8
        for step, gslot, rid, _ in sched.admissions:
            assert step >= arrival[rid] + delay
        # slot reuse respects the gossip horizon
        last_release = {}
        for step, gslot, rid, seq in sorted(
                sched.admissions + sched.releases, key=lambda e: e[3]):
            is_release = (step, gslot, rid, seq) in sched.releases
            if is_release:
                last_release[gslot] = step
            elif gslot in last_release:
                assert step >= last_release[gslot] + delay


def test_merged_workload_orders_like_the_gossip_queue():
    spec = LoadSpec(n_requests=5, vocab=64, rate=1.0, seed=2)
    merged = merge_workloads(sharded_workload(spec, 3))
    keys = [(r.arrival_step, r.home, r.rid) for r in merged]
    assert keys == sorted(keys)
    assert len(merged) == 15


def test_transport_equivalence_deterministic_sweep():
    """sim transport == collective transport (loopback gather), log for
    log, over a deterministic grid of topologies, delays, capacities and
    compaction settings — the no-hypothesis version of the equivalence
    property (CI also runs the hypothesis sweep)."""
    for n_hosts, spp, delay, cap, thresh, seed in [
            (1, 1, 0, 1, None, 0), (2, 3, 1, 2, None, 1),
            (4, 2, 2, 8, None, 2), (3, 4, 1, 1, 0.0, 3),
            (2, 4, 0, 4, 0.25, 4), (8, 2, 3, 2, 0.0, 5)]:
        spec = LoadSpec(n_requests=4, vocab=64, rate=1.5, seed=seed)
        a, sa = simulate_sharded_schedule(
            sharded_workload(spec, n_hosts), spp, delay,
            compact_threshold=thresh)
        b, sb = simulate_sharded_schedule(
            sharded_workload(spec, n_hosts), spp, delay,
            transport=CollectiveTransport(n_hosts, delay, capacity=cap),
            compact_threshold=thresh)
        key = (n_hosts, spp, delay, cap, thresh)
        assert a.admissions == b.admissions, key
        assert a.releases == b.releases, key
        assert a.compactions == b.compactions, key
        assert sa == sb, key
        for ha, hb in zip(a.hosts, b.hosts):
            assert (ha.admissions, ha.releases, ha.compactions) == \
                (hb.admissions, hb.releases, hb.compactions), key


def test_compaction_is_schedule_invariant_and_sound():
    """Deterministic compaction contract: the remap changes slot ids,
    never admission/release steps or rids; perms never cross a host
    boundary; the log replays soundly through COMPACT events; every
    request still completes."""
    spec = LoadSpec(n_requests=6, vocab=128, rate=2.0,
                    prompt_lens=(4, 8), gen_lens=(2, 5, 11), seed=3)
    for n_hosts, spp in [(2, 4), (4, 2), (1, 6)]:
        s0, st0 = simulate_sharded_schedule(
            sharded_workload(spec, n_hosts), spp, 1)
        s1, st1 = simulate_sharded_schedule(
            sharded_workload(spec, n_hosts), spp, 1,
            compact_threshold=0.0)
        assert len(s1.compactions) > 0, "threshold 0.0 never compacted"
        # admissions keep the slot-independent ready order exactly;
        # intra-step release order follows slot order, which the remap
        # permutes — compare releases as per-step multisets
        key = lambda evs: [(e[0], e[2]) for e in evs]
        assert key(s0.admissions) == key(s1.admissions)
        assert sorted(key(s0.releases)) == sorted(key(s1.releases))
        assert (st0.decode_steps, st0.idle_steps, st0.tokens_out) == \
            (st1.decode_steps, st1.idle_steps, st1.tokens_out)
        for step, perm, seq in s1.compactions:
            assert sorted(perm) == list(range(n_hosts * spp))
            assert all(new // spp == old // spp
                       for new, old in enumerate(perm))
        final = replay_slot_log(s1.admissions, s1.releases,
                                s1.compactions, n_hosts * spp)
        assert all(o is None for o in final)


def test_chaos_drill_recovers_from_mid_traffic_host_kill(report):
    """ISSUE 6 acceptance on the REAL engine (8-device subprocess): a
    committed FailPlan kills 1 of 4 hosts mid-traffic; the drill's own
    in-process asserts already proved FIFO re-admission, log equality
    with the model-free sim and slot-log soundness — this test pins the
    headline numbers into the pytest report too."""
    chaos = report["chaos"]
    assert chaos["verified"] is True
    first, last = chaos["arrival_span"]
    assert first < chaos["kill_step"] <= last     # genuinely mid-traffic
    base_tokens = chaos["base"]["tokens"]
    for tname in ("sim", "collective"):
        kr = chaos["kill_runs"][tname]
        assert kr["done"] and all(kr["done"].values())
        assert kr["stats"]["host_downs"] == 1
        assert kr["stats"]["requeued"] >= 1       # non-vacuous drill
        assert kr["stats"]["rejects"] == 0
        assert kr["tokens"] == base_tokens        # bit-identical recovery
        assert len(kr["log"]["reclaims"]) == kr["stats"]["requeued"]
    # engine log == model-free sim log under the kill, both transports
    assert chaos["kill_runs"]["sim"]["log"] == chaos["kill_sim"]["log"]
    assert (chaos["kill_runs"]["collective"]["log"]
            == chaos["kill_sim"]["log"])
    # host death never creates a new decode executable
    assert chaos["decode_compiles"] == 1


def test_kill_recovery_deterministic_twins():
    """No-hypothesis twins of the chaos property (CI also runs the
    hypothesis sweep): across fixed (topology, gossip delay, kill
    schedule) cases — single kill, double kill, kill + arrival-gossip
    slowdown — no request is lost, recovered tokens equal the fault-free
    twin's bit-for-bit, the slot log replays soundly through RECLAIMs,
    and the collective transport replays the identical recovery."""
    cases = [(2, 1, 0, "kill_host:0@2"),
             (4, 2, 1, "kill_host:1@3"),
             (3, 2, 2, "kill_host:2@4,kill_host:0@8"),
             (4, 1, 1, "kill_host:3@2,delay_arrivals:2@3")]
    for n_hosts, spp, gd, spec_str in cases:
        plan = FailPlan.parse(spec_str)
        spec = LoadSpec(n_requests=3, vocab=64, rate=1.5,
                        gen_lens=(2, 4, 7), seed=9)
        base_wl = sharded_workload(spec, n_hosts)
        simulate_sharded_schedule(base_wl, spp, gd)
        base_tokens = {r.rid: r.tokens for reqs in base_wl for r in reqs}

        kill_wl = sharded_workload(spec, n_hosts)
        sk, stk = simulate_sharded_schedule(kill_wl, spp, gd,
                                            failpoints=plan)
        reqs = [r for rs in kill_wl for r in rs]
        assert all(r.done and not r.rejected for r in reqs), spec_str
        assert {r.rid: r.tokens for r in reqs} == base_tokens, spec_str
        assert stk.host_downs == len(plan.kill_steps()), spec_str
        assert stk.requeued == len(sk.reclaims) >= 1, spec_str
        replay_slot_log(sk.admissions, sk.releases, sk.compactions,
                        sk.n_slots, rejects=sk.rejects,
                        reclaims=sk.reclaims)

        sc, stc = simulate_sharded_schedule(
            sharded_workload(spec, n_hosts), spp, gd,
            transport=CollectiveTransport(n_hosts, gd, capacity=4),
            failpoints=plan)
        assert (sk.admissions, sk.releases, sk.reclaims, sk.rejects,
                sk.host_downs) == \
            (sc.admissions, sc.releases, sc.reclaims, sc.rejects,
             sc.host_downs), spec_str
        assert stk == stc, spec_str


def test_overload_drill_sheds_and_degrades_on_the_real_engine(report):
    """ISSUE 10 acceptance on the REAL engine (8-device subprocess): the
    committed surge+slow_decode FailPlan overloads a 4-host pool running
    the committed AdmissionPolicy; the drill's own in-process asserts
    already proved shed determinism, twin bit-identity, log equality and
    zero recompiles — this test pins the headline numbers into the
    pytest report too."""
    ov = report["overload"]
    assert ov["verified"] is True
    assert ov["overload_steps"], "plan injected no overload"
    assert ov["base"]["stats"]["sheds"] == 0
    assert all(ov["base"]["done"].values())
    base_tokens = ov["base"]["tokens"]
    for tname in ("sim", "collective"):
        sr = ov["surge_runs"][tname]
        shed = {str(rid) for rid in sr["shed_rids"]}   # JSON string keys
        assert sr["stats"]["sheds"] == len(shed) > 0, tname
        assert sr["stats"]["degrades"] >= 2, tname   # escalate + restore
        assert sr["stats"]["rejects"] == 0, tname
        # served tokens bit-identical to the unloaded twin; shed requests
        # got NO tokens
        for rid, d in sr["done"].items():
            if rid in shed:
                assert sr["tokens"][rid] == [], (tname, rid)
            else:
                assert d and sr["tokens"][rid] == base_tokens[rid], \
                    (tname, rid)
        assert sr["slo_attainment"] == slo_attainment(
            ov["n_requests"] - len(shed), ov["n_requests"])
    # shed decisions identical across transports and the model-free sim
    assert (ov["surge_runs"]["sim"]["shed_rids"]
            == ov["surge_runs"]["collective"]["shed_rids"]
            == ov["surge_sim"]["shed_rids"])
    assert ov["surge_runs"]["sim"]["log"] == ov["surge_sim"]["log"]
    assert (ov["surge_runs"]["collective"]["log"]
            == ov["surge_sim"]["log"])
    # zero recompiles through every DEGRADE/RESTORE transition
    assert all(n <= 1 for n in ov["stage_decode_compiles"].values())
    assert ov["stage_decode_compiles"]["0"] == 1


def test_overload_deterministic_twins():
    """No-hypothesis twins of the overload property (CI also runs the
    hypothesis sweep): across fixed (topology, surge, deadline, queue
    bound) cases — every request is exactly one of completed / shed,
    shed requests were never admitted, FIFO holds among survivors, and
    the collective transport sheds the identical set."""
    cases = [(2, 1, 0, "surge:3@0", 2, None),
             (4, 2, 1, "surge:2@1,slow_decode:3@2", 4, 2),
             (3, 1, 1, "slow_decode:4@0", 3, 1),
             (2, 2, 0, "surge:4@2", 1, None)]
    policy_kw = dict(pressure_window=2, degrade_lo=0.25, degrade_hi=0.5,
                     restore_below=0.1)
    any_shed = False
    for n_hosts, spp, gd, spec_str, slack, depth in cases:
        key = (n_hosts, spp, gd, spec_str)
        plan = FailPlan.parse(spec_str)
        policy = AdmissionPolicy(max_queue_depth=depth, **policy_kw)
        spec = LoadSpec(n_requests=4, vocab=64, rate=2.0,
                        gen_lens=(2, 4, 7), seed=13)
        wl = overload_workload(spec, n_hosts, surge_start=0,
                               surge_factor=2, deadline_slack=slack)
        sk, stk = simulate_sharded_schedule(wl, spp, gd, failpoints=plan,
                                            admission_policy=policy)
        reqs = [r for rs in wl for r in rs]
        assert all(r.done for r in reqs), key
        shed = {r.rid for r in reqs if r.shed}
        any_shed |= bool(shed)
        assert stk.sheds == len(shed) == len(sk.sheds), key
        for r in reqs:
            if r.shed:
                assert r.admitted_step < 0 and not r.tokens, key
            else:
                assert r.admitted_step >= 0, key
                assert len(r.tokens) == r.max_gen, key
        # FIFO among survivors on the replicated queue key
        eff = {r.rid: (plan.effective_arrival(r.arrival_step), r.home,
                       r.rid) for r in reqs}
        order = [rid for _, _, rid, seq in
                 sorted(sk.admissions, key=lambda e: e[3])]
        assert [eff[rid] for rid in order] == \
            sorted(eff[rid] for rid in order), key
        replay_slot_log(sk.admissions, sk.releases, sk.compactions,
                        sk.n_slots, rejects=sk.rejects,
                        reclaims=sk.reclaims)

        sc, stc = simulate_sharded_schedule(
            overload_workload(spec, n_hosts, surge_start=0,
                              surge_factor=2, deadline_slack=slack),
            spp, gd,
            transport=CollectiveTransport(n_hosts, gd, capacity=16),
            failpoints=plan, admission_policy=policy)
        assert sk.sheds == sc.sheds, key
        assert sk.degrades == sc.degrades, key
        assert (sk.admissions, sk.releases) == \
            (sc.admissions, sc.releases), key
        assert stk == stc, key
    assert any_shed, "no case shed anything — the twins are vacuous"


def test_sim_prefill_reject_at_cap_and_retry_below_cap():
    """fail_prefill below PREFILL_MAX_ATTEMPTS is invisible to the
    schedule (the pool retries another worker); AT the cap the victim is
    REJECTed — slot freed, logged, everyone else token-identical."""
    from repro.serving import PREFILL_MAX_ATTEMPTS

    spec = LoadSpec(n_requests=3, vocab=64, rate=1.0,
                    gen_lens=(2, 4), seed=4)
    base_wl = sharded_workload(spec, 2)
    simulate_sharded_schedule(base_wl, 2, 1)
    base_tokens = {r.rid: r.tokens for reqs in base_wl for r in reqs}
    victim = sorted(base_tokens)[1]

    # below the cap: nothing observable in the model-free schedule
    wl = sharded_workload(spec, 2)
    s_ok, st_ok = simulate_sharded_schedule(
        wl, 2, 1, failpoints=FailPlan.parse(
            f"fail_prefill:{victim}:{PREFILL_MAX_ATTEMPTS - 1}"))
    assert st_ok.rejects == 0 and not s_ok.rejects
    assert {r.rid: r.tokens for rs in wl for r in rs} == base_tokens

    # at the cap: REJECT — victim unserved, others complete untouched
    wl = sharded_workload(spec, 2)
    s_rj, st_rj = simulate_sharded_schedule(
        wl, 2, 1, failpoints=FailPlan.parse(
            f"fail_prefill:{victim}:{PREFILL_MAX_ATTEMPTS}"))
    assert st_rj.rejects == 1
    assert [rid for _, _, rid, _ in s_rj.rejects] == [victim]
    for r in (r for rs in wl for r in rs):
        if r.rid == victim:
            assert r.rejected and r.tokens == [] and r.done
        else:
            assert not r.rejected and r.tokens == base_tokens[r.rid]
    replay_slot_log(s_rj.admissions, s_rj.releases, s_rj.compactions,
                    s_rj.n_slots, rejects=s_rj.rejects,
                    reclaims=s_rj.reclaims)


def test_corrupted_replica_raises_within_one_round():
    """Digest satellite: a replica whose reported state digest diverges
    crashes the exchange round it reports in — BOTH transports, and the
    raise names the disagreeing host and the step."""
    spec = LoadSpec(n_requests=3, vocab=64, rate=1.0, seed=2)
    plan = FailPlan.parse("corrupt_digest:1@2")
    for transport in (None,
                      CollectiveTransport(3, 1, capacity=4)):
        with pytest.raises(ReplicaDivergence, match=r"step 2.*\[1\]"):
            simulate_sharded_schedule(sharded_workload(spec, 3), 2, 1,
                                      transport=transport,
                                      failpoints=plan)


def test_hung_round_past_deadline_raises_timeout():
    """Deadline satellite: an injected hang longer than the per-round
    deadline raises TransportTimeout instead of stalling the pool."""
    spec = LoadSpec(n_requests=3, vocab=64, rate=1.0, seed=2)
    plan = FailPlan.parse("hang_round:99@2")
    for transport in (None,
                      CollectiveTransport(3, 1, capacity=4)):
        with pytest.raises(TransportTimeout, match="step 2"):
            simulate_sharded_schedule(sharded_workload(spec, 3), 2, 1,
                                      transport=transport,
                                      failpoints=plan)
    # a hang UNDER the deadline is survivable and schedule-invariant
    base_wl = sharded_workload(spec, 3)
    s0, _ = simulate_sharded_schedule(base_wl, 2, 1)
    wl = sharded_workload(spec, 3)
    s1, _ = simulate_sharded_schedule(
        wl, 2, 1, failpoints=FailPlan.parse("hang_round:4@2"))
    assert (s0.admissions, s0.releases) == (s1.admissions, s1.releases)


def test_delay0_same_step_release_readmits_instead_of_dropping():
    """Regression: with gossip_delay=0 a slot freed during the admit
    phase (max_gen=1) is visible the same step; the driver must re-admit
    the waiting request at the same clock tick, not break the loop and
    drop it (the pre-refactor next_event_time filtered the candidate
    out)."""
    reqs = [Request(rid=i, prompt=np.zeros(2, np.int32), max_gen=1,
                    arrival_step=0, home=0) for i in range(3)]
    sched, stats = simulate_sharded_schedule([reqs], slots_per_host=1,
                                             gossip_delay=0)
    assert all(r.done for r in reqs)
    assert len(sched.admissions) == 3
    # all three turned around at step 0: pure same-tick re-admission
    assert [e[0] for e in sched.admissions] == [0, 0, 0]
