"""Deterministic multi-host serving simulation tests (DESIGN.md §8).

The heavyweight piece runs ``repro.serving.sim_multihost`` in a
subprocess with ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` —
the forced topology must be set before jax initializes, and this pytest
process must keep seeing 1 CPU device (tests/test_launch.py asserts it).
The driver serves the same seeded per-host workload through the sharded
engine, the single-host engine, and solo static serving, and the
assertions here prove:

  * per-request tokens are BIT-identical across all three paths — the
    data-axis sharding, gossiped admission, and disaggregated prefill
    change the schedule but never a single recovered token;
  * the sharded engine's event log equals the model-free
    ``simulate_sharded_schedule`` replay integer-for-integer;
  * no slot is double-claimed (per-slot admit/release alternation on the
    merged log) and the merged log is a linearization of per-host logs;
  * the single-compiled-step invariant survives sharding (decode compiled
    exactly once).

The JAX-free tests below the subprocess fixture pin the loadgen and
scheduler determinism contracts (satellite: arrival streams are pure
functions of (seed, host_id); two runs replay identical event logs).
"""
import json
import subprocess
import sys

import numpy as np
import pytest

from conftest import subprocess_env

from repro.serving import (LoadSpec, host_stream, merge_workloads,
                           sharded_workload, simulate_sharded_schedule)

N_HOSTS = 8
SLOTS_PER_HOST = 1


@pytest.fixture(scope="module")
def report(tmp_path_factory):
    """One subprocess run of the 8-device sim, shared by the tests."""
    out = tmp_path_factory.mktemp("multihost") / "report.json"
    env = subprocess_env()
    # the driver appends the forced-topology flag itself; wiping any
    # inherited XLA_FLAGS keeps the 8-device count authoritative
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, "-m", "repro.serving.sim_multihost",
         "--out", str(out)],
        capture_output=True, text=True, env=env,
        cwd="/root/repo", timeout=540)
    assert r.returncode == 0, r.stdout + r.stderr
    with open(out) as f:
        return json.load(f)


def test_sim_ran_on_8_devices(report):
    assert report["n_devices"] == 8
    assert report["n_hosts"] == N_HOSTS


def test_tokens_bit_identical_across_all_paths(report):
    """Sharded pool == single-host pool == solo static, token for token."""
    toks = report["tokens"]
    assert toks["sharded"], "sharded run produced no results"
    assert set(toks["sharded"]) == set(toks["single"]) == set(toks["solo"])
    for rid in toks["solo"]:
        assert toks["sharded"][rid] == toks["solo"][rid], (
            f"req {rid}: sharded {toks['sharded'][rid]} != solo "
            f"{toks['solo'][rid]}")
        assert toks["single"][rid] == toks["solo"][rid], (
            f"req {rid}: single {toks['single'][rid]} != solo "
            f"{toks['solo'][rid]}")


def test_every_request_completes(report):
    assert report["done"] and all(report["done"].values())


def test_single_compiled_decode_step_survives_sharding(report):
    assert report["decode_compiles"] == 1


def test_engine_log_matches_model_free_simulation(report):
    """The engine's gossiped schedule is exactly the JAX-free replay —
    scheduling is decoupled from the model (the workload has no EOS)."""
    as_tuples = lambda evs: [tuple(e) for e in evs]
    assert as_tuples(report["log"]["admissions"]) == \
        as_tuples(report["sim_log"]["admissions"])
    assert as_tuples(report["log"]["releases"]) == \
        as_tuples(report["sim_log"]["releases"])
    assert report["stats"]["sharded"]["decode_steps"] == \
        report["stats"]["sim"]["decode_steps"]


def test_no_slot_double_claim_and_linearization(report):
    """Merged-log soundness: per-slot admit/release alternation with
    matching rids, and the merged log restricted to each host's slot
    range reproduces that host's local log exactly (linearization)."""
    adm = [tuple(e) for e in report["log"]["admissions"]]
    rel = [tuple(e) for e in report["log"]["releases"]]
    n_slots = N_HOSTS * SLOTS_PER_HOST

    class _Log:                      # adapt to conftest's checker shape
        admissions, releases = adm, rel
    from conftest import assert_slot_log_sound
    assert_slot_log_sound(_Log, n_slots)

    # every request admitted exactly once, by exactly one host
    rids = [rid for _, _, rid, _ in adm]
    assert len(rids) == len(set(rids))
    hosts_of = {}
    for _, gslot, rid, _ in adm:
        hosts_of.setdefault(rid, set()).add(gslot // SLOTS_PER_HOST)
    assert all(len(h) == 1 for h in hosts_of.values())

    for h, hlog in enumerate(report["log"]["per_host"]):
        lo, hi = h * SLOTS_PER_HOST, (h + 1) * SLOTS_PER_HOST
        assert [tuple(e) for e in hlog["admissions"]] == \
            [e for e in adm if lo <= e[1] < hi]
        assert [tuple(e) for e in hlog["releases"]] == \
            [e for e in rel if lo <= e[1] < hi]
    # seqs strictly increase within each host log (order preserved)
    for hlog in report["log"]["per_host"]:
        seqs = [e[3] for e in hlog["admissions"] + hlog["releases"]]
        assert sorted(seqs) == sorted(set(seqs))


# ---------------------------------------------------------------------------
# JAX-free determinism contracts (loadgen + scheduler) — run in-process
# ---------------------------------------------------------------------------

def test_host_stream_is_pure_in_seed_and_host():
    """satellite: arrivals are a pure function of (seed, host_id) — the
    stream does not depend on which hosts were drawn before it."""
    spec = LoadSpec(n_requests=6, vocab=256, rate=0.8, seed=11)
    alone = host_stream(spec, host=3, n_hosts=8)
    in_full_draw = sharded_workload(spec, 8)[3]
    assert [r.rid for r in alone] == [r.rid for r in in_full_draw]
    assert [r.arrival_step for r in alone] == \
        [r.arrival_step for r in in_full_draw]
    assert [r.max_gen for r in alone] == [r.max_gen for r in in_full_draw]
    assert all((x.prompt == y.prompt).all()
               for x, y in zip(alone, in_full_draw))
    # distinct hosts get distinct streams (same seed)
    other = host_stream(spec, host=4, n_hosts=8)
    assert [r.arrival_step for r in other] != \
        [r.arrival_step for r in alone] or \
        any((x.prompt != y.prompt).any() for x, y in zip(other, alone))
    # rids are globally unique and host-tagged
    all_rids = [r.rid for reqs in sharded_workload(spec, 8) for r in reqs]
    assert len(all_rids) == len(set(all_rids))
    assert all(r.home == h for h, reqs in
               enumerate(sharded_workload(spec, 8)) for r in reqs)


def test_two_sharded_runs_replay_identical_event_logs():
    """satellite: the multi-host schedule is exactly reproducible — two
    independent replays of the same (seed, topology) produce identical
    merged AND per-host event logs."""
    spec = LoadSpec(n_requests=5, vocab=128, rate=1.3, seed=7)
    logs = []
    for _ in range(2):
        sched, stats = simulate_sharded_schedule(
            sharded_workload(spec, 4), slots_per_host=2, gossip_delay=1)
        logs.append((sched.admissions, sched.releases,
                     [(h.admissions, h.releases) for h in sched.hosts],
                     stats))
    assert logs[0] == logs[1]


def test_gossip_delay_defers_visibility():
    """A request arriving at t is admitted no earlier than t + delay, and
    a freed slot is reused no earlier than release + delay."""
    for delay in (0, 1, 3):
        spec = LoadSpec(n_requests=4, vocab=64, rate=2.0, seed=5)
        wl = sharded_workload(spec, 2)
        arrival = {r.rid: r.arrival_step for reqs in wl for r in reqs}
        sched, _ = simulate_sharded_schedule(wl, slots_per_host=1,
                                             gossip_delay=delay)
        assert len(sched.admissions) == 8
        for step, gslot, rid, _ in sched.admissions:
            assert step >= arrival[rid] + delay
        # slot reuse respects the gossip horizon
        last_release = {}
        for step, gslot, rid, seq in sorted(
                sched.admissions + sched.releases, key=lambda e: e[3]):
            is_release = (step, gslot, rid, seq) in sched.releases
            if is_release:
                last_release[gslot] = step
            elif gslot in last_release:
                assert step >= last_release[gslot] + delay


def test_merged_workload_orders_like_the_gossip_queue():
    spec = LoadSpec(n_requests=5, vocab=64, rate=1.0, seed=2)
    merged = merge_workloads(sharded_workload(spec, 3))
    keys = [(r.arrival_step, r.home, r.rid) for r in merged]
    assert keys == sorted(keys)
    assert len(merged) == 15
