"""CBE (Algorithm 1): co-occurrence-aware collision redirection."""
import numpy as np
import scipy.sparse as sp

from repro.core import hashing
from repro.core.cbe import cbe_hash_matrix, cooccurrence_stats


def _data(n=400, d=60, density=0.06, seed=0):
    X = sp.random(n, d, density=density, format="csr",
                  random_state=np.random.default_rng(seed))
    X.data[:] = 1.0
    return X


def test_output_shape_and_range():
    X = _data()
    H = hashing.make_hash_matrix_np(60, 4, 20, seed=0)
    H2 = cbe_hash_matrix(X, H, 20, seed=0)
    assert H2.shape == H.shape
    assert H2.min() >= 0 and H2.max() < 20
    assert H2.dtype == np.int32


def test_top_cooccurring_pair_shares_a_bit():
    # construct data where items 0 and 1 co-occur massively
    n, d, m = 500, 30, 12
    rows = []
    for i in range(n):
        r = np.zeros(d)
        if i % 2 == 0:
            r[[0, 1]] = 1.0
        r[2 + (i % (d - 2))] = 1.0
        rows.append(r)
    X = sp.csr_matrix(np.stack(rows))
    H = hashing.make_hash_matrix_np(d, 3, m, seed=1)
    H2 = cbe_hash_matrix(X, H, m, seed=1)
    assert set(H2[0]) & set(H2[1]), \
        "most co-occurring pair must collide on a shared bit"


def test_untouched_rows_keep_original_hashes():
    X = _data(seed=3)
    H = hashing.make_hash_matrix_np(60, 4, 20, seed=3)
    H2 = cbe_hash_matrix(X, H, 20, seed=3, max_pairs=5)
    # with only 5 pairs processed, at most 10 rows may change
    changed = (H2 != H).any(axis=1).sum()
    assert changed <= 10


def test_cooccurrence_stats_reasonable():
    X = _data()
    pct, rho = cooccurrence_stats(X)
    assert 0 <= pct <= 100
    assert 0 <= rho <= 1


def test_deterministic_given_seed():
    X = _data(seed=5)
    H = hashing.make_hash_matrix_np(60, 4, 20, seed=5)
    a = cbe_hash_matrix(X, H, 20, seed=9)
    b = cbe_hash_matrix(X, H, 20, seed=9)
    np.testing.assert_array_equal(a, b)
