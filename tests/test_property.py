"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # dev-only dep (requirements-dev.txt)
from hypothesis import given, settings, strategies as st

from repro.core import hashing
from repro.core.bloom import BloomSpec, encode, decode_scores
from repro.data.pipeline import BatchIterator


@given(
    d=st.integers(20, 500),
    ratio=st.floats(0.1, 1.0),
    k=st.integers(1, 6),
    n_items=st.integers(1, 10),
    seed=st.integers(0, 10_000),
)
@settings(max_examples=30, deadline=None)
def test_bloom_recall_is_total(d, ratio, k, n_items, seed):
    """Paper Sec 3.1: member checks have 100% recall for ANY (d, m, k)."""
    m = max(k, int(d * ratio))
    spec = BloomSpec(d=d, m=m, k=k, seed=seed)
    rng = np.random.default_rng(seed)
    items = rng.choice(d, size=min(n_items, d), replace=False)
    u = encode(spec, jnp.asarray(items)[None, :])
    idx = np.asarray(spec.indices_for(jnp.asarray(items)))
    bits = np.asarray(u[0])
    assert (bits[idx.reshape(-1)] == 1).all()


@given(
    seed=st.integers(0, 1000),
    c1=st.integers(0, 30),
    c2=st.integers(0, 30),
)
@settings(max_examples=20, deadline=None)
def test_bloom_encoding_is_monotone_in_sets(seed, c1, c2):
    """u(A ∪ B) >= u(A) elementwise — adding items never clears bits."""
    d, m, k = 200, 64, 3
    spec = BloomSpec(d=d, m=m, k=k, seed=seed)
    rng = np.random.default_rng(seed)
    A = rng.choice(d, size=max(c1, 1), replace=False)
    B = rng.choice(d, size=max(c2, 1), replace=False)
    AB = np.unique(np.concatenate([A, B]))
    uA = np.asarray(encode(spec, jnp.asarray(A)[None]))
    uAB = np.asarray(encode(spec, jnp.asarray(AB)[None]))
    assert (uAB >= uA).all()


@given(
    n=st.integers(10, 200),
    batch=st.integers(1, 16),
    stop=st.integers(0, 30),
    seed=st.integers(0, 100),
)
@settings(max_examples=20, deadline=None)
def test_pipeline_resume_equivalence(n, batch, stop, seed):
    """Restoring iterator state replays the exact remaining sequence."""
    batch = min(batch, n)
    X = np.arange(n)[:, None]
    it1 = BatchIterator([X], batch, seed=seed)
    ref = [it1.__next__()[0].copy() for _ in range(stop + 10)]

    it2 = BatchIterator([X], batch, seed=seed)
    for _ in range(stop):
        next(it2)
    st_ = it2.state()
    it3 = BatchIterator([X], batch, seed=999)
    it3.restore(st_)
    for i in range(stop, stop + 10):
        np.testing.assert_array_equal(next(it3)[0], ref[i])


@given(st.integers(1, 64), st.integers(1, 8), st.integers(0, 500))
@settings(max_examples=25, deadline=None)
def test_hash_matrix_rows_are_valid_bloom_codes(d, k, seed):
    m = max(k, 32)
    H = np.asarray(hashing.make_hash_matrix(d, k, m, seed))
    assert H.shape == (d, k)
    assert ((H >= 0) & (H < m)).all()


@given(
    m=st.integers(8, 128),
    k=st.integers(1, 4),
    seed=st.integers(0, 100),
)
@settings(max_examples=15, deadline=None)
def test_decode_scores_permutation_invariance(m, k, seed):
    """Scores depend only on (log_v, H) — batch order is irrelevant."""
    d = 128
    spec = BloomSpec(d=d, m=min(m, d), k=min(k, m), seed=seed)
    key = jax.random.PRNGKey(seed)
    logv = jax.nn.log_softmax(jax.random.normal(key, (4, m)))
    s = np.asarray(decode_scores(spec, logv, chunk=16))
    s_perm = np.asarray(decode_scores(spec, logv[::-1], chunk=16))
    np.testing.assert_allclose(s, s_perm[::-1], rtol=1e-6)


# ---------------------------------------------------------------------------
# Serving scheduler invariants (repro.serving.scheduler — JAX-free, so
# hypothesis can drive thousands of random arrival/finish sequences)
# ---------------------------------------------------------------------------

@given(
    n_slots=st.integers(1, 5),
    arrivals=st.lists(st.integers(0, 30), min_size=0, max_size=25),
    lifetimes=st.data(),
    seed=st.integers(0, 10_000),
)
@settings(max_examples=60, deadline=None)
def test_scheduler_invariants_under_random_traffic(n_slots, arrivals,
                                                   lifetimes, seed):
    """Slot conservation, FIFO admission among ready requests, and no
    starvation, for ANY arrival pattern and ANY finish pattern."""
    from repro.serving.scheduler import Request, RequestQueue, Scheduler

    reqs = [Request(rid=i, prompt=np.zeros((4,), np.int32), max_gen=1,
                    arrival_step=a) for i, a in enumerate(arrivals)]
    life = {r.rid: lifetimes.draw(st.integers(1, 6), label=f"life{r.rid}")
            for r in reqs}
    queue = RequestQueue(reqs)
    sched = Scheduler(n_slots)
    rng = np.random.default_rng(seed)

    now = 0
    remaining = {}
    guard = 0
    while len(queue) or sched.n_active:
        guard += 1
        assert guard < 10_000, "scheduler loop did not terminate"
        for req in sched.admit(queue, now):
            remaining[req.rid] = life[req.rid]
        # slot conservation every step
        assert sched.n_active <= n_slots
        assert len(sched.free_slots) + sched.n_active == n_slots
        for slot, req in list(sched.active.items()):
            remaining[req.rid] -= 1
            # random early finishes exercise out-of-order retirement
            if remaining[req.rid] <= 0 or rng.random() < 0.3:
                sched.release(slot, now)
        now += 1

    # no starvation: every request was admitted and finished
    assert len(sched.admissions) == len(reqs)
    assert len(sched.releases) == len(reqs)
    assert all(r.done for r in reqs)
    assert all(r.admitted_step >= r.arrival_step for r in reqs)

    # FIFO among ready: admission order == arrival order (stable by rid,
    # because RequestQueue sorts stably on arrival_step)
    admitted_rids = [rid for _, _, rid, _ in
                     sorted(sched.admissions, key=lambda e: e[3])]
    expected = [r.rid for r in
                sorted(reqs, key=lambda r: (r.arrival_step, r.rid))]
    assert admitted_rids == expected

    # slot conservation, globally: per-slot event log alternates
    # admit/release with matching rids
    from conftest import assert_slot_log_sound
    assert_slot_log_sound(sched, n_slots)


@given(
    n_hosts=st.integers(1, 4),
    slots_per_host=st.integers(1, 3),
    gossip_delay=st.integers(0, 3),
    arrivals=st.lists(
        st.tuples(st.integers(0, 20),      # arrival step
                  st.integers(0, 3),       # home host (mod n_hosts)
                  st.integers(1, 6)),      # lifetime (max_gen)
        min_size=0, max_size=20),
)
@settings(max_examples=60, deadline=None)
def test_gossiped_queue_invariants_under_random_traffic(
        n_hosts, slots_per_host, gossip_delay, arrivals):
    """The sharded admission protocol, for ANY per-host arrival pattern
    and ANY gossip delay: no slot double-claim across host shards, FIFO
    among ready requests, every admitted request completes, and the
    merged event log is a linearization of the per-host logs."""
    from repro.serving.scheduler import Request, simulate_sharded_schedule

    per_host = [[] for _ in range(n_hosts)]
    reqs = []
    for i, (a, h, life) in enumerate(arrivals):
        r = Request(rid=i, prompt=np.zeros((2,), np.int32), max_gen=life,
                    arrival_step=a, home=h % n_hosts)
        per_host[r.home].append(r)
        reqs.append(r)

    sched, stats = simulate_sharded_schedule(
        per_host, slots_per_host, gossip_delay)

    # every request admitted exactly once and completed
    assert len(sched.admissions) == len(reqs)
    assert len(sched.releases) == len(reqs)
    assert all(r.done for r in reqs)
    assert all(r.admitted_step >= r.arrival_step + gossip_delay
               for r in reqs)
    admitted_rids = [rid for _, _, rid, _ in sched.admissions]
    assert len(admitted_rids) == len(set(admitted_rids))

    # no slot double-claim across host shards: per-GLOBAL-slot
    # admit/release alternation with matching rids on the merged log,
    # and each request claimed by exactly one host
    from conftest import assert_slot_log_sound
    assert_slot_log_sound(sched, sched.n_slots)
    host_claims = {}
    for _, gslot, rid, _ in sched.admissions:
        host_claims.setdefault(rid, set()).add(sched.host_of(gslot))
    assert all(len(h) == 1 for h in host_claims.values())

    # FIFO among ready: the admission sequence respects the gossiped
    # queue's deterministic global order (arrival, home, rid)
    expected = [r.rid for r in
                sorted(reqs, key=lambda r: (r.arrival_step, r.home,
                                            r.rid))]
    assert admitted_rids == expected

    # merged log is a linearization of per-host logs: restricting it to
    # each host's slot range reproduces the host log in order, and the
    # union of host logs IS the merged log
    for h, shard in enumerate(sched.hosts):
        assert shard.admissions == [
            e for e in sched.admissions if sched.host_of(e[1]) == h]
        assert shard.releases == [
            e for e in sched.releases if sched.host_of(e[1]) == h]
        for evs in (shard.admissions, shard.releases):
            assert [e[3] for e in evs] == sorted(e[3] for e in evs)
    merged = sorted(sched.admissions + sched.releases, key=lambda e: e[3])
    from_hosts = sorted(
        (e for s in sched.hosts for e in s.admissions + s.releases),
        key=lambda e: e[3])
    assert merged == from_hosts

    # slot conservation in aggregate
    assert stats.slot_steps_active <= stats.slot_steps_total
    assert stats.tokens_out == sum(r.max_gen for r in reqs)


@given(
    n_hosts=st.integers(1, 3),
    slots_per_host=st.integers(1, 2),
    gossip_delay=st.integers(0, 2),
    seed=st.integers(0, 500),
    n_requests=st.integers(1, 6),
)
@settings(max_examples=25, deadline=None)
def test_gossiped_schedule_is_deterministic(n_hosts, slots_per_host,
                                            gossip_delay, seed,
                                            n_requests):
    """Two independent replays of (seed, topology) — with host streams
    drawn in different orders — produce identical event logs."""
    from repro.serving.loadgen import LoadSpec, host_stream
    from repro.serving.scheduler import simulate_sharded_schedule

    spec = LoadSpec(n_requests=n_requests, vocab=64, rate=1.0, seed=seed)
    wl_a = [host_stream(spec, h, n_hosts) for h in range(n_hosts)]
    wl_b = [host_stream(spec, h, n_hosts)
            for h in reversed(range(n_hosts))][::-1]
    sa, sta = simulate_sharded_schedule(wl_a, slots_per_host, gossip_delay)
    sb, stb = simulate_sharded_schedule(wl_b, slots_per_host, gossip_delay)
    assert sa.admissions == sb.admissions
    assert sa.releases == sb.releases
    assert sta == stb


@given(
    n_hosts=st.integers(1, 4),
    slots_per_host=st.integers(1, 4),
    gossip_delay=st.integers(0, 3),
    capacity=st.integers(1, 8),
    compact=st.sampled_from([None, 0.0, 0.25, 0.5]),
    arrivals=st.lists(
        st.tuples(st.integers(0, 20),      # arrival step
                  st.integers(0, 3),       # home host (mod n_hosts)
                  st.integers(1, 6)),      # lifetime (max_gen)
        min_size=0, max_size=20),
)
@settings(max_examples=50, deadline=None)
def test_transport_equivalence_sim_vs_collective(
        n_hosts, slots_per_host, gossip_delay, capacity, compact,
        arrivals):
    """Tentpole contract (DESIGN.md §9): the fixed-size padded all_gather
    transport produces the IDENTICAL merged and per-host event logs as
    the in-process simulated gossip, for ANY topology, gossip delay,
    buffer capacity (overflow rounds included), traffic pattern and
    compaction setting — the protocol is a pure function of the delta
    stream, never of how the deltas physically move."""
    from repro.serving.control import CollectiveTransport
    from repro.serving.scheduler import Request, simulate_sharded_schedule

    def workload():
        per_host = [[] for _ in range(n_hosts)]
        for i, (a, h, life) in enumerate(arrivals):
            per_host[h % n_hosts].append(
                Request(rid=i, prompt=np.zeros((2,), np.int32),
                        max_gen=life, arrival_step=a, home=h % n_hosts))
        return per_host

    sa, sta = simulate_sharded_schedule(
        workload(), slots_per_host, gossip_delay,
        compact_threshold=compact)
    sb, stb = simulate_sharded_schedule(
        workload(), slots_per_host, gossip_delay,
        transport=CollectiveTransport(n_hosts, gossip_delay,
                                      capacity=capacity),
        compact_threshold=compact)
    assert sa.admissions == sb.admissions
    assert sa.releases == sb.releases
    assert sa.compactions == sb.compactions
    assert sta == stb
    for ha, hb in zip(sa.hosts, sb.hosts):
        assert (ha.admissions, ha.releases, ha.compactions) == \
            (hb.admissions, hb.releases, hb.compactions)


@given(
    n_hosts=st.integers(1, 4),
    slots_per_host=st.integers(1, 4),
    gossip_delay=st.integers(0, 2),
    threshold=st.floats(0.0, 0.75),
    arrivals=st.lists(
        st.tuples(st.integers(0, 15), st.integers(0, 3),
                  st.integers(1, 6)),
        min_size=0, max_size=18),
)
@settings(max_examples=50, deadline=None)
def test_compaction_invariants_under_random_traffic(
        n_hosts, slots_per_host, gossip_delay, threshold, arrivals):
    """Compaction contract (DESIGN.md §9), for ANY traffic/threshold:

    * per-request token streams are bit-for-bit unchanged (the model-free
      placeholder stream has one entry per emitted token — identical
      lengths and finish steps mean the engine, whose per-row math is
      row-independent, emits identical tokens);
    * admission/release (step, rid) sequences equal the no-compaction
      schedule — the remap moves slot ids, never the schedule;
    * log replay through COMPACT events stays integer-exact and sound
      (no slot double-claimed, no live slot dropped), and two replays
      produce identical logs;
    * every COMPACT perm is a host-local permutation.
    """
    from repro.serving.control import replay_slot_log
    from repro.serving.scheduler import Request, simulate_sharded_schedule

    def workload():
        per_host = [[] for _ in range(n_hosts)]
        for i, (a, h, life) in enumerate(arrivals):
            per_host[h % n_hosts].append(
                Request(rid=i, prompt=np.zeros((2,), np.int32),
                        max_gen=life, arrival_step=a, home=h % n_hosts))
        return per_host

    base_wl = workload()
    s0, st0 = simulate_sharded_schedule(base_wl, slots_per_host,
                                        gossip_delay)
    comp_wl = workload()
    s1, st1 = simulate_sharded_schedule(comp_wl, slots_per_host,
                                        gossip_delay,
                                        compact_threshold=threshold)

    # schedule invariance (slot ids may differ, nothing else may):
    # admission order is the slot-independent ready order, so it matches
    # exactly; releases within one step are logged in slot order, which a
    # remap permutes — compare them as per-step multisets
    key = lambda evs: [(e[0], e[2]) for e in evs]
    assert key(s0.admissions) == key(s1.admissions)
    assert sorted(key(s0.releases)) == sorted(key(s1.releases))
    assert (st0.decode_steps, st0.idle_steps, st0.tokens_out,
            st0.slot_steps_active) == \
        (st1.decode_steps, st1.idle_steps, st1.tokens_out,
         st1.slot_steps_active)
    # token streams bit-for-bit (placeholder streams: same length/content)
    for r0, r1 in zip((r for reqs in base_wl for r in reqs),
                      (r for reqs in comp_wl for r in reqs)):
        assert r0.rid == r1.rid and r0.tokens == r1.tokens
        assert r0.finish_step == r1.finish_step
        assert r1.done

    n_slots = n_hosts * slots_per_host
    for step, perm, seq in s1.compactions:
        assert sorted(perm) == list(range(n_slots))
        assert all(new // slots_per_host == old // slots_per_host
                   for new, old in enumerate(perm))
    final = replay_slot_log(s1.admissions, s1.releases, s1.compactions,
                            n_slots)
    assert all(o is None for o in final)      # no live slot dropped

    # exact replay: a second run reproduces the logs integer-for-integer
    s2, st2 = simulate_sharded_schedule(workload(), slots_per_host,
                                        gossip_delay,
                                        compact_threshold=threshold)
    assert (s1.admissions, s1.releases, s1.compactions) == \
        (s2.admissions, s2.releases, s2.compactions)
    assert st1 == st2


@given(
    n_hosts=st.integers(2, 4),
    slots_per_host=st.integers(1, 3),
    gossip_delay=st.integers(0, 2),
    kill_seed=st.integers(0, 10_000),
    n_kills=st.integers(1, 2),
    extra_delay=st.integers(0, 2),
    arrivals=st.lists(
        st.tuples(st.integers(0, 15),      # arrival step
                  st.integers(0, 3),       # home host (mod n_hosts)
                  st.integers(1, 6)),      # lifetime (max_gen)
        min_size=1, max_size=16),
)
@settings(max_examples=50, deadline=None)
def test_chaos_recovery_under_random_kills(
        n_hosts, slots_per_host, gossip_delay, kill_seed, n_kills,
        extra_delay, arrivals):
    """ISSUE 6 chaos sweep — for ANY topology, gossip delay, seeded
    kill schedule (1-2 hosts die mid-traffic, always ≥1 survivor) and
    arrival-gossip slowdown:

    * no request is lost or spuriously rejected — survivors reclaim the
      dead hosts' slots and finish everything;
    * every token stream is BIT-identical to the fault-free twin (the
      placeholder stream is pure in (rid, index), exactly like the
      engine's greedy row-independent decode);
    * re-admissions preserve FIFO order: requests reclaimed by the same
      HOST_DOWN wave re-enter in their original (arrival, home, rid)
      order (across waves no global order exists — a first-wave requeue
      may legitimately re-admit before a later kill even happens);
    * the slot log replays soundly through RECLAIM events;
    * the collective transport replays the IDENTICAL recovery schedule
      (merged log, per-host logs, stats) as the simulated gossip.
    """
    from repro.serving.control import CollectiveTransport, replay_slot_log
    from repro.serving.failpoints import FailPlan
    from repro.serving.scheduler import Request, simulate_sharded_schedule

    def workload():
        per_host = [[] for _ in range(n_hosts)]
        for i, (a, h, life) in enumerate(arrivals):
            per_host[h % n_hosts].append(
                Request(rid=i, prompt=np.zeros((2,), np.int32),
                        max_gen=life, arrival_step=a, home=h % n_hosts))
        return per_host

    lo = min(a for a, _, _ in arrivals)
    hi = max(a for a, _, _ in arrivals) + 2
    n_kills = min(n_kills, n_hosts - 1)
    plan = FailPlan.sample_kills(kill_seed, n_hosts, lo, hi + 1, n_kills)
    if extra_delay:
        plan = plan.merge(
            FailPlan.parse(f"delay_arrivals:{extra_delay}@{lo + 1}"))

    base_wl = workload()
    simulate_sharded_schedule(base_wl, slots_per_host, gossip_delay)
    base_tokens = {r.rid: r.tokens for reqs in base_wl for r in reqs}

    kill_wl = workload()
    sk, stk = simulate_sharded_schedule(kill_wl, slots_per_host,
                                        gossip_delay, failpoints=plan)

    # no request lost, none rejected (a pure kill/delay plan never
    # exhausts prefill attempts), and recovered tokens are bit-identical
    kill_reqs = [r for reqs in kill_wl for r in reqs]
    assert all(r.done and not r.rejected for r in kill_reqs)
    assert stk.rejects == 0
    assert {r.rid: r.tokens for r in kill_reqs} == base_tokens
    # one requeue per RECLAIM event (a rid may be reclaimed twice if its
    # second host also dies)
    assert stk.requeued == len(sk.reclaims)

    # FIFO among survivors: reclaimed rids re-admit in original order
    reclaimed = {rid for _, _, rid, _ in sk.reclaims}
    last_adm = {}
    for _, _, rid, seq in sk.admissions:
        if rid in reclaimed:
            last_adm[rid] = seq
    assert set(last_adm) == reclaimed      # every reclaim re-admitted
    key = {r.rid: (r.arrival_step, r.home, r.rid) for r in kill_reqs}
    wave = {}                              # rid -> its LAST reclaim step
    for step, _, rid, _ in sk.reclaims:
        wave[rid] = step
    for w in set(wave.values()):
        order = sorted((rid for rid, s in wave.items() if s == w),
                       key=last_adm.get)
        assert [key[r] for r in order] == sorted(key[r] for r in order)

    # slot log replays soundly through RECLAIM/REJECT events
    from conftest import assert_slot_log_sound
    assert_slot_log_sound(sk, sk.n_slots)

    # transport equivalence survives the failure schedule
    sc, stc = simulate_sharded_schedule(
        workload(), slots_per_host, gossip_delay,
        transport=CollectiveTransport(n_hosts, gossip_delay, capacity=4),
        failpoints=plan)
    assert (sk.admissions, sk.releases, sk.reclaims, sk.rejects,
            sk.host_downs) == (sc.admissions, sc.releases, sc.reclaims,
                               sc.rejects, sc.host_downs)
    assert stk == stc
    for ha, hb in zip(sk.hosts, sc.hosts):
        assert (ha.admissions, ha.releases, ha.reclaims) == \
            (hb.admissions, hb.releases, hb.reclaims)


@given(
    n_hosts=st.integers(2, 4),
    slots_per_host=st.integers(1, 3),
    gossip_delay=st.integers(0, 2),
    surge_factor=st.integers(2, 4),
    surge_step=st.integers(0, 6),
    slow=st.integers(0, 3),              # <2 = no slow_decode injected
    deadline_slack=st.integers(1, 6),
    max_depth=st.one_of(st.none(), st.integers(1, 3)),
    arrivals=st.lists(
        st.tuples(st.integers(0, 15),    # arrival step
                  st.integers(0, 3),     # home host (mod n_hosts)
                  st.integers(1, 6)),    # lifetime (max_gen)
        min_size=1, max_size=16),
)
@settings(max_examples=50, deadline=None)
def test_overload_shed_determinism_sim_vs_collective(
        n_hosts, slots_per_host, gossip_delay, surge_factor, surge_step,
        slow, deadline_slack, max_depth, arrivals):
    """ISSUE 10 overload sweep — for ANY topology, gossip delay, surge /
    slow_decode injection, deadline slack and queue bound:

    * every request reaches exactly one terminal state: completed
      (admitted, served to max_gen) or SHED (never admitted, zero
      tokens) — never both, never neither;
    * the shed decision is a pure function of replicated state: the
      collective transport sheds the IDENTICAL rid set at the identical
      steps as the simulated gossip (merged log, per-host logs, stats);
    * FIFO holds among the non-shed requests: admissions follow the
      replicated (effective_arrival, home, rid) queue key — shedding
      removes entries but never reorders the survivors;
    * the slot log replays soundly (sheds vacate no slot).
    """
    from repro.serving.admission import AdmissionPolicy
    from repro.serving.control import CollectiveTransport
    from repro.serving.failpoints import FailPlan
    from repro.serving.scheduler import Request, simulate_sharded_schedule

    def workload():
        per_host = [[] for _ in range(n_hosts)]
        for i, (a, h, life) in enumerate(arrivals):
            per_host[h % n_hosts].append(
                Request(rid=i, prompt=np.zeros((2,), np.int32),
                        max_gen=life, arrival_step=a, home=h % n_hosts,
                        deadline_step=a + deadline_slack))
        return per_host

    spec = f"surge:{surge_factor}@{surge_step}"
    if slow >= 2:
        spec += f",slow_decode:{slow}@{surge_step + 1}"
    plan = FailPlan.parse(spec)
    policy = AdmissionPolicy(max_queue_depth=max_depth, pressure_window=2,
                             degrade_lo=0.25, degrade_hi=0.5,
                             restore_below=0.1)

    wl = workload()
    sk, stk = simulate_sharded_schedule(wl, slots_per_host, gossip_delay,
                                        failpoints=plan,
                                        admission_policy=policy)
    reqs = [r for reqs in wl for r in reqs]
    assert all(r.done for r in reqs), "request left non-terminal"
    shed = {r.rid for r in reqs if r.shed}
    completed = {r.rid for r in reqs
                 if r.done and not r.shed and not r.rejected}
    assert not (shed & completed)
    assert shed | completed == {r.rid for r in reqs}, "request lost"
    # a shed request was NEVER served: not admitted, zero tokens
    for r in reqs:
        if r.shed:
            assert r.admitted_step < 0 and not r.tokens, r.rid
        else:
            assert r.admitted_step >= 0 and len(r.tokens) == r.max_gen
    assert stk.rejects == 0            # no prefill faults in the plan
    assert stk.sheds == len(shed) == len(sk.sheds)
    assert shed == {rid for _, rid, _, _ in sk.sheds}

    # FIFO among the non-shed: admission seq order follows the
    # replicated queue key (surge compression IS the key — DESIGN.md §14)
    eff = {r.rid: (plan.effective_arrival(r.arrival_step), r.home, r.rid)
           for r in reqs}
    admitted = sorted(((seq, rid) for _, _, rid, seq in sk.admissions))
    keys = [eff[rid] for _, rid in admitted]
    assert keys == sorted(keys), "shedding reordered survivors"
    assert {rid for _, rid in admitted} == completed

    from conftest import assert_slot_log_sound
    assert_slot_log_sound(sk, sk.n_slots)

    # the collective transport replays the identical overload schedule
    sc, stc = simulate_sharded_schedule(
        workload(), slots_per_host, gossip_delay,
        transport=CollectiveTransport(n_hosts, gossip_delay, capacity=16),
        failpoints=plan, admission_policy=policy)
    assert sk.sheds == sc.sheds
    assert sk.degrades == sc.degrades
    assert (sk.admissions, sk.releases, sk.rejects) == \
        (sc.admissions, sc.releases, sc.rejects)
    assert stk == stc
    for ha, hb in zip(sk.hosts, sc.hosts):
        assert (ha.admissions, ha.releases, ha.sheds) == \
            (hb.admissions, hb.releases, hb.sheds)


@given(
    occupied=st.lists(st.booleans(), min_size=1, max_size=24),
    slots_per_host=st.integers(1, 6),
    threshold=st.floats(0.0, 1.0),
)
@settings(max_examples=60, deadline=None)
def test_plan_compaction_is_a_host_local_packing(occupied, slots_per_host,
                                                 threshold):
    """The planner alone: any plan is a host-local permutation that packs
    each compacted host's live slots into its dense prefix in order; a
    None plan means no host exceeded the threshold."""
    from repro.serving.control import (fragmentation, invert_perm,
                                       plan_compaction)
    n_hosts = max(1, len(occupied) // slots_per_host)
    occ = (occupied * slots_per_host)[:n_hosts * slots_per_host]
    occupant = [i if o else -1 for i, o in enumerate(occ)]
    perm = plan_compaction(occupant, slots_per_host, threshold)
    if perm is None:
        return
    n_slots = len(occupant)
    assert sorted(perm) == list(range(n_slots))
    assert invert_perm(invert_perm(perm)) == list(perm)
    new_occ = [occupant[p] for p in perm]
    for h in range(n_hosts):
        lo = h * slots_per_host
        assert all(new // slots_per_host == old // slots_per_host
                   for new, old in enumerate(perm[lo:lo + slots_per_host],
                                             start=lo))
        live_new = [r for r in new_occ[lo:lo + slots_per_host] if r != -1]
        live_old = [r for r in occupant[lo:lo + slots_per_host] if r != -1]
        assert live_new == live_old            # order-preserving, lossless
        if fragmentation(occupant, slots_per_host, h) > threshold:
            # packed: live slots form the dense prefix
            prefix = new_occ[lo:lo + len(live_new)]
            assert all(r != -1 for r in prefix)
            assert fragmentation(new_occ, slots_per_host, h) == 0.0


@given(
    pushes=st.lists(st.integers(0, 20), min_size=1, max_size=15),
    now=st.integers(0, 25),
)
@settings(max_examples=40, deadline=None)
def test_request_queue_online_push_keeps_arrival_order(pushes, now):
    from repro.serving.scheduler import Request, RequestQueue

    q = RequestQueue()
    for i, a in enumerate(pushes):
        q.push(Request(rid=i, prompt=np.zeros((2,), np.int32), max_gen=1,
                       arrival_step=a))
    popped = []
    while True:
        r = q.pop_ready(now)
        if r is None:
            break
        popped.append((r.arrival_step, r.rid))
    assert popped == sorted(popped)
    assert all(a <= now for a, _ in popped)
    assert len(q) == sum(a > now for a in pushes)


@given(
    T=st.integers(1, 20),
    k=st.integers(1, 5),
    m=st.integers(4, 96),
    m_tile=st.sampled_from([4, 8, 16, 32]),
    e_tile=st.sampled_from([1, 3, 4, 8]),
    skew=st.sampled_from(["uniform", "hot", "one_tile", "constant"]),
    seed=st.integers(0, 1000),
)
@settings(max_examples=25, deadline=None)
def test_csr_backward_matches_oracle_under_random_shapes(
        T, k, m, m_tile, e_tile, skew, seed):
    """ISSUE 5: the CSR-binned embed backward == the XLA oracle gradient
    for ANY (shape, tiling, hash-index distribution) — uniform draws,
    collision-heavy ("hot": everything lands on a few indices;
    "constant": ONE index), and all-entries-in-one-m-tile, with ragged
    non-tile-multiple T and m throughout."""
    from repro.kernels import ref
    from repro.kernels.bloom_embed import bloom_embed_pallas

    k = min(k, m)
    rng = np.random.default_rng(seed)
    if skew == "uniform":
        idx = rng.integers(0, m, size=(T, k))
    elif skew == "hot":
        idx = rng.integers(0, max(1, min(3, m)), size=(T, k))
    elif skew == "one_tile":
        lo = min(m_tile, m) * min(1, max(0, (m - 1) // min(m_tile, m)))
        idx = lo + rng.integers(0, min(m_tile, m - lo), size=(T, k))
    else:  # constant
        idx = np.full((T, k), m - 1)
    idx = jnp.asarray(idx, jnp.int32)
    D = 24
    table = jnp.asarray(rng.normal(size=(m, D)), jnp.float32)
    cot = jnp.asarray(rng.normal(size=(T, D)), jnp.float32)

    g_csr = jax.grad(lambda t: jnp.sum(
        bloom_embed_pallas(t, idx, d_tile=16, interpret=True,
                           bwd_impl="csr", m_tile=m_tile,
                           e_tile=e_tile) * cot))(table)
    g_ref = jax.grad(lambda t: jnp.sum(
        ref.bloom_embed_ref(t, idx) * cot))(table)
    np.testing.assert_allclose(np.asarray(g_csr), np.asarray(g_ref),
                               atol=1e-4, rtol=1e-4)


@given(
    m=st.integers(1, 48),
    D=st.integers(1, 64),
    seed=st.integers(0, 10_000),
    scale=st.sampled_from([1e-14, 1e-6, 1.0, 1e3]),
)
@settings(max_examples=40, deadline=None)
def test_int8_quantizer_round_trip_bound(m, D, seed, scale):
    """core.quant int8 invariants (DESIGN.md §13), across magnitudes from
    the 1e-12-floor regime to large tables:

      * scales are strictly positive (all-zero rows stay finite);
      * the round trip is bounded ELEMENTWISE by scale/2 per row — the
        bound the kernel-level oracle tests build on;
      * each row's max-magnitude element survives the round trip to
        within the same bound (symmetric quantization never saturates
        the row max: amax/scale <= 127 by construction).
    """
    from repro.core import quant

    rng = np.random.default_rng(seed)
    x = jnp.asarray(scale * rng.normal(size=(m, D)), jnp.float32)
    if seed % 3 == 0 and m > 1:
        x = x.at[0].set(0.0)          # exercise the all-zero-row floor
    q, s = quant.quantize_table(x, "int8")
    assert q.dtype == jnp.int8 and s.shape == (m,)
    s_np = np.asarray(s, np.float64)
    assert np.all(s_np > 0)
    dq = np.asarray(quant.dequantize_table(q, s), np.float64)
    err = np.abs(np.asarray(x, np.float64) - dq)
    # float32 round-off on scale * round(x/scale) adds a few ulp on top
    # of the exact-arithmetic scale/2 bound
    bound = s_np[:, None] / 2 + 1e-6 * s_np[:, None] + 1e-30
    assert np.all(err <= bound), (
        f"round-trip error {err.max():.3g} exceeds scale/2 "
        f"({(s_np / 2).max():.3g})")
    # per-row max preserved within the bound
    amax = np.abs(np.asarray(x, np.float64)).max(axis=-1)
    dq_amax = np.abs(dq).max(axis=-1)
    assert np.all(np.abs(amax - dq_amax) <= bound[:, 0])


@given(td=st.sampled_from(["float32", "bfloat16", "fp8_e4m3"]),
       seed=st.integers(0, 1000))
@settings(max_examples=20, deadline=None)
def test_scale_free_dtypes_round_trip_is_cast(td, seed):
    """The non-int8 dtypes return scales=None and round-trip exactly as
    their plain jnp cast — no hidden rescaling."""
    from repro.core import quant

    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(5, 16)), jnp.float32)
    q, s = quant.quantize_table(x, td)
    assert s is None
    assert q.dtype == quant.storage_dtype(td)
    np.testing.assert_array_equal(
        np.asarray(quant.dequantize_table(q, s)),
        np.asarray(q.astype(jnp.float32)))
