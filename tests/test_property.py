"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # dev-only dep (requirements-dev.txt)
from hypothesis import given, settings, strategies as st

from repro.core import hashing
from repro.core.bloom import BloomSpec, encode, decode_scores
from repro.data.pipeline import BatchIterator


@given(
    d=st.integers(20, 500),
    ratio=st.floats(0.1, 1.0),
    k=st.integers(1, 6),
    n_items=st.integers(1, 10),
    seed=st.integers(0, 10_000),
)
@settings(max_examples=30, deadline=None)
def test_bloom_recall_is_total(d, ratio, k, n_items, seed):
    """Paper Sec 3.1: member checks have 100% recall for ANY (d, m, k)."""
    m = max(k, int(d * ratio))
    spec = BloomSpec(d=d, m=m, k=k, seed=seed)
    rng = np.random.default_rng(seed)
    items = rng.choice(d, size=min(n_items, d), replace=False)
    u = encode(spec, jnp.asarray(items)[None, :])
    idx = np.asarray(spec.indices_for(jnp.asarray(items)))
    bits = np.asarray(u[0])
    assert (bits[idx.reshape(-1)] == 1).all()


@given(
    seed=st.integers(0, 1000),
    c1=st.integers(0, 30),
    c2=st.integers(0, 30),
)
@settings(max_examples=20, deadline=None)
def test_bloom_encoding_is_monotone_in_sets(seed, c1, c2):
    """u(A ∪ B) >= u(A) elementwise — adding items never clears bits."""
    d, m, k = 200, 64, 3
    spec = BloomSpec(d=d, m=m, k=k, seed=seed)
    rng = np.random.default_rng(seed)
    A = rng.choice(d, size=max(c1, 1), replace=False)
    B = rng.choice(d, size=max(c2, 1), replace=False)
    AB = np.unique(np.concatenate([A, B]))
    uA = np.asarray(encode(spec, jnp.asarray(A)[None]))
    uAB = np.asarray(encode(spec, jnp.asarray(AB)[None]))
    assert (uAB >= uA).all()


@given(
    n=st.integers(10, 200),
    batch=st.integers(1, 16),
    stop=st.integers(0, 30),
    seed=st.integers(0, 100),
)
@settings(max_examples=20, deadline=None)
def test_pipeline_resume_equivalence(n, batch, stop, seed):
    """Restoring iterator state replays the exact remaining sequence."""
    batch = min(batch, n)
    X = np.arange(n)[:, None]
    it1 = BatchIterator([X], batch, seed=seed)
    ref = [it1.__next__()[0].copy() for _ in range(stop + 10)]

    it2 = BatchIterator([X], batch, seed=seed)
    for _ in range(stop):
        next(it2)
    st_ = it2.state()
    it3 = BatchIterator([X], batch, seed=999)
    it3.restore(st_)
    for i in range(stop, stop + 10):
        np.testing.assert_array_equal(next(it3)[0], ref[i])


@given(st.integers(1, 64), st.integers(1, 8), st.integers(0, 500))
@settings(max_examples=25, deadline=None)
def test_hash_matrix_rows_are_valid_bloom_codes(d, k, seed):
    m = max(k, 32)
    H = np.asarray(hashing.make_hash_matrix(d, k, m, seed))
    assert H.shape == (d, k)
    assert ((H >= 0) & (H < m)).all()


@given(
    m=st.integers(8, 128),
    k=st.integers(1, 4),
    seed=st.integers(0, 100),
)
@settings(max_examples=15, deadline=None)
def test_decode_scores_permutation_invariance(m, k, seed):
    """Scores depend only on (log_v, H) — batch order is irrelevant."""
    d = 128
    spec = BloomSpec(d=d, m=min(m, d), k=min(k, m), seed=seed)
    key = jax.random.PRNGKey(seed)
    logv = jax.nn.log_softmax(jax.random.normal(key, (4, m)))
    s = np.asarray(decode_scores(spec, logv, chunk=16))
    s_perm = np.asarray(decode_scores(spec, logv[::-1], chunk=16))
    np.testing.assert_allclose(s, s_perm[::-1], rtol=1e-6)
