"""Launch layer: mesh builders, dry-run subprocess integration, drivers."""
import json
import os
import subprocess
import sys

import jax
import pytest

from conftest import subprocess_env

from repro.launch.mesh import make_elastic_mesh, make_local_mesh


def test_local_mesh_axes():
    mesh = make_local_mesh()
    assert mesh.axis_names == ("data", "model")
    assert mesh.size == jax.device_count()


def test_elastic_mesh_shapes():
    # elastic re-shard after a world-size change keeps TP fixed
    m = make_elastic_mesh(jax.device_count(), model_parallel=1)
    assert m.shape["model"] == 1
    with pytest.raises(AssertionError):
        make_elastic_mesh(3, model_parallel=2)


@pytest.mark.slow
def test_dryrun_subprocess_smallest_cell(tmp_path):
    """End-to-end dry-run integration: 512 placeholder devices, production
    mesh, lower+compile+memory analysis — on the cheapest cell."""
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "mamba2-1.3b", "--shape", "long_500k", "--no-roofline",
         "--out", str(tmp_path)],
        capture_output=True, text=True, env=subprocess_env(),
        cwd="/root/repo", timeout=420)
    assert r.returncode == 0, r.stdout + r.stderr
    arts = os.listdir(tmp_path)
    assert len(arts) == 1
    with open(tmp_path / arts[0]) as f:
        d = json.load(f)
    assert d["n_devices"] == 256
    assert d["full"]["memory"]["temp_bytes"] < 16e9  # fits v5e HBM


def test_device_count_is_one_outside_dryrun():
    """Smoke tests must see the real device count (the XLA flag is only
    set inside launch/dryrun.py's own process)."""
    assert jax.device_count() == 1
