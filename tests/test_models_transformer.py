"""LM assembly across families: fwd/train/prefill/decode consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs.base import (BloomConfig, MambaConfig, MoEConfig,
                                ModelConfig)
from repro.models import encdec, transformer as tf

KEY = jax.random.PRNGKey(0)


def _dense_cfg(**kw):
    base = dict(name="t", num_layers=2, d_model=32, num_heads=4,
                num_kv_heads=2, head_dim=8, d_ff=64, vocab=128,
                dtype="float32", attn_chunk_q=8, attn_chunk_k=8,
                bloom=BloomConfig(enabled=True, m_ratio=0.5, k=3))
    base.update(kw)
    return ModelConfig(**base)


def test_scan_equals_unrolled_layers():
    cfg_scan = _dense_cfg(scan_layers=True)
    cfg_un = _dense_cfg(scan_layers=False)
    params = tf.lm_init(KEY, cfg_scan)
    toks = jax.random.randint(KEY, (2, 8), 0, 128)
    o1 = tf.lm_apply(params, cfg_scan, {"tokens": toks})["logits"]
    o2 = tf.lm_apply(params, cfg_un, {"tokens": toks})["logits"]
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-5)


def test_remat_equals_no_remat():
    cfg_a = _dense_cfg(remat="full")
    cfg_b = _dense_cfg(remat="none")
    params = tf.lm_init(KEY, cfg_a)
    toks = jax.random.randint(KEY, (2, 8), 0, 128)
    la, _ = tf.lm_loss_fn(params, cfg_a, {"tokens": toks})
    lb, _ = tf.lm_loss_fn(params, cfg_b, {"tokens": toks})
    assert float(la) == pytest.approx(float(lb), rel=1e-6)
    ga = jax.grad(lambda p: tf.lm_loss_fn(p, cfg_a, {"tokens": toks})[0])(
        params)
    gb = jax.grad(lambda p: tf.lm_loss_fn(p, cfg_b, {"tokens": toks})[0])(
        params)
    na = float(jnp.linalg.norm(ga["io"]["embed"]))
    nb = float(jnp.linalg.norm(gb["io"]["embed"]))
    assert na == pytest.approx(nb, rel=1e-4)


def test_prefill_then_decode_matches_full_forward():
    """logits(prefill tokens[:-1]) + decode(tokens[-1]) must equal the full
    forward — the serving path is numerically the training path."""
    cfg = _dense_cfg()
    params = tf.lm_init(KEY, cfg)
    S = 8
    toks = jax.random.randint(KEY, (2, S), 0, 128)
    full = tf.lm_apply(params, cfg, {"tokens": toks})["logits"]

    pre = tf.lm_apply(params, cfg, {"tokens": toks[:, :S - 1]},
                      mode="prefill")
    caches = tf.init_lm_cache(cfg, 2, S, dtype=jnp.float32)
    small = pre["caches"]

    def put(buf, sm):
        sl = tuple(slice(0, s) for s in sm.shape)
        return buf.at[sl].set(sm.astype(buf.dtype))

    caches = jax.tree.map(put, caches, small)
    dec = tf.lm_apply(params, cfg, {"tokens": toks[:, S - 1:]},
                      mode="decode", caches=caches,
                      pos=jnp.int32(S - 1))
    np.testing.assert_allclose(np.asarray(dec["logits"][:, 0]),
                               np.asarray(full[:, -1]), atol=5e-4)
    # prefill logits also match the full forward prefix
    np.testing.assert_allclose(np.asarray(pre["logits"]),
                               np.asarray(full[:, :S - 1]), atol=5e-4)


@pytest.mark.parametrize("arch", list(configs.ARCH_NAMES))
def test_prefill_decode_consistency_all_archs(arch):
    """Same consistency check across every assigned architecture family."""
    cfg = configs.get_smoke_config(arch, dtype="float32")
    S = 16
    toks = jax.random.randint(KEY, (2, S), 0, cfg.vocab)
    if cfg.family == "audio":
        emb = jax.random.normal(KEY, (2, 8, cfg.d_model))
        full = encdec.encdec_apply(params := encdec.encdec_init(KEY, cfg),
                                   cfg, {"tokens": toks, "embeds": emb}
                                   )["logits"]
        pre = encdec.encdec_apply(params, cfg,
                                  {"tokens": toks[:, :S - 1],
                                   "embeds": emb}, mode="prefill")
        caches = encdec.init_encdec_cache(cfg, 2, S, 8, dtype=jnp.float32)
        apply_decode = lambda c: encdec.encdec_apply(  # noqa: E731
            params, cfg, {"tokens": toks[:, S - 1:]}, mode="decode",
            caches=c, pos=jnp.int32(S - 1))
    else:
        params = tf.lm_init(KEY, cfg)
        batch = {"tokens": toks}
        if cfg.family == "vlm":
            batch["embeds"] = jax.random.normal(KEY, (2, 4, cfg.d_model))
        full = tf.lm_apply(params, cfg, batch)["logits"]
        pre_batch = dict(batch, tokens=toks[:, :S - 1])
        pre = tf.lm_apply(params, cfg, pre_batch, mode="prefill")
        caches = tf.init_lm_cache(cfg, 2, S + 4, dtype=jnp.float32)
        apply_decode = lambda c: tf.lm_apply(  # noqa: E731
            params, cfg, {"tokens": toks[:, S - 1:]}, mode="decode",
            caches=c, pos=jnp.int32(full.shape[1] - 1))

    def put(buf, sm):
        sl = tuple(slice(0, s) for s in sm.shape)
        return buf.at[sl].set(sm.astype(buf.dtype))

    caches = jax.tree.map(put, caches, pre["caches"])
    dec = apply_decode(caches)
    assert np.isfinite(np.asarray(dec["logits"])).all()
    np.testing.assert_allclose(np.asarray(dec["logits"][:, 0]),
                               np.asarray(full[:, -1]), atol=3e-3)


def test_vlm_frontend_prefix_changes_logits():
    cfg = _dense_cfg(family="vlm", frontend="vision_stub")
    params = tf.lm_init(KEY, cfg)
    toks = jax.random.randint(KEY, (1, 6), 0, 128)
    e1 = jax.random.normal(KEY, (1, 4, 32))
    e2 = jax.random.normal(jax.random.fold_in(KEY, 1), (1, 4, 32))
    o1 = tf.lm_apply(params, cfg, {"tokens": toks, "embeds": e1})["logits"]
    o2 = tf.lm_apply(params, cfg, {"tokens": toks, "embeds": e2})["logits"]
    assert o1.shape[1] == 10  # 4 patches + 6 tokens
    assert float(jnp.abs(o1 - o2).max()) > 1e-6


def test_loss_mask_respected():
    cfg = _dense_cfg()
    params = tf.lm_init(KEY, cfg)
    toks = jax.random.randint(KEY, (2, 8), 0, 128)
    mask = jnp.zeros((2, 7))
    loss, _ = tf.lm_loss_fn(params, cfg,
                            {"tokens": toks, "loss_mask": mask})
    assert float(loss) == 0.0


def test_dense_io_vs_bloom_io_shapes():
    for bloom in (True, False):
        cfg = _dense_cfg(bloom=BloomConfig(enabled=bloom, m_ratio=0.5, k=3))
        params = tf.lm_init(KEY, cfg)
        toks = jax.random.randint(KEY, (1, 4), 0, 128)
        logits = tf.lm_apply(params, cfg, {"tokens": toks})["logits"]
        assert logits.shape[-1] == (64 if bloom else 128)
