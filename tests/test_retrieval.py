"""Web-scale one-shot retrieval serving (DESIGN.md §11): the Zipf
workload contract, the RetrievalEngine slot-pool schedule + replay
determinism, the top-k tie-break contract shared by all three decode
paths, and the loadgen/metrics bugfixes the scenario smoked out."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_retrieval_config
from repro.core import bloom
from repro.kernels.bloom_decode_topk import bloom_decode_topk_pallas
from repro.models import io as io_lib
from repro.serving import (Engine, LoadSpec, Request, RetrievalEngine,
                           RetrievalLoadSpec, assert_fresh_instances,
                           burst_workload, evaluate_retrieval,
                           init_retrieval_params, make_workload,
                           retrieval_workload)
from repro.serving.engine import assert_kind
from repro.train import metrics as M

from conftest import assert_slot_log_sound


# ---------------------------------------------------------------------------
# loadgen: the Zipf retrieval stream + LoadSpec validation + fresh copies
# ---------------------------------------------------------------------------

def test_retrieval_workload_pure_in_seed_and_host():
    spec = RetrievalLoadSpec(n_requests=12, catalog=200_000, seed=3)
    a = retrieval_workload(spec, host=1, n_hosts=4)
    b = retrieval_workload(spec, host=1, n_hosts=4)
    for i, (ra, rb) in enumerate(zip(a, b)):
        assert ra.rid == rb.rid == i * 4 + 1
        assert np.array_equal(ra.prompt, rb.prompt)
        assert np.array_equal(ra.targets, rb.targets)
        assert ra.arrival_step == rb.arrival_step
    # different host -> a different stream (independent entropy pairs)
    c = retrieval_workload(spec, host=2, n_hosts=4)
    assert any(not np.array_equal(ra.prompt, rc.prompt)
               for ra, rc in zip(a, c))


def test_retrieval_workload_shape_and_skew():
    spec = RetrievalLoadSpec(n_requests=32, catalog=1_000_000, c_max=8,
                             n_targets=2, seed=0)
    reqs = retrieval_workload(spec)
    all_items = []
    for r in reqs:
        assert r.kind == "oneshot" and r.max_gen == 1
        assert r.prompt_len == 8 and len(r.targets) == 2
        items = np.concatenate([r.prompt, r.targets])
        assert len(set(items.tolist())) == 10      # distinct per request
        assert items.min() >= 0 and items.max() < spec.catalog
        all_items.extend(items.tolist())
    # Zipf(1) skew: the median drawn item sits around sqrt(catalog),
    # nowhere near the uniform-law median of catalog/2
    assert np.median(all_items) < spec.catalog / 50


def test_loadspec_validation():
    with pytest.raises(ValueError, match="rate"):
        LoadSpec(rate=0.0)
    with pytest.raises(ValueError, match="rate"):
        LoadSpec(rate=-1.0)
    with pytest.raises(ValueError, match="gen_weights"):
        LoadSpec(gen_lens=(4, 8, 24), gen_weights=(0.5, 0.5))
    with pytest.raises(ValueError, match="rate"):
        RetrievalLoadSpec(rate=0.0)
    with pytest.raises(ValueError, match="catalog"):
        RetrievalLoadSpec(catalog=16, c_max=8, n_targets=2)


def test_burst_workload_leaves_source_requests_alone():
    spec = LoadSpec(n_requests=6, vocab=128, rate=1.0, seed=0)
    base = make_workload(spec)
    arrivals = [r.arrival_step for r in base]
    burst = burst_workload(spec, step=5)
    # the old in-place mutation rewrote base's arrival steps to 5
    assert [r.arrival_step for r in base] == arrivals
    assert all(r.arrival_step == 5 for r in burst)
    assert not (set(map(id, base)) & set(map(id, burst)))


def test_fresh_copy_and_fresh_instance_guard():
    r = Request(rid=7, prompt=np.arange(4, dtype=np.int32), max_gen=3,
                kind="oneshot", targets=np.array([9], np.int32))
    r.tokens.append(11)
    r.admitted_step = 2
    r.slot = 1
    c = r.fresh_copy(arrival_step=4)
    assert c.rid == 7 and c.kind == "oneshot" and c.arrival_step == 4
    assert c.tokens == [] and c.admitted_step == -1 and c.slot == -1
    assert c.prompt is not r.prompt and np.array_equal(c.prompt, r.prompt)
    # served instances (or shared ones) must be refused by A/B drivers
    with pytest.raises(AssertionError, match="engine-filled"):
        assert_fresh_instances([r])
    with pytest.raises(AssertionError, match="SAME instance"):
        assert_fresh_instances([c], [c])
    assert_fresh_instances([c], [r.fresh_copy()])


# ---------------------------------------------------------------------------
# the retrieval engine: one-shot schedule, replay determinism, kind guard
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def served():
    rcfg = get_retrieval_config("smoke")
    load = RetrievalLoadSpec(n_requests=10, catalog=rcfg.d,
                             c_max=rcfg.c_max, rate=2.0, seed=0)
    wl = retrieval_workload(load)
    params = init_retrieval_params(rcfg)
    engine = RetrievalEngine(rcfg, params, n_slots=4)
    res_a, st_a = engine.run([r.fresh_copy() for r in wl])
    res_b, st_b = engine.run([r.fresh_copy() for r in wl])
    return rcfg, params, engine, res_a, st_a, res_b, st_b


def test_retrieval_engine_serves_all_oneshot(served):
    rcfg, _, engine, res, st, _, _ = served
    assert all(r.done and not r.rejected for r in res.values())
    for r in res.values():
        assert len(r.topk_ids) == rcfg.topk
        assert all(0 <= i < rcfg.d for i in r.topk_ids)
        assert len(set(r.topk_ids)) == rcfg.topk    # distinct items
        # one-shot: exactly one recover step per request
        assert r.finish_step == r.admitted_step + 1
        assert r.tokens == [r.topk_ids[0]]
    assert st.prefills == len(res) and st.tokens_out == len(res)
    assert_slot_log_sound(engine._sched, engine.n_slots)


def test_retrieval_replay_bit_identical(served):
    _, _, _, res_a, st_a, res_b, st_b = served
    for rid, ra in res_a.items():
        assert ra.topk_ids == res_b[rid].topk_ids
        assert ra.topk_scores == res_b[rid].topk_scores
    assert st_a.decode_steps == st_b.decode_steps
    assert st_a.slot_steps_active == st_b.slot_steps_active


def test_retrieval_bytes_model(served):
    rcfg, _, engine, _, st, _, _ = served
    mb = engine.modeled_bytes
    # streaming never exceeds the full-occupancy model and the dense
    # oracle pays the (d, m) table per step regardless of occupancy
    full = (rcfg.d * rcfg.k * 4 + rcfg.b_tile * rcfg.m * 4) \
        * (engine.n_slots // rcfg.b_tile + 1) * st.decode_steps \
        + engine.n_slots * rcfg.topk * 8 * st.decode_steps
    assert 0 < mb["streaming_bytes"] <= full
    assert mb["dense_oracle_bytes"] >= st.decode_steps * rcfg.d * rcfg.m * 4
    assert mb["dense_oracle_bytes"] > 3 * mb["streaming_bytes"]


def test_kind_guards():
    lm = Request(rid=0, prompt=np.arange(3, dtype=np.int32), max_gen=2)
    oneshot = Request(rid=1, prompt=np.arange(3, dtype=np.int32),
                      max_gen=1, kind="oneshot")
    with pytest.raises(NotImplementedError, match="oneshot"):
        assert_kind([lm, oneshot], "lm", "the token-LM engine")
    rcfg = get_retrieval_config("smoke")
    engine = RetrievalEngine(rcfg, init_retrieval_params(rcfg), n_slots=2)
    with pytest.raises(NotImplementedError, match="kind='lm'"):
        engine.run([lm])


def test_retrieval_degradation_serves_bit_identical_prefixes(served):
    """ISSUE 10 on the retrieval engine: under overload the degrade
    ladder narrows the served top-k width — every degraded request's
    ``topk_ids`` must be a BIT-identical prefix of the undegraded run's
    (the pinned lowest-id tie-break contract), sheds never serve, and no
    stage transition compiles a new recover executable."""
    from repro.serving import AdmissionPolicy, FailPlan
    from repro.serving.admission import STAGE_NORMAL, stage_topk

    rcfg, params, _, res_a, _, _, _ = served
    load = RetrievalLoadSpec(n_requests=10, catalog=rcfg.d,
                             c_max=rcfg.c_max, rate=2.0, seed=0)
    wl = [r.fresh_copy() for r in retrieval_workload(load)]
    for r in wl:
        r.deadline_step = r.arrival_step + 6
    policy = AdmissionPolicy(max_queue_depth=2, pressure_window=2,
                             degrade_lo=0.25, degrade_hi=0.5,
                             restore_below=0.1)
    engine = RetrievalEngine(
        rcfg, params, n_slots=2,
        failpoints=FailPlan.parse("surge:3@1,slow_decode:3@2"),
        admission_policy=policy)
    res, st = engine.run(wl)

    assert st.sheds > 0, "surge shed nothing — vacuous"
    assert st.degrades >= 1, "pressure never degraded the pool"
    widths = set()
    for rid, r in res.items():
        assert r.done, rid
        if r.shed:
            assert r.admitted_step < 0 and not r.topk_ids, rid
            continue
        k = len(r.topk_ids)
        widths.add(k)
        assert r.topk_ids == res_a[rid].topk_ids[:k], (
            f"req {rid}: degraded top-{k} is not a prefix of the "
            f"undegraded top-{rcfg.topk}")
    assert len(widths) > 1, "no request served at a narrowed width"
    assert widths <= {stage_topk(rcfg.topk, s, policy)
                      for s in range(policy.max_stage + 1)}
    # zero recompiles across the whole ladder; program ends restored
    for stage, fn in engine.program._stage_decodes.items():
        assert fn._cache_size() <= 1, f"stage {stage} recompiled"
    assert engine.program._stage_decodes[STAGE_NORMAL]._cache_size() == 1
    assert engine.program._stage == STAGE_NORMAL
    assert_slot_log_sound(engine._sched, engine.n_slots)


def test_retrieval_rejects_oversized_item_sets():
    rcfg = get_retrieval_config("smoke")
    engine = RetrievalEngine(rcfg, init_retrieval_params(rcfg), n_slots=2)
    big = Request(rid=0, prompt=np.arange(rcfg.c_max + 1, dtype=np.int32),
                  max_gen=1, kind="oneshot")
    with pytest.raises(AssertionError, match="c_max"):
        engine.run([big])


# ---------------------------------------------------------------------------
# tie-aware ranking eval (the acceptance sanity: untrained << 1.0)
# ---------------------------------------------------------------------------

def test_untrained_eval_far_below_one(served):
    rcfg, params, _, res, _, _, _ = served
    ev = evaluate_retrieval(rcfg, params, list(res.values()))
    assert ev["n_evaluated"] == len(res)
    assert 0.0 <= ev["map"] < 0.1 and 0.0 <= ev["rr"] < 0.1


def test_constant_scores_rr_is_midrank_not_one(served):
    # a zeroed tower emits constant logits -> every catalog score ties;
    # the old optimistic rank reported RR = 1.0 here, mid-rank gives
    # ~2/d (the honest expectation over random tie orders)
    rcfg, params, _, res, _, _, _ = served
    zero = jax.tree.map(jnp.zeros_like, params)
    ev = evaluate_retrieval(rcfg, zero, list(res.values()))
    assert ev["rr"] < 0.01
    assert ev["rr"] == pytest.approx(2.0 / rcfg.d, rel=0.5)


# ---------------------------------------------------------------------------
# the top-k tie-break contract (DESIGN.md §11): equal Eq. 3 scores
# resolve lowest-item-id first on ALL THREE decode paths, even when the
# tie group straddles chunk (streaming oracle) or v_tile (pallas) edges
# ---------------------------------------------------------------------------

def _tie_reference(spec, logp, topk):
    """One-shot XLA reference: materialize every Eq. 3 score, take
    jax.lax.top_k — whose tie-break is lowest index wins."""
    scores = bloom.decode_scores(spec, logp)
    return jax.lax.top_k(scores, topk)


@pytest.mark.parametrize("logp_kind", ["constant", "collision"])
def test_topk_tiebreak_contract_three_paths(logp_kind):
    # d >> number of distinct (k=2, m=16) hash sets => massive score
    # ties, guaranteed to straddle the chunk=64 / v_tile=64 boundaries
    spec = bloom.BloomSpec(d=256, m=16, k=2, seed=1, on_the_fly=True)
    topk = 12
    if logp_kind == "constant":
        logits = jnp.zeros((3, spec.m))            # ALL d scores equal
    else:
        logits = jax.random.normal(jax.random.PRNGKey(0), (3, spec.m))
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)

    ref_v, ref_i = _tie_reference(spec, logp, topk)
    if logp_kind == "constant":
        # the contract made concrete: a full tie returns items 0..topk-1
        assert np.array_equal(np.asarray(ref_i),
                              np.tile(np.arange(topk), (3, 1)))

    # path 2: streaming oracle, small chunk so ties cross merges
    s_v, s_i = bloom.decode_topk(spec, logp, topk, chunk=64)
    np.testing.assert_array_equal(np.asarray(s_i), np.asarray(ref_i))
    np.testing.assert_allclose(np.asarray(s_v), np.asarray(ref_v),
                               rtol=1e-6)

    # path 3: the Pallas kernel, small v_tile so ties cross tiles
    H = bloom.cached_hash_matrix(spec)
    p_v, p_i = bloom_decode_topk_pallas(logp, H, topk, b_tile=2,
                                        v_tile=64)
    np.testing.assert_array_equal(np.asarray(p_i), np.asarray(ref_i))
    np.testing.assert_allclose(np.asarray(p_v), np.asarray(ref_v),
                               rtol=1e-6)

    # and the shared serving entrypoint (io.recover_topk_spec) follows
    # the same contract on its xla path, with inactive rows masked
    active = jnp.array([True, False, True])
    r_v, r_i = io_lib.recover_topk_spec(spec, logits, topk, impl="xla",
                                        chunk=64, active=active)
    np.testing.assert_array_equal(np.asarray(r_i)[0], np.asarray(ref_i)[0])
    assert np.all(np.asarray(r_i)[1] == 0)
    assert np.all(np.isneginf(np.asarray(r_v)[1]))
