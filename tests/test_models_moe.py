"""MoE: capacity-buffer routing vs dense oracle, aux loss, shared experts."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import MoEConfig, ModelConfig
from repro.models import moe

KEY = jax.random.PRNGKey(0)


def _cfg(E=8, k=2, shared=0, cf=8.0):
    return ModelConfig(
        name="moe", family="moe", num_layers=1, d_model=16, num_heads=2,
        num_kv_heads=2, d_ff=32, vocab=64, dtype="float32",
        moe=MoEConfig(num_experts=E, top_k=k, num_shared=shared,
                      d_ff_expert=24, capacity_factor=cf))


def test_capacity_path_matches_dense_oracle_when_no_drops():
    """With a huge capacity factor nothing is dropped -> exact match."""
    cfg = _cfg(cf=16.0)
    params = moe.moe_init(KEY, cfg)
    x = jax.random.normal(jax.random.fold_in(KEY, 1), (2, 10, 16))
    y1, aux = moe.moe_apply(params, x, cfg)
    y2 = moe.moe_apply_reference(params, x, cfg)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)
    assert np.isfinite(float(aux))


def test_shared_experts_added():
    cfg = _cfg(shared=2, cf=16.0)
    params = moe.moe_init(KEY, cfg)
    x = jax.random.normal(KEY, (1, 6, 16))
    y1, _ = moe.moe_apply(params, x, cfg)
    y2 = moe.moe_apply_reference(params, x, cfg)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)


def test_capacity_dropping_bounds_work():
    """Tiny capacity factor must not crash; output stays finite."""
    cfg = _cfg(cf=0.25)
    params = moe.moe_init(KEY, cfg)
    x = jax.random.normal(KEY, (2, 16, 16))
    y, aux = moe.moe_apply(params, x, cfg)
    assert np.isfinite(np.asarray(y)).all()


def test_aux_loss_uniform_router_is_one():
    """Perfectly balanced routing gives aux ~= 1 (E * sum_e (1/E)*(1/E))."""
    cfg = _cfg(E=4, k=1, cf=16.0)
    params = moe.moe_init(KEY, cfg)
    # zero router weights -> uniform probs; top-1 picks expert 0 always,
    # so f is concentrated: aux = E * (1 * 1/E) = 1
    params["router"] = jnp.zeros_like(params["router"])
    x = jax.random.normal(KEY, (1, 8, 16))
    _, aux = moe.moe_apply(params, x, cfg)
    assert float(aux) == pytest.approx(1.0, rel=1e-3)


def test_grads_flow_through_routing():
    cfg = _cfg(cf=16.0)
    params = moe.moe_init(KEY, cfg)
    x = jax.random.normal(KEY, (1, 8, 16))

    def loss(p):
        y, aux = moe.moe_apply(p, x, cfg)
        return (y ** 2).sum() + aux

    g = jax.grad(loss)(params)
    gn = float(jnp.linalg.norm(g["router"]))
    assert np.isfinite(gn) and gn > 0
    assert float(jnp.linalg.norm(g["w_down"])) > 0


def test_top1_routes_to_argmax_expert():
    cfg = _cfg(E=4, k=1, cf=16.0)
    params = moe.moe_init(KEY, cfg)
    x = jax.random.normal(jax.random.fold_in(KEY, 7), (1, 5, 16))
    logits = x.reshape(-1, 16) @ params["router"]
    sel = np.asarray(jnp.argmax(logits, -1))
    # recompute through the public api: zero out all but selected expert's
    # w_down and check output unchanged
    y_full, _ = moe.moe_apply(params, x, cfg)
    wd = np.asarray(params["w_down"])
    mask = np.zeros_like(wd)
    for e in np.unique(sel):
        mask[e] = wd[e]
    params2 = dict(params, w_down=jnp.asarray(mask))
    y_masked, _ = moe.moe_apply(params2, x, cfg)
    np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_masked),
                               atol=1e-5)
