"""Attention: flash fwd/bwd vs naive oracle, causal-skip, GQA variants,
decode-vs-prefill consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models import attention as A

KEY = jax.random.PRNGKey(0)


def _qkv(B=2, S=12, KV=2, G=2, hd=8, T=None):
    T = T or S
    q = jax.random.normal(KEY, (B, S, KV, G, hd))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (B, T, KV, hd))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (B, T, KV, hd))
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    post = jnp.broadcast_to(jnp.arange(T)[None], (B, T))
    return q, k, v, pos, post


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("chunk", [4, 5, 12, 64])
def test_flash_matches_naive_fwd(causal, chunk):
    q, k, v, pos, post = _qkv()
    o1 = A.chunked_attention(q, k, v, causal=causal, chunk_k=chunk,
                             q_pos=pos, kv_pos=post)
    o2 = A.naive_attention(q, k, v, causal=causal, q_pos=pos, kv_pos=post)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-5)


@pytest.mark.parametrize("causal", [True, False])
def test_flash_grads_match_naive(causal):
    q, k, v, pos, post = _qkv()

    def loss_chunk(q, k, v):
        o = A.chunked_attention(q, k, v, causal=causal, chunk_k=5,
                                q_pos=pos, kv_pos=post)
        return (o ** 2).sum()

    def loss_naive(q, k, v):
        o = A.naive_attention(q, k, v, causal=causal, q_pos=pos,
                              kv_pos=post)
        return (o ** 2).sum()

    g1 = jax.grad(loss_chunk, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(loss_naive, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-5)


def test_causal_skip_matches_rectangle():
    q, k, v, pos, post = _qkv(S=16)
    o1 = A.chunked_attention_causal_skip(q, k, v, chunk_q=4, chunk_k=4,
                                         q_pos=pos, kv_pos=post)
    o2 = A.chunked_attention(q, k, v, causal=True, chunk_k=4, q_pos=pos,
                             kv_pos=post)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-5)


def test_kv_valid_masking():
    q, k, v, pos, post = _qkv(S=6)
    valid = jnp.array([[True] * 4 + [False] * 2] * 2)
    o1 = A.chunked_attention(q, k, v, causal=False, chunk_k=3, q_pos=pos,
                             kv_pos=post, kv_valid=valid)
    o2 = A.naive_attention(q, k[:, :4], v[:, :4], causal=False)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-5)


def _mk_cfg(**kw):
    base = dict(name="t", num_layers=1, d_model=32, num_heads=4,
                num_kv_heads=2, head_dim=8, d_ff=64, vocab=64,
                dtype="float32", attn_chunk_q=4, attn_chunk_k=4)
    base.update(kw)
    return ModelConfig(**base)


@pytest.mark.parametrize("kw", [
    {}, {"qk_norm": True}, {"qkv_bias": True},
    {"num_kv_heads": 4}, {"use_rope": False}, {"causal_skip": True},
])
def test_self_attention_variants_shapes_and_finite(kw):
    cfg = _mk_cfg(**kw)
    params = A.attention_init(KEY, cfg)
    x = jax.random.normal(KEY, (2, 8, 32))
    pos = jnp.broadcast_to(jnp.arange(8)[None], (2, 8))
    y = A.self_attention(params, cfg, x, pos)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y)).all()


def test_decode_matches_full_attention_last_position():
    """Prefill S-1 tokens, decode token S-1 -> must equal a full-length
    self-attention's last position output."""
    cfg = _mk_cfg()
    params = A.attention_init(KEY, cfg)
    S = 8
    x = jax.random.normal(KEY, (2, S, 32))
    pos = jnp.broadcast_to(jnp.arange(S)[None], (2, S))
    full = A.self_attention(params, cfg, x, pos)

    out_pre, kv = A.self_attention_with_cache(
        params, cfg, x[:, :S - 1],
        jnp.broadcast_to(jnp.arange(S - 1)[None], (2, S - 1)),
        cache_dtype=jnp.float32)
    cache = A.init_kv_cache(cfg, 2, S, dtype=jnp.float32)
    cache = {
        "k": cache["k"].at[:, :S - 1].set(kv["k"]),
        "v": cache["v"].at[:, :S - 1].set(kv["v"]),
    }
    dec, _ = A.decode_self_attention(params, cfg, x[:, S - 1:],
                                     cache, S - 1)
    np.testing.assert_allclose(np.asarray(dec[:, 0]),
                               np.asarray(full[:, -1]), atol=2e-4)


def test_cross_attention_shape():
    cfg = _mk_cfg()
    params = A.attention_init(KEY, cfg)
    x = jax.random.normal(KEY, (2, 5, 32))
    enc = jax.random.normal(jax.random.fold_in(KEY, 3), (2, 9, 32))
    pos = jnp.broadcast_to(jnp.arange(5)[None], (2, 5))
    y = A.cross_attention(params, cfg, x, enc, pos)
    assert y.shape == (2, 5, 32)
