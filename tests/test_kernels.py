"""Pallas kernels vs ref.py oracles: shape/dtype sweeps and custom-VJP
gradient checks (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.bloom import BloomSpec
from repro.kernels import ops, ref
from repro.kernels.bloom_ce import bloom_ce_pallas
from repro.kernels.bloom_decode import bloom_decode_pallas
from repro.kernels.bloom_decode_topk import bloom_decode_topk_pallas
from repro.kernels.bloom_embed import bloom_embed_pallas

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("T,k,m,D", [
    (1, 1, 16, 32), (7, 3, 64, 48), (32, 4, 128, 256), (13, 8, 256, 100),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_bloom_embed_sweep(T, k, m, D, dtype):
    table = jax.random.normal(KEY, (m, D), dtype)
    idx = jax.random.randint(jax.random.fold_in(KEY, 1), (T, k), 0, m)
    got = bloom_embed_pallas(table, idx, d_tile=64, interpret=True)
    want = ref.bloom_embed_ref(table, idx)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-2 if dtype == jnp.bfloat16 else 1e-6,
                               atol=1e-2 if dtype == jnp.bfloat16 else 1e-6)


@pytest.mark.parametrize("B,m,d,k", [
    (1, 32, 100, 1), (5, 64, 333, 3), (8, 128, 1024, 4), (3, 96, 50, 2),
])
def test_bloom_decode_sweep(B, m, d, k):
    logp = jax.nn.log_softmax(jax.random.normal(KEY, (B, m)))
    H = jax.random.randint(jax.random.fold_in(KEY, 2), (d, k), 0, m)
    got = bloom_decode_pallas(logp, H, b_tile=4, v_tile=64, interpret=True)
    want = ref.bloom_decode_ref(logp, H)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("T,m,k", [
    (1, 16, 1), (9, 64, 4), (32, 128, 3), (17, 256, 8),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_bloom_ce_sweep(T, m, k, dtype):
    z = jax.random.normal(KEY, (T, m), dtype)
    h = jax.random.randint(jax.random.fold_in(KEY, 3), (T, k), 0, m)
    got = bloom_ce_pallas(z, h, t_tile=4, interpret=True)
    want = ref.bloom_ce_ref(z, h)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-2 if dtype == jnp.bfloat16 else 1e-5,
                               atol=2e-2 if dtype == jnp.bfloat16 else 1e-5)


def test_ops_match_model_layer_oracles():
    """kernels.ops wrappers == repro.core jnp implementations end to end."""
    from repro.core import losses
    from repro.core.bloom import decode_scores
    spec = BloomSpec(d=500, m=128, k=4, seed=3)
    table = jax.random.normal(KEY, (128, 64))
    tokens = jax.random.randint(KEY, (2, 5), 0, 500)

    got = ops.bloom_embed(table, tokens, spec)
    idx = spec.indices_for(tokens)
    want = jnp.take(table, idx, axis=0).sum(axis=2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5)

    logits = jax.random.normal(KEY, (2, 5, 128))
    labels = jax.random.randint(KEY, (2, 5), 0, 500)
    got = ops.bloom_ce(logits, labels, spec)
    want = losses.bloom_xent_label(spec, logits, labels)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)

    logp = jax.nn.log_softmax(jax.random.normal(KEY, (3, 128)))
    got = ops.bloom_decode(logp, spec)
    want = decode_scores(spec, logp)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("B,m,d,k,topk", [
    (1, 32, 100, 1, 1), (5, 64, 333, 3, 8), (8, 128, 1024, 4, 16),
    (3, 96, 50, 2, 50),   # topk == d: full sort equivalence
])
def test_bloom_decode_topk_sweep(B, m, d, k, topk):
    """Fused streaming decode-topk == decode-then-top_k, without the (B, d)
    intermediate."""
    logp = jax.nn.log_softmax(jax.random.normal(KEY, (B, m)))
    H = jax.random.randint(jax.random.fold_in(KEY, 2), (d, k), 0, m)
    vals, ids = bloom_decode_topk_pallas(logp, H, topk, b_tile=4, v_tile=64,
                                         interpret=True)
    scores = ref.bloom_decode_ref(logp, H)
    want_v, _ = jax.lax.top_k(scores, topk)
    np.testing.assert_allclose(np.asarray(vals), np.asarray(want_v),
                               rtol=1e-5, atol=1e-5)
    # ids must point at rows achieving those scores (ties may permute ids)
    picked = jnp.take_along_axis(scores, ids, axis=-1)
    np.testing.assert_allclose(np.asarray(picked), np.asarray(want_v),
                               rtol=1e-5, atol=1e-5)
    assert int(ids.min()) >= 0 and int(ids.max()) < d


def test_bloom_decode_topk_masked_vocab_never_yields_sentinel_ids():
    """-inf log-probs (masked vocab) must yield real vocab ids and the same
    lowest-index tie ordering as decode-then-top_k — no -1 sentinels."""
    B, m, d, k, topk = 3, 32, 300, 2, 8
    logp = jax.nn.log_softmax(jax.random.normal(KEY, (B, m)))
    # mask most of the m-space: the vast majority of Eq. 3 scores hit -inf
    logp = logp.at[:, 4:].set(-jnp.inf)
    H = jax.random.randint(jax.random.fold_in(KEY, 2), (d, k), 0, m)
    vals, ids = bloom_decode_topk_pallas(logp, H, topk, b_tile=2, v_tile=64,
                                         interpret=True)
    scores = ref.bloom_decode_ref(logp, H)
    want_v, want_i = jax.lax.top_k(scores, topk)
    np.testing.assert_array_equal(np.asarray(ids), np.asarray(want_i))
    np.testing.assert_allclose(np.asarray(vals), np.asarray(want_v))
    assert int(ids.min()) >= 0


@pytest.mark.parametrize("occupancy", [1 / 8, 1 / 2, 1.0])
@pytest.mark.parametrize("b_tile", [1, 4])
def test_bloom_decode_topk_row_skipping_matches_dense(occupancy, b_tile):
    """The slot-occupancy-prefetched grid == the dense grid on every row
    block containing a live slot, and (-inf, 0) on fully-dead blocks —
    exactly the post-hoc masking recover_topk applies (DESIGN.md §8).
    With b_tile=1 that is per-slot-row skipping."""
    B, m, d, k, topk = 8, 64, 333, 3, 5
    logp = jax.nn.log_softmax(jax.random.normal(KEY, (B, m)))
    H = jax.random.randint(jax.random.fold_in(KEY, 2), (d, k), 0, m)
    active = np.zeros(B, bool)
    active[:max(1, int(B * occupancy))] = True

    vals, ids = bloom_decode_topk_pallas(
        logp, H, topk, b_tile=b_tile, v_tile=64, interpret=True,
        active=jnp.asarray(active))
    dense_v, dense_i = bloom_decode_topk_pallas(
        logp, H, topk, b_tile=b_tile, v_tile=64, interpret=True)

    live_block = active.reshape(-1, b_tile).any(axis=1).repeat(b_tile)
    np.testing.assert_array_equal(np.asarray(vals)[live_block],
                                  np.asarray(dense_v)[live_block])
    np.testing.assert_array_equal(np.asarray(ids)[live_block],
                                  np.asarray(dense_i)[live_block])
    assert np.all(np.asarray(vals)[~live_block] == -np.inf)
    assert np.all(np.asarray(ids)[~live_block] == 0)


def test_bloom_decode_topk_row_skipping_scattered_occupancy():
    """Non-contiguous live slots (the realistic mid-flight pool): blocks
    are skipped wherever a whole b_tile of slots drained, and the pinned
    logp/H index maps never corrupt a later live block's output."""
    B, m, d, k, topk = 12, 48, 257, 2, 4
    logp = jax.nn.log_softmax(jax.random.normal(KEY, (B, m)))
    H = jax.random.randint(jax.random.fold_in(KEY, 3), (d, k), 0, m)
    # live, dead, dead, live blocks at b_tile=3
    active = np.array([True, False, True,
                       False, False, False,
                       False, False, False,
                       False, True, False])
    vals, ids = bloom_decode_topk_pallas(
        logp, H, topk, b_tile=3, v_tile=64, interpret=True,
        active=jnp.asarray(active))
    dense_v, dense_i = bloom_decode_topk_pallas(
        logp, H, topk, b_tile=3, v_tile=64, interpret=True)
    live_block = active.reshape(-1, 3).any(axis=1).repeat(3)
    np.testing.assert_array_equal(np.asarray(vals)[live_block],
                                  np.asarray(dense_v)[live_block])
    np.testing.assert_array_equal(np.asarray(ids)[live_block],
                                  np.asarray(dense_i)[live_block])
    assert np.all(np.asarray(vals)[~live_block] == -np.inf)

    # leading dead blocks (low slots drained first — forward pin path):
    # only the LAST block is live
    active2 = np.zeros(B, bool)
    active2[-2] = True
    vals2, ids2 = bloom_decode_topk_pallas(
        logp, H, topk, b_tile=3, v_tile=64, interpret=True,
        active=jnp.asarray(active2))
    np.testing.assert_array_equal(np.asarray(vals2)[-3:],
                                  np.asarray(dense_v)[-3:])
    np.testing.assert_array_equal(np.asarray(ids2)[-3:],
                                  np.asarray(dense_i)[-3:])
    assert np.all(np.asarray(vals2)[:-3] == -np.inf)
    assert np.all(np.asarray(ids2)[:-3] == 0)


def test_recover_topk_active_mask_drives_row_skipping_kernel():
    """io.recover_topk(active=...) on the pallas path returns the same
    (scores, ids) as the xla path with the same mask — the kernel-level
    block skipping composes with the row-level post-mask."""
    import dataclasses
    from repro import configs
    from repro.models import io as io_lib

    cfg = configs.get_smoke_config("qwen1.5-0.5b")
    B = 6
    logits = jax.random.normal(KEY, (B, cfg.m_vocab))
    active = jnp.asarray(np.array([True, False, True, False, False, True]))
    cfg_x = dataclasses.replace(cfg, io_impl="xla")
    cfg_p = dataclasses.replace(cfg, io_impl="pallas")
    sx, ix = io_lib.recover_topk(cfg_x, logits, topk=4, active=active)
    sp, ip = io_lib.recover_topk(cfg_p, logits, topk=4, active=active)
    np.testing.assert_allclose(np.asarray(sx), np.asarray(sp),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(ix), np.asarray(ip))
    assert np.all(np.asarray(sp)[~np.asarray(active)] == -np.inf)
    assert np.all(np.asarray(ip)[~np.asarray(active)] == 0)


# --------------------------------------------------------------------------
# custom-VJP gradients vs the XLA oracles (acceptance: <= 1e-4 max abs err)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("T,k,m,D", [
    (1, 1, 16, 32), (7, 3, 64, 48), (32, 4, 128, 256), (13, 8, 256, 100),
])
def test_bloom_embed_grad(T, k, m, D):
    """Scatter-add backward kernel == XLA gather-sum gradient."""
    table = jax.random.normal(KEY, (m, D))
    idx = jax.random.randint(jax.random.fold_in(KEY, 1), (T, k), 0, m)
    cot = jax.random.normal(jax.random.fold_in(KEY, 9), (T, D))
    g_pal = jax.grad(lambda t: jnp.sum(
        bloom_embed_pallas(t, idx, d_tile=64, interpret=True) * cot))(table)
    g_ref = jax.grad(lambda t: jnp.sum(
        ref.bloom_embed_ref(t, idx) * cot))(table)
    np.testing.assert_allclose(np.asarray(g_pal), np.asarray(g_ref),
                               atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("T,m,k", [
    (1, 16, 1), (9, 64, 4), (32, 128, 3), (17, 256, 8),
])
def test_bloom_ce_grad(T, m, k):
    """lse-residual backward kernel == XLA softmax-CE gradient."""
    z = jax.random.normal(KEY, (T, m))
    h = jax.random.randint(jax.random.fold_in(KEY, 3), (T, k), 0, m)
    cot = jax.random.normal(jax.random.fold_in(KEY, 9), (T,))
    g_pal = jax.grad(lambda zz: jnp.sum(
        bloom_ce_pallas(zz, h, t_tile=4, interpret=True) * cot))(z)
    g_ref = jax.grad(lambda zz: jnp.sum(
        ref.bloom_ce_ref(zz, h) * cot))(z)
    np.testing.assert_allclose(np.asarray(g_pal), np.asarray(g_ref),
                               atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("B,m,d,k", [
    (1, 32, 100, 1), (5, 64, 333, 3), (8, 128, 1024, 4),
])
def test_bloom_decode_grad(B, m, d, k):
    """Blocked scatter-add backward kernel == XLA Eq. 3 gradient."""
    logp = jax.nn.log_softmax(jax.random.normal(KEY, (B, m)))
    H = jax.random.randint(jax.random.fold_in(KEY, 2), (d, k), 0, m)
    cot = jax.random.normal(jax.random.fold_in(KEY, 9), (B, d))
    g_pal = jax.grad(lambda lp: jnp.sum(
        bloom_decode_pallas(lp, H, b_tile=4, v_tile=64,
                            interpret=True) * cot))(logp)
    g_ref = jax.grad(lambda lp: jnp.sum(
        ref.bloom_decode_ref(lp, H) * cot))(logp)
    np.testing.assert_allclose(np.asarray(g_pal), np.asarray(g_ref),
                               atol=1e-4, rtol=1e-4)


# --------------------------------------------------------------------------
# CSR-binned backward (bwd_impl="csr") vs the XLA oracle AND the dense
# Pallas backward — uniform, collision-heavy (skewed-hash) and ragged
# (non-tile-multiple T / m) shapes, incl. the all-tokens-in-one-m-tile
# and empty-m-tile extremes (ISSUE 5)
# --------------------------------------------------------------------------

def _embed_grads(table, idx, cot, *, m_tile, e_tile):
    """(csr, dense, oracle) dtable gradients for one embed shape."""
    g_csr = jax.grad(lambda t: jnp.sum(
        bloom_embed_pallas(t, idx, d_tile=64, interpret=True,
                           bwd_impl="csr", m_tile=m_tile,
                           e_tile=e_tile) * cot))(table)
    g_dense = jax.grad(lambda t: jnp.sum(
        bloom_embed_pallas(t, idx, d_tile=64, interpret=True,
                           bwd_impl="dense", m_tile=m_tile) * cot))(table)
    g_ref = jax.grad(lambda t: jnp.sum(
        ref.bloom_embed_ref(t, idx) * cot))(table)
    return g_csr, g_dense, g_ref


@pytest.mark.parametrize("T,k,m,D,m_tile,e_tile", [
    (1, 1, 16, 32, 16, 4),      # single entry, single tile
    (7, 3, 60, 48, 16, 4),      # ragged m (not an m_tile multiple)
    (13, 8, 250, 100, 64, 128), # e_tile > per-segment entries, ragged m
    (32, 4, 128, 256, 32, 8),   # multi-tile segments
    (5, 2, 40, 20, 16, 3),      # non-power-of-two e_tile, ragged T
])
def test_bloom_embed_grad_csr_uniform(T, k, m, D, m_tile, e_tile):
    """CSR backward == oracle == dense backward on uniform hash draws."""
    table = jax.random.normal(KEY, (m, D))
    idx = jax.random.randint(jax.random.fold_in(KEY, 1), (T, k), 0, m)
    cot = jax.random.normal(jax.random.fold_in(KEY, 9), (T, D))
    g_csr, g_dense, g_ref = _embed_grads(table, idx, cot,
                                         m_tile=m_tile, e_tile=e_tile)
    np.testing.assert_allclose(np.asarray(g_csr), np.asarray(g_ref),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(g_csr), np.asarray(g_dense),
                               atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("hot", [1, 3])
def test_bloom_embed_grad_csr_collision_heavy(hot):
    """Skewed-hash extreme: every entry collides into `hot` distinct
    indices of ONE m-tile — one long multi-tile segment, every other
    m-tile empty (the pad-tile path must still zero their blocks)."""
    T, k, m, D, m_tile, e_tile = 24, 4, 160, 64, 32, 8
    table = jax.random.normal(KEY, (m, D))
    idx = jax.random.randint(jax.random.fold_in(KEY, 2), (T, k), 0, hot)
    cot = jax.random.normal(jax.random.fold_in(KEY, 9), (T, D))
    g_csr, g_dense, g_ref = _embed_grads(table, idx, cot,
                                         m_tile=m_tile, e_tile=e_tile)
    np.testing.assert_allclose(np.asarray(g_csr), np.asarray(g_ref),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(g_csr), np.asarray(g_dense),
                               atol=1e-4, rtol=1e-4)
    # rows the skew never touched must come back exactly zero
    assert np.all(np.asarray(g_csr)[hot:] == 0.0)


def test_bloom_embed_grad_csr_middle_tile_only():
    """Entries confined to a MIDDLE m-tile: leading and trailing m-tiles
    are both empty (exercises pad tiles on both sides of the live run)."""
    T, k, m, D, m_tile, e_tile = 9, 3, 96, 40, 32, 4
    table = jax.random.normal(KEY, (m, D))
    idx = 32 + jax.random.randint(jax.random.fold_in(KEY, 3), (T, k),
                                  0, 32)
    cot = jax.random.normal(jax.random.fold_in(KEY, 9), (T, D))
    g_csr, _, g_ref = _embed_grads(table, idx, cot,
                                   m_tile=m_tile, e_tile=e_tile)
    np.testing.assert_allclose(np.asarray(g_csr), np.asarray(g_ref),
                               atol=1e-4, rtol=1e-4)
    got = np.asarray(g_csr)
    assert np.all(got[:32] == 0.0) and np.all(got[64:] == 0.0)


@pytest.mark.parametrize("B,m,d,k,m_tile,e_tile", [
    (1, 32, 100, 1, 16, 8),
    (5, 64, 333, 3, 16, 32),    # ragged everything
    (8, 128, 1024, 4, 64, 128),
])
def test_bloom_decode_grad_csr(B, m, d, k, m_tile, e_tile):
    """CSR decode backward (shared row-scatter kernel on the transposed
    cotangent) == oracle == dense backward."""
    logp = jax.nn.log_softmax(jax.random.normal(KEY, (B, m)))
    H = jax.random.randint(jax.random.fold_in(KEY, 2), (d, k), 0, m)
    cot = jax.random.normal(jax.random.fold_in(KEY, 9), (B, d))

    def run(impl):
        return jax.grad(lambda lp: jnp.sum(
            bloom_decode_pallas(lp, H, b_tile=4, v_tile=64,
                                interpret=True, bwd_impl=impl,
                                m_tile=m_tile, e_tile=e_tile) * cot))(logp)

    g_csr, g_dense = run("csr"), run("dense")
    g_ref = jax.grad(lambda lp: jnp.sum(
        ref.bloom_decode_ref(lp, H) * cot))(logp)
    np.testing.assert_allclose(np.asarray(g_csr), np.asarray(g_ref),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(g_csr), np.asarray(g_dense),
                               atol=1e-4, rtol=1e-4)


def test_bloom_decode_grad_csr_skewed_hash():
    """Collision-heavy H (whole vocab hashes into one m-tile)."""
    B, m, d, k, m_tile = 4, 96, 200, 3, 32
    logp = jax.nn.log_softmax(jax.random.normal(KEY, (B, m)))
    H = jax.random.randint(jax.random.fold_in(KEY, 5), (d, k), 0, 7)
    cot = jax.random.normal(jax.random.fold_in(KEY, 9), (B, d))
    g_csr = jax.grad(lambda lp: jnp.sum(
        bloom_decode_pallas(lp, H, b_tile=4, v_tile=64, interpret=True,
                            bwd_impl="csr", m_tile=m_tile,
                            e_tile=16) * cot))(logp)
    g_ref = jax.grad(lambda lp: jnp.sum(
        ref.bloom_decode_ref(lp, H) * cot))(logp)
    np.testing.assert_allclose(np.asarray(g_csr), np.asarray(g_ref),
                               atol=1e-4, rtol=1e-4)
    assert np.all(np.asarray(g_csr)[:, 7:] == 0.0)


def test_ops_decode_grad_uses_cached_bins():
    """ops.bloom_decode's csr path rides the per-spec cached bins thunk
    and still matches the XLA Eq. 3 gradient; forward-only calls never
    build the bins (the thunk resolves at backward-trace time only)."""
    from repro.core.bloom import cached_decode_bins, decode_scores
    from repro.kernels.bloom_csr import CSR_E_TILE
    from repro.kernels.common import BWD_M_TILE
    spec = BloomSpec(d=500, m=128, k=4, seed=3)
    logp = jax.nn.log_softmax(jax.random.normal(KEY, (3, 128)))
    cot = jax.random.normal(jax.random.fold_in(KEY, 9), (3, 500))

    # forward-only: no bins are built for a never-differentiated spec
    spec_fwd = BloomSpec(d=500, m=128, k=4, seed=4)
    hits0 = cached_decode_bins.cache_info().currsize
    ops.bloom_decode(logp, spec_fwd)
    assert cached_decode_bins.cache_info().currsize == hits0, \
        "forward-only bloom_decode must not pay the binning sort"

    # the hardest path: grad under an OUTER user jit — the bins thunk
    # resolves inside the backward trace, and both per-spec caches must
    # come out holding CONCRETE arrays (ensure_compile_time_eval), never
    # the outer trace's tracers
    g_csr = jax.grad(jax.jit(lambda lp: jnp.sum(
        ops.bloom_decode(lp, spec) * cot)))(logp)
    g_ref = jax.grad(lambda lp: jnp.sum(
        decode_scores(spec, lp) * cot))(logp)
    np.testing.assert_allclose(np.asarray(g_csr), np.asarray(g_ref),
                               atol=1e-4, rtol=1e-4)
    # the grad above populated the cache with concrete, eagerly-usable
    # arrays; hits return the same object
    b1 = cached_decode_bins(spec, BWD_M_TILE, CSR_E_TILE)
    b2 = cached_decode_bins(spec, BWD_M_TILE, CSR_E_TILE)
    assert b1.tok is b2.tok
    assert int(np.asarray(b1.tile_len).sum()) == spec.d * spec.k
    # and an eager (un-jitted) grad after the jitted one still works
    g_eager = jax.grad(lambda lp: jnp.sum(
        ops.bloom_decode(lp, spec) * cot))(logp)
    np.testing.assert_allclose(np.asarray(g_eager), np.asarray(g_ref),
                               atol=1e-4, rtol=1e-4)


def test_bin_csr_layout_invariants():
    """The binning pass is a permutation: every (source row, m index)
    entry lands in exactly one live slot of a tile owned by its m-block;
    tiles are sorted by block, every block owns >= 1 tile, and pad slots
    are sentinel-valued."""
    from repro.kernels.bloom_csr import bin_csr, csr_tile_counts
    T, k, m, m_tile, e_tile = 23, 5, 150, 32, 8
    idx = jax.random.randint(jax.random.fold_in(KEY, 7), (T, k), 0, m)
    bins = bin_csr(idx, m, m_tile=m_tile, e_tile=e_tile)
    nM, NT, et = csr_tile_counts(m, T * k, m_tile, e_tile)
    assert et == e_tile and bins.n_tiles == NT and bins.e_tile == e_tile

    tok = np.asarray(bins.tok)
    val = np.asarray(bins.val)[:, 0]
    tmb = np.asarray(bins.tile_mb)
    tfirst = np.asarray(bins.tile_first)
    tlen = np.asarray(bins.tile_len)

    # live (tok, val) pairs == the original (row, idx) entries, as multisets
    live = val >= 0
    got = sorted(zip(tok[live].tolist(), val[live].tolist()))
    want = sorted((t, int(v)) for t, row in enumerate(np.asarray(idx))
                  for v in row)
    assert got == want
    # tiles ascend by block; every block appears; first flags mark runs
    assert (np.diff(tmb) >= 0).all()
    assert set(range(nM)) <= set(tmb.tolist())
    assert tfirst.sum() == nM
    for t in range(NT):
        s = slice(t * e_tile, (t + 1) * e_tile)
        v = val[s]
        assert (v[:tlen[t]] >= 0).all()            # live prefix ...
        assert (v[tlen[t]:] == -1).all()           # ... then pad slots
        if tlen[t]:
            assert ((v[:tlen[t]] // m_tile) == tmb[t]).all()
    assert tlen.sum() == T * k


def test_bwd_impl_validation():
    table = jax.random.normal(KEY, (32, 16))
    idx = jax.random.randint(KEY, (4, 2), 0, 32)
    with pytest.raises(ValueError, match="bwd_impl"):
        bloom_embed_pallas(table, idx, interpret=True, bwd_impl="nope")
    logp = jax.nn.log_softmax(jax.random.normal(KEY, (2, 32)))
    H = jax.random.randint(KEY, (50, 2), 0, 32)
    with pytest.raises(ValueError, match="bwd_impl"):
        bloom_decode_pallas(logp, H, interpret=True, bwd_impl="nope")


def test_csr_bins_tiling_mismatch_is_rejected():
    """Bins carry (m, m_tile) as static metadata; the kernel entry must
    refuse bins built for a different tiling instead of silently
    scattering into the wrong output blocks."""
    from repro.kernels.bloom_csr import bin_csr, csr_scatter_add_pallas
    m, D, T, k = 96, 24, 6, 2
    idx = jax.random.randint(jax.random.fold_in(KEY, 4), (T, k), 0, m)
    g = jax.random.normal(KEY, (T, D))
    bins = bin_csr(idx, m, m_tile=16, e_tile=4)
    with pytest.raises(ValueError, match="mismatched bins"):
        csr_scatter_add_pallas(g, bins, m, m_tile=32, interpret=True)
    with pytest.raises(ValueError, match="mismatched bins"):
        csr_scatter_add_pallas(g, bins, m - 32, m_tile=16, interpret=True)


def test_interpret_defaults_to_backend_autodetect():
    """Satellite: no `interpret=` arg must NOT force interpret mode on TPU —
    kernels resolve it from the backend (True here: CPU test box)."""
    from repro.kernels.common import resolve_interpret
    assert resolve_interpret(None) == (jax.default_backend() != "tpu")
    assert resolve_interpret(True) is True
    assert resolve_interpret(False) is False
    # entry points accept interpret=None end to end
    table = jax.random.normal(KEY, (32, 16))
    idx = jax.random.randint(KEY, (4, 2), 0, 32)
    out = bloom_embed_pallas(table, idx)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.bloom_embed_ref(table, idx)),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("bwd_impl", ["csr", "dense"])
def test_grad_through_model_pallas_vs_xla(bwd_impl):
    """jax.grad of the full LM loss: io_impl='pallas' == io_impl='xla'
    for both Bloom backwards (csr is the ModelConfig default)."""
    import dataclasses
    from repro import configs
    from repro.models import transformer as tf
    cfg_x = configs.get_smoke_config("qwen3-4b", dtype="float32")
    cfg_p = dataclasses.replace(cfg_x, io_impl="pallas",
                                bwd_impl=bwd_impl)
    params = tf.lm_init(KEY, cfg_x)
    toks = jax.random.randint(KEY, (2, 8), 0, cfg_x.vocab)

    def loss(p, cfg):
        l, _ = tf.lm_loss_fn(p, cfg, {"tokens": toks})
        return l

    gx = jax.grad(loss)(params, cfg_x)
    gp = jax.grad(loss)(params, cfg_p)
    flat_x = jax.tree.leaves(gx)
    flat_p = jax.tree.leaves(gp)
    for a, b in zip(flat_x, flat_p):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-4, rtol=1e-3)


def test_pallas_io_impl_in_model():
    """A model configured with io_impl='pallas' must match io_impl='xla'."""
    from repro import configs
    from repro.models import transformer as tf
    cfg_x = configs.get_smoke_config("qwen3-4b", dtype="float32")
    import dataclasses
    cfg_p = dataclasses.replace(cfg_x, io_impl="pallas")
    params = tf.lm_init(KEY, cfg_x)
    toks = jax.random.randint(KEY, (2, 8), 0, cfg_x.vocab)
    lx, _ = tf.lm_loss_fn(params, cfg_x, {"tokens": toks})
    lp, _ = tf.lm_loss_fn(params, cfg_p, {"tokens": toks})
    assert float(lx) == pytest.approx(float(lp), rel=1e-5)


# ---------------------------------------------------------------------------
# Quantized tables (table_dtype, DESIGN.md §13)
# ---------------------------------------------------------------------------

from repro.core import quant  # noqa: E402  (quant tests below)


@pytest.mark.parametrize("table_dtype", list(quant.TABLE_DTYPES))
@pytest.mark.parametrize("T,k,m,D", [(7, 3, 64, 48), (32, 4, 128, 256)])
def test_bloom_embed_quantized_sweep(table_dtype, T, k, m, D):
    """Quantized forward == gather-sum over the DEQUANTIZED table (the
    XLA storage-model oracle): the kernel's in-VMEM dequant must match
    quantize+dequantize outside the kernel bit-for-bit in math."""
    table = jax.random.normal(KEY, (m, D), jnp.float32)
    idx = jax.random.randint(jax.random.fold_in(KEY, 1), (T, k), 0, m)
    got = bloom_embed_pallas(table, idx, d_tile=64, interpret=True,
                             table_dtype=table_dtype,
                             out_dtype=jnp.float32)
    q, s = quant.quantize_table(table, table_dtype)
    want = ref.bloom_embed_ref(quant.dequantize_table(q, s), idx)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_bloom_embed_int8_close_to_fp32_oracle():
    """int8 storage stays within the ANALYTIC quantization bound of the
    float32 oracle: per-element error <= sum_j scales[idx[t, j]] / 2
    (per-row symmetric rounding contributes at most scale/2 per fetched
    row) — the module-doc bound of core.quant, end to end through the
    kernel."""
    T, k, m, D = 32, 4, 128, 256
    table = jax.random.normal(KEY, (m, D), jnp.float32)
    idx = jax.random.randint(jax.random.fold_in(KEY, 1), (T, k), 0, m)
    got = bloom_embed_pallas(table, idx, d_tile=64, interpret=True,
                             table_dtype="int8", out_dtype=jnp.float32)
    want = ref.bloom_embed_ref(table, idx)
    _, scales = quant.quantize_table(table, "int8")
    bound = jnp.take(scales, idx, axis=0).sum(-1, keepdims=True) / 2
    err = jnp.abs(got - want)
    assert float(jnp.max(err - bound)) <= 1e-5, (
        f"int8 embed error {float(err.max()):.4g} exceeds the analytic "
        f"scale/2-per-row bound ({float(bound.max()):.4g})")
    # and the bound itself is small on a unit-normal table (scales ~
    # amax/127 ~ 0.03): the storage knob costs < 1e-1 absolute here
    assert float(err.max()) < 0.1


@pytest.mark.parametrize("bwd_impl", ["dense", "csr"])
@pytest.mark.parametrize("table_dtype", ["int8", "fp8_e4m3"])
def test_bloom_embed_quantized_grad_straight_through(bwd_impl, table_dtype):
    """Gradients flow straight-through to the MASTER table: grad with a
    quantized forward == grad of the unquantized kernel (the fp32
    scatter-add backward is shared; only the forward's fetched rows
    change)."""
    T, k, m, D = 13, 3, 64, 32
    table = jax.random.normal(KEY, (m, D), jnp.float32)
    idx = jax.random.randint(jax.random.fold_in(KEY, 1), (T, k), 0, m)
    cot = jax.random.normal(jax.random.fold_in(KEY, 2), (T, D))

    def f(tbl, td):
        out = bloom_embed_pallas(tbl, idx, d_tile=32, interpret=True,
                                 bwd_impl=bwd_impl, table_dtype=td,
                                 out_dtype=jnp.float32)
        return jnp.vdot(out, cot)

    g_q = jax.grad(f)(table, table_dtype)
    g_f = jax.grad(f)(table, None)
    assert g_q.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(g_q), np.asarray(g_f),
                               rtol=1e-5, atol=1e-5)


def test_bloom_embed_fwd_quantized_matches_inline():
    """The frozen-params serve path (cached_quantized_table +
    bloom_embed_fwd_quantized) == the in-graph quantizing entry point."""
    from repro.core.bloom import cached_quantized_table
    from repro.kernels.bloom_embed import bloom_embed_fwd_quantized
    T, k, m, D = 9, 2, 64, 48
    spec = BloomSpec(d=300, m=m, k=k, seed=5)
    table = jax.random.normal(KEY, (m, D), jnp.float32)
    idx = jax.random.randint(jax.random.fold_in(KEY, 1), (T, k), 0, m)
    q, s = cached_quantized_table(spec, table, "int8")
    # identity-keyed cache: same table object must hit
    assert cached_quantized_table(spec, table, "int8")[0] is q
    got = bloom_embed_fwd_quantized(q, s, idx, d_tile=32, interpret=True)
    want = bloom_embed_pallas(table, idx, d_tile=32, interpret=True,
                              table_dtype="int8", out_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("table_dtype", ["bfloat16", "int8", "fp8_e4m3"])
def test_bloom_decode_topk_quantized(table_dtype):
    """Fused decode-topk over quantized resident logp == decode-then-topk
    over the fake-quantized (dequantized) logp.  Quantization may permute
    ids on induced ties, so ids are scored through the oracle's matrix
    (the `picked` contract of the unquantized sweep)."""
    B, m, d, k, topk = 5, 64, 333, 3, 8
    logp = jax.nn.log_softmax(jax.random.normal(KEY, (B, m)))
    H = jax.random.randint(jax.random.fold_in(KEY, 2), (d, k), 0, m)
    vals, ids = bloom_decode_topk_pallas(logp, H, topk, b_tile=4,
                                         v_tile=64, interpret=True,
                                         table_dtype=table_dtype)
    q, s = quant.quantize_table(logp, table_dtype)
    scores = ref.bloom_decode_ref(quant.dequantize_table(q, s), H)
    want_v, _ = jax.lax.top_k(scores, topk)
    np.testing.assert_allclose(np.asarray(vals), np.asarray(want_v),
                               rtol=1e-5, atol=1e-5)
    picked = jnp.take_along_axis(scores, ids, axis=-1)
    np.testing.assert_allclose(np.asarray(picked), np.asarray(want_v),
                               rtol=1e-5, atol=1e-5)
    assert int(ids.min()) >= 0 and int(ids.max()) < d


def test_bloom_decode_topk_int8_close_to_fp32_oracle():
    """int8 resident logp stays within the analytic k * scale/2 bound of
    the fp32 decode-topk values (each Eq. 3 score sums k row reads, each
    off by at most scale/2 after per-row symmetric rounding)."""
    B, m, d, k, topk = 8, 128, 1024, 4, 16
    logp = jax.nn.log_softmax(jax.random.normal(KEY, (B, m)))
    H = jax.random.randint(jax.random.fold_in(KEY, 2), (d, k), 0, m)
    vals, _ = bloom_decode_topk_pallas(logp, H, topk, b_tile=4, v_tile=128,
                                       interpret=True, table_dtype="int8")
    want_v, _ = jax.lax.top_k(ref.bloom_decode_ref(logp, H), topk)
    _, scales = quant.quantize_table(logp, "int8")
    bound = k * scales[:, None] / 2
    err = jnp.abs(vals - want_v)
    assert float(jnp.max(err - bound)) <= 1e-5, (
        f"int8 decode-topk error {float(err.max()):.4g} exceeds the "
        f"analytic k*scale/2 bound ({float(bound.max()):.4g})")
    assert float(err.max()) < 0.25


def test_bloom_decode_topk_inkernel_hash_matches_H():
    """hash_spec=(d, k, seed) drops the H operand and re-derives indices
    in-kernel, bit-identical to core.hashing.double_hash — so both paths
    gather the same rows.  The summed SCORES may differ by float fusion
    (XLA fuses the two paths differently, ~1 ulp; ids then permute only
    on near-exact ties), so values are compared to tight float tolerance
    and ids through the score matrix (the `picked` contract)."""
    from repro.core.bloom import cached_hash_matrix
    B, m, d, k, topk = 5, 64, 333, 3, 8
    spec = BloomSpec(d=d, m=m, k=k, seed=7)
    logp = jax.nn.log_softmax(jax.random.normal(KEY, (B, m)))
    H = cached_hash_matrix(spec)
    for td in (None, "int8"):
        v_h, _ = bloom_decode_topk_pallas(logp, H, topk, b_tile=4,
                                          v_tile=64, interpret=True,
                                          table_dtype=td)
        v_k, i_k = bloom_decode_topk_pallas(logp, None, topk, b_tile=4,
                                            v_tile=64, interpret=True,
                                            table_dtype=td,
                                            hash_spec=(d, k, spec.seed))
        np.testing.assert_allclose(np.asarray(v_k), np.asarray(v_h),
                                   rtol=1e-6, atol=1e-6)
        if td is None:
            scores = ref.bloom_decode_ref(logp, H)
        else:
            q, s = quant.quantize_table(logp, "int8")
            scores = ref.bloom_decode_ref(quant.dequantize_table(q, s), H)
        picked = jnp.take_along_axis(scores, i_k, axis=-1)
        np.testing.assert_allclose(np.asarray(picked), np.asarray(v_k),
                                   rtol=1e-6, atol=1e-6)


def test_bloom_decode_topk_quantized_row_skipping():
    """table_dtype composes with the occupancy grid: live rows match the
    dense quantized grid, fully-dead blocks return (-inf, 0)."""
    B, m, d, k, topk, b_tile = 8, 64, 333, 3, 5, 2
    logp = jax.nn.log_softmax(jax.random.normal(KEY, (B, m)))
    H = jax.random.randint(jax.random.fold_in(KEY, 2), (d, k), 0, m)
    active = jnp.asarray([True, False, False, False, True, True,
                          False, False])
    v_d, i_d = bloom_decode_topk_pallas(logp, H, topk, b_tile=b_tile,
                                        v_tile=64, interpret=True,
                                        table_dtype="int8")
    v_s, i_s = bloom_decode_topk_pallas(logp, H, topk, b_tile=b_tile,
                                        v_tile=64, interpret=True,
                                        table_dtype="int8", active=active)
    blk_live = np.asarray(active).reshape(-1, b_tile).any(axis=1)
    row_live = np.repeat(blk_live, b_tile)
    np.testing.assert_array_equal(np.asarray(v_s)[row_live],
                                  np.asarray(v_d)[row_live])
    np.testing.assert_array_equal(np.asarray(i_s)[row_live],
                                  np.asarray(i_d)[row_live])
    assert np.all(np.asarray(v_s)[~row_live] == -np.inf)
    assert np.all(np.asarray(i_s)[~row_live] == 0)


def test_table_dtype_validation():
    """Typos fail fast with the full menu, at every layer that accepts
    the knob (quant core, kernel entry, config __post_init__)."""
    import dataclasses
    from repro import configs
    from repro.configs.retrieval import get_retrieval_config
    with pytest.raises(ValueError, match="table_dtype must be one of"):
        quant.resolve_table_dtype("int4")
    # aliases canonicalize; "auto" only with allow_auto
    assert quant.resolve_table_dtype("fp32") == "float32"
    assert quant.resolve_table_dtype("auto", allow_auto=True) == "auto"
    with pytest.raises(ValueError, match="table_dtype"):
        quant.resolve_table_dtype("auto")
    table = jax.random.normal(KEY, (32, 16))
    idx = jax.random.randint(KEY, (4, 2), 0, 32)
    with pytest.raises(ValueError, match="table_dtype"):
        bloom_embed_pallas(table, idx, interpret=True, table_dtype="int4")
    with pytest.raises(ValueError, match="table_dtype"):
        get_retrieval_config("smoke", table_dtype="f16")
    cfg = configs.get_smoke_config("qwen3-4b")
    cfg_bad = dataclasses.replace(cfg, table_dtype="f16")
    from repro.models import io as io_lib
    with pytest.raises(ValueError, match="table_dtype"):
        io_lib.resolved_table_dtype(cfg_bad)


@pytest.mark.parametrize("table_dtype", ["bfloat16", "int8"])
def test_model_quantized_pallas_matches_xla_fake_quant(table_dtype):
    """Model layer: io_impl='pallas' with a table_dtype == io_impl='xla'
    fake-quantizing the same rows — the two storage models must rank and
    activate through identical dequantized values."""
    import dataclasses
    from repro import configs
    from repro.models import io as io_lib, transformer as tf
    cfg_x = configs.get_smoke_config("qwen3-4b", dtype="float32")
    cfg_x = dataclasses.replace(cfg_x, table_dtype=table_dtype)
    cfg_p = dataclasses.replace(cfg_x, io_impl="pallas")
    params = tf.lm_init(KEY, cfg_x)
    toks = jax.random.randint(KEY, (2, 8), 0, cfg_x.vocab)
    ex = io_lib.embed_tokens(params["io"], cfg_x, toks)
    ep = io_lib.embed_tokens(params["io"], cfg_p, toks)
    np.testing.assert_allclose(np.asarray(ex), np.asarray(ep),
                               rtol=1e-5, atol=1e-5)
