"""Pallas kernels vs ref.py oracles: shape/dtype sweeps (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.bloom import BloomSpec
from repro.kernels import ops, ref
from repro.kernels.bloom_ce import bloom_ce_pallas
from repro.kernels.bloom_decode import bloom_decode_pallas
from repro.kernels.bloom_embed import bloom_embed_pallas

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("T,k,m,D", [
    (1, 1, 16, 32), (7, 3, 64, 48), (32, 4, 128, 256), (13, 8, 256, 100),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_bloom_embed_sweep(T, k, m, D, dtype):
    table = jax.random.normal(KEY, (m, D), dtype)
    idx = jax.random.randint(jax.random.fold_in(KEY, 1), (T, k), 0, m)
    got = bloom_embed_pallas(table, idx, d_tile=64, interpret=True)
    want = ref.bloom_embed_ref(table, idx)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-2 if dtype == jnp.bfloat16 else 1e-6,
                               atol=1e-2 if dtype == jnp.bfloat16 else 1e-6)


@pytest.mark.parametrize("B,m,d,k", [
    (1, 32, 100, 1), (5, 64, 333, 3), (8, 128, 1024, 4), (3, 96, 50, 2),
])
def test_bloom_decode_sweep(B, m, d, k):
    logp = jax.nn.log_softmax(jax.random.normal(KEY, (B, m)))
    H = jax.random.randint(jax.random.fold_in(KEY, 2), (d, k), 0, m)
    got = bloom_decode_pallas(logp, H, b_tile=4, v_tile=64, interpret=True)
    want = ref.bloom_decode_ref(logp, H)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("T,m,k", [
    (1, 16, 1), (9, 64, 4), (32, 128, 3), (17, 256, 8),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_bloom_ce_sweep(T, m, k, dtype):
    z = jax.random.normal(KEY, (T, m), dtype)
    h = jax.random.randint(jax.random.fold_in(KEY, 3), (T, k), 0, m)
    got = bloom_ce_pallas(z, h, t_tile=4, interpret=True)
    want = ref.bloom_ce_ref(z, h)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-2 if dtype == jnp.bfloat16 else 1e-5,
                               atol=2e-2 if dtype == jnp.bfloat16 else 1e-5)


def test_ops_match_model_layer_oracles():
    """kernels.ops wrappers == repro.core jnp implementations end to end."""
    from repro.core import losses
    from repro.core.bloom import decode_scores
    spec = BloomSpec(d=500, m=128, k=4, seed=3)
    table = jax.random.normal(KEY, (128, 64))
    tokens = jax.random.randint(KEY, (2, 5), 0, 500)

    got = ops.bloom_embed(table, tokens, spec)
    idx = spec.indices_for(tokens)
    want = jnp.take(table, idx, axis=0).sum(axis=2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5)

    logits = jax.random.normal(KEY, (2, 5, 128))
    labels = jax.random.randint(KEY, (2, 5), 0, 500)
    got = ops.bloom_ce(logits, labels, spec)
    want = losses.bloom_xent_label(spec, logits, labels)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)

    logp = jax.nn.log_softmax(jax.random.normal(KEY, (3, 128)))
    got = ops.bloom_decode(logp, spec)
    want = decode_scores(spec, logp)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_pallas_io_impl_in_model():
    """A model configured with io_impl='pallas' must match io_impl='xla'."""
    from repro import configs
    from repro.models import transformer as tf
    cfg_x = configs.get_smoke_config("qwen3-4b", dtype="float32")
    import dataclasses
    cfg_p = dataclasses.replace(cfg_x, io_impl="pallas")
    params = tf.lm_init(KEY, cfg_x)
    toks = jax.random.randint(KEY, (2, 8), 0, cfg_x.vocab)
    lx, _ = tf.lm_loss_fn(params, cfg_x, {"tokens": toks})
    lp, _ = tf.lm_loss_fn(params, cfg_p, {"tokens": toks})
    assert float(lx) == pytest.approx(float(lp), rel=1e-5)
