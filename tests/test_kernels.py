"""Pallas kernels vs ref.py oracles: shape/dtype sweeps and custom-VJP
gradient checks (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.bloom import BloomSpec
from repro.kernels import ops, ref
from repro.kernels.bloom_ce import bloom_ce_pallas
from repro.kernels.bloom_decode import bloom_decode_pallas
from repro.kernels.bloom_decode_topk import bloom_decode_topk_pallas
from repro.kernels.bloom_embed import bloom_embed_pallas

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("T,k,m,D", [
    (1, 1, 16, 32), (7, 3, 64, 48), (32, 4, 128, 256), (13, 8, 256, 100),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_bloom_embed_sweep(T, k, m, D, dtype):
    table = jax.random.normal(KEY, (m, D), dtype)
    idx = jax.random.randint(jax.random.fold_in(KEY, 1), (T, k), 0, m)
    got = bloom_embed_pallas(table, idx, d_tile=64, interpret=True)
    want = ref.bloom_embed_ref(table, idx)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-2 if dtype == jnp.bfloat16 else 1e-6,
                               atol=1e-2 if dtype == jnp.bfloat16 else 1e-6)


@pytest.mark.parametrize("B,m,d,k", [
    (1, 32, 100, 1), (5, 64, 333, 3), (8, 128, 1024, 4), (3, 96, 50, 2),
])
def test_bloom_decode_sweep(B, m, d, k):
    logp = jax.nn.log_softmax(jax.random.normal(KEY, (B, m)))
    H = jax.random.randint(jax.random.fold_in(KEY, 2), (d, k), 0, m)
    got = bloom_decode_pallas(logp, H, b_tile=4, v_tile=64, interpret=True)
    want = ref.bloom_decode_ref(logp, H)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("T,m,k", [
    (1, 16, 1), (9, 64, 4), (32, 128, 3), (17, 256, 8),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_bloom_ce_sweep(T, m, k, dtype):
    z = jax.random.normal(KEY, (T, m), dtype)
    h = jax.random.randint(jax.random.fold_in(KEY, 3), (T, k), 0, m)
    got = bloom_ce_pallas(z, h, t_tile=4, interpret=True)
    want = ref.bloom_ce_ref(z, h)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-2 if dtype == jnp.bfloat16 else 1e-5,
                               atol=2e-2 if dtype == jnp.bfloat16 else 1e-5)


def test_ops_match_model_layer_oracles():
    """kernels.ops wrappers == repro.core jnp implementations end to end."""
    from repro.core import losses
    from repro.core.bloom import decode_scores
    spec = BloomSpec(d=500, m=128, k=4, seed=3)
    table = jax.random.normal(KEY, (128, 64))
    tokens = jax.random.randint(KEY, (2, 5), 0, 500)

    got = ops.bloom_embed(table, tokens, spec)
    idx = spec.indices_for(tokens)
    want = jnp.take(table, idx, axis=0).sum(axis=2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5)

    logits = jax.random.normal(KEY, (2, 5, 128))
    labels = jax.random.randint(KEY, (2, 5), 0, 500)
    got = ops.bloom_ce(logits, labels, spec)
    want = losses.bloom_xent_label(spec, logits, labels)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)

    logp = jax.nn.log_softmax(jax.random.normal(KEY, (3, 128)))
    got = ops.bloom_decode(logp, spec)
    want = decode_scores(spec, logp)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("B,m,d,k,topk", [
    (1, 32, 100, 1, 1), (5, 64, 333, 3, 8), (8, 128, 1024, 4, 16),
    (3, 96, 50, 2, 50),   # topk == d: full sort equivalence
])
def test_bloom_decode_topk_sweep(B, m, d, k, topk):
    """Fused streaming decode-topk == decode-then-top_k, without the (B, d)
    intermediate."""
    logp = jax.nn.log_softmax(jax.random.normal(KEY, (B, m)))
    H = jax.random.randint(jax.random.fold_in(KEY, 2), (d, k), 0, m)
    vals, ids = bloom_decode_topk_pallas(logp, H, topk, b_tile=4, v_tile=64,
                                         interpret=True)
    scores = ref.bloom_decode_ref(logp, H)
    want_v, _ = jax.lax.top_k(scores, topk)
    np.testing.assert_allclose(np.asarray(vals), np.asarray(want_v),
                               rtol=1e-5, atol=1e-5)
    # ids must point at rows achieving those scores (ties may permute ids)
    picked = jnp.take_along_axis(scores, ids, axis=-1)
    np.testing.assert_allclose(np.asarray(picked), np.asarray(want_v),
                               rtol=1e-5, atol=1e-5)
    assert int(ids.min()) >= 0 and int(ids.max()) < d


def test_bloom_decode_topk_masked_vocab_never_yields_sentinel_ids():
    """-inf log-probs (masked vocab) must yield real vocab ids and the same
    lowest-index tie ordering as decode-then-top_k — no -1 sentinels."""
    B, m, d, k, topk = 3, 32, 300, 2, 8
    logp = jax.nn.log_softmax(jax.random.normal(KEY, (B, m)))
    # mask most of the m-space: the vast majority of Eq. 3 scores hit -inf
    logp = logp.at[:, 4:].set(-jnp.inf)
    H = jax.random.randint(jax.random.fold_in(KEY, 2), (d, k), 0, m)
    vals, ids = bloom_decode_topk_pallas(logp, H, topk, b_tile=2, v_tile=64,
                                         interpret=True)
    scores = ref.bloom_decode_ref(logp, H)
    want_v, want_i = jax.lax.top_k(scores, topk)
    np.testing.assert_array_equal(np.asarray(ids), np.asarray(want_i))
    np.testing.assert_allclose(np.asarray(vals), np.asarray(want_v))
    assert int(ids.min()) >= 0


@pytest.mark.parametrize("occupancy", [1 / 8, 1 / 2, 1.0])
@pytest.mark.parametrize("b_tile", [1, 4])
def test_bloom_decode_topk_row_skipping_matches_dense(occupancy, b_tile):
    """The slot-occupancy-prefetched grid == the dense grid on every row
    block containing a live slot, and (-inf, 0) on fully-dead blocks —
    exactly the post-hoc masking recover_topk applies (DESIGN.md §8).
    With b_tile=1 that is per-slot-row skipping."""
    B, m, d, k, topk = 8, 64, 333, 3, 5
    logp = jax.nn.log_softmax(jax.random.normal(KEY, (B, m)))
    H = jax.random.randint(jax.random.fold_in(KEY, 2), (d, k), 0, m)
    active = np.zeros(B, bool)
    active[:max(1, int(B * occupancy))] = True

    vals, ids = bloom_decode_topk_pallas(
        logp, H, topk, b_tile=b_tile, v_tile=64, interpret=True,
        active=jnp.asarray(active))
    dense_v, dense_i = bloom_decode_topk_pallas(
        logp, H, topk, b_tile=b_tile, v_tile=64, interpret=True)

    live_block = active.reshape(-1, b_tile).any(axis=1).repeat(b_tile)
    np.testing.assert_array_equal(np.asarray(vals)[live_block],
                                  np.asarray(dense_v)[live_block])
    np.testing.assert_array_equal(np.asarray(ids)[live_block],
                                  np.asarray(dense_i)[live_block])
    assert np.all(np.asarray(vals)[~live_block] == -np.inf)
    assert np.all(np.asarray(ids)[~live_block] == 0)


def test_bloom_decode_topk_row_skipping_scattered_occupancy():
    """Non-contiguous live slots (the realistic mid-flight pool): blocks
    are skipped wherever a whole b_tile of slots drained, and the pinned
    logp/H index maps never corrupt a later live block's output."""
    B, m, d, k, topk = 12, 48, 257, 2, 4
    logp = jax.nn.log_softmax(jax.random.normal(KEY, (B, m)))
    H = jax.random.randint(jax.random.fold_in(KEY, 3), (d, k), 0, m)
    # live, dead, dead, live blocks at b_tile=3
    active = np.array([True, False, True,
                       False, False, False,
                       False, False, False,
                       False, True, False])
    vals, ids = bloom_decode_topk_pallas(
        logp, H, topk, b_tile=3, v_tile=64, interpret=True,
        active=jnp.asarray(active))
    dense_v, dense_i = bloom_decode_topk_pallas(
        logp, H, topk, b_tile=3, v_tile=64, interpret=True)
    live_block = active.reshape(-1, 3).any(axis=1).repeat(3)
    np.testing.assert_array_equal(np.asarray(vals)[live_block],
                                  np.asarray(dense_v)[live_block])
    np.testing.assert_array_equal(np.asarray(ids)[live_block],
                                  np.asarray(dense_i)[live_block])
    assert np.all(np.asarray(vals)[~live_block] == -np.inf)

    # leading dead blocks (low slots drained first — forward pin path):
    # only the LAST block is live
    active2 = np.zeros(B, bool)
    active2[-2] = True
    vals2, ids2 = bloom_decode_topk_pallas(
        logp, H, topk, b_tile=3, v_tile=64, interpret=True,
        active=jnp.asarray(active2))
    np.testing.assert_array_equal(np.asarray(vals2)[-3:],
                                  np.asarray(dense_v)[-3:])
    np.testing.assert_array_equal(np.asarray(ids2)[-3:],
                                  np.asarray(dense_i)[-3:])
    assert np.all(np.asarray(vals2)[:-3] == -np.inf)
    assert np.all(np.asarray(ids2)[:-3] == 0)


def test_recover_topk_active_mask_drives_row_skipping_kernel():
    """io.recover_topk(active=...) on the pallas path returns the same
    (scores, ids) as the xla path with the same mask — the kernel-level
    block skipping composes with the row-level post-mask."""
    import dataclasses
    from repro import configs
    from repro.models import io as io_lib

    cfg = configs.get_smoke_config("qwen1.5-0.5b")
    B = 6
    logits = jax.random.normal(KEY, (B, cfg.m_vocab))
    active = jnp.asarray(np.array([True, False, True, False, False, True]))
    cfg_x = dataclasses.replace(cfg, io_impl="xla")
    cfg_p = dataclasses.replace(cfg, io_impl="pallas")
    sx, ix = io_lib.recover_topk(cfg_x, logits, topk=4, active=active)
    sp, ip = io_lib.recover_topk(cfg_p, logits, topk=4, active=active)
    np.testing.assert_allclose(np.asarray(sx), np.asarray(sp),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(ix), np.asarray(ip))
    assert np.all(np.asarray(sp)[~np.asarray(active)] == -np.inf)
    assert np.all(np.asarray(ip)[~np.asarray(active)] == 0)


# --------------------------------------------------------------------------
# custom-VJP gradients vs the XLA oracles (acceptance: <= 1e-4 max abs err)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("T,k,m,D", [
    (1, 1, 16, 32), (7, 3, 64, 48), (32, 4, 128, 256), (13, 8, 256, 100),
])
def test_bloom_embed_grad(T, k, m, D):
    """Scatter-add backward kernel == XLA gather-sum gradient."""
    table = jax.random.normal(KEY, (m, D))
    idx = jax.random.randint(jax.random.fold_in(KEY, 1), (T, k), 0, m)
    cot = jax.random.normal(jax.random.fold_in(KEY, 9), (T, D))
    g_pal = jax.grad(lambda t: jnp.sum(
        bloom_embed_pallas(t, idx, d_tile=64, interpret=True) * cot))(table)
    g_ref = jax.grad(lambda t: jnp.sum(
        ref.bloom_embed_ref(t, idx) * cot))(table)
    np.testing.assert_allclose(np.asarray(g_pal), np.asarray(g_ref),
                               atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("T,m,k", [
    (1, 16, 1), (9, 64, 4), (32, 128, 3), (17, 256, 8),
])
def test_bloom_ce_grad(T, m, k):
    """lse-residual backward kernel == XLA softmax-CE gradient."""
    z = jax.random.normal(KEY, (T, m))
    h = jax.random.randint(jax.random.fold_in(KEY, 3), (T, k), 0, m)
    cot = jax.random.normal(jax.random.fold_in(KEY, 9), (T,))
    g_pal = jax.grad(lambda zz: jnp.sum(
        bloom_ce_pallas(zz, h, t_tile=4, interpret=True) * cot))(z)
    g_ref = jax.grad(lambda zz: jnp.sum(
        ref.bloom_ce_ref(zz, h) * cot))(z)
    np.testing.assert_allclose(np.asarray(g_pal), np.asarray(g_ref),
                               atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("B,m,d,k", [
    (1, 32, 100, 1), (5, 64, 333, 3), (8, 128, 1024, 4),
])
def test_bloom_decode_grad(B, m, d, k):
    """Blocked scatter-add backward kernel == XLA Eq. 3 gradient."""
    logp = jax.nn.log_softmax(jax.random.normal(KEY, (B, m)))
    H = jax.random.randint(jax.random.fold_in(KEY, 2), (d, k), 0, m)
    cot = jax.random.normal(jax.random.fold_in(KEY, 9), (B, d))
    g_pal = jax.grad(lambda lp: jnp.sum(
        bloom_decode_pallas(lp, H, b_tile=4, v_tile=64,
                            interpret=True) * cot))(logp)
    g_ref = jax.grad(lambda lp: jnp.sum(
        ref.bloom_decode_ref(lp, H) * cot))(logp)
    np.testing.assert_allclose(np.asarray(g_pal), np.asarray(g_ref),
                               atol=1e-4, rtol=1e-4)


def test_interpret_defaults_to_backend_autodetect():
    """Satellite: no `interpret=` arg must NOT force interpret mode on TPU —
    kernels resolve it from the backend (True here: CPU test box)."""
    from repro.kernels.common import resolve_interpret
    assert resolve_interpret(None) == (jax.default_backend() != "tpu")
    assert resolve_interpret(True) is True
    assert resolve_interpret(False) is False
    # entry points accept interpret=None end to end
    table = jax.random.normal(KEY, (32, 16))
    idx = jax.random.randint(KEY, (4, 2), 0, 32)
    out = bloom_embed_pallas(table, idx)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.bloom_embed_ref(table, idx)),
                               rtol=1e-6, atol=1e-6)


def test_grad_through_model_pallas_vs_xla():
    """jax.grad of the full LM loss: io_impl='pallas' == io_impl='xla'."""
    import dataclasses
    from repro import configs
    from repro.models import transformer as tf
    cfg_x = configs.get_smoke_config("qwen3-4b", dtype="float32")
    cfg_p = dataclasses.replace(cfg_x, io_impl="pallas")
    params = tf.lm_init(KEY, cfg_x)
    toks = jax.random.randint(KEY, (2, 8), 0, cfg_x.vocab)

    def loss(p, cfg):
        l, _ = tf.lm_loss_fn(p, cfg, {"tokens": toks})
        return l

    gx = jax.grad(loss)(params, cfg_x)
    gp = jax.grad(loss)(params, cfg_p)
    flat_x = jax.tree.leaves(gx)
    flat_p = jax.tree.leaves(gp)
    for a, b in zip(flat_x, flat_p):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-4, rtol=1e-3)


def test_pallas_io_impl_in_model():
    """A model configured with io_impl='pallas' must match io_impl='xla'."""
    from repro import configs
    from repro.models import transformer as tf
    cfg_x = configs.get_smoke_config("qwen3-4b", dtype="float32")
    import dataclasses
    cfg_p = dataclasses.replace(cfg_x, io_impl="pallas")
    params = tf.lm_init(KEY, cfg_x)
    toks = jax.random.randint(KEY, (2, 8), 0, cfg_x.vocab)
    lx, _ = tf.lm_loss_fn(params, cfg_x, {"tokens": toks})
    lp, _ = tf.lm_loss_fn(params, cfg_p, {"tokens": toks})
    assert float(lx) == pytest.approx(float(lp), rel=1e-5)
