"""Shared fixtures. NOTE: no XLA_FLAGS here on purpose — smoke tests and
benches must see the real device count (1 CPU); only launch/dryrun.py
forces 512 placeholder devices (in its own process)."""
import os

import jax
import numpy as np
import pytest


def subprocess_env():
    """Clean env for driver subprocess tests.

    PATH stays stripped to the system dirs on purpose (drivers must not
    lean on the dev shell), but JAX backend selection has to survive the
    strip: without JAX_PLATFORMS the child process probes for accelerator
    runtimes at import and hangs on CPU-only CI boxes.
    """
    env = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root"}
    for var in ("JAX_PLATFORMS", "XLA_FLAGS", "XLA_PYTHON_CLIENT_PREALLOCATE"):
        if var in os.environ:
            env[var] = os.environ[var]
    env.setdefault("JAX_PLATFORMS", jax.default_backend())
    return env


def assert_slot_log_sound(sched, n_slots):
    """Shared invariant check on a serving scheduler's event log — thin
    wrapper over THE replay helper (serving/control.replay_slot_log):
    admissions/releases per slot alternate with matching rids through any
    COMPACT remaps, i.e. no slot ever hosts two live requests and no
    live request is dropped by a compaction.  REJECT (prefill exhausted)
    and RECLAIM (HOST_DOWN) events vacate slots like releases and are
    replayed under the same invariant.  Used by the deterministic sim
    tests, the chaos twins, and the hypothesis property suite."""
    from repro.serving.control import replay_slot_log
    replay_slot_log(sched.admissions, sched.releases,
                    getattr(sched, "compactions", []), n_slots,
                    rejects=getattr(sched, "rejects", []),
                    reclaims=getattr(sched, "reclaims", []))


@pytest.fixture
def rng():
    return np.random.default_rng(0)


@pytest.fixture
def key():
    return jax.random.PRNGKey(0)
