"""Shared fixtures. NOTE: no XLA_FLAGS here on purpose — smoke tests and
benches must see the real device count (1 CPU); only launch/dryrun.py
forces 512 placeholder devices (in its own process)."""
import os

import jax
import numpy as np
import pytest


def subprocess_env():
    """Clean env for driver subprocess tests.

    PATH stays stripped to the system dirs on purpose (drivers must not
    lean on the dev shell), but JAX backend selection has to survive the
    strip: without JAX_PLATFORMS the child process probes for accelerator
    runtimes at import and hangs on CPU-only CI boxes.
    """
    env = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root"}
    for var in ("JAX_PLATFORMS", "XLA_FLAGS", "XLA_PYTHON_CLIENT_PREALLOCATE"):
        if var in os.environ:
            env[var] = os.environ[var]
    env.setdefault("JAX_PLATFORMS", jax.default_backend())
    return env


def assert_slot_log_sound(sched, n_slots):
    """Shared invariant check on a serving Scheduler's event log: per
    slot, admissions/releases strictly alternate (ordered by the global
    event seq) with matching rids — i.e. no slot ever hosts two live
    requests.  Used by the deterministic sim test and the hypothesis
    property suite."""
    for slot in range(n_slots):
        events = sorted(
            [(seq, 0, rid) for _, s, rid, seq in sched.admissions
             if s == slot]
            + [(seq, 1, rid) for _, s, rid, seq in sched.releases
               if s == slot])
        assert [kind for _, kind, _ in events] == \
            [i % 2 for i in range(len(events))]
        for i in range(0, len(events), 2):
            assert events[i][2] == events[i + 1][2]


@pytest.fixture
def rng():
    return np.random.default_rng(0)


@pytest.fixture
def key():
    return jax.random.PRNGKey(0)
