"""Mamba-2 SSD: chunked vs sequential oracle, decode continuation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import MambaConfig, ModelConfig
from repro.models import mamba2

KEY = jax.random.PRNGKey(0)


def _cfg(chunk=4, d_state=8, head_dim=8, d_model=32):
    return ModelConfig(name="m", family="ssm", num_layers=1,
                       d_model=d_model, d_ff=0, vocab=64, dtype="float32",
                       mamba=MambaConfig(d_state=d_state, head_dim=head_dim,
                                         expand=2, chunk=chunk))


@pytest.mark.parametrize("chunk,S", [(4, 12), (3, 12), (6, 12), (12, 12)])
def test_chunked_matches_sequential(chunk, S):
    cfg = _cfg(chunk=chunk)
    params = mamba2.mamba_init(KEY, cfg)
    x = jax.random.normal(jax.random.fold_in(KEY, 1), (2, S, 32)) * 0.3
    y1 = mamba2.mamba_apply(params, cfg, x)
    y2 = mamba2.mamba_reference(params, cfg, x)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-5)


def test_prefill_cache_then_decode_matches_full():
    cfg = _cfg(chunk=4)
    params = mamba2.mamba_init(KEY, cfg)
    S = 8
    x = jax.random.normal(jax.random.fold_in(KEY, 2), (2, S + 1, 32)) * 0.3
    full = mamba2.mamba_apply(params, cfg, x)
    _, cache = mamba2.mamba_apply(params, cfg, x[:, :S], return_cache=True)
    cache = jax.tree.map(lambda a: a.astype(jnp.float32), cache)
    y_dec, _ = mamba2.mamba_decode_step(params, cfg, x[:, S:S + 1], cache)
    np.testing.assert_allclose(np.asarray(y_dec[:, 0]),
                               np.asarray(full[:, -1]), atol=5e-4)


def test_decode_state_is_constant_size():
    cfg = _cfg()
    cache = mamba2.init_mamba_cache(cfg, batch=3)
    sizes = {k: v.shape for k, v in cache.items()}
    # no sequence-length dimension anywhere
    assert sizes["ssm"] == (3, 8, 8, 8)  # (B, H, N, P)
    assert sizes["conv_x"][1] == cfg.mamba.d_conv - 1


def test_gradients_flow():
    cfg = _cfg()
    params = mamba2.mamba_init(KEY, cfg)
    x = jax.random.normal(KEY, (1, 8, 32)) * 0.3

    def loss(p):
        return (mamba2.mamba_apply(p, cfg, x) ** 2).sum()

    g = jax.grad(loss)(params)
    norms = jax.tree.map(lambda a: float(jnp.linalg.norm(a)), g)
    flat = jax.tree.leaves(norms)
    assert all(np.isfinite(v) for v in flat)
    assert sum(flat) > 0


def test_multi_group_broadcast():
    cfg = ModelConfig(name="m", family="ssm", num_layers=1, d_model=32,
                      d_ff=0, vocab=64, dtype="float32",
                      mamba=MambaConfig(d_state=8, head_dim=8, expand=2,
                                        n_groups=2, chunk=4))
    params = mamba2.mamba_init(KEY, cfg)
    x = jax.random.normal(KEY, (2, 8, 32)) * 0.3
    y1 = mamba2.mamba_apply(params, cfg, x)
    y2 = mamba2.mamba_reference(params, cfg, x)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-5)
