"""Deterministic simulation tests for the continuous-batching engine.

Everything here runs seeded on CPU (interpret-mode friendly shapes):
  * token-level equivalence — a request served through the slot pool is
    BIT-identical to serving it alone through the static path (per-row
    decode math is row-independent; the masked slot cache write stores
    the same values as the static dynamic-slice write);
  * scheduler soundness on the real engine — no slot double-assigned,
    every admitted request completes;
  * the throughput claim — continuous batching finishes the mixed-length
    loadgen workload in >= 1.5x fewer decode steps than static batching.
"""
import jax
import numpy as np
import pytest

from repro import configs
from repro.launch import steps as steps_lib
from repro.serving import (Engine, LoadSpec, Request, make_workload,
                           mixed_length_workload)

ARCH = "qwen1.5-0.5b"
N_SLOTS = 3
MAX_LEN = 40


@pytest.fixture(scope="module")
def served():
    """One continuous run of the canonical mixed-length workload, plus
    the per-request solo static runs, shared across the tests below."""
    cfg = configs.get_smoke_config(ARCH)
    params = steps_lib.cast_params_for_compute(
        steps_lib.init_fn_for(cfg)(jax.random.PRNGKey(0)), cfg)

    engine = Engine(cfg, params, n_slots=N_SLOTS, max_len=MAX_LEN, topk=4)
    results, stats = engine.run(mixed_length_workload(cfg.vocab, 10, seed=0))

    solo = Engine(cfg, params, n_slots=1, max_len=MAX_LEN, topk=4)
    solo_tokens = {}
    for req in mixed_length_workload(cfg.vocab, 10, seed=0):
        req.arrival_step = 0
        r, _ = solo.run_static([req])
        solo_tokens[req.rid] = r[req.rid].tokens

    static_results, static_stats = engine.run_static(
        mixed_length_workload(cfg.vocab, 10, seed=0))
    return dict(cfg=cfg, engine=engine, results=results, stats=stats,
                solo_tokens=solo_tokens, static_results=static_results,
                static_stats=static_stats)


def test_tokens_bit_identical_to_solo_static(served):
    """Paper Fig. 3 serving path: pooling requests must not change a
    single recovered token vs serving each request alone."""
    assert served["results"], "workload produced no results"
    for rid, req in served["results"].items():
        assert req.tokens == served["solo_tokens"][rid], (
            f"req {rid}: continuous {req.tokens} != solo "
            f"{served['solo_tokens'][rid]}")


def test_every_request_completes_no_slot_double_assigned(served):
    results = served["results"]
    assert all(r.done for r in results.values())
    assert all(len(r.tokens) >= 1 for r in results.values())
    # each request respects its generation budget
    assert all(len(r.tokens) <= r.max_gen for r in results.values())

    # reconstruct slot occupancy from the scheduler event log (ordered by
    # the global event sequence — several events can share a clock step)
    from conftest import assert_slot_log_sound
    sched = served["engine"]._sched
    assert {rid for _, _, rid, _ in sched.admissions} == set(results)
    assert len(sched.admissions) == len(results)     # admitted exactly once
    assert len(sched.releases) == len(results)
    assert_slot_log_sound(sched, N_SLOTS)


def test_continuous_beats_static_by_1_5x(served):
    cont, stat = served["stats"], served["static_stats"]
    assert cont.decode_steps > 0
    assert stat.decode_steps >= 1.5 * cont.decode_steps, (
        f"static {stat.decode_steps} vs continuous {cont.decode_steps}")
    assert cont.utilization > stat.utilization
    # same total work either way — only the schedule differs
    assert cont.tokens_out == stat.tokens_out
    for rid, req in served["static_results"].items():
        assert req.tokens == served["solo_tokens"][rid]


def test_eos_stops_a_slot_early(served):
    """Rerun the same deterministic workload with eos_id set to a token
    known (from the baseline run) to appear mid-stream; that request must
    retire at the eos while the others are unaffected up to their own
    first eos occurrence."""
    baseline = served["solo_tokens"]
    victim = max(baseline, key=lambda r: len(baseline[r]))
    toks = baseline[victim]
    assert len(toks) >= 3, "need a long request to cut short"
    eos = toks[len(toks) // 2]

    cfg = served["cfg"]
    engine = Engine(cfg, served["engine"].params, n_slots=N_SLOTS,
                    max_len=MAX_LEN, topk=4, eos_id=eos)
    results, _ = engine.run(mixed_length_workload(cfg.vocab, 10, seed=0))
    for rid, req in results.items():
        full = baseline[rid]
        cut = (full[:full.index(eos) + 1] if eos in full else full)
        assert req.tokens == cut, (rid, req.tokens, cut)
    assert len(results[victim].tokens) < len(baseline[victim])


def test_engine_rejects_overlong_request():
    cfg = configs.get_smoke_config(ARCH)
    params = steps_lib.cast_params_for_compute(
        steps_lib.init_fn_for(cfg)(jax.random.PRNGKey(0)), cfg)
    engine = Engine(cfg, params, n_slots=1, max_len=8, topk=2)
    req = Request(rid=0, prompt=np.zeros((6,), np.int32), max_gen=6)
    with pytest.raises(AssertionError, match="exceeds pool max_len"):
        engine.run([req])


def test_prefill_pool_is_schedule_and_token_invariant(served):
    """Prefill pool satellite (DESIGN.md §9): a burst served through a
    3-worker pool produces the EXACT tokens of the 1-worker pool (and of
    solo static serving), with FIFO dispatch spreading the burst across
    all workers and the summed virtual queue wait strictly shrinking."""
    from repro.serving import LoadSpec, burst_workload

    cfg = served["cfg"]
    spec = LoadSpec(n_requests=6, vocab=cfg.vocab, prompt_lens=(6, 10, 14),
                    gen_lens=(3, 6), seed=1)
    max_len = 24

    stats = {}
    tokens = {}
    for n_workers in (1, 3):
        engine = Engine(cfg, served["engine"].params, n_slots=6,
                        max_len=max_len, topk=4,
                        prefill_workers=n_workers)
        results, st = engine.run(burst_workload(spec))
        tokens[n_workers] = {rid: r.tokens for rid, r in results.items()}
        stats[n_workers] = (engine.prefill_pool.stats, st)
    assert tokens[1] == tokens[3]
    assert stats[1][1].decode_steps == stats[3][1].decode_steps

    pool1, pool3 = stats[1][0], stats[3][0]
    assert pool1["jobs"] == pool3["jobs"] == 6
    assert pool1["per_worker"] == [6]
    assert len(pool3["per_worker"]) == 3
    assert sum(pool3["per_worker"]) == 6
    assert all(c > 0 for c in pool3["per_worker"])   # burst spreads out
    assert pool3["max_queue_depth"] == pool1["max_queue_depth"] == 6
    # head-of-line blocking: 1 worker serializes the burst, 3 overlap it
    assert pool3["wait_units"] < pool1["wait_units"]


def test_engine_prefill_retry_and_reject_via_failpoints(served):
    """Failure-model satellite on the REAL engine: a prefill fault below
    the attempt cap is retried on another worker and every token stays
    bit-identical; AT the cap the victim is REJECTed (slot freed, logged)
    while every other request is served untouched."""
    from repro.serving import FailPlan, PREFILL_MAX_ATTEMPTS

    cfg = served["cfg"]
    baseline = served["solo_tokens"]
    victim = max(baseline, key=lambda r: len(baseline[r]))

    # below the cap: retries absorb the fault — schedule/token invariant
    engine = Engine(cfg, served["engine"].params, n_slots=N_SLOTS,
                    max_len=MAX_LEN, topk=4, prefill_workers=2,
                    failpoints=FailPlan.parse(
                        f"fail_prefill:{victim}:{PREFILL_MAX_ATTEMPTS - 1}"))
    results, st = engine.run(mixed_length_workload(cfg.vocab, 10, seed=0))
    assert st.rejects == 0
    assert engine.prefill_pool.stats["retries"] == PREFILL_MAX_ATTEMPTS - 1
    assert engine.prefill_pool.stats["rejects"] == 0
    for rid, req in results.items():
        assert req.tokens == baseline[rid]

    # at the cap: REJECT — the victim ends unserved, everyone else is
    # bit-identical to the fault-free baseline
    engine = Engine(cfg, served["engine"].params, n_slots=N_SLOTS,
                    max_len=MAX_LEN, topk=4, prefill_workers=2,
                    failpoints=FailPlan.parse(
                        f"fail_prefill:{victim}:{PREFILL_MAX_ATTEMPTS}"))
    results, st = engine.run(mixed_length_workload(cfg.vocab, 10, seed=0))
    assert st.rejects == 1
    assert engine.prefill_pool.stats["rejects"] == 1
    assert results[victim].rejected and results[victim].tokens == []
    for rid, req in results.items():
        if rid != victim:
            assert not req.rejected
            assert req.tokens == baseline[rid]
    from conftest import assert_slot_log_sound
    assert_slot_log_sound(engine._sched, N_SLOTS)


def test_overload_sheds_and_degrades_without_recompiling(served):
    """ISSUE 10 on the single-host engine: a surge + slow_decode plan
    overloads the pool under an AdmissionPolicy; expired/over-bound
    requests are SHED (never admitted, zero tokens), every SERVED
    request's tokens stay bit-identical to the unloaded solo baseline
    (degradation narrows the served top-k; the next token is the top-1
    id, invariant under the width), the ladder escalates AND restores,
    and no DEGRADE/RESTORE ever compiles a new decode executable."""
    from repro.serving import AdmissionPolicy, FailPlan
    from repro.serving.admission import STAGE_NORMAL

    cfg = served["cfg"]
    baseline = served["solo_tokens"]
    policy = AdmissionPolicy(max_queue_depth=2, pressure_window=2,
                             degrade_lo=0.25, degrade_hi=0.5,
                             restore_below=0.1)
    engine = Engine(cfg, served["engine"].params, n_slots=N_SLOTS,
                    max_len=MAX_LEN, topk=4,
                    failpoints=FailPlan.parse("surge:3@1,slow_decode:3@2"),
                    admission_policy=policy)
    workload = mixed_length_workload(cfg.vocab, 10, seed=0)
    for r in workload:
        r.deadline_step = r.arrival_step + 6
    results, st = engine.run(workload)

    shed = {rid for rid, r in results.items() if r.shed}
    assert st.sheds == len(shed) > 0, "surge shed nothing — vacuous"
    assert st.degrades >= 2, "ladder never escalated AND restored"
    degr = engine._sched.degrades
    assert any(new > old for _, old, new, _ in degr)
    assert any(new < old for _, old, new, _ in degr)
    assert len(engine._sched.sheds) == st.sheds
    for rid, r in results.items():
        assert r.done, rid
        if r.shed:
            assert r.admitted_step < 0 and r.tokens == [], rid
        else:
            assert r.tokens == baseline[rid], (
                f"req {rid} token drift under degradation")
    # zero recompiles: each pre-built stage executable compiled at most
    # once; stage 0 exactly once; and the program ends restored
    for stage, fn in engine.program._stage_decodes.items():
        assert fn._cache_size() <= 1, f"stage {stage} recompiled"
    assert engine.program._stage_decodes[STAGE_NORMAL]._cache_size() == 1
    assert engine.program._stage == STAGE_NORMAL
    from conftest import assert_slot_log_sound
    assert_slot_log_sound(engine._sched, N_SLOTS)

    # the identical (workload, plan, policy) replays the identical shed
    # set and log — shed decisions are deterministic
    twin_engine = Engine(cfg, served["engine"].params, n_slots=N_SLOTS,
                         max_len=MAX_LEN, topk=4,
                         failpoints=FailPlan.parse(
                             "surge:3@1,slow_decode:3@2"),
                         admission_policy=policy)
    twin_wl = mixed_length_workload(cfg.vocab, 10, seed=0)
    for r in twin_wl:
        r.deadline_step = r.arrival_step + 6
    twin_results, twin_st = twin_engine.run(twin_wl)
    assert {rid for rid, r in twin_results.items() if r.shed} == shed
    assert twin_engine._sched.sheds == engine._sched.sheds
    assert twin_engine._sched.degrades == engine._sched.degrades
    assert (twin_st.as_row(), twin_st.sheds, twin_st.degrades) == \
        (st.as_row(), st.sheds, st.degrades)   # wall_s alone may differ


def test_loadgen_is_deterministic():
    spec = LoadSpec(n_requests=20, vocab=128, rate=0.7, seed=123)
    a, b = make_workload(spec), make_workload(spec)
    assert [r.arrival_step for r in a] == [r.arrival_step for r in b]
    assert [r.max_gen for r in a] == [r.max_gen for r in b]
    assert all((x.prompt == y.prompt).all() for x, y in zip(a, b))
    # arrivals are sorted and lengths come from the configured mix
    arr = [r.arrival_step for r in a]
    assert arr == sorted(arr)
    assert {r.prompt_len for r in a} <= set(spec.prompt_lens)
    assert {r.max_gen for r in a} <= set(spec.gen_lens)
